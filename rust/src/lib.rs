//! # junctiond-repro
//!
//! Reproduction of *"Junctiond: Extending FaaS Runtimes with Kernel-Bypass"*
//! (Saurez et al., 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the faasd-shaped FaaS runtime (gateway →
//!   provider → execution backend), the `junctiond` function manager, a
//!   Junction kernel-bypass simulator, the `containerd` baseline backend,
//!   and the discrete-event substrate that replaces the paper's two-machine
//!   100 GbE testbed.
//! * **Layer 2/1 (python/, build-time only)** — the function bodies (AES-128
//!   -CTR over a 600-byte payload, MLP inference, row-sum) written in JAX
//!   with Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt`.
//!
//! The Rust binary loads the artifacts through the PJRT CPU client (`xla`
//! crate) and executes the *real* function compute on the request path;
//! Python never runs at serve time.
//!
//! See `DESIGN.md` for the full system inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

// Style lints the codebase deliberately tolerates; the CI clippy gate
// (-D warnings) is aimed at the correctness/perf lint classes.
#![allow(
    clippy::identity_op,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::len_without_is_empty,
    clippy::new_without_default,
    clippy::manual_range_contains,
    clippy::needless_range_loop,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if
)]

pub mod config;
pub mod containerd_sim;
pub mod experiments;
pub mod faas;
pub mod faultplane;
pub mod hostclock;
pub mod invariants;
pub mod junction;
pub mod junctiond;
pub mod netpath;
pub mod oskernel;
pub mod rpc;
pub mod runtime;
pub mod server;
pub mod simcore;
pub mod snapshot;
pub mod telemetry;
pub mod workload;
