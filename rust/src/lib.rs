//! # junctiond-repro
//!
//! Reproduction of *"Junctiond: Extending FaaS Runtimes with Kernel-Bypass"*
//! (Saurez et al., 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the faasd-shaped FaaS runtime (gateway →
//!   provider → execution backend), the `junctiond` function manager, a
//!   Junction kernel-bypass simulator, the `containerd` baseline backend,
//!   and the discrete-event substrate that replaces the paper's two-machine
//!   100 GbE testbed.
//! * **Layer 2/1 (python/, build-time only)** — the function bodies (AES-128
//!   -CTR over a 600-byte payload, MLP inference, row-sum) written in JAX
//!   with Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt`.
//!
//! The Rust binary loads the artifacts through the PJRT CPU client (`xla`
//! crate) and executes the *real* function compute on the request path;
//! Python never runs at serve time.
//!
//! See `DESIGN.md` for the full system inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod config;
pub mod containerd_sim;
pub mod experiments;
pub mod faas;
pub mod junction;
pub mod junctiond;
pub mod oskernel;
pub mod rpc;
pub mod runtime;
pub mod server;
pub mod simcore;
pub mod snapshot;
pub mod telemetry;
pub mod workload;
