//! Experiment drivers: one function per paper figure/table/claim, shared
//! by the CLI (`junctiond-repro <cmd>`), the examples, and the benches.
//!
//! See DESIGN.md §3 for the experiment index (E1..E7). Every driver
//! returns [`crate::telemetry::Table`]s so callers can print markdown or
//! dump CSV.

pub mod schedcheck;

use std::cell::RefCell;
use std::rc::Rc;

use crate::config::{Backend, ExperimentConfig, PlatformConfig};
use crate::faas::{
    run_shard_cluster, Cluster, FaasSim, FunctionSpec, RuntimeKind, ScaleMode, ShardClusterCfg,
};
use crate::hostclock::Stopwatch;
use crate::invariants::{audit_all, Audit, Violation};
use crate::junction::Scheduler;
use crate::simcore::{Sim, Time, MICROS, MILLIS, SECONDS};
use crate::telemetry::{BlameReport, Cell, LatencySummary, Table, Trace, HOP_NAMES};
use crate::workload::{ClosedLoop, OpenLoop, RunResult};

/// Calibrate `function_compute_ns` from the real AES-600B artifact when
/// available; fall back to the platform default otherwise (e.g. when
/// `make artifacts` hasn't run). Cached for the process lifetime.
pub fn calibrated_compute_ns() -> Time {
    use std::sync::OnceLock;
    static CAL: OnceLock<Time> = OnceLock::new();
    *CAL.get_or_init(|| {
        let dir = crate::runtime::default_artifacts_dir();
        match crate::runtime::Executor::load(&dir)
            .and_then(|e| crate::runtime::calibrate(&e, 30))
        {
            Ok(c) => {
                eprintln!(
                    "# calibration: aes600 p50={}µs (mean {}µs, min {}µs, n={})",
                    c.p50_ns / MICROS,
                    c.mean_ns / MICROS,
                    c.min_ns / MICROS,
                    c.runs
                );
                c.p50_ns
            }
            Err(e) => {
                let d = PlatformConfig::default().function_compute_ns;
                eprintln!("# calibration unavailable ({e}); using default {}µs", d / MICROS);
                d
            }
        }
    })
}

/// Build the standard experiment config for a backend (paper testbed:
/// 10-core worker).
pub fn standard_config(backend: Backend, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        backend,
        provider_cache: true,
        worker_cores: 10,
        seed,
        function_compute_ns: calibrated_compute_ns(),
        instance_concurrency: 4,
    }
}

/// Deploy the AES function and advance past its cold start.
pub fn warm_deployment(cfg: &ExperimentConfig) -> (Sim, FaasSim) {
    let mut sim = Sim::new();
    let platform = Rc::new(PlatformConfig::default());
    let fs = FaasSim::new(cfg, platform);
    let spec = FunctionSpec::new("aes", "aes600", RuntimeKind::Go)
        .with_scale(ScaleMode::MaxCores, PlatformConfig::default().junction_max_cores as u32);
    fs.deploy(&mut sim, spec);
    sim.run_until(SECONDS);
    (sim, fs)
}

// ---------------------------------------------------------------------------
// E1 / Figure 5 — latency distribution, 100 sequential AES invocations
// ---------------------------------------------------------------------------

/// Per-backend result of the Fig. 5 workload.
pub struct Fig5Result {
    pub gateway: LatencySummary,
    pub exec: LatencySummary,
    pub gateway_cdf: Vec<(u64, f64)>,
    pub exec_cdf: Vec<(u64, f64)>,
}

pub fn fig5_run(backend: Backend, invocations: u32, seed: u64) -> Fig5Result {
    let (r, _violations) = fig5_run_audited(backend, invocations, seed);
    debug_assert!(_violations.is_empty(), "fig5 left broken invariants: {_violations:?}");
    r
}

/// [`fig5_run`] plus a full post-run invariant audit of the drained sim
/// (E5's leg of `selfcheck` / `tests/invariants.rs`).
pub fn fig5_run_audited(
    backend: Backend,
    invocations: u32,
    seed: u64,
) -> (Fig5Result, Vec<Violation>) {
    let cfg = standard_config(backend, seed);
    let (mut sim, fs) = warm_deployment(&cfg);
    let mut r = ClosedLoop::new("aes", invocations).run(&mut sim, &fs);
    let violations = audit_all(&fs);
    let result = Fig5Result {
        gateway: r.gateway_observed.summary(),
        exec: r.exec.summary(),
        gateway_cdf: r.gateway_observed.cdf(),
        exec_cdf: r.exec.cdf(),
    };
    (result, violations)
}

/// The Fig. 5 comparison table (plus the paper's claimed reductions).
pub fn fig5_table(invocations: u32, seed: u64) -> (Table, Fig5Result, Fig5Result) {
    let c = fig5_run(Backend::Containerd, invocations, seed);
    let j = fig5_run(Backend::Junctiond, invocations, seed);
    let mut t = Table::new(
        &format!("Figure 5 — latency distribution, {invocations} sequential AES-600B invocations"),
        &["metric", "containerd (µs)", "junctiond (µs)", "reduction %", "paper %"],
    );
    let red = |a: u64, b: u64| (1.0 - b as f64 / a as f64) * 100.0;
    t.push_row(vec![
        "gateway p50".into(),
        Cell::NsAsUs(c.gateway.p50),
        Cell::NsAsUs(j.gateway.p50),
        red(c.gateway.p50, j.gateway.p50).into(),
        Cell::F2(37.33),
    ]);
    t.push_row(vec![
        "gateway p99".into(),
        Cell::NsAsUs(c.gateway.p99),
        Cell::NsAsUs(j.gateway.p99),
        red(c.gateway.p99, j.gateway.p99).into(),
        Cell::F2(63.42),
    ]);
    t.push_row(vec![
        "exec p50".into(),
        Cell::NsAsUs(c.exec.p50),
        Cell::NsAsUs(j.exec.p50),
        red(c.exec.p50, j.exec.p50).into(),
        Cell::F2(35.30),
    ]);
    t.push_row(vec![
        "exec p99".into(),
        Cell::NsAsUs(c.exec.p99),
        Cell::NsAsUs(j.exec.p99),
        red(c.exec.p99, j.exec.p99).into(),
        Cell::F2(81.00),
    ]);
    (t, c, j)
}

// ---------------------------------------------------------------------------
// E2 / Figure 6 — response time vs offered load
// ---------------------------------------------------------------------------

/// Default offered-load grid (rps). Spans both knees: containerd saturates
/// in the single-digit thousands, junctiond an order of magnitude later.
pub fn fig6_default_rates() -> Vec<f64> {
    vec![
        250.0, 500.0, 1_000.0, 2_000.0, 3_000.0, 4_000.0, 5_000.0, 6_000.0, 8_000.0, 12_000.0,
        16_000.0, 24_000.0, 32_000.0, 40_000.0, 48_000.0, 56_000.0, 64_000.0, 72_000.0,
    ]
}

pub struct Fig6Point {
    pub backend: Backend,
    pub offered_rps: f64,
    pub goodput_rps: f64,
    pub p50: u64,
    pub p99: u64,
}

pub fn fig6_run(
    backend: Backend,
    rates: &[f64],
    duration: Time,
    seed: u64,
) -> Vec<Fig6Point> {
    rates
        .iter()
        .map(|&rate| {
            let cfg = standard_config(backend, seed);
            let (mut sim, fs) = warm_deployment(&cfg);
            let mut r: RunResult =
                OpenLoop::new("aes", rate, duration, seed ^ (rate as u64)).run(&mut sim, &fs);
            Fig6Point {
                backend,
                offered_rps: rate,
                goodput_rps: r.goodput_rps(),
                p50: r.gateway_observed.quantile(0.5),
                p99: r.gateway_observed.quantile(0.99),
            }
        })
        .collect()
}

pub fn fig6_table(rates: &[f64], duration: Time, seed: u64) -> (Table, Vec<Fig6Point>) {
    let mut points = fig6_run(Backend::Containerd, rates, duration, seed);
    points.extend(fig6_run(Backend::Junctiond, rates, duration, seed));
    let mut t = Table::new(
        "Figure 6 — response time at varying offered load (gateway-observed)",
        &["backend", "offered rps", "goodput rps", "p50 (µs)", "p99 (µs)"],
    );
    for p in &points {
        t.push_row(vec![
            p.backend.name().into(),
            Cell::F2(p.offered_rps),
            Cell::F2(p.goodput_rps),
            Cell::NsAsUs(p.p50),
            Cell::NsAsUs(p.p99),
        ]);
    }
    (t, points)
}

/// Sustainable throughput: the highest offered rate whose p99 stays under
/// `sla_ns` (the knee detector used for the "10×" claim).
pub fn knee(points: &[Fig6Point], backend: Backend, sla_ns: u64) -> f64 {
    points
        .iter()
        .filter(|p| p.backend == backend && p.p99 <= sla_ns)
        .map(|p| p.goodput_rps)
        .fold(0.0, f64::max)
}

// ---------------------------------------------------------------------------
// E3 — cold starts
// ---------------------------------------------------------------------------

pub fn coldstart_table(trials: u32, seed: u64) -> Table {
    let mut t = Table::new(
        "Cold starts — instance init + first-invocation latency",
        &["backend", "metric", "p50 (ms)", "p99 (ms)"],
    );
    for backend in [Backend::Containerd, Backend::Junctiond] {
        let mut init = crate::telemetry::Samples::new();
        let mut first = crate::telemetry::Samples::new();
        for k in 0..trials {
            let cfg = standard_config(backend, seed + k as u64);
            let mut sim = Sim::new();
            let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
            let cold = fs.deploy(
                &mut sim,
                FunctionSpec::new("aes", "aes600", RuntimeKind::Go),
            );
            init.record(cold);
            // First invocation immediately after deploy (pays the boot).
            let out = std::rc::Rc::new(std::cell::RefCell::new(0u64));
            let out2 = out.clone();
            fs.submit(&mut sim, "aes", move |_, timing| *out2.borrow_mut() = timing.e2e());
            sim.run_to_completion();
            first.record(*out.borrow());
        }
        let ms = 1_000_000.0;
        t.push_row(vec![
            backend.name().into(),
            "instance init".into(),
            Cell::F2(init.quantile(0.5) as f64 / ms),
            Cell::F2(init.quantile(0.99) as f64 / ms),
        ]);
        t.push_row(vec![
            backend.name().into(),
            "first invocation e2e".into(),
            Cell::F2(first.quantile(0.5) as f64 / ms),
            Cell::F2(first.quantile(0.99) as f64 / ms),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// E3b — cold-start tier sweep (snapshot/ subsystem)
// ---------------------------------------------------------------------------

/// Provisioning latency per tier of the ladder (warm pool / snapshot
/// restore / cold boot), for both backends. Each trial walks one full
/// cycle on a fresh deployment: cold boot (captures the snapshot), park +
/// warm re-acquire, then flush the pool and restore from the snapshot.
pub fn coldstart_tiers_table(trials: u32, seed: u64) -> Table {
    use crate::snapshot::ProvisionTier;
    let mut t = Table::new(
        "Cold-start tiers — provisioning latency by ladder rung",
        &["backend", "tier", "p50 (ms)", "p99 (ms)", "speedup vs cold"],
    );
    for backend in [Backend::Containerd, Backend::Junctiond] {
        let mut samples =
            [crate::telemetry::Samples::new(), crate::telemetry::Samples::new(), crate::telemetry::Samples::new()];
        for k in 0..trials {
            let cfg = standard_config(backend, seed + k as u64);
            let mut sim = Sim::new();
            let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
            let spec = FunctionSpec::new("aes", "aes600", RuntimeKind::Go);
            let (cold, tier) = fs.deploy_tiered(&mut sim, spec.clone(), true);
            assert_eq!(tier, ProvisionTier::ColdBoot);
            samples[ProvisionTier::ColdBoot.idx()].record(cold);
            // Past boot + snapshot capture, then park and re-acquire warm.
            sim.run_until(SECONDS);
            assert!(fs.undeploy(&mut sim, "aes"));
            let (warm, tier) = fs.deploy_tiered(&mut sim, spec.clone(), true);
            assert_eq!(tier, ProvisionTier::WarmPool);
            samples[ProvisionTier::WarmPool.idx()].record(warm);
            // Evict the pool: the ladder falls back to the snapshot.
            sim.run_until(2 * SECONDS);
            assert!(fs.undeploy(&mut sim, "aes"));
            fs.flush_warm_pool(&mut sim);
            let (restore, tier) = fs.deploy_tiered(&mut sim, spec, true);
            assert_eq!(tier, ProvisionTier::SnapshotRestore);
            samples[ProvisionTier::SnapshotRestore.idx()].record(restore);
            sim.run_to_completion();
        }
        let ms = 1_000_000.0;
        let cold_p50 = samples[ProvisionTier::ColdBoot.idx()].quantile(0.5);
        for tier in ProvisionTier::ALL {
            let s = &mut samples[tier.idx()];
            let p50 = s.quantile(0.5);
            t.push_row(vec![
                backend.name().into(),
                tier.name().into(),
                Cell::F2(p50 as f64 / ms),
                Cell::F2(s.quantile(0.99) as f64 / ms),
                Cell::F2(cold_p50 as f64 / p50.max(1) as f64),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// E4 — provider metadata-cache ablation (§4)
// ---------------------------------------------------------------------------

pub fn ablation_cache_table(invocations: u32, seed: u64) -> Table {
    let mut t = Table::new(
        "Ablation §4 — provider metadata cache",
        &["backend", "cache", "p50 (µs)", "p99 (µs)", "hit rate"],
    );
    for backend in [Backend::Containerd, Backend::Junctiond] {
        for cache in [true, false] {
            let mut cfg = standard_config(backend, seed);
            cfg.provider_cache = cache;
            let (mut sim, fs) = warm_deployment(&cfg);
            let mut r = ClosedLoop::new("aes", invocations).run(&mut sim, &fs);
            let (hits, misses) = fs.provider_stats();
            t.push_row(vec![
                backend.name().into(),
                if cache { "on" } else { "off" }.into(),
                Cell::NsAsUs(r.gateway_observed.quantile(0.5)),
                Cell::NsAsUs(r.gateway_observed.quantile(0.99)),
                Cell::F2(hits as f64 / (hits + misses).max(1) as f64),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// E5 — polling-core scaling (§3: "a single dedicated core [manages]
// thousands of functions")
// ---------------------------------------------------------------------------

pub fn ablation_polling_table(populations: &[u32], seed: u64) -> Table {
    let mut t = Table::new(
        "Ablation §3 — cores reserved for polling vs hosted functions (10-core server)",
        &[
            "functions",
            "junction poll cores",
            "junction usable",
            "dpdk poll cores",
            "dpdk usable",
            "junction p99 (µs) @1k rps",
        ],
    );
    const SERVER_CORES: u32 = 10;
    for &n in populations {
        // Junction: one scheduler core regardless of n (verified live below).
        let mut cfg = standard_config(Backend::Junctiond, seed);
        cfg.seed ^= n as u64;
        let (mut sim, fs) = warm_deployment(&cfg);
        // Deploy n-1 additional (idle) functions: the paper's density case.
        {
            for i in 0..n.saturating_sub(1) {
                fs.deploy(
                    &mut sim,
                    FunctionSpec::new(&format!("fn-{i:04}"), "aes600", RuntimeKind::Python),
                );
            }
            sim.run_until(sim.now() + SECONDS);
        }
        let mut r = OpenLoop::new("aes", 1_000.0, SECONDS, seed).run(&mut sim, &fs);
        let jd_poll = 1u32;
        let dpdk_poll = Scheduler::dpdk_polling_cores(n);
        t.push_row(vec![
            Cell::Int(n as i64),
            Cell::Int(jd_poll as i64),
            Cell::Int((SERVER_CORES - jd_poll) as i64),
            Cell::Int(dpdk_poll as i64),
            Cell::Int(SERVER_CORES.saturating_sub(dpdk_poll) as i64),
            Cell::NsAsUs(r.gateway_observed.quantile(0.99)),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// E6 — scale-up mode ablation (§3)
// ---------------------------------------------------------------------------

pub fn ablation_scaleup_table(rate_rps: f64, seed: u64) -> Table {
    let mut t = Table::new(
        &format!("Ablation §3 — junctiond scale-up modes @ {rate_rps} rps offered"),
        &["mode", "scale", "goodput rps", "p50 (µs)", "p99 (µs)"],
    );
    let modes: [(&str, ScaleMode, RuntimeKind); 3] = [
        ("multi-process", ScaleMode::MultiProcess, RuntimeKind::Python),
        ("max-cores", ScaleMode::MaxCores, RuntimeKind::Go),
        ("isolated", ScaleMode::IsolatedInstances, RuntimeKind::Go),
    ];
    for (name, mode, runtime) in modes {
        for scale in [1u32, 2, 4, 8] {
            let cfg = standard_config(Backend::Junctiond, seed);
            let mut sim = Sim::new();
            let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
            fs.deploy(
                &mut sim,
                FunctionSpec::new("aes", "aes600", runtime).with_scale(mode, scale),
            );
            sim.run_until(SECONDS);
            let mut r = OpenLoop::new("aes", rate_rps, SECONDS, seed).run(&mut sim, &fs);
            t.push_row(vec![
                name.into(),
                Cell::Int(scale as i64),
                Cell::F2(r.goodput_rps()),
                Cell::NsAsUs(r.gateway_observed.quantile(0.5)),
                Cell::NsAsUs(r.gateway_observed.quantile(0.99)),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// E8 — isolation surface (§3: "reducing the amount of trusted code that
// needs to be reviewed and is vulnerable to attack")
// ---------------------------------------------------------------------------

/// Host-kernel interactions per invocation, per backend. The paper argues
/// Junction's isolation qualitatively; this table quantifies it in the
/// model: how many syscall traps / kernel-stack messages / scheduler
/// wakeups one warm invocation exercises on the host kernel.
pub fn isolation_table(invocations: u32, seed: u64) -> Table {
    let mut t = Table::new(
        "Isolation §3 — host-kernel surface exercised per invocation",
        &["backend", "host syscalls/inv", "kernel msgs/inv", "host wakeups/inv", "user-space syscalls/inv"],
    );
    for backend in [Backend::Containerd, Backend::Junctiond] {
        let cfg = standard_config(backend, seed);
        let (mut sim, fs) = warm_deployment(&cfg);
        let before = fs.cost_telemetry();
        ClosedLoop::new("aes", invocations).run(&mut sim, &fs);
        let after = fs.cost_telemetry();
        let per = |a: u64, b: u64| (a - b) as f64 / invocations as f64;
        t.push_row(vec![
            backend.name().into(),
            Cell::F2(per(after.host_syscalls, before.host_syscalls)),
            Cell::F2(per(after.kernel_msgs, before.kernel_msgs)),
            Cell::F2(per(after.host_wakeups, before.host_wakeups)),
            Cell::F2(per(after.user_syscalls, before.user_syscalls)),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// E9 — cluster autoscaling (§2.1: controller + worker manager)
// ---------------------------------------------------------------------------

/// Step-load autoscaling experiment on the multi-worker cluster: offered
/// load steps low → high → low; the controller must add replicas under
/// pressure and shed them when idle.
pub fn autoscale_table(backend: Backend, seed: u64) -> Table {
    let compute = PlatformConfig::default().function_compute_ns;
    let mut sim = Sim::new();
    let mut cluster = Cluster::new(backend, 4, 10, seed, compute);
    cluster.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
    sim.run_until(SECONDS);
    let cluster = Rc::new(RefCell::new(cluster));
    Cluster::start_controller(cluster.clone(), &mut sim, 14 * SECONDS);

    // Load phases: (offset s, duration s, rps). High phase exceeds one
    // containerd replica's capacity several times over.
    let phases = [(0u64, 3u64, 1_000.0f64), (3, 4, 12_000.0), (7, 3, 1_000.0)];
    let lat: Rc<RefCell<Vec<crate::telemetry::Samples>>> = Rc::new(RefCell::new(vec![
        crate::telemetry::Samples::new(),
        crate::telemetry::Samples::new(),
        crate::telemetry::Samples::new(),
    ]));
    let replica_peak = Rc::new(RefCell::new(vec![0u32; 3]));
    let base = sim.now();
    let mut rng = crate::simcore::Rng::new(seed ^ 0xA5);
    for (pi, (off, dur, rps)) in phases.iter().enumerate() {
        let start = base + off * SECONDS;
        let end = start + dur * SECONDS;
        let mut t = start as f64;
        let gap = SECONDS as f64 / rps;
        while (t as Time) < end {
            t += rng.exp(gap);
            if (t as Time) >= end {
                break;
            }
            let c2 = cluster.clone();
            let lat2 = lat.clone();
            let peak2 = replica_peak.clone();
            sim.at(t as Time, move |sim| {
                {
                    let c = c2.borrow();
                    let r = c.replica_count("aes");
                    let mut p = peak2.borrow_mut();
                    if r > p[pi] {
                        p[pi] = r;
                    }
                }
                let lat3 = lat2.clone();
                c2.borrow_mut().submit(sim, "aes", move |_, timing| {
                    lat3.borrow_mut()[pi].record(timing.gateway_observed());
                });
            });
        }
    }
    sim.run_to_completion();

    let mut t = Table::new(
        &format!("Autoscaling step load — {} backend, 4-worker pool", backend.name()),
        &["phase", "offered rps", "peak replicas", "p50 (µs)", "p99 (µs)"],
    );
    let names = ["low", "high (12k rps)", "low again"];
    for pi in 0..3 {
        let mut l = lat.borrow_mut();
        t.push_row(vec![
            names[pi].into(),
            Cell::F2(phases[pi].2),
            Cell::Int(replica_peak.borrow()[pi] as i64),
            Cell::NsAsUs(l[pi].quantile(0.5)),
            Cell::NsAsUs(l[pi].quantile(0.99)),
        ]);
    }
    let c = cluster.borrow();
    t.push_row(vec![
        "scale events".into(),
        Cell::Str(format!("ups={} downs={}", c.scale_ups, c.scale_downs)),
        Cell::Int(c.replica_count("aes") as i64),
        Cell::Str("final".into()),
        Cell::Str("-".into()),
    ]);
    t
}

// ---------------------------------------------------------------------------
// E11 — cluster network data path (netpath): Fig. 6 at cluster scale
// ---------------------------------------------------------------------------

/// One measured point of the cluster load sweep, with the per-hop latency
/// breakdown the network model produces (NIC queue, gateway/provider
/// passes, exec window) and the NIC's drop/retry accounting.
pub struct NetPathPoint {
    pub backend: Backend,
    pub offered_rps: f64,
    pub goodput_rps: f64,
    pub p50: u64,
    pub p99: u64,
    /// Median NIC hop: RX ring wait + per-packet service (+ retransmit
    /// backoffs).
    pub nic_p50: u64,
    /// Median gateway→instance-admission span (in-worker RPC passes).
    pub gw_p50: u64,
    /// Median exec window.
    pub exec_p50: u64,
    /// Requests abandoned after the NIC retransmit budget.
    pub dropped: u64,
    /// NIC retransmissions.
    pub retries: u64,
}

/// Default offered-load grids for the cluster sweep (2×16-core workers).
/// The containerd grid spans its exec-serialization knee and ends with an
/// overload point past the kernel RX path's *aggregate* packet rate
/// (least-inflight routing splits load across both worker NICs, each good
/// for ~139k pps at ~7.2 µs/packet, so the ring only sheds past ~280k
/// offered), where the bounded NIC ring must drop; the junctiond grid
/// shares the sub-knee rates (for pointwise latency comparison) and
/// extends past 10× the containerd knee.
pub fn netpath_default_containerd_rates() -> Vec<f64> {
    vec![500.0, 1_000.0, 2_000.0, 4_000.0, 6_000.0, 9_000.0, 320_000.0]
}

pub fn netpath_default_junction_rates() -> Vec<f64> {
    vec![
        500.0, 1_000.0, 2_000.0, 4_000.0, 6_000.0, 9_000.0, 16_000.0, 32_000.0, 48_000.0,
        64_000.0, 80_000.0, 100_000.0,
    ]
}

/// Run the cluster load sweep for one backend: `n_workers` independent
/// worker servers (each with its own NIC ring, scheduler, cost samplers)
/// behind the least-inflight front end, one replica of the AES function
/// pre-scaled onto every worker, driven by the open-loop generator.
pub fn netpath_cluster_run(
    backend: Backend,
    n_workers: usize,
    worker_cores: usize,
    compute_ns: Time,
    rates: &[f64],
    duration: Time,
    seed: u64,
) -> Vec<NetPathPoint> {
    rates
        .iter()
        .map(|&rate| {
            let (p, _violations) = netpath_point_audited(
                backend,
                n_workers,
                worker_cores,
                compute_ns,
                rate,
                duration,
                seed,
            );
            debug_assert!(_violations.is_empty(), "netpath broke invariants: {_violations:?}");
            p
        })
        .collect()
}

/// One rate point of the cluster sweep, plus a full post-run cluster
/// audit (E11's leg of `selfcheck` / `tests/invariants.rs`).
pub fn netpath_point_audited(
    backend: Backend,
    n_workers: usize,
    worker_cores: usize,
    compute_ns: Time,
    rate: f64,
    duration: Time,
    seed: u64,
) -> (NetPathPoint, Vec<Violation>) {
    let mut sim = Sim::new();
    let mut cluster = Cluster::new(backend, n_workers, worker_cores, seed, compute_ns);
    cluster.policy.max_replicas = n_workers as u32;
    cluster.deploy(
        &mut sim,
        FunctionSpec::new("aes", "aes600", RuntimeKind::Go).with_scale(
            ScaleMode::MaxCores,
            PlatformConfig::default().junction_max_cores as u32,
        ),
    );
    for _ in 1..n_workers {
        cluster.scale_up(&mut sim, "aes");
    }
    sim.run_until(SECONDS); // past every cold start
    let cluster = Rc::new(RefCell::new(cluster));
    let gen = OpenLoop::new("aes", rate, duration, seed ^ (rate as u64));
    let mut r: RunResult = gen.run_on(&mut sim, &cluster);
    let (dropped, retries) = (r.dropped, r.retried);
    let violations = audit_all(&*cluster.borrow());
    let point = NetPathPoint {
        backend,
        offered_rps: rate,
        goodput_rps: r.goodput_rps(),
        p50: r.gateway_observed.quantile(0.5),
        p99: r.gateway_observed.quantile(0.99),
        nic_p50: r.nic_hop.quantile(0.5),
        gw_p50: r.pre_exec.quantile(0.5),
        exec_p50: r.exec.quantile(0.5),
        dropped,
        retries,
    };
    (point, violations)
}

/// The cluster-scale Fig. 6 table: both backends, per-hop breakdown and
/// drop/retry columns.
pub fn netpath_table(
    n_workers: usize,
    worker_cores: usize,
    c_rates: &[f64],
    j_rates: &[f64],
    duration: Time,
    seed: u64,
) -> (Table, Vec<NetPathPoint>) {
    let compute = calibrated_compute_ns();
    let mut points = netpath_cluster_run(
        Backend::Containerd,
        n_workers,
        worker_cores,
        compute,
        c_rates,
        duration,
        seed,
    );
    points.extend(netpath_cluster_run(
        Backend::Junctiond,
        n_workers,
        worker_cores,
        compute,
        j_rates,
        duration,
        seed,
    ));
    let mut t = Table::new(
        &format!(
            "Cluster network data path — {n_workers}×{worker_cores}-core workers, per-packet NIC model"
        ),
        &[
            "backend",
            "offered rps",
            "goodput rps",
            "p50 (µs)",
            "p99 (µs)",
            "nic p50 (µs)",
            "gateway p50 (µs)",
            "exec p50 (µs)",
            "dropped",
            "retries",
        ],
    );
    for p in &points {
        t.push_row(vec![
            p.backend.name().into(),
            Cell::F2(p.offered_rps),
            Cell::F2(p.goodput_rps),
            Cell::NsAsUs(p.p50),
            Cell::NsAsUs(p.p99),
            Cell::NsAsUs(p.nic_p50),
            Cell::NsAsUs(p.gw_p50),
            Cell::NsAsUs(p.exec_p50),
            Cell::Int(p.dropped as i64),
            Cell::Int(p.retries as i64),
        ]);
    }
    (t, points)
}

/// Saturation throughput on the cluster sweep: highest goodput among
/// points whose p99 meets `sla_ns` (same knee detector as Fig. 6).
pub fn netpath_knee(points: &[NetPathPoint], backend: Backend, sla_ns: u64) -> f64 {
    points
        .iter()
        .filter(|p| p.backend == backend && p.p99 <= sla_ns && p.dropped == 0)
        .map(|p| p.goodput_rps)
        .fold(0.0, f64::max)
}

// ---------------------------------------------------------------------------
// E12 — density scale: the rebuilt event engine driven to ≥1M registered
// functions / ≥10M simulated invocations (§Perf; FaaSNet-scale regime)
// ---------------------------------------------------------------------------

/// One measured point of the density sweep: cluster shape, registered
/// population, driven load, and the *host-side* engine telemetry (events
/// fired, wall clock, events/sec) alongside the virtual-time latency the
/// run produced.
pub struct DensityPoint {
    pub backend: Backend,
    pub engine: &'static str,
    pub workers: usize,
    /// Functions registered across the cluster (hot subset + idle tail).
    pub functions: u64,
    /// Functions receiving the Zipf traffic.
    pub hot_functions: usize,
    pub submitted: u64,
    pub completed: u64,
    pub dropped: u64,
    /// Virtual clock at the end of the run.
    pub virtual_ns: Time,
    /// Host wall clock for the whole run (deploys + drive + drain).
    pub wall_secs: f64,
    /// Engine events fired over the whole run.
    pub events_fired: u64,
    /// Host-side engine throughput: `events_fired / wall_secs`.
    pub events_per_sec: f64,
    pub p50: u64,
    pub p99: u64,
}

/// Run one density point: register `n_functions` across an
/// `n_workers`-worker cluster (every function deploys a real instance —
/// the Shahrad characterization: the population exists, a Zipf head
/// serves nearly all traffic), pre-scale the head across the pool, then
/// drive `rate_rps` of open-loop Zipf traffic for `duration`.
///
/// Uses the platform-default compute cost (no PJRT calibration): the
/// point of E12 is the *engine*, and calibration noise would make the
/// cross-engine bit-identity check meaningless.
#[allow(clippy::too_many_arguments)]
pub fn density_scale_run(
    backend: Backend,
    n_workers: usize,
    worker_cores: usize,
    n_functions: u64,
    hot_functions: usize,
    rate_rps: f64,
    duration: Time,
    seed: u64,
) -> DensityPoint {
    use crate::workload::PopulationLoop;
    assert!(hot_functions as u64 <= n_functions);
    let compute = PlatformConfig::default().function_compute_ns;
    let sw = Stopwatch::new();
    let mut sim = Sim::new();
    let engine = match sim.engine_kind() {
        crate::simcore::EngineKind::Wheel => "wheel",
        crate::simcore::EngineKind::ReferenceHeap => "reference-heap",
    };
    let mut cluster = Cluster::new(backend, n_workers, worker_cores, seed, compute);
    cluster.policy.max_replicas = n_workers as u32;
    let mut rng = crate::simcore::Rng::new(seed ^ 0xD57);
    let hot = crate::workload::population(hot_functions, &mut rng);
    for (name, _) in &hot {
        cluster.deploy(&mut sim, FunctionSpec::new(name, "aes600", RuntimeKind::Go));
    }
    // The idle tail: registered, deployed once, never invoked. This is
    // what "a million functions on the platform" means in production
    // traces — and what the scheduler/engine must shrug off.
    for i in hot_functions as u64..n_functions {
        cluster.deploy(
            &mut sim,
            FunctionSpec::new(&format!("cold-{i:07}"), "aes600", RuntimeKind::Python),
        );
    }
    // Pre-scale the Zipf head onto every worker: it carries most of the
    // offered load, and E12 measures the engine, not autoscaler lag.
    for (name, _) in hot.iter().take(hot_functions.min(64)) {
        for _ in 1..n_workers {
            cluster.scale_up(&mut sim, name);
        }
    }
    sim.run_until(sim.now() + SECONDS); // past every cold start
    let cluster = Rc::new(RefCell::new(cluster));
    let driver = PopulationLoop::new(hot, rate_rps, duration, seed);
    let mut r = driver.run_on(&mut sim, &cluster);
    let wall_secs = sw.elapsed_secs();
    DensityPoint {
        backend,
        engine,
        workers: n_workers,
        functions: n_functions,
        hot_functions,
        submitted: r.submitted,
        completed: r.completed,
        dropped: r.dropped,
        virtual_ns: sim.now(),
        wall_secs,
        events_fired: sim.events_fired(),
        events_per_sec: sim.events_fired() as f64 / wall_secs.max(1e-9),
        p50: r.gateway_observed.quantile(0.5),
        p99: r.gateway_observed.quantile(0.99),
    }
}

/// Markdown table for a set of density points.
pub fn density_scale_table(points: &[DensityPoint]) -> Table {
    let mut t = Table::new(
        "E12 — density scale: engine throughput at cluster scale",
        &[
            "backend",
            "engine",
            "workers",
            "functions",
            "hot",
            "submitted",
            "completed",
            "dropped",
            "virtual s",
            "wall s",
            "events",
            "events/s (host)",
            "p50 (µs)",
            "p99 (µs)",
        ],
    );
    for p in points {
        t.push_row(vec![
            p.backend.name().into(),
            p.engine.into(),
            Cell::Int(p.workers as i64),
            Cell::Int(p.functions as i64),
            Cell::Int(p.hot_functions as i64),
            Cell::Int(p.submitted as i64),
            Cell::Int(p.completed as i64),
            Cell::Int(p.dropped as i64),
            Cell::F2(p.virtual_ns as f64 / SECONDS as f64),
            Cell::F2(p.wall_secs),
            Cell::Int(p.events_fired as i64),
            Cell::F2(p.events_per_sec),
            Cell::NsAsUs(p.p50),
            Cell::NsAsUs(p.p99),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// E18 — shard scale: the parallel engine shards (simcore::shard, §3j)
// driving the E12 density workload across OS threads
// ---------------------------------------------------------------------------

/// One measured point of the shard sweep. Every field except `wall_secs`
/// and `shard_stats` is deterministic — byte-identical across repeated
/// same-seed runs, across shard counts, and across the serial/threaded
/// transports — so the rendered table can be `cmp`-diffed in CI while
/// the host-side telemetry rides separately (stderr / BENCH_shard.json).
#[derive(Clone)]
pub struct ShardScalePoint {
    pub backend: Backend,
    pub shards: usize,
    /// `"threaded"` (one OS thread per shard) or `"serial"` (the same
    /// barrier-epoch protocol run inline — the equality baseline).
    pub transport: &'static str,
    pub workers: usize,
    pub functions: u64,
    pub hot_functions: usize,
    pub submitted: u64,
    pub completed: u64,
    pub dropped: u64,
    pub timed_out: u64,
    pub completed_in_window: u64,
    /// Engine events fired, summed across shards — invariant under the
    /// shard count (the model schedules the same events wherever its
    /// endpoints happen to live).
    pub events_fired: u64,
    /// Gateway-observed e2e latency (two cross-rack wire hops + the
    /// in-rack pipeline), measurement-window arrivals only.
    pub p50: u64,
    pub p99: u64,
    pub exec_p99: u64,
    /// Host wall clock for the whole run — telemetry, never tabled.
    pub wall_secs: f64,
    /// Per-shard runner counters (epochs, skips, wire messages, wall).
    pub shard_stats: Vec<crate::simcore::ShardStats>,
}

/// Run one point of E18: the E12 density shape (Zipf head + idle tail,
/// open-loop arrivals) rebuilt as a message-passing cluster on `shards`
/// engine shards. Panics on any conservation/audit violation — on the
/// sharded path a broken law is a broken run, not a footnote.
#[allow(clippy::too_many_arguments)]
pub fn shard_scale_run(
    backend: Backend,
    shards: usize,
    threaded: bool,
    n_workers: usize,
    worker_cores: usize,
    n_functions: u64,
    hot_functions: usize,
    rate_rps: f64,
    duration: Time,
    seed: u64,
) -> ShardScalePoint {
    let sw = Stopwatch::new();
    let out = run_shard_cluster(&ShardClusterCfg {
        backend,
        shards,
        threaded,
        workers: n_workers,
        worker_cores,
        functions: n_functions,
        hot_functions,
        rate_rps,
        duration,
        seed,
    });
    let wall_secs = sw.elapsed_secs();
    assert!(
        out.audit_violations.is_empty(),
        "E18 shard run broke invariants: {:?}",
        out.audit_violations
    );
    let mut g = out.gateway;
    ShardScalePoint {
        backend,
        shards,
        transport: if threaded { "threaded" } else { "serial" },
        workers: n_workers,
        functions: n_functions,
        hot_functions,
        submitted: g.submitted,
        completed: g.completed,
        dropped: g.dropped,
        timed_out: g.timed_out,
        completed_in_window: g.completed_in_window,
        events_fired: out.events_fired,
        p50: g.e2e.quantile(0.5),
        p99: g.e2e.quantile(0.99),
        exec_p99: g.exec.quantile(0.99),
        wall_secs,
        shard_stats: out.shard_stats,
    }
}

/// Markdown table for a set of shard points — deterministic columns
/// only, so `shardscale` stdout can be byte-diffed across runs and
/// shard counts. Wall-clock/speedup live in [`shard_scale_host_summary`].
pub fn shard_scale_table(points: &[ShardScalePoint]) -> Table {
    let mut t = Table::new(
        "E18 — shard scale: parallel engine shards on the density workload",
        &[
            "backend",
            "shards",
            "transport",
            "workers",
            "functions",
            "hot",
            "submitted",
            "completed",
            "dropped",
            "timed out",
            "in window",
            "events",
            "p50 (µs)",
            "p99 (µs)",
            "exec p99 (µs)",
        ],
    );
    for p in points {
        t.push_row(vec![
            p.backend.name().into(),
            Cell::Int(p.shards as i64),
            p.transport.into(),
            Cell::Int(p.workers as i64),
            Cell::Int(p.functions as i64),
            Cell::Int(p.hot_functions as i64),
            Cell::Int(p.submitted as i64),
            Cell::Int(p.completed as i64),
            Cell::Int(p.dropped as i64),
            Cell::Int(p.timed_out as i64),
            Cell::Int(p.completed_in_window as i64),
            Cell::Int(p.events_fired as i64),
            Cell::NsAsUs(p.p50),
            Cell::NsAsUs(p.p99),
            Cell::NsAsUs(p.exec_p99),
        ]);
    }
    t
}

/// The host-side leg of E18, kept off stdout so the deterministic table
/// stays byte-diffable: wall clock, events/sec, and per-shard runner
/// counters for each point, plus the speedup of every point against the
/// slowest single-shard point in the set (when one is present).
pub fn shard_scale_host_summary(points: &[ShardScalePoint]) -> String {
    use std::fmt::Write as _;
    let base = points
        .iter()
        .filter(|p| p.shards == 1)
        .map(|p| p.wall_secs)
        .fold(f64::NAN, f64::max);
    let mut s = String::from("# host telemetry (nondeterministic; not part of the table)\n");
    for p in points {
        let eps = p.events_fired as f64 / p.wall_secs.max(1e-9);
        let _ = write!(
            s,
            "shards={} transport={} wall={:.3}s events/s={:.0}",
            p.shards,
            p.transport,
            p.wall_secs,
            eps
        );
        if base.is_finite() && p.shards > 1 {
            write!(s, " speedup_vs_1={:.2}x", base / p.wall_secs.max(1e-9)).unwrap();
        }
        let epochs: u64 = p.shard_stats.iter().map(|st| st.epochs).sum();
        let skipped: u64 = p.shard_stats.iter().map(|st| st.skipped_epochs).sum();
        let wire: u64 = p.shard_stats.iter().map(|st| st.msgs_out).sum();
        writeln!(s, " epochs={epochs} skipped={skipped} wire_msgs={wire}").unwrap();
    }
    s
}

// ---------------------------------------------------------------------------
// E13 — full-duplex netpath: worker TX rings + gateway-side RX under load
// ---------------------------------------------------------------------------

/// One measured point of the duplex sweep: the response direction's
/// telemetry (TX flush amortization, backpressure stalls, gateway-side RX)
/// alongside the request-side latency numbers.
pub struct DuplexPoint {
    pub backend: Backend,
    pub offered_rps: f64,
    /// Payload carried by request *and* response frames (echo workload).
    pub payload_bytes: u64,
    pub goodput_rps: f64,
    pub p50: u64,
    pub p99: u64,
    /// Median transmit hop: TX ring wait + per-frame flush + return wire.
    pub tx_p50: u64,
    pub submitted: u64,
    pub completed: u64,
    pub dropped: u64,
    /// Invocations served across the pool (includes warmup completions).
    pub served: u64,
    /// Worker TX rings: achieved flush amortization (frames per burst).
    pub tx_mean_batch: f64,
    /// Worker TX backpressure stalls and responder re-offers.
    pub tx_stalled: u64,
    pub tx_retries: u64,
    /// Response frames that left the workers.
    pub tx_packets: u64,
    /// Gateway-side RX: frames received and achieved burst amortization.
    pub gw_rx_packets: u64,
    pub gw_rx_mean_batch: f64,
}

/// Default offered-load grids for the duplex sweep (2×16-core workers).
/// The containerd grid ends past the kernel RX path's aggregate packet
/// rate (the overload point where the ring must shed); the junctiond grid
/// shares the low rates and extends far past the containerd knee so the
/// TX flush amortization has load to grow into.
pub fn duplex_default_containerd_rates() -> Vec<f64> {
    vec![1_000.0, 4_000.0, 8_000.0, 320_000.0]
}

pub fn duplex_default_junction_rates() -> Vec<f64> {
    vec![1_000.0, 4_000.0, 16_000.0, 48_000.0, 160_000.0]
}

/// Run the duplex sweep for one backend: the same cluster shape as E11
/// (per-worker NIC rings behind the least-inflight front end) but with the
/// response direction live — bounded TX rings on every worker and the
/// front end's own RX NIC. Uses the platform-default compute cost (no PJRT
/// calibration) so the output is byte-deterministic for a given seed — the
/// CI determinism job diffs two same-seed runs of this table.
pub fn duplex_cluster_run(
    backend: Backend,
    n_workers: usize,
    worker_cores: usize,
    payload_bytes: u64,
    rates: &[f64],
    duration: Time,
    seed: u64,
) -> Vec<DuplexPoint> {
    rates
        .iter()
        .map(|&rate| {
            let platform = Rc::new(PlatformConfig {
                rpc_payload_bytes: payload_bytes,
                ..PlatformConfig::default()
            });
            let compute = platform.function_compute_ns;
            let mut sim = Sim::new();
            let mut cluster = Cluster::new_with_platform(
                backend,
                n_workers,
                worker_cores,
                seed,
                compute,
                platform.clone(),
            );
            cluster.policy.max_replicas = n_workers as u32;
            cluster.deploy(
                &mut sim,
                FunctionSpec::new("aes", "aes600", RuntimeKind::Go)
                    .with_scale(ScaleMode::MaxCores, platform.junction_max_cores as u32),
            );
            for _ in 1..n_workers {
                cluster.scale_up(&mut sim, "aes");
            }
            sim.run_until(SECONDS); // past every cold start
            let cluster = Rc::new(RefCell::new(cluster));
            let gen = OpenLoop::new("aes", rate, duration, seed ^ (rate as u64) ^ payload_bytes);
            let mut r: RunResult = gen.run_on(&mut sim, &cluster);
            let c = cluster.borrow();
            let (_, tx) = c.nic_totals();
            let gw = c.frontend_rx_stats();
            DuplexPoint {
                backend,
                offered_rps: rate,
                payload_bytes,
                goodput_rps: r.goodput_rps(),
                p50: r.gateway_observed.quantile(0.5),
                p99: r.gateway_observed.quantile(0.99),
                tx_p50: r.tx_hop.quantile(0.5),
                submitted: r.submitted,
                completed: r.completed,
                dropped: r.dropped,
                served: c.total_completed(),
                tx_mean_batch: tx.mean_batch(),
                tx_stalled: tx.tx_stalled,
                tx_retries: tx.tx_retries,
                tx_packets: tx.tx_packets,
                gw_rx_packets: gw.rx_delivered,
                gw_rx_mean_batch: gw.mean_batch(),
            }
        })
        .collect()
}

/// The E13 duplex table: both backends, response-direction telemetry.
pub fn duplex_table(
    n_workers: usize,
    worker_cores: usize,
    payload_bytes: u64,
    c_rates: &[f64],
    j_rates: &[f64],
    duration: Time,
    seed: u64,
) -> (Table, Vec<DuplexPoint>) {
    let mut points = duplex_cluster_run(
        Backend::Containerd,
        n_workers,
        worker_cores,
        payload_bytes,
        c_rates,
        duration,
        seed,
    );
    points.extend(duplex_cluster_run(
        Backend::Junctiond,
        n_workers,
        worker_cores,
        payload_bytes,
        j_rates,
        duration,
        seed,
    ));
    let mut t = Table::new(
        &format!(
            "E13 — full-duplex netpath: {n_workers}×{worker_cores}-core workers, \
             {payload_bytes} B echo payload"
        ),
        &[
            "backend",
            "offered rps",
            "goodput rps",
            "p50 (µs)",
            "p99 (µs)",
            "tx p50 (µs)",
            "tx batch",
            "gw rx batch",
            "tx stalls",
            "dropped",
        ],
    );
    for p in &points {
        t.push_row(vec![
            p.backend.name().into(),
            Cell::F2(p.offered_rps),
            Cell::F2(p.goodput_rps),
            Cell::NsAsUs(p.p50),
            Cell::NsAsUs(p.p99),
            Cell::NsAsUs(p.tx_p50),
            Cell::F2(p.tx_mean_batch),
            Cell::F2(p.gw_rx_mean_batch),
            Cell::Int(p.tx_stalled as i64),
            Cell::Int(p.dropped as i64),
        ]);
    }
    (t, points)
}

/// Echo payload sweep: both backends at a fixed modest rate, payload sizes
/// swept across three orders of magnitude. The kernel path pays the
/// per-KiB socket↔DMA copy on *both* directions of every frame; the
/// zero-copy bypass path's costs are payload-independent — so the gap
/// widens with payload size.
pub fn duplex_payload_sweep_table(
    n_workers: usize,
    worker_cores: usize,
    payloads: &[u64],
    rate: f64,
    duration: Time,
    seed: u64,
) -> (Table, Vec<DuplexPoint>) {
    let mut points = Vec::new();
    for backend in [Backend::Containerd, Backend::Junctiond] {
        for &payload in payloads {
            points.extend(duplex_cluster_run(
                backend,
                n_workers,
                worker_cores,
                payload,
                &[rate],
                duration,
                seed,
            ));
        }
    }
    let mut t = Table::new(
        &format!("E13b — echo payload sweep @ {rate} rps, {n_workers}×{worker_cores}-core workers"),
        &[
            "backend",
            "payload (B)",
            "goodput rps",
            "p50 (µs)",
            "p99 (µs)",
            "tx p50 (µs)",
            "tx stalls",
            "dropped",
        ],
    );
    for p in &points {
        t.push_row(vec![
            p.backend.name().into(),
            Cell::Int(p.payload_bytes as i64),
            Cell::F2(p.goodput_rps),
            Cell::NsAsUs(p.p50),
            Cell::NsAsUs(p.p99),
            Cell::NsAsUs(p.tx_p50),
            Cell::Int(p.tx_stalled as i64),
            Cell::Int(p.dropped as i64),
        ]);
    }
    (t, points)
}

// ---------------------------------------------------------------------------
// E14 — structural interference: co-located antagonists vs the tail
// ---------------------------------------------------------------------------

/// One measured point of the interference sweep: a latency-sensitive
/// function co-located with `antagonists` heavy tenants on one 10-core
/// worker, with residual jitter off — every microsecond of tail comes
/// from per-core contention in the compute fabric.
pub struct InterferencePoint {
    pub backend: Backend,
    /// Co-located antagonist tenants (each a serial instance running a
    /// chunky body at `ant_rps_per_tenant`).
    pub antagonists: u32,
    pub ant_rps_per_tenant: f64,
    pub completed: u64,
    pub dropped: u64,
    /// Latency-sensitive function's gateway-observed quantiles.
    pub p50: u64,
    pub p99: u64,
    /// The worker fabric's counters at the end of the run (preemption/
    /// steal/migration churn + the conservation fields the E14 gate
    /// checks: per-core busy sums to the total, submitted == completed).
    pub fabric: crate::simcore::FabricStats,
}

/// Default antagonist-count sweep for E14 (the top point oversubscribes
/// the 10-core worker so the kernel backend's queues grow unboundedly).
pub fn interference_default_counts() -> Vec<u32> {
    vec![0, 4, 8, 12, 16]
}

/// Run one E14 point: deploy the latency-sensitive function (`lat`,
/// platform-default ~100 µs body) plus `antagonists` tenants with
/// `ant_compute_ns` bodies, drive every antagonist open-loop at
/// `ant_rps_per_tenant`, and measure `lat` at a fixed modest 400 rps.
///
/// Deterministic: platform-default compute (no PJRT calibration), no
/// wall-clock output — the CI determinism job diffs two same-seed runs
/// of the table byte-for-byte.
pub fn interference_run(
    backend: Backend,
    antagonists: u32,
    ant_rps_per_tenant: f64,
    ant_compute_ns: Time,
    duration: Time,
    seed: u64,
) -> InterferencePoint {
    let (p, _violations) = interference_run_audited(
        backend,
        antagonists,
        ant_rps_per_tenant,
        ant_compute_ns,
        duration,
        seed,
    );
    debug_assert!(_violations.is_empty(), "interference broke invariants: {_violations:?}");
    p
}

/// [`interference_run`] plus a full post-run invariant audit of the
/// simulated node (E14's leg of `selfcheck` / `tests/invariants.rs`).
pub fn interference_run_audited(
    backend: Backend,
    antagonists: u32,
    ant_rps_per_tenant: f64,
    ant_compute_ns: Time,
    duration: Time,
    seed: u64,
) -> (InterferencePoint, Vec<Violation>) {
    let platform = Rc::new(PlatformConfig::default());
    assert_eq!(
        platform.residual_jitter, 0,
        "E14 measures structural interference only (residual jitter must be off)"
    );
    let cfg = ExperimentConfig {
        backend,
        provider_cache: true,
        worker_cores: 10,
        seed,
        function_compute_ns: platform.function_compute_ns,
        instance_concurrency: 4,
    };
    let mut sim = Sim::new();
    let fs = FaasSim::new(&cfg, platform);
    fs.deploy(
        &mut sim,
        FunctionSpec::new("lat", "aes600", RuntimeKind::Go).with_scale(ScaleMode::MaxCores, 2),
    );
    for k in 0..antagonists {
        fs.deploy(
            &mut sim,
            FunctionSpec::new(&format!("ant-{k:02}"), "aes600", RuntimeKind::Go)
                .with_compute(ant_compute_ns),
        );
    }
    sim.run_until(SECONDS); // past every cold start
    // Drive each antagonist with a self-scheduling Poisson chain across
    // the lat function's whole measurement horizon (warmup + window):
    // one pending event per tenant at any time, not one closure per
    // arrival materialized up front (the same bounded-generation rule
    // the open-loop driver follows). The OpenLoop run below drives the
    // sim to completion, draining everything.
    let horizon = sim.now() + duration + duration / 10;
    for k in 0..antagonists {
        let rng = crate::simcore::Rng::new(seed ^ 0xE14_0000 ^ k as u64);
        antagonist_arrival(
            &mut sim,
            fs.clone(),
            format!("ant-{k:02}"),
            rng,
            SECONDS as f64 / ant_rps_per_tenant,
            sim.now() as f64,
            horizon,
        );
    }
    let mut r = OpenLoop::new("lat", 400.0, duration, seed ^ 0x7A7).run(&mut sim, &fs);
    let violations = audit_all(&fs);
    let point = InterferencePoint {
        backend,
        antagonists,
        ant_rps_per_tenant,
        completed: r.completed,
        dropped: r.dropped,
        p50: r.gateway_observed.quantile(0.5),
        p99: r.gateway_observed.quantile(0.99),
        fabric: fs.fabric_stats(),
    };
    (point, violations)
}

/// One link of an antagonist's Poisson arrival chain: submit at `t +
/// exp(gap)` and schedule the next link from inside that event, keeping
/// exactly one pending arrival per tenant (`t` stays f64 so the
/// exponential sum never loses sub-ns precision).
fn antagonist_arrival(
    sim: &mut Sim,
    fs: FaasSim,
    name: String,
    mut rng: crate::simcore::Rng,
    gap: f64,
    t: f64,
    horizon: Time,
) {
    let next = t + rng.exp(gap);
    if (next as Time) >= horizon {
        return;
    }
    sim.at(next as Time, move |sim| {
        fs.submit(sim, &name, |_, _| {});
        antagonist_arrival(sim, fs, name, rng, gap, next, horizon);
    });
}

/// The E14 table: both backends over the antagonist sweep, with the
/// degradation factor relative to each backend's idle (0-antagonist)
/// baseline and the fabric's structural-churn counters.
pub fn interference_table(
    counts: &[u32],
    ant_rps_per_tenant: f64,
    ant_compute_ns: Time,
    duration: Time,
    seed: u64,
) -> (Table, Vec<InterferencePoint>) {
    let mut points = Vec::new();
    for backend in [Backend::Containerd, Backend::Junctiond] {
        for &n in counts {
            points.push(interference_run(
                backend,
                n,
                ant_rps_per_tenant,
                ant_compute_ns,
                duration,
                seed,
            ));
        }
    }
    let mut t = Table::new(
        &format!(
            "E14 — structural interference: co-located latency fn vs antagonists \
             ({} µs bodies @ {ant_rps_per_tenant:.0} rps/tenant, 10-core worker, residual jitter off)",
            ant_compute_ns / MICROS
        ),
        &[
            "backend",
            "antagonists",
            "lat p50 (µs)",
            "lat p99 (µs)",
            "p99 × idle",
            "preempt",
            "steals",
            "migrations",
            "dropped",
        ],
    );
    for p in &points {
        let base = points
            .iter()
            .find(|q| q.backend == p.backend && q.antagonists == 0)
            .map(|q| q.p99)
            .unwrap_or(p.p99);
        t.push_row(vec![
            p.backend.name().into(),
            Cell::Int(p.antagonists as i64),
            Cell::NsAsUs(p.p50),
            Cell::NsAsUs(p.p99),
            Cell::F2(p.p99 as f64 / base.max(1) as f64),
            Cell::Int(p.fabric.preemptions as i64),
            Cell::Int(p.fabric.steals as i64),
            Cell::Int(p.fabric.migrations as i64),
            Cell::Int(p.dropped as i64),
        ]);
    }
    (t, points)
}

// ---------------------------------------------------------------------------
// E15 — invocation tracing: tail-latency blame decomposition
// ---------------------------------------------------------------------------

/// One backend's E15 result: the blame decomposition plus the slowest
/// traced invocations (reservoir exemplars, for the Chrome trace export).
pub struct TailAttribution {
    pub backend: Backend,
    pub completed: u64,
    pub dropped: u64,
    pub report: BlameReport,
    pub exemplars: Vec<Trace>,
}

/// Run one E15 point: warm single-worker deployment, tracing on, then a
/// 150k-rps open loop with 20 µs bodies. That rate sits *above* the
/// kernel netpath's serial RX drain capacity (IRQ + softirq + copy per
/// frame ≈ 133k pps) but far below the 10-core fabric's compute capacity
/// (≈ 500k rps at 20 µs), so the kernel backend's tail is queueing in
/// the netpath + pre-exec scheduler stages while the bypass backend's
/// tail stays execution-dominated — the per-hop decomposition makes the
/// paper's "where does the time go" argument quantitative.
///
/// Deterministic: platform-default compute (no PJRT), fixed seeds, and
/// tracing itself adds no events and draws no randomness.
pub fn tail_attribution_run(backend: Backend, duration: Time, seed: u64) -> TailAttribution {
    let (t, _violations) = tail_attribution_run_audited(backend, duration, seed);
    debug_assert!(_violations.is_empty(), "tail attribution broke invariants: {_violations:?}");
    t
}

/// [`tail_attribution_run`] plus a full post-run invariant audit of the
/// simulated node (E15's leg of `selfcheck` / `tests/invariants.rs`).
pub fn tail_attribution_run_audited(
    backend: Backend,
    duration: Time,
    seed: u64,
) -> (TailAttribution, Vec<Violation>) {
    let platform = Rc::new(PlatformConfig::default());
    assert_eq!(
        platform.residual_jitter, 0,
        "E15 attributes structural latency only (residual jitter must be off)"
    );
    let max_cores = platform.junction_max_cores as u32;
    let cfg = ExperimentConfig {
        backend,
        provider_cache: true,
        worker_cores: 10,
        seed,
        function_compute_ns: 20 * MICROS,
        instance_concurrency: 16,
    };
    let mut sim = Sim::new();
    let fs = FaasSim::new(&cfg, platform);
    fs.deploy(
        &mut sim,
        FunctionSpec::new("aes", "aes600", RuntimeKind::Go)
            .with_scale(ScaleMode::MaxCores, max_cores),
    );
    sim.run_until(SECONDS);
    let tracer = fs.enable_tracing(8);
    let r = OpenLoop::new("aes", 150_000.0, duration, seed ^ 0xE15).run(&mut sim, &fs);
    let violations = audit_all(&fs);
    let attribution = TailAttribution {
        backend,
        completed: r.completed,
        dropped: r.dropped,
        report: tracer.blame_report(),
        exemplars: tracer.exemplars(),
    };
    (attribution, violations)
}

/// The E15 table: per-hop share (%) of end-to-end latency at p50 and
/// p99 for both backends. Shares are over completions at or above that
/// quantile, so each row's six hop columns sum to 100.
pub fn tail_attribution_table(duration: Time, seed: u64) -> (Table, Vec<TailAttribution>) {
    let points: Vec<TailAttribution> = [Backend::Containerd, Backend::Junctiond]
        .into_iter()
        .map(|b| tail_attribution_run(b, duration, seed))
        .collect();
    let mut cols: Vec<&str> = vec!["backend", "quantile", "e2e (µs)"];
    cols.extend(HOP_NAMES);
    cols.extend(["completed", "dropped"]);
    let mut t = Table::new(
        "E15 — tail-latency blame: per-hop share (%) of e2e at each quantile \
         (150k rps open loop, 20 µs bodies, 10-core worker)",
        &cols,
    );
    for p in &points {
        let rows =
            [("p50", p.report.e2e_p50, p.report.p50), ("p99", p.report.e2e_p99, p.report.p99)];
        for (q, e2e, shares) in rows {
            let mut row: Vec<Cell> = vec![p.backend.name().into(), q.into(), Cell::NsAsUs(e2e)];
            for s in shares {
                row.push(Cell::F2(s * 100.0));
            }
            row.push(Cell::Int(p.completed as i64));
            row.push(Cell::Int(p.dropped as i64));
            t.push_row(row);
        }
    }
    (t, points)
}

// ---------------------------------------------------------------------------
// E10 — multi-tenant trace replay (§1 motivation; [22] skew)
// ---------------------------------------------------------------------------

pub fn multitenant_table(n_functions: u32, total_rps: f64, seed: u64) -> Table {
    use crate::workload::{replay, TraceGenerator};
    let mut t = Table::new(
        &format!("Multi-tenant trace — {n_functions} functions, {total_rps} rps aggregate, Zipf skew"),
        &["backend", "completed", "cold deploys", "p50 (µs)", "p99 (µs)", "p99.9 (µs)"],
    );
    for backend in [Backend::Containerd, Backend::Junctiond] {
        let cfg = standard_config(backend, seed);
        let mut sim = Sim::new();
        let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
        let gen = TraceGenerator::new(n_functions, total_rps, seed);
        let events = gen.generate(2 * SECONDS);
        let mut r = replay(&mut sim, &fs, &events, n_functions, |i| format!("fn-{i}"));
        t.push_row(vec![
            backend.name().into(),
            Cell::Int(r.completed as i64),
            Cell::Int(r.cold_hits as i64),
            Cell::NsAsUs(r.latency.quantile(0.5)),
            Cell::NsAsUs(r.latency.quantile(0.99)),
            Cell::NsAsUs(r.latency.quantile(0.999)),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// E16 — resilience matrix: seeded fault schedules vs the recovery machinery
// (deadlines + cross-replica retry, hedging, health ejection, brownout)
// ---------------------------------------------------------------------------

/// One leg of the E16 resilience matrix: a fault scenario on one backend,
/// with the request-conservation ledger, the recovery-machinery counters,
/// and the post-run invariant audit.
#[derive(Debug, Clone)]
pub struct ResiliencePoint {
    pub backend: Backend,
    pub scenario: &'static str,
    pub submitted: u64,
    pub completed: u64,
    pub dropped: u64,
    pub timed_out: u64,
    pub failed: u64,
    pub hedge_wins: u64,
    pub retries_other: u64,
    pub shed_batch: u64,
    pub wire_lost: u64,
    pub ejections: u64,
    pub p50: Time,
    pub p99: Time,
    /// Worst re-provision latency any crash in the scenario paid through
    /// the tier ladder (0 for crash-free scenarios).
    pub recovery_ns: Time,
    pub violations: Vec<Violation>,
}

impl ResiliencePoint {
    /// The fault-plane conservation law: every submitted request resolves
    /// exactly once — completed, dropped (incl. failed/shed), or timed out.
    pub fn conserved(&self) -> bool {
        self.submitted == self.completed + self.dropped + self.timed_out
    }
}

/// The recovery-machinery platform for E16: per-invocation deadlines with
/// cross-replica retry, jittered NIC backoff, and health ejection.
/// `hedge_bp` 0 disables hedging; 5000 hedges at the observed median.
fn resilience_platform(hedge_bp: u64) -> Rc<PlatformConfig> {
    Rc::new(PlatformConfig {
        deadline_timeout_ns: 50 * MILLIS,
        deadline_max_retries: 3,
        deadline_retry_backoff_ns: 20 * MICROS,
        hedge_quantile_bp: hedge_bp,
        fault_health_fail_threshold: 5,
        fault_health_eject_ns: 5 * MILLIS,
        nic_retry_jitter: 1,
        ..PlatformConfig::default()
    })
}

/// Offered load per backend: well below each backend's saturation knee so
/// the matrix measures fault response, not overload responses.
fn resilience_rate(backend: Backend) -> f64 {
    match backend {
        Backend::Containerd => 4_000.0,
        Backend::Junctiond => 16_000.0,
    }
}

/// Two-worker cluster with `aes` scaled to both workers, warmed past
/// every cold start (which also captures the snapshots crash recovery
/// restores from).
fn resilience_cluster(
    backend: Backend,
    seed: u64,
    platform: Rc<PlatformConfig>,
) -> (Sim, Rc<RefCell<Cluster>>) {
    let compute = platform.function_compute_ns;
    let mut sim = Sim::new();
    let mut c = Cluster::new_with_platform(backend, 2, 10, seed, compute, platform);
    c.policy.max_replicas = 2;
    c.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
    c.scale_up(&mut sim, "aes");
    sim.run_until(SECONDS);
    (sim, Rc::new(RefCell::new(c)))
}

fn resilience_point(
    backend: Backend,
    scenario: &'static str,
    r: &mut RunResult,
    cluster: &Rc<RefCell<Cluster>>,
    faults: &Rc<RefCell<crate::faultplane::FaultStats>>,
) -> ResiliencePoint {
    let cl = cluster.borrow();
    let rec = cl.recovery_stats();
    let fs = *faults.borrow();
    let mut violations = audit_all(&*cl);
    fs.audit_into(&mut violations);
    ResiliencePoint {
        backend,
        scenario,
        submitted: r.submitted,
        completed: r.completed,
        dropped: r.dropped,
        timed_out: r.timed_out,
        failed: r.failed,
        hedge_wins: r.hedge_wins,
        retries_other: rec.retries_other,
        shed_batch: rec.shed_batch,
        wire_lost: rec.wire_lost,
        ejections: rec.ejections,
        p50: r.gateway_observed.quantile(0.5),
        p99: r.gateway_observed.quantile(0.99),
        recovery_ns: fs.worst_recovery_ns,
        violations,
    }
}

/// Crash + wire-loss leg: an instance crash, then a full worker crash,
/// then a lossy-wire window, against the deadline/retry machinery. The
/// headline number is `recovery_ns` — what the crash actually paid to
/// re-provision (snapshot restore, not cold boot) — which is where the
/// kernel-vs-bypass restart asymmetry shows up.
pub fn resilience_crash_run(backend: Backend, duration: Time, seed: u64) -> ResiliencePoint {
    use crate::faultplane::FaultSchedule;
    let (mut sim, cluster) = resilience_cluster(backend, seed, resilience_platform(0));
    let schedule = FaultSchedule::new()
        .instance_crash(SECONDS + duration / 4, 0, "aes")
        .worker_crash(SECONDS + duration / 2, 1)
        .wire_loss(SECONDS + 3 * duration / 4, 1_000, duration / 4);
    let faults = crate::faultplane::install(schedule, &mut sim, &cluster);
    let mut r =
        OpenLoop::new("aes", resilience_rate(backend), duration, seed).run_on(&mut sim, &cluster);
    resilience_point(backend, "crash+loss", &mut r, &cluster, &faults)
}

/// Gray-failure leg: worker 0 runs 16× slow for most of the window while
/// nothing fails and nothing ejects — the failure mode only hedging can
/// defend. Run with `hedge` off and on to measure the p99 delta.
pub fn resilience_gray_run(
    backend: Backend,
    duration: Time,
    seed: u64,
    hedge: bool,
) -> ResiliencePoint {
    use crate::faultplane::FaultSchedule;
    let bp = if hedge { 5_000 } else { 0 };
    let (mut sim, cluster) = resilience_cluster(backend, seed, resilience_platform(bp));
    let schedule = FaultSchedule::new().gray(SECONDS + duration / 5, 0, 1_600, duration);
    let faults = crate::faultplane::install(schedule, &mut sim, &cluster);
    let mut r =
        OpenLoop::new("aes", resilience_rate(backend), duration, seed).run_on(&mut sim, &cluster);
    resilience_point(backend, if hedge { "gray+hedge" } else { "gray" }, &mut r, &cluster, &faults)
}

/// Brownout leg: a Batch-class function rides along with the interactive
/// one; repeated worker crashes drop the healthy fraction below the
/// watermark, and admission control sheds Batch work at the door so the
/// survivors keep serving Interactive.
pub fn resilience_brownout_run(backend: Backend, duration: Time, seed: u64) -> ResiliencePoint {
    use crate::faultplane::FaultSchedule;
    use crate::workload::PopulationLoop;
    let mut brownout = (*resilience_platform(0)).clone();
    brownout.fault_brownout_watermark_bp = 6_000;
    let platform = Rc::new(brownout);
    let compute = platform.function_compute_ns;
    let mut sim = Sim::new();
    let mut c = Cluster::new_with_platform(backend, 2, 10, seed, compute, platform);
    c.policy.max_replicas = 2;
    c.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
    c.deploy(&mut sim, FunctionSpec::new("bg", "aes600", RuntimeKind::Go).with_batch());
    c.scale_up(&mut sim, "aes");
    c.scale_up(&mut sim, "bg");
    sim.run_until(SECONDS);
    let cluster = Rc::new(RefCell::new(c));
    // Crash worker 0 five times across the window: each recovery interval
    // has 1 of 2 workers healthy (5000 bp < the 6000 bp watermark).
    let mut schedule = FaultSchedule::new();
    for i in 1..=5u64 {
        schedule = schedule.worker_crash(SECONDS + i * duration / 6, 0);
    }
    let faults = crate::faultplane::install(schedule, &mut sim, &cluster);
    let mix = vec![("aes".to_string(), 1.0), ("bg".to_string(), 1.0)];
    let mut r = PopulationLoop::new(mix, resilience_rate(backend), duration, seed)
        .run_on(&mut sim, &cluster);
    resilience_point(backend, "brownout", &mut r, &cluster, &faults)
}

/// The E16 table: {crash+loss, gray, gray+hedge, brownout} × both
/// backends. Deterministic for a given (duration, seed) — the CI
/// resilience job byte-diffs two same-seed runs.
pub fn resilience_table(duration: Time, seed: u64) -> (Table, Vec<ResiliencePoint>) {
    let mut points = Vec::new();
    for backend in [Backend::Containerd, Backend::Junctiond] {
        points.push(resilience_crash_run(backend, duration, seed));
        points.push(resilience_gray_run(backend, duration, seed, false));
        points.push(resilience_gray_run(backend, duration, seed, true));
        points.push(resilience_brownout_run(backend, duration, seed));
    }
    let mut t = Table::new(
        "E16 — resilience matrix: seeded faults vs deadline/retry, hedging, brownout",
        &[
            "backend",
            "scenario",
            "completed",
            "dropped",
            "timed out",
            "hedge wins",
            "retries",
            "shed",
            "p50 (µs)",
            "p99 (µs)",
            "recovery (µs)",
        ],
    );
    for p in &points {
        t.push_row(vec![
            p.backend.name().into(),
            p.scenario.into(),
            Cell::Int(p.completed as i64),
            Cell::Int(p.dropped as i64),
            Cell::Int(p.timed_out as i64),
            Cell::Int(p.hedge_wins as i64),
            Cell::Int(p.retries_other as i64),
            Cell::Int(p.shed_batch as i64),
            Cell::NsAsUs(p.p50),
            Cell::NsAsUs(p.p99),
            Cell::NsAsUs(p.recovery_ns),
        ]);
    }
    (t, points)
}

// ---------------------------------------------------------------------------
// Selfcheck — run the audit-bearing experiments and report every invariant
// violation the runtime walkers find (CLI `selfcheck`, `tests/invariants.rs`,
// CI detlint job).
// ---------------------------------------------------------------------------

/// One experiment leg of [`selfcheck`]: which scenario ran on which
/// backend, and every invariant violation `audit_all` found afterwards
/// (empty means the run left the runtime in a lawful quiesced state).
pub struct SelfcheckReport {
    pub scenario: &'static str,
    pub backend: Backend,
    pub violations: Vec<Violation>,
}

/// Run the four audit-bearing experiments (E5 closed loop, E11 cluster
/// netpath, E14 interference, E15 tail attribution) on both backends and
/// collect each run's post-quiesce invariant audit. This is the dynamic
/// counterpart of `cargo xtask detlint`: the linter proves the *code*
/// keeps its determinism discipline, `selfcheck` proves the *runtime*
/// keeps its conservation laws.
pub fn selfcheck(duration: Time, seed: u64) -> Vec<SelfcheckReport> {
    let compute = PlatformConfig::default().function_compute_ns;
    let mut reports = Vec::new();
    for backend in [Backend::Containerd, Backend::Junctiond] {
        let (_, v) = fig5_run_audited(backend, 40, seed);
        reports.push(SelfcheckReport { scenario: "fig5", backend, violations: v });
        let (_, v) = netpath_point_audited(backend, 2, 10, compute, 2000.0, duration, seed);
        reports.push(SelfcheckReport { scenario: "netpath", backend, violations: v });
        let (_, v) = interference_run_audited(backend, 4, 400.0, 2 * MILLIS, duration, seed);
        reports.push(SelfcheckReport { scenario: "interference", backend, violations: v });
        let (_, v) = tail_attribution_run_audited(backend, duration, seed);
        reports.push(SelfcheckReport { scenario: "tail-blame", backend, violations: v });
    }
    reports
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::MILLIS;

    fn quiet_compute() -> Time {
        // Avoid PJRT in unit tests (artifact may be absent in CI shards):
        // use the platform default.
        PlatformConfig::default().function_compute_ns
    }

    fn cfg_no_pjrt(backend: Backend, seed: u64) -> ExperimentConfig {
        ExperimentConfig {
            backend,
            provider_cache: true,
            worker_cores: 10,
            seed,
            function_compute_ns: quiet_compute(),
            instance_concurrency: 4,
        }
    }

    fn fig5_no_pjrt(backend: Backend, n: u32, seed: u64) -> Fig5Result {
        let cfg = cfg_no_pjrt(backend, seed);
        let (mut sim, fs) = warm_deployment(&cfg);
        let mut r = ClosedLoop::new("aes", n).run(&mut sim, &fs);
        Fig5Result {
            gateway: r.gateway_observed.summary(),
            exec: r.exec.summary(),
            gateway_cdf: r.gateway_observed.cdf(),
            exec_cdf: r.exec.cdf(),
        }
    }

    #[test]
    fn fig5_shape_junction_wins_both_percentiles() {
        let c = fig5_no_pjrt(Backend::Containerd, 100, 1);
        let j = fig5_no_pjrt(Backend::Junctiond, 100, 1);
        // The paper's claims: median −37%, P99 −63% (gateway-observed);
        // accept a generous band around them (shape, not absolutes).
        let p50_red = 1.0 - j.gateway.p50 as f64 / c.gateway.p50 as f64;
        let p99_red = 1.0 - j.gateway.p99 as f64 / c.gateway.p99 as f64;
        assert!(p50_red > 0.20 && p50_red < 0.75, "p50 reduction {p50_red}");
        assert!(p99_red > 0.35 && p99_red < 0.95, "p99 reduction {p99_red}");
        // Exec-window reductions (paper: −35.3% median, −81% P99).
        let e50 = 1.0 - j.exec.p50 as f64 / c.exec.p50 as f64;
        let e99 = 1.0 - j.exec.p99 as f64 / c.exec.p99 as f64;
        assert!(e50 > 0.15 && e50 < 0.75, "exec p50 reduction {e50}");
        assert!(e99 > 0.30 && e99 < 0.97, "exec p99 reduction {e99}");
    }

    #[test]
    fn fig6_knee_is_an_order_of_magnitude_apart() {
        // Coarse grid to keep the test quick; SLA = 5 ms p99.
        let rates =
            vec![1000.0, 2000.0, 4000.0, 6000.0, 8000.0, 16000.0, 32000.0, 48000.0];
        let duration = SECONDS;
        let run = |backend| {
            rates
                .iter()
                .map(|&rate| {
                    let cfg = cfg_no_pjrt(backend, 3);
                    let (mut sim, fs) = warm_deployment(&cfg);
                    let mut r =
                        OpenLoop::new("aes", rate, duration, 3 ^ rate as u64).run(&mut sim, &fs);
                    Fig6Point {
                        backend,
                        offered_rps: rate,
                        goodput_rps: r.goodput_rps(),
                        p50: r.gateway_observed.quantile(0.5),
                        p99: r.gateway_observed.quantile(0.99),
                    }
                })
                .collect::<Vec<_>>()
        };
        let mut points = run(Backend::Containerd);
        points.extend(run(Backend::Junctiond));
        let sla = 5 * MILLIS;
        let kc = knee(&points, Backend::Containerd, sla);
        let kj = knee(&points, Backend::Junctiond, sla);
        let ratio = kj / kc.max(1.0);
        assert!(ratio > 4.0, "knee ratio {ratio} (containerd {kc}, junctiond {kj})");
    }

    #[test]
    fn coldstart_orders_of_magnitude() {
        let t = coldstart_table(10, 5);
        // Row 0: containerd init; row 2: junctiond init.
        let get = |r: usize, c: usize| match &t.rows[r][c] {
            Cell::F2(v) => *v,
            _ => panic!("unexpected cell"),
        };
        let c_init = get(0, 2);
        let j_init = get(2, 2);
        assert!(c_init > 50.0 * j_init, "container {c_init}ms vs junction {j_init}ms");
        // Junction init ≈ 3.4ms (paper).
        assert!((j_init - 3.4).abs() < 0.4, "junction init {j_init}ms");
    }

    #[test]
    fn tier_sweep_orders_ladder_and_preserves_backend_gap() {
        let t = coldstart_tiers_table(5, 7);
        // Rows per backend: warm-pool, snapshot-restore, cold-boot.
        let p50 = |r: usize| match &t.rows[r][2] {
            Cell::F2(v) => *v,
            _ => panic!("unexpected cell"),
        };
        let (c_warm, c_restore, c_cold) = (p50(0), p50(1), p50(2));
        let (j_warm, j_restore, j_cold) = (p50(3), p50(4), p50(5));
        assert!(c_warm < c_restore && c_restore < c_cold, "containerd ladder inverted");
        assert!(j_warm < j_restore && j_restore < j_cold, "junction ladder inverted");
        assert!(j_warm < c_warm && j_restore < c_restore && j_cold < c_cold,
            "junction must beat containerd at every tier");
        // The gap stays an order of magnitude at the restore tier.
        assert!(j_restore * 10.0 <= c_restore, "{j_restore} vs {c_restore}");
    }

    #[test]
    fn cache_ablation_shows_miss_penalty() {
        let t = ablation_cache_table(50, 2);
        let p50 = |row: usize| match &t.rows[row][2] {
            Cell::NsAsUs(v) => *v,
            _ => panic!(),
        };
        // containerd rows: 0 = cache on, 1 = cache off.
        assert!(
            p50(1) > p50(0) + 500 * MICROS,
            "cache off ({}) should be ≫ on ({})",
            p50(1),
            p50(0)
        );
    }

    #[test]
    fn isolation_junction_removes_host_surface() {
        let t = isolation_table(50, 1);
        let f2 = |r: usize, c: usize| match &t.rows[r][c] {
            Cell::F2(v) => *v,
            _ => panic!(),
        };
        // containerd: ≥10 host syscalls and ≥10 kernel messages per inv.
        assert!(f2(0, 1) > 10.0, "containerd host syscalls/inv {}", f2(0, 1));
        assert!(f2(0, 2) > 8.0, "containerd kernel msgs/inv {}", f2(0, 2));
        // junctiond: zero host syscalls on the request path; all syscalls
        // user-space.
        assert_eq!(f2(1, 1), 0.0, "junction host syscalls must be 0");
        assert_eq!(f2(1, 2), 0.0, "junction kernel msgs must be 0");
        assert!(f2(1, 4) >= 50.0, "junction user-space syscalls/inv {}", f2(1, 4));
    }

    #[test]
    fn autoscale_high_phase_grows_replicas() {
        let t = autoscale_table(Backend::Containerd, 3);
        let peak = |r: usize| match &t.rows[r][2] {
            Cell::Int(v) => *v,
            _ => panic!(),
        };
        assert!(peak(1) > peak(0), "high phase should grow replicas: {} vs {}", peak(1), peak(0));
    }

    fn netpath_small(backend: Backend, rates: &[f64], seed: u64) -> Vec<NetPathPoint> {
        netpath_cluster_run(backend, 2, 10, quiet_compute(), rates, 300 * MILLIS, seed)
    }

    #[test]
    fn netpath_cluster_junction_dominates_pointwise() {
        let rates = [1_000.0, 3_000.0];
        let c = netpath_small(Backend::Containerd, &rates, 7);
        let j = netpath_small(Backend::Junctiond, &rates, 7);
        for (cp, jp) in c.iter().zip(&j) {
            assert!(
                jp.p50 < cp.p50 && jp.p99 < cp.p99,
                "junction must win at {} rps: p50 {} vs {}, p99 {} vs {}",
                cp.offered_rps,
                jp.p50,
                cp.p50,
                jp.p99,
                cp.p99
            );
            assert_eq!(cp.dropped, 0, "no drops below the NIC packet rate");
            assert_eq!(jp.dropped, 0);
            // The per-hop breakdown is populated and ordered sensibly: the
            // kernel NIC hop costs more than the polled one.
            assert!(jp.nic_p50 < cp.nic_p50, "{} vs {}", jp.nic_p50, cp.nic_p50);
            assert!(cp.exec_p50 > 0 && cp.gw_p50 > 0);
        }
    }

    #[test]
    fn netpath_cluster_junction_sustains_high_rate() {
        let j = netpath_small(Backend::Junctiond, &[12_000.0], 11);
        let p = &j[0];
        assert!(p.p99 < 5 * MILLIS, "junction p99 {} at 12k rps", p.p99);
        assert!(p.goodput_rps > 10_000.0, "goodput {}", p.goodput_rps);
        assert_eq!(p.dropped, 0);
    }

    #[test]
    fn density_point_small_scale_completes() {
        let p = density_scale_run(Backend::Junctiond, 2, 10, 200, 16, 2_000.0, 300 * MILLIS, 9);
        assert_eq!(p.functions, 200);
        assert_eq!(p.dropped, 0, "junction path must not shed at this rate");
        assert!(
            p.completed == p.submitted,
            "all in-window requests must resolve: {} vs {}",
            p.completed,
            p.submitted
        );
        assert!(p.submitted > 400, "offered 2k rps over 300ms: {}", p.submitted);
        assert!(p.events_fired > p.completed * 5, "pipeline is many events per invocation");
        assert!(p.p50 > 0 && p.p99 >= p.p50);
    }

    /// E12's determinism clause at test scale: the wheel and the
    /// reference heap produce identical *virtual-time* results for the
    /// same density workload (host wall-clock is the only thing allowed
    /// to differ).
    #[test]
    fn density_virtual_results_identical_across_engines() {
        use crate::simcore::{set_default_engine, EngineKind};
        let run = || density_scale_run(Backend::Junctiond, 2, 10, 120, 12, 1_500.0, 200 * MILLIS, 4);
        let a = run();
        let prev = set_default_engine(EngineKind::ReferenceHeap);
        let b = run();
        set_default_engine(prev);
        assert_eq!(a.engine, "wheel");
        assert_eq!(b.engine, "reference-heap");
        assert_eq!(a.submitted, b.submitted);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.virtual_ns, b.virtual_ns, "virtual clocks diverged");
        assert_eq!(a.events_fired, b.events_fired, "event counts diverged");
        assert_eq!((a.p50, a.p99), (b.p50, b.p99), "latency tables diverged");
    }

    #[test]
    fn duplex_kernel_tx_pinned_junction_amortizes() {
        let c = duplex_cluster_run(Backend::Containerd, 2, 10, 600, &[2_000.0], 200 * MILLIS, 7);
        let j = duplex_cluster_run(Backend::Junctiond, 2, 10, 600, &[2_000.0], 200 * MILLIS, 7);
        let (cp, jp) = (&c[0], &j[0]);
        // Kernel TX flushes one frame per qdisc pass — exactly 1.0.
        assert!(cp.tx_packets > 0);
        assert!((cp.tx_mean_batch - 1.0).abs() < 1e-9, "kernel TX batch {}", cp.tx_mean_batch);
        // Bypass TX is polled: amortization is at least 1 and the polled
        // hop undercuts the kernel one.
        assert!(jp.tx_mean_batch >= 1.0, "{}", jp.tx_mean_batch);
        assert!(jp.tx_p50 < cp.tx_p50, "polled TX {} vs kernel {}", jp.tx_p50, cp.tx_p50);
        // Conservation at the response direction, both backends.
        for p in [cp, jp] {
            assert_eq!(p.submitted, p.completed + p.dropped, "{:?} leaked", p.backend);
            assert_eq!(p.tx_packets, p.served, "{:?}: TX frames != served", p.backend);
            assert_eq!(p.gw_rx_packets, p.served, "{:?}: gateway RX != served", p.backend);
        }
    }

    #[test]
    fn duplex_payload_sweep_widens_kernel_gap() {
        let (_, points) =
            duplex_payload_sweep_table(2, 10, &[64, 16_384], 1_000.0, 200 * MILLIS, 3);
        let find = |b: Backend, pl: u64| {
            points.iter().find(|p| p.backend == b && p.payload_bytes == pl).unwrap()
        };
        let c_small = find(Backend::Containerd, 64);
        let c_big = find(Backend::Containerd, 16_384);
        let j_small = find(Backend::Junctiond, 64);
        let j_big = find(Backend::Junctiond, 16_384);
        // The kernel path pays the per-KiB copy both ways: a 16 KiB echo
        // costs it visibly more than a 64 B one on the TX hop.
        assert!(
            c_big.tx_p50 > c_small.tx_p50 + 3 * MICROS,
            "kernel TX must pay the copy: {} vs {}",
            c_big.tx_p50,
            c_small.tx_p50
        );
        // The zero-copy path's TX hop barely moves.
        assert!(
            j_big.tx_p50 < j_small.tx_p50 + 3 * MICROS,
            "bypass TX must stay payload-flat: {} vs {}",
            j_big.tx_p50,
            j_small.tx_p50
        );
        // And junctiond wins end-to-end at every payload.
        assert!(j_small.p50 < c_small.p50 && j_big.p50 < c_big.p50);
    }

    #[test]
    fn interference_emerges_structurally_and_conserves() {
        // E14 at test scale: co-locating heavy antagonists must blow up
        // the kernel backend's tail structurally (no sampled interference
        // — residual jitter is off by default) while the bypass backend's
        // fair-share grants keep the latency function's tail bounded.
        let dur = 250 * MILLIS;
        let run = |b, n| interference_run(b, n, 400.0, 2 * crate::simcore::MILLIS, dur, 3);
        let k0 = run(Backend::Containerd, 0);
        let k12 = run(Backend::Containerd, 12);
        let j0 = run(Backend::Junctiond, 0);
        let j12 = run(Backend::Junctiond, 12);
        assert!(
            k12.p99 as f64 > 3.0 * k0.p99 as f64,
            "kernel tail must degrade under antagonists: {} → {}",
            k0.p99,
            k12.p99
        );
        assert!(
            (j12.p99 as f64) < 4.0 * j0.p99 as f64,
            "bypass tail must stay bounded: {} → {}",
            j0.p99,
            j12.p99
        );
        assert!(
            j12.fabric.preemptions > 0,
            "bypass regrants must preempt at quantum edges"
        );
        assert!(k12.fabric.preemptions > 0, "kernel timeslicing must preempt");
        for p in [&k0, &k12, &j0, &j12] {
            assert_eq!(
                p.fabric.per_core_busy_ns.iter().sum::<u64>(),
                p.fabric.busy_ns,
                "{:?}: per-core busy_ns must sum to the fabric total",
                p.backend
            );
            assert_eq!(
                p.fabric.jobs_submitted, p.fabric.jobs_completed,
                "{:?}: every issued segment must complete",
                p.backend
            );
            assert_eq!(p.dropped, 0, "{:?}: nothing drops at these packet rates", p.backend);
        }
    }

    #[test]
    fn e15_blame_shares_sum_to_one() {
        for backend in [Backend::Containerd, Backend::Junctiond] {
            let p = tail_attribution_run(backend, 30 * MILLIS, 11);
            assert!(p.report.count > 0, "{backend:?}: no traced completions");
            let s50: f64 = p.report.p50.iter().sum();
            let s99: f64 = p.report.p99.iter().sum();
            assert!((s50 - 1.0).abs() < 1e-9, "{backend:?}: p50 shares sum to {s50}");
            assert!((s99 - 1.0).abs() < 1e-9, "{backend:?}: p99 shares sum to {s99}");
            assert_eq!(p.exemplars.len(), 8, "{backend:?}: reservoir should be full");
        }
    }

    #[test]
    fn e15_blame_shape_kernel_vs_bypass() {
        // 150k rps is past the kernel netpath's drain capacity but well
        // inside compute capacity: the kernel backend's p99 tail must be
        // blamed on the netpath + pre-exec stages, the bypass backend's
        // on execution itself.
        let c = tail_attribution_run(Backend::Containerd, 60 * MILLIS, 11);
        let j = tail_attribution_run(Backend::Junctiond, 60 * MILLIS, 11);
        let c_net = c.report.p99[1] + c.report.p99[2];
        let j_net = j.report.p99[1] + j.report.p99[2];
        assert!(c_net > 0.5, "kernel p99 should be net/sched dominated: {c_net}");
        assert!(c_net > j_net, "kernel net/sched blame {c_net} must exceed bypass {j_net}");
        let j_max = j.report.p99.iter().cloned().fold(0.0f64, f64::max);
        assert!(
            (j.report.p99[3] - j_max).abs() < 1e-12,
            "bypass p99 should be exec-dominated: {:?}",
            j.report.p99
        );
    }

    #[test]
    fn e15_table_is_deterministic() {
        let (a, _) = tail_attribution_table(30 * MILLIS, 5);
        let (b, _) = tail_attribution_table(30 * MILLIS, 5);
        assert_eq!(a.to_markdown(), b.to_markdown(), "same-seed E15 tables diverged");
    }

    #[test]
    fn multitenant_junction_dominates() {
        let t = multitenant_table(20, 500.0, 9);
        let p99 = |r: usize| match &t.rows[r][4] {
            Cell::NsAsUs(v) => *v,
            _ => panic!(),
        };
        assert!(p99(1) < p99(0), "junction p99 {} vs containerd {}", p99(1), p99(0));
    }

    #[test]
    fn scaleup_modes_all_serve() {
        let t = ablation_scaleup_table(2_000.0, 4);
        assert_eq!(t.rows.len(), 12);
        for row in &t.rows {
            if let Cell::F2(goodput) = row[2] {
                assert!(goodput > 500.0, "goodput {goodput} too low");
            }
        }
    }

    #[test]
    fn e16_matrix_conserves_and_audits_clean() {
        let (t, points) = resilience_table(60 * MILLIS, 3);
        assert_eq!(t.rows.len(), 8, "4 scenarios × 2 backends");
        for p in &points {
            assert!(
                p.conserved(),
                "{:?}/{}: submitted {} != completed {} + dropped {} + timed_out {}",
                p.backend,
                p.scenario,
                p.submitted,
                p.completed,
                p.dropped,
                p.timed_out
            );
            assert!(p.completed > 0, "{:?}/{}: nothing completed", p.backend, p.scenario);
            assert!(
                p.violations.is_empty(),
                "{:?}/{}: audit violations: {:?}",
                p.backend,
                p.scenario,
                p.violations
            );
        }
        // Crash legs must actually pay a re-provision, and the bypass
        // backend's restore must beat the kernel backend's.
        let rec = |b: Backend| {
            points.iter().find(|p| p.backend == b && p.scenario == "crash+loss").unwrap().recovery_ns
        };
        assert!(rec(Backend::Junctiond) > 0, "junction crash paid no recovery");
        assert!(
            rec(Backend::Junctiond) < rec(Backend::Containerd),
            "bypass restore {} must beat kernel restore {}",
            rec(Backend::Junctiond),
            rec(Backend::Containerd)
        );
    }

    #[test]
    fn e16_table_is_deterministic() {
        let (a, _) = resilience_table(40 * MILLIS, 11);
        let (b, _) = resilience_table(40 * MILLIS, 11);
        assert_eq!(a.to_markdown(), b.to_markdown(), "same-seed E16 tables diverged");
    }

    fn e18_quick(shards: usize, threaded: bool) -> ShardScalePoint {
        shard_scale_run(
            Backend::Junctiond,
            shards,
            threaded,
            4,
            8,
            256,
            32,
            4_000.0,
            50 * MILLIS,
            13,
        )
    }

    #[test]
    fn e18_table_is_shard_count_invariant() {
        // Neutralize the one cell that legitimately differs (the shard
        // count itself); every other rendered byte must match.
        let mut a = e18_quick(1, false);
        let mut b = e18_quick(2, false);
        a.shards = 0;
        b.shards = 0;
        assert_eq!(
            shard_scale_table(std::slice::from_ref(&a)).to_markdown(),
            shard_scale_table(std::slice::from_ref(&b)).to_markdown(),
            "sharding changed the model's results"
        );
        assert!(a.submitted > 50 && a.completed > 0, "workload too small to mean anything");
    }
}
