//! E17 — the same-time commutativity schedule explorer (`schedcheck`).
//!
//! The sharded-engine refactor (ROADMAP) will execute events *within* a
//! conservative synchronization window in whatever order the shards
//! reach them; only the cross-window order is guaranteed. The question
//! this harness answers empirically is therefore: **which results
//! depend on the engine's same-timestamp tie-break order?**
//!
//! It reruns whole experiment tables under every [`TieBreak`] policy —
//! schedule order, reverse schedule order, and a seeded shuffle — by
//! flipping the thread-local default ([`set_default_tiebreak`]) around
//! the run, exactly the way the differential engine tests flip
//! [`crate::simcore::set_default_engine`]. A table whose rendered bytes
//! are identical under all three permutations is certified
//! *tie-break-invariant*: every same-instant race in that run commutes,
//! so the rows are safe for intra-window parallel execution. A table
//! that diverges is reported with the first diverging line so the race
//! can be fixed (distinct timestamps), declared (a `tie-break:`
//! rationale for detlint L7), or excluded from the parallel plan.
//!
//! The harness also runs a deliberately order-dependent workload — a
//! controller sampling a gauge that arrivals increment at the *same*
//! instant — and must flag it with the first diverging
//! `(time, seq, module)` triple: proof the explorer detects
//! non-commutativity rather than vacuously certifying everything.
//! Calibration cannot fool the byte-diff: [`super::calibrated_compute_ns`]
//! is process-cached, so every policy sees the same compute cost.

use std::cell::RefCell;
use std::rc::Rc;

use crate::simcore::{set_default_tiebreak, Sim, TieBreak, Time, MILLIS};
use crate::telemetry::Table;

/// The permutations a certification run compares, derived from the
/// experiment seed (ascending first: it is the engine default and the
/// baseline every CI byte-diff already pins).
pub fn policies(seed: u64) -> [TieBreak; 3] {
    [
        TieBreak::SeqAscending,
        TieBreak::SeqDescending,
        TieBreak::SeededShuffle(seed ^ 0x7361_6d65_7469_6d65),
    ]
}

/// Short display name of a policy.
pub fn policy_name(tb: TieBreak) -> String {
    match tb {
        TieBreak::SeqAscending => "seq-ascending".to_string(),
        TieBreak::SeqDescending => "seq-descending".to_string(),
        TieBreak::SeededShuffle(s) => format!("seeded-shuffle({s:#x})"),
    }
}

/// Result of rerunning one experiment table under every policy.
pub struct TableCert {
    pub name: &'static str,
    /// `(policy name, rendered markdown)` per policy, ascending first.
    pub renders: Vec<(String, String)>,
}

impl TableCert {
    /// Byte-identical under every policy?
    pub fn invariant(&self) -> bool {
        self.renders.iter().all(|(_, r)| *r == self.renders[0].1)
    }

    /// For a divergent table: `(policy name, line number, baseline line,
    /// divergent line)` of the first differing rendered line against the
    /// ascending baseline.
    pub fn first_diff(&self) -> Option<(String, usize, String, String)> {
        let base: Vec<&str> = self.renders[0].1.lines().collect();
        for (name, render) in &self.renders[1..] {
            if *render == self.renders[0].1 {
                continue;
            }
            let lines: Vec<&str> = render.lines().collect();
            for i in 0..base.len().max(lines.len()) {
                let a = base.get(i).copied().unwrap_or("<missing>");
                let b = lines.get(i).copied().unwrap_or("<missing>");
                if a != b {
                    return Some((name.clone(), i + 1, a.to_string(), b.to_string()));
                }
            }
        }
        None
    }
}

/// Render `table()` under tie-break policy `tb` (restoring the previous
/// thread default afterwards, even though every caller sets it anyway).
fn render_under<F: FnOnce() -> Table>(tb: TieBreak, table: F) -> String {
    let prev = set_default_tiebreak(tb);
    let t = table();
    set_default_tiebreak(prev);
    t.to_markdown()
}

/// Rerun `table` under every policy and compare the rendered bytes.
pub fn certify<F: Fn() -> Table>(name: &'static str, seed: u64, table: F) -> TableCert {
    let renders = policies(seed)
        .into_iter()
        .map(|tb| (policy_name(tb), render_under(tb, &table)))
        .collect();
    TableCert { name, renders }
}

/// One fired event of the order-dependent demonstration workload:
/// `(virtual time, schedule-order seq, module tag)`.
pub type Fire = (Time, u64, &'static str);

/// The divergence the demonstration workload must produce.
pub struct BadDiverge {
    pub policy_a: String,
    pub policy_b: String,
    /// Index into the fired-event sequence where the runs first differ.
    pub fire_index: usize,
    pub a: Fire,
    pub b: Fire,
}

/// A deliberately order-dependent workload: every millisecond, an
/// "arrival" event increments a shared gauge and a "controller" event
/// scheduled at the *identical* timestamp samples it to make a scaling
/// decision. Whichever fires first changes both the fired-event log and
/// the controller's samples — the exact hazard detlint L7 flags
/// statically and a sharded engine would hit nondeterministically.
fn bad_workload_fires(tb: TieBreak) -> Vec<Fire> {
    let mut sim = Sim::with_engine_and_tiebreak(crate::simcore::default_engine(), tb);
    let fires: Rc<RefCell<Vec<Fire>>> = Rc::new(RefCell::new(Vec::new()));
    let gauge = Rc::new(RefCell::new(0i64));
    let samples: Rc<RefCell<Vec<i64>>> = Rc::new(RefCell::new(Vec::new()));
    for k in 0..16u64 {
        let t = (k + 1) * MILLIS;
        let (f, g) = (fires.clone(), gauge.clone());
        sim.at(t, move |s| {
            *g.borrow_mut() += 1;
            let (time, seq) = s.current_fire().expect("inside a fire");
            f.borrow_mut().push((time, seq, "arrival"));
        });
        let (f, g, smp) = (fires.clone(), gauge.clone(), samples.clone());
        sim.at(t, move |s| {
            smp.borrow_mut().push(*g.borrow());
            let (time, seq) = s.current_fire().expect("inside a fire");
            f.borrow_mut().push((time, seq, "controller"));
        });
    }
    sim.run_to_completion();
    let v = fires.borrow().clone();
    v
}

/// Run the demonstration workload under every policy; return the first
/// divergence (`None` would mean the explorer failed to detect it).
pub fn bad_workload_divergence(seed: u64) -> Option<BadDiverge> {
    let pols = policies(seed);
    let base = bad_workload_fires(pols[0]);
    for &tb in &pols[1..] {
        let other = bad_workload_fires(tb);
        for i in 0..base.len().max(other.len()) {
            let a = base.get(i).copied();
            let b = other.get(i).copied();
            if a != b {
                return Some(BadDiverge {
                    policy_a: policy_name(pols[0]),
                    policy_b: policy_name(tb),
                    fire_index: i,
                    a: a.unwrap_or((0, 0, "<none>")),
                    b: b.unwrap_or((0, 0, "<none>")),
                });
            }
        }
    }
    None
}

/// Certify the experiment tables: E5 and E11 always, E16 unless
/// `quick`. Returns the per-table certificates plus the demonstration
/// divergence.
pub fn schedcheck(quick: bool, duration: Time, seed: u64) -> (Vec<TableCert>, Option<BadDiverge>) {
    let invocations = if quick { 40 } else { 100 };
    let mut certs = vec![
        certify("E5 fig5", seed, || super::fig5_table(invocations, seed).0),
        certify("E11 netpath", seed, || {
            super::netpath_table(
                2,
                16,
                &super::netpath_default_containerd_rates(),
                &super::netpath_default_junction_rates(),
                duration,
                seed,
            )
            .0
        }),
    ];
    if !quick {
        certs.push(certify("E16 resilience", seed, || super::resilience_table(duration, seed).0));
    }
    (certs, bad_workload_divergence(seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bad_workload_diverges_under_permuted_tiebreaks() {
        let d = bad_workload_divergence(17).expect("order-dependent workload must diverge");
        // The first divergence is a tied (same-time) pair: identical
        // virtual time, different (seq, module).
        assert_eq!(d.a.0, d.b.0, "divergence must be at a tied timestamp");
        assert_ne!((d.a.1, d.a.2), (d.b.1, d.b.2));
    }

    #[test]
    fn bad_workload_is_deterministic_per_policy() {
        for tb in policies(17) {
            assert_eq!(bad_workload_fires(tb), bad_workload_fires(tb));
        }
    }
}
