//! The unified runtime invariant auditor.
//!
//! Every stateful component of the simulation carries conservation laws —
//! cores are neither created nor destroyed, every submitted job is
//! completed or queued or running, warm-pool memory accounting matches
//! resident slots, every packet accepted by a ring is delivered or still
//! in it. Historically each component enforced its own laws with bare
//! `assert!`s inside a `check_invariants` method, invoked ad hoc from its
//! own tests. This module unifies them behind one vocabulary:
//!
//! - [`Audit`]: one component, one `module` name, structured
//!   [`Violation`]s instead of panic strings.
//! - [`AuditTree`]: a whole-simulation walker (`FaasSim`, `Cluster`)
//!   that audits every component it owns plus the cross-component laws
//!   (ring conservation) that no single component can see.
//! - [`audit_all`]: run a walker, collect everything.
//! - [`debug_quiesce`]: the debug-build hook called at simulation
//!   quiesce points (pool sweeps, cluster reconciles); compiled out of
//!   release builds so the hot path stays unmeasured.
//!
//! The CLI exposes the same walker as `junctiond-repro selfcheck`, and
//! `tests/invariants.rs` runs it after full E5/E11/E14/E15 experiments
//! on both backends. detlint's `unaudited_stats` lint (L4) closes the
//! loop: a `*Stats` struct that no audit or conservation test mentions
//! fails the build.

use std::fmt;

/// One broken invariant: which component, which law, and the observed
/// numbers. `rule` is a stable kebab-case identifier (catalogued in
/// DESIGN.md §3g) so tests and CI logs can match on it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub module: &'static str,
    pub rule: &'static str,
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}: {}", self.module, self.rule, self.detail)
    }
}

/// A component with self-checkable conservation laws.
pub trait Audit {
    /// Stable component name (`"junction/scheduler"`, `"simcore/fabric"`…).
    fn module(&self) -> &'static str;

    /// Append every currently-broken law to `out`. Must not mutate the
    /// component and must be safe to call at any externally-consistent
    /// point (between events, not mid-transition).
    fn audit_into(&self, out: &mut Vec<Violation>);

    /// Collect this component's violations.
    fn audit(&self) -> Vec<Violation> {
        let mut out = Vec::new();
        self.audit_into(&mut out);
        out
    }

    /// Panic with every violation listed — the structured replacement for
    /// the old bare-`assert!` `check_invariants` bodies.
    fn assert_clean(&self) {
        let v = self.audit();
        if !v.is_empty() {
            panic!("{} invariants violated:\n{}", self.module(), render(&v));
        }
    }
}

/// A simulation root that can audit every component it owns, plus the
/// cross-component laws between them.
pub trait AuditTree {
    fn audit_tree(&self, out: &mut Vec<Violation>);
}

/// Audit a whole simulation; empty means every law holds.
pub fn audit_all<T: AuditTree + ?Sized>(root: &T) -> Vec<Violation> {
    let mut out = Vec::new();
    root.audit_tree(&mut out);
    out
}

/// Debug-build quiesce hook: a full-tree audit that panics on the first
/// broken law. Compiled to nothing in release builds, so benches and the
/// paper-figure runs pay zero cost.
pub fn debug_quiesce<T: AuditTree + ?Sized>(root: &T) {
    if cfg!(debug_assertions) {
        let v = audit_all(root);
        if !v.is_empty() {
            panic!("quiesce audit failed:\n{}", render(&v));
        }
    }
}

/// Push a violation when `ok` is false. The detail closure keeps the
/// happy path allocation-free.
pub fn check<F: FnOnce() -> String>(
    out: &mut Vec<Violation>,
    module: &'static str,
    rule: &'static str,
    ok: bool,
    detail: F,
) {
    if !ok {
        out.push(Violation { module, rule, detail: detail() });
    }
}

fn render(v: &[Violation]) -> String {
    let lines: Vec<String> = v.iter().map(|v| format!("  {v}")).collect();
    lines.join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Toy {
        held: u64,
        capacity: u64,
    }

    impl Audit for Toy {
        fn module(&self) -> &'static str {
            "toy"
        }

        fn audit_into(&self, out: &mut Vec<Violation>) {
            check(out, self.module(), "held-capacity", self.held <= self.capacity, || {
                format!("held {} > capacity {}", self.held, self.capacity)
            });
        }
    }

    impl AuditTree for Toy {
        fn audit_tree(&self, out: &mut Vec<Violation>) {
            self.audit_into(out);
        }
    }

    #[test]
    fn clean_component_audits_empty() {
        let t = Toy { held: 1, capacity: 2 };
        assert!(t.audit().is_empty());
        t.assert_clean();
        assert!(audit_all(&t).is_empty());
        debug_quiesce(&t);
    }

    #[test]
    fn broken_component_reports_structured_violation() {
        let t = Toy { held: 3, capacity: 2 };
        let v = t.audit();
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].module, "toy");
        assert_eq!(v[0].rule, "held-capacity");
        assert!(v[0].detail.contains("3"));
    }

    #[test]
    #[should_panic(expected = "held-capacity")]
    fn assert_clean_panics_with_rule_name() {
        Toy { held: 3, capacity: 2 }.assert_clean();
    }
}
