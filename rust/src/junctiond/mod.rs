//! junctiond — the paper's contribution (§3–§4): the function manager that
//! replaces containerd as faasd's execution backend.
//!
//! junctiond is "a simple component that manages the configuration of
//! junction instances (including network settings), the deployment of
//! instances via the custom `junction_run` command, and the monitoring of
//! the running state of all functions" (§4). It is the only component that
//! runs *outside* a Junction instance, so it can spawn isolated instances
//! for each function; the faasd gateway and provider themselves run inside
//! Junction instances (§3, Figure 4).

mod manager;

pub use manager::{InstanceConfig, Junctiond, ManagerStats, RunState};
