//! The junctiond function manager.

use std::collections::BTreeMap;
use std::rc::Rc;

use crate::config::PlatformConfig;
use crate::faas::{FunctionSpec, ScaleMode};
use crate::invariants::{check, Audit, Violation};
use crate::junction::{InstanceId, InstanceState, Scheduler};
use crate::simcore::{Rng, Time};

/// Network + resource configuration junctiond writes for each instance
/// before `junction_run` (§4: "manages the configuration of junction
/// instances (including network settings)").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InstanceConfig {
    pub name: String,
    /// Local IP assigned to the instance's NIC queue pair.
    pub ip: u32,
    pub port: u16,
    pub queue_pairs: u32,
    pub max_cores: u32,
}

/// Monitoring snapshot for one function (§4 "monitoring the running state
/// of all functions").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunState {
    pub function: String,
    pub instances: u32,
    pub running: u32,
    pub uprocs: u32,
    pub in_flight: u32,
}

/// Crash-path counters (§4's monitoring loop remediation). Conservation
/// law: every revival answers a crash, so `restarted <= crashed` always
/// (crashes recovered by a fresh redeploy instead of a restart sweep
/// keep the inequality strict).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ManagerStats {
    /// Instances that died via [`Junctiond::fail_instance`].
    pub crashed: u64,
    /// Instances revived by [`Junctiond::restart_crashed`].
    pub restarted: u64,
}

/// The manager: owns the server's Junction scheduler, the per-function
/// instance sets, and their configs.
pub struct Junctiond {
    platform: Rc<PlatformConfig>,
    pub scheduler: Scheduler,
    functions: BTreeMap<String, Vec<InstanceId>>,
    configs: BTreeMap<InstanceId, InstanceConfig>,
    rng: Rng,
    next_ip: u32,
    next_port: u16,
    pub deploys: u64,
    pub stats: ManagerStats,
}

impl Junctiond {
    pub fn new(platform: Rc<PlatformConfig>, server_cores: u32, rng: Rng) -> Self {
        Junctiond {
            scheduler: Scheduler::new(platform.clone(), server_cores),
            platform,
            functions: BTreeMap::new(),
            configs: BTreeMap::new(),
            rng,
            next_ip: 0x0A01_0002, // 10.1.0.x — junction subnet
            next_port: 8080,
            deploys: 0,
            stats: ManagerStats::default(),
        }
    }

    /// Hand out the next free port. Allocates *before* incrementing, and
    /// after u16 wraparound skips the reserved range so ports below 1024
    /// are never handed to an instance.
    fn alloc_port(&mut self) -> u16 {
        let port = self.next_port;
        self.next_port = match self.next_port.checked_add(1) {
            Some(next) if next >= 1024 => next,
            _ => 1024, // wrapped past 65535 (or drifted into the reserved range)
        };
        port
    }

    fn alloc_config(&mut self, name: &str, max_cores: u32) -> InstanceConfig {
        let cfg = InstanceConfig {
            name: name.to_string(),
            ip: self.next_ip,
            port: self.alloc_port(),
            queue_pairs: max_cores,
            max_cores,
        };
        self.next_ip += 1;
        cfg
    }

    /// Boot-latency sample: `base` ± 5% (paper §5: instance init is fast
    /// and tight). Shared by cold boot, snapshot restore, and recovery.
    fn sample_boot(&mut self, base: Time) -> Time {
        let spread = base / 10;
        base - spread / 2 + self.rng.below(spread + 1)
    }

    /// `junction_run`: spawn one instance. Returns (id, cold_start_ns).
    /// Junction instance init is fast and tight: 3.4 ms ± a small spread
    /// (paper §5 "Cold starts").
    fn junction_run(&mut self, name: &str, max_cores: u32) -> (InstanceId, Time) {
        let base = self.platform.junction_cold_start_ns;
        self.junction_run_with(name, max_cores, base)
    }

    /// `junction_run` with an explicit boot-cost base (the snapshot-restore
    /// tier boots the same instance shape at a much lower cost).
    fn junction_run_with(&mut self, name: &str, max_cores: u32, boot_base: Time) -> (InstanceId, Time) {
        let cfg = self.alloc_config(name, max_cores);
        let id = self.scheduler.register(name, max_cores);
        self.configs.insert(id, cfg);
        let boot = self.sample_boot(boot_base);
        (id, boot)
    }

    /// Deploy a function per its spec. Returns (instance ids, cold_ns):
    /// * `MultiProcess` → 1 instance, `scale` uProcs (Python-style);
    /// * `MaxCores`     → 1 instance, 1 uProc, core cap = `scale`;
    /// * `IsolatedInstances` → `scale` instances of 1 uProc each.
    pub fn deploy_function(&mut self, spec: &FunctionSpec) -> (Vec<InstanceId>, Time) {
        let base = self.platform.junction_cold_start_ns;
        self.deploy_with_boot(spec, base)
    }

    /// Deploy from a per-function memory snapshot (the snapshot-restore
    /// tier): identical instance shape, restore-cost boot.
    pub fn restore_function(&mut self, spec: &FunctionSpec, restore_base_ns: Time) -> (Vec<InstanceId>, Time) {
        self.deploy_with_boot(spec, restore_base_ns)
    }

    fn deploy_with_boot(&mut self, spec: &FunctionSpec, boot_base: Time) -> (Vec<InstanceId>, Time) {
        self.deploys += 1;
        let mut ids = Vec::new();
        let mut cold_total = 0;
        match spec.scale_mode {
            ScaleMode::MultiProcess => {
                // One grantable core per uProc: with the compute fabric,
                // an instance's segments really run on its granted cores,
                // so a 1-core cap would serialize all uProcs (the seed's
                // flat pool hid this).
                let (id, cold) =
                    self.junction_run_with(&spec.name, spec.scale.max(1), boot_base);
                for k in 0..spec.scale.max(1) {
                    self.scheduler
                        .instance_mut(id)
                        .unwrap()
                        .spawn_uproc(&format!("{}-w{k}", spec.name));
                }
                ids.push(id);
                cold_total = cold;
            }
            ScaleMode::MaxCores => {
                let (id, cold) = self.junction_run_with(&spec.name, spec.scale.max(1), boot_base);
                self.scheduler.instance_mut(id).unwrap().spawn_uproc(&spec.name);
                ids.push(id);
                cold_total = cold;
            }
            ScaleMode::IsolatedInstances => {
                // Instances boot in parallel; cold time is the max.
                for k in 0..spec.scale.max(1) {
                    let (id, cold) = self.junction_run_with(&format!("{}-{k}", spec.name), 1, boot_base);
                    self.scheduler
                        .instance_mut(id)
                        .unwrap()
                        .spawn_uproc(&format!("{}-{k}", spec.name));
                    ids.push(id);
                    cold_total = cold_total.max(cold);
                }
            }
        }
        self.functions.insert(spec.name.clone(), ids.clone());
        (ids, cold_total)
    }

    /// Deploy one of the faasd *services* (gateway/provider) into its own
    /// instance (§3: "Junction instances are utilized not only to host the
    /// function code, but also to run the various services").
    pub fn deploy_service(&mut self, name: &str, max_cores: u32) -> (InstanceId, Time) {
        let (id, cold) = self.junction_run(name, max_cores);
        self.scheduler.instance_mut(id).unwrap().spawn_uproc(name);
        (id, cold)
    }

    /// Scale an existing function up/down per its mode.
    pub fn scale(&mut self, spec: &FunctionSpec, new_scale: u32) -> anyhow::Result<()> {
        let ids =
            self.functions.get(&spec.name).cloned().ok_or_else(|| {
                anyhow::anyhow!("scale: function '{}' not deployed", spec.name)
            })?;
        match spec.scale_mode {
            ScaleMode::MultiProcess => {
                let id = ids[0];
                let inst = self.scheduler.instance_mut(id).unwrap();
                let have = inst.uprocs.len() as u32;
                for k in have..new_scale {
                    inst.spawn_uproc(&format!("{}-w{k}", spec.name));
                }
                // Keep the core cap in step with the uProc count.
                if new_scale > inst.max_cores {
                    inst.set_max_cores(new_scale);
                    if let Some(cfg) = self.configs.get_mut(&id) {
                        cfg.max_cores = new_scale;
                        cfg.queue_pairs = new_scale;
                    }
                }
            }
            ScaleMode::MaxCores => {
                let id = ids[0];
                self.scheduler.instance_mut(id).unwrap().set_max_cores(new_scale.max(1));
                if let Some(cfg) = self.configs.get_mut(&id) {
                    cfg.max_cores = new_scale.max(1);
                    cfg.queue_pairs = new_scale.max(1);
                }
            }
            ScaleMode::IsolatedInstances => {
                anyhow::bail!("isolated-instance scaling redeploys; use deploy_function")
            }
        }
        Ok(())
    }

    pub fn instances_of(&self, name: &str) -> &[InstanceId] {
        self.functions.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn config_of(&self, id: InstanceId) -> Option<&InstanceConfig> {
        self.configs.get(&id)
    }

    /// Monitoring endpoint: run state of every function (§4).
    pub fn monitor(&self) -> Vec<RunState> {
        self.functions
            .iter()
            .map(|(name, ids)| {
                let mut running = 0;
                let mut uprocs = 0;
                let mut in_flight = 0;
                for id in ids {
                    let inst = self.scheduler.instance(*id).unwrap();
                    if inst.state == InstanceState::Running {
                        running += 1;
                    }
                    uprocs += inst.uprocs.len() as u32;
                    in_flight += inst.in_flight;
                }
                RunState {
                    function: name.clone(),
                    instances: ids.len() as u32,
                    running,
                    uprocs,
                    in_flight,
                }
            })
            .collect()
    }

    /// Failure injection: an instance's uProcs die (host process crash).
    /// The scheduler releases its cores; junctiond's monitor will report
    /// it non-running until [`Junctiond::restart_crashed`] revives it.
    pub fn fail_instance(&mut self, id: InstanceId) {
        self.stats.crashed += 1;
        let held = {
            let inst = self.scheduler.instance_mut(id).expect("unknown instance");
            inst.state = InstanceState::Stopped;
            inst.uprocs.clear();
            inst.in_flight = 0;
            inst.granted_cores = 0;
            std::mem::take(&mut inst.core_ids)
        };
        // Return the crashed instance's physical cores to the pool
        // (force_release records them in stats.releases).
        self.scheduler.force_release(held);
    }

    /// Crash-recovery sweep (the §4 monitoring loop's remediation): every
    /// Stopped instance is relaunched via `junction_run`. Returns
    /// (revived count, worst-case cold-start ns).
    pub fn restart_crashed(&mut self) -> (u32, Time) {
        let crashed: Vec<(InstanceId, String)> = self
            .functions
            .values()
            .flatten()
            .filter_map(|id| {
                let inst = self.scheduler.instance(*id)?;
                (inst.state == InstanceState::Stopped).then(|| (*id, inst.name.clone()))
            })
            .collect();
        let mut worst = 0;
        let n = crashed.len() as u32;
        self.stats.restarted += n as u64;
        for (id, name) in crashed {
            let inst = self.scheduler.instance_mut(id).unwrap();
            inst.spawn_uproc(&name);
            inst.state = InstanceState::Running;
            let base = self.platform.junction_cold_start_ns;
            let cold = self.sample_boot(base);
            worst = worst.max(cold);
        }
        (n, worst)
    }

    /// Detach a function's instances for parking in the warm pool: they
    /// stay registered with the scheduler (Running, idle, memory resident)
    /// but junctiond no longer lists the function. Returns the ids.
    pub fn park_instances(&mut self, name: &str) -> Vec<InstanceId> {
        let ids = self.functions.remove(name).unwrap_or_default();
        for id in &ids {
            let inst = self.scheduler.instance(*id).expect("unknown instance");
            debug_assert_eq!(inst.in_flight, 0, "parking a busy instance");
        }
        ids
    }

    /// Re-attach previously parked instances to a (re)deployed function —
    /// the warm-pool acquire path. The instances keep their network config
    /// (IP/port/queue pairs survive the park).
    pub fn adopt_instances(&mut self, name: &str, max_cores: u32, ids: &[InstanceId]) {
        self.deploys += 1;
        for id in ids {
            let inst = self.scheduler.instance_mut(*id).expect("unknown instance");
            inst.name = name.to_string();
            if inst.uprocs.is_empty() {
                inst.spawn_uproc(name);
            }
            inst.set_max_cores(max_cores.max(1));
            if let Some(cfg) = self.configs.get_mut(id) {
                cfg.name = name.to_string();
                cfg.max_cores = max_cores.max(1);
                cfg.queue_pairs = max_cores.max(1);
            }
        }
        self.functions.insert(name.to_string(), ids.to_vec());
    }

    /// Boot a fresh single-uProc instance straight into a parked state
    /// (background prewarm): registered and Running but attached to no
    /// function until adopted. Returns the instance and its boot latency.
    pub fn spawn_parked(&mut self, name: &str, max_cores: u32) -> (InstanceId, Time) {
        let (id, boot) = self.junction_run(name, max_cores);
        self.scheduler.instance_mut(id).unwrap().spawn_uproc(name);
        (id, boot)
    }

    /// Tear down an evicted pooled instance: stop it, return any cores,
    /// and free its network config.
    pub fn retire_instance(&mut self, id: InstanceId) {
        let held = {
            let inst = self.scheduler.instance_mut(id).expect("unknown instance");
            inst.state = InstanceState::Stopped;
            inst.uprocs.clear();
            inst.in_flight = 0;
            inst.granted_cores = 0;
            std::mem::take(&mut inst.core_ids)
        };
        self.scheduler.force_release(held);
        self.configs.remove(&id);
    }

    /// Per-instance effective concurrency for the pipeline's gate.
    pub fn concurrency_of(&self, id: InstanceId, spec: &FunctionSpec) -> u32 {
        let inst = self.scheduler.instance(id).expect("unknown instance");
        match spec.scale_mode {
            ScaleMode::MultiProcess => inst.concurrency(1),
            ScaleMode::MaxCores => inst.max_cores.min(self.platform.junction_max_cores as u32),
            ScaleMode::IsolatedInstances => 1,
        }
    }
}

/// Referential-integrity laws of the function manager: the function
/// index and the network-config map may only point at instances the
/// scheduler actually knows. (Parked instances leave `functions` but
/// keep their config; retired instances keep their registration but lose
/// the config — both directions are one-way inclusions, not bijections.)
impl Audit for Junctiond {
    fn module(&self) -> &'static str {
        "junctiond/manager"
    }

    fn audit_into(&self, out: &mut Vec<Violation>) {
        let m = self.module();
        for (name, ids) in &self.functions {
            for &id in ids {
                check(out, m, "function-map", self.scheduler.instance(id).is_some(), || {
                    format!("function {name} lists instance {id} unknown to the scheduler")
                });
                check(out, m, "function-map", self.configs.contains_key(&id), || {
                    format!("function {name} instance {id} has no network config")
                });
            }
        }
        for id in self.configs.keys() {
            check(out, m, "config-map", self.scheduler.instance(*id).is_some(), || {
                format!("network config held for instance {id} unknown to the scheduler")
            });
        }
        check(out, m, "crash-conservation", self.stats.restarted <= self.stats.crashed, || {
            format!(
                "restarted {} > crashed {} — a revival without a crash",
                self.stats.restarted, self.stats.crashed
            )
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faas::RuntimeKind;
    use crate::simcore::MILLIS;

    fn manager() -> Junctiond {
        Junctiond::new(Rc::new(PlatformConfig::default()), 10, Rng::new(17))
    }

    #[test]
    fn deploy_python_multiprocess() {
        let mut jd = manager();
        let spec = FunctionSpec::new("py-fn", "aes600", RuntimeKind::Python)
            .with_scale(ScaleMode::MultiProcess, 4);
        let (ids, cold) = jd.deploy_function(&spec);
        assert_eq!(ids.len(), 1);
        assert!(cold > 3 * MILLIS && cold < 4 * MILLIS, "cold={cold}");
        let inst = jd.scheduler.instance(ids[0]).unwrap();
        assert_eq!(inst.uprocs.len(), 4);
        assert_eq!(jd.concurrency_of(ids[0], &spec), 4);
    }

    #[test]
    fn deploy_go_maxcores() {
        let mut jd = manager();
        let spec = FunctionSpec::new("go-fn", "aes600", RuntimeKind::Go)
            .with_scale(ScaleMode::MaxCores, 6);
        let (ids, _) = jd.deploy_function(&spec);
        let inst = jd.scheduler.instance(ids[0]).unwrap();
        assert_eq!(inst.max_cores, 6);
        assert_eq!(inst.queue_pairs, 6);
        assert_eq!(jd.concurrency_of(ids[0], &spec), 6);
    }

    #[test]
    fn deploy_isolated_instances() {
        let mut jd = manager();
        let spec = FunctionSpec::new("iso-fn", "aes600", RuntimeKind::Go)
            .with_scale(ScaleMode::IsolatedInstances, 3);
        let (ids, _) = jd.deploy_function(&spec);
        assert_eq!(ids.len(), 3);
        // Distinct network configs per instance.
        let ips: Vec<u32> = ids.iter().map(|id| jd.config_of(*id).unwrap().ip).collect();
        let mut dedup = ips.clone();
        dedup.dedup();
        assert_eq!(ips.len(), dedup.len());
    }

    #[test]
    fn services_run_in_instances_too() {
        let mut jd = manager();
        let (gw, _) = jd.deploy_service("gateway", 2);
        let (prov, _) = jd.deploy_service("provider", 2);
        assert_ne!(gw, prov);
        assert_eq!(jd.scheduler.instance(gw).unwrap().state, InstanceState::Running);
    }

    #[test]
    fn scale_up_multiprocess_adds_uprocs() {
        let mut jd = manager();
        let spec = FunctionSpec::new("py", "aes600", RuntimeKind::Python)
            .with_scale(ScaleMode::MultiProcess, 1);
        let (ids, _) = jd.deploy_function(&spec);
        jd.scale(&spec, 5).unwrap();
        assert_eq!(jd.scheduler.instance(ids[0]).unwrap().uprocs.len(), 5);
    }

    #[test]
    fn scale_up_maxcores_updates_config() {
        let mut jd = manager();
        let spec =
            FunctionSpec::new("go", "aes600", RuntimeKind::Go).with_scale(ScaleMode::MaxCores, 2);
        let (ids, _) = jd.deploy_function(&spec);
        jd.scale(&spec, 8).unwrap();
        assert_eq!(jd.config_of(ids[0]).unwrap().max_cores, 8);
        assert_eq!(jd.scheduler.instance(ids[0]).unwrap().max_cores, 8);
    }

    #[test]
    fn crash_and_recover_cycle() {
        let mut jd = manager();
        let spec = FunctionSpec::new("aes", "aes600", RuntimeKind::Go);
        let (ids, _) = jd.deploy_function(&spec);
        let id = ids[0];
        // Instance takes traffic, then crashes mid-flight.
        jd.scheduler.packet_arrival(id);
        assert_eq!(jd.scheduler.granted_total(), 1);
        jd.fail_instance(id);
        assert_eq!(jd.scheduler.instance(id).unwrap().state, InstanceState::Stopped);
        assert_eq!(jd.scheduler.granted_total(), 0, "crashed cores must return to the pool");
        // Monitoring shows it down.
        let down = jd.monitor();
        assert_eq!(down[0].running, 0);
        // Recovery sweep relaunches at junction cold-start cost (~3.4ms).
        let (revived, worst) = jd.restart_crashed();
        assert_eq!(revived, 1);
        assert!(worst > 3 * MILLIS && worst < 4 * MILLIS);
        assert_eq!(jd.monitor()[0].running, 1);
        assert_eq!(jd.stats, ManagerStats { crashed: 1, restarted: 1 });
        jd.assert_clean();
        jd.scheduler.check_invariants();
        // And it serves again.
        assert!(matches!(
            jd.scheduler.packet_arrival(id),
            crate::junction::GrantOutcome::Granted { .. }
        ));
    }

    #[test]
    fn restart_is_noop_without_crashes() {
        let mut jd = manager();
        jd.deploy_function(&FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        let (revived, worst) = jd.restart_crashed();
        assert_eq!((revived, worst), (0, 0));
    }

    #[test]
    fn port_allocation_returns_allocated_then_advances() {
        let mut jd = manager();
        assert_eq!(jd.next_port, 8080);
        let p = jd.alloc_port();
        assert_eq!(p, 8080, "must hand out the current port, not the next one");
        assert_eq!(jd.next_port, 8081);
    }

    #[test]
    fn port_allocation_skips_reserved_range_on_wraparound() {
        let mut jd = manager();
        jd.next_port = u16::MAX;
        assert_eq!(jd.alloc_port(), u16::MAX);
        // Wrapped: never hand out 0..1024.
        let p = jd.alloc_port();
        assert_eq!(p, 1024, "after wraparound allocation must resume at 1024");
        assert_eq!(jd.alloc_port(), 1025);
        for _ in 0..100 {
            assert!(jd.alloc_port() >= 1024);
        }
    }

    #[test]
    fn park_adopt_cycle_keeps_config_and_serves() {
        let mut jd = manager();
        let spec = FunctionSpec::new("aes", "aes600", RuntimeKind::Go);
        let (ids, _) = jd.deploy_function(&spec);
        let cfg_before = jd.config_of(ids[0]).unwrap().clone();
        let parked = jd.park_instances("aes");
        assert_eq!(parked, ids);
        assert!(jd.instances_of("aes").is_empty());
        assert!(jd.monitor().is_empty(), "parked functions leave the monitor");
        // Instance is still Running (memory resident), just detached.
        assert_eq!(jd.scheduler.instance(ids[0]).unwrap().state, InstanceState::Running);
        jd.adopt_instances("aes", 1, &parked);
        assert_eq!(jd.instances_of("aes"), &parked[..]);
        let cfg_after = jd.config_of(ids[0]).unwrap();
        assert_eq!(cfg_after.ip, cfg_before.ip, "network config survives the park");
        assert_eq!(cfg_after.port, cfg_before.port);
        assert!(matches!(
            jd.scheduler.packet_arrival(ids[0]),
            crate::junction::GrantOutcome::Granted { .. }
        ));
        jd.scheduler.check_invariants();
    }

    #[test]
    fn retire_frees_config_and_stops_instance() {
        let mut jd = manager();
        let (ids, _) = jd.deploy_function(&FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        let parked = jd.park_instances("aes");
        jd.retire_instance(parked[0]);
        assert_eq!(jd.scheduler.instance(ids[0]).unwrap().state, InstanceState::Stopped);
        assert!(jd.config_of(ids[0]).is_none());
        jd.scheduler.check_invariants();
    }

    #[test]
    fn restore_is_much_cheaper_than_cold_boot() {
        let mut jd = manager();
        let spec = FunctionSpec::new("aes", "aes600", RuntimeKind::Go);
        let (_, cold) = jd.deploy_function(&spec);
        jd.park_instances("aes");
        let restore_base = PlatformConfig::default().junction_restore_ns;
        let spec2 = FunctionSpec::new("aes-r", "aes600", RuntimeKind::Go);
        let (ids, restore) = jd.restore_function(&spec2, restore_base);
        assert_eq!(ids.len(), 1);
        assert!(restore * 4 < cold, "restore {restore} should be ≪ cold {cold}");
        assert_eq!(jd.scheduler.instance(ids[0]).unwrap().state, InstanceState::Running);
    }

    #[test]
    fn monitor_reports_all_functions() {
        let mut jd = manager();
        jd.deploy_function(&FunctionSpec::new("a", "aes600", RuntimeKind::Go));
        jd.deploy_function(&FunctionSpec::new("b", "aes600", RuntimeKind::Python));
        let states = jd.monitor();
        assert_eq!(states.len(), 2);
        assert!(states.iter().all(|s| s.running == s.instances));
    }
}
