//! junctiond-repro CLI — the launcher for every experiment in the repo.
//!
//! ```text
//! junctiond-repro fig5      [--invocations N] [--seed S] [--csv DIR]
//! junctiond-repro fig6      [--duration-ms MS] [--seed S] [--csv DIR]
//! junctiond-repro coldstart [--trials N] [--seed S]
//! junctiond-repro ablation  --which cache|polling|scaleup|...|blame [--trace-out FILE]
//! junctiond-repro density   [--workers N] [--worker-cores N] [--functions N]
//!                           [--hot N] [--rate RPS] [--duration-ms MS] [--seed S]
//! junctiond-repro shardscale [--shards N] [--serial] [--workers N] [--worker-cores N]
//!                           [--functions N] [--hot N] [--rate RPS] [--duration-ms MS] [--seed S]
//! junctiond-repro serve     --mode kernel|bypass [--requests N]
//! junctiond-repro calibrate [--runs N]
//! junctiond-repro selfcheck [--duration-ms MS] [--seed S]
//! junctiond-repro schedcheck [--quick] [--duration-ms MS] [--seed S]
//! junctiond-repro monitor
//! ```
//!
//! (Hand-rolled argument parsing: the crates.io registry is offline in
//! this environment, so no clap.)

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use junctiond_repro::config::Backend;
use junctiond_repro::experiments as ex;
use junctiond_repro::server::{run_pipeline, ServeMode};
use junctiond_repro::simcore::{MICROS, MILLIS};
use junctiond_repro::telemetry::write_csv;

/// Flags that take no value (presence is the value).
const BOOL_FLAGS: [&str; 2] = ["quick", "serial"];

fn parse_flags(args: &[String]) -> Result<BTreeMap<String, String>> {
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let Some(key) = a.strip_prefix("--") else {
            bail!("unexpected argument '{a}'");
        };
        if BOOL_FLAGS.contains(&key) {
            flags.insert(key.to_string(), "1".to_string());
            i += 1;
            continue;
        }
        let val = args.get(i + 1).cloned().unwrap_or_default();
        anyhow::ensure!(!val.starts_with("--") && !val.is_empty(), "flag --{key} needs a value");
        flags.insert(key.to_string(), val);
        i += 2;
    }
    Ok(flags)
}

fn get_u64(flags: &BTreeMap<String, String>, key: &str, default: u64) -> Result<u64> {
    flags
        .get(key)
        .map(|v| v.parse::<u64>().with_context(|| format!("--{key} '{v}' is not a number")))
        .unwrap_or(Ok(default))
}

fn maybe_csv(
    flags: &BTreeMap<String, String>,
    table: &junctiond_repro::telemetry::Table,
    name: &str,
) -> Result<()> {
    if let Some(dir) = flags.get("csv") {
        let path = std::path::Path::new(dir).join(format!("{name}.csv"));
        write_csv(table, &path)?;
        eprintln!("# wrote {}", path.display());
    }
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: junctiond-repro \
         <fig5|fig6|coldstart|ablation|density|shardscale|serve|calibrate|selfcheck|schedcheck|\
         monitor> [flags]\n\
         flags: --invocations N --trials N --duration-ms MS --seed S --csv DIR --quick\n\
         --which cache|polling|scaleup|isolation|autoscale|multitenant|tiers|netpath|duplex|\
         interference|blame|faults\n\
         --mode kernel|bypass --requests N --runs N --workers N --worker-cores N\n\
         --functions N --hot N --rate RPS --payload BYTES --trace-out FILE\n\
         --shards N --serial (shardscale: engine shards / single-threaded transport)"
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else { usage() };
    let flags = parse_flags(&argv[1..])?;
    match cmd.as_str() {
        "fig5" => {
            let n = get_u64(&flags, "invocations", 100)? as u32;
            let seed = get_u64(&flags, "seed", 1)?;
            let (table, _, _) = ex::fig5_table(n, seed);
            println!("{}", table.to_markdown());
            maybe_csv(&flags, &table, "fig5")?;
        }
        "fig6" => {
            let dur = get_u64(&flags, "duration-ms", 1000)? * MILLIS;
            let seed = get_u64(&flags, "seed", 3)?;
            let rates = ex::fig6_default_rates();
            let (table, points) = ex::fig6_table(&rates, dur, seed);
            println!("{}", table.to_markdown());
            let sla = 5 * MILLIS;
            let kc = ex::knee(&points, Backend::Containerd, sla);
            let kj = ex::knee(&points, Backend::Junctiond, sla);
            println!(
                "sustainable throughput (p99 ≤ 5ms): containerd {kc:.0} rps, junctiond {kj:.0} rps ({:.1}×)",
                kj / kc.max(1.0)
            );
            maybe_csv(&flags, &table, "fig6")?;
        }
        "coldstart" => {
            let trials = get_u64(&flags, "trials", 100)? as u32;
            let seed = get_u64(&flags, "seed", 5)?;
            let table = ex::coldstart_table(trials, seed);
            println!("{}", table.to_markdown());
            maybe_csv(&flags, &table, "coldstart")?;
        }
        "ablation" => {
            let which = flags.get("which").map(|s| s.as_str()).unwrap_or("cache");
            let seed = get_u64(&flags, "seed", 2)?;
            if which == "netpath" {
                // Cluster-scale Fig. 6: the network data path under load.
                let dur = get_u64(&flags, "duration-ms", 400)? * MILLIS;
                let workers = get_u64(&flags, "workers", 2)? as usize;
                let cores = get_u64(&flags, "worker-cores", 16)? as usize;
                let (table, points) = ex::netpath_table(
                    workers,
                    cores,
                    &ex::netpath_default_containerd_rates(),
                    &ex::netpath_default_junction_rates(),
                    dur,
                    seed,
                );
                println!("{}", table.to_markdown());
                let sla = 5 * MILLIS;
                let kc = ex::netpath_knee(&points, Backend::Containerd, sla);
                let kj = ex::netpath_knee(&points, Backend::Junctiond, sla);
                println!(
                    "cluster sustainable throughput (p99 ≤ 5ms): containerd {kc:.0} rps, \
                     junctiond {kj:.0} rps ({:.1}×)",
                    kj / kc.max(1.0)
                );
                maybe_csv(&flags, &table, "ablation_netpath")?;
                return Ok(());
            }
            if which == "interference" {
                // E14: structural interference — a latency-sensitive
                // function co-located with antagonist tenants, residual
                // jitter off, the tail arising only from per-core
                // contention in the compute fabric. Deterministic output
                // (platform-default compute, no wall clock): the CI
                // determinism job diffs two same-seed runs.
                let dur = get_u64(&flags, "duration-ms", 400)? * MILLIS;
                let rate = get_u64(&flags, "rate", 400)? as f64;
                let (table, points) = ex::interference_table(
                    &ex::interference_default_counts(),
                    rate,
                    2 * MILLIS,
                    dur,
                    seed,
                );
                println!("{}", table.to_markdown());
                let factor = |b: Backend| {
                    let base = points
                        .iter()
                        .find(|p| p.backend == b && p.antagonists == 0)
                        .map(|p| p.p99)
                        .unwrap_or(1);
                    let top = points
                        .iter()
                        .filter(|p| p.backend == b)
                        .max_by_key(|p| p.antagonists)
                        .map(|p| p.p99)
                        .unwrap_or(base);
                    top as f64 / base.max(1) as f64
                };
                println!(
                    "p99 degradation at the top antagonist load: containerd {:.1}×, junctiond {:.1}×",
                    factor(Backend::Containerd),
                    factor(Backend::Junctiond)
                );
                maybe_csv(&flags, &table, "ablation_interference")?;
                return Ok(());
            }
            if which == "blame" {
                // E15: invocation tracing — per-hop blame decomposition
                // of the tail, both backends, tracing ON. Deliberately
                // deterministic (platform-default compute, no wall-clock
                // output): the CI determinism job diffs two same-seed
                // runs byte-for-byte, which doubles as the proof that
                // tracing never perturbs the simulation.
                let dur = get_u64(&flags, "duration-ms", 300)? * MILLIS;
                let (table, points) = ex::tail_attribution_table(dur, seed);
                println!("{}", table.to_markdown());
                for p in &points {
                    println!(
                        "{} p99 blame outside exec: {:.1}%",
                        p.backend.name(),
                        p.report.p99_non_exec_share() * 100.0
                    );
                }
                if let Some(path) = flags.get("trace-out") {
                    let groups: Vec<(u32, &[junctiond_repro::telemetry::Trace])> = points
                        .iter()
                        .enumerate()
                        .map(|(i, p)| (i as u32 + 1, p.exemplars.as_slice()))
                        .collect();
                    let json = junctiond_repro::telemetry::chrome_trace_json(&groups);
                    std::fs::write(path, json)
                        .with_context(|| format!("writing trace to {path}"))?;
                    eprintln!("# wrote {path} (load in chrome://tracing or Perfetto)");
                }
                maybe_csv(&flags, &table, "ablation_blame")?;
                return Ok(());
            }
            if which == "faults" {
                // E16: the resilience matrix — seeded fault schedules
                // (crash, gray failure, wire loss, brownout) against the
                // deadline/retry/hedging recovery machinery. Deliberately
                // deterministic (platform-default compute, no wall-clock
                // output): the CI resilience job diffs two same-seed runs
                // byte-for-byte.
                let dur = get_u64(&flags, "duration-ms", 300)? * MILLIS;
                let (table, points) = ex::resilience_table(dur, seed);
                println!("{}", table.to_markdown());
                let find = |b: Backend, s: &str| {
                    points.iter().find(|p| p.backend == b && p.scenario == s).unwrap()
                };
                let jc = find(Backend::Junctiond, "crash+loss");
                let cc = find(Backend::Containerd, "crash+loss");
                println!(
                    "crash re-provision: junctiond {}µs vs containerd {}µs ({:.1}× faster)",
                    jc.recovery_ns / MICROS,
                    cc.recovery_ns / MICROS,
                    cc.recovery_ns as f64 / jc.recovery_ns.max(1) as f64
                );
                for b in [Backend::Containerd, Backend::Junctiond] {
                    let off = find(b, "gray").p99;
                    let on = find(b, "gray+hedge").p99;
                    println!(
                        "gray-failure p99 {}: {}µs unhedged → {}µs hedged ({:.1}×)",
                        b.name(),
                        off / MICROS,
                        on / MICROS,
                        off as f64 / on.max(1) as f64
                    );
                }
                maybe_csv(&flags, &table, "ablation_faults")?;
                return Ok(());
            }
            if which == "duplex" {
                // E13: the full-duplex data path — worker TX rings with
                // backpressure + the front end's own RX NIC, plus the echo
                // payload sweep. Deliberately free of wall-clock output
                // and PJRT calibration: the CI determinism job diffs two
                // same-seed runs of this output byte-for-byte.
                let dur = get_u64(&flags, "duration-ms", 300)? * MILLIS;
                let workers = get_u64(&flags, "workers", 2)? as usize;
                let cores = get_u64(&flags, "worker-cores", 16)? as usize;
                let payload = get_u64(&flags, "payload", 600)?;
                let rate = get_u64(&flags, "rate", 2_000)? as f64;
                let (table, points) = ex::duplex_table(
                    workers,
                    cores,
                    payload,
                    &ex::duplex_default_containerd_rates(),
                    &ex::duplex_default_junction_rates(),
                    dur,
                    seed,
                );
                println!("{}", table.to_markdown());
                let top_j = points
                    .iter()
                    .filter(|p| p.backend == Backend::Junctiond)
                    .max_by(|a, b| a.offered_rps.partial_cmp(&b.offered_rps).unwrap());
                if let Some(p) = top_j {
                    println!(
                        "bypass TX amortization at {:.0} rps: {:.2} frames/burst",
                        p.offered_rps, p.tx_mean_batch
                    );
                }
                let (sweep, _) = ex::duplex_payload_sweep_table(
                    workers,
                    cores,
                    &[64, 600, 4 << 10, 16 << 10, 64 << 10],
                    rate,
                    dur,
                    seed,
                );
                println!("{}", sweep.to_markdown());
                maybe_csv(&flags, &table, "ablation_duplex")?;
                maybe_csv(&flags, &sweep, "ablation_duplex_payload")?;
                return Ok(());
            }
            let table = match which {
                "cache" => ex::ablation_cache_table(100, seed),
                "polling" => ex::ablation_polling_table(&[1, 4, 16, 64, 256, 1024, 4096], seed),
                "scaleup" => ex::ablation_scaleup_table(20_000.0, seed),
                "isolation" => ex::isolation_table(100, seed),
                "autoscale" => ex::autoscale_table(Backend::Junctiond, seed),
                "multitenant" => ex::multitenant_table(60, 1_000.0, seed),
                "tiers" => ex::coldstart_tiers_table(20, seed),
                other => bail!(
                    "unknown ablation '{other}' (cache|polling|scaleup|isolation|autoscale\
                     |multitenant|tiers|netpath|duplex|interference|blame|faults)"
                ),
            };
            println!("{}", table.to_markdown());
            maybe_csv(&flags, &table, &format!("ablation_{which}"))?;
        }
        "density" => {
            // E12: the engine at density scale. Defaults are a laptop-sized
            // slice; the paper-scale sweep (≥1M functions / ≥10M
            // invocations) is `benches/density_scale.rs` without
            // BENCH_QUICK, or these flags turned up.
            let workers = get_u64(&flags, "workers", 4)? as usize;
            let cores = get_u64(&flags, "worker-cores", 16)? as usize;
            let functions = get_u64(&flags, "functions", 100_000)?;
            let hot = get_u64(&flags, "hot", 1_024)? as usize;
            let rate = get_u64(&flags, "rate", 50_000)? as f64;
            let dur = get_u64(&flags, "duration-ms", 2_000)? * MILLIS;
            let seed = get_u64(&flags, "seed", 12)?;
            let p = ex::density_scale_run(
                Backend::Junctiond,
                workers,
                cores,
                functions,
                hot,
                rate,
                dur,
                seed,
            );
            let table = ex::density_scale_table(std::slice::from_ref(&p));
            println!("{}", table.to_markdown());
            println!(
                "engine={} events={} wall={:.2}s → {:.0} events/s (host)",
                p.engine, p.events_fired, p.wall_secs, p.events_per_sec
            );
            maybe_csv(&flags, &table, "density")?;
        }
        "shardscale" => {
            // E18: the density workload on the parallel shard runner
            // (§3j). Stdout carries ONLY the deterministic table — CI
            // byte-diffs it across repeated runs, shard counts, and the
            // serial/threaded transports — so the host-side telemetry
            // (wall clock, speedup, epoch counters) goes to stderr.
            let shards = get_u64(&flags, "shards", 4)? as usize;
            let workers = get_u64(&flags, "workers", 8)? as usize;
            let cores = get_u64(&flags, "worker-cores", 16)? as usize;
            let functions = get_u64(&flags, "functions", 100_000)?;
            let hot = get_u64(&flags, "hot", 1_024)? as usize;
            let rate = get_u64(&flags, "rate", 50_000)? as f64;
            let dur = get_u64(&flags, "duration-ms", 2_000)? * MILLIS;
            let seed = get_u64(&flags, "seed", 12)?;
            let threaded = !flags.contains_key("serial");
            let p = ex::shard_scale_run(
                Backend::Junctiond,
                shards,
                threaded,
                workers,
                cores,
                functions,
                hot,
                rate,
                dur,
                seed,
            );
            let table = ex::shard_scale_table(std::slice::from_ref(&p));
            println!("{}", table.to_markdown());
            eprint!("{}", ex::shard_scale_host_summary(std::slice::from_ref(&p)));
            maybe_csv(&flags, &table, "shardscale")?;
        }
        "serve" => {
            let mode = match flags.get("mode").map(|s| s.as_str()).unwrap_or("bypass") {
                "kernel" => ServeMode::Kernel,
                "bypass" => ServeMode::Bypass,
                other => bail!("unknown mode '{other}' (kernel|bypass)"),
            };
            let n = get_u64(&flags, "requests", 100)? as usize;
            let mut h = run_pipeline(mode, junctiond_repro::runtime::default_artifacts_dir())?;
            let payload = [0x5Au8; 600];
            let mut lat = junctiond_repro::telemetry::Samples::with_capacity(n);
            for _ in 0..5 {
                h.invoke_aes600(&payload)?; // warmup
            }
            // Wall-clock latency through the sanctioned hostclock seam
            // (serve mode measures the real pipeline, not the DES).
            for _ in 0..n {
                let sw = junctiond_repro::hostclock::Stopwatch::new();
                h.invoke_aes600(&payload)?;
                lat.record(sw.elapsed_ns() as u64);
            }
            h.shutdown()?;
            println!("serve mode={} {}", mode.name(), lat.summary().fmt_us());
        }
        "calibrate" => {
            let runs = get_u64(&flags, "runs", 50)? as u32;
            let exec = junctiond_repro::runtime::Executor::load(
                &junctiond_repro::runtime::default_artifacts_dir(),
            )?;
            let c = junctiond_repro::runtime::calibrate(&exec, runs)?;
            println!(
                "aes600 compute: p50 {}µs, mean {}µs, min {}µs over {} runs",
                c.p50_ns / 1000,
                c.mean_ns / 1000,
                c.min_ns / 1000,
                c.runs
            );
        }
        "selfcheck" => {
            // Run the unified invariant auditor (invariants::audit_all)
            // after full E5/E11/E14/E15 experiments on both backends —
            // the release-build twin of the debug quiesce hooks, and the
            // CI gate next to the same-seed byte diff.
            let dur = get_u64(&flags, "duration-ms", 120)? * MILLIS;
            let seed = get_u64(&flags, "seed", 17)?;
            let reports = ex::selfcheck(dur, seed);
            let mut broken = 0usize;
            for r in &reports {
                if r.violations.is_empty() {
                    println!("selfcheck {:>12} {:<10} ok", r.scenario, r.backend.name());
                } else {
                    broken += r.violations.len();
                    for v in &r.violations {
                        println!(
                            "selfcheck {:>12} {:<10} VIOLATION {v}",
                            r.scenario,
                            r.backend.name()
                        );
                    }
                }
            }
            if broken > 0 {
                bail!("selfcheck: {broken} invariant violation(s)");
            }
            println!("selfcheck: all invariants hold across {} runs", reports.len());
        }
        "schedcheck" => {
            // E17: the same-time commutativity schedule explorer — rerun
            // E5/E11 (and E16 unless --quick) under all three TieBreak
            // policies and byte-diff the rendered tables. Exits nonzero
            // if any table diverges across policies, or if the built-in
            // order-dependent demonstration workload fails to be flagged
            // (the detector must detect).
            let quick = flags.contains_key("quick");
            let dur = get_u64(&flags, "duration-ms", 120)? * MILLIS;
            let seed = get_u64(&flags, "seed", 17)?;
            let pols = ex::schedcheck::policies(seed);
            let names: Vec<String> = pols.into_iter().map(ex::schedcheck::policy_name).collect();
            let ms = dur / MILLIS;
            println!("schedcheck: seed {seed}, duration {ms}ms, policies: {}", names.join(" "));
            let (certs, diverge) = ex::schedcheck::schedcheck(quick, dur, seed);
            let mut broken = 0usize;
            for c in &certs {
                if c.invariant() {
                    let bytes = c.renders[0].1.len();
                    let n = c.renders.len();
                    let msg = format!("INVARIANT ({n} policies byte-identical, {bytes} bytes)");
                    println!("schedcheck {:<14} {msg}", c.name);
                } else {
                    broken += 1;
                    let (policy, line, a, b) = c.first_diff().expect("divergent cert has a diff");
                    println!("schedcheck {:<14} DIVERGED vs {policy} at line {line}:", c.name);
                    println!("  {}: {a}", names[0]);
                    println!("  {policy}: {b}");
                }
            }
            match diverge {
                Some(d) => {
                    let (ta, sa, ma) = d.a;
                    let (tb, sb, mb) = d.b;
                    let at = format!("first diverging fire #{}", d.fire_index);
                    println!("schedcheck bad-workload    FLAGGED (as required): {at}");
                    println!("  {} fired (time={ta}, seq={sa}, module={ma})", d.policy_a);
                    println!("  {} fired (time={tb}, seq={sb}, module={mb})", d.policy_b);
                }
                None => {
                    bail!("schedcheck: order-dependent demonstration workload was NOT flagged");
                }
            }
            if broken > 0 {
                bail!("schedcheck: {broken} table(s) are tie-break-sensitive");
            }
            let n = certs.len();
            println!("schedcheck: certified {n}/{n} tables tie-break-invariant");
        }
        "monitor" => {
            // Demonstrate junctiond's monitoring endpoint on a toy deployment.
            use junctiond_repro::config::{ExperimentConfig, PlatformConfig};
            use junctiond_repro::faas::{FaasSim, FunctionSpec, RuntimeKind};
            use junctiond_repro::simcore::Sim;
            let cfg = ExperimentConfig { backend: Backend::Junctiond, ..Default::default() };
            let mut sim = Sim::new();
            let fs = FaasSim::new(&cfg, std::rc::Rc::new(PlatformConfig::default()));
            for (name, runtime) in [("aes", RuntimeKind::Go), ("thumbnailer", RuntimeKind::Python)]
            {
                fs.deploy(&mut sim, FunctionSpec::new(name, "aes600", runtime));
            }
            sim.run_until(junctiond_repro::simcore::SECONDS);
            for _ in 0..4 {
                fs.submit(&mut sim, "aes", |_, _| {});
            }
            sim.run_to_completion();
            println!("{:#?}", fs.scheduler_stats());
        }
        _ => usage(),
    }
    Ok(())
}
