//! Junction kernel-bypass simulator (paper §2.2.1).
//!
//! Junction is a libOS-based kernel-bypass system: each *instance* is one
//! host process running user-level processes (*uProcs*) over a user-space
//! kernel; NIC send/recv queue pairs are mapped directly into each
//! instance; a central *scheduler* on a dedicated core busy-polls event
//! queues and allocates cores to instances on demand.
//!
//! The properties of the real system that matter for the paper's FaaS
//! integration — and that this model reproduces — are:
//!
//! 1. **User-space syscalls**: a uProc syscall is a function call into the
//!    Junction kernel (~tens of ns), not a trap (§2.2.1 "most system calls
//!    are handled entirely within the Junction instance").
//! 2. **Direct packet delivery**: the NIC DMAs into per-instance queues; no
//!    softirq, no software switch, no veth hop.
//! 3. **Cheap wakeups**: a uThread wakeup on a granted core is a user-level
//!    switch; granting a core to an idle instance costs ~1 µs (IPI).
//! 4. **Polling ∝ cores, not instances**: one dedicated scheduler core
//!    polls for *all* instances on the server (§3: "a single dedicated
//!    core [can] manage thousands of functions on a 36-core server"),
//!    versus one polling core *per instance* for DPDK-style bypass.

mod costs;
mod instance;
mod scheduler;

pub use costs::BypassCosts;
pub use instance::{Instance, InstanceId, InstanceState, UProc};
pub use scheduler::{GrantOutcome, Scheduler, SchedulerStats};
