//! Per-operation costs of the Junction (kernel-bypass) path.
//!
//! The counterpart of `oskernel::KernelCosts`. Jitter is small and
//! *bounded*: there are no timer interrupts or softirq bursts inside a
//! Junction instance; residual variance comes from cache effects and the
//! scheduler's polling granularity, modeled as a few-percent uniform band.

use std::rc::Rc;

use crate::config::PlatformConfig;
use crate::simcore::{Rng, Time};

/// Sampler for bypass-path costs. Deterministic given its RNG stream.
pub struct BypassCosts {
    p: Rc<PlatformConfig>,
    rng: Rng,
    /// Uniform jitter half-width as a fraction of the base cost.
    jitter_frac: f64,
    /// Rare scheduler-contention tail (enabled on *service* instances —
    /// gateway/provider — which repeatedly park and re-acquire cores; see
    /// `PlatformConfig::junction_sched_tail_*`).
    sched_tail: bool,
    /// Keep the sampled contention tail. Grant contention is structural
    /// now (service segments queue on granted cores in the compute
    /// fabric; preemptive regrants wait for real quantum edges), so the
    /// sampled stand-in defaults off — same no-double-counting rule as
    /// `KernelCosts`.
    residual_jitter: bool,
    // telemetry
    pub msgs_recv: u64,
    pub msgs_sent: u64,
    pub wakeups: u64,
    pub syscalls: u64,
}

impl BypassCosts {
    pub fn new(platform: Rc<PlatformConfig>, rng: Rng) -> Self {
        BypassCosts {
            rng,
            jitter_frac: 0.15,
            sched_tail: false,
            residual_jitter: platform.residual_jitter != 0,
            p: platform,
            msgs_recv: 0,
            msgs_sent: 0,
            wakeups: 0,
            syscalls: 0,
        }
    }

    /// Enable the rare core-grant contention tail (service instances).
    pub fn with_sched_tail(mut self) -> Self {
        self.sched_tail = true;
        self
    }

    /// Sample the rare contention delay (0 in the common case). Residual
    /// jitter: returns 0 unless `PlatformConfig::residual_jitter` is set —
    /// with the compute fabric on, grant contention emerges structurally.
    pub fn sched_tail_delay(&mut self) -> Time {
        if !self.residual_jitter {
            return 0;
        }
        if self.sched_tail && self.rng.below(10_000) < self.p.junction_sched_tail_prob_bp {
            self.rng.range(self.p.junction_sched_tail_min_ns, self.p.junction_sched_tail_max_ns)
        } else {
            0
        }
    }

    /// base ± jitter_frac, uniform.
    fn jittered(&mut self, base: Time) -> Time {
        let span = (base as f64 * self.jitter_frac) as u64;
        if span == 0 {
            return base;
        }
        base - span + self.rng.below(2 * span + 1)
    }

    /// Receive one message: the NIC has already DMA'd the packet into the
    /// instance's queue; cost is the user-space stack traversal.
    pub fn recv_msg(&mut self) -> Time {
        self.msgs_recv += 1;
        self.jittered(self.p.junction_stack_msg_ns)
    }

    /// Per-packet share of a polled DPDK-style RX burst: same user-space
    /// stack traversal as [`BypassCosts::recv_msg`], zero-copy (the
    /// poll-iteration cost itself is charged once per burst by the
    /// netpath drain engine — see `Scheduler::note_nic_poll`).
    pub fn rx_poll_packet(&mut self) -> Time {
        self.recv_msg()
    }

    /// Send one message through the user-space stack + NIC doorbell.
    pub fn send_msg(&mut self) -> Time {
        self.msgs_sent += 1;
        self.jittered(self.p.junction_stack_msg_ns)
    }

    /// Per-frame share of a polled TX flush burst: same user-space stack
    /// traversal + doorbell as [`BypassCosts::send_msg`], zero-copy (the
    /// poll-iteration cost itself is charged once per burst by the
    /// netpath TX flush engine — see `Scheduler::note_nic_tx_poll`).
    pub fn tx_poll_packet(&mut self) -> Time {
        self.send_msg()
    }

    /// uThread wakeup when the instance already holds a core.
    pub fn wakeup_warm(&mut self) -> Time {
        self.wakeups += 1;
        self.jittered(self.p.junction_wakeup_ns)
    }

    /// `n` user-space syscalls (function calls into the Junction kernel).
    pub fn syscalls(&mut self, n: u32) -> Time {
        self.syscalls += n as u64;
        n as Time * self.p.junction_syscall_ns
    }

    /// One-way wire latency (same physical NICs as the baseline).
    pub fn wire(&self) -> Time {
        self.p.wire_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oskernel::KernelCosts;

    fn costs() -> BypassCosts {
        BypassCosts::new(Rc::new(PlatformConfig::default()), Rng::new(11))
    }

    #[test]
    fn bypass_is_much_cheaper_than_kernel() {
        let mut b = costs();
        let mut k = KernelCosts::new(Rc::new(PlatformConfig::default()), Rng::new(11));
        let bsum: Time = (0..1000).map(|_| b.recv_msg() + b.send_msg()).sum();
        let ksum: Time = (0..1000).map(|_| k.recv_msg() + k.send_msg()).sum();
        assert!(ksum > 5 * bsum, "kernel {ksum} vs bypass {bsum}");
    }

    #[test]
    fn jitter_is_bounded() {
        let mut b = costs();
        let base = PlatformConfig::default().junction_stack_msg_ns;
        for _ in 0..10_000 {
            let v = b.recv_msg();
            assert!(v >= base - base * 15 / 100);
            assert!(v <= base + base * 15 / 100 + 1);
        }
    }

    #[test]
    fn user_space_syscalls_are_cheap() {
        let mut b = costs();
        let p = PlatformConfig::default();
        assert!(b.syscalls(100) < 100 * p.syscall_ns / 5);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = BypassCosts::new(Rc::new(PlatformConfig::default()), Rng::new(5));
        let mut b = BypassCosts::new(Rc::new(PlatformConfig::default()), Rng::new(5));
        for _ in 0..100 {
            assert_eq!(a.recv_msg(), b.recv_msg());
        }
    }
}
