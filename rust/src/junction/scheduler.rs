//! The Junction core scheduler (paper §2.2.1 "Scheduler").
//!
//! Runs on one *dedicated, reserved core* and busy-polls two signal
//! sources: NIC event queues (packet arrivals for idle instances) and
//! uThread run-queue state (demand from running instances). Based on those
//! signals it grants and revokes cores, up to each instance's configured
//! limit, preempting for fairness when the server is contended.
//!
//! The scalability property the paper leans on (§3): the scheduler's
//! polling work is proportional to the number of *cores* it manages, not
//! the number of *instances* hosted — so one polling core serves thousands
//! of parked functions, where DPDK-style bypass would need a polling core
//! per function. `poll_iteration_cost` encodes exactly that, and the E5
//! ablation (`benches/ablation_polling.rs`) measures the consequence.

use super::instance::{Instance, InstanceId, InstanceState};
use crate::config::PlatformConfig;
use crate::invariants::{check, Audit, Violation};
use crate::simcore::Time;

/// What happened when a packet arrived for an instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GrantOutcome {
    /// Instance already held a core: user-level wakeup.
    Warm { latency: Time },
    /// Instance was idle; a free core was granted (IPI + queue mapping).
    Granted { latency: Time },
    /// Instance was granted a core revoked from an over-share donor. The
    /// latency is the grant path only: the *quantum-edge wait* for the
    /// donor to vacate is structural — the grantee's first segment queues
    /// on the transferred core behind the donor's current slice in the
    /// compute fabric (this replaced the seed's sampled "grant plus one
    /// wakeup" stand-in).
    Preempted { latency: Time },
    /// No core available right now; the request runs once the fabric
    /// frees up (contention is modeled by the fabric's shared queue).
    Contended { latency: Time },
}

impl GrantOutcome {
    pub fn latency(&self) -> Time {
        match self {
            GrantOutcome::Warm { latency }
            | GrantOutcome::Granted { latency }
            | GrantOutcome::Preempted { latency }
            | GrantOutcome::Contended { latency } => *latency,
        }
    }

    /// Stable cause tag for tracing (`sched.wakeup` span cause).
    pub fn kind(&self) -> &'static str {
        match self {
            GrantOutcome::Warm { .. } => "warm",
            GrantOutcome::Granted { .. } => "granted",
            GrantOutcome::Preempted { .. } => "preempted",
            GrantOutcome::Contended { .. } => "contended",
        }
    }
}

/// Scheduler counters (polled by the density/polling benches).
#[derive(Debug, Clone, Copy, Default)]
pub struct SchedulerStats {
    pub grants: u64,
    pub warm_wakeups: u64,
    pub contended: u64,
    pub preemptions: u64,
    pub releases: u64,
    /// NIC RX poll iterations executed by the dedicated polling core.
    pub nic_polls: u64,
    /// Packets drained across all NIC RX polls. `nic_rx_packets /
    /// nic_polls` is the achieved rx_burst amortization.
    pub nic_rx_packets: u64,
    /// NIC TX poll iterations (response-frame flushes) executed by the
    /// dedicated polling core.
    pub nic_tx_polls: u64,
    /// Frames flushed across all NIC TX polls. `nic_tx_packets /
    /// nic_tx_polls` is the achieved tx_burst amortization.
    pub nic_tx_packets: u64,
}

/// Central core scheduler for all Junction instances on one server.
pub struct Scheduler {
    platform: std::rc::Rc<PlatformConfig>,
    /// Dense instance table: `InstanceId` is the index (ids are assigned
    /// sequentially by `register`). Vec indexing beats a BTreeMap on the
    /// wakeup hot path (§Perf).
    instances: Vec<Instance>,
    /// Cores grantable to instances (server cores minus the scheduler's
    /// own dedicated polling core).
    grantable_cores: u32,
    granted_total: u32,
    /// Free *physical* core ids (the poller owns core 0, so grants hand
    /// out 1..server_cores). LIFO reuse keeps the hot set small and the
    /// order deterministic.
    free_cores: Vec<u32>,
    next_id: InstanceId,
    pub stats: SchedulerStats,
}

impl Scheduler {
    /// `server_cores` includes the core the scheduler itself reserves.
    pub fn new(platform: std::rc::Rc<PlatformConfig>, server_cores: u32) -> Self {
        assert!(server_cores >= 2, "need at least one grantable core besides the poller");
        Scheduler {
            platform,
            instances: Vec::new(),
            grantable_cores: server_cores - 1,
            granted_total: 0,
            free_cores: (1..server_cores).rev().collect(),
            next_id: 0,
            stats: SchedulerStats::default(),
        }
    }

    /// Register a new instance (junctiond calls this from `junction_run`).
    pub fn register(&mut self, name: &str, max_cores: u32) -> InstanceId {
        let id = self.next_id;
        self.next_id += 1;
        self.instances.push(Instance::new(id, name, max_cores));
        id
    }

    pub fn instance(&self, id: InstanceId) -> Option<&Instance> {
        self.instances.get(id as usize)
    }

    pub fn instance_mut(&mut self, id: InstanceId) -> Option<&mut Instance> {
        self.instances.get_mut(id as usize)
    }

    pub fn instance_count(&self) -> usize {
        self.instances.len()
    }

    pub fn granted_total(&self) -> u32 {
        self.granted_total
    }

    pub fn grantable_cores(&self) -> u32 {
        self.grantable_cores
    }

    /// Cores this server must *reserve for polling* to host `n` instances.
    /// Junction: always 1. (Compare `dpdk_polling_cores`.)
    pub fn polling_cores(&self) -> u32 {
        1
    }

    /// The DPDK-style alternative the paper contrasts with (§1): one
    /// polling core per isolated application instance.
    pub fn dpdk_polling_cores(n_instances: u32) -> u32 {
        n_instances
    }

    /// CPU cost of one scheduler polling iteration. Proportional to the
    /// number of granted (active) cores — *not* to `instance_count` — plus
    /// a small constant for the event-queue doorbell scan.
    pub fn poll_iteration_cost(&self) -> Time {
        let per_core = self.platform.junction_poll_iter_ns;
        per_core + per_core * self.granted_total as Time
    }

    /// One NIC RX poll iteration draining a burst of `batch` packets off a
    /// worker's event queues (the netpath drain engine calls this). The
    /// cost is the standing poll-iteration cost — it does *not* grow with
    /// the burst size, which is exactly the DPDK-style amortization the
    /// bypass path's throughput rests on; the caller spreads it across the
    /// burst.
    pub fn note_nic_poll(&mut self, batch: u32) -> Time {
        self.stats.nic_polls += 1;
        self.stats.nic_rx_packets += batch as u64;
        self.poll_iteration_cost()
    }

    /// One NIC TX poll iteration flushing a burst of `batch` response
    /// frames from a worker's TX ring — the transmit-side twin of
    /// [`Scheduler::note_nic_poll`]: the cost is the standing
    /// poll-iteration cost regardless of the burst size, so it amortizes
    /// across the flushed frames.
    pub fn note_nic_tx_poll(&mut self, batch: u32) -> Time {
        self.stats.nic_tx_polls += 1;
        self.stats.nic_tx_packets += batch as u64;
        self.poll_iteration_cost()
    }

    /// Grant one free physical core to `id` (caller checked capacity).
    fn grant_one(&mut self, id: InstanceId) {
        let core = self.free_cores.pop().expect("grant without a free core");
        let inst = self.instances.get_mut(id as usize).unwrap();
        inst.granted_cores += 1;
        inst.core_ids.push(core);
        self.granted_total += 1;
        self.stats.grants += 1;
    }

    /// A packet arrived for `id` (NIC event queue signaled). Accounts the
    /// in-flight request and decides the wakeup path.
    pub fn packet_arrival(&mut self, id: InstanceId) -> GrantOutcome {
        let p_wakeup = self.platform.junction_wakeup_ns;
        let p_grant = self.platform.junction_grant_ns;
        {
            let inst = self.instances.get_mut(id as usize).expect("unknown instance");
            assert_eq!(inst.state, InstanceState::Running, "packet for non-running instance");
            inst.in_flight += 1;
            inst.total_invocations += 1;
        }
        if self.instances[id as usize].granted_cores > 0 {
            self.stats.warm_wakeups += 1;
            // The poll loop's growth path: demand (in-flight > grant)
            // grows the grant toward max_cores while capacity allows, so
            // concurrent requests spread across physical cores.
            self.grow_grants(id);
            return GrantOutcome::Warm { latency: p_wakeup };
        }
        if self.granted_total < self.grantable_cores {
            self.grant_one(id);
            return GrantOutcome::Granted { latency: p_grant };
        }
        // All cores granted elsewhere: fairness rebalance may preempt.
        self.stats.contended += 1;
        if self.try_preempt_for(id) {
            GrantOutcome::Preempted { latency: p_grant }
        } else {
            GrantOutcome::Contended { latency: p_grant }
        }
    }

    /// Physical core the instance's next segment should run on (round-
    /// robin across the grant). `None` while the instance holds no core —
    /// the segment then waits in the fabric's shared queue.
    pub fn pick_core(&mut self, id: InstanceId) -> Option<u32> {
        let inst = self.instances.get_mut(id as usize)?;
        if inst.core_ids.is_empty() {
            return None;
        }
        let core = inst.core_ids[inst.next_core % inst.core_ids.len()];
        inst.next_core = inst.next_core.wrapping_add(1);
        Some(core)
    }

    /// A request finished inside `id`. Releases the grant when the
    /// instance goes idle (the scheduler parks idle instances to keep
    /// polling cheap).
    pub fn request_done(&mut self, id: InstanceId) {
        let inst = self.instances.get_mut(id as usize).expect("unknown instance");
        assert!(inst.in_flight > 0, "request_done with nothing in flight");
        inst.in_flight -= 1;
        if inst.in_flight == 0 && inst.granted_cores > 0 {
            self.granted_total -= inst.granted_cores;
            self.stats.releases += inst.granted_cores as u64;
            inst.granted_cores = 0;
            let freed = std::mem::take(&mut inst.core_ids);
            self.free_cores.extend(freed);
        }
    }

    /// Grow an instance's grant toward its demand if capacity allows
    /// (called from the poll loop; demand = runnable uThreads).
    pub fn grow_grants(&mut self, id: InstanceId) -> u32 {
        let mut grown = 0;
        while self.granted_total < self.grantable_cores {
            let inst = self.instances.get(id as usize).expect("unknown instance");
            if !inst.wants_core() {
                break;
            }
            self.grant_one(id);
            grown += 1;
        }
        grown
    }

    /// Fair-share preemption: a hungry instance below its fair share
    /// revokes one core from the most-allocated donor at-or-above fair
    /// share (Caladan-style rebalance: under full allocation, cores
    /// round-robin among demanding instances at arrival granularity, so
    /// a lightly-loaded tenant is never starved behind heavy ones — the
    /// structural basis of the bypass backend's bounded tail under
    /// antagonist load, E14).
    fn try_preempt_for(&mut self, hungry: InstanceId) -> bool {
        {
            // Never grant past the hungry instance's configured core cap —
            // preempting a donor for a grant the cap forbids would both
            // break the cap invariant and waste the donor's core.
            let h = self.instances.get(hungry as usize).expect("unknown instance");
            if h.granted_cores >= h.max_cores {
                return false;
            }
        }
        let demanding = self.instances.iter().filter(|i| i.in_flight > 0).count() as u32;
        if demanding == 0 {
            return false;
        }
        let fair = (self.grantable_cores / demanding).max(1);
        let donor = self
            .instances
            .iter()
            .filter(|i| i.id != hungry && i.granted_cores >= fair && i.granted_cores > 0)
            .max_by_key(|i| i.granted_cores)
            .map(|i| i.id);
        let Some(donor_id) = donor else { return false };
        // The *physical* core moves with the grant: the donor's newest
        // core transfers to the hungry instance, whose first segment will
        // queue on it behind the donor's current slice — the structural
        // quantum-edge wait of a preemptive regrant.
        let core = {
            let d = self.instances.get_mut(donor_id as usize).unwrap();
            d.granted_cores -= 1;
            d.preemptions += 1;
            d.core_ids.pop().expect("donor grant without a physical core")
        };
        self.stats.preemptions += 1;
        let h = self.instances.get_mut(hungry as usize).unwrap();
        h.granted_cores += 1;
        h.core_ids.push(core);
        true
    }

    /// Return physical cores to the pool without an owner (crash path:
    /// the caller took the instance's `core_ids` and zeroed its grant
    /// bookkeeping). Records them in `stats.releases` like
    /// [`Scheduler::request_done`] does, so grant/release telemetry stays
    /// balanced on the crash path.
    pub fn force_release(&mut self, cores: Vec<u32>) {
        let returned = (cores.len() as u32).min(self.granted_total);
        self.granted_total -= returned;
        self.stats.releases += returned as u64;
        self.free_cores.extend(cores);
    }

    /// Debug/test invariant check: grant accounting is consistent.
    /// Thin wrapper over the structured [`Audit`] impl so the ~30
    /// existing call sites keep their panic-on-drift semantics.
    pub fn check_invariants(&self) {
        self.assert_clean();
    }
}

/// Conservation laws of the core granter. `grants`/`releases` are the
/// telemetry counters in [`SchedulerStats`]; everything else is direct
/// structural accounting over instances and the free pool.
impl Audit for Scheduler {
    fn module(&self) -> &'static str {
        "junction/scheduler"
    }

    fn audit_into(&self, out: &mut Vec<Violation>) {
        let m = self.module();
        let sum: u32 = self.instances.iter().map(|i| i.granted_cores).sum();
        check(out, m, "granted-accounting", sum == self.granted_total, || {
            format!("instances hold {sum} cores but granted_total is {}", self.granted_total)
        });
        check(out, m, "over-grant", self.granted_total <= self.grantable_cores, || {
            format!("granted_total {} > grantable {}", self.granted_total, self.grantable_cores)
        });
        let free = self.free_cores.len() as u32;
        check(out, m, "core-conservation", free + self.granted_total == self.grantable_cores, || {
            format!(
                "free {free} + granted {} != grantable {}",
                self.granted_total, self.grantable_cores
            )
        });
        // Telemetry balance: every core ever granted was either released
        // (request_done or force_release) or is still held. Preemption
        // transfers a core without touching either counter.
        let balanced = self.stats.grants == self.stats.releases + self.granted_total as u64;
        check(out, m, "grant-release-telemetry", balanced, || {
            format!(
                "grants {} != releases {} + held {}",
                self.stats.grants, self.stats.releases, self.granted_total
            )
        });
        let mut held: Vec<u32> = self.free_cores.clone();
        for inst in self.instances.iter() {
            check(out, m, "core-cap", inst.granted_cores <= inst.max_cores, || {
                format!(
                    "instance {} holds {} cores over its cap {}",
                    inst.name, inst.granted_cores, inst.max_cores
                )
            });
            let mapped = inst.core_ids.len() as u32 == inst.granted_cores;
            check(out, m, "core-map", mapped, || {
                format!(
                    "instance {} maps {} physical cores but records {} granted",
                    inst.name,
                    inst.core_ids.len(),
                    inst.granted_cores
                )
            });
            held.extend(&inst.core_ids);
        }
        held.sort_unstable();
        held.dedup();
        check(out, m, "core-uniqueness", held.len() as u32 == self.grantable_cores, || {
            format!(
                "{} distinct physical cores visible, expected {} (double-grant or loss)",
                held.len(),
                self.grantable_cores
            )
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::{forall, Gen};
    use std::rc::Rc;

    fn sched(cores: u32) -> Scheduler {
        Scheduler::new(Rc::new(PlatformConfig::default()), cores)
    }

    fn running_instance(s: &mut Scheduler, name: &str, max_cores: u32) -> InstanceId {
        let id = s.register(name, max_cores);
        s.instance_mut(id).unwrap().spawn_uproc("w");
        id
    }

    #[test]
    fn first_packet_grants_then_warm() {
        let mut s = sched(4);
        let id = running_instance(&mut s, "fn", 2);
        assert!(matches!(s.packet_arrival(id), GrantOutcome::Granted { .. }));
        assert!(matches!(s.packet_arrival(id), GrantOutcome::Warm { .. }));
        s.check_invariants();
    }

    #[test]
    fn idle_instance_releases_core() {
        let mut s = sched(4);
        let id = running_instance(&mut s, "fn", 2);
        s.packet_arrival(id);
        assert_eq!(s.granted_total(), 1);
        s.request_done(id);
        assert_eq!(s.granted_total(), 0);
        assert!(s.instance(id).unwrap().is_idle());
        s.check_invariants();
    }

    #[test]
    fn contention_triggers_preemption_for_fairness() {
        let mut s = sched(3); // 2 grantable
        let a = running_instance(&mut s, "a", 2);
        let b = running_instance(&mut s, "b", 2);
        // a grabs both cores.
        s.packet_arrival(a);
        s.instance_mut(a).unwrap().in_flight += 1; // fake concurrent demand
        s.grow_grants(a);
        assert_eq!(s.instance(a).unwrap().granted_cores, 2);
        // b's packet must steal one back (fair share = 1 each).
        let out = s.packet_arrival(b);
        assert!(matches!(out, GrantOutcome::Preempted { .. }), "{out:?}");
        assert_eq!(s.instance(a).unwrap().granted_cores, 1);
        assert_eq!(s.instance(b).unwrap().granted_cores, 1);
        assert_eq!(s.stats.preemptions, 1);
        // The physical core moved with the grant.
        let a_core = s.instance(a).unwrap().core_ids[0];
        let b_core = s.instance(b).unwrap().core_ids[0];
        assert_ne!(a_core, b_core);
        s.check_invariants();
    }

    #[test]
    fn polling_cores_constant_vs_dpdk_linear() {
        let mut s = sched(10);
        for i in 0..1000 {
            running_instance(&mut s, &format!("fn{i}"), 1);
        }
        assert_eq!(s.polling_cores(), 1);
        assert_eq!(Scheduler::dpdk_polling_cores(1000), 1000);
    }

    #[test]
    fn poll_cost_scales_with_cores_not_instances() {
        let mut dense = sched(10);
        for i in 0..4096 {
            running_instance(&mut dense, &format!("fn{i}"), 1);
        }
        let mut sparse = sched(10);
        let a = running_instance(&mut sparse, "a", 4);
        // Idle-heavy server: poll cost identical regardless of 4096 vs 1
        // registered instances.
        assert_eq!(dense.poll_iteration_cost(), sparse.poll_iteration_cost());
        // Activating cores raises the cost.
        sparse.packet_arrival(a);
        assert!(sparse.poll_iteration_cost() > dense.poll_iteration_cost());
    }

    #[test]
    fn nic_poll_cost_amortizes_over_burst() {
        let mut s = sched(4);
        // One iteration costs the same whether it drains 1 or 32 packets…
        let c1 = s.note_nic_poll(1);
        let c32 = s.note_nic_poll(32);
        assert_eq!(c1, c32);
        // …so the per-packet share falls with the burst size.
        assert!(c32 / 32 < c1);
        assert_eq!(s.stats.nic_polls, 2);
        assert_eq!(s.stats.nic_rx_packets, 33);
    }

    #[test]
    fn max_cores_is_respected() {
        let mut s = sched(10);
        let id = running_instance(&mut s, "fn", 2);
        s.instance_mut(id).unwrap().in_flight = 8;
        s.grow_grants(id);
        assert_eq!(s.instance(id).unwrap().granted_cores, 2);
        s.check_invariants();
    }

    #[test]
    fn property_no_double_grant_under_random_traffic() {
        forall("scheduler grant accounting", 60, |g: &mut Gen| {
            let cores = g.u64(2, 12) as u32;
            let mut s = sched(cores);
            let n_inst = g.usize(1, 8);
            let ids: Vec<_> = (0..n_inst)
                .map(|i| {
                    let max = g.u64(1, 4) as u32;
                    let id = s.register(&format!("f{i}"), max);
                    s.instance_mut(id).unwrap().spawn_uproc("w");
                    id
                })
                .collect();
            let mut in_flight: Vec<u32> = vec![0; n_inst];
            for _ in 0..200 {
                let k = g.usize(0, n_inst - 1);
                if g.u64(0, 19) == 0 {
                    // Crash path: the reaper zeroes the instance's
                    // bookkeeping, then force-releases its cores — the
                    // telemetry invariant in check_invariants must hold
                    // through it (force_release records releases).
                    let held = {
                        let inst = s.instance_mut(ids[k]).unwrap();
                        inst.granted_cores = 0;
                        inst.in_flight = 0;
                        std::mem::take(&mut inst.core_ids)
                    };
                    s.force_release(held);
                    in_flight[k] = 0;
                } else if g.bool() || in_flight[k] == 0 {
                    s.packet_arrival(ids[k]);
                    in_flight[k] += 1;
                } else {
                    s.request_done(ids[k]);
                    in_flight[k] -= 1;
                }
                s.check_invariants();
            }
        });
    }

    #[test]
    fn force_release_records_releases() {
        let mut s = sched(4);
        let id = running_instance(&mut s, "fn", 2);
        s.packet_arrival(id);
        assert_eq!(s.granted_total(), 1);
        assert_eq!(s.stats.releases, 0);
        // Crash path: the caller zeroes the instance's bookkeeping, then
        // returns its physical cores to the pool.
        let held = {
            let inst = s.instance_mut(id).unwrap();
            inst.granted_cores = 0;
            inst.in_flight = 0;
            std::mem::take(&mut inst.core_ids)
        };
        let n = held.len() as u64;
        s.force_release(held);
        assert_eq!(s.granted_total(), 0);
        assert_eq!(s.stats.releases, n, "crash-path releases must be recorded");
        s.check_invariants();
    }

    #[test]
    fn preemption_refuses_hungry_at_core_cap() {
        let mut s = sched(3); // 2 grantable
        let a = running_instance(&mut s, "a", 2);
        let b = running_instance(&mut s, "b", 0); // cap 0: may never hold a core
        s.packet_arrival(a);
        s.instance_mut(a).unwrap().in_flight += 1; // concurrent demand
        s.grow_grants(a);
        assert_eq!(s.instance(a).unwrap().granted_cores, 2);
        s.instance_mut(b).unwrap().in_flight += 1; // demand from b
        assert!(!s.try_preempt_for(b), "must not grant past b's core cap");
        assert_eq!(s.instance(b).unwrap().granted_cores, 0);
        assert_eq!(s.instance(a).unwrap().granted_cores, 2, "donor must keep its cores");
        assert_eq!(s.stats.preemptions, 0);
        s.check_invariants();
    }

    #[test]
    fn property_work_conservation() {
        // If a packet arrives while free cores exist, the instance must end
        // up holding a core (never Contended).
        forall("work conservation", 40, |g: &mut Gen| {
            let mut s = sched(g.u64(3, 10) as u32);
            let n = g.usize(1, 4);
            let ids: Vec<_> =
                (0..n).map(|i| running_instance(&mut s, &format!("f{i}"), 2)).collect();
            for _ in 0..50 {
                let k = g.usize(0, n - 1);
                if s.granted_total() < s.grantable_cores() {
                    let out = s.packet_arrival(ids[k]);
                    assert!(
                        !matches!(out, GrantOutcome::Contended { .. }),
                        "contended despite free cores"
                    );
                } else {
                    s.packet_arrival(ids[k]);
                }
                if g.bool() {
                    if let Some(&id) = ids.iter().find(|&&id| s.instance(id).unwrap().in_flight > 0)
                    {
                        s.request_done(id);
                    }
                }
                s.check_invariants();
            }
        });
    }
}
