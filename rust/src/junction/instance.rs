//! Junction instances and uProcs.
//!
//! An [`Instance`] models one Junction host process: a container-like
//! isolation boundary holding one or more [`UProc`]s that share the
//! instance's user-space kernel, NIC queue pair(s), and core grant. The
//! FaaS layer maps every faasd component (gateway, provider) and every
//! function replica onto an instance (paper §3, Figure 4).

use crate::simcore::Time;

/// Identifier for an instance on a server.
pub type InstanceId = u32;

/// A user-level process inside an instance (one executable).
#[derive(Debug, Clone)]
pub struct UProc {
    pub name: String,
    /// uThreads currently runnable (demand signal for the scheduler).
    pub runnable_threads: u32,
}

/// Instance lifecycle, as junctiond observes it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceState {
    /// `junction_run` spawned, user-space kernel booting (~3.4 ms, §5).
    Starting,
    /// At least one uProc live; can receive packets.
    Running,
    /// All uProcs exited.
    Stopped,
}

/// One Junction instance (host process + uProcs + queue pair + core grant).
#[derive(Debug, Clone)]
pub struct Instance {
    pub id: InstanceId,
    pub name: String,
    pub state: InstanceState,
    pub uprocs: Vec<UProc>,
    /// Maximum cores the scheduler may grant (configured limit, §2.2.1).
    pub max_cores: u32,
    /// Cores currently granted by the scheduler.
    pub granted_cores: u32,
    /// The *physical* cores backing the grant (`granted_cores ==
    /// core_ids.len()` is a checked invariant). The compute fabric runs
    /// this instance's segments on these cores with local-queue priority,
    /// so grant exclusivity and preemptive-regrant waits are structural.
    pub core_ids: Vec<u32>,
    /// Round-robin cursor for spreading segments across the grant.
    pub next_core: usize,
    /// Requests currently executing inside the instance.
    pub in_flight: u32,
    /// NIC queue pairs assigned (∝ max core allocation, §2.2.1).
    pub queue_pairs: u32,
    /// Virtual time the instance finished booting (for cold-start math).
    pub ready_at: Time,
    // telemetry
    pub total_invocations: u64,
    pub preemptions: u64,
}

impl Instance {
    pub fn new(id: InstanceId, name: &str, max_cores: u32) -> Self {
        Instance {
            id,
            name: name.to_string(),
            state: InstanceState::Starting,
            uprocs: Vec::new(),
            max_cores,
            granted_cores: 0,
            core_ids: Vec::new(),
            next_core: 0,
            in_flight: 0,
            queue_pairs: max_cores, // one QP per potential core
            ready_at: 0,
            total_invocations: 0,
            preemptions: 0,
        }
    }

    /// Spawn a uProc (e.g. one Python worker process). Scale-up mode (a)
    /// from §3: "multiple processes can be deployed within the same
    /// Junction instance".
    pub fn spawn_uproc(&mut self, name: &str) {
        self.uprocs.push(UProc { name: name.to_string(), runnable_threads: 0 });
        if self.state == InstanceState::Starting {
            self.state = InstanceState::Running;
        }
    }

    /// Raise the core cap. Scale-up mode (b) from §3: "the maximum core
    /// assignment to a given uProc can be modified".
    pub fn set_max_cores(&mut self, max: u32) {
        self.max_cores = max;
        self.queue_pairs = max;
    }

    /// Concurrency the instance can offer: one request per uProc thread
    /// slot. Python-style runtimes get 1 slot per uProc; threaded runtimes
    /// get `max_cores` slots per uProc.
    pub fn concurrency(&self, threads_per_uproc: u32) -> u32 {
        (self.uprocs.len() as u32).max(1) * threads_per_uproc.max(1)
    }

    /// Demand signal the scheduler polls: does this instance want (more)
    /// cores right now?
    pub fn wants_core(&self) -> bool {
        self.state == InstanceState::Running
            && self.in_flight > self.granted_cores
            && self.granted_cores < self.max_cores
    }

    /// Is the instance idle (parked, holding no cores)?
    pub fn is_idle(&self) -> bool {
        self.in_flight == 0 && self.granted_cores == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_starting_to_running() {
        let mut inst = Instance::new(1, "fn-aes", 2);
        assert_eq!(inst.state, InstanceState::Starting);
        inst.spawn_uproc("aes-worker");
        assert_eq!(inst.state, InstanceState::Running);
    }

    #[test]
    fn multi_uproc_scaleup_increases_concurrency() {
        let mut inst = Instance::new(1, "fn-py", 1);
        inst.spawn_uproc("w0");
        assert_eq!(inst.concurrency(1), 1);
        inst.spawn_uproc("w1");
        inst.spawn_uproc("w2");
        assert_eq!(inst.concurrency(1), 3);
    }

    #[test]
    fn max_core_scaleup_tracks_queue_pairs() {
        let mut inst = Instance::new(1, "fn-go", 1);
        inst.spawn_uproc("go");
        inst.set_max_cores(4);
        assert_eq!(inst.queue_pairs, 4);
        assert_eq!(inst.concurrency(4), 4);
    }

    #[test]
    fn demand_signal() {
        let mut inst = Instance::new(1, "fn", 2);
        inst.spawn_uproc("w");
        assert!(!inst.wants_core());
        inst.in_flight = 1;
        assert!(inst.wants_core());
        inst.granted_cores = 1;
        assert!(!inst.wants_core()); // satisfied
        inst.in_flight = 3;
        assert!(inst.wants_core()); // wants a second core
        inst.granted_cores = 2;
        assert!(!inst.wants_core()); // capped at max_cores
    }
}
