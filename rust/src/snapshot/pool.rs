//! The warm-instance pool: per-function keep-alive, idle-TTL eviction, a
//! global memory budget with LRU reclaim, and the bookkeeping for
//! background prewarms.
//!
//! The pool is backend-agnostic: it tracks opaque [`PoolHandle`]s (a
//! Junction instance id or a containerd container id) and decides *which*
//! parked instance serves an acquire; the caller (the pipeline's `World`)
//! applies the backend side effects (resume/pause/stop/retire) to the
//! handles the pool returns.
//!
//! Slot lifecycle:
//!
//! ```text
//! try_park ──► Warm ──acquire_warm──► InUse          (serving)
//!                │
//!                ├─sweep_ttl / reclaim_to_budget──► Evicted (terminal)
//! begin_prewarm ──► Restoring ──promote_ready──► Warm
//! ```
//!
//! An instance is only ever served out of `InUse`; `Evicted` and
//! `Restoring` slots are never returned by `acquire_warm` — the property
//! test at the bottom pins this.

use std::collections::{BTreeMap, VecDeque};

use crate::config::PlatformConfig;
use crate::invariants::{check, Audit, Violation};
use crate::simcore::Time;

pub type SlotId = usize;

/// Backend-opaque handle to a pooled instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolHandle {
    /// A Junction instance id (scheduler-registered, parked idle).
    Junction(u32),
    /// A containerd container id (paused).
    Container(u32),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotState {
    /// Parked, memory resident, acquirable.
    Warm,
    /// Acquired; the instance is serving a deployment.
    InUse,
    /// Background restore in flight; acquirable once `ready_at` passes.
    Restoring { ready_at: Time },
    /// Torn down by TTL or memory reclaim (terminal).
    Evicted,
}

/// One pooled-instance record.
#[derive(Debug, Clone)]
pub struct Slot {
    pub function: String,
    pub handle: PoolHandle,
    pub state: SlotState,
    /// When the slot last entered `Warm`.
    pub parked_at: Time,
    pub mem_bytes: u64,
}

/// Pool telemetry counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub parks: u64,
    pub warm_hits: u64,
    pub prewarms: u64,
    pub ttl_evictions: u64,
    pub lru_evictions: u64,
    /// Evictions from explicit `flush` calls (not TTL or budget).
    pub flushes: u64,
}

/// Keep-alive policy knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolConfig {
    /// Idle duration after which a parked instance is evicted.
    pub idle_ttl_ns: Time,
    /// Global resident-memory budget for parked + restoring instances.
    pub mem_budget_bytes: u64,
    /// Cap on parked instances per function.
    pub max_warm_per_fn: u32,
}

impl PoolConfig {
    pub fn from_platform(p: &PlatformConfig) -> PoolConfig {
        PoolConfig {
            idle_ttl_ns: p.pool_idle_ttl_ns,
            mem_budget_bytes: p.pool_mem_budget_bytes,
            max_warm_per_fn: 8,
        }
    }
}

/// The warm pool.
pub struct WarmPool {
    pub cfg: PoolConfig,
    slots: Vec<Slot>,
    /// function → parked slot ids, front = oldest parked (LRU end).
    warm: BTreeMap<String, VecDeque<SlotId>>,
    /// Slots currently in `Restoring` (scanned by `promote_ready`).
    restoring: Vec<SlotId>,
    /// Resident bytes held by Warm + Restoring slots.
    pub mem_in_use: u64,
    pub stats: PoolStats,
}

impl WarmPool {
    pub fn new(cfg: PoolConfig) -> WarmPool {
        WarmPool {
            cfg,
            slots: Vec::new(),
            warm: BTreeMap::new(),
            restoring: Vec::new(),
            mem_in_use: 0,
            stats: PoolStats::default(),
        }
    }

    pub fn slot(&self, id: SlotId) -> &Slot {
        &self.slots[id]
    }

    pub fn warm_count(&self, function: &str) -> usize {
        self.warm.get(function).map(|q| q.len()).unwrap_or(0)
    }

    pub fn restoring_count(&self, function: &str) -> usize {
        self.restoring.iter().filter(|&&id| self.slots[id].function == function).count()
    }

    pub fn total_warm(&self) -> usize {
        self.warm.values().map(|q| q.len()).sum()
    }

    /// Park an idle instance as warm. Returns `None` (caller must tear the
    /// instance down) when the per-function cap is reached.
    pub fn try_park(
        &mut self,
        function: &str,
        handle: PoolHandle,
        now: Time,
        mem_bytes: u64,
    ) -> Option<SlotId> {
        if self.warm_count(function) >= self.cfg.max_warm_per_fn as usize {
            return None;
        }
        let id = self.slots.len();
        self.slots.push(Slot {
            function: function.to_string(),
            handle,
            state: SlotState::Warm,
            parked_at: now,
            mem_bytes,
        });
        self.warm.entry(function.to_string()).or_default().push_back(id);
        self.mem_in_use += mem_bytes;
        self.stats.parks += 1;
        Some(id)
    }

    /// Register a background prewarm (instance being restored/booted into
    /// the pool). It becomes acquirable once promoted past `ready_at`.
    pub fn begin_prewarm(
        &mut self,
        function: &str,
        handle: PoolHandle,
        ready_at: Time,
        mem_bytes: u64,
    ) -> SlotId {
        let id = self.slots.len();
        self.slots.push(Slot {
            function: function.to_string(),
            handle,
            state: SlotState::Restoring { ready_at },
            parked_at: ready_at,
            mem_bytes,
        });
        self.restoring.push(id);
        self.mem_in_use += mem_bytes;
        self.stats.prewarms += 1;
        id
    }

    /// Promote every finished restore to `Warm`. Idempotent.
    pub fn promote_ready(&mut self, now: Time) -> Vec<SlotId> {
        let mut promoted = Vec::new();
        let mut still = Vec::new();
        for id in std::mem::take(&mut self.restoring) {
            match self.slots[id].state {
                SlotState::Restoring { ready_at } if ready_at <= now => {
                    self.slots[id].state = SlotState::Warm;
                    self.slots[id].parked_at = ready_at;
                    let function = self.slots[id].function.clone();
                    self.warm.entry(function).or_default().push_back(id);
                    promoted.push(id);
                }
                SlotState::Restoring { .. } => still.push(id),
                // Already promoted/evicted through another path: drop.
                _ => {}
            }
        }
        self.restoring = still;
        promoted
    }

    /// Acquire the most-recently-parked warm instance for `function`
    /// (MRU keeps caches hottest; eviction runs from the LRU end).
    pub fn acquire_warm(&mut self, function: &str, now: Time) -> Option<(SlotId, PoolHandle)> {
        self.promote_ready(now);
        let q = self.warm.get_mut(function)?;
        let id = q.pop_back()?;
        if q.is_empty() {
            self.warm.remove(function);
        }
        debug_assert_eq!(self.slots[id].state, SlotState::Warm);
        self.slots[id].state = SlotState::InUse;
        self.mem_in_use -= self.slots[id].mem_bytes;
        self.stats.warm_hits += 1;
        Some((id, self.slots[id].handle))
    }

    fn evict(&mut self, id: SlotId) -> PoolHandle {
        debug_assert_eq!(self.slots[id].state, SlotState::Warm);
        self.slots[id].state = SlotState::Evicted;
        self.mem_in_use -= self.slots[id].mem_bytes;
        let function = self.slots[id].function.clone();
        if let Some(q) = self.warm.get_mut(&function) {
            q.retain(|&s| s != id);
            if q.is_empty() {
                self.warm.remove(&function);
            }
        }
        self.slots[id].handle
    }

    /// Evict one specific warm slot whose idle TTL expired (the per-slot
    /// timer path: the pipeline arms a cancellable timer per park instead
    /// of sweeping). Returns the handle for teardown; `None` when the
    /// slot is no longer warm — with real timer cancellation that is a
    /// defensive guard, not an expected path.
    pub fn evict_idle(&mut self, id: SlotId) -> Option<PoolHandle> {
        if self.slots.get(id)?.state != SlotState::Warm {
            return None;
        }
        let h = self.evict(id);
        self.stats.ttl_evictions += 1;
        Some(h)
    }

    /// Every currently-warm slot with its park time (used to arm TTL
    /// timers when maintenance starts on a pool that already has parked
    /// instances).
    pub fn warm_slots(&self) -> Vec<(SlotId, Time)> {
        self.warm.values().flatten().map(|&id| (id, self.slots[id].parked_at)).collect()
    }

    /// Evict every warm slot idle for at least the TTL. Returns the evicted
    /// handles oldest-first; the caller tears the instances down.
    /// Scans only the warm queues, not every slot ever created.
    pub fn sweep_ttl(&mut self, now: Time) -> Vec<(SlotId, PoolHandle)> {
        self.promote_ready(now);
        let mut expired: Vec<SlotId> = self
            .warm
            .values()
            .flatten()
            .copied()
            .filter(|&id| now.saturating_sub(self.slots[id].parked_at) >= self.cfg.idle_ttl_ns)
            .collect();
        expired.sort_by_key(|&id| (self.slots[id].parked_at, id));
        let mut out = Vec::with_capacity(expired.len());
        for id in expired {
            let h = self.evict(id);
            self.stats.ttl_evictions += 1;
            out.push((id, h));
        }
        out
    }

    /// LRU-reclaim warm slots until resident memory fits the budget. The
    /// global LRU victim is the oldest queue front (queues are in park
    /// order, so each front is that function's oldest).
    pub fn reclaim_to_budget(&mut self) -> Vec<(SlotId, PoolHandle)> {
        let mut out = Vec::new();
        while self.mem_in_use > self.cfg.mem_budget_bytes {
            let oldest = self
                .warm
                .values()
                .filter_map(|q| q.front().copied())
                .min_by_key(|&id| (self.slots[id].parked_at, id));
            let Some(id) = oldest else { break };
            let h = self.evict(id);
            self.stats.lru_evictions += 1;
            out.push((id, h));
        }
        out
    }

    /// Evict every warm slot regardless of age (test/bench helper: forces
    /// the next acquire down to the snapshot or cold tier).
    pub fn flush(&mut self) -> Vec<(SlotId, PoolHandle)> {
        let all: Vec<SlotId> = self.warm.values().flatten().copied().collect();
        let mut out = Vec::with_capacity(all.len());
        for id in all {
            let h = self.evict(id);
            self.stats.flushes += 1;
            out.push((id, h));
        }
        out
    }

    /// May this slot serve an invocation at `now`? Only an acquired
    /// (`InUse`) instance serves; evicted and still-restoring never do.
    pub fn servable(&self, id: SlotId, _now: Time) -> bool {
        matches!(self.slots[id].state, SlotState::InUse)
    }

    /// Accounting invariants (called from tests and debug paths). Thin
    /// wrapper over the structured [`Audit`] impl.
    pub fn check_invariants(&self) {
        self.assert_clean();
    }
}

/// Conservation laws of the warm pool: memory accounting matches the
/// resident slots, the warm index only points at warm slots filed under
/// the right function, and the restoring list only holds restorations in
/// flight. [`PoolStats`] eviction counters are bounded by admissions in
/// `tests/invariants.rs`.
impl Audit for WarmPool {
    fn module(&self) -> &'static str {
        "snapshot/pool"
    }

    fn audit_into(&self, out: &mut Vec<Violation>) {
        let m = self.module();
        let resident: u64 = self
            .slots
            .iter()
            .filter(|s| matches!(s.state, SlotState::Warm | SlotState::Restoring { .. }))
            .map(|s| s.mem_bytes)
            .sum();
        check(out, m, "pool-mem", resident == self.mem_in_use, || {
            format!("resident slots hold {resident} bytes, mem_in_use says {}", self.mem_in_use)
        });
        for (function, q) in &self.warm {
            for &id in q {
                let warm = self.slots[id].state == SlotState::Warm;
                check(out, m, "warm-queue", warm, || {
                    let state = self.slots[id].state;
                    format!("slot {id} in the warm queue for {function} is {state:?}")
                });
                let filed = &self.slots[id].function == function;
                check(out, m, "warm-queue", filed, || {
                    format!(
                        "slot {id} filed under {function} but belongs to {}",
                        self.slots[id].function
                    )
                });
            }
        }
        for &id in &self.restoring {
            let restoring = matches!(self.slots[id].state, SlotState::Restoring { .. });
            check(out, m, "restoring", restoring, || {
                format!("restoring list holds slot {id} in state {:?}", self.slots[id].state)
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::{forall, Gen, SECONDS};

    fn pool(budget: u64, ttl: Time) -> WarmPool {
        WarmPool::new(PoolConfig { idle_ttl_ns: ttl, mem_budget_bytes: budget, max_warm_per_fn: 8 })
    }

    fn h(n: u32) -> PoolHandle {
        PoolHandle::Junction(n)
    }

    #[test]
    fn ttl_eviction_is_oldest_first() {
        let mut p = pool(u64::MAX, 10 * SECONDS);
        let a = p.try_park("f", h(0), 0, 1).unwrap();
        let b = p.try_park("f", h(1), 3 * SECONDS, 1).unwrap();
        let c = p.try_park("g", h(2), 1 * SECONDS, 1).unwrap();
        // At t=11s: a (11s idle) and c (10s idle) expire, b (8s) survives.
        let evicted = p.sweep_ttl(11 * SECONDS);
        let ids: Vec<SlotId> = evicted.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![a, c], "must evict in park order (oldest first)");
        assert_eq!(p.stats.ttl_evictions, 2);
        assert_eq!(p.warm_count("f"), 1);
        assert!(p.acquire_warm("g", 11 * SECONDS).is_none());
        let _ = b;
        p.check_invariants();
    }

    #[test]
    fn memory_budget_reclaims_lru() {
        let mut p = pool(3, Time::MAX);
        let a = p.try_park("f", h(0), 10, 1).unwrap();
        p.try_park("f", h(1), 20, 1).unwrap();
        p.try_park("g", h(2), 30, 1).unwrap();
        assert!(p.reclaim_to_budget().is_empty(), "within budget: no reclaim");
        p.try_park("g", h(3), 40, 1).unwrap();
        let evicted = p.reclaim_to_budget();
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].0, a, "LRU (oldest parked) must go first");
        assert!(p.mem_in_use <= 3);
        assert_eq!(p.stats.lru_evictions, 1);
        p.check_invariants();
    }

    #[test]
    fn acquire_is_mru_and_marks_in_use() {
        let mut p = pool(u64::MAX, Time::MAX);
        p.try_park("f", h(0), 10, 5).unwrap();
        let newer = p.try_park("f", h(1), 20, 5).unwrap();
        let (id, handle) = p.acquire_warm("f", 30).unwrap();
        assert_eq!(id, newer, "MRU slot must serve first");
        assert_eq!(handle, h(1));
        assert!(p.servable(id, 30));
        assert_eq!(p.mem_in_use, 5, "acquired slot leaves the resident budget");
        assert_eq!(p.warm_count("f"), 1);
        p.check_invariants();
    }

    #[test]
    fn per_function_park_cap() {
        let mut p = WarmPool::new(PoolConfig {
            idle_ttl_ns: Time::MAX,
            mem_budget_bytes: u64::MAX,
            max_warm_per_fn: 2,
        });
        assert!(p.try_park("f", h(0), 0, 1).is_some());
        assert!(p.try_park("f", h(1), 0, 1).is_some());
        assert!(p.try_park("f", h(2), 0, 1).is_none(), "cap reached");
        assert!(p.try_park("g", h(3), 0, 1).is_some(), "cap is per function");
    }

    #[test]
    fn evict_idle_removes_only_warm_slots() {
        let mut p = pool(u64::MAX, 10 * SECONDS);
        let a = p.try_park("f", h(0), 0, 1).unwrap();
        let b = p.try_park("f", h(1), 5, 1).unwrap();
        assert_eq!(p.warm_slots(), vec![(a, 0), (b, 5)]);
        assert_eq!(p.evict_idle(a), Some(h(0)), "warm slot evicts by id");
        assert_eq!(p.stats.ttl_evictions, 1);
        assert_eq!(p.evict_idle(a), None, "already evicted: defensive no-op");
        let (got, _) = p.acquire_warm("f", 10).unwrap();
        assert_eq!(got, b);
        assert_eq!(p.evict_idle(b), None, "in-use slot must not evict");
        assert!(p.warm_slots().is_empty());
        p.check_invariants();
    }

    #[test]
    fn prewarm_promotes_only_after_ready() {
        let mut p = pool(u64::MAX, Time::MAX);
        let id = p.begin_prewarm("f", h(0), 100, 7);
        assert!(!p.servable(id, 50));
        assert!(p.acquire_warm("f", 50).is_none(), "still restoring: not acquirable");
        let (got, _) = p.acquire_warm("f", 100).expect("ready at 100");
        assert_eq!(got, id);
        assert!(p.servable(id, 100));
        assert_eq!(p.stats.prewarms, 1);
        p.check_invariants();
    }

    /// The satellite property: an invocation is never served by an evicted
    /// or still-restoring instance — under arbitrary interleavings of
    /// park/prewarm/acquire/sweep/reclaim with an advancing clock.
    #[test]
    fn property_never_serve_evicted_or_restoring() {
        forall("pool never serves evicted/restoring", 80, |g: &mut Gen| {
            let budget = g.u64(2, 6);
            let ttl = g.u64(1, 20) * SECONDS;
            let mut p = pool(budget, ttl);
            let mut now: Time = 0;
            let mut next_handle = 0u32;
            let fns = ["a", "b", "c"];
            // Shadow state: every slot id ever evicted.
            let mut evicted: Vec<SlotId> = Vec::new();
            for _ in 0..120 {
                now += g.u64(0, 4) * SECONDS;
                let f = *g.choose(&fns);
                match g.u64(0, 4) {
                    0 => {
                        p.try_park(f, h(next_handle), now, 1);
                        next_handle += 1;
                    }
                    1 => {
                        p.begin_prewarm(f, h(next_handle), now + g.u64(1, 3) * SECONDS, 1);
                        next_handle += 1;
                    }
                    2 => {
                        if let Some((id, _)) = p.acquire_warm(f, now) {
                            // The served instance must be InUse, never a
                            // slot that was evicted or is still restoring.
                            assert!(p.servable(id, now), "acquired slot not servable");
                            assert!(!evicted.contains(&id), "served an evicted slot");
                            assert!(
                                !matches!(p.slot(id).state, SlotState::Restoring { .. }),
                                "served a still-restoring slot"
                            );
                        }
                    }
                    3 => evicted.extend(p.sweep_ttl(now).into_iter().map(|(id, _)| id)),
                    _ => evicted.extend(p.reclaim_to_budget().into_iter().map(|(id, _)| id)),
                }
                for &id in &evicted {
                    assert!(!p.servable(id, now), "evicted slot became servable");
                }
                p.check_invariants();
            }
        });
    }
}
