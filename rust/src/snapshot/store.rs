//! Per-function memory-snapshot store.
//!
//! On a function's first cold boot the backend captures a memory snapshot
//! of the freshly initialized instance (off the critical path — the boot
//! latency the caller observes is unchanged). The snapshot becomes
//! *available* `capture_ns` after the instance is ready; from then on,
//! re-provisioning the function can restore from it instead of cold
//! booting (Quark-style secure-runtime starts; FaaSNet-style provisioning
//! artifacts).

use std::collections::BTreeMap;

use crate::simcore::Time;

/// One captured snapshot's metadata.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Snapshot {
    pub function: String,
    /// Virtual time the capture started (instance ready).
    pub captured_at: Time,
    /// Virtual time the snapshot becomes restorable.
    pub available_at: Time,
    pub size_bytes: u64,
    /// How many instances were restored from this snapshot.
    pub restores: u64,
}

/// Snapshot metadata table + counters.
#[derive(Debug, Default)]
pub struct SnapshotStore {
    snaps: BTreeMap<String, Snapshot>,
    pub captures: u64,
    pub bytes_written: u64,
}

impl SnapshotStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Begin capturing a snapshot for `function` at `start` (typically the
    /// instance's ready time). Returns when it becomes available. Capture
    /// is once-per-function: a later call returns the existing snapshot's
    /// availability unchanged.
    pub fn capture(&mut self, function: &str, start: Time, capture_ns: Time, size: u64) -> Time {
        if let Some(s) = self.snaps.get(function) {
            return s.available_at;
        }
        self.captures += 1;
        self.bytes_written += size;
        let available_at = start + capture_ns;
        self.snaps.insert(
            function.to_string(),
            Snapshot {
                function: function.to_string(),
                captured_at: start,
                available_at,
                size_bytes: size,
                restores: 0,
            },
        );
        available_at
    }

    pub fn get(&self, function: &str) -> Option<&Snapshot> {
        self.snaps.get(function)
    }

    /// Is a snapshot restorable for `function` at virtual time `now`?
    pub fn ready(&self, function: &str, now: Time) -> bool {
        self.snaps.get(function).is_some_and(|s| s.available_at <= now)
    }

    pub fn note_restore(&mut self, function: &str) {
        if let Some(s) = self.snaps.get_mut(function) {
            s.restores += 1;
        }
    }

    /// Drop a snapshot (e.g. on function removal). Returns whether one
    /// existed.
    pub fn evict(&mut self, function: &str) -> bool {
        self.snaps.remove(function).is_some()
    }

    pub fn len(&self) -> usize {
        self.snaps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.snaps.is_empty()
    }

    pub fn total_restores(&self) -> u64 {
        self.snaps.values().map(|s| s.restores).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::MILLIS;

    #[test]
    fn capture_gates_availability() {
        let mut st = SnapshotStore::new();
        let avail = st.capture("aes", 10 * MILLIS, 5 * MILLIS, 1 << 20);
        assert_eq!(avail, 15 * MILLIS);
        assert!(!st.ready("aes", 14 * MILLIS));
        assert!(st.ready("aes", 15 * MILLIS));
        assert!(!st.ready("other", u64::MAX));
    }

    #[test]
    fn capture_is_once_per_function() {
        let mut st = SnapshotStore::new();
        let a = st.capture("aes", 0, MILLIS, 100);
        let b = st.capture("aes", 99 * MILLIS, MILLIS, 100);
        assert_eq!(a, b, "recapture must not move availability");
        assert_eq!(st.captures, 1);
        assert_eq!(st.bytes_written, 100);
        assert_eq!(st.len(), 1);
    }

    #[test]
    fn restores_are_counted() {
        let mut st = SnapshotStore::new();
        st.capture("aes", 0, MILLIS, 100);
        st.note_restore("aes");
        st.note_restore("aes");
        st.note_restore("missing"); // no-op
        assert_eq!(st.get("aes").unwrap().restores, 2);
        assert_eq!(st.total_restores(), 2);
    }

    #[test]
    fn evict_removes() {
        let mut st = SnapshotStore::new();
        st.capture("aes", 0, MILLIS, 100);
        assert!(st.evict("aes"));
        assert!(!st.evict("aes"));
        assert!(st.is_empty());
        assert!(!st.ready("aes", u64::MAX));
    }
}
