//! Tiered instance provisioning: warm pools + snapshot/restore for both
//! execution backends.
//!
//! The paper's headline cold-start number (Junction instance init ≈ 3.4 ms
//! vs containerd's ~250 ms container boot) is a *single* fixed-cost boot
//! path. Real FaaS tail latency at scale is dominated by the provisioning
//! *policy* wrapped around that path (FaaSNet, ATC'21; Quark, 2023), so
//! this subsystem gives every function a three-rung ladder:
//!
//! | tier             | junctiond | containerd | mechanism                     |
//! |------------------|-----------|------------|-------------------------------|
//! | warm-pool        |   25 µs   |   2.5 ms   | unpark a parked instance      |
//! | snapshot-restore |  600 µs   |    45 ms   | restore per-function snapshot |
//! | cold-boot        |  3.4 ms   |   250 ms   | the seed's boot path          |
//!
//! * [`tiers`] — the [`ProvisionTier`] ladder and per-backend [`TierCosts`].
//! * [`store`] — [`SnapshotStore`]: per-function snapshots, captured off
//!   the critical path after first boot.
//! * [`pool`] — [`WarmPool`]: keep-alive with idle-TTL eviction and a
//!   global memory budget with LRU reclaim.
//! * [`policy`] — [`PrewarmPolicy`] + [`ArrivalEstimator`]: arrival-rate
//!   driven background prewarming, fed by the workload layer.
//!
//! The pipeline (`faas::pipeline`) provisions every replica through the
//! ladder, records the serving tier per invocation, and exports per-tier
//! counters through `telemetry::MetricsRegistry`.

pub mod policy;
pub mod pool;
pub mod store;
pub mod tiers;

pub use policy::{ArrivalEstimator, PrewarmPolicy};
pub use pool::{PoolConfig, PoolHandle, PoolStats, SlotId, SlotState, WarmPool};
pub use store::{Snapshot, SnapshotStore};
pub use tiers::{ProvisionTier, TierCosts};
