//! Prewarm policy: decide how many warm instances to keep parked per
//! function, driven by an arrival-rate estimate fed from the workload
//! layer (every `FaasSim::submit` observes into the estimator).
//!
//! The shape follows the FaaSNet/keep-alive literature: estimate the
//! per-function arrival rate with an event-driven EWMA, keep enough warm
//! capacity to absorb `headroom_window` worth of arrivals, and cap it so a
//! single hot function cannot monopolize the pool budget.

use crate::simcore::{Time, SECONDS};

/// Event-driven exponentially-weighted arrival-rate estimator.
///
/// On each arrival the instantaneous rate `1/gap` is blended in with a
/// weight that grows with the gap (`1 - exp(-gap/tau)`), so the estimate
/// is independent of the sampling pattern; reads decay the estimate toward
/// zero for silent functions.
#[derive(Debug, Clone)]
pub struct ArrivalEstimator {
    ewma_rps: f64,
    last_arrival: Option<Time>,
    /// Time constant of the EWMA.
    tau_ns: f64,
}

impl ArrivalEstimator {
    pub fn new(tau: Time) -> ArrivalEstimator {
        ArrivalEstimator { ewma_rps: 0.0, last_arrival: None, tau_ns: tau as f64 }
    }

    /// Record one arrival at virtual time `now`.
    pub fn observe(&mut self, now: Time) {
        match self.last_arrival {
            None => {
                // First arrival: seed with one arrival per tau.
                self.ewma_rps = SECONDS as f64 / self.tau_ns;
            }
            Some(prev) => {
                let gap = now.saturating_sub(prev).max(1) as f64;
                let inst_rps = SECONDS as f64 / gap;
                let alpha = 1.0 - (-gap / self.tau_ns).exp();
                self.ewma_rps = alpha * inst_rps + (1.0 - alpha) * self.ewma_rps;
            }
        }
        self.last_arrival = Some(now);
    }

    /// Current rate estimate (rps), decayed by the silence since the last
    /// arrival.
    pub fn rate_rps(&self, now: Time) -> f64 {
        let Some(prev) = self.last_arrival else { return 0.0 };
        let silence = now.saturating_sub(prev) as f64;
        self.ewma_rps * (-silence / self.tau_ns).exp()
    }
}

/// How many warm instances to keep parked for a function.
#[derive(Debug, Clone, Copy)]
pub struct PrewarmPolicy {
    /// Cover this much future arrival mass with warm capacity.
    pub headroom_window_ns: Time,
    /// Per-function ceiling on prewarmed instances.
    pub max_prewarm: u32,
    /// Below this rate a function is considered cold and gets no prewarm.
    pub min_rate_rps: f64,
}

impl Default for PrewarmPolicy {
    fn default() -> Self {
        PrewarmPolicy {
            headroom_window_ns: SECONDS / 2,
            max_prewarm: 4,
            min_rate_rps: 20.0,
        }
    }
}

impl PrewarmPolicy {
    /// Target parked-warm count for an estimated arrival rate.
    pub fn target_warm(&self, rate_rps: f64) -> u32 {
        if rate_rps < self.min_rate_rps {
            return 0;
        }
        let window_s = self.headroom_window_ns as f64 / SECONDS as f64;
        ((rate_rps * window_s).ceil() as u32).min(self.max_prewarm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::MILLIS;

    #[test]
    fn estimator_converges_to_offered_rate() {
        // 1 kHz arrivals → estimate near 1000 rps after warm-up.
        let mut e = ArrivalEstimator::new(100 * MILLIS);
        let mut t = 0;
        for _ in 0..2_000 {
            t += MILLIS;
            e.observe(t);
        }
        let r = e.rate_rps(t);
        assert!((r - 1000.0).abs() < 100.0, "rate {r}");
    }

    #[test]
    fn estimator_decays_when_silent() {
        let mut e = ArrivalEstimator::new(100 * MILLIS);
        let mut t = 0;
        for _ in 0..500 {
            t += MILLIS;
            e.observe(t);
        }
        let busy = e.rate_rps(t);
        let idle = e.rate_rps(t + SECONDS);
        assert!(idle < busy / 100.0, "busy {busy} idle {idle}");
        assert_eq!(ArrivalEstimator::new(MILLIS).rate_rps(123), 0.0);
    }

    #[test]
    fn policy_targets_scale_with_rate_and_clamp() {
        let p = PrewarmPolicy::default();
        assert_eq!(p.target_warm(0.0), 0);
        assert_eq!(p.target_warm(p.min_rate_rps / 2.0), 0, "cold functions get no prewarm");
        let low = p.target_warm(p.min_rate_rps);
        let high = p.target_warm(1_000.0);
        assert!(low >= 1);
        assert!(high >= low);
        assert_eq!(high, p.max_prewarm, "hot function clamps at the cap");
    }
}
