//! Provisioning tiers and their per-backend cost model.
//!
//! Every function instance can be provisioned through a three-rung ladder
//! (cheapest first):
//!
//! 1. [`ProvisionTier::WarmPool`] — a warm-paused instance parked in the
//!    pool; acquiring it is an unpark, memory stays resident.
//! 2. [`ProvisionTier::SnapshotRestore`] — rebuild the instance from a
//!    per-function memory snapshot captured after its first boot; ≪ cold.
//! 3. [`ProvisionTier::ColdBoot`] — today's full boot path.
//!
//! Both backends walk the same ladder; the containerd rungs are 10–100×
//! slower than the Junction rungs (see `PlatformConfig::validate`), so the
//! paper's cold-start gap survives at every tier.

use crate::config::{Backend, PlatformConfig};
use crate::simcore::Time;

/// Which rung of the provisioning ladder served an instance request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum ProvisionTier {
    /// Unparked a warm-paused pooled instance.
    WarmPool,
    /// Restored from a per-function memory snapshot.
    SnapshotRestore,
    /// Full cold boot (the seed's only path).
    #[default]
    ColdBoot,
}

impl ProvisionTier {
    pub const ALL: [ProvisionTier; 3] =
        [ProvisionTier::WarmPool, ProvisionTier::SnapshotRestore, ProvisionTier::ColdBoot];

    pub fn name(&self) -> &'static str {
        match self {
            ProvisionTier::WarmPool => "warm-pool",
            ProvisionTier::SnapshotRestore => "snapshot-restore",
            ProvisionTier::ColdBoot => "cold-boot",
        }
    }

    /// Dense index for per-tier counter arrays.
    pub fn idx(&self) -> usize {
        match self {
            ProvisionTier::WarmPool => 0,
            ProvisionTier::SnapshotRestore => 1,
            ProvisionTier::ColdBoot => 2,
        }
    }
}

/// Per-backend cost constants for the ladder (cold-boot cost stays with
/// each backend's own sampler so its spread model is unchanged).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierCosts {
    pub warm_acquire_ns: Time,
    pub restore_ns: Time,
    pub capture_ns: Time,
    pub cold_base_ns: Time,
    /// Resident bytes one parked warm instance (or snapshot) holds.
    pub instance_mem_bytes: u64,
}

impl TierCosts {
    pub fn junction(p: &PlatformConfig) -> TierCosts {
        TierCosts {
            warm_acquire_ns: p.junction_warm_acquire_ns,
            restore_ns: p.junction_restore_ns,
            capture_ns: p.junction_snapshot_capture_ns,
            cold_base_ns: p.junction_cold_start_ns,
            instance_mem_bytes: p.junction_instance_mem_bytes,
        }
    }

    pub fn container(p: &PlatformConfig) -> TierCosts {
        TierCosts {
            warm_acquire_ns: p.container_warm_acquire_ns,
            restore_ns: p.container_restore_ns,
            capture_ns: p.container_snapshot_capture_ns,
            cold_base_ns: p.container_cold_start_ns,
            instance_mem_bytes: p.container_instance_mem_bytes,
        }
    }

    pub fn for_backend(backend: Backend, p: &PlatformConfig) -> TierCosts {
        match backend {
            Backend::Junctiond => TierCosts::junction(p),
            Backend::Containerd => TierCosts::container(p),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_is_ordered_for_both_backends() {
        let p = PlatformConfig::default();
        for costs in [TierCosts::junction(&p), TierCosts::container(&p)] {
            assert!(costs.warm_acquire_ns < costs.restore_ns);
            assert!(costs.restore_ns < costs.cold_base_ns);
        }
    }

    #[test]
    fn junction_beats_containerd_at_every_tier() {
        let p = PlatformConfig::default();
        let j = TierCosts::junction(&p);
        let c = TierCosts::container(&p);
        assert!(j.warm_acquire_ns * 10 <= c.warm_acquire_ns);
        assert!(j.restore_ns * 10 <= c.restore_ns);
        assert!(j.cold_base_ns * 10 <= c.cold_base_ns);
    }

    #[test]
    fn tier_indices_are_dense_and_named() {
        let mut seen = [false; 3];
        for t in ProvisionTier::ALL {
            seen[t.idx()] = true;
            assert!(!t.name().is_empty());
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(ProvisionTier::default(), ProvisionTier::ColdBoot);
    }
}
