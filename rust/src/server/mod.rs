//! Real-mode serving: the faasd topology on real transports, with real
//! PJRT compute — no simulation.
//!
//! This is the end-to-end demonstration (E7 in DESIGN.md): the same
//! client → gateway → provider → worker pipeline as the DES, where
//!
//! * **kernel mode** uses genuine loopback TCP sockets — every hop
//!   traverses the host kernel's network stack (syscalls, softirq, the
//!   works), exactly like mainline faasd;
//! * **bypass mode** uses in-process shared-memory rings with a polling
//!   consumer — the hops never enter the kernel, the honest analogue of
//!   Junction's user-space networking on this hardware (no bypass NICs
//!   here; the substitution is documented in DESIGN.md §1).
//!
//! The worker thread owns the PJRT [`crate::runtime::Executor`] and runs
//! the real AES-600B artifact for every request.

mod components;
mod ring;
mod transport;

pub use components::{run_pipeline, PipelineHandle, ServeMode};
pub use ring::RingPair;
pub use transport::{FrameRx, FrameTx, TcpFramed};
