//! Shared-memory frame rings: the kernel-bypass transport.
//!
//! A [`RingPair`] is a bidirectional channel of byte frames between two
//! threads. The receive side first *polls* (a bounded spin, mirroring how
//! a Junction instance's network stack consumes its NIC queue pair), then
//! parks on a condvar.
//!
//! **Hardware adaptation note** (DESIGN.md §1): Junction dedicates a core
//! to polling, which pays off only when a core is actually available to
//! burn. This environment is a 1-core container, where unbounded spinning
//! *inverts* the benefit — a spinning consumer starves the producer for a
//! whole scheduler quantum. The hybrid spin-then-park below keeps the
//! bypass property that matters on this box (no per-message TCP/IP stack
//! traversal, no epoll round, no socket syscalls — at most one futex wake
//! on the slow path) while staying honest about the substitution. On a
//! multi-core box the spin phase wins and the parking path never runs.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Spin iterations before parking. On a 1-core box spinning is pure waste
/// (the producer cannot run while we burn the quantum), so the budget is 0
/// there; on a many-core host the spin phase keeps latency sub-µs.
fn spin_budget() -> u32 {
    use std::sync::atomic::AtomicU32;
    static BUDGET: AtomicU32 = AtomicU32::new(u32::MAX);
    let v = BUDGET.load(Ordering::Relaxed);
    if v != u32::MAX {
        return v;
    }
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let budget = if cores <= 2 { 0 } else { 256 };
    BUDGET.store(budget, Ordering::Relaxed);
    budget
}

/// One direction of frame flow. Optionally *bounded* like a NIC RX
/// descriptor ring: a full bounded ring tail-drops on `try_send`, the
/// discipline the DES models in `netpath` (the default ring is unbounded,
/// matching the original transport behavior).
pub struct Ring {
    q: Mutex<VecDeque<Vec<u8>>>,
    cv: Condvar,
    closed: AtomicBool,
    capacity: usize,
    drops: AtomicU64,
}

impl Ring {
    fn new() -> Arc<Ring> {
        Self::with_capacity(usize::MAX)
    }

    fn with_capacity(capacity: usize) -> Arc<Ring> {
        Arc::new(Ring {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
            capacity,
            drops: AtomicU64::new(0),
        })
    }

    /// Offer one frame. Returns `false` (tail drop, counted) when a
    /// bounded ring is full.
    pub fn try_send(&self, frame: Vec<u8>) -> bool {
        {
            let mut q = self.q.lock().unwrap();
            if q.len() >= self.capacity {
                drop(q);
                self.drops.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            q.push_back(frame);
        }
        self.cv.notify_one();
        true
    }

    /// Send one frame; on a bounded ring this tail-drops silently when
    /// full (use [`Ring::try_send`] to observe the outcome).
    pub fn send(&self, frame: Vec<u8>) {
        let _ = self.try_send(frame);
    }

    /// Frames tail-dropped by a bounded ring.
    pub fn drops(&self) -> u64 {
        self.drops.load(Ordering::Relaxed)
    }

    /// Hybrid receive: bounded poll first (bypass fast path), then park.
    /// Returns `None` after `close()` once drained.
    pub fn recv(&self) -> Option<Vec<u8>> {
        // Fast path: poll without blocking.
        for _ in 0..spin_budget() {
            if let Ok(mut q) = self.q.try_lock() {
                if let Some(f) = q.pop_front() {
                    return Some(f);
                }
            }
            if self.closed.load(Ordering::Acquire) {
                break;
            }
            std::hint::spin_loop();
        }
        // Slow path: park on the condvar.
        let mut q = self.q.lock().unwrap();
        loop {
            if let Some(f) = q.pop_front() {
                return Some(f);
            }
            if self.closed.load(Ordering::Acquire) {
                return None;
            }
            q = self.cv.wait(q).unwrap();
        }
    }

    /// Non-blocking poll (single scan, no spin).
    pub fn try_recv(&self) -> Option<Vec<u8>> {
        self.q.lock().unwrap().pop_front()
    }

    /// Batched receive (DPDK `rx_burst`-style): block for the first frame
    /// like [`Ring::recv`], then drain up to `max - 1` more in the same
    /// lock acquisition. One consumer wakeup amortizes over the burst —
    /// the real-mode counterpart of the DES netpath's batch drain.
    pub fn recv_batch(&self, max: usize) -> Vec<Vec<u8>> {
        let Some(first) = self.recv() else { return Vec::new() };
        let mut out = vec![first];
        let mut q = self.q.lock().unwrap();
        while out.len() < max.max(1) {
            match q.pop_front() {
                Some(f) => out.push(f),
                None => break,
            }
        }
        out
    }

    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }
}

/// A bidirectional pair of rings: `a` endpoints send on `ab`/recv on `ba`,
/// `b` endpoints the reverse.
pub struct RingPair {
    pub ab: Arc<Ring>,
    pub ba: Arc<Ring>,
}

impl RingPair {
    #[allow(clippy::new_without_default)]
    pub fn new() -> RingPair {
        RingPair { ab: Ring::new(), ba: Ring::new() }
    }

    /// Bounded pair: both directions tail-drop past `capacity` frames
    /// (NIC-ring semantics; see the module note and `netpath`).
    pub fn bounded(capacity: usize) -> RingPair {
        RingPair { ab: Ring::with_capacity(capacity), ba: Ring::with_capacity(capacity) }
    }

    /// Endpoint handles: (a_send, a_recv), (b_send, b_recv).
    pub fn endpoints(&self) -> ((Arc<Ring>, Arc<Ring>), (Arc<Ring>, Arc<Ring>)) {
        ((self.ab.clone(), self.ba.clone()), (self.ba.clone(), self.ab.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_flow_in_order() {
        let pair = RingPair::new();
        let ((a_tx, _), (_, b_rx)) = pair.endpoints();
        for i in 0..10u8 {
            a_tx.send(vec![i]);
        }
        for i in 0..10u8 {
            assert_eq!(b_rx.recv().unwrap(), vec![i]);
        }
    }

    #[test]
    fn cross_thread_ping_pong() {
        let pair = RingPair::new();
        let ((a_tx, a_rx), (b_tx, b_rx)) = pair.endpoints();
        let t = std::thread::spawn(move || {
            for _ in 0..100 {
                let f = b_rx.recv().unwrap();
                b_tx.send(f.iter().map(|b| b + 1).collect());
            }
        });
        for i in 0..100u8 {
            a_tx.send(vec![i]);
            assert_eq!(a_rx.recv().unwrap(), vec![i + 1]);
        }
        t.join().unwrap();
    }

    #[test]
    fn close_unblocks_receiver() {
        let pair = RingPair::new();
        let ((_, a_rx), (b_tx, _)) = pair.endpoints();
        let rx = a_rx.clone();
        let t = std::thread::spawn(move || rx.recv());
        std::thread::sleep(std::time::Duration::from_millis(10));
        b_tx.send(vec![1]);
        assert_eq!(t.join().unwrap(), Some(vec![1]));
        let t2 = {
            let rx = a_rx.clone();
            std::thread::spawn(move || rx.recv())
        };
        std::thread::sleep(std::time::Duration::from_millis(10));
        pair.ba.close();
        assert_eq!(t2.join().unwrap(), None);
    }

    #[test]
    fn bounded_ring_sheds_overflow() {
        let pair = RingPair::bounded(4);
        let ((a_tx, _), (_, b_rx)) = pair.endpoints();
        let mut accepted = 0;
        for i in 0..6u8 {
            if a_tx.try_send(vec![i]) {
                accepted += 1;
            }
        }
        assert_eq!(accepted, 4);
        assert_eq!(a_tx.drops(), 2);
        for i in 0..4u8 {
            assert_eq!(b_rx.recv().unwrap(), vec![i], "FIFO survivors");
        }
        // Space freed: sends succeed again.
        assert!(a_tx.try_send(vec![9]));
    }

    #[test]
    fn recv_batch_drains_burst_in_one_call() {
        let pair = RingPair::new();
        let ((a_tx, _), (_, b_rx)) = pair.endpoints();
        for i in 0..10u8 {
            a_tx.send(vec![i]);
        }
        let burst = b_rx.recv_batch(8);
        assert_eq!(burst.len(), 8);
        assert_eq!(burst[0], vec![0]);
        assert_eq!(burst[7], vec![7]);
        let rest = b_rx.recv_batch(8);
        assert_eq!(rest.len(), 2);
        pair.ab.close();
        assert!(b_rx.recv_batch(8).is_empty(), "closed + drained → empty batch");
    }

    #[test]
    fn no_frames_lost_under_bursts() {
        let pair = RingPair::new();
        let ((a_tx, _), (_, b_rx)) = pair.endpoints();
        let t = std::thread::spawn(move || {
            let mut got = 0u32;
            while b_rx.recv().is_some() {
                got += 1;
            }
            got
        });
        for _ in 0..5000u32 {
            a_tx.send(vec![0]);
        }
        pair.ab.close();
        assert_eq!(t.join().unwrap(), 5000);
    }
}
