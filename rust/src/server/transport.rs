//! Frame transports: a common send/recv interface over TCP sockets
//! (kernel path) or shared-memory rings (bypass path).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::ring::Ring;

/// Sending half of a frame channel.
pub trait FrameTx: Send {
    fn send_frame(&mut self, frame: &[u8]) -> Result<()>;
}

/// Receiving half of a frame channel. `None` = peer closed.
pub trait FrameRx: Send {
    fn recv_frame(&mut self) -> Result<Option<Vec<u8>>>;
}

// ---------------------------------------------------------------------------
// TCP (kernel path)
// ---------------------------------------------------------------------------

/// Length-prefixed frames over a TCP stream. The frame layout already
/// starts with a u32 length (see `rpc::Message::encode`), so the wire
/// format *is* the frame.
pub struct TcpFramed {
    stream: TcpStream,
}

impl TcpFramed {
    pub fn new(stream: TcpStream) -> Result<TcpFramed> {
        stream.set_nodelay(true).context("set_nodelay")?;
        Ok(TcpFramed { stream })
    }

    pub fn try_clone(&self) -> Result<TcpFramed> {
        Ok(TcpFramed { stream: self.stream.try_clone()? })
    }
}

impl FrameTx for TcpFramed {
    fn send_frame(&mut self, frame: &[u8]) -> Result<()> {
        self.stream.write_all(frame)?;
        Ok(())
    }
}

impl FrameRx for TcpFramed {
    fn recv_frame(&mut self) -> Result<Option<Vec<u8>>> {
        let mut header = [0u8; 4];
        match self.stream.read_exact(&mut header) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
            Err(e) => return Err(e.into()),
        }
        let total = u32::from_le_bytes(header) as usize;
        anyhow::ensure!((13..16 << 20).contains(&total), "bad frame length {total}");
        let mut frame = vec![0u8; total];
        frame[..4].copy_from_slice(&header);
        self.stream.read_exact(&mut frame[4..])?;
        Ok(Some(frame))
    }
}

// ---------------------------------------------------------------------------
// Ring (bypass path)
// ---------------------------------------------------------------------------

/// Ring-backed sender.
pub struct RingTx(pub Arc<Ring>);
/// Ring-backed polling receiver.
pub struct RingRx(pub Arc<Ring>);

impl FrameTx for RingTx {
    fn send_frame(&mut self, frame: &[u8]) -> Result<()> {
        self.0.send(frame.to_vec());
        Ok(())
    }
}

impl FrameRx for RingRx {
    fn recv_frame(&mut self) -> Result<Option<Vec<u8>>> {
        Ok(self.0.recv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::Message;
    use std::net::TcpListener;

    #[test]
    fn tcp_round_trip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut fr = TcpFramed::new(s).unwrap();
            while let Some(frame) = fr.recv_frame().unwrap() {
                let m = Message::decode(&frame).unwrap();
                if m.kind == crate::rpc::Kind::Shutdown {
                    break;
                }
                let resp = Message::invoke_response(m.request_id, 0, &m.body);
                fr.send_frame(&resp.encode()).unwrap();
            }
        });
        let mut c = TcpFramed::new(TcpStream::connect(addr).unwrap()).unwrap();
        for i in 0..20u64 {
            let m = Message::invoke_request(i, "echo", b"hello");
            c.send_frame(&m.encode()).unwrap();
            let resp = Message::decode(&c.recv_frame().unwrap().unwrap()).unwrap();
            assert_eq!(resp.request_id, i);
        }
        c.send_frame(&Message::shutdown().encode()).unwrap();
        t.join().unwrap();
    }

    #[test]
    fn ring_transport_round_trip() {
        let pair = crate::server::RingPair::new();
        let ((a_tx, a_rx), (b_tx, b_rx)) = pair.endpoints();
        let (mut tx, mut rx) = (RingTx(a_tx), RingRx(a_rx));
        let t = std::thread::spawn(move || {
            let (mut btx, mut brx) = (RingTx(b_tx), RingRx(b_rx));
            let f = brx.recv_frame().unwrap().unwrap();
            btx.send_frame(&f).unwrap();
        });
        let m = Message::invoke_request(1, "f", b"x");
        tx.send_frame(&m.encode()).unwrap();
        let back = rx.recv_frame().unwrap().unwrap();
        assert_eq!(Message::decode(&back).unwrap(), m);
        t.join().unwrap();
    }

    #[test]
    fn tcp_eof_returns_none() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            drop(s); // immediate close
        });
        let mut c = TcpFramed::new(TcpStream::connect(addr).unwrap()).unwrap();
        t.join().unwrap();
        assert!(c.recv_frame().unwrap().is_none());
    }
}
