//! The real-mode pipeline: gateway, provider, and worker threads wired by
//! either transport, with the PJRT executor on the worker.
//!
//! Topology (one hop chain, mirroring faasd):
//!
//! ```text
//! client ──(gateway channel)──► gateway thread
//!          ──(provider channel)──► provider thread
//!          ──(worker channel)──► worker thread [PJRT aes600]
//! ```
//!
//! In `ServeMode::Kernel` each channel is a loopback TCP connection; in
//! `ServeMode::Bypass` each is a polled shared-memory ring. The component
//! logic is identical — only the transport differs, which is exactly the
//! paper's point.

use std::collections::BTreeMap;
use std::net::{TcpListener, TcpStream};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::rpc::{Kind, Message};
use crate::runtime::Executor;

use super::ring::RingPair;
use super::transport::{FrameRx, FrameTx, RingRx, RingTx, TcpFramed};

/// Which transport the pipeline's hops use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeMode {
    /// Loopback TCP through the host kernel (mainline-faasd analogue).
    Kernel,
    /// Polled shared-memory rings (Junction analogue).
    Bypass,
}

impl ServeMode {
    pub fn name(&self) -> &'static str {
        match self {
            ServeMode::Kernel => "kernel",
            ServeMode::Bypass => "bypass",
        }
    }
}

/// Handle to a running pipeline: the client-facing channel + join handles.
pub struct PipelineHandle {
    tx: Box<dyn FrameTx>,
    rx: Box<dyn FrameRx>,
    threads: Vec<JoinHandle<()>>,
    next_id: u64,
}

impl PipelineHandle {
    /// Invoke the AES-600B function once; returns the 600-byte ciphertext.
    pub fn invoke_aes600(&mut self, payload: &[u8; 600]) -> Result<Vec<u8>> {
        let id = self.next_id;
        self.next_id += 1;
        let req = Message::invoke_request(id, "aes600", payload);
        self.tx.send_frame(&req.encode())?;
        let frame = self.rx.recv_frame()?.context("pipeline closed")?;
        let resp = Message::decode(&frame)?;
        anyhow::ensure!(resp.request_id == id, "response id mismatch");
        let (status, body) = resp.parse_response()?;
        anyhow::ensure!(status == 0, "function error status {status}");
        Ok(body.to_vec())
    }

    /// Shut the pipeline down and join all component threads.
    pub fn shutdown(mut self) -> Result<()> {
        self.tx.send_frame(&Message::shutdown().encode())?;
        for t in self.threads.drain(..) {
            t.join().map_err(|_| anyhow::anyhow!("component thread panicked"))?;
        }
        Ok(())
    }
}

/// A generic proxy component: receives a frame upstream, does its (small)
/// component work, forwards downstream; relays responses back. This is
/// both the gateway and the provider (their faasd logic differs only in
/// bookkeeping, which `label` tags).
fn proxy_loop(
    label: &'static str,
    mut up_rx: Box<dyn FrameRx>,
    mut up_tx: Box<dyn FrameTx>,
    mut down_tx: Box<dyn FrameTx>,
    mut down_rx: Box<dyn FrameRx>,
) {
    // Provider metadata cache stand-in: function name → hit count. The
    // real resolve logic lives in the DES (`faas::Provider`); here it is
    // per-request bookkeeping on the same code path. Ordered map: any
    // future dump of this cache must not depend on hash order.
    let mut cache: BTreeMap<String, u64> = BTreeMap::new();
    loop {
        let Ok(Some(frame)) = up_rx.recv_frame() else { break };
        let Ok(msg) = Message::decode(&frame) else { break };
        match msg.kind {
            Kind::Shutdown => {
                let _ = down_tx.send_frame(&frame);
                break;
            }
            Kind::InvokeRequest => {
                if let Ok((name, _)) = msg.parse_request() {
                    *cache.entry(name.to_string()).or_insert(0) += 1;
                }
                if down_tx.send_frame(&frame).is_err() {
                    break;
                }
                match down_rx.recv_frame() {
                    Ok(Some(resp)) => {
                        if up_tx.send_frame(&resp).is_err() {
                            break;
                        }
                    }
                    _ => break,
                }
            }
            Kind::InvokeResponse => { /* stray response: drop */ }
        }
    }
    let _ = label; // kept for debugger breakpoints; no logger dependency
}

/// Worker loop: owns the PJRT executor; executes the real artifact.
fn worker_loop(mut rx: Box<dyn FrameRx>, mut tx: Box<dyn FrameTx>, artifacts: std::path::PathBuf) {
    let exec = Executor::load(&artifacts).expect("worker: loading artifacts");
    let key = *b"junctiond-repro!";
    let nonce = [7u8; 12];
    let mut resp_buf = Vec::with_capacity(640);
    loop {
        let Ok(Some(frame)) = rx.recv_frame() else { break };
        let Ok(msg) = Message::decode(&frame) else { break };
        match msg.kind {
            Kind::Shutdown => break,
            Kind::InvokeRequest => {
                let reply = match msg.parse_request() {
                    Ok(("aes600", payload)) if payload.len() == 600 => {
                        let mut pt = [0u8; 600];
                        pt.copy_from_slice(payload);
                        match exec.aes600(&pt, &key, &nonce) {
                            Ok(ct) => Message::invoke_response(msg.request_id, 0, &ct),
                            Err(_) => Message::invoke_response(msg.request_id, 2, b""),
                        }
                    }
                    _ => Message::invoke_response(msg.request_id, 1, b"bad request"),
                };
                reply.encode_into(&mut resp_buf);
                if tx.send_frame(&resp_buf).is_err() {
                    break;
                }
            }
            Kind::InvokeResponse => {}
        }
    }
}

/// Build and start the 3-component pipeline in the chosen mode. Returns a
/// client handle.
pub fn run_pipeline(mode: ServeMode, artifacts: std::path::PathBuf) -> Result<PipelineHandle> {
    match mode {
        ServeMode::Bypass => {
            // Three ring pairs: client↔gateway, gateway↔provider,
            // provider↔worker.
            let cg = RingPair::new();
            let gp = RingPair::new();
            let pw = RingPair::new();
            let ((c_tx, c_rx), (g_up_tx, g_up_rx)) = cg.endpoints();
            let ((g_down_tx, g_down_rx), (p_up_tx, p_up_rx)) = gp.endpoints();
            let ((p_down_tx, p_down_rx), (w_tx, w_rx)) = pw.endpoints();
            let gw = std::thread::Builder::new().name("gateway".into()).spawn(move || {
                proxy_loop(
                    "gateway",
                    Box::new(RingRx(g_up_rx)),
                    Box::new(RingTx(g_up_tx)),
                    Box::new(RingTx(g_down_tx)),
                    Box::new(RingRx(g_down_rx)),
                )
            })?;
            let prov = std::thread::Builder::new().name("provider".into()).spawn(move || {
                proxy_loop(
                    "provider",
                    Box::new(RingRx(p_up_rx)),
                    Box::new(RingTx(p_up_tx)),
                    Box::new(RingTx(p_down_tx)),
                    Box::new(RingRx(p_down_rx)),
                )
            })?;
            let worker = std::thread::Builder::new().name("worker".into()).spawn(move || {
                worker_loop(Box::new(RingRx(w_rx)), Box::new(RingTx(w_tx)), artifacts)
            })?;
            Ok(PipelineHandle {
                tx: Box::new(RingTx(c_tx)),
                rx: Box::new(RingRx(c_rx)),
                threads: vec![gw, prov, worker],
                next_id: 1,
            })
        }
        ServeMode::Kernel => {
            // Three loopback TCP connections.
            let gw_listener = TcpListener::bind("127.0.0.1:0")?;
            let prov_listener = TcpListener::bind("127.0.0.1:0")?;
            let worker_listener = TcpListener::bind("127.0.0.1:0")?;
            let gw_addr = gw_listener.local_addr()?;
            let prov_addr = prov_listener.local_addr()?;
            let worker_addr = worker_listener.local_addr()?;

            let worker = std::thread::Builder::new().name("worker".into()).spawn(move || {
                let (s, _) = worker_listener.accept().expect("worker accept");
                let fr = TcpFramed::new(s).expect("worker framed");
                let fr2 = fr.try_clone().expect("clone");
                worker_loop(Box::new(fr), Box::new(fr2), artifacts)
            })?;
            let prov = std::thread::Builder::new().name("provider".into()).spawn(move || {
                let (s, _) = prov_listener.accept().expect("provider accept");
                let up = TcpFramed::new(s).expect("framed");
                let up2 = up.try_clone().expect("clone");
                let down =
                    TcpFramed::new(TcpStream::connect(worker_addr).expect("dial worker"))
                        .expect("framed");
                let down2 = down.try_clone().expect("clone");
                proxy_loop("provider", Box::new(up), Box::new(up2), Box::new(down), Box::new(down2))
            })?;
            let gw = std::thread::Builder::new().name("gateway".into()).spawn(move || {
                let (s, _) = gw_listener.accept().expect("gateway accept");
                let up = TcpFramed::new(s).expect("framed");
                let up2 = up.try_clone().expect("clone");
                let down = TcpFramed::new(TcpStream::connect(prov_addr).expect("dial provider"))
                    .expect("framed");
                let down2 = down.try_clone().expect("clone");
                proxy_loop("gateway", Box::new(up), Box::new(up2), Box::new(down), Box::new(down2))
            })?;
            let client = TcpFramed::new(TcpStream::connect(gw_addr)?)?;
            let client_rx = client.try_clone()?;
            Ok(PipelineHandle {
                tx: Box::new(client),
                rx: Box::new(client_rx),
                threads: vec![gw, prov, worker],
                next_id: 1,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{default_artifacts_dir, rustcrypto_aes_ctr};

    fn check_pipeline(mode: ServeMode) {
        let mut h = run_pipeline(mode, default_artifacts_dir()).unwrap();
        let mut pt = [0u8; 600];
        for (i, b) in pt.iter_mut().enumerate() {
            *b = (i % 256) as u8;
        }
        let ct = h.invoke_aes600(&pt).unwrap();
        // The worker uses a fixed key/nonce; verify against RustCrypto.
        let want = rustcrypto_aes_ctr(&pt, b"junctiond-repro!", &[7u8; 12]);
        assert_eq!(ct, want);
        // A few more to exercise steady-state.
        for _ in 0..5 {
            let ct2 = h.invoke_aes600(&pt).unwrap();
            assert_eq!(ct2, ct);
        }
        h.shutdown().unwrap();
    }

    #[test]
    fn bypass_pipeline_serves_real_aes() {
        check_pipeline(ServeMode::Bypass);
    }

    #[test]
    fn kernel_pipeline_serves_real_aes() {
        check_pipeline(ServeMode::Kernel);
    }
}
