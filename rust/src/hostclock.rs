//! The one sanctioned seam between the deterministic simulation and the
//! host: wall-clock stopwatches (bench reporting, serve-mode latency
//! printouts, executor calibration), environment reads (artifact paths,
//! BENCH_QUICK toggles), and the CPU-parallelism probe the shard runner
//! benches size themselves with.
//!
//! Everything in this file is *observably nondeterministic* — that is
//! the point of quarantining it. detlint's `wall_clock` lint (L2)
//! forbids `std::time::Instant`, `SystemTime`, `thread_rng`, and
//! `std::env::var` everywhere else in the crate, so the virtual timeline
//! can never silently couple to host time, host entropy, or host
//! configuration. Code that genuinely needs the host — measuring how
//! long a bench took on this machine, or finding the artifacts dir —
//! goes through these helpers, which keeps every such coupling greppable
//! and reviewable in one place.
//!
//! Nothing here may feed values back into simulation state: a
//! `Stopwatch` reading must only ever be *reported* (printed beside the
//! virtual-time results), never used to schedule, order, or seed events.

use std::time::Instant;

/// A host-monotonic stopwatch for wall-clock reporting.
///
/// `Send + Sync` by construction (`Instant` is plain data), so the shard
/// runner can carry per-shard stopwatches across its worker threads
/// without any sim module touching `Instant` directly — the static
/// assertion below pins the guarantee.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    t0: Instant,
}

/// Compile-time proof that per-shard wall-clock accounting can cross
/// thread boundaries through this seam alone.
const _: fn() = || {
    fn requires_send_sync<T: Send + Sync>() {}
    requires_send_sync::<Stopwatch>();
};

impl Stopwatch {
    /// Start timing now (host time).
    pub fn new() -> Self {
        Stopwatch { t0: Instant::now() }
    }

    /// Nanoseconds of host time since `new()`.
    pub fn elapsed_ns(&self) -> u128 {
        self.t0.elapsed().as_nanos()
    }

    /// Seconds of host time since `new()`.
    pub fn elapsed_secs(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

/// Read an environment variable, or `None` if unset / non-UTF8. The only
/// sanctioned env read in the crate; callers must not let the result
/// alter simulation behavior for a fixed CLI invocation (artifact paths
/// and bench-quick toggles change *what* runs, never event order).
pub fn env_var(key: &str) -> Option<String> {
    std::env::var(key).ok()
}

/// Host CPU parallelism (for sizing shard fleets and gating wall-clock
/// speedup assertions in benches); `1` when the host won't say. Like the
/// stopwatch, the value must only pick *how much hardware* a run uses —
/// never event order, seeds, or any deterministic output.
pub fn host_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_is_monotonic() {
        let sw = Stopwatch::new();
        let a = sw.elapsed_ns();
        let b = sw.elapsed_ns();
        assert!(b >= a);
        assert!(sw.elapsed_secs() >= 0.0);
    }

    #[test]
    fn env_var_reads_are_optional() {
        assert!(env_var("JUNCTIOND_DETLINT_NO_SUCH_VAR").is_none());
    }

    #[test]
    fn host_parallelism_is_at_least_one() {
        assert!(host_parallelism() >= 1);
    }
}
