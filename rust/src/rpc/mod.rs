//! gRPC-like RPC layer: message types + length-prefixed wire framing.
//!
//! faasd connects its components with gRPC (§2.1.1: "each invocation
//! involves at least three gRPC invocations"). This module carries the
//! repo's equivalent: a compact binary framing shared by the real-mode
//! servers in `server/` (over TCP sockets for the kernel path, over
//! shared-memory rings for the bypass path). The DES pipeline charges the
//! *costs* of these hops from the platform model instead of moving real
//! bytes.
//!
//! Frame layout: `[u32 LE total_len][u8 kind][u64 LE request_id][body]`.

use anyhow::{bail, Result};

/// Message kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Kind {
    InvokeRequest = 1,
    InvokeResponse = 2,
    Shutdown = 3,
}

impl Kind {
    fn from_u8(v: u8) -> Result<Kind> {
        Ok(match v {
            1 => Kind::InvokeRequest,
            2 => Kind::InvokeResponse,
            3 => Kind::Shutdown,
            other => bail!("unknown rpc kind {other}"),
        })
    }
}

/// One RPC message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Message {
    pub kind: Kind,
    pub request_id: u64,
    /// For requests: `<fn-name>\0<payload>`; for responses: `<status>\0<payload>`.
    pub body: Vec<u8>,
}

impl Message {
    pub fn invoke_request(request_id: u64, function: &str, payload: &[u8]) -> Message {
        let mut body = Vec::with_capacity(function.len() + 1 + payload.len());
        body.extend_from_slice(function.as_bytes());
        body.push(0);
        body.extend_from_slice(payload);
        Message { kind: Kind::InvokeRequest, request_id, body }
    }

    pub fn invoke_response(request_id: u64, status: u8, payload: &[u8]) -> Message {
        let mut body = Vec::with_capacity(2 + payload.len());
        body.push(status);
        body.push(0);
        body.extend_from_slice(payload);
        Message { kind: Kind::InvokeResponse, request_id, body }
    }

    pub fn shutdown() -> Message {
        Message { kind: Kind::Shutdown, request_id: 0, body: Vec::new() }
    }

    /// Split a request body into (function, payload).
    pub fn parse_request(&self) -> Result<(&str, &[u8])> {
        anyhow::ensure!(self.kind == Kind::InvokeRequest, "not a request");
        let sep =
            self.body.iter().position(|&b| b == 0).ok_or_else(|| anyhow::anyhow!("no sep"))?;
        let name = std::str::from_utf8(&self.body[..sep])?;
        Ok((name, &self.body[sep + 1..]))
    }

    /// Split a response body into (status, payload).
    pub fn parse_response(&self) -> Result<(u8, &[u8])> {
        anyhow::ensure!(self.kind == Kind::InvokeResponse, "not a response");
        anyhow::ensure!(self.body.len() >= 2 && self.body[1] == 0, "malformed response");
        Ok((self.body[0], &self.body[2..]))
    }

    /// Total on-wire size of this message's frame (header + body). The
    /// DES network path charges per-packet costs against this without
    /// materializing the bytes.
    pub fn frame_size(&self) -> usize {
        4 + 1 + 8 + self.body.len()
    }

    /// Frame size of an invoke-request for `function` carrying
    /// `payload_len` payload bytes, without materializing the message
    /// (the DES hot path sizes every packet this way).
    pub fn request_frame_size(function: &str, payload_len: usize) -> usize {
        4 + 1 + 8 + function.len() + 1 + payload_len
    }

    /// Frame size of an invoke-response carrying `payload_len` bytes.
    pub fn response_frame_size(payload_len: usize) -> usize {
        4 + 1 + 8 + 2 + payload_len
    }

    /// Encode into a length-prefixed frame.
    pub fn encode(&self) -> Vec<u8> {
        let total = self.frame_size();
        let mut out = Vec::with_capacity(total);
        out.extend_from_slice(&(total as u32).to_le_bytes());
        out.push(self.kind as u8);
        out.extend_from_slice(&self.request_id.to_le_bytes());
        out.extend_from_slice(&self.body);
        out
    }

    /// Encode into a caller-provided buffer (hot-path variant: the server's
    /// per-connection buffer is reused across requests, so steady-state
    /// serving does no allocation here).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        let total = self.frame_size();
        out.reserve(total);
        out.extend_from_slice(&(total as u32).to_le_bytes());
        out.push(self.kind as u8);
        out.extend_from_slice(&self.request_id.to_le_bytes());
        out.extend_from_slice(&self.body);
    }

    /// Decode one frame (must be exactly one message).
    pub fn decode(frame: &[u8]) -> Result<Message> {
        anyhow::ensure!(frame.len() >= 13, "short frame: {} bytes", frame.len());
        let total = u32::from_le_bytes(frame[0..4].try_into().unwrap()) as usize;
        anyhow::ensure!(total == frame.len(), "length mismatch: {} != {}", total, frame.len());
        let kind = Kind::from_u8(frame[4])?;
        let request_id = u64::from_le_bytes(frame[5..13].try_into().unwrap());
        Ok(Message { kind, request_id, body: frame[13..].to_vec() })
    }

    /// Read the frame length from a 4-byte header.
    pub fn frame_len(header: &[u8; 4]) -> usize {
        u32::from_le_bytes(*header) as usize
    }
}

/// Number of gRPC hops in one faasd invocation (§2.1.1: client→gateway,
/// gateway→provider, provider→function), used by cost accounting.
pub const HOPS_PER_INVOCATION: u32 = 3;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::{forall, Gen};

    #[test]
    fn request_round_trip() {
        let m = Message::invoke_request(42, "aes600", b"payload-bytes");
        let decoded = Message::decode(&m.encode()).unwrap();
        assert_eq!(decoded, m);
        let (name, payload) = decoded.parse_request().unwrap();
        assert_eq!(name, "aes600");
        assert_eq!(payload, b"payload-bytes");
    }

    #[test]
    fn response_round_trip() {
        let m = Message::invoke_response(42, 0, b"cipher");
        let decoded = Message::decode(&m.encode()).unwrap();
        let (status, payload) = decoded.parse_response().unwrap();
        assert_eq!(status, 0);
        assert_eq!(payload, b"cipher");
    }

    #[test]
    fn encode_into_matches_encode() {
        let m = Message::invoke_request(7, "f", b"xyz");
        let mut buf = Vec::new();
        m.encode_into(&mut buf);
        assert_eq!(buf, m.encode());
    }

    #[test]
    fn frame_size_matches_encoded_length() {
        for m in [
            Message::invoke_request(1, "aes600", &[0x5A; 600]),
            Message::invoke_response(1, 0, b"cipher"),
            Message::shutdown(),
        ] {
            assert_eq!(m.frame_size(), m.encode().len());
        }
        // The allocation-free size helpers agree with real messages.
        assert_eq!(
            Message::request_frame_size("aes600", 600),
            Message::invoke_request(1, "aes600", &[0x5A; 600]).frame_size()
        );
        assert_eq!(
            Message::response_frame_size(600),
            Message::invoke_response(1, 0, &[0u8; 600]).frame_size()
        );
    }

    #[test]
    fn corrupt_frames_rejected() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[0, 0, 0, 0]).is_err());
        let mut good = Message::shutdown().encode();
        good[4] = 99; // unknown kind
        assert!(Message::decode(&good).is_err());
        let mut short = Message::shutdown().encode();
        short[0] = 200; // wrong length
        assert!(Message::decode(&short).is_err());
    }

    #[test]
    fn property_any_payload_round_trips() {
        forall("rpc round trip", 100, |g: &mut Gen| {
            let n = g.usize(0, 2000);
            let payload: Vec<u8> = (0..n).map(|_| g.u64(0, 255) as u8).collect();
            let id = g.u64(0, u64::MAX - 1);
            let m = Message::invoke_request(id, "fn-name", &payload);
            let d = Message::decode(&m.encode()).unwrap();
            assert_eq!(d.request_id, id);
            let (name, p) = d.parse_request().unwrap();
            assert_eq!(name, "fn-name");
            assert_eq!(p, &payload[..]);
        });
    }
}
