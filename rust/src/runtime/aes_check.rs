//! Independent AES oracle (RustCrypto `aes` crate) used to cross-check the
//! JAX/Pallas artifact at the Rust layer. Two implementations written in
//! different languages against different abstractions agreeing bit-for-bit
//! is the strongest correctness signal this repo has.

use aes::cipher::{BlockEncrypt, KeyInit};
use aes::Aes128;

/// AES-128-CTR with a 12-byte nonce and 32-bit big-endian block counter —
/// the same construction as `python/compile/model.py::aes600`.
pub fn rustcrypto_aes_ctr(plaintext: &[u8], key: &[u8; 16], nonce: &[u8; 12]) -> Vec<u8> {
    let cipher = Aes128::new(key.into());
    let n_blocks = plaintext.len().div_ceil(16);
    let mut keystream = Vec::with_capacity(n_blocks * 16);
    for ctr in 0..n_blocks as u32 {
        let mut block = [0u8; 16];
        block[..12].copy_from_slice(nonce);
        block[12..].copy_from_slice(&ctr.to_be_bytes());
        let mut b = aes::Block::from(block);
        cipher.encrypt_block(&mut b);
        keystream.extend_from_slice(&b);
    }
    plaintext.iter().zip(&keystream).map(|(p, k)| p ^ k).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctr_is_involutive() {
        let pt: Vec<u8> = (0..600).map(|i| (i % 251) as u8).collect();
        let key = [7u8; 16];
        let nonce = [1u8; 12];
        let ct = rustcrypto_aes_ctr(&pt, &key, &nonce);
        let rt = rustcrypto_aes_ctr(&ct, &key, &nonce);
        assert_eq!(rt, pt);
    }

    #[test]
    fn fips197_appendix_b_block() {
        // Encrypting the FIPS-197 plaintext directly (single block, CTR
        // keystream == ECB of the counter block), checked via ECB on the
        // raw cipher.
        use aes::cipher::BlockEncrypt;
        let key: [u8; 16] = [
            0x2B, 0x7E, 0x15, 0x16, 0x28, 0xAE, 0xD2, 0xA6, 0xAB, 0xF7, 0x15, 0x88, 0x09, 0xCF,
            0x4F, 0x3C,
        ];
        let cipher = Aes128::new(&key.into());
        let mut block = aes::Block::from([
            0x32u8, 0x43, 0xF6, 0xA8, 0x88, 0x5A, 0x30, 0x8D, 0x31, 0x31, 0x98, 0xA2, 0xE0, 0x37,
            0x07, 0x34,
        ]);
        cipher.encrypt_block(&mut block);
        assert_eq!(
            block.as_slice(),
            &[
                0x39, 0x25, 0x84, 0x1D, 0x02, 0xDC, 0x09, 0xFB, 0xDC, 0x11, 0x85, 0x97, 0x19,
                0x6A, 0x0B, 0x32
            ]
        );
    }
}
