//! Artifact loading + execution.
//!
//! Two backends behind one `Executor` API:
//!
//! * **`pjrt` feature** — load `<dir>/manifest.ini`, compile each HLO-text
//!   artifact on the PJRT CPU client (`xla` crate), and execute the real
//!   lowered compute. Requires the native xla_extension toolchain.
//! * **default (hermetic)** — install the pure-Rust reference kernels from
//!   [`super::fallback`] under the same catalog names and signatures. No
//!   artifacts, no native libraries, bit-exact AES semantics.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

#[cfg(feature = "pjrt")]
use crate::config::Ini;
use crate::hostclock::Stopwatch;
use crate::simcore::Time;

/// Dtype+shape signature of one artifact argument, parsed from
/// `manifest.ini` (e.g. `int32:600`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgSig {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl ArgSig {
    #[cfg(feature = "pjrt")]
    fn parse(s: &str) -> Result<ArgSig> {
        let (dtype, dims) =
            s.split_once(':').with_context(|| format!("bad arg sig '{s}'"))?;
        let shape = dims
            .split(',')
            .filter(|d| !d.is_empty())
            .map(|d| d.trim().parse::<usize>().with_context(|| format!("bad dim '{d}'")))
            .collect::<Result<Vec<_>>>()?;
        Ok(ArgSig { dtype: dtype.trim().to_string(), shape })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Which pure-Rust reference kernel serves a catalog entry in the default
/// build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BuiltinKernel {
    Aes600,
    AesBlocks,
    MlpInfer,
    RowSum,
    Blur,
}

enum ArtifactKind {
    Builtin(BuiltinKernel),
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtLoadedExecutable),
}

/// One compiled (or builtin) artifact.
pub struct FunctionArtifact {
    pub name: String,
    pub args: Vec<ArgSig>,
    kind: ArtifactKind,
    pub invocations: std::cell::Cell<u64>,
}

/// The executor: the full catalog, PJRT-compiled or builtin.
pub struct Executor {
    #[cfg(feature = "pjrt")]
    #[allow(dead_code)]
    client: xla::PjRtClient,
    artifacts: BTreeMap<String, FunctionArtifact>,
    pub dir: PathBuf,
}

impl Executor {
    /// Load the function catalog (PJRT with `--features pjrt`, builtin
    /// reference kernels otherwise).
    pub fn load(dir: &Path) -> Result<Executor> {
        #[cfg(feature = "pjrt")]
        fn inner(dir: &Path) -> Result<Executor> {
            Executor::load_pjrt(dir)
        }
        #[cfg(not(feature = "pjrt"))]
        fn inner(dir: &Path) -> Result<Executor> {
            Ok(Executor::builtin(dir))
        }
        inner(dir)
    }

    /// Builtin catalog: same names and shapes as `make artifacts` emits.
    #[cfg(not(feature = "pjrt"))]
    fn builtin(dir: &Path) -> Executor {
        fn entry(name: &str, kernel: BuiltinKernel, sigs: &[(&str, &[usize])]) -> FunctionArtifact {
            FunctionArtifact {
                name: name.to_string(),
                args: sigs
                    .iter()
                    .map(|(d, s)| ArgSig { dtype: d.to_string(), shape: s.to_vec() })
                    .collect(),
                kind: ArtifactKind::Builtin(kernel),
                invocations: std::cell::Cell::new(0),
            }
        }
        let mut artifacts = BTreeMap::new();
        for art in [
            entry("aes600", BuiltinKernel::Aes600, &[("int32", &[600]), ("int32", &[16]), ("int32", &[12])]),
            entry("aes_blocks", BuiltinKernel::AesBlocks, &[("int32", &[256, 16]), ("int32", &[11, 16])]),
            entry("mlp_infer", BuiltinKernel::MlpInfer, &[("float32", &[1, 64])]),
            entry("rowsum", BuiltinKernel::RowSum, &[("float32", &[64, 64])]),
            entry("blur", BuiltinKernel::Blur, &[("float32", &[64, 64])]),
        ] {
            artifacts.insert(art.name.clone(), art);
        }
        Executor { artifacts, dir: dir.to_path_buf() }
    }

    /// Load every entry listed in `<dir>/manifest.ini` onto the PJRT CPU
    /// client.
    #[cfg(feature = "pjrt")]
    fn load_pjrt(dir: &Path) -> Result<Executor> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Ini::load(&dir.join("manifest.ini"))?;
        // Section names are `<name>.artifact` keys in the flattened INI.
        let names: Vec<String> = manifest
            .keys()
            .filter_map(|k| k.strip_suffix(".artifact").map(|s| s.to_string()))
            .collect();
        anyhow::ensure!(!names.is_empty(), "empty manifest in {}", dir.display());
        let mut artifacts = BTreeMap::new();
        for name in names {
            let file = manifest.get(&format!("{name}.artifact")).unwrap();
            let sig = manifest
                .get(&format!("{name}.args"))
                .with_context(|| format!("missing args for {name}"))?;
            let args = sig
                .split(';')
                .filter(|s| !s.is_empty())
                .map(ArgSig::parse)
                .collect::<Result<Vec<_>>>()?;
            let proto = xla::HloModuleProto::from_text_file(dir.join(file).to_str().unwrap())
                .map_err(|e| anyhow::anyhow!("loading {file}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
            artifacts.insert(
                name.clone(),
                FunctionArtifact {
                    name,
                    args,
                    kind: ArtifactKind::Pjrt(exe),
                    invocations: std::cell::Cell::new(0),
                },
            );
        }
        Ok(Executor { client, artifacts, dir: dir.to_path_buf() })
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.artifacts.keys().map(|s| s.as_str())
    }

    pub fn artifact(&self, name: &str) -> Option<&FunctionArtifact> {
        self.artifacts.get(name)
    }

    /// Arity + per-argument element-count validation against the catalog
    /// signature (shared by both execution backends).
    fn checked(&self, name: &str, lens: &[usize]) -> Result<&FunctionArtifact> {
        let art =
            self.artifacts.get(name).with_context(|| format!("unknown artifact '{name}'"))?;
        anyhow::ensure!(
            lens.len() == art.args.len(),
            "{name}: expected {} args, got {}",
            art.args.len(),
            lens.len()
        );
        for (sig, &len) in art.args.iter().zip(lens) {
            anyhow::ensure!(len == sig.elements(), "{name}: arg size {len} != {:?}", sig.shape);
        }
        Ok(art)
    }

    #[cfg(feature = "pjrt")]
    fn invoke_literals<T: xla::NativeType + xla::ArrayElement>(
        &self,
        name: &str,
        args: &[Vec<T>],
    ) -> Result<Vec<T>> {
        let art = self.artifacts.get(name).unwrap();
        let ArtifactKind::Pjrt(exe) = &art.kind else {
            anyhow::bail!("{name}: not a PJRT artifact")
        };
        let mut literals = Vec::with_capacity(args.len());
        for (sig, data) in art.args.iter().zip(args) {
            let lit = xla::Literal::vec1(data);
            let lit = if sig.shape.len() > 1 {
                let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?
            } else {
                lit
            };
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("tuple: {e:?}"))?;
        out.to_vec::<T>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
    }

    /// Execute an i32-typed artifact with the given flat argument vectors
    /// (shapes from the catalog are applied). Returns the flat i32 output
    /// of the 1-tuple result.
    pub fn invoke_i32(&self, name: &str, args: &[Vec<i32>]) -> Result<Vec<i32>> {
        let lens: Vec<usize> = args.iter().map(|a| a.len()).collect();
        let art = self.checked(name, &lens)?;
        let out = match &art.kind {
            ArtifactKind::Builtin(k) => builtin_i32(*k, name, args)?,
            #[cfg(feature = "pjrt")]
            ArtifactKind::Pjrt(_) => self.invoke_literals::<i32>(name, args)?,
        };
        art.invocations.set(art.invocations.get() + 1);
        Ok(out)
    }

    /// f32 counterpart (mlp_infer / rowsum / blur artifacts).
    pub fn invoke_f32(&self, name: &str, args: &[Vec<f32>]) -> Result<Vec<f32>> {
        let lens: Vec<usize> = args.iter().map(|a| a.len()).collect();
        let art = self.checked(name, &lens)?;
        let out = match &art.kind {
            ArtifactKind::Builtin(k) => builtin_f32(*k, name, args)?,
            #[cfg(feature = "pjrt")]
            ArtifactKind::Pjrt(_) => self.invoke_literals::<f32>(name, args)?,
        };
        art.invocations.set(art.invocations.get() + 1);
        Ok(out)
    }

    /// AES-128-CTR over a 600-byte payload via the `aes600` artifact — the
    /// paper's benchmark function.
    pub fn aes600(&self, plaintext: &[u8; 600], key: &[u8; 16], nonce: &[u8; 12]) -> Result<[u8; 600]> {
        let args = vec![
            plaintext.iter().map(|&b| b as i32).collect(),
            key.iter().map(|&b| b as i32).collect(),
            nonce.iter().map(|&b| b as i32).collect(),
        ];
        let out = self.invoke_i32("aes600", &args)?;
        anyhow::ensure!(out.len() == 600, "aes600 returned {} elements", out.len());
        let mut ct = [0u8; 600];
        for (dst, &v) in ct.iter_mut().zip(&out) {
            anyhow::ensure!((0..=255).contains(&v), "non-byte output {v}");
            *dst = v as u8;
        }
        Ok(ct)
    }
}

fn as_bytes(name: &str, arg: &[i32]) -> Result<Vec<u8>> {
    arg.iter()
        .map(|&v| {
            anyhow::ensure!((0..=255).contains(&v), "{name}: non-byte input {v}");
            Ok(v as u8)
        })
        .collect()
}

fn builtin_i32(k: BuiltinKernel, name: &str, args: &[Vec<i32>]) -> Result<Vec<i32>> {
    match k {
        BuiltinKernel::Aes600 => {
            let pt = as_bytes(name, &args[0])?;
            let key: [u8; 16] = as_bytes(name, &args[1])?.try_into().unwrap();
            let nonce: [u8; 12] = as_bytes(name, &args[2])?.try_into().unwrap();
            let ct = super::rustcrypto_aes_ctr(&pt, &key, &nonce);
            Ok(ct.iter().map(|&b| b as i32).collect())
        }
        BuiltinKernel::AesBlocks => {
            let blocks = as_bytes(name, &args[0])?;
            let rk_flat = as_bytes(name, &args[1])?;
            let mut rks = [[0u8; 16]; 11];
            for (r, rk) in rks.iter_mut().enumerate() {
                rk.copy_from_slice(&rk_flat[16 * r..16 * r + 16]);
            }
            let out = super::fallback::aes_blocks(&blocks, &rks);
            Ok(out.iter().map(|&b| b as i32).collect())
        }
        _ => anyhow::bail!("{name}: float32 artifact — use invoke_f32"),
    }
}

fn builtin_f32(k: BuiltinKernel, name: &str, args: &[Vec<f32>]) -> Result<Vec<f32>> {
    match k {
        BuiltinKernel::MlpInfer => Ok(super::fallback::mlp_infer(&args[0])),
        BuiltinKernel::RowSum => Ok(super::fallback::rowsum(&args[0], 64, 64)),
        BuiltinKernel::Blur => Ok(super::fallback::blur3x3(&args[0], 64, 64)),
        _ => anyhow::bail!("{name}: int32 artifact — use invoke_i32"),
    }
}

/// Result of timing the AES-600B artifact on this machine.
#[derive(Debug, Clone, Copy)]
pub struct Calibration {
    pub p50_ns: Time,
    pub mean_ns: Time,
    pub min_ns: Time,
    pub runs: u32,
}

/// Measure the real per-invocation compute cost of `aes600`. The *median*
/// feeds `ExperimentConfig::function_compute_ns`, so the simulator's
/// function service time is the measured cost of the actual function body
/// (lowered HLO under `pjrt`, the reference kernel otherwise).
pub fn calibrate(exec: &Executor, runs: u32) -> Result<Calibration> {
    let pt = [7u8; 600];
    let key = [1u8; 16];
    let nonce = [2u8; 12];
    // Warmup (first run pays one-time initialization).
    for _ in 0..3 {
        exec.aes600(&pt, &key, &nonce)?;
    }
    // Host-clock measurement through the sanctioned seam: calibration is
    // the one place wall time may feed the simulator's *input* (a cost
    // constant fixed before the run), never its event order.
    let mut samples = Vec::with_capacity(runs as usize);
    for _ in 0..runs {
        let sw = Stopwatch::new();
        exec.aes600(&pt, &key, &nonce)?;
        samples.push(sw.elapsed_ns() as u64);
    }
    samples.sort_unstable();
    let p50 = samples[samples.len() / 2];
    let mean = samples.iter().sum::<u64>() / samples.len() as u64;
    Ok(Calibration { p50_ns: p50.max(1), mean_ns: mean, min_ns: samples[0], runs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{default_artifacts_dir, rustcrypto_aes_ctr};

    fn executor() -> Executor {
        Executor::load(&default_artifacts_dir()).expect("loading executor catalog")
    }

    #[test]
    fn loads_all_catalog_entries() {
        let e = executor();
        let names: Vec<&str> = e.names().collect();
        for expected in ["aes600", "aes_blocks", "mlp_infer", "rowsum", "blur"] {
            assert!(names.contains(&expected), "missing {expected} in {names:?}");
        }
    }

    #[test]
    fn f32_artifacts_execute() {
        let e = executor();
        // rowsum: (64,64) ones → every row sums to 64.
        let out = e.invoke_f32("rowsum", &[vec![1.0f32; 64 * 64]]).unwrap();
        assert_eq!(out.len(), 64);
        assert!(out.iter().all(|&v| (v - 64.0).abs() < 1e-4));
        // blur: constant image stays constant in the interior.
        let img = vec![2.0f32; 64 * 64];
        let b = e.invoke_f32("blur", &[img]).unwrap();
        assert_eq!(b.len(), 64 * 64);
        let center = b[32 * 64 + 32];
        assert!((center - 2.0).abs() < 1e-4, "center {center}");
        let corner = b[0];
        assert!(corner < 1.0, "corner {corner} should be attenuated by zero pad");
        // mlp_infer: finite logits.
        let y = e.invoke_f32("mlp_infer", &[vec![0.5f32; 64]]).unwrap();
        assert_eq!(y.len(), 10);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn aes600_matches_rustcrypto_oracle() {
        // The artifact path must agree with the independent RustCrypto
        // construction.
        let e = executor();
        let mut pt = [0u8; 600];
        for (i, b) in pt.iter_mut().enumerate() {
            *b = (i * 31 % 256) as u8;
        }
        let key = *b"0123456789abcdef";
        let nonce = [9u8; 12];
        let got = e.aes600(&pt, &key, &nonce).unwrap();
        let want = rustcrypto_aes_ctr(&pt, &key, &nonce);
        assert_eq!(got.to_vec(), want);
    }

    #[test]
    fn aes600_roundtrip() {
        let e = executor();
        let pt = [42u8; 600];
        let key = [3u8; 16];
        let nonce = [4u8; 12];
        let ct = e.aes600(&pt, &key, &nonce).unwrap();
        let rt = e.aes600(&ct, &key, &nonce).unwrap();
        assert_eq!(rt, pt);
        assert_ne!(ct, pt);
    }

    #[test]
    fn aes_blocks_executes_with_round_keys() {
        let e = executor();
        let blocks = vec![0i32; 256 * 16];
        let rks = vec![0i32; 11 * 16];
        let out = e.invoke_i32("aes_blocks", &[blocks, rks]).unwrap();
        assert_eq!(out.len(), 256 * 16);
        assert_eq!(&out[..16], &out[16..32], "identical blocks encrypt identically");
        assert_eq!(e.artifact("aes_blocks").unwrap().invocations.get(), 1);
    }

    #[test]
    fn bad_arity_rejected() {
        let e = executor();
        assert!(e.invoke_i32("aes600", &[vec![0; 600]]).is_err());
        assert!(e.invoke_i32("nope", &[]).is_err());
    }

    #[test]
    fn bad_shape_rejected() {
        let e = executor();
        let args = vec![vec![0i32; 599], vec![0; 16], vec![0; 12]];
        assert!(e.invoke_i32("aes600", &args).is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let e = executor();
        // mlp_infer is float32: the i32 entry point must refuse it.
        assert!(e.invoke_i32("mlp_infer", &[vec![0i32; 64]]).is_err());
        assert!(e.invoke_f32("aes600", &[vec![0.0; 600], vec![0.0; 16], vec![0.0; 12]]).is_err());
    }

    #[test]
    fn calibration_is_positive_and_stable() {
        let e = executor();
        let cal = calibrate(&e, 20).unwrap();
        assert!(cal.p50_ns > 0);
        assert!(cal.min_ns <= cal.p50_ns);
        assert!(cal.p50_ns < 1_000_000_000, "AES-600B taking >1s is wrong");
    }
}
