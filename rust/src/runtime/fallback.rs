//! Pure-Rust reference kernels backing the default (hermetic) build of the
//! runtime [`Executor`](super::Executor).
//!
//! The `pjrt` feature executes the AOT-lowered HLO artifacts through the
//! PJRT CPU client; without it these implementations serve the same
//! catalog (`aes600`, `aes_blocks`, `mlp_infer`, `rowsum`, `blur`) with
//! identical shapes and semantics, so every layer above — the real-mode
//! server, calibration, the experiments — runs unchanged offline.
//!
//! `aes600` reuses the RustCrypto oracle in `aes_check`; `aes_blocks`
//! needs AES with *caller-provided round keys* (the Pallas kernel's
//! signature), which no crate exposes, so [`aes128`] carries a compact
//! FIPS-197 implementation validated against RustCrypto and the standard
//! test vectors.

/// Minimal AES-128 core operating on caller-provided round keys.
pub mod aes128 {
    /// FIPS-197 S-box.
    const SBOX: [u8; 256] = [
        0x63, 0x7c, 0x77, 0x7b, 0xf2, 0x6b, 0x6f, 0xc5, 0x30, 0x01, 0x67, 0x2b, 0xfe, 0xd7,
        0xab, 0x76, 0xca, 0x82, 0xc9, 0x7d, 0xfa, 0x59, 0x47, 0xf0, 0xad, 0xd4, 0xa2, 0xaf,
        0x9c, 0xa4, 0x72, 0xc0, 0xb7, 0xfd, 0x93, 0x26, 0x36, 0x3f, 0xf7, 0xcc, 0x34, 0xa5,
        0xe5, 0xf1, 0x71, 0xd8, 0x31, 0x15, 0x04, 0xc7, 0x23, 0xc3, 0x18, 0x96, 0x05, 0x9a,
        0x07, 0x12, 0x80, 0xe2, 0xeb, 0x27, 0xb2, 0x75, 0x09, 0x83, 0x2c, 0x1a, 0x1b, 0x6e,
        0x5a, 0xa0, 0x52, 0x3b, 0xd6, 0xb3, 0x29, 0xe3, 0x2f, 0x84, 0x53, 0xd1, 0x00, 0xed,
        0x20, 0xfc, 0xb1, 0x5b, 0x6a, 0xcb, 0xbe, 0x39, 0x4a, 0x4c, 0x58, 0xcf, 0xd0, 0xef,
        0xaa, 0xfb, 0x43, 0x4d, 0x33, 0x85, 0x45, 0xf9, 0x02, 0x7f, 0x50, 0x3c, 0x9f, 0xa8,
        0x51, 0xa3, 0x40, 0x8f, 0x92, 0x9d, 0x38, 0xf5, 0xbc, 0xb6, 0xda, 0x21, 0x10, 0xff,
        0xf3, 0xd2, 0xcd, 0x0c, 0x13, 0xec, 0x5f, 0x97, 0x44, 0x17, 0xc4, 0xa7, 0x7e, 0x3d,
        0x64, 0x5d, 0x19, 0x73, 0x60, 0x81, 0x4f, 0xdc, 0x22, 0x2a, 0x90, 0x88, 0x46, 0xee,
        0xb8, 0x14, 0xde, 0x5e, 0x0b, 0xdb, 0xe0, 0x32, 0x3a, 0x0a, 0x49, 0x06, 0x24, 0x5c,
        0xc2, 0xd3, 0xac, 0x62, 0x91, 0x95, 0xe4, 0x79, 0xe7, 0xc8, 0x37, 0x6d, 0x8d, 0xd5,
        0x4e, 0xa9, 0x6c, 0x56, 0xf4, 0xea, 0x65, 0x7a, 0xae, 0x08, 0xba, 0x78, 0x25, 0x2e,
        0x1c, 0xa6, 0xb4, 0xc6, 0xe8, 0xdd, 0x74, 0x1f, 0x4b, 0xbd, 0x8b, 0x8a, 0x70, 0x3e,
        0xb5, 0x66, 0x48, 0x03, 0xf6, 0x0e, 0x61, 0x35, 0x57, 0xb9, 0x86, 0xc1, 0x1d, 0x9e,
        0xe1, 0xf8, 0x98, 0x11, 0x69, 0xd9, 0x8e, 0x94, 0x9b, 0x1e, 0x87, 0xe9, 0xce, 0x55,
        0x28, 0xdf, 0x8c, 0xa1, 0x89, 0x0d, 0xbf, 0xe6, 0x42, 0x68, 0x41, 0x99, 0x2d, 0x0f,
        0xb0, 0x54, 0xbb, 0x16,
    ];

    const RCON: [u8; 10] = [0x01, 0x02, 0x04, 0x08, 0x10, 0x20, 0x40, 0x80, 0x1b, 0x36];

    /// Expand a 16-byte key into the 11 round keys (FIPS-197 §5.2).
    pub fn expand_key(key: &[u8; 16]) -> [[u8; 16]; 11] {
        let mut w = [[0u8; 4]; 44];
        for i in 0..4 {
            w[i].copy_from_slice(&key[4 * i..4 * i + 4]);
        }
        for i in 4..44 {
            let mut t = w[i - 1];
            if i % 4 == 0 {
                t = [
                    SBOX[t[1] as usize] ^ RCON[i / 4 - 1],
                    SBOX[t[2] as usize],
                    SBOX[t[3] as usize],
                    SBOX[t[0] as usize],
                ];
            }
            for b in 0..4 {
                w[i][b] = w[i - 4][b] ^ t[b];
            }
        }
        let mut rks = [[0u8; 16]; 11];
        for (r, rk) in rks.iter_mut().enumerate() {
            for c in 0..4 {
                rk[4 * c..4 * c + 4].copy_from_slice(&w[4 * r + c]);
            }
        }
        rks
    }

    #[inline]
    fn xtime(b: u8) -> u8 {
        (b << 1) ^ (0x1b * (b >> 7))
    }

    fn sub_bytes(b: &mut [u8; 16]) {
        for x in b.iter_mut() {
            *x = SBOX[*x as usize];
        }
    }

    // State is column-major: byte (row, col) lives at index 4*col + row.
    fn shift_rows(b: &mut [u8; 16]) {
        let mut out = [0u8; 16];
        for col in 0..4 {
            for row in 0..4 {
                out[4 * col + row] = b[4 * ((col + row) % 4) + row];
            }
        }
        *b = out;
    }

    fn mix_columns(b: &mut [u8; 16]) {
        for col in 0..4 {
            let i = 4 * col;
            let (a0, a1, a2, a3) = (b[i], b[i + 1], b[i + 2], b[i + 3]);
            b[i] = xtime(a0) ^ (xtime(a1) ^ a1) ^ a2 ^ a3;
            b[i + 1] = a0 ^ xtime(a1) ^ (xtime(a2) ^ a2) ^ a3;
            b[i + 2] = a0 ^ a1 ^ xtime(a2) ^ (xtime(a3) ^ a3);
            b[i + 3] = (xtime(a0) ^ a0) ^ a1 ^ a2 ^ xtime(a3);
        }
    }

    fn add_round_key(b: &mut [u8; 16], rk: &[u8; 16]) {
        for k in 0..16 {
            b[k] ^= rk[k];
        }
    }

    /// Encrypt one block in place with pre-expanded round keys.
    pub fn encrypt_block(block: &mut [u8; 16], rks: &[[u8; 16]; 11]) {
        add_round_key(block, &rks[0]);
        for rk in &rks[1..10] {
            sub_bytes(block);
            shift_rows(block);
            mix_columns(block);
            add_round_key(block, rk);
        }
        sub_bytes(block);
        shift_rows(block);
        add_round_key(block, &rks[10]);
    }
}

/// AES-128 ECB over consecutive 16-byte blocks with caller-provided round
/// keys — the `aes_blocks` artifact's contract.
pub fn aes_blocks(blocks: &[u8], round_keys: &[[u8; 16]; 11]) -> Vec<u8> {
    let mut out = Vec::with_capacity(blocks.len());
    for chunk in blocks.chunks(16) {
        let mut b = [0u8; 16];
        b[..chunk.len()].copy_from_slice(chunk);
        aes128::encrypt_block(&mut b, round_keys);
        out.extend_from_slice(&b[..chunk.len()]);
    }
    out
}

/// Two-layer MLP (64 → 32 relu → 10) with fixed pseudo-random weights:
/// shape-faithful stand-in for the `mlp_infer` artifact.
pub fn mlp_infer(x: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len(), 64);
    let mut rng = crate::simcore::Rng::new(0x4d4c_5031); // "MLP1"
    let mut weight = || (rng.next_f64() as f32 - 0.5) * 0.4;
    let mut hidden = [0f32; 32];
    for h in hidden.iter_mut() {
        let mut acc = weight(); // bias
        for &xi in x {
            acc += xi * weight();
        }
        *h = acc.max(0.0); // relu
    }
    let mut logits = vec![0f32; 10];
    for l in logits.iter_mut() {
        let mut acc = weight(); // bias
        for &hi in &hidden {
            acc += hi * weight();
        }
        *l = acc;
    }
    logits
}

/// Row sums of a `rows × cols` matrix.
pub fn rowsum(m: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(m.len(), rows * cols);
    (0..rows).map(|r| m[r * cols..(r + 1) * cols].iter().sum()).collect()
}

/// 3×3 box blur with zero padding over an `h × w` image.
pub fn blur3x3(img: &[f32], h: usize, w: usize) -> Vec<f32> {
    debug_assert_eq!(img.len(), h * w);
    let mut out = vec![0f32; h * w];
    for y in 0..h {
        for x in 0..w {
            let mut acc = 0f32;
            for dy in -1i64..=1 {
                for dx in -1i64..=1 {
                    let (ny, nx) = (y as i64 + dy, x as i64 + dx);
                    if ny >= 0 && ny < h as i64 && nx >= 0 && nx < w as i64 {
                        acc += img[ny as usize * w + nx as usize];
                    }
                }
            }
            out[y * w + x] = acc / 9.0;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use aes::cipher::{BlockEncrypt, KeyInit};
    use aes::Aes128;

    #[test]
    fn fips197_appendix_b_vector() {
        let key: [u8; 16] = [
            0x2b, 0x7e, 0x15, 0x16, 0x28, 0xae, 0xd2, 0xa6, 0xab, 0xf7, 0x15, 0x88, 0x09, 0xcf,
            0x4f, 0x3c,
        ];
        let mut block: [u8; 16] = [
            0x32, 0x43, 0xf6, 0xa8, 0x88, 0x5a, 0x30, 0x8d, 0x31, 0x31, 0x98, 0xa2, 0xe0, 0x37,
            0x07, 0x34,
        ];
        let rks = aes128::expand_key(&key);
        aes128::encrypt_block(&mut block, &rks);
        assert_eq!(
            block,
            [
                0x39, 0x25, 0x84, 0x1d, 0x02, 0xdc, 0x09, 0xfb, 0xdc, 0x11, 0x85, 0x97, 0x19,
                0x6a, 0x0b, 0x32
            ]
        );
    }

    #[test]
    fn matches_rustcrypto_on_many_blocks() {
        // Bit-exact agreement with the completely independent RustCrypto
        // implementation, across several keys and blocks.
        for seed in 0..4u8 {
            let key: [u8; 16] = std::array::from_fn(|i| (i as u8) * 7 + seed * 31 + 1);
            let cipher = Aes128::new(&key.into());
            let rks = aes128::expand_key(&key);
            for b in 0..8u8 {
                let mut mine: [u8; 16] = std::array::from_fn(|i| (i as u8) ^ (b * 17));
                let mut theirs = aes::Block::from(mine);
                aes128::encrypt_block(&mut mine, &rks);
                cipher.encrypt_block(&mut theirs);
                assert_eq!(mine.as_slice(), theirs.as_slice());
            }
        }
    }

    #[test]
    fn aes_blocks_is_deterministic_per_block() {
        let rks = aes128::expand_key(&[0u8; 16]);
        let blocks = vec![0u8; 16 * 4];
        let out = aes_blocks(&blocks, &rks);
        assert_eq!(out.len(), 64);
        assert_eq!(&out[..16], &out[16..32], "identical blocks encrypt identically");
    }

    #[test]
    fn rowsum_and_blur_shapes() {
        let out = rowsum(&vec![1.0; 64 * 64], 64, 64);
        assert_eq!(out.len(), 64);
        assert!(out.iter().all(|&v| (v - 64.0).abs() < 1e-4));
        let b = blur3x3(&vec![2.0; 64 * 64], 64, 64);
        assert_eq!(b.len(), 64 * 64);
        assert!((b[32 * 64 + 32] - 2.0).abs() < 1e-4);
        assert!(b[0] < 1.0, "corner attenuated by zero pad: {}", b[0]);
    }

    #[test]
    fn mlp_is_deterministic_and_finite() {
        let x = vec![0.5f32; 64];
        let a = mlp_infer(&x);
        let b = mlp_infer(&x);
        assert_eq!(a, b);
        assert_eq!(a.len(), 10);
        assert!(a.iter().all(|v| v.is_finite()));
    }
}
