//! Function runtime: loads the catalog and executes function bodies.
//!
//! This is the L2/L3 bridge. With the **`pjrt` feature**, `make artifacts`
//! (Python, build-time only) lowers the JAX/Pallas function catalog to
//! `artifacts/*.hlo.txt`; this module loads the HLO **text** via
//! `HloModuleProto::from_text_file`, compiles it once on the PJRT CPU
//! client, and executes it from the serving hot path. Python never runs at
//! serve time. The **default build** is hermetic: the same catalog is
//! served by pure-Rust reference kernels ([`fallback`]) with identical
//! shapes and AES semantics, so nothing above this layer changes offline.
//!
//! Also here: [`calibrate`], which measures the real compute cost of the
//! AES-600B artifact on this machine and feeds it to the simulator's
//! service-time model, and a cross-check of the JAX/Pallas AES against
//! the independent RustCrypto `aes` implementation.

mod aes_check;
mod executor;
pub mod fallback;

pub use aes_check::rustcrypto_aes_ctr;
pub use executor::{calibrate, ArgSig, Calibration, Executor, FunctionArtifact};

/// Default artifacts directory, relative to the repo root.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    // Honor an override for tests / relocated builds, through the one
    // sanctioned environment seam (it picks *which* catalog loads; it
    // never reaches simulation state).
    if let Some(dir) = crate::hostclock::env_var("JUNCTIOND_ARTIFACTS") {
        return dir.into();
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
