//! Host-kernel cost model (the baseline network/OS path).
//!
//! Models the per-operation costs a request pays when faasd components and
//! functions run as ordinary Linux processes with kernel networking:
//! syscall traps, context switches, hard-IRQ + softirq packet processing,
//! scheduler wakeups, epoll rounds, and the veth/bridge hop into a
//! container network namespace.
//!
//! Costs are sampled, not constant: the kernel path carries *heavy-tailed
//! jitter* (timer interrupts landing mid-request, softirq bursts, scheduler
//! migrations, TLB shootdowns). This jitter is exactly what the paper's
//! P99 numbers measure — Junction's user-space path removes most of it
//! (§5: P99 −63.42% end-to-end, −81% function execution). The tail model
//! is a bounded Pareto on the wakeup/IRQ components (`Rng::heavy_tail`),
//! which matches the qualitative shape of kernel jitter distributions.

use std::rc::Rc;

use crate::config::PlatformConfig;
use crate::simcore::{Rng, Time};

/// Jitter knobs for the kernel path.
#[derive(Debug, Clone)]
pub struct JitterModel {
    /// Pareto shape for wakeup/IRQ tails (lower = heavier tail).
    pub alpha: f64,
    /// Cap in multiples of the mean.
    pub cap: f64,
    /// Fraction of *deterministic* base retained; the rest is the sampled
    /// tail component's mean.
    pub base_fraction: f64,
}

impl Default for JitterModel {
    fn default() -> Self {
        JitterModel { alpha: 1.6, cap: 60.0, base_fraction: 0.7 }
    }
}

/// Precomputed inverse-CDF table for the bounded-Pareto tail: sampling
/// `Q[below(N)]` is distribution-equivalent to `Rng::heavy_tail` but costs
/// an array read instead of `powf`/`ln` — the DES hot path samples this
/// 10–20 times per simulated invocation (§Perf: this table cut the
/// containerd pipeline cost ~2×).
struct TailTable {
    /// Multipliers of the mean, Q((i+0.5)/N) for i in 0..N.
    q: Vec<f64>,
}

const TAIL_TABLE_N: usize = 4096;

impl TailTable {
    fn new(alpha: f64, cap: f64) -> TailTable {
        let norm = alpha / (alpha - 1.0); // mean of the unbounded unit Pareto
        let mut q: Vec<f64> = (0..TAIL_TABLE_N)
            .map(|i| {
                let u = 1.0 - (i as f64 + 0.5) / TAIL_TABLE_N as f64; // (0,1]
                (u.powf(-1.0 / alpha) / norm).min(cap)
            })
            .collect();
        // The clamp at `cap` removes tail mass, so a table normalized by
        // the *unbounded* Pareto mean averages a few percent below 1 and
        // every `tailed(m)` call under-charges its jitter component.
        // Renormalize by the capped table's actual mean so that
        // E[sample] == 1 exactly (entries may then exceed `cap` by the
        // same few percent — the cap bounds the shape, not the mean).
        let mean = q.iter().sum::<f64>() / TAIL_TABLE_N as f64;
        for v in &mut q {
            *v /= mean;
        }
        TailTable { q }
    }

    #[inline]
    fn sample(&self, rng: &mut Rng) -> f64 {
        self.q[rng.below(TAIL_TABLE_N as u64) as usize]
    }
}

/// Sampler for kernel-path costs. One per simulated server; deterministic
/// given its RNG stream.
pub struct KernelCosts {
    p: Rc<PlatformConfig>,
    jitter: JitterModel,
    tail: TailTable,
    rng: Rng,
    /// Keep the sampled scheduling-noise/interference add-ons. Since the
    /// compute fabric models co-location interference *structurally*
    /// (per-core contention, quantum preemption, softirq stealing), the
    /// sampled draws default off — `sched_noise` and
    /// `segment_interference` return 0 — so the tail is never counted
    /// twice. The per-operation heavy-tail jitter (`tailed`) is *not*
    /// residual: it models intra-op kernel variance, and stays on.
    residual_jitter: bool,
    // telemetry
    pub msgs_recv: u64,
    pub msgs_sent: u64,
    pub wakeups: u64,
    pub syscalls: u64,
}

impl KernelCosts {
    pub fn new(platform: Rc<PlatformConfig>, rng: Rng) -> Self {
        let jitter = JitterModel::default();
        KernelCosts {
            tail: TailTable::new(jitter.alpha, jitter.cap),
            jitter,
            rng,
            residual_jitter: platform.residual_jitter != 0,
            p: platform,
            msgs_recv: 0,
            msgs_sent: 0,
            wakeups: 0,
            syscalls: 0,
        }
    }

    pub fn with_jitter(mut self, jitter: JitterModel) -> Self {
        self.tail = TailTable::new(jitter.alpha, jitter.cap);
        self.jitter = jitter;
        self
    }

    /// Sample a value with the configured heavy-tail jitter around `mean`.
    fn tailed(&mut self, mean: Time) -> Time {
        let base = (mean as f64 * self.jitter.base_fraction) as Time;
        let tail_mean = mean as f64 * (1.0 - self.jitter.base_fraction);
        let sampled = tail_mean * self.tail.sample(&mut self.rng);
        base + sampled as Time
    }

    /// CPU cost of receiving one small message in a process: hard IRQ +
    /// softirq, kernel TCP stack traversal, the `epoll_wait`/`read`
    /// syscalls, and the wakeup + context switch to the sleeping task.
    /// (= [`KernelCosts::nic_rx_packet`] + [`KernelCosts::app_recv`] for a
    /// loopback hop that never crosses the physical NIC ring.)
    pub fn recv_msg(&mut self) -> Time {
        self.msgs_recv += 1;
        self.wakeups += 1;
        self.syscalls += 2;
        let irq = self.tailed(self.p.irq_softirq_ns);
        let stack = self.p.kernel_stack_msg_ns;
        let wake = self.tailed(self.p.sched_wakeup_ns) + self.p.context_switch_ns;
        irq + stack + wake + self.p.epoll_round_ns + 2 * self.p.syscall_ns
    }

    /// NIC-level kernel RX work for one packet off the physical ring: hard
    /// IRQ + softirq processing, kernel stack traversal, and the DMA-buffer
    /// → socket-buffer copy (`copy_ns`, sized by the frame). This is the
    /// half of `recv_msg` the netpath drain engine charges per packet; the
    /// consuming process pays [`KernelCosts::app_recv`] separately.
    pub fn nic_rx_packet(&mut self, copy_ns: Time) -> Time {
        self.msgs_recv += 1;
        self.tailed(self.p.irq_softirq_ns) + self.p.kernel_stack_msg_ns + copy_ns
    }

    /// App-side receive after the NIC/socket handoff: futex/epoll wakeup,
    /// context switch into the task, one epoll round and the `read`-class
    /// syscalls. The other half of `recv_msg` (see
    /// [`KernelCosts::nic_rx_packet`]).
    pub fn app_recv(&mut self) -> Time {
        self.wakeups += 1;
        self.syscalls += 2;
        self.tailed(self.p.sched_wakeup_ns)
            + self.p.context_switch_ns
            + self.p.epoll_round_ns
            + 2 * self.p.syscall_ns
    }

    /// CPU cost of sending one small message: `write`/`sendmsg` syscall +
    /// kernel TCP TX path (checksum, qdisc, driver) + ACK processing
    /// amortized onto the sender.
    /// (= [`KernelCosts::app_send`] + [`KernelCosts::nic_tx_packet`] for a
    /// loopback hop that never crosses the physical NIC ring.)
    pub fn send_msg(&mut self) -> Time {
        self.msgs_sent += 1;
        self.syscalls += 1;
        // The eventual ACK costs roughly half a softirq on this side.
        let ack = self.tailed(self.p.irq_softirq_ns / 2);
        self.p.syscall_ns + self.p.kernel_stack_msg_ns + ack
    }

    /// App-side half of a kernel send: the `write`/`sendmsg` syscall trap
    /// into the socket layer. The NIC-level TX work is charged separately
    /// by the netpath TX flush engine (see [`KernelCosts::nic_tx_packet`]);
    /// together the two halves cost what the single-shot
    /// [`KernelCosts::send_msg`] charges, so splitting the hop does not
    /// double-charge the kernel path.
    pub fn app_send(&mut self) -> Time {
        self.syscalls += 1;
        self.p.syscall_ns
    }

    /// NIC-level kernel TX work for one packet onto the physical ring:
    /// qdisc + driver TX path (`kernel_stack_msg_ns`), the socket-buffer →
    /// DMA-buffer copy (`copy_ns`, sized by the frame), and the eventual
    /// ACK softirq amortized onto this side. The other half of `send_msg`
    /// (see [`KernelCosts::app_send`]).
    pub fn nic_tx_packet(&mut self, copy_ns: Time) -> Time {
        self.msgs_sent += 1;
        self.p.kernel_stack_msg_ns + self.tailed(self.p.irq_softirq_ns / 2) + copy_ns
    }

    /// Extra cost when the message crosses a veth/bridge pair into a
    /// container netns (the "software switching" the paper calls out).
    pub fn veth_hop(&mut self) -> Time {
        self.p.veth_hop_ns
    }

    /// Cost of `n` syscalls from the function body (read input, write
    /// output, clock_gettime, mmap churn...). Each traps into the kernel.
    pub fn syscalls(&mut self, n: u32) -> Time {
        self.syscalls += n as u64;
        n as Time * self.p.syscall_ns
    }

    /// Per-request process-scheduling overhead inside a busy instance:
    /// timer ticks + involuntary context switches. **Residual jitter**:
    /// returns 0 unless `PlatformConfig::residual_jitter` re-enables the
    /// sampled draw — the compute fabric now produces this effect
    /// structurally (quantum preemption + migration cost).
    pub fn sched_noise(&mut self) -> Time {
        if !self.residual_jitter {
            return 0;
        }
        self.tailed(self.p.context_switch_ns)
    }

    /// Rare kernel-path interference burst charged per CPU segment: CFS
    /// throttling, a GC pause landing on a timer tick, an IRQ storm, or a
    /// cross-core migration. **Residual jitter**: returns 0 unless
    /// `PlatformConfig::residual_jitter` re-enables the sampled draw.
    /// With the compute fabric on (the default), this interference
    /// *emerges* from per-core contention — softirq work stealing tenant
    /// cores, timeslice waits, cross-core migrations — instead of being
    /// sampled, so the knob defaults off to avoid double counting
    /// (unit-tested below).
    pub fn segment_interference(&mut self) -> Time {
        if !self.residual_jitter {
            return 0;
        }
        if self.rng.below(10_000) < self.p.kernel_interference_prob_bp {
            self.rng.range(self.p.kernel_interference_min_ns, self.p.kernel_interference_max_ns)
        } else {
            0
        }
    }

    /// One-way wire latency between the client and worker machines.
    pub fn wire(&self) -> Time {
        self.p.wire_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::MICROS;

    fn costs() -> KernelCosts {
        KernelCosts::new(Rc::new(PlatformConfig::default()), Rng::new(7))
    }

    #[test]
    fn recv_is_more_expensive_than_send() {
        let mut c = costs();
        let recv: Time = (0..1000).map(|_| c.recv_msg()).sum();
        let send: Time = (0..1000).map(|_| c.send_msg()).sum();
        assert!(recv > send, "recv {recv} send {send}");
    }

    #[test]
    fn costs_have_heavy_tail() {
        let mut c = costs();
        let samples: Vec<Time> = (0..20_000).map(|_| c.recv_msg()).collect();
        let mean = samples.iter().sum::<u64>() as f64 / samples.len() as f64;
        let max = *samples.iter().max().unwrap() as f64;
        // The tail must reach several times the mean (kernel jitter), but
        // stay bounded (Pareto cap).
        assert!(max > mean * 2.0, "max {max} mean {mean}");
        assert!(max < mean * 100.0, "runaway tail: max {max} mean {mean}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = KernelCosts::new(Rc::new(PlatformConfig::default()), Rng::new(3));
        let mut b = KernelCosts::new(Rc::new(PlatformConfig::default()), Rng::new(3));
        for _ in 0..100 {
            assert_eq!(a.recv_msg(), b.recv_msg());
            assert_eq!(a.send_msg(), b.send_msg());
        }
    }

    #[test]
    fn nic_rx_plus_app_recv_splits_recv_msg() {
        // The two halves charged by the netpath must together cost what
        // the single-shot recv_msg charges (same components, zero copy),
        // so splitting the hop does not double-charge the kernel path.
        let mut whole = costs();
        let mut split = costs();
        let n = 5000;
        let a: Time = (0..n).map(|_| whole.recv_msg()).sum();
        let b: Time = (0..n).map(|_| split.nic_rx_packet(0) + split.app_recv()).sum();
        let (am, bm) = (a as f64 / n as f64, b as f64 / n as f64);
        assert!((am - bm).abs() / am < 0.05, "means diverge: {am} vs {bm}");
        assert_eq!(whole.msgs_recv, split.msgs_recv);
        assert_eq!(whole.wakeups, split.wakeups);
        assert_eq!(whole.syscalls, split.syscalls);
    }

    #[test]
    fn nic_tx_plus_app_send_splits_send_msg() {
        // The two halves charged by the netpath TX engine must together
        // cost what the single-shot send_msg charges (same components,
        // zero copy), so splitting the hop does not double-charge.
        let mut whole = costs();
        let mut split = costs();
        let n = 5000;
        let a: Time = (0..n).map(|_| whole.send_msg()).sum();
        let b: Time = (0..n).map(|_| split.app_send() + split.nic_tx_packet(0)).sum();
        let (am, bm) = (a as f64 / n as f64, b as f64 / n as f64);
        assert!((am - bm).abs() / am < 0.05, "means diverge: {am} vs {bm}");
        assert_eq!(whole.msgs_sent, split.msgs_sent);
        assert_eq!(whole.syscalls, split.syscalls);
    }

    #[test]
    fn tailed_mean_matches_nominal() {
        // Regression: the tail table used to normalize by the *unbounded*
        // Pareto mean while samples were clamped at `cap`, so `tailed(m)`
        // systematically under-charged the jitter component and every
        // kernel-path cost sat a few percent below its nominal mean.
        let mut c = costs();
        let mean = 10 * MICROS;
        let n = 200_000u64;
        let total: Time = (0..n).map(|_| c.tailed(mean)).sum();
        let emp = total as f64 / n as f64;
        let err = (emp - mean as f64).abs() / mean as f64;
        assert!(err < 0.03, "tailed({mean}) empirical mean {emp:.0} (err {err:.4})");
    }

    #[test]
    fn residual_jitter_defaults_off_no_double_count() {
        // With the structural fabric on (platform default), the sampled
        // interference add-ons must charge nothing — the tail comes from
        // per-core contention only.
        let mut c = costs();
        for _ in 0..10_000 {
            assert_eq!(c.sched_noise(), 0);
            assert_eq!(c.segment_interference(), 0);
        }
        // Re-enabling the knob restores the seed's sampled draws.
        let p = PlatformConfig { residual_jitter: 1, ..PlatformConfig::default() };
        let mut c = KernelCosts::new(Rc::new(p), Rng::new(7));
        let noise: Time = (0..10_000).map(|_| c.sched_noise()).sum();
        let bursts = (0..10_000).filter(|_| c.segment_interference() > 0).count();
        assert!(noise > 0, "residual sched_noise must sample when enabled");
        assert!(bursts > 0, "residual interference must sample when enabled");
    }

    #[test]
    fn syscall_batches_accumulate_telemetry() {
        let mut c = costs();
        let t = c.syscalls(80);
        assert_eq!(t, 80 * PlatformConfig::default().syscall_ns);
        assert_eq!(c.syscalls, 80);
    }

    #[test]
    fn recv_cost_is_microseconds_scale() {
        let mut c = costs();
        let v = c.recv_msg();
        assert!(v > 5 * MICROS && v < 600 * MICROS, "recv {v}ns out of plausible range");
    }
}
