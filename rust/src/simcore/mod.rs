//! Deterministic discrete-event simulation engine.
//!
//! This is the substrate that replaces the paper's two-machine testbed: a
//! single-threaded virtual-time simulator with a two-tier event scheduler
//! (slab-backed hierarchical timer wheel + far-timer heap — see
//! `engine`/`wheel`/`slab`), closure-based events with O(1) cancellation,
//! a per-core compute fabric (`fabric` — run queues, priority classes,
//! preemption quanta; the seed's flat FIFO pool survives in `resource`
//! as the differential reference), and a deterministic xorshift RNG (no
//! external `rand` crate — the registry is offline). The `shard` module
//! scales this across OS threads: one engine per shard, synchronized
//! conservatively on the cross-shard wire delay (DESIGN.md §3j), with
//! each individual shard still single-threaded by construction.
//!
//! Time is in **virtual nanoseconds** (`Time = u64`); helper constructors
//! exist for µs/ms. Determinism is a hard invariant: two runs with the
//! same seed and inputs produce identical event orders (ties broken by
//! insertion sequence number), and the wheel engine fires the exact
//! sequence the seed's reference heap does — the differential property
//! test in `engine` and the cross-engine experiment checks in
//! `tests/integration.rs` pin this.

mod engine;
mod fabric;
mod proptest;
mod resource;
mod rng;
mod shard;
mod slab;
mod wheel;

pub use engine::{
    default_engine, default_tiebreak, set_default_engine, set_default_tiebreak, tick_train,
    EngineKind, EngineStats, Sim, TieBreak, Time, TimerHandle, MICROS, MILLIS, SECONDS,
};
pub use shard::{
    run_sharded, EndpointId, NetHandle, ShardId, ShardNet, ShardPlan, ShardRun, ShardStats,
    ShardWorld, WireMsg,
};
pub use fabric::{
    default_fabric, set_default_fabric, ComputeFabric, FabricConfig, FabricKind, FabricStats,
    JobClass, SliceEnd, SliceObs, SliceRecord,
};
pub use proptest::{forall, Gen};
pub use rng::Rng;
