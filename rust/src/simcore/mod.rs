//! Deterministic discrete-event simulation engine.
//!
//! This is the substrate that replaces the paper's two-machine testbed: a
//! single-threaded virtual-time simulator with an event heap, closure-based
//! events, FIFO multi-server resources (used to model CPU cores and NIC
//! queues), and a deterministic xorshift RNG (no external `rand` crate —
//! the registry is offline).
//!
//! Time is in **virtual nanoseconds** (`Time = u64`); helper constructors
//! exist for µs/ms. Determinism is a hard invariant: two runs with the same
//! seed and inputs produce identical event orders (ties broken by insertion
//! sequence number), which the property tests in this module verify.

mod engine;
mod proptest;
mod resource;
mod rng;

pub use engine::{Sim, Time, MICROS, MILLIS, SECONDS};
pub use proptest::{forall, Gen};
pub use resource::CorePool;
pub use rng::Rng;
