//! Conservative parallel shard runner: one [`Sim`] engine per OS thread,
//! synchronized on the cross-shard wire delay.
//!
//! The model (DESIGN.md §3j) is classic conservative parallel DES
//! (Chandy–Misra–Bryant with a barrier-epoch transport, no speculative
//! rollback): virtual time is cut into epochs of length `wire_ns` — the
//! minimum cross-shard wire latency, i.e. the lookahead — and every shard
//! runs its local engine to the epoch barrier, exchanges one batch per
//! peer (an empty batch is the null message that keeps the protocol
//! deadlock-free), injects what it received, and advances. A message
//! staged at local time `t` in epoch `k` delivers at `t + wire_ns`, which
//! is *strictly after* barrier `k` (because `t > k·wire_ns` once the
//! epoch is underway), so a shard that has already run to the barrier can
//! never receive an event in its past — no rollback machinery needed.
//!
//! Determinism is the hard invariant. The cross-shard merge tie-break is
//! stated once: inbound messages are injected in
//! `(deliver_at, src endpoint, per-source seq)` order. Sequence numbers
//! are per *source endpoint* (not per shard), so the merged order — and
//! therefore every engine sequence number a delivery receives — is
//! independent of how endpoints are packed onto shards. Combined with the
//! model discipline that a handler touches only its own endpoint's state,
//! this makes results byte-identical across `--shards {1,2,4,8}`, across
//! repeated same-seed runs, and between [`run_sharded`]'s serial and
//! threaded transports.
//!
//! Idle phases (pool TTL drains, prewarm gaps) would otherwise cost one
//! barrier per `wire_ns` of virtual time; instead each batch carries a
//! horizon hint (earliest pending event, scanned only once a shard's
//! pending count is small) and all shards — computing from identical
//! exchanged data — jump to the same next interesting epoch, or agree to
//! terminate when no shard has events or staged messages left.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};

use super::engine::{default_engine, default_tiebreak, EngineKind, Sim, TieBreak, Time};
use crate::hostclock::Stopwatch;

/// Index of a shard (an engine + OS thread) inside a [`ShardPlan`].
pub type ShardId = usize;

/// Index of a model endpoint (gateway, worker, rack…). Endpoints are the
/// unit of placement: the plan maps each endpoint to a shard, and wire
/// messages address endpoints, never shards.
pub type EndpointId = u32;

/// Pending events above this count skip the horizon scan and report
/// [`Horizon::Busy`]: the scan is O(slab capacity), so it only runs once
/// a shard has mostly drained and epoch fast-forwarding can actually win.
const IDLE_SCAN_MAX: usize = 4096;

/// Bounded depth of each inter-shard channel. Lockstep barriers keep at
/// most two batches in flight per directed link (a peer can run at most
/// one epoch ahead before blocking on our batch).
const LINK_DEPTH: usize = 4;

/// A timestamped payload crossing a shard boundary. `seq` is assigned by
/// the sending [`ShardNet`] per source endpoint; `(deliver_at, src, seq)`
/// is the total merge order at the receiver.
#[derive(Debug, Clone, Copy)]
pub struct WireMsg<P> {
    pub deliver_at: Time,
    pub src: EndpointId,
    pub dst: EndpointId,
    pub seq: u64,
    pub payload: P,
}

/// Per-shard staging buffer for outbound wire messages — the only
/// lint-sanctioned cross-shard mutation seam (`[state.ShardNet]`,
/// `wire` domain, in `xtask/shard_map.toml`). Model code holds it as
/// `Rc<RefCell<ShardNet<P>>>` and calls [`ShardNet::send`]; the runner
/// drains it at every epoch barrier.
pub struct ShardNet<P> {
    wire_ns: Time,
    staged: Vec<WireMsg<P>>,
    /// Per-source-endpoint sequence counters. Keyed by endpoint — not by
    /// shard — so the merge order is invariant under repacking endpoints
    /// onto fewer or more shards.
    seqs: BTreeMap<EndpointId, u64>,
}

impl<P> ShardNet<P> {
    fn new(wire_ns: Time) -> Self {
        assert!(wire_ns > 0, "shard wire latency (the lookahead) must be positive");
        ShardNet { wire_ns, staged: Vec::new(), seqs: BTreeMap::new() }
    }

    /// The cross-shard wire latency — also the conservative lookahead
    /// window, so every send is visible to the receiver one epoch later.
    pub fn wire_ns(&self) -> Time {
        self.wire_ns
    }

    /// Stage `payload` from endpoint `src` to endpoint `dst`, delivering
    /// one wire delay after `now`.
    pub fn send(&mut self, now: Time, src: EndpointId, dst: EndpointId, payload: P) {
        let seq = self.seqs.entry(src).or_insert(0);
        let msg = WireMsg { deliver_at: now + self.wire_ns, src, dst, seq: *seq, payload };
        *seq += 1;
        self.staged.push(msg);
    }

    /// Messages staged since the last barrier (runner-side drain).
    fn take_staged(&mut self) -> Vec<WireMsg<P>> {
        std::mem::take(&mut self.staged)
    }
}

/// Shared handle to a shard's outbound wire seam.
pub type NetHandle<P> = Rc<RefCell<ShardNet<P>>>;

/// The static sharding plan: how many shards, which endpoint lives where,
/// and the wire latency that doubles as the lookahead window.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub shards: usize,
    /// `endpoint_shard[endpoint] = shard`. Every entry must be `< shards`.
    pub endpoint_shard: Vec<ShardId>,
    /// Cross-shard wire latency in virtual ns; also the epoch length.
    pub wire_ns: Time,
}

impl ShardPlan {
    fn validate(&self) {
        assert!(self.shards > 0, "a plan needs at least one shard");
        assert!(self.wire_ns > 0, "lookahead (wire_ns) must be positive");
        for (ep, &s) in self.endpoint_shard.iter().enumerate() {
            assert!(s < self.shards, "endpoint {ep} mapped to out-of-range shard {s}");
        }
    }
}

/// One shard's model world. Built by its builder *on the shard's own
/// thread* (worlds hold `Rc` state and never cross threads; only
/// [`WireMsg`] payloads and the final report do).
pub trait ShardWorld<P>: Sized {
    /// Aggregate the runner hands back to the caller; crosses threads.
    type Report: Send;

    /// Schedule the arrival of `msg` into this shard's engine. Called at
    /// an epoch barrier with `sim.now() <= msg.deliver_at`;
    /// implementations schedule via `sim.at(msg.deliver_at, ..)`.
    fn inject(&mut self, sim: &mut Sim, msg: WireMsg<P>);

    /// Consume the world once the cluster-wide schedule has drained.
    fn finish(self, sim: &mut Sim) -> Self::Report;
}

/// Host-side telemetry for one shard's run: barrier counts, message
/// traffic, and wall clock (via the `hostclock` seam — no raw host
/// clock reads in sim modules). Never feeds deterministic output.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardStats {
    pub shard: usize,
    /// Epoch barriers this shard actually executed.
    pub epochs: u64,
    /// Epoch indices fast-forwarded over while globally idle.
    pub skipped_epochs: u64,
    /// Wire messages sent to other shards (self-deliveries excluded).
    pub msgs_out: u64,
    /// Wire messages injected locally (incl. self-deliveries).
    pub msgs_in: u64,
    /// Empty per-peer batches sent — pure null messages.
    pub null_batches: u64,
    /// Engine events fired on this shard.
    pub events_fired: u64,
    /// Past-time schedule clamps on this shard (0 when the lookahead
    /// invariant holds — injection never targets the past).
    pub past_schedules: u64,
    /// Host wall clock spent on this shard's lane, via [`Stopwatch`].
    pub wall_secs: f64,
}

/// Result of [`run_sharded`]: per-shard reports and host telemetry, both
/// indexed by shard id.
pub struct ShardRun<R> {
    pub reports: Vec<R>,
    pub stats: Vec<ShardStats>,
}

/// What a shard knows about its own future at a barrier, shipped inside
/// every batch so all shards can agree on the next epoch.
#[derive(Debug, Clone, Copy)]
enum Horizon {
    /// Too many pending events to scan — step one epoch at a time.
    Busy,
    /// Earliest pending event (before injecting this barrier's arrivals).
    NextAt(Time),
    /// No pending events at all.
    Drained,
}

/// One per-peer exchange unit. An empty `msgs` vector is the null
/// message; `horizon`/`min_staged` drive epoch fast-forward and
/// termination.
struct EpochBatch<P> {
    epoch: u64,
    msgs: Vec<WireMsg<P>>,
    horizon: Horizon,
    min_staged: Option<Time>,
}

/// Virtual time of barrier `k`: epochs are `((k)·L, (k+1)·L]`.
fn barrier_at(k: u64, wire_ns: Time) -> Time {
    (k + 1).saturating_mul(wire_ns)
}

/// Decide the epoch after barrier `k` from the exchanged hints — a pure
/// function of data every shard holds identically, so all shards jump
/// together. `None` terminates the run: no shard has a pending event and
/// nothing was staged, so no event can ever exist again.
fn next_epoch(
    k: u64,
    wire_ns: Time,
    horizons: &[Horizon],
    staged_mins: &[Option<Time>],
) -> Option<u64> {
    let mut busy = false;
    let mut t_min: Option<Time> = None;
    let mut fold = |t: Time| t_min = Some(t_min.map_or(t, |m| m.min(t)));
    for h in horizons {
        match *h {
            Horizon::Busy => busy = true,
            Horizon::NextAt(t) => fold(t),
            Horizon::Drained => {}
        }
    }
    for t in staged_mins.iter().flatten() {
        fold(*t);
    }
    if busy {
        return Some(k + 1);
    }
    // Earliest future event is at t: the first epoch whose barrier
    // reaches it is (t-1)/L (barrier of epoch e is (e+1)·L ≥ t).
    t_min.map(|t| (k + 1).max(t.saturating_sub(1) / wire_ns))
}

/// The per-shard execution state: engine + net + world + telemetry.
struct Lane<P, W> {
    id: ShardId,
    shards: usize,
    endpoint_shard: Vec<ShardId>,
    sim: Sim,
    net: Rc<RefCell<ShardNet<P>>>,
    world: W,
    stats: ShardStats,
    sw: Stopwatch,
}

/// What [`Lane::advance`] hands the transport at a barrier.
struct StagePack<P> {
    /// Staged messages partitioned by destination shard (own slot =
    /// self-deliveries).
    outgoing: Vec<Vec<WireMsg<P>>>,
    horizon: Horizon,
    min_staged: Option<Time>,
}

impl<P, W: ShardWorld<P>> Lane<P, W> {
    fn new<B>(id: ShardId, plan: &ShardPlan, sched: SchedPolicy, builder: B) -> Self
    where
        B: FnOnce(&mut Sim, NetHandle<P>) -> W,
    {
        let sw = Stopwatch::new();
        let mut sim = Sim::with_engine_and_tiebreak(sched.0, sched.1);
        let net = Rc::new(RefCell::new(ShardNet::new(plan.wire_ns)));
        let world = builder(&mut sim, net.clone());
        Lane {
            id,
            shards: plan.shards,
            endpoint_shard: plan.endpoint_shard.clone(),
            sim,
            net,
            world,
            stats: ShardStats { shard: id, ..Default::default() },
            sw,
        }
    }

    /// Run to the barrier, drain the wire seam, and summarize the local
    /// horizon. Messages are partitioned by destination shard.
    fn advance(&mut self, barrier: Time) -> StagePack<P> {
        self.stats.epochs += 1;
        self.sim.run_until(barrier);
        let staged = self.net.borrow_mut().take_staged();
        let mut outgoing: Vec<Vec<WireMsg<P>>> = (0..self.shards).map(|_| Vec::new()).collect();
        let mut min_staged: Option<Time> = None;
        for m in staged {
            debug_assert!(
                m.deliver_at > barrier,
                "wire message must deliver strictly after its send barrier \
                 (deliver_at {} <= barrier {})",
                m.deliver_at,
                barrier
            );
            min_staged = Some(min_staged.map_or(m.deliver_at, |t| t.min(m.deliver_at)));
            let dst = self.endpoint_shard[m.dst as usize];
            if dst != self.id {
                self.stats.msgs_out += 1;
            }
            outgoing[dst].push(m);
        }
        for (j, q) in outgoing.iter().enumerate() {
            if j != self.id && q.is_empty() {
                self.stats.null_batches += 1;
            }
        }
        let pending = self.sim.pending();
        let horizon = if pending == 0 {
            Horizon::Drained
        } else if pending <= IDLE_SCAN_MAX {
            match self.sim.next_event_time() {
                Some(t) => Horizon::NextAt(t),
                None => Horizon::Drained,
            }
        } else {
            Horizon::Busy
        };
        StagePack { outgoing, horizon, min_staged }
    }

    /// Inject this barrier's arrivals in the canonical merge order.
    fn absorb(&mut self, mut inbound: Vec<WireMsg<P>>) {
        // tie-break: the cross-shard merge order, stated once — sort by
        // (deliver_at, src endpoint, per-source seq); equal-time arrivals
        // then receive engine seqs in this order on every shard count.
        inbound.sort_by_key(|m| (m.deliver_at, m.src, m.seq));
        self.stats.msgs_in += inbound.len() as u64;
        for m in inbound {
            debug_assert!(m.deliver_at >= self.sim.now(), "injection must never target the past");
            self.world.inject(&mut self.sim, m);
        }
    }

    /// Consume the lane once the global schedule has drained.
    fn finish(self) -> (W::Report, ShardStats) {
        let Lane { mut sim, world, mut stats, sw, net, .. } = self;
        debug_assert_eq!(sim.pending(), 0, "termination protocol left pending events");
        debug_assert!(net.borrow().staged.is_empty(), "termination protocol left staged messages");
        stats.events_fired = sim.events_fired();
        stats.past_schedules = sim.past_schedules();
        stats.wall_secs = sw.elapsed_secs();
        (world.finish(&mut sim), stats)
    }
}

/// Run `builders[s]` on shard `s` under `plan`, serially on the calling
/// thread (`threaded == false`) or with one OS thread per shard. Both
/// transports execute the identical barrier protocol, so their outputs
/// are byte-identical — the `--shards 1` vs serial differential test
/// rides on this.
///
/// Each shard's [`Sim`] is built with the *calling* thread's default
/// engine kind and tie-break policy (captured before spawning), so
/// differential engine-swap tests work unchanged across threads.
pub fn run_sharded<P, W, B>(
    plan: &ShardPlan,
    builders: Vec<B>,
    threaded: bool,
) -> ShardRun<W::Report>
where
    P: Send + 'static,
    W: ShardWorld<P>,
    B: FnOnce(&mut Sim, NetHandle<P>) -> W + Send,
{
    plan.validate();
    assert_eq!(builders.len(), plan.shards, "one builder per shard");
    // Captured on the calling thread: shard threads have fresh
    // thread-locals, and differential tests flip the defaults here.
    let sched: SchedPolicy = (default_engine(), default_tiebreak());
    if threaded && plan.shards > 1 {
        run_threaded(plan, builders, sched)
    } else {
        run_serial(plan, builders, sched)
    }
}

/// Engine kind + tie-break policy every shard engine is built with.
type SchedPolicy = (EngineKind, TieBreak);

fn run_serial<P, W, B>(
    plan: &ShardPlan,
    builders: Vec<B>,
    sched: SchedPolicy,
) -> ShardRun<W::Report>
where
    P: Send + 'static,
    W: ShardWorld<P>,
    B: FnOnce(&mut Sim, NetHandle<P>) -> W,
{
    let n = plan.shards;
    let mut lanes: Vec<Lane<P, W>> =
        builders.into_iter().enumerate().map(|(i, b)| Lane::new(i, plan, sched, b)).collect();
    let mut k = 0u64;
    loop {
        let barrier = barrier_at(k, plan.wire_ns);
        let mut inboxes: Vec<Vec<WireMsg<P>>> = (0..n).map(|_| Vec::new()).collect();
        let mut horizons = Vec::with_capacity(n);
        let mut mins = Vec::with_capacity(n);
        for lane in lanes.iter_mut() {
            let pack = lane.advance(barrier);
            for (j, msgs) in pack.outgoing.into_iter().enumerate() {
                inboxes[j].extend(msgs);
            }
            horizons.push(pack.horizon);
            mins.push(pack.min_staged);
        }
        for (lane, inbox) in lanes.iter_mut().zip(inboxes) {
            lane.absorb(inbox);
        }
        match next_epoch(k, plan.wire_ns, &horizons, &mins) {
            Some(k2) => {
                for lane in lanes.iter_mut() {
                    lane.stats.skipped_epochs += k2 - k - 1;
                }
                k = k2;
            }
            None => break,
        }
    }
    let mut reports = Vec::with_capacity(n);
    let mut stats = Vec::with_capacity(n);
    for lane in lanes {
        let (r, s) = lane.finish();
        reports.push(r);
        stats.push(s);
    }
    ShardRun { reports, stats }
}

/// Sender / receiver half of one directed inter-shard link (`None` on
/// the self-diagonal of the mesh).
type LinkTx<P> = Option<SyncSender<EpochBatch<P>>>;
type LinkRx<P> = Option<Receiver<EpochBatch<P>>>;

fn run_threaded<P, W, B>(
    plan: &ShardPlan,
    builders: Vec<B>,
    sched: SchedPolicy,
) -> ShardRun<W::Report>
where
    P: Send + 'static,
    W: ShardWorld<P>,
    B: FnOnce(&mut Sim, NetHandle<P>) -> W + Send,
{
    let n = plan.shards;
    // Full mesh of bounded links: txs[i][j] sends i → j (None on the
    // diagonal), rxs[i][j] receives j's batches at i.
    let mut txs: Vec<Vec<LinkTx<P>>> = (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
    let mut rxs: Vec<Vec<LinkRx<P>>> = (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
    for i in 0..n {
        for j in 0..n {
            if i != j {
                let (tx, rx) = sync_channel(LINK_DEPTH);
                txs[i][j] = Some(tx);
                rxs[j][i] = Some(rx);
            }
        }
    }
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(n);
        let lanes = builders.into_iter().zip(txs).zip(rxs).enumerate();
        for (i, ((builder, tx_row), rx_row)) in lanes {
            let plan = plan.clone();
            let handle = std::thread::Builder::new()
                .name(format!("shard-{i}"))
                .spawn_scoped(scope, move || {
                    let mut lane: Lane<P, W> = Lane::new(i, &plan, sched, builder);
                    let mut k = 0u64;
                    loop {
                        let barrier = barrier_at(k, plan.wire_ns);
                        let mut pack = lane.advance(barrier);
                        // Send every peer its batch (null if empty) before
                        // receiving anything: with all shards doing the
                        // same, every recv below is eventually satisfied.
                        for (j, tx) in tx_row.iter().enumerate() {
                            if let Some(tx) = tx {
                                let msgs = std::mem::take(&mut pack.outgoing[j]);
                                let batch = EpochBatch {
                                    epoch: k,
                                    msgs,
                                    horizon: pack.horizon,
                                    min_staged: pack.min_staged,
                                };
                                tx.send(batch).expect("peer shard hung up mid-epoch");
                            }
                        }
                        let mut inbound = std::mem::take(&mut pack.outgoing[i]);
                        let mut horizons = vec![pack.horizon];
                        let mut mins = vec![pack.min_staged];
                        for rx in rx_row.iter().flatten() {
                            let b = rx.recv().expect("peer shard hung up mid-epoch");
                            debug_assert_eq!(b.epoch, k, "shards diverged on the epoch schedule");
                            inbound.extend(b.msgs);
                            horizons.push(b.horizon);
                            mins.push(b.min_staged);
                        }
                        lane.absorb(inbound);
                        match next_epoch(k, plan.wire_ns, &horizons, &mins) {
                            Some(k2) => {
                                lane.stats.skipped_epochs += k2 - k - 1;
                                k = k2;
                            }
                            None => break,
                        }
                    }
                    lane.finish()
                })
                .expect("spawn shard thread");
            handles.push(handle);
        }
        let mut reports = Vec::with_capacity(n);
        let mut stats = Vec::with_capacity(n);
        for handle in handles {
            let (r, s) = handle.join().expect("shard thread panicked");
            reports.push(r);
            stats.push(s);
        }
        ShardRun { reports, stats }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const WIRE: Time = 1_000;

    /// Endpoints playing ping-pong across the wire: each delivery is
    /// recorded and bounced back (sourced from the *receiving* endpoint,
    /// `msg.dst`) with `payload + 1` until a limit — so the per-endpoint
    /// seq streams are identical however endpoints are packed onto
    /// shards.
    struct PingWorld {
        net: NetHandle<u64>,
        log: Rc<RefCell<Vec<(Time, u64)>>>,
        limit: u64,
    }

    impl ShardWorld<u64> for PingWorld {
        type Report = Vec<(Time, u64)>;

        fn inject(&mut self, sim: &mut Sim, msg: WireMsg<u64>) {
            let net = self.net.clone();
            let log = self.log.clone();
            let limit = self.limit;
            sim.at(msg.deliver_at, move |sim| {
                log.borrow_mut().push((sim.now(), msg.payload));
                if msg.payload < limit {
                    net.borrow_mut().send(sim.now(), msg.dst, msg.src, msg.payload + 1);
                }
            });
        }

        fn finish(self, _sim: &mut Sim) -> Self::Report {
            self.log.borrow().clone()
        }
    }

    type PingRun = (Vec<Vec<(Time, u64)>>, Vec<ShardStats>);

    fn pingpong(shards: usize, threaded: bool, start_at: Time) -> PingRun {
        let plan = ShardPlan {
            shards,
            endpoint_shard: (0..2).map(|e| e % shards).collect(),
            wire_ns: WIRE,
        };
        let builders: Vec<_> = (0..shards)
            .map(|s| {
                move |sim: &mut Sim, net: NetHandle<u64>| {
                    let world = PingWorld {
                        net: net.clone(),
                        log: Rc::new(RefCell::new(Vec::new())),
                        limit: 8,
                    };
                    if s == 0 {
                        let net = net.clone();
                        sim.at(start_at, move |sim| {
                            net.borrow_mut().send(sim.now(), 0, 1, 0);
                        });
                    }
                    world
                }
            })
            .collect();
        let run = run_sharded(&plan, builders, threaded);
        (run.reports, run.stats)
    }

    #[test]
    fn pingpong_terminates_and_counts_both_sides() {
        let (reports, stats) = pingpong(2, false, 5);
        // Endpoint 1 sees payloads 0,2,4,6,8; endpoint 0 sees 1,3,5,7.
        assert_eq!(reports[1].iter().map(|&(_, p)| p).collect::<Vec<_>>(), vec![0, 2, 4, 6, 8]);
        assert_eq!(reports[0].iter().map(|&(_, p)| p).collect::<Vec<_>>(), vec![1, 3, 5, 7]);
        // Each hop is exactly one wire delay after the previous.
        assert_eq!(reports[1][0].0, 5 + WIRE);
        assert_eq!(reports[0][0].0, 5 + 2 * WIRE);
        let s: &ShardStats = &stats[0];
        assert!(s.msgs_out == 5 && stats[1].msgs_out == 4, "cross-shard traffic miscounted");
        assert_eq!(s.past_schedules, 0, "lookahead must keep injections out of the past");
    }

    #[test]
    fn serial_and_threaded_transports_are_identical() {
        for shards in [1, 2] {
            let (serial, _) = pingpong(shards, false, 5);
            let (threaded, _) = pingpong(shards, true, 5);
            assert_eq!(serial, threaded, "transports diverged at {shards} shards");
        }
    }

    #[test]
    fn results_are_invariant_across_shard_counts() {
        let (one, _) = pingpong(1, false, 5);
        let (two, _) = pingpong(2, true, 5);
        let flat1: Vec<_> = {
            let mut v: Vec<(Time, u64)> = one.concat();
            v.sort_unstable();
            v
        };
        let mut flat2: Vec<(Time, u64)> = two.concat();
        flat2.sort_unstable();
        assert_eq!(flat1, flat2, "delivery schedule must not depend on shard count");
    }

    #[test]
    fn idle_gaps_fast_forward_instead_of_stepping() {
        // First event sits 10_000 epochs out; the horizon exchange must
        // jump there, not walk every barrier.
        let far = 10_000 * WIRE + 3;
        let (reports, stats) = pingpong(2, true, far);
        assert_eq!(reports[1][0].0, far + WIRE);
        let walked: u64 = stats.iter().map(|s| s.epochs).max().unwrap();
        assert!(walked < 64, "expected epoch fast-forward, walked {walked} barriers");
        assert!(stats[0].skipped_epochs > 9_000, "skip counter missed the idle gap");
    }
}
