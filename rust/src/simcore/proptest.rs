//! Minimal property-testing harness (the `proptest` crate is unavailable
//! offline, so we carry the 5% of it this repo needs: seeded generators,
//! many cases, and a reproduction line on failure).
//!
//! Usage:
//! ```
//! use junctiond_repro::simcore::{forall, Gen};
//! forall("addition commutes", 200, |g| {
//!     let (a, b) = (g.u64(0, 1000), g.u64(0, 1000));
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//! On failure the panic message includes the case seed so the exact case
//! replays with `Gen::from_seed`.

use super::rng::Rng;

/// Per-case generator handed to the property closure.
pub struct Gen {
    rng: Rng,
    seed: u64,
}

impl Gen {
    pub fn from_seed(seed: u64) -> Self {
        Gen { rng: Rng::new(seed), seed }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Uniform u64 in [lo, hi] inclusive.
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as u64, hi as u64) as usize
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len() - 1)]
    }

    /// A vector of `n` draws.
    pub fn vec_u64(&mut self, n: usize, lo: u64, hi: u64) -> Vec<u64> {
        (0..n).map(|_| self.u64(lo, hi)).collect()
    }

    /// Sub-generator with an independent stream.
    pub fn fork(&mut self) -> Gen {
        let seed = self.rng.next_u64() | 1;
        Gen::from_seed(seed)
    }
}

/// Run `prop` for `cases` independently-seeded cases. Panics (with the
/// failing seed) on the first failure.
pub fn forall<F: FnMut(&mut Gen)>(name: &str, cases: u32, mut prop: F) {
    // Derive case seeds from the property name so adding properties doesn't
    // shift the cases of existing ones.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    let mut master = Rng::new(h | 1);
    for case in 0..cases {
        let seed = master.next_u64() | 1;
        let mut g = Gen::from_seed(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (replay with Gen::from_seed({seed})): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("u64 bounds respected", 100, |g| {
            let v = g.u64(10, 20);
            assert!((10..=20).contains(&v));
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        forall("always fails", 10, |_| panic!("boom"));
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first = Vec::new();
        forall("collect", 20, |g| first.push(g.u64(0, 1_000_000)));
        let mut second = Vec::new();
        forall("collect", 20, |g| second.push(g.u64(0, 1_000_000)));
        assert_eq!(first, second);
    }

    #[test]
    fn choose_hits_all_elements() {
        let items = [1, 2, 3, 4];
        let mut seen = [false; 4];
        forall("choose coverage", 200, |g| {
            seen[*g.choose(&[0usize, 1, 2, 3])] = true;
            let _ = items;
        });
        assert!(seen.iter().all(|&b| b));
    }
}
