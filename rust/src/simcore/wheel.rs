//! Hierarchical timer wheel with a far-timer heap tier.
//!
//! The wheel is the engine's near-future ordering structure: 6 levels of
//! 64 slots, level `l` slots spanning `2^(6l)` ns, so the wheel covers
//! deltas up to `2^36` ns (≈ 69 virtual seconds) with O(1) insert and
//! amortized-O(1) fire. Timers past the horizon overflow into a binary
//! heap (`far`) — the calendar tier for idle-TTL/keep-alive-scale timers —
//! and are popped straight from there when they become the global minimum
//! (they never migrate back into the wheel).
//!
//! **Level rule** (the tokio/kernel scheme): an entry for time `t` lives
//! at the level of the highest bit in `now ^ t`. This caps the forward
//! slot distance at 63 per level and guarantees cascades always move
//! entries strictly downward (progress), because once `now` enters a
//! bucket's window, `now ^ t` has no bits at or above that level.
//!
//! **Determinism**: level-0 slots are 1 ns wide, so a level-0 bucket holds
//! exactly one timestamp. When the wheel advances into it, the bucket is
//! sorted once by [`EventKey`] `(time, seq)` and drained front-to-back;
//! events scheduled *during* the drain at the same instant carry larger
//! `seq` and append in order, so ties always fire in schedule order —
//! bit-identical to the reference heap (property-tested in `engine.rs`).
//!
//! **Cancellation** is lazy here: [`super::slab::EventSlab`] bumps the
//! slot generation and the stale `(key, idx, gen)` copy is skipped when it
//! surfaces. A cancelled timer is never sifted through a heap — skipping
//! it costs one comparison, which is what makes cancel-heavy (retransmit)
//! workloads cheap.
//!
//! **Zero-alloc steady state**: buckets, the cascade scratch buffer and
//! the far heap all retain capacity across fires, so a steady schedule/
//! fire/cancel workload performs no heap allocation inside the engine.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::engine::Time;
use super::slab::EventKey;

const SLOT_BITS: usize = 6;
const SLOTS: usize = 1 << SLOT_BITS; // 64
const SLOT_MASK: u64 = (SLOTS as u64) - 1;
/// Number of wheel levels; deltas with a differing bit at or above
/// `SLOT_BITS * LEVELS` (= 36) go to the far tier.
const LEVELS: usize = 6;

/// One `(key, idx, gen)` reference into the event slab. Copied freely
/// between tiers; the slab's generation check is the source of truth.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub(crate) struct WheelEntry {
    pub key: EventKey,
    pub idx: u32,
    pub gen: u32,
}

struct LevelSlots {
    /// Bit `s` set ⇔ `buckets[s]` is non-empty.
    occupied: u64,
    buckets: Vec<Vec<WheelEntry>>,
}

impl LevelSlots {
    fn new() -> Self {
        LevelSlots { occupied: 0, buckets: (0..SLOTS).map(|_| Vec::new()).collect() }
    }
}

/// The level-0 bucket currently being drained: sorted by key, entries
/// `[cursor..]` still pending. All its entries share one timestamp
/// (`TimerWheel::now`), so same-instant events scheduled mid-drain append
/// in `seq` order and the vector stays sorted.
struct Active {
    slot: usize,
    cursor: usize,
}

pub(crate) struct TimerWheel {
    levels: Vec<LevelSlots>,
    far: BinaryHeap<Reverse<WheelEntry>>,
    /// Wheel-internal clock: the last bucket window start processed.
    /// Invariant: `now` never exceeds any pending wheel entry's time, and
    /// never exceeds the engine's clock.
    now: Time,
    /// Entries resident in wheel buckets (including stale/cancelled ones).
    wheel_len: usize,
    active: Option<Active>,
    /// Reusable cascade buffer (swapped with the bucket being cascaded).
    scratch: Vec<WheelEntry>,
}

impl TimerWheel {
    pub fn new() -> Self {
        TimerWheel {
            levels: (0..LEVELS).map(|_| LevelSlots::new()).collect(),
            far: BinaryHeap::new(),
            now: 0,
            wheel_len: 0,
            active: None,
            scratch: Vec::new(),
        }
    }

    /// Entries parked in the far (heap) tier (tests/diagnostics; the
    /// engine's live count comes from the slab).
    #[cfg(test)]
    pub fn far_len(&self) -> usize {
        self.far.len()
    }

    /// Insert a slab reference. `outer_now` is the engine clock, used to
    /// re-anchor the wheel whenever it is empty. The assignment must not
    /// be a `max`: draining a *stale* (cancelled) tail can leave the
    /// wheel's internal `now` ahead of the engine clock (the engine only
    /// advances on live fires), and a later valid insert below that
    /// stranded anchor would be filed into the wheel's past — cascading
    /// upward unboundedly. An empty wheel has no entries constraining
    /// `now`, so snapping straight to the engine clock is always safe
    /// (far-tier entries are popped by exact key and don't care).
    pub fn insert(&mut self, key: EventKey, idx: u32, gen: u32, outer_now: Time) {
        if self.wheel_len == 0 && self.active.is_none() {
            self.now = outer_now;
        }
        // Invariant (upheld by the engine's clamp-and-count plus the
        // empty-wheel re-anchor above): no insert targets the wheel's
        // past. No silent clamp here — a violation must fail loudly, not
        // quietly mis-order events.
        debug_assert!(key.time >= self.now, "insert into the wheel's past");
        let masked = key.time ^ self.now;
        let e = WheelEntry { key, idx, gen };
        if (masked >> (SLOT_BITS * LEVELS)) != 0 {
            self.far.push(Reverse(e));
        } else {
            self.insert_wheel(e);
        }
    }

    fn insert_wheel(&mut self, e: WheelEntry) {
        let t = e.key.time;
        let masked = t ^ self.now;
        let level = if masked == 0 {
            0
        } else {
            (63 - masked.leading_zeros() as usize) / SLOT_BITS
        };
        debug_assert!(level < LEVELS);
        let shift = level * SLOT_BITS;
        let slot = ((t >> shift) & SLOT_MASK) as usize;
        // Mid-drain insert into the bucket currently being drained (an
        // event firing at `now` scheduled another at the same instant):
        // the drained prefix `[..cursor]` is spent, and the pending tail
        // `[cursor..]` is sorted — place the entry by key so that
        // non-ascending tie-break tokens keep parity with the reference
        // heap. Under the default ascending policy the fresh token
        // exceeds every pending one, so this stays the plain append it
        // has always been (bit-identical behaviour).
        let mid_drain = match &self.active {
            Some(a) if level == 0 && a.slot == slot => Some(a.cursor),
            _ => None,
        };
        let bucket = &mut self.levels[level].buckets[slot];
        if let Some(cursor) = mid_drain {
            debug_assert_eq!(t, self.now);
            let pos = cursor + bucket[cursor..].partition_point(|x| *x < e);
            bucket.insert(pos, e);
        } else {
            bucket.push(e);
        }
        self.levels[level].occupied |= 1u64 << slot;
        self.wheel_len += 1;
    }

    /// Earliest occupied bucket as `(level, slot, window_start)`, scanning
    /// the occupancy bitmaps (one rotate + trailing_zeros per level). Ties
    /// on `window_start` prefer the *higher* level, so coarser buckets
    /// cascade before an equal-time level-0 bucket activates — required
    /// for seq-order ties across levels.
    fn earliest_bucket(&self) -> Option<(usize, usize, Time)> {
        let mut best: Option<(usize, usize, Time)> = None;
        for level in (0..LEVELS).rev() {
            let occ = self.levels[level].occupied;
            if occ == 0 {
                continue;
            }
            let shift = level * SLOT_BITS;
            let pos = ((self.now >> shift) & SLOT_MASK) as u32;
            let k = occ.rotate_right(pos).trailing_zeros() as u64;
            let slot = (((pos as u64) + k) & SLOT_MASK) as usize;
            let start = ((self.now >> shift) + k) << shift;
            match best {
                Some((_, _, bstart)) if bstart <= start => {}
                _ => best = Some((level, slot, start)),
            }
        }
        best
    }

    /// Redistribute a level-`l` bucket into lower levels. The bucket's
    /// window start is ≤ every entry inside; entering it pins `now` to the
    /// window, after which every entry's `now ^ t` falls below this level.
    fn cascade(&mut self, level: usize, slot: usize, start: Time) {
        debug_assert!(level > 0);
        self.now = self.now.max(start);
        self.levels[level].occupied &= !(1u64 << slot);
        let mut tmp = std::mem::take(&mut self.scratch);
        debug_assert!(tmp.is_empty());
        std::mem::swap(&mut tmp, &mut self.levels[level].buckets[slot]);
        self.wheel_len -= tmp.len();
        for e in tmp.drain(..) {
            debug_assert!(e.key.time >= self.now);
            self.insert_wheel(e);
        }
        // Swap capacities back: both the bucket and the scratch buffer
        // keep their allocations for the next cascade.
        self.scratch = tmp;
    }

    /// Pop the globally-earliest entry if its time is ≤ `until`; `None`
    /// when the structure is empty or the earliest entry is later. The
    /// caller (engine) validates the reference against the slab and skips
    /// stale (cancelled/rescheduled) pops.
    pub fn pop_at_or_before(&mut self, until: Time) -> Option<(EventKey, u32, u32)> {
        loop {
            // Drain the active level-0 bucket first (all entries at `now`).
            if let Some(a) = &self.active {
                let bucket = &self.levels[0].buckets[a.slot];
                if a.cursor < bucket.len() {
                    let e = bucket[a.cursor];
                    debug_assert_eq!(e.key.time, self.now);
                    // The far tier can hold an equal-time, smaller-seq key.
                    if let Some(&Reverse(f)) = self.far.peek() {
                        if f.key < e.key {
                            if f.key.time > until {
                                return None;
                            }
                            self.far.pop();
                            return Some((f.key, f.idx, f.gen));
                        }
                    }
                    if e.key.time > until {
                        return None;
                    }
                    self.active.as_mut().unwrap().cursor += 1;
                    self.wheel_len -= 1;
                    return Some((e.key, e.idx, e.gen));
                }
                // Exhausted: retire the bucket (keeps its capacity).
                let slot = a.slot;
                self.levels[0].buckets[slot].clear();
                self.levels[0].occupied &= !(1u64 << slot);
                self.active = None;
            }
            match self.earliest_bucket() {
                None => {
                    // Far tier only.
                    let &Reverse(f) = self.far.peek()?;
                    if f.key.time > until {
                        return None;
                    }
                    self.far.pop();
                    return Some((f.key, f.idx, f.gen));
                }
                Some((level, slot, start)) => {
                    if let Some(&Reverse(f)) = self.far.peek() {
                        if f.key.time < start {
                            if f.key.time > until {
                                return None;
                            }
                            self.far.pop();
                            return Some((f.key, f.idx, f.gen));
                        }
                    }
                    if start > until {
                        return None;
                    }
                    if level == 0 {
                        self.now = start;
                        self.levels[0].buckets[slot].sort_unstable();
                        self.active = Some(Active { slot, cursor: 0 });
                    } else {
                        self.cascade(level, slot, start);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(time: Time, seq: u64) -> EventKey {
        EventKey { time, seq }
    }

    /// Drive a wheel directly (no slab): insert raw refs, pop everything.
    fn drain(w: &mut TimerWheel) -> Vec<EventKey> {
        let mut out = Vec::new();
        while let Some((k, _, _)) = w.pop_at_or_before(Time::MAX) {
            out.push(k);
        }
        out
    }

    #[test]
    fn fires_in_key_order_across_levels() {
        let mut w = TimerWheel::new();
        // Deltas spanning every level plus the far tier.
        let times = [
            3u64,
            63,
            64,
            4_095,
            4_096,
            262_143,
            262_144,
            1 << 24,
            1 << 30,
            (1 << 36) + 17, // far tier
            5,
            1 << 35,
        ];
        for (i, &t) in times.iter().enumerate() {
            w.insert(key(t, i as u64), i as u32, 0, 0);
        }
        let fired = drain(&mut w);
        let mut expect: Vec<EventKey> =
            times.iter().enumerate().map(|(i, &t)| key(t, i as u64)).collect();
        expect.sort();
        assert_eq!(fired, expect);
    }

    #[test]
    fn same_time_entries_fire_in_seq_order_even_across_tiers() {
        let mut w = TimerWheel::new();
        // Same timestamp reached three ways: direct level-0 insert later,
        // a level-2 insert that cascades down, and a far-tier insert.
        let t = (1 << 36) + 1000;
        w.insert(key(t, 0), 0, 0, 0); // far at insert time (now=0)
        w.insert(key(500, 1), 1, 0, 0);
        // Fire the 500 event so `now` advances; then t is wheel-range.
        let (k, _, _) = w.pop_at_or_before(Time::MAX).unwrap();
        assert_eq!(k, key(500, 1));
        w.insert(key(t, 2), 2, 0, 500);
        w.insert(key(t, 3), 3, 0, 500);
        let fired = drain(&mut w);
        assert_eq!(fired, vec![key(t, 0), key(t, 2), key(t, 3)]);
    }

    #[test]
    fn pop_respects_until_and_leaves_later_entries() {
        let mut w = TimerWheel::new();
        w.insert(key(10, 0), 0, 0, 0);
        w.insert(key(20, 1), 1, 0, 0);
        w.insert(key(30, 2), 2, 0, 0);
        assert_eq!(w.pop_at_or_before(20).unwrap().0, key(10, 0));
        assert_eq!(w.pop_at_or_before(20).unwrap().0, key(20, 1));
        assert!(w.pop_at_or_before(20).is_none(), "30 is beyond the horizon");
        assert_eq!(w.pop_at_or_before(Time::MAX).unwrap().0, key(30, 2));
        assert!(w.pop_at_or_before(Time::MAX).is_none());
    }

    #[test]
    fn rotation_boundary_entries_do_not_alias_the_current_slot() {
        // now = 63, t = 64: same level-0 slot index modulo 64, but the
        // xor rule sends it to level 1 and cascades it back correctly.
        let mut w = TimerWheel::new();
        w.insert(key(63, 0), 0, 0, 0);
        assert_eq!(w.pop_at_or_before(Time::MAX).unwrap().0, key(63, 0));
        w.insert(key(64, 1), 1, 0, 63);
        w.insert(key(127, 2), 2, 0, 63);
        assert_eq!(w.pop_at_or_before(Time::MAX).unwrap().0, key(64, 1));
        assert_eq!(w.pop_at_or_before(Time::MAX).unwrap().0, key(127, 2));
    }

    /// Regression: draining a stale tail leaves the wheel's `now` ahead
    /// of the engine clock; the next insert (valid by the engine clock)
    /// must re-anchor downward instead of being filed into the wheel's
    /// past (which cascaded upward until `levels[6]` indexed out of
    /// bounds).
    #[test]
    fn reanchor_resets_now_when_wheel_drains_ahead_of_engine_clock() {
        let mut w = TimerWheel::new();
        w.insert(key(100, 0), 0, 0, 0);
        // Drain (in the engine this would be a cancelled entry: the wheel
        // advances to its bucket, the engine clock does not).
        assert_eq!(w.pop_at_or_before(Time::MAX).unwrap().0, key(100, 0));
        // Engine clock is only at 50; schedule for 60.
        w.insert(key(60, 1), 1, 0, 50);
        assert_eq!(w.pop_at_or_before(Time::MAX).unwrap().0, key(60, 1));
        assert!(w.pop_at_or_before(Time::MAX).is_none());
    }

    #[test]
    fn empty_wheel_reanchors_to_outer_clock_after_far_pops() {
        let mut w = TimerWheel::new();
        let far_t = (1u64 << 36) + 5;
        w.insert(key(far_t, 0), 0, 0, 0);
        assert_eq!(w.pop_at_or_before(Time::MAX).unwrap().0, key(far_t, 0));
        // The wheel's `now` never advanced; a near insert (relative to the
        // outer clock) must still land in the wheel, not the far heap.
        w.insert(key(far_t + 100, 1), 1, 0, far_t);
        assert_eq!(w.far_len(), 0, "near timer leaked to the far tier");
        assert_eq!(w.pop_at_or_before(Time::MAX).unwrap().0, key(far_t + 100, 1));
    }

    #[test]
    fn insert_during_active_drain_at_same_instant_stays_ordered() {
        let mut w = TimerWheel::new();
        w.insert(key(100, 0), 0, 0, 0);
        w.insert(key(100, 1), 1, 0, 0);
        let (k0, _, _) = w.pop_at_or_before(Time::MAX).unwrap();
        assert_eq!(k0, key(100, 0));
        // Mid-drain append at the same instant with a larger seq.
        w.insert(key(100, 2), 2, 0, 100);
        assert_eq!(w.pop_at_or_before(Time::MAX).unwrap().0, key(100, 1));
        assert_eq!(w.pop_at_or_before(Time::MAX).unwrap().0, key(100, 2));
        assert!(w.pop_at_or_before(Time::MAX).is_none());
    }

    #[test]
    fn mid_drain_insert_with_smaller_token_fires_before_pending_tail() {
        // Non-ascending tie-break policies hand out tokens *below* the
        // pending tail's; the mid-drain insert must place them by key,
        // not append (which was only correct for ascending seq).
        let mut w = TimerWheel::new();
        w.insert(key(100, 10), 0, 0, 0);
        w.insert(key(100, 20), 1, 0, 0);
        w.insert(key(100, 40), 2, 0, 0);
        assert_eq!(w.pop_at_or_before(Time::MAX).unwrap().0, key(100, 10));
        // Smaller than both pending tokens → next out.
        w.insert(key(100, 5), 3, 0, 100);
        // Between the two pending tokens → fires between them.
        w.insert(key(100, 30), 4, 0, 100);
        assert_eq!(w.pop_at_or_before(Time::MAX).unwrap().0, key(100, 5));
        assert_eq!(w.pop_at_or_before(Time::MAX).unwrap().0, key(100, 20));
        assert_eq!(w.pop_at_or_before(Time::MAX).unwrap().0, key(100, 30));
        assert_eq!(w.pop_at_or_before(Time::MAX).unwrap().0, key(100, 40));
        assert!(w.pop_at_or_before(Time::MAX).is_none());
    }

    #[test]
    fn randomized_wheel_matches_sorted_order() {
        let mut rng = crate::simcore::Rng::new(0xF00D);
        for _ in 0..20 {
            let mut w = TimerWheel::new();
            let mut keys = Vec::new();
            let mut now = 0u64;
            let mut seq = 0u64;
            // Interleave inserts and pops to advance the wheel clock.
            for _ in 0..400 {
                if rng.below(4) == 0 && !keys.is_empty() {
                    // Pop one: must be the minimum of what's pending.
                    keys.sort();
                    let expect: EventKey = keys.remove(0);
                    let (got, _, _) = w.pop_at_or_before(Time::MAX).unwrap();
                    assert_eq!(got, expect);
                    now = got.time;
                } else {
                    // Mix near, mid, far deltas.
                    let delta = match rng.below(4) {
                        0 => rng.below(64),
                        1 => rng.below(1 << 12),
                        2 => rng.below(1 << 30),
                        _ => rng.below(1 << 40),
                    };
                    let k = key(now + delta, seq);
                    seq += 1;
                    w.insert(k, 0, 0, now);
                    keys.push(k);
                }
            }
            keys.sort();
            let rest = drain(&mut w);
            assert_eq!(rest, keys);
        }
    }
}
