//! Seed-shaped reference FIFO pool state.
//!
//! This is the *retained reference implementation* of the seed's
//! `CorePool`: a flat multi-server resource with one shared FIFO run
//! queue and run-to-completion jobs. Production code no longer uses it —
//! the compute model is [`super::fabric::ComputeFabric`], which gives
//! every core its own timeline (run queues, priority classes, a
//! preemption quantum, pinning and stealing) so that scheduling
//! interference *emerges* from per-core contention instead of being
//! sampled from a noise distribution.
//!
//! The reference survives for the same reason the seed event heap
//! survived the PR 3 engine rebuild: `FabricKind::ReferenceFifo` runs the
//! pipeline on this exact seed algorithm, and a differential property
//! test plus E5/E11 table-equality checks pin that the fabric with
//! quantum = ∞, stealing off, and a single class reproduces these FIFO
//! timings bit-for-bit. Two seed bugs are deliberately preserved here
//! (and fixed in the fabric): `reserve` only lowers the core count, so a
//! mid-flight reservation takes effect only after the queue drains; and
//! busy time is charged at admission, so utilization sampled mid-run can
//! exceed 1.0.
//!
//! State transitions only — the event scheduling (and the closure
//! plumbing that goes with it) lives in `fabric.rs` so both engines share
//! one code path for timers.

use std::collections::VecDeque;

use super::engine::{Sim, Time};

pub(crate) type JobFn = Box<dyn FnOnce(&mut Sim)>;

pub(crate) struct RefJob {
    pub duration: Time,
    pub done: JobFn,
}

/// The seed `CorePool`'s fields, verbatim.
pub(crate) struct RefState {
    pub cores: usize,
    pub busy: usize,
    pub queue: VecDeque<RefJob>,
    // Telemetry.
    pub busy_ns: u64,
    pub max_queue: usize,
    pub jobs_run: u64,
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
}

impl RefState {
    pub fn new(cores: usize) -> Self {
        assert!(cores > 0, "a core pool needs at least one core");
        RefState {
            cores,
            busy: 0,
            queue: VecDeque::new(),
            busy_ns: 0,
            max_queue: 0,
            jobs_run: 0,
            jobs_submitted: 0,
            jobs_completed: 0,
        }
    }

    /// Seed admission: take a core if one is free, else queue FIFO.
    /// Returns the job back when it should start now (the caller schedules
    /// its completion).
    pub fn admit(&mut self, job: RefJob) -> Option<RefJob> {
        self.jobs_submitted += 1;
        if self.busy < self.cores {
            self.busy += 1;
            self.jobs_run += 1;
            Some(job)
        } else {
            self.queue.push_back(job);
            let qlen = self.queue.len();
            if qlen > self.max_queue {
                self.max_queue = qlen;
            }
            None
        }
    }

    /// Seed release: pop the next queued job (keeping the core) or free
    /// the core. Preserves the seed's reserve-under-load behavior: the
    /// queue keeps refilling even while `busy > cores` after a mid-flight
    /// `reserve` lowered the count.
    pub fn release_one(&mut self) -> Option<RefJob> {
        self.jobs_completed += 1;
        match self.queue.pop_front() {
            Some(job) => {
                self.jobs_run += 1;
                Some(job)
            }
            None => {
                self.busy -= 1;
                None
            }
        }
    }
}
