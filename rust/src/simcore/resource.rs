//! FIFO multi-server resources for the simulator.
//!
//! `CorePool` models a set of CPU cores with a shared FIFO run queue:
//! callers request `duration` of core time; when a core frees up, the next
//! queued job runs to completion for its duration. Run-to-completion at the
//! *step* granularity is the right fidelity for this paper's µs-scale
//! per-hop costs (see DESIGN.md §2): preemption effects are modeled by the
//! `junction::Scheduler` above this layer, which slices its jobs into
//! quantum-sized steps before they reach the pool.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use super::engine::{Sim, Time};

type JobFn = Box<dyn FnOnce(&mut Sim)>;

struct Job {
    duration: Time,
    done: JobFn,
}

struct PoolInner {
    cores: usize,
    busy: usize,
    queue: VecDeque<Job>,
    // Telemetry.
    busy_ns: u64,
    max_queue: usize,
    jobs_run: u64,
}

/// A pool of identical cores with a shared FIFO queue.
///
/// Cloning is cheap (`Rc`); all clones refer to the same pool.
#[derive(Clone)]
pub struct CorePool {
    inner: Rc<RefCell<PoolInner>>,
}

impl CorePool {
    pub fn new(cores: usize) -> Self {
        assert!(cores > 0, "a core pool needs at least one core");
        CorePool {
            inner: Rc::new(RefCell::new(PoolInner {
                cores,
                busy: 0,
                queue: VecDeque::new(),
                busy_ns: 0,
                max_queue: 0,
                jobs_run: 0,
            })),
        }
    }

    /// Number of cores in the pool.
    pub fn cores(&self) -> usize {
        self.inner.borrow().cores
    }

    /// Cores currently running a job.
    pub fn busy(&self) -> usize {
        self.inner.borrow().busy
    }

    /// Jobs waiting for a core.
    pub fn queued(&self) -> usize {
        self.inner.borrow().queue.len()
    }

    /// High-water mark of the run queue (saturation telemetry).
    pub fn max_queue(&self) -> usize {
        self.inner.borrow().max_queue
    }

    /// Total core-busy nanoseconds accumulated (utilization telemetry).
    pub fn busy_ns(&self) -> u64 {
        self.inner.borrow().busy_ns
    }

    pub fn jobs_run(&self) -> u64 {
        self.inner.borrow().jobs_run
    }

    /// Reserve `n` cores permanently (e.g. a dedicated polling core). The
    /// reserved cores never run queued jobs.
    pub fn reserve(&self, n: usize) {
        let mut p = self.inner.borrow_mut();
        assert!(n < p.cores, "cannot reserve all {} cores", p.cores);
        p.cores -= n;
    }

    /// Run `done` after holding a core for `duration`. If all cores are
    /// busy the job queues FIFO; queueing delay emerges from the event
    /// order, which is how saturation shows up in the latency figures.
    pub fn run<F: FnOnce(&mut Sim) + 'static>(&self, sim: &mut Sim, duration: Time, done: F) {
        let mut p = self.inner.borrow_mut();
        if p.busy < p.cores {
            p.busy += 1;
            p.jobs_run += 1;
            drop(p);
            self.finish_later(sim, duration, Box::new(done));
        } else {
            p.queue.push_back(Job { duration, done: Box::new(done) });
            let qlen = p.queue.len();
            if qlen > p.max_queue {
                p.max_queue = qlen;
            }
        }
    }

    fn finish_later(&self, sim: &mut Sim, duration: Time, done: JobFn) {
        let pool = self.clone();
        {
            let mut p = pool.inner.borrow_mut();
            p.busy_ns += duration;
        }
        sim.after(duration, move |sim| {
            done(sim);
            pool.release_one(sim);
        });
    }

    fn release_one(&self, sim: &mut Sim) {
        let next = {
            let mut p = self.inner.borrow_mut();
            match p.queue.pop_front() {
                Some(job) => {
                    p.jobs_run += 1;
                    Some(job)
                }
                None => {
                    p.busy -= 1;
                    None
                }
            }
        };
        if let Some(job) = next {
            self.finish_later(sim, job.duration, job.done);
        }
    }

    /// Utilization in [0,1] over `elapsed` virtual time.
    pub fn utilization(&self, elapsed: Time) -> f64 {
        if elapsed == 0 {
            return 0.0;
        }
        let p = self.inner.borrow();
        p.busy_ns as f64 / (elapsed as f64 * p.cores as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn single_core_serializes() {
        let mut sim = Sim::new();
        let pool = CorePool::new(1);
        let log = Rc::new(RefCell::new(Vec::new()));
        for _ in 0..3 {
            let log = log.clone();
            pool.run(&mut sim, 10, move |s| log.borrow_mut().push(s.now()));
        }
        sim.run_to_completion();
        assert_eq!(*log.borrow(), vec![10, 20, 30]);
    }

    #[test]
    fn multi_core_runs_in_parallel() {
        let mut sim = Sim::new();
        let pool = CorePool::new(3);
        let log = Rc::new(RefCell::new(Vec::new()));
        for _ in 0..3 {
            let log = log.clone();
            pool.run(&mut sim, 10, move |s| log.borrow_mut().push(s.now()));
        }
        sim.run_to_completion();
        assert_eq!(*log.borrow(), vec![10, 10, 10]);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut sim = Sim::new();
        let pool = CorePool::new(1);
        let log = Rc::new(RefCell::new(Vec::new()));
        for i in 0..5u32 {
            let log = log.clone();
            pool.run(&mut sim, 7, move |_| log.borrow_mut().push(i));
        }
        sim.run_to_completion();
        assert_eq!(*log.borrow(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn utilization_accounts_busy_time() {
        let mut sim = Sim::new();
        let pool = CorePool::new(2);
        for _ in 0..4 {
            pool.run(&mut sim, 50, |_| {});
        }
        sim.run_to_completion();
        // 4 jobs × 50ns on 2 cores → 100ns wall, utilization 1.0.
        assert_eq!(sim.now(), 100);
        assert!((pool.utilization(100) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn reserve_removes_capacity() {
        let mut sim = Sim::new();
        let pool = CorePool::new(2);
        pool.reserve(1);
        let log = Rc::new(RefCell::new(Vec::new()));
        for _ in 0..2 {
            let log = log.clone();
            pool.run(&mut sim, 10, move |s| log.borrow_mut().push(s.now()));
        }
        sim.run_to_completion();
        assert_eq!(*log.borrow(), vec![10, 20]); // serialized on 1 core
    }

    #[test]
    fn queue_telemetry() {
        let mut sim = Sim::new();
        let pool = CorePool::new(1);
        for _ in 0..10 {
            pool.run(&mut sim, 5, |_| {});
        }
        assert_eq!(pool.queued(), 9);
        assert_eq!(pool.max_queue(), 9);
        sim.run_to_completion();
        assert_eq!(pool.queued(), 0);
        assert_eq!(pool.jobs_run(), 10);
        assert_eq!(pool.busy(), 0);
    }
}
