//! Deterministic xorshift64* RNG + distribution samplers.
//!
//! The crates.io registry is offline in this environment, so instead of
//! `rand` we carry a small, well-known generator. xorshift64* passes the
//! statistical tests that matter for workload generation (BigCrush small
//! set) and is trivially reproducible across runs.

/// xorshift64* pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed (0 is remapped — xorshift's fixed point).
    pub fn new(seed: u64) -> Self {
        Rng { state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed } }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits → mantissa.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free mapping (Lemire); bias < 2^-64·n,
        // negligible for simulation purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Exponential with the given mean (inter-arrival sampling for Poisson
    /// processes). Returns a float so callers can keep sub-ns precision
    /// before rounding to `Time`.
    #[inline]
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Bounded Pareto-ish heavy tail (for cold-start / think-time models):
    /// `mean * (u^{-1/alpha})` clipped at `cap` multiples of the mean.
    pub fn heavy_tail(&mut self, mean: f64, alpha: f64, cap: f64) -> f64 {
        let u = (1.0 - self.next_f64()).max(1e-12);
        (mean * u.powf(-1.0 / alpha) / (alpha / (alpha - 1.0))).min(mean * cap)
    }

    /// Fork a statistically-independent child stream (for per-entity RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() | 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(8);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(123);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exp_mean_matches() {
        let mut r = Rng::new(99);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(250.0)).sum::<f64>() / n as f64;
        assert!((mean - 250.0).abs() < 5.0, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork();
        let mut b = root.fork();
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
