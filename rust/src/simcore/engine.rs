//! The event engine of the simulator: a two-tier scheduler (hierarchical
//! timer wheel + far-timer heap) over a slab arena of event entries.
//!
//! The seed engine was one global `BinaryHeap<Box<dyn FnOnce>>`: every
//! event paid an O(log n) sift through a pointer-chasing heap, there was
//! no cancellation (dead timers had to fire as tombstone closures and
//! check a flag), and at cluster scale the heap becomes the simulator's
//! hottest data structure. The rebuilt engine keeps the exact same
//! semantics — events fire in `(time, seq)` order, ties in schedule
//! order, bit-deterministic — but stores events in an [`EventSlab`]
//! (reused slots, zero steady-state allocation) ordered by a
//! [`TimerWheel`] (O(1) insert/fire for near timers, heap tier for far
//! ones), and returns a generation-checked [`TimerHandle`] supporting
//! O(1) [`Sim::cancel`] / [`Sim::reschedule`]. A cancelled timer is never
//! sifted or fired: its slab slot is freed immediately and the stale
//! wheel reference is skipped with one comparison when it surfaces.
//!
//! The seed's heap survives as [`EngineKind::ReferenceHeap`] — same slab,
//! same API, `BinaryHeap` ordering — kept as the differential-testing
//! oracle (`property_wheel_matches_reference_heap`, plus the cross-engine
//! experiment-output tests in `tests/integration.rs`) and as the baseline
//! the `engine_throughput` bench measures the ≥5× speedup against.
//!
//! Scheduling into the past is **clamp-and-count** in every build
//! profile: the event fires at `now` and [`Sim::past_schedules`]
//! increments (the seed silently clamped in release but asserted in
//! debug, so the two profiles disagreed).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::slab::{EventFn, EventKey, EventSlab};
use super::wheel::{TimerWheel, WheelEntry};

pub use super::slab::{TieBreak, TimerHandle};

/// Virtual time in nanoseconds.
pub type Time = u64;

/// One virtual microsecond in `Time` units.
pub const MICROS: Time = 1_000;
/// One virtual millisecond in `Time` units.
pub const MILLIS: Time = 1_000_000;
/// One virtual second in `Time` units.
pub const SECONDS: Time = 1_000_000_000;

/// Which ordering structure a [`Sim`] uses. Both fire the identical
/// `(time, seq)` order; they differ only in host-side cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Two-tier scheduler: hierarchical timer wheel + far heap (default).
    Wheel,
    /// The seed's `BinaryHeap` ordering over the same slab. Cancelled
    /// events stay in the heap as tombstones until popped — the cost
    /// profile the wheel is benchmarked against.
    ReferenceHeap,
}

thread_local! {
    static DEFAULT_ENGINE: std::cell::Cell<EngineKind> =
        std::cell::Cell::new(EngineKind::Wheel);
}

/// Set the engine [`Sim::new`] uses on this thread; returns the previous
/// default. The differential tests flip this to run whole experiments
/// under both engines without threading a parameter through every layer.
pub fn set_default_engine(kind: EngineKind) -> EngineKind {
    DEFAULT_ENGINE.with(|c| {
        let prev = c.get();
        c.set(kind);
        prev
    })
}

/// The engine new `Sim`s on this thread are built with.
pub fn default_engine() -> EngineKind {
    DEFAULT_ENGINE.with(|c| c.get())
}

thread_local! {
    static DEFAULT_TIEBREAK: std::cell::Cell<TieBreak> =
        std::cell::Cell::new(TieBreak::SeqAscending);
}

/// Set the same-time tie-break policy [`Sim::new`] uses on this thread;
/// returns the previous default. The schedule explorer (`schedcheck`)
/// flips this to rerun whole experiments under permuted tie-breaks
/// without threading a parameter through every layer — exactly like
/// [`set_default_engine`].
pub fn set_default_tiebreak(policy: TieBreak) -> TieBreak {
    DEFAULT_TIEBREAK.with(|c| {
        let prev = c.get();
        c.set(policy);
        prev
    })
}

/// The tie-break policy new `Sim`s on this thread are built with.
pub fn default_tiebreak() -> TieBreak {
    DEFAULT_TIEBREAK.with(|c| c.get())
}

enum EngineImpl {
    Wheel(TimerWheel),
    ReferenceHeap(BinaryHeap<Reverse<WheelEntry>>),
}

/// Engine-internal counters for the §Perf benches.
#[derive(Debug, Clone, Copy)]
pub struct EngineStats {
    pub kind: EngineKind,
    /// Live (scheduled, not fired/cancelled) events.
    pub pending: usize,
    /// Event-slab slots ever created (high-water mark of concurrency).
    pub slot_capacity: usize,
    /// Events cancelled via [`Sim::cancel`] (includes reschedules).
    pub cancelled: u64,
    /// Schedules clamped because they targeted the past.
    pub past_schedules: u64,
}

/// A discrete-event simulation: the two-tier event scheduler plus a
/// virtual clock.
///
/// Events are boxed `FnOnce(&mut Sim)` closures; world state lives in
/// `Rc<RefCell<..>>` structures captured by the closures (the simulation
/// is single-threaded by construction). The seed's `at`/`after`/
/// `run_until`/`run_to_completion` API is unchanged; `*_handle`,
/// `cancel` and `reschedule` are the additions.
pub struct Sim {
    now: Time,
    seq: u64,
    tiebreak: TieBreak,
    slab: EventSlab,
    engine: EngineImpl,
    events_fired: u64,
    cancelled: u64,
    past_schedules: u64,
    current: Option<(Time, u64)>,
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

impl Sim {
    /// New simulation on this thread's default engine (the wheel, unless
    /// a differential test flipped it).
    pub fn new() -> Self {
        Self::with_engine(default_engine())
    }

    /// New simulation on an explicit engine, with this thread's default
    /// tie-break policy.
    pub fn with_engine(kind: EngineKind) -> Self {
        Self::with_engine_and_tiebreak(kind, default_tiebreak())
    }

    /// New simulation on an explicit engine and tie-break policy.
    pub fn with_engine_and_tiebreak(kind: EngineKind, tiebreak: TieBreak) -> Self {
        let engine = match kind {
            EngineKind::Wheel => EngineImpl::Wheel(TimerWheel::new()),
            EngineKind::ReferenceHeap => EngineImpl::ReferenceHeap(BinaryHeap::new()),
        };
        Sim {
            now: 0,
            seq: 0,
            tiebreak,
            slab: EventSlab::new(),
            engine,
            events_fired: 0,
            cancelled: 0,
            past_schedules: 0,
            current: None,
        }
    }

    /// New simulation on the seed-shaped reference heap engine.
    pub fn new_reference() -> Self {
        Self::with_engine(EngineKind::ReferenceHeap)
    }

    pub fn engine_kind(&self) -> EngineKind {
        match self.engine {
            EngineImpl::Wheel(_) => EngineKind::Wheel,
            EngineImpl::ReferenceHeap(_) => EngineKind::ReferenceHeap,
        }
    }

    /// The same-time tie-break policy this sim was built with.
    pub fn tie_break(&self) -> TieBreak {
        self.tiebreak
    }

    /// `(time, schedule-order seq)` of the most recently fired event —
    /// `None` before the first fire. The seq is the insertion sequence
    /// number (policy-independent up to the first divergence), which is
    /// what the schedule explorer prints when two tie-break policies
    /// first disagree.
    pub fn current_fire(&self) -> Option<(Time, u64)> {
        self.current
    }

    /// Current virtual time.
    #[inline]
    pub fn now(&self) -> Time {
        self.now
    }

    /// Total events fired so far (perf counter for the §Perf benches).
    #[inline]
    pub fn events_fired(&self) -> u64 {
        self.events_fired
    }

    /// Schedules that targeted a time before `now` and were clamped to
    /// fire immediately (the consistent clamp-and-count policy).
    #[inline]
    pub fn past_schedules(&self) -> u64 {
        self.past_schedules
    }

    pub fn engine_stats(&self) -> EngineStats {
        EngineStats {
            kind: self.engine_kind(),
            pending: self.slab.len(),
            slot_capacity: self.slab.capacity(),
            cancelled: self.cancelled,
            past_schedules: self.past_schedules,
        }
    }

    /// Number of pending (live) events.
    pub fn pending(&self) -> usize {
        self.slab.len()
    }

    /// Virtual time of the earliest pending event, `None` when the
    /// schedule is empty. Costs a linear scan of the event slab (the
    /// wheel cannot peek without cascading), so callers should gate it on
    /// a small [`Sim::pending`] count — the shard runner does, using it
    /// only to fast-forward epochs once a shard has gone quiet.
    pub fn next_event_time(&self) -> Option<Time> {
        self.slab.min_time()
    }

    fn schedule_boxed(&mut self, t: Time, cb: EventFn) -> TimerHandle {
        let t = if t < self.now {
            self.past_schedules += 1;
            self.now
        } else {
            t
        };
        let key = EventKey { time: t, seq: self.tiebreak.token(self.seq) };
        let orig = self.seq;
        self.seq += 1;
        let h = self.slab.insert(key, orig, cb);
        match &mut self.engine {
            EngineImpl::Wheel(w) => w.insert(key, h.idx, h.gen, self.now),
            EngineImpl::ReferenceHeap(heap) => {
                heap.push(Reverse(WheelEntry { key, idx: h.idx, gen: h.gen }));
            }
        }
        h
    }

    /// Schedule `event` at absolute virtual time `t`. Times in the past
    /// are clamped to `now` and counted in [`Sim::past_schedules`].
    pub fn at<F: FnOnce(&mut Sim) + 'static>(&mut self, t: Time, event: F) {
        let _ = self.schedule_boxed(t, Box::new(event));
    }

    /// Schedule `event` after a relative delay.
    #[inline]
    pub fn after<F: FnOnce(&mut Sim) + 'static>(&mut self, delay: Time, event: F) {
        self.at(self.now + delay, event);
    }

    /// Like [`Sim::at`], returning a handle for O(1) cancel/reschedule.
    pub fn at_handle<F: FnOnce(&mut Sim) + 'static>(&mut self, t: Time, event: F) -> TimerHandle {
        self.schedule_boxed(t, Box::new(event))
    }

    /// Like [`Sim::after`], returning a handle for O(1) cancel/reschedule.
    #[inline]
    pub fn after_handle<F: FnOnce(&mut Sim) + 'static>(
        &mut self,
        delay: Time,
        event: F,
    ) -> TimerHandle {
        self.at_handle(self.now + delay, event)
    }

    /// Cancel a scheduled event: O(1), frees its slab slot immediately.
    /// Returns `false` if the handle is stale (already fired, cancelled,
    /// or rescheduled) — never an error.
    pub fn cancel(&mut self, h: TimerHandle) -> bool {
        if self.slab.cancel(h) {
            self.cancelled += 1;
            true
        } else {
            false
        }
    }

    /// Move a pending event to a new absolute time, keeping its callback:
    /// O(1). The event is re-sequenced (it behaves like a fresh schedule
    /// for tie-breaking) and the old handle goes stale; `None` if the
    /// handle was already stale. Times in the past clamp-and-count like
    /// [`Sim::at`].
    pub fn reschedule(&mut self, h: TimerHandle, t: Time) -> Option<TimerHandle> {
        let (_, _, cb) = self.slab.take(h.idx, h.gen)?;
        self.cancelled += 1;
        Some(self.schedule_boxed(t, cb))
    }

    /// Pop the earliest live event at or before `until`, skipping stale
    /// (cancelled/rescheduled) references lazily.
    fn pop_live(&mut self, until: Time) -> Option<(EventKey, u64, EventFn)> {
        loop {
            let (key, idx, gen) = match &mut self.engine {
                EngineImpl::Wheel(w) => w.pop_at_or_before(until)?,
                EngineImpl::ReferenceHeap(heap) => {
                    let &Reverse(e) = heap.peek()?;
                    if e.key.time > until {
                        return None;
                    }
                    heap.pop();
                    (e.key, e.idx, e.gen)
                }
            };
            if let Some((k, orig, cb)) = self.slab.take(idx, gen) {
                debug_assert_eq!(k, key);
                return Some((k, orig, cb));
            }
            // Stale reference: the event was cancelled or rescheduled.
        }
    }

    /// Run until no live event remains at or before `until`.
    ///
    /// Events scheduled exactly at `until` still fire; the first event
    /// strictly after `until` stays pending and the clock stops at
    /// `until`. Calling with `until < now` is a no-op: the clock never
    /// moves backwards (the seed engine's early-return path set
    /// `now = until` unclamped, rewinding the clock).
    pub fn run_until(&mut self, until: Time) {
        while let Some((key, orig, cb)) = self.pop_live(until) {
            self.now = key.time;
            self.current = Some((key.time, orig));
            self.events_fired += 1;
            cb(self);
        }
        self.now = self.now.max(until);
    }

    /// Run until every live event has fired.
    pub fn run_to_completion(&mut self) {
        while let Some((key, orig, cb)) = self.pop_live(Time::MAX) {
            self.now = key.time;
            self.current = Some((key.time, orig));
            self.events_fired += 1;
            cb(self);
        }
    }
}

/// Drive `tick` every `interval` from `sim.now() + interval` until
/// `sim.now() + horizon` (exclusive) — the fixed tick times of the seed's
/// pre-scheduled trains (controller reconcile, pool maintenance), but
/// holding **one** pending event at a time instead of `horizon/interval`
/// closures scheduled up front.
pub fn tick_train<F: FnMut(&mut Sim) + 'static>(
    sim: &mut Sim,
    interval: Time,
    horizon: Time,
    tick: F,
) {
    assert!(interval > 0, "tick train needs a positive interval");
    let end = sim.now() + horizon;
    let first = sim.now() + interval;
    schedule_tick(sim, first, interval, end, std::rc::Rc::new(std::cell::RefCell::new(tick)));
}

fn schedule_tick(
    sim: &mut Sim,
    at: Time,
    interval: Time,
    end: Time,
    tick: std::rc::Rc<std::cell::RefCell<dyn FnMut(&mut Sim)>>,
) {
    if at >= end {
        return;
    }
    sim.at(at, move |sim| {
        (tick.borrow_mut())(sim);
        schedule_tick(sim, at + interval, interval, end, tick);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    const BOTH: [EngineKind; 2] = [EngineKind::Wheel, EngineKind::ReferenceHeap];

    #[test]
    fn fires_in_time_order() {
        for kind in BOTH {
            let mut sim = Sim::with_engine(kind);
            let log = Rc::new(RefCell::new(Vec::new()));
            for &t in &[30u64, 10, 20] {
                let log = log.clone();
                sim.at(t, move |s| log.borrow_mut().push(s.now()));
            }
            sim.run_to_completion();
            assert_eq!(*log.borrow(), vec![10, 20, 30], "{kind:?}");
        }
    }

    #[test]
    fn ties_fire_in_schedule_order() {
        for kind in BOTH {
            let mut sim = Sim::with_engine(kind);
            let log = Rc::new(RefCell::new(Vec::new()));
            for i in 0..100 {
                let log = log.clone();
                // tie-break: deliberately tied — this test pins the
                // default ascending tie order itself.
                sim.at(5, move |_| log.borrow_mut().push(i));
            }
            sim.run_to_completion();
            assert_eq!(*log.borrow(), (0..100).collect::<Vec<_>>(), "{kind:?}");
        }
    }

    #[test]
    fn nested_scheduling_advances_clock() {
        let mut sim = Sim::new();
        let hits = Rc::new(RefCell::new(0u32));
        let h = hits.clone();
        sim.at(10, move |s| {
            assert_eq!(s.now(), 10);
            let h2 = h.clone();
            s.after(5, move |s2| {
                assert_eq!(s2.now(), 15);
                *h2.borrow_mut() += 1;
            });
            *h.borrow_mut() += 1;
        });
        sim.run_to_completion();
        assert_eq!(*hits.borrow(), 2);
    }

    #[test]
    fn run_until_stops_and_resumes() {
        for kind in BOTH {
            let mut sim = Sim::with_engine(kind);
            let log = Rc::new(RefCell::new(Vec::new()));
            for &t in &[10u64, 20, 30] {
                let log = log.clone();
                sim.at(t, move |s| log.borrow_mut().push(s.now()));
            }
            sim.run_until(20);
            assert_eq!(*log.borrow(), vec![10, 20]);
            assert_eq!(sim.now(), 20);
            assert_eq!(sim.pending(), 1);
            sim.run_to_completion();
            assert_eq!(*log.borrow(), vec![10, 20, 30]);
        }
    }

    /// Regression (satellite): `run_until(until < now)` must not rewind
    /// the clock. The seed's early-return branch (pending event beyond
    /// `until`) assigned `self.now = until` unclamped.
    #[test]
    fn run_until_never_moves_clock_backwards() {
        for kind in BOTH {
            let mut sim = Sim::with_engine(kind);
            sim.at(200, |_| {});
            sim.run_until(100);
            assert_eq!(sim.now(), 100);
            // Pending-event path (the seed bug).
            sim.run_until(50);
            assert_eq!(sim.now(), 100, "{kind:?}: clock rewound with events pending");
            // Drained path.
            sim.run_to_completion();
            assert_eq!(sim.now(), 200);
            sim.run_until(120);
            assert_eq!(sim.now(), 200, "{kind:?}: clock rewound after drain");
        }
    }

    /// Satellite: scheduling into the past clamps to `now` and counts, in
    /// every build profile and on both engines.
    #[test]
    fn scheduling_into_past_clamps_and_counts() {
        for kind in BOTH {
            let mut sim = Sim::with_engine(kind);
            sim.at(100, |_| {});
            sim.run_to_completion();
            assert_eq!(sim.now(), 100);
            let fired_at = Rc::new(RefCell::new(0u64));
            let f = fired_at.clone();
            sim.at(40, move |s| *f.borrow_mut() = s.now());
            assert_eq!(sim.past_schedules(), 1);
            sim.run_to_completion();
            assert_eq!(*fired_at.borrow(), 100, "{kind:?}: past event must fire at now");
            assert_eq!(sim.now(), 100);
            // Relative scheduling never goes backwards — counter stays.
            sim.after(10, |_| {});
            sim.run_to_completion();
            assert_eq!(sim.past_schedules(), 1);
        }
    }

    #[test]
    fn clock_is_monotone_under_many_events() {
        for kind in BOTH {
            let mut sim = Sim::with_engine(kind);
            let last = Rc::new(RefCell::new(0u64));
            let mut rng = crate::simcore::Rng::new(42);
            for _ in 0..10_000 {
                let t = rng.next_u64() % 1_000_000;
                let last = last.clone();
                sim.at(t, move |s| {
                    assert!(s.now() >= *last.borrow());
                    *last.borrow_mut() = s.now();
                });
            }
            sim.run_to_completion();
        }
    }

    #[test]
    fn cancel_prevents_fire_and_is_idempotent() {
        for kind in BOTH {
            let mut sim = Sim::with_engine(kind);
            let log = Rc::new(RefCell::new(Vec::new()));
            let l1 = log.clone();
            sim.at(10, move |_| l1.borrow_mut().push(10));
            let l2 = log.clone();
            let h = sim.at_handle(20, move |_| l2.borrow_mut().push(20));
            let l3 = log.clone();
            sim.at(30, move |_| l3.borrow_mut().push(30));
            assert_eq!(sim.pending(), 3);
            assert!(sim.cancel(h));
            assert_eq!(sim.pending(), 2, "{kind:?}: cancel must free immediately");
            assert!(!sim.cancel(h), "double cancel is a no-op");
            sim.run_to_completion();
            assert_eq!(*log.borrow(), vec![10, 30], "{kind:?}");
            assert_eq!(sim.events_fired(), 2, "{kind:?}: cancelled event must not fire");
            assert!(!sim.cancel(h), "cancel after run is still a no-op");
        }
    }

    /// Regression: a cancelled entry later than every live event leaves
    /// the wheel's internal clock ahead of the engine clock after
    /// `run_to_completion` drains it. A subsequent (valid) schedule must
    /// re-anchor and fire at the right time instead of panicking or
    /// cascading out of the wheel.
    #[test]
    fn schedule_after_draining_cancelled_tail() {
        for kind in BOTH {
            let mut sim = Sim::with_engine(kind);
            sim.at(50, |_| {});
            let h = sim.at_handle(100, |_| {});
            sim.cancel(h);
            sim.run_to_completion();
            assert_eq!(sim.now(), 50, "{kind:?}: stale tail must not advance the clock");
            let fired = Rc::new(RefCell::new(Vec::new()));
            let f = fired.clone();
            sim.after(10, move |s| f.borrow_mut().push(s.now()));
            sim.run_to_completion();
            assert_eq!(*fired.borrow(), vec![60], "{kind:?}");
            assert_eq!(sim.past_schedules(), 0, "{kind:?}: 60 is the future, not the past");
        }
    }

    #[test]
    fn cancel_after_fire_is_stale() {
        let mut sim = Sim::new();
        let h = sim.at_handle(5, |_| {});
        sim.run_to_completion();
        assert!(!sim.cancel(h));
    }

    #[test]
    fn reschedule_moves_event_and_invalidates_old_handle() {
        for kind in BOTH {
            let mut sim = Sim::with_engine(kind);
            let log = Rc::new(RefCell::new(Vec::new()));
            let l = log.clone();
            let h = sim.at_handle(100, move |s| l.borrow_mut().push(s.now()));
            let h2 = sim.reschedule(h, 40).expect("live handle reschedules");
            assert!(!sim.cancel(h), "old handle must be stale after reschedule");
            sim.run_to_completion();
            assert_eq!(*log.borrow(), vec![40], "{kind:?}");
            assert_eq!(sim.events_fired(), 1);
            assert!(sim.reschedule(h2, 50).is_none(), "fired handle cannot reschedule");
        }
    }

    #[test]
    fn rescheduled_event_ties_as_fresh_schedule() {
        for kind in BOTH {
            let mut sim = Sim::with_engine(kind);
            let log = Rc::new(RefCell::new(Vec::new()));
            let l1 = log.clone();
            let h = sim.at_handle(10, move |_| l1.borrow_mut().push("moved"));
            let l2 = log.clone();
            sim.at(50, move |_| l2.borrow_mut().push("fixed"));
            // Move the first event onto the second's instant: it now ties
            // as the *later* schedule and fires second.
            sim.reschedule(h, 50).unwrap();
            sim.run_to_completion();
            assert_eq!(*log.borrow(), vec!["fixed", "moved"], "{kind:?}");
        }
    }

    #[test]
    fn far_horizon_timers_fire_in_order() {
        for kind in BOTH {
            let mut sim = Sim::with_engine(kind);
            let log = Rc::new(RefCell::new(Vec::new()));
            // Mix wheel-range and far-tier (≥ 2^36 ns ≈ 69 s) targets.
            for &t in &[500 * SECONDS, 1, 100 * SECONDS, 70 * SECONDS, MILLIS, 3] {
                let log = log.clone();
                sim.at(t, move |s| log.borrow_mut().push(s.now()));
            }
            sim.run_to_completion();
            assert_eq!(
                *log.borrow(),
                vec![1, 3, MILLIS, 70 * SECONDS, 100 * SECONDS, 500 * SECONDS],
                "{kind:?}"
            );
        }
    }

    #[test]
    fn cancel_far_timer_before_fire() {
        for kind in BOTH {
            let mut sim = Sim::with_engine(kind);
            let fired = Rc::new(RefCell::new(false));
            let f = fired.clone();
            let h = sim.at_handle(120 * SECONDS, move |_| *f.borrow_mut() = true);
            sim.at(SECONDS, |_| {});
            sim.run_until(2 * SECONDS);
            assert!(sim.cancel(h));
            sim.run_to_completion();
            assert!(!*fired.borrow(), "{kind:?}");
            assert_eq!(sim.now(), 2 * SECONDS, "no live event after the horizon");
        }
    }

    #[test]
    fn tick_train_fires_seed_tick_times_with_one_pending_event() {
        for kind in BOTH {
            let mut sim = Sim::with_engine(kind);
            sim.at(7, |_| {});
            sim.run_to_completion(); // now = 7
            let ticks = Rc::new(RefCell::new(Vec::new()));
            let t2 = ticks.clone();
            tick_train(&mut sim, 10, 45, move |s| t2.borrow_mut().push(s.now()));
            assert_eq!(sim.pending(), 1, "{kind:?}: train holds one event at a time");
            sim.run_to_completion();
            // Seed semantics: t = now+i·interval while t < now+horizon.
            assert_eq!(*ticks.borrow(), vec![17, 27, 37, 47], "{kind:?}");
        }
    }

    #[test]
    fn events_fired_counts_only_live_fires() {
        let mut sim = Sim::new();
        for i in 0..10u64 {
            sim.at(i, |_| {});
        }
        let h = sim.at_handle(100, |_| {});
        sim.cancel(h);
        sim.run_to_completion();
        assert_eq!(sim.events_fired(), 10);
        assert_eq!(sim.pending(), 0);
        assert_eq!(sim.engine_stats().cancelled, 1);
    }

    #[test]
    fn default_engine_is_thread_local_and_restorable() {
        assert_eq!(default_engine(), EngineKind::Wheel);
        let prev = set_default_engine(EngineKind::ReferenceHeap);
        assert_eq!(prev, EngineKind::Wheel);
        assert_eq!(Sim::new().engine_kind(), EngineKind::ReferenceHeap);
        set_default_engine(prev);
        assert_eq!(Sim::new().engine_kind(), EngineKind::Wheel);
    }

    // ---- differential property test (satellite) -------------------------

    /// What one event does when it fires. Targets refer to event ids in
    /// the shared plan; acting on an already-fired/cancelled target is a
    /// deterministic no-op on both engines.
    #[derive(Clone)]
    enum Act {
        Spawn { delta: Time, id: usize },
        Cancel { target: usize },
        Resched { target: usize, delta: Time },
    }

    struct Ctx {
        log: RefCell<Vec<(usize, Time)>>,
        handles: RefCell<Vec<Option<TimerHandle>>>,
        plan: Vec<Vec<Act>>,
    }

    fn schedule_event(sim: &mut Sim, at: Time, id: usize, ctx: Rc<Ctx>) {
        let c = ctx.clone();
        let h = sim.at_handle(at, move |sim| {
            c.log.borrow_mut().push((id, sim.now()));
            let acts = c.plan[id].clone();
            for a in acts {
                match a {
                    Act::Spawn { delta, id: cid } => {
                        let at = sim.now() + delta;
                        schedule_event(sim, at, cid, c.clone());
                    }
                    Act::Cancel { target } => {
                        let h = c.handles.borrow_mut()[target].take();
                        if let Some(h) = h {
                            sim.cancel(h);
                        }
                    }
                    Act::Resched { target, delta } => {
                        let h = c.handles.borrow_mut()[target].take();
                        if let Some(h) = h {
                            let t = sim.now() + delta;
                            let h2 = sim.reschedule(h, t);
                            c.handles.borrow_mut()[target] = h2;
                        }
                    }
                }
            }
        });
        ctx.handles.borrow_mut()[id] = Some(h);
    }

    fn run_plan(
        kind: EngineKind,
        tb: TieBreak,
        roots: &[(Time, usize)],
        plan: &[Vec<Act>],
    ) -> (Vec<(usize, Time)>, u64, Time, u64) {
        let mut sim = Sim::with_engine_and_tiebreak(kind, tb);
        let ctx = Rc::new(Ctx {
            log: RefCell::new(Vec::new()),
            handles: RefCell::new(vec![None; plan.len()]),
            plan: plan.to_vec(),
        });
        for &(t, id) in roots {
            schedule_event(&mut sim, t, id, ctx.clone());
        }
        sim.run_to_completion();
        let log = ctx.log.borrow().clone();
        (log, sim.events_fired(), sim.now(), sim.past_schedules())
    }

    /// Satellite: under **every** tie-break policy, the wheel and the
    /// reference heap fire the identical event sequence — times, tie
    /// order, clock, counters — across seeded random schedules with
    /// nesting, cancellations and re-schedules spanning every wheel
    /// level and the far tier (including same-instant mid-drain spawns,
    /// where non-ascending tokens exercise the sorted insert).
    #[test]
    fn property_wheel_matches_reference_heap() {
        use crate::simcore::{forall, Gen};
        forall("wheel ≡ reference heap", 30, |g: &mut Gen| {
            let m = g.usize(20, 60);
            let mut plan: Vec<Vec<Act>> = vec![Vec::new(); m];
            let mut roots: Vec<(Time, usize)> = Vec::new();
            let delta = |g: &mut Gen| -> Time {
                match g.u64(0, 3) {
                    0 => g.u64(0, 63),                       // same/near instant
                    1 => g.u64(0, 4096),                     // low wheel levels
                    2 => g.u64(0, 10 * SECONDS),             // high wheel levels
                    _ => g.u64(60 * SECONDS, 200 * SECONDS), // far tier
                }
            };
            // Every id is either a root or spawned by a lower id: each is
            // scheduled at most once, deterministically.
            for id in 0..m {
                if id == 0 || g.bool() {
                    roots.push((delta(g), id));
                } else {
                    let parent = g.usize(0, id - 1);
                    let d = delta(g);
                    plan[parent].push(Act::Spawn { delta: d, id });
                }
            }
            // Sprinkle cancels/reschedules over arbitrary targets.
            for _ in 0..g.usize(0, m / 2) {
                let actor = g.usize(0, m - 1);
                let target = g.usize(0, m - 1);
                let act = if g.bool() {
                    Act::Cancel { target }
                } else {
                    Act::Resched { target, delta: delta(g) }
                };
                plan[actor].push(act);
            }
            let policies = [
                TieBreak::SeqAscending,
                TieBreak::SeqDescending,
                TieBreak::SeededShuffle(g.u64(0, 1 << 48)),
            ];
            for tb in policies {
                let a = run_plan(EngineKind::Wheel, tb, &roots, &plan);
                let b = run_plan(EngineKind::ReferenceHeap, tb, &roots, &plan);
                assert_eq!(a.0, b.0, "fired (id, time) sequences diverged under {tb:?}");
                assert_eq!(a.1, b.1, "events_fired diverged under {tb:?}");
                assert_eq!(a.2, b.2, "final clock diverged under {tb:?}");
                assert_eq!(a.3, b.3, "past_schedules diverged under {tb:?}");
            }
        });
    }

    /// Tentpole: ties fire in *reverse* schedule order under
    /// `SeqDescending`, in a seed-deterministic permutation under
    /// `SeededShuffle`, and the three policies agree on everything that
    /// does not race at an identical timestamp.
    #[test]
    fn tiebreak_policies_permute_ties_deterministically() {
        let order = |tb: TieBreak| -> Vec<u32> {
            let mut sim = Sim::with_engine_and_tiebreak(EngineKind::Wheel, tb);
            let log = Rc::new(RefCell::new(Vec::new()));
            for i in 0..8u32 {
                let log = log.clone();
                // tie-break: this test exists to observe tie order.
                sim.at(5, move |_| log.borrow_mut().push(i));
            }
            sim.run_to_completion();
            let v = log.borrow().clone();
            v
        };
        assert_eq!(order(TieBreak::SeqAscending), (0..8).collect::<Vec<_>>());
        assert_eq!(order(TieBreak::SeqDescending), (0..8).rev().collect::<Vec<_>>());
        let s1 = order(TieBreak::SeededShuffle(17));
        let s2 = order(TieBreak::SeededShuffle(17));
        assert_eq!(s1, s2, "same seed must give the same permutation");
        let mut sorted = s1.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<_>>(), "shuffle must be a permutation");
        assert_ne!(s1, order(TieBreak::SeededShuffle(18)), "seeds must differ");
    }

    /// Satellite: a *commutative* workload — tied events only bump
    /// per-time counters, so any tie order yields the same aggregate —
    /// produces identical results under all three policies on both
    /// engines. This is the certification the schedule explorer
    /// (`schedcheck`) applies to whole experiment tables.
    #[test]
    fn tiebreak_policies_agree_on_commutative_workload() {
        use std::collections::BTreeMap;
        let run = |kind: EngineKind, tb: TieBreak| -> (BTreeMap<Time, u32>, u64, Time) {
            let mut sim = Sim::with_engine_and_tiebreak(kind, tb);
            let counts: Rc<RefCell<BTreeMap<Time, u32>>> = Rc::new(RefCell::new(BTreeMap::new()));
            for round in 0..6u64 {
                let t = 100 + round * 37;
                for _ in 0..5 {
                    let counts = counts.clone();
                    // tie-break: commutative by construction — each tied
                    // event increments the same per-time counter.
                    sim.at(t, move |s| {
                        *counts.borrow_mut().entry(s.now()).or_insert(0) += 1;
                        // Same-instant respawn: exercises mid-drain
                        // inserts under permuted tokens.
                        let counts = counts.clone();
                        sim_bump(s, counts);
                    });
                }
            }
            sim.run_to_completion();
            let c = counts.borrow().clone();
            (c, sim.events_fired(), sim.now())
        };
        fn sim_bump(s: &mut Sim, counts: Rc<RefCell<BTreeMap<Time, u32>>>) {
            // tie-break: commutative — order among these bumps is
            // unobservable in the aggregate.
            s.after(0, move |s| {
                *counts.borrow_mut().entry(s.now()).or_insert(0) += 1;
            });
        }
        let policies = [
            TieBreak::SeqAscending,
            TieBreak::SeqDescending,
            TieBreak::SeededShuffle(17),
        ];
        let baseline = run(EngineKind::Wheel, TieBreak::SeqAscending);
        for kind in BOTH {
            for tb in policies {
                assert_eq!(run(kind, tb), baseline, "{kind:?}/{tb:?} diverged");
            }
        }
    }

    /// The steady-state scheduling hot path reuses slab slots: a long
    /// self-sustaining event chain must not grow the arena.
    #[test]
    fn steady_state_chain_keeps_slab_flat() {
        let mut sim = Sim::new();
        fn chain(sim: &mut Sim, remaining: u32) {
            if remaining == 0 {
                return;
            }
            sim.after(100, move |s| chain(s, remaining - 1));
        }
        // Prime, then measure.
        chain(&mut sim, 10);
        sim.run_to_completion();
        let cap = sim.engine_stats().slot_capacity;
        chain(&mut sim, 50_000);
        sim.run_to_completion();
        assert_eq!(
            sim.engine_stats().slot_capacity,
            cap,
            "steady-state chain grew the slab arena"
        );
        assert_eq!(sim.events_fired(), 10 + 50_000);
    }
}
