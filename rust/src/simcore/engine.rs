//! The event-heap core of the simulator.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Virtual time in nanoseconds.
pub type Time = u64;

/// One virtual microsecond in `Time` units.
pub const MICROS: Time = 1_000;
/// One virtual millisecond in `Time` units.
pub const MILLIS: Time = 1_000_000;
/// One virtual second in `Time` units.
pub const SECONDS: Time = 1_000_000_000;

type EventFn = Box<dyn FnOnce(&mut Sim)>;

struct Entry {
    time: Time,
    seq: u64,
    event: EventFn,
}

// Order by (time, seq): seq is the insertion counter, so simultaneous events
// fire in schedule order — this is what makes runs bit-deterministic.
impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// A discrete-event simulation: an event heap plus a virtual clock.
///
/// Events are boxed `FnOnce(&mut Sim)` closures; world state lives in
/// `Rc<RefCell<..>>` structures captured by the closures (the simulation is
/// single-threaded by construction).
pub struct Sim {
    now: Time,
    seq: u64,
    heap: BinaryHeap<Reverse<Entry>>,
    events_fired: u64,
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

impl Sim {
    pub fn new() -> Self {
        Sim { now: 0, seq: 0, heap: BinaryHeap::new(), events_fired: 0 }
    }

    /// Current virtual time.
    #[inline]
    pub fn now(&self) -> Time {
        self.now
    }

    /// Total events fired so far (perf counter for the §Perf benches).
    #[inline]
    pub fn events_fired(&self) -> u64 {
        self.events_fired
    }

    /// Schedule `event` at absolute virtual time `t` (must be >= now).
    pub fn at<F: FnOnce(&mut Sim) + 'static>(&mut self, t: Time, event: F) {
        debug_assert!(t >= self.now, "scheduling into the past: {} < {}", t, self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Entry { time: t.max(self.now), seq, event: Box::new(event) }));
    }

    /// Schedule `event` after a relative delay.
    #[inline]
    pub fn after<F: FnOnce(&mut Sim) + 'static>(&mut self, delay: Time, event: F) {
        self.at(self.now + delay, event);
    }

    /// Run until the heap is empty or the clock passes `until`.
    ///
    /// Events scheduled exactly at `until` still fire; the first event
    /// strictly after `until` is left in the heap and the clock stops at
    /// `until`.
    pub fn run_until(&mut self, until: Time) {
        loop {
            match self.heap.peek() {
                None => break,
                Some(Reverse(e)) if e.time > until => {
                    self.now = until;
                    return;
                }
                Some(_) => {}
            }
            let Reverse(entry) = self.heap.pop().unwrap();
            self.now = entry.time;
            self.events_fired += 1;
            (entry.event)(self);
        }
        // Heap drained before `until`: advance the clock to the horizon.
        self.now = self.now.max(until);
    }

    /// Run until the event heap drains completely.
    pub fn run_to_completion(&mut self) {
        while let Some(Reverse(entry)) = self.heap.pop() {
            self.now = entry.time;
            self.events_fired += 1;
            (entry.event)(self);
        }
    }

    /// Number of pending events.
    pub fn pending(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn fires_in_time_order() {
        let mut sim = Sim::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for &t in &[30u64, 10, 20] {
            let log = log.clone();
            sim.at(t, move |s| log.borrow_mut().push(s.now()));
        }
        sim.run_to_completion();
        assert_eq!(*log.borrow(), vec![10, 20, 30]);
    }

    #[test]
    fn ties_fire_in_schedule_order() {
        let mut sim = Sim::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for i in 0..100 {
            let log = log.clone();
            sim.at(5, move |_| log.borrow_mut().push(i));
        }
        sim.run_to_completion();
        assert_eq!(*log.borrow(), (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn nested_scheduling_advances_clock() {
        let mut sim = Sim::new();
        let hits = Rc::new(RefCell::new(0u32));
        let h = hits.clone();
        sim.at(10, move |s| {
            assert_eq!(s.now(), 10);
            let h2 = h.clone();
            s.after(5, move |s2| {
                assert_eq!(s2.now(), 15);
                *h2.borrow_mut() += 1;
            });
            *h.borrow_mut() += 1;
        });
        sim.run_to_completion();
        assert_eq!(*hits.borrow(), 2);
    }

    #[test]
    fn run_until_stops_and_resumes() {
        let mut sim = Sim::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        for &t in &[10u64, 20, 30] {
            let log = log.clone();
            sim.at(t, move |s| log.borrow_mut().push(s.now()));
        }
        sim.run_until(20);
        assert_eq!(*log.borrow(), vec![10, 20]);
        assert_eq!(sim.now(), 20);
        assert_eq!(sim.pending(), 1);
        sim.run_to_completion();
        assert_eq!(*log.borrow(), vec![10, 20, 30]);
    }

    #[test]
    fn clock_is_monotone_under_many_events() {
        let mut sim = Sim::new();
        let last = Rc::new(RefCell::new(0u64));
        let mut rng = crate::simcore::Rng::new(42);
        for _ in 0..10_000 {
            let t = rng.next_u64() % 1_000_000;
            let last = last.clone();
            sim.at(t, move |s| {
                assert!(s.now() >= *last.borrow());
                *last.borrow_mut() = s.now();
            });
        }
        sim.run_to_completion();
    }
}
