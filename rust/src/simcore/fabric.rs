//! The compute fabric: per-core timelines for the simulator.
//!
//! [`ComputeFabric`] replaces the seed's flat `CorePool` semaphore with a
//! structural model of a multi-core host: every core has its own timeline
//! (a running slice plus per-class local run queues), unpinned work waits
//! in shared per-class queues, and three knobs decide how contention
//! resolves:
//!
//! * **preemption quantum** — a running slice ends at the quantum edge
//!   when equal-or-higher-priority work is waiting; the preempted job
//!   requeues at the tail of its queue (CFS-style round-robin). Quantum
//!   edges are exact: an arrival at a busy core *advances* the core's
//!   slice-end timer to the next edge (O(1) cancel + reschedule on the
//!   PR 3 slab engine), so the uncontended fast path still costs one
//!   event per job.
//! * **classes** — [`JobClass::Irq`] beats [`JobClass::Normal`] beats
//!   [`JobClass::Batch`] at every pick and at every quantum edge. The
//!   kernel backend lands softirq work on specific cores as `Irq`, which
//!   is exactly how NIC processing steals cycles from whatever tenant
//!   runs there.
//! * **stealing** — an idle core with nothing shared to run may steal the
//!   oldest job from the longest local backlog, paying the migration
//!   cost (cache refill + wakeup IPI). Kernel backend: on. Bypass
//!   backend: off (core grants are sticky).
//!
//! `run_on(core, ..)` gives *soft affinity*: the job waits in that core's
//! local queue and runs there with local-before-shared priority, but the
//! core still takes shared work when its local queues are empty (work
//! conserving, no deadlock when grants churn). [`ComputeFabric::pin`]
//! makes a core hard-dedicated (local work only); `reserve` removes it
//! from the fabric entirely (the bypass scheduler's polling core).
//!
//! Interference now *emerges*: co-located tenants contend for the same
//! per-core timelines, so the kernel backend's tail grows structurally
//! with antagonist load while the bypass backend's pinned run-to-
//! completion grants hold it flat (E14, `benches/fig_isolation.rs`).
//! The sampled `sched_noise`/`segment_interference` draws that used to
//! stand in for this are demoted to a residual-jitter knob that defaults
//! off (see `oskernel`), so nothing is double-counted.
//!
//! Two seed bugs are fixed here and pinned by tests: `reserve` mid-flight
//! takes effect at the next dispatch (the seed kept refilling from the
//! queue until it drained), and busy time accrues at slice *completion*
//! (the seed charged the full duration at admission, so utilization
//! sampled mid-run could exceed 1.0).
//!
//! [`FabricKind::ReferenceFifo`] runs the seed algorithm unchanged (see
//! `resource.rs`); [`FabricKind::CompatFifo`] runs the per-core engine
//! with quantum = ∞, stealing off, and affinity/classes degraded to the
//! single shared FIFO. A differential property test plus the E5/E11
//! table-equality checks in `tests/integration.rs` pin that the two are
//! bit-for-bit identical — the same technique PR 3 used to swap the
//! event engine.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::rc::Rc;

use super::engine::{Sim, Time, TimerHandle};
use super::resource::{JobFn, RefJob, RefState};

use crate::invariants::{check, Audit, Violation};

/// Which engine a [`ComputeFabric`] runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricKind {
    /// Per-core timelines with the configured quantum/steal/affinity
    /// semantics (production default).
    Structural,
    /// The per-core engine degraded to the seed semantics: quantum = ∞,
    /// stealing off, `run_on`/`run_class` collapse to the shared FIFO.
    /// Must reproduce [`FabricKind::ReferenceFifo`] bit-for-bit.
    CompatFifo,
    /// The seed `CorePool` algorithm, kept as the differential reference.
    ReferenceFifo,
}

thread_local! {
    static DEFAULT_FABRIC: Cell<FabricKind> = const { Cell::new(FabricKind::Structural) };
}

/// The fabric kind new `FaasSim`s build (thread-local, like the event
/// engine's `set_default_engine`).
pub fn default_fabric() -> FabricKind {
    DEFAULT_FABRIC.with(|k| k.get())
}

/// Override the default fabric kind; returns the previous value so tests
/// can restore it.
pub fn set_default_fabric(kind: FabricKind) -> FabricKind {
    DEFAULT_FABRIC.with(|k| k.replace(kind))
}

/// Priority class of a fabric job. Lower value = higher priority.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum JobClass {
    /// IRQ/softirq work: preempts tenant work at the next quantum edge.
    Irq = 0,
    /// Tenant segments (the default).
    Normal = 1,
    /// Background/best-effort work: never preempts tenants.
    Batch = 2,
}

const NCLASS: usize = 3;

impl JobClass {
    #[inline]
    fn idx(self) -> usize {
        self as usize
    }
}

/// Structural knobs of the per-core engine.
#[derive(Debug, Clone, Copy)]
pub struct FabricConfig {
    /// Preemption quantum in ns; 0 = run to completion (no slicing).
    pub quantum_ns: Time,
    /// Idle cores may steal from another core's local backlog.
    pub steal: bool,
    /// Surcharge when a job resumes on a different core than it last ran
    /// on (cache refill + wakeup IPI), and when a job is stolen.
    pub migration_cost_ns: Time,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig { quantum_ns: 0, steal: false, migration_cost_ns: 0 }
    }
}

/// Counter snapshot for telemetry rollups (`Cluster::fabric_totals`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FabricStats {
    /// Schedulable (non-reserved) cores.
    pub cores: usize,
    pub busy_ns: u64,
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    /// Jobs that started running (seed `jobs_run` semantics).
    pub jobs_run: u64,
    pub preemptions: u64,
    pub steals: u64,
    pub migrations: u64,
    /// High-water mark of jobs waiting (shared + local queues).
    pub max_queue: usize,
    /// Busy ns per physical core (empty in `ReferenceFifo` mode).
    pub per_core_busy_ns: Vec<u64>,
}

impl FabricStats {
    /// Fold another fabric's counters into this one (cluster rollup):
    /// scalars add, `max_queue` takes the max, per-core vectors add
    /// index-wise (worker core `i` accumulates across the pool).
    pub fn merge(&mut self, other: &FabricStats) {
        self.cores += other.cores;
        self.busy_ns += other.busy_ns;
        self.jobs_submitted += other.jobs_submitted;
        self.jobs_completed += other.jobs_completed;
        self.jobs_run += other.jobs_run;
        self.preemptions += other.preemptions;
        self.steals += other.steals;
        self.migrations += other.migrations;
        self.max_queue = self.max_queue.max(other.max_queue);
        if self.per_core_busy_ns.len() < other.per_core_busy_ns.len() {
            self.per_core_busy_ns.resize(other.per_core_busy_ns.len(), 0);
        }
        for (i, v) in other.per_core_busy_ns.iter().enumerate() {
            self.per_core_busy_ns[i] += v;
        }
    }
}

/// How an observed slice ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SliceEnd {
    /// The job finished on this slice.
    Complete,
    /// Preempted (quantum edge with waiting work, or shed off a core
    /// reserved mid-slice) and requeued.
    Preempt,
    /// Reached its quantum edge with no preemptor; continues immediately
    /// on the same core.
    Continue,
}

impl SliceEnd {
    pub fn as_str(self) -> &'static str {
        match self {
            SliceEnd::Complete => "complete",
            SliceEnd::Preempt => "preempt",
            SliceEnd::Continue => "quantum_edge",
        }
    }
}

/// One executed slice of an observed job.
#[derive(Debug, Clone, Copy)]
pub struct SliceRecord {
    pub core: usize,
    pub start: Time,
    pub end: Time,
    pub outcome: SliceEnd,
}

/// Per-slice observer attached via [`ComputeFabric::run_observed`]. The
/// observer is invoked after each slice, outside the fabric's internal
/// borrow but *before* the job's `done` callback; it must only record
/// (it must not re-enter the fabric).
pub type SliceObs = Rc<dyn Fn(SliceRecord)>;

struct Job {
    remaining: Time,
    class: JobClass,
    /// Soft affinity: wait in this core's local queue.
    pin: Option<usize>,
    /// Core the job last ran on (migration surcharge on cross-core resume).
    last_core: Option<usize>,
    started: bool,
    /// Slice observer (tracing); travels with the job across requeues and
    /// steals. `None` costs nothing on the hot path.
    obs: Option<SliceObs>,
    done: JobFn,
}

struct Running {
    job: Job,
    slice_start: Time,
    /// Scheduled slice-end time (advanced to the quantum edge on arrival).
    end: Time,
    handle: TimerHandle,
}

struct Core {
    /// Removed from the fabric (scheduler polling core); never dispatches.
    reserved: bool,
    /// Hard-dedicated: serves its local queues only, never shared work.
    pinned: bool,
    /// The completed job's `done` callback is currently executing: the
    /// core is still owned by that job (seed semantics — the seed freed
    /// the core only *after* `done` ran), so a callback that submits new
    /// fabric work queues instead of double-dispatching this core.
    completing: bool,
    running: Option<Running>,
    local: [VecDeque<Job>; NCLASS],
    busy_ns: u64,
    jobs_run: u64,
    preemptions: u64,
}

impl Core {
    fn new() -> Core {
        Core {
            reserved: false,
            pinned: false,
            completing: false,
            running: None,
            local: std::array::from_fn(|_| VecDeque::new()),
            busy_ns: 0,
            jobs_run: 0,
            preemptions: 0,
        }
    }

    fn local_len(&self) -> usize {
        self.local.iter().map(|q| q.len()).sum()
    }
}

struct PerCore {
    cfg: FabricConfig,
    cores: Vec<Core>,
    shared: [VecDeque<Job>; NCLASS],
    /// Jobs waiting in any queue (shared + local).
    waiting: usize,
    max_queue: usize,
    busy_ns: u64,
    jobs_submitted: u64,
    jobs_completed: u64,
    jobs_run: u64,
    preemptions: u64,
    steals: u64,
    migrations: u64,
}

impl PerCore {
    fn new(cores: usize, cfg: FabricConfig) -> PerCore {
        assert!(cores > 0, "a compute fabric needs at least one core");
        PerCore {
            cfg,
            cores: (0..cores).map(|_| Core::new()).collect(),
            shared: std::array::from_fn(|_| VecDeque::new()),
            waiting: 0,
            max_queue: 0,
            busy_ns: 0,
            jobs_submitted: 0,
            jobs_completed: 0,
            jobs_run: 0,
            preemptions: 0,
            steals: 0,
            migrations: 0,
        }
    }

    fn unreserved(&self) -> usize {
        self.cores.iter().filter(|c| !c.reserved).count()
    }

    fn push_shared(&mut self, job: Job) {
        self.shared[job.class.idx()].push_back(job);
        self.note_queued();
    }

    fn push_local(&mut self, core: usize, job: Job) {
        self.cores[core].local[job.class.idx()].push_back(job);
        self.note_queued();
    }

    fn note_queued(&mut self) {
        self.waiting += 1;
        if self.waiting > self.max_queue {
            self.max_queue = self.waiting;
        }
    }

    /// Lowest-index idle core that may take shared work.
    fn first_open_idle(&self) -> Option<usize> {
        self.cores
            .iter()
            .position(|c| !c.reserved && !c.pinned && c.running.is_none() && !c.completing)
    }

    /// Is there waiting work that would meaningfully preempt a job of
    /// `class` (with local affinity `running_pin`) on this core? Local
    /// work of equal-or-higher class always does (round-robin rotation is
    /// meaningful there); *shared* work of the same class cannot rotate
    /// ahead of a core-affine job — the requeued job would win the next
    /// pick anyway — so only strictly higher classes preempt it from the
    /// shared queue. This keeps a granted core from burning a preempt/
    /// requeue/redispatch event cycle every quantum while shared work
    /// waits for some other core to free up.
    fn waiting_preempts(&self, core: usize, class: JobClass, running_pin: Option<usize>) -> bool {
        let c = &self.cores[core];
        let affine_here = running_pin == Some(core);
        for cl in 0..=class.idx() {
            if !c.local[cl].is_empty() {
                return true;
            }
            if !c.pinned && !self.shared[cl].is_empty() && (cl < class.idx() || !affine_here) {
                return true;
            }
        }
        false
    }

    /// Pop the next job for `core`: local before shared per class, then a
    /// steal from the longest local backlog if enabled.
    fn pick(&mut self, core: usize) -> Option<Job> {
        if self.cores[core].reserved {
            return None;
        }
        let pinned = self.cores[core].pinned;
        for cl in 0..NCLASS {
            if let Some(job) = self.cores[core].local[cl].pop_front() {
                self.waiting -= 1;
                return Some(job);
            }
            if !pinned {
                if let Some(job) = self.shared[cl].pop_front() {
                    self.waiting -= 1;
                    return Some(job);
                }
            }
        }
        if self.cfg.steal && !pinned {
            return self.steal_for(core);
        }
        None
    }

    /// Steal the oldest highest-class job from the longest local backlog
    /// among other stealable cores, paying the migration surcharge.
    fn steal_for(&mut self, thief: usize) -> Option<Job> {
        let donor = self
            .cores
            .iter()
            .enumerate()
            .filter(|(i, c)| *i != thief && !c.reserved && !c.pinned && c.local_len() > 0)
            .max_by_key(|(i, c)| (c.local_len(), usize::MAX - i)) // longest, lowest index on ties
            .map(|(i, _)| i)?;
        for cl in 0..NCLASS {
            if let Some(mut job) = self.cores[donor].local[cl].pop_front() {
                self.waiting -= 1;
                self.steals += 1;
                job.pin = None;
                job.last_core = None;
                if self.cfg.migration_cost_ns > 0 {
                    job.remaining += self.cfg.migration_cost_ns;
                    self.migrations += 1;
                }
                return Some(job);
            }
        }
        None
    }
}

enum Engine {
    PerCore(PerCore),
    Reference(RefState),
}

struct Inner {
    kind: FabricKind,
    engine: Engine,
}

/// A multi-core compute resource with per-core timelines. Cloning is
/// cheap (`Rc`); all clones refer to the same fabric.
#[derive(Clone)]
pub struct ComputeFabric {
    inner: Rc<RefCell<Inner>>,
}

enum Submitted {
    Start(usize, Job),
    Advance(usize),
    /// Queued shared: every busy core running equal-or-lower-priority
    /// work gets its slice end advanced to the next quantum edge.
    AdvanceShared(JobClass),
    Queued,
}

enum SliceOutcome {
    Done(JobFn),
    Requeued,
    Continue(Job),
}

impl ComputeFabric {
    /// A neutral per-core fabric (quantum = ∞, stealing off) — drop-in
    /// for the seed pool in tests and benches.
    pub fn new(cores: usize) -> Self {
        ComputeFabric::new_kind(FabricKind::Structural, cores, FabricConfig::default())
    }

    pub fn new_kind(kind: FabricKind, cores: usize, cfg: FabricConfig) -> Self {
        let engine = match kind {
            FabricKind::Structural => Engine::PerCore(PerCore::new(cores, cfg)),
            // Compat ignores the caller's knobs: it *is* the neutral config.
            FabricKind::CompatFifo => Engine::PerCore(PerCore::new(cores, FabricConfig::default())),
            FabricKind::ReferenceFifo => Engine::Reference(RefState::new(cores)),
        };
        ComputeFabric { inner: Rc::new(RefCell::new(Inner { kind, engine })) }
    }

    pub fn kind(&self) -> FabricKind {
        self.inner.borrow().kind
    }

    /// Schedulable cores (reserved cores excluded — seed semantics).
    pub fn cores(&self) -> usize {
        match &self.inner.borrow().engine {
            Engine::PerCore(pc) => pc.unreserved(),
            Engine::Reference(r) => r.cores,
        }
    }

    /// Cores currently running a job (reserved cores still draining count).
    pub fn busy(&self) -> usize {
        match &self.inner.borrow().engine {
            Engine::PerCore(pc) => pc.cores.iter().filter(|c| c.running.is_some()).count(),
            Engine::Reference(r) => r.busy,
        }
    }

    /// Jobs waiting for a core (shared + local queues).
    pub fn queued(&self) -> usize {
        match &self.inner.borrow().engine {
            Engine::PerCore(pc) => pc.waiting,
            Engine::Reference(r) => r.queue.len(),
        }
    }

    /// High-water mark of the waiting-job count (saturation telemetry).
    pub fn max_queue(&self) -> usize {
        match &self.inner.borrow().engine {
            Engine::PerCore(pc) => pc.max_queue,
            Engine::Reference(r) => r.max_queue,
        }
    }

    /// Total core-busy nanoseconds. Accrued at slice completion (the seed
    /// charged at admission — see the module header).
    pub fn busy_ns(&self) -> u64 {
        match &self.inner.borrow().engine {
            Engine::PerCore(pc) => pc.busy_ns,
            Engine::Reference(r) => r.busy_ns,
        }
    }

    pub fn jobs_run(&self) -> u64 {
        match &self.inner.borrow().engine {
            Engine::PerCore(pc) => pc.jobs_run,
            Engine::Reference(r) => r.jobs_run,
        }
    }

    pub fn jobs_submitted(&self) -> u64 {
        match &self.inner.borrow().engine {
            Engine::PerCore(pc) => pc.jobs_submitted,
            Engine::Reference(r) => r.jobs_submitted,
        }
    }

    pub fn jobs_completed(&self) -> u64 {
        match &self.inner.borrow().engine {
            Engine::PerCore(pc) => pc.jobs_completed,
            Engine::Reference(r) => r.jobs_completed,
        }
    }

    /// Busy ns per physical core (includes reserved cores, which stay 0
    /// unless they were reserved mid-drain). Empty in reference mode.
    pub fn per_core_busy_ns(&self) -> Vec<u64> {
        match &self.inner.borrow().engine {
            Engine::PerCore(pc) => pc.cores.iter().map(|c| c.busy_ns).collect(),
            Engine::Reference(_) => Vec::new(),
        }
    }

    /// Counter snapshot for rollups.
    pub fn stats(&self) -> FabricStats {
        let inner = self.inner.borrow();
        match &inner.engine {
            Engine::PerCore(pc) => FabricStats {
                cores: pc.unreserved(),
                busy_ns: pc.busy_ns,
                jobs_submitted: pc.jobs_submitted,
                jobs_completed: pc.jobs_completed,
                jobs_run: pc.jobs_run,
                preemptions: pc.preemptions,
                steals: pc.steals,
                migrations: pc.migrations,
                max_queue: pc.max_queue,
                per_core_busy_ns: pc.cores.iter().map(|c| c.busy_ns).collect(),
            },
            Engine::Reference(r) => FabricStats {
                cores: r.cores,
                busy_ns: r.busy_ns,
                jobs_submitted: r.jobs_submitted,
                jobs_completed: r.jobs_completed,
                jobs_run: r.jobs_run,
                max_queue: r.max_queue,
                ..FabricStats::default()
            },
        }
    }

    /// Utilization in [0,1] over `elapsed` virtual time. With completion-
    /// accrued busy time a mid-run sample can no longer exceed 1.0.
    pub fn utilization(&self, elapsed: Time) -> f64 {
        if elapsed == 0 {
            return 0.0;
        }
        let cores = self.cores();
        self.busy_ns() as f64 / (elapsed as f64 * cores as f64)
    }

    /// Reserve `n` cores permanently (e.g. the bypass scheduler's
    /// dedicated polling core). Lowest-index unreserved cores are taken.
    /// Unlike the seed, a mid-flight reservation takes effect at the next
    /// dispatch: a reserved core finishes its current job, then never
    /// picks another (`busy <= cores` is a checked invariant from there).
    pub fn reserve(&self, n: usize) {
        let mut inner = self.inner.borrow_mut();
        match &mut inner.engine {
            Engine::PerCore(pc) => {
                assert!(n < pc.unreserved(), "cannot reserve all {} cores", pc.unreserved());
                let mut left = n;
                for i in 0..pc.cores.len() {
                    if left == 0 {
                        break;
                    }
                    if pc.cores[i].reserved {
                        continue;
                    }
                    pc.cores[i].reserved = true;
                    pc.cores[i].pinned = false;
                    // Orphan local work migrates to the shared queues so
                    // nothing starves on a core that will never dispatch.
                    for cl in 0..NCLASS {
                        while let Some(job) = pc.cores[i].local[cl].pop_front() {
                            pc.shared[cl].push_back(job);
                        }
                    }
                    left -= 1;
                }
            }
            Engine::Reference(r) => {
                assert!(n < r.cores, "cannot reserve all {} cores", r.cores);
                r.cores -= n;
            }
        }
    }

    /// Hard-dedicate a core: it serves its local queues only. No-op in
    /// the FIFO modes (the seed model has no per-core identity).
    pub fn pin(&self, core: usize) {
        let mut inner = self.inner.borrow_mut();
        if inner.kind != FabricKind::Structural {
            return;
        }
        let Engine::PerCore(pc) = &mut inner.engine else { unreachable!() };
        assert!(!pc.cores[core].reserved, "cannot pin a reserved core");
        pc.cores[core].pinned = true;
    }

    /// Release a hard-dedicated core back to shared work; kicks a dispatch
    /// if it was idling with shared work waiting.
    pub fn unpin(&self, sim: &mut Sim, core: usize) {
        let kick = {
            let mut inner = self.inner.borrow_mut();
            let structural = inner.kind == FabricKind::Structural;
            match &mut inner.engine {
                Engine::PerCore(pc) if structural => {
                    pc.cores[core].pinned = false;
                    // Never kick mid-completion: the in-flight pc_next
                    // would double-dispatch the core.
                    pc.cores[core].running.is_none() && !pc.cores[core].completing
                }
                _ => false,
            }
        };
        if kick {
            self.pc_next(sim, core);
        }
    }

    /// Run `done` after holding a core for `duration` (shared FIFO,
    /// [`JobClass::Normal`] — the seed-compatible entry point).
    pub fn run<F: FnOnce(&mut Sim) + 'static>(&self, sim: &mut Sim, duration: Time, done: F) {
        self.run_class(sim, JobClass::Normal, duration, done);
    }

    /// Run in a priority class (shared queue of that class).
    pub fn run_class<F: FnOnce(&mut Sim) + 'static>(
        &self,
        sim: &mut Sim,
        class: JobClass,
        duration: Time,
        done: F,
    ) {
        self.submit(sim, None, class, duration, None, Box::new(done));
    }

    /// Run with soft affinity to `core`: the job waits in that core's
    /// local queue (priority over shared work there). Degrades to the
    /// shared FIFO in the compat/reference modes.
    pub fn run_on<F: FnOnce(&mut Sim) + 'static>(
        &self,
        sim: &mut Sim,
        core: usize,
        class: JobClass,
        duration: Time,
        done: F,
    ) {
        self.submit(sim, Some(core), class, duration, None, Box::new(done));
    }

    /// Like [`Self::run_on`] (or [`Self::run_class`] when `pin` is
    /// `None`), additionally invoking `obs` after every executed slice —
    /// the tracing hook. Reference mode has no slices and drops the
    /// observer; timing is unchanged either way.
    pub fn run_observed<F: FnOnce(&mut Sim) + 'static>(
        &self,
        sim: &mut Sim,
        pin: Option<usize>,
        class: JobClass,
        duration: Time,
        obs: Option<SliceObs>,
        done: F,
    ) {
        self.submit(sim, pin, class, duration, obs, Box::new(done));
    }

    fn submit(
        &self,
        sim: &mut Sim,
        pin: Option<usize>,
        class: JobClass,
        duration: Time,
        obs: Option<SliceObs>,
        done: JobFn,
    ) {
        let kind = self.inner.borrow().kind;
        match kind {
            FabricKind::ReferenceFifo => {
                // The seed engine has no per-slice structure to observe.
                drop(obs);
                let start = {
                    let mut inner = self.inner.borrow_mut();
                    let Engine::Reference(r) = &mut inner.engine else { unreachable!() };
                    r.admit(RefJob { duration, done })
                };
                if let Some(job) = start {
                    self.ref_finish_later(sim, job);
                }
            }
            FabricKind::CompatFifo | FabricKind::Structural => {
                let (pin, class) = if kind == FabricKind::CompatFifo {
                    (None, JobClass::Normal) // degrade: single shared FIFO
                } else {
                    (pin, class)
                };
                let job = Job {
                    remaining: duration,
                    class,
                    pin,
                    last_core: None,
                    started: false,
                    obs,
                    done,
                };
                self.pc_submit(sim, job);
            }
        }
    }

    // ---- per-core engine ------------------------------------------------

    fn pc_submit(&self, sim: &mut Sim, mut job: Job) {
        let decision = {
            let mut inner = self.inner.borrow_mut();
            let Engine::PerCore(pc) = &mut inner.engine else { unreachable!() };
            pc.jobs_submitted += 1;
            if let Some(c) = job.pin {
                if pc.cores[c].reserved {
                    // The target left the fabric (reserved mid-flight):
                    // fall back to the shared queue.
                    job.pin = None;
                }
            }
            match job.pin {
                Some(c) => {
                    if pc.cores[c].running.is_none() && !pc.cores[c].completing {
                        Submitted::Start(c, job)
                    } else {
                        // No advance while `completing` (running is None):
                        // the in-progress completion's pc_next picks the
                        // queued job immediately anyway.
                        let advance = pc.cfg.quantum_ns > 0
                            && pc.cores[c]
                                .running
                                .as_ref()
                                .map(|r| job.class <= r.job.class)
                                .unwrap_or(false);
                        pc.push_local(c, job);
                        if advance {
                            Submitted::Advance(c)
                        } else {
                            Submitted::Queued
                        }
                    }
                }
                None => match pc.first_open_idle() {
                    Some(c) => Submitted::Start(c, job),
                    None => {
                        let class = job.class;
                        pc.push_shared(job);
                        if pc.cfg.quantum_ns > 0 {
                            Submitted::AdvanceShared(class)
                        } else {
                            Submitted::Queued
                        }
                    }
                },
            }
        };
        match decision {
            Submitted::Start(core, job) => self.pc_dispatch(sim, core, job),
            Submitted::Advance(core) => self.pc_advance(sim, core),
            Submitted::AdvanceShared(class) => {
                // Only one core can pick the queued job, so advance just
                // the preemptable core with the *nearest* quantum edge
                // (lowest index on ties) at one cancel+reschedule per
                // arrival. Bursts spread across cores on their own: an
                // already-advanced core has `end == edge` and fails the
                // `edge < end` filter, so the next arrival advances the
                // next-nearest core, and dispatch slices at the quantum
                // while any preemptable backlog remains.
                let now = sim.now();
                let target = {
                    let inner = self.inner.borrow();
                    let Engine::PerCore(pc) = &inner.engine else { unreachable!() };
                    let q = pc.cfg.quantum_ns;
                    pc.cores
                        .iter()
                        .enumerate()
                        .filter_map(|(i, c)| {
                            if c.pinned {
                                return None;
                            }
                            let run = c.running.as_ref()?;
                            // Same preemptability rule as waiting_preempts:
                            // same-class shared work never displaces a
                            // core-affine job. A core reserved mid-slice
                            // is always preemptable — its slice end sheds
                            // the job to the shared queue.
                            let preemptable = c.reserved
                                || (class <= run.job.class
                                    && (class < run.job.class || run.job.pin != Some(i)));
                            if !preemptable {
                                return None;
                            }
                            let edge =
                                run.slice_start + ((now - run.slice_start) / q + 1) * q;
                            (edge < run.end).then_some((edge, i))
                        })
                        .min()
                        .map(|(_, i)| i)
                };
                if let Some(c) = target {
                    self.pc_advance(sim, c);
                }
            }
            Submitted::Queued => {}
        }
    }

    /// Pull the busy core's slice end forward to the next quantum edge
    /// (an equal-or-higher-priority arrival wants the core).
    fn pc_advance(&self, sim: &mut Sim, core: usize) {
        let now = sim.now();
        let resched = {
            let inner = self.inner.borrow();
            let Engine::PerCore(pc) = &inner.engine else { unreachable!() };
            let q = pc.cfg.quantum_ns;
            let run = pc.cores[core].running.as_ref().expect("advance on an idle core");
            let edge = run.slice_start + ((now - run.slice_start) / q + 1) * q;
            (edge < run.end).then_some((run.handle, edge))
        };
        if let Some((old, edge)) = resched {
            let live = sim.cancel(old);
            debug_assert!(live, "slice-end timer must be live when advanced");
            let fab = self.clone();
            let h = sim.at_handle(edge, move |sim| fab.pc_slice_end(sim, core));
            let mut inner = self.inner.borrow_mut();
            let Engine::PerCore(pc) = &mut inner.engine else { unreachable!() };
            let run = pc.cores[core].running.as_mut().unwrap();
            run.handle = h;
            run.end = edge;
        }
    }

    fn pc_dispatch(&self, sim: &mut Sim, core: usize, job: Job) {
        let now = sim.now();
        let (job, slice) = {
            let mut inner = self.inner.borrow_mut();
            let Engine::PerCore(pc) = &mut inner.engine else { unreachable!() };
            debug_assert!(!pc.cores[core].reserved, "dispatch on a reserved core");
            debug_assert!(pc.cores[core].running.is_none(), "dispatch on a busy core");
            let mut job = job;
            if !job.started {
                job.started = true;
                pc.jobs_run += 1;
                pc.cores[core].jobs_run += 1;
            }
            if let Some(last) = job.last_core {
                if last != core && pc.cfg.migration_cost_ns > 0 {
                    job.remaining += pc.cfg.migration_cost_ns;
                    pc.migrations += 1;
                }
            }
            job.last_core = Some(core);
            let q = pc.cfg.quantum_ns;
            // Slice at the quantum only when waiting work could actually
            // take the core at the edge; later arrivals advance the slice
            // end themselves, so the uncontended path stays one event.
            let slice = if q == 0 || !pc.waiting_preempts(core, job.class, job.pin) {
                job.remaining
            } else {
                job.remaining.min(q)
            };
            (job, slice)
        };
        let fab = self.clone();
        let handle = sim.at_handle(now + slice, move |sim| fab.pc_slice_end(sim, core));
        let mut inner = self.inner.borrow_mut();
        let Engine::PerCore(pc) = &mut inner.engine else { unreachable!() };
        pc.cores[core].running =
            Some(Running { job, slice_start: now, end: now + slice, handle });
    }

    fn pc_slice_end(&self, sim: &mut Sim, core: usize) {
        let now = sim.now();
        let (outcome, observed) = {
            let mut inner = self.inner.borrow_mut();
            let Engine::PerCore(pc) = &mut inner.engine else { unreachable!() };
            let mut run = pc.cores[core].running.take().expect("slice end on an idle core");
            let elapsed = now - run.slice_start;
            pc.cores[core].busy_ns += elapsed;
            pc.busy_ns += elapsed;
            run.job.remaining = run.job.remaining.saturating_sub(elapsed);
            let obs = run.job.obs.clone();
            let slice_start = run.slice_start;
            let outcome = if run.job.remaining == 0 {
                pc.jobs_completed += 1;
                // The core stays owned until the callback returns (seed
                // semantics): pc_next clears the flag before picking.
                pc.cores[core].completing = true;
                SliceOutcome::Done(run.job.done)
            } else if pc.cores[core].reserved {
                // The core was reserved mid-slice: force the job off it
                // (pin stripped — a reserved core never dispatches again,
                // so affinity to it would strand the job forever).
                pc.preemptions += 1;
                pc.cores[core].preemptions += 1;
                let mut job = run.job;
                job.pin = None;
                job.last_core = Some(core);
                pc.push_shared(job);
                SliceOutcome::Requeued
            } else if pc.waiting_preempts(core, run.job.class, run.job.pin) {
                pc.preemptions += 1;
                pc.cores[core].preemptions += 1;
                let mut job = run.job;
                job.last_core = Some(core);
                match job.pin {
                    Some(p) if !pc.cores[p].reserved => pc.push_local(p, job),
                    _ => {
                        job.pin = None;
                        pc.push_shared(job);
                    }
                }
                SliceOutcome::Requeued
            } else {
                SliceOutcome::Continue(run.job)
            };
            let kind = match &outcome {
                SliceOutcome::Done(_) => SliceEnd::Complete,
                SliceOutcome::Requeued => SliceEnd::Preempt,
                SliceOutcome::Continue(_) => SliceEnd::Continue,
            };
            let observed =
                obs.map(|o| (o, SliceRecord { core, start: slice_start, end: now, outcome: kind }));
            (outcome, observed)
        };
        // Outside the borrow, before `done`: the observer only records.
        if let Some((obs, rec)) = observed {
            obs(rec);
        }
        match outcome {
            SliceOutcome::Done(done) => {
                done(sim);
                self.pc_next(sim, core);
            }
            SliceOutcome::Requeued => self.pc_next(sim, core),
            SliceOutcome::Continue(job) => self.pc_dispatch(sim, core, job),
        }
    }

    fn pc_next(&self, sim: &mut Sim, core: usize) {
        let job = {
            let mut inner = self.inner.borrow_mut();
            let Engine::PerCore(pc) = &mut inner.engine else { unreachable!() };
            pc.cores[core].completing = false;
            pc.pick(core)
        };
        if let Some(job) = job {
            self.pc_dispatch(sim, core, job);
        }
    }

    // ---- reference (seed) engine ----------------------------------------

    fn ref_finish_later(&self, sim: &mut Sim, job: RefJob) {
        {
            let mut inner = self.inner.borrow_mut();
            let Engine::Reference(r) = &mut inner.engine else { unreachable!() };
            // Seed semantics, preserved: the full duration charges at
            // admission (the fabric accrues at completion instead).
            r.busy_ns += job.duration;
        }
        let fab = self.clone();
        let done = job.done;
        sim.after(job.duration, move |sim| {
            done(sim);
            let next = {
                let mut inner = fab.inner.borrow_mut();
                let Engine::Reference(r) = &mut inner.engine else { unreachable!() };
                r.release_one()
            };
            if let Some(job) = next {
                fab.ref_finish_later(sim, job);
            }
        });
    }

    /// Debug/test invariants: per-core busy time sums to the total, job
    /// accounting conserves, and no job runs on capacity that does not
    /// exist (`busy <= cores`, counting reserved cores only while they
    /// drain the job they held at reservation time). Thin wrapper over
    /// the structured [`Audit`] impl.
    pub fn check_invariants(&self) {
        self.assert_clean();
    }
}

/// Conservation laws of the compute fabric, checked against whichever
/// engine backs it. The totals here are the same counters exported as
/// [`FabricStats`] by `stats()`.
impl Audit for ComputeFabric {
    fn module(&self) -> &'static str {
        "simcore/fabric"
    }

    fn audit_into(&self, out: &mut Vec<Violation>) {
        let m = self.module();
        let inner = self.inner.borrow();
        match &inner.engine {
            Engine::PerCore(pc) => {
                let per_core: u64 = pc.cores.iter().map(|c| c.busy_ns).sum();
                check(out, m, "busy-total", per_core == pc.busy_ns, || {
                    format!("per-core busy_ns sums to {per_core}, total says {}", pc.busy_ns)
                });
                let starts: u64 = pc.cores.iter().map(|c| c.jobs_run).sum();
                check(out, m, "jobs-run-total", starts == pc.jobs_run, || {
                    format!("per-core job starts sum to {starts}, total says {}", pc.jobs_run)
                });
                let preempts: u64 = pc.cores.iter().map(|c| c.preemptions).sum();
                check(out, m, "preemption-total", preempts == pc.preemptions, || {
                    format!("per-core preemptions sum to {preempts}, total {}", pc.preemptions)
                });
                let running = pc.cores.iter().filter(|c| c.running.is_some()).count() as u64;
                let held = running + pc.waiting as u64;
                let conserved = pc.jobs_submitted == pc.jobs_completed + held;
                check(out, m, "job-conservation", conserved, || {
                    format!(
                        "submitted {} != completed {} + running {running} + waiting {}",
                        pc.jobs_submitted, pc.jobs_completed, pc.waiting
                    )
                });
                let busy_unreserved =
                    pc.cores.iter().filter(|c| !c.reserved && c.running.is_some()).count();
                check(out, m, "overcommit", busy_unreserved <= pc.unreserved(), || {
                    format!(
                        "{busy_unreserved} jobs running on {} schedulable cores",
                        pc.unreserved()
                    )
                });
            }
            Engine::Reference(r) => {
                let held = r.busy as u64 + r.queue.len() as u64;
                check(out, m, "job-conservation", r.jobs_submitted == r.jobs_completed + held, || {
                    format!(
                        "submitted {} != completed {} + busy {} + queued {}",
                        r.jobs_submitted,
                        r.jobs_completed,
                        r.busy,
                        r.queue.len()
                    )
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::{forall, Gen};
    use std::cell::RefCell;
    use std::rc::Rc;

    fn structural(cores: usize, cfg: FabricConfig) -> ComputeFabric {
        ComputeFabric::new_kind(FabricKind::Structural, cores, cfg)
    }

    // ---- seed-compatible behavior (ported seed tests) -------------------

    #[test]
    fn single_core_serializes() {
        let mut sim = Sim::new();
        let pool = ComputeFabric::new(1);
        let log = Rc::new(RefCell::new(Vec::new()));
        for _ in 0..3 {
            let log = log.clone();
            pool.run(&mut sim, 10, move |s| log.borrow_mut().push(s.now()));
        }
        sim.run_to_completion();
        assert_eq!(*log.borrow(), vec![10, 20, 30]);
        pool.check_invariants();
    }

    #[test]
    fn multi_core_runs_in_parallel() {
        let mut sim = Sim::new();
        let pool = ComputeFabric::new(3);
        let log = Rc::new(RefCell::new(Vec::new()));
        for _ in 0..3 {
            let log = log.clone();
            pool.run(&mut sim, 10, move |s| log.borrow_mut().push(s.now()));
        }
        sim.run_to_completion();
        assert_eq!(*log.borrow(), vec![10, 10, 10]);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut sim = Sim::new();
        let pool = ComputeFabric::new(1);
        let log = Rc::new(RefCell::new(Vec::new()));
        for i in 0..5u32 {
            let log = log.clone();
            pool.run(&mut sim, 7, move |_| log.borrow_mut().push(i));
        }
        sim.run_to_completion();
        assert_eq!(*log.borrow(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn utilization_accounts_busy_time() {
        let mut sim = Sim::new();
        let pool = ComputeFabric::new(2);
        for _ in 0..4 {
            pool.run(&mut sim, 50, |_| {});
        }
        sim.run_to_completion();
        assert_eq!(sim.now(), 100);
        assert!((pool.utilization(100) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn reserve_removes_capacity() {
        let mut sim = Sim::new();
        let pool = ComputeFabric::new(2);
        pool.reserve(1);
        let log = Rc::new(RefCell::new(Vec::new()));
        for _ in 0..2 {
            let log = log.clone();
            pool.run(&mut sim, 10, move |s| log.borrow_mut().push(s.now()));
        }
        sim.run_to_completion();
        assert_eq!(*log.borrow(), vec![10, 20]); // serialized on 1 core
        assert_eq!(pool.cores(), 1);
    }

    #[test]
    fn queue_telemetry() {
        let mut sim = Sim::new();
        let pool = ComputeFabric::new(1);
        for _ in 0..10 {
            pool.run(&mut sim, 5, |_| {});
        }
        assert_eq!(pool.queued(), 9);
        assert_eq!(pool.max_queue(), 9);
        sim.run_to_completion();
        assert_eq!(pool.queued(), 0);
        assert_eq!(pool.jobs_run(), 10);
        assert_eq!(pool.jobs_completed(), 10);
        assert_eq!(pool.busy(), 0);
        pool.check_invariants();
    }

    // ---- seed bug fixes (satellites) ------------------------------------

    #[test]
    fn reserve_under_load_takes_effect_at_next_dispatch() {
        // Seed bug: `reserve` only lowered the core count, so with a
        // backlog both cores kept refilling from the queue until it
        // drained. The fabric stops the reserved core at its current job.
        let mut sim = Sim::new();
        let pool = ComputeFabric::new(2);
        let log = Rc::new(RefCell::new(Vec::new()));
        for i in 0..6u32 {
            let log = log.clone();
            pool.run(&mut sim, 10, move |s| log.borrow_mut().push((i, s.now())));
        }
        let pool2 = pool.clone();
        sim.at(5, move |_| pool2.reserve(1));
        sim.run_to_completion();
        // Core 0 reserved mid-job: it finishes job 0 at t=10, then stops.
        // Core 1 alone serves the rest: 10, 20, 30, 40, 50.
        assert_eq!(
            *log.borrow(),
            vec![(0, 10), (1, 10), (2, 20), (3, 30), (4, 40), (5, 50)],
            "reservation must take effect at the next dispatch, not at queue drain"
        );
        assert_eq!(pool.cores(), 1);
        pool.check_invariants();
    }

    #[test]
    fn utilization_mid_run_never_exceeds_one() {
        // Seed bug: busy time charged at admission made utilization
        // sampled mid-run exceed 1.0 (a 100 ns job read 2.0 at t=50).
        let mut sim = Sim::new();
        let pool = ComputeFabric::new(1);
        pool.run(&mut sim, 100, |_| {});
        let seen = Rc::new(RefCell::new(Vec::new()));
        for t in [25u64, 50, 75, 100, 150] {
            let pool2 = pool.clone();
            let seen2 = seen.clone();
            sim.at(t, move |s| seen2.borrow_mut().push(pool2.utilization(s.now())));
        }
        sim.run_to_completion();
        for (i, u) in seen.borrow().iter().enumerate() {
            assert!(*u <= 1.0 + 1e-9, "sample {i} over-read utilization: {u}");
        }
        // Fully accrued at completion.
        assert!((pool.utilization(100) - 1.0).abs() < 1e-9);
    }

    // ---- structural semantics -------------------------------------------

    #[test]
    fn observed_slices_tile_the_job_and_tag_outcomes() {
        // Two 25 ns jobs round-robin on one core with a 10 ns quantum.
        // The observer must see every slice, the slices must sum to the
        // job's duration, and the outcomes must be Preempt at contended
        // quantum edges with exactly one final Complete.
        let cfg = FabricConfig { quantum_ns: 10, steal: false, migration_cost_ns: 0 };
        let mut sim = Sim::new();
        let pool = structural(1, cfg);
        let recs: Rc<RefCell<Vec<SliceRecord>>> = Rc::new(RefCell::new(Vec::new()));
        for _ in 0..2 {
            let recs2 = recs.clone();
            let obs: SliceObs = Rc::new(move |r| recs2.borrow_mut().push(r));
            pool.run_observed(&mut sim, None, JobClass::Normal, 25, Some(obs), |_| {});
        }
        sim.run_to_completion();
        let recs = recs.borrow();
        let total: Time = recs.iter().map(|r| r.end - r.start).sum();
        assert_eq!(total, 50, "observed slices must sum to submitted work");
        let completes = recs.iter().filter(|r| r.outcome == SliceEnd::Complete).count();
        assert_eq!(completes, 2, "one Complete per job");
        assert!(
            recs.iter().any(|r| r.outcome == SliceEnd::Preempt),
            "quantum contention must surface as Preempt slices"
        );
        for r in recs.iter() {
            assert_eq!(r.core, 0);
            assert!(r.start < r.end);
        }
        pool.check_invariants();
    }

    #[test]
    fn unobserved_jobs_are_unaffected_by_observed_peers() {
        // Timing with an observer attached must equal timing without:
        // same workload run twice, once observed, completion times equal.
        let run = |observe: bool| {
            let cfg = FabricConfig { quantum_ns: 10, steal: false, migration_cost_ns: 0 };
            let mut sim = Sim::new();
            let pool = structural(1, cfg);
            let log = Rc::new(RefCell::new(Vec::new()));
            for _ in 0..3 {
                let log = log.clone();
                let obs: Option<SliceObs> = observe.then(|| {
                    let o: SliceObs = Rc::new(|_| {});
                    o
                });
                pool.run_observed(&mut sim, None, JobClass::Normal, 25, obs, move |s| {
                    log.borrow_mut().push(s.now())
                });
            }
            sim.run_to_completion();
            let v = log.borrow().clone();
            v
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn quantum_round_robins_equal_class() {
        let cfg = FabricConfig { quantum_ns: 10, steal: false, migration_cost_ns: 0 };
        let mut sim = Sim::new();
        let pool = structural(1, cfg);
        let log = Rc::new(RefCell::new(Vec::new()));
        for i in 0..2u32 {
            let log = log.clone();
            pool.run(&mut sim, 30, move |s| log.borrow_mut().push((i, s.now())));
        }
        sim.run_to_completion();
        // Timesliced: j0 and j1 interleave in 10 ns quanta instead of the
        // FIFO's (30, 60).
        assert_eq!(*log.borrow(), vec![(0, 50), (1, 60)]);
        assert!(pool.stats().preemptions >= 2, "{:?}", pool.stats());
        pool.check_invariants();
    }

    #[test]
    fn irq_arrival_advances_to_quantum_edge() {
        let cfg = FabricConfig { quantum_ns: 10, steal: false, migration_cost_ns: 0 };
        let mut sim = Sim::new();
        let pool = structural(1, cfg);
        let log = Rc::new(RefCell::new(Vec::new()));
        {
            let log = log.clone();
            pool.run(&mut sim, 50, move |s| log.borrow_mut().push(("normal", s.now())));
        }
        {
            let pool2 = pool.clone();
            let log = log.clone();
            sim.at(12, move |sim| {
                let log = log.clone();
                pool2.run_on(sim, 0, JobClass::Irq, 5, move |s| {
                    log.borrow_mut().push(("irq", s.now()));
                });
            });
        }
        sim.run_to_completion();
        // The uncontended 50 ns slice is advanced to the t=20 edge, the
        // IRQ work runs [20,25), the tenant resumes and finishes at 55.
        assert_eq!(*log.borrow(), vec![("irq", 25), ("normal", 55)]);
        assert_eq!(pool.stats().preemptions, 1);
        pool.check_invariants();
    }

    #[test]
    fn batch_class_never_preempts_tenants() {
        let cfg = FabricConfig { quantum_ns: 10, steal: false, migration_cost_ns: 0 };
        let mut sim = Sim::new();
        let pool = structural(1, cfg);
        let log = Rc::new(RefCell::new(Vec::new()));
        {
            let log = log.clone();
            pool.run(&mut sim, 30, move |s| log.borrow_mut().push(("normal", s.now())));
        }
        {
            let log = log.clone();
            pool.run_class(&mut sim, JobClass::Batch, 10, move |s| {
                log.borrow_mut().push(("batch", s.now()));
            });
        }
        sim.run_to_completion();
        assert_eq!(*log.borrow(), vec![("normal", 30), ("batch", 40)]);
        assert_eq!(pool.stats().preemptions, 0);
    }

    #[test]
    fn steal_migrates_local_backlog_with_cost() {
        let cfg = FabricConfig { quantum_ns: 0, steal: true, migration_cost_ns: 7 };
        let mut sim = Sim::new();
        let pool = structural(2, cfg);
        let log = Rc::new(RefCell::new(Vec::new()));
        // Core 0: a running job plus a local backlog job.
        for i in 0..2u32 {
            let log = log.clone();
            pool.run_on(&mut sim, 0, JobClass::Normal, 10, move |s| {
                log.borrow_mut().push((i, s.now()));
            });
        }
        // Core 1: a short job; at its completion it steals core 0's backlog.
        {
            let log = log.clone();
            pool.run_on(&mut sim, 1, JobClass::Normal, 1, move |s| {
                log.borrow_mut().push((9, s.now()));
            });
        }
        sim.run_to_completion();
        // Stolen job pays the 7 ns migration surcharge: 1 + 10 + 7 = 18.
        assert_eq!(*log.borrow(), vec![(9, 1), (0, 10), (1, 18)]);
        let s = pool.stats();
        assert_eq!(s.steals, 1, "{s:?}");
        assert_eq!(s.migrations, 1, "{s:?}");
        pool.check_invariants();
    }

    #[test]
    fn pinned_core_serves_local_only_until_unpinned() {
        let mut sim = Sim::new();
        let pool = ComputeFabric::new(2);
        pool.pin(1);
        let log = Rc::new(RefCell::new(Vec::new()));
        for i in 0..2u32 {
            let log = log.clone();
            pool.run(&mut sim, 10, move |s| log.borrow_mut().push((i, s.now())));
        }
        {
            let log = log.clone();
            pool.run_on(&mut sim, 1, JobClass::Normal, 5, move |s| {
                log.borrow_mut().push((9, s.now()));
            });
        }
        sim.run_to_completion();
        // Shared jobs serialize on core 0; the pinned core runs only its
        // local job.
        assert_eq!(*log.borrow(), vec![(9, 5), (0, 10), (1, 20)]);
        // Unpinning an idle core kicks waiting shared work.
        for i in 10..12u32 {
            let log = log.clone();
            pool.run(&mut sim, 10, move |s| log.borrow_mut().push((i, s.now())));
        }
        let pool2 = pool.clone();
        // tie-break: fires after the same-instant run() submissions by
        // schedule order; any order leaves the same pool state.
        sim.after(0, move |sim| pool2.unpin(sim, 1));
        sim.run_to_completion();
        assert_eq!(log.borrow().len(), 5);
        pool.check_invariants();
    }

    #[test]
    fn per_core_busy_conserves_total() {
        let cfg = FabricConfig { quantum_ns: 25, steal: true, migration_cost_ns: 3 };
        let mut sim = Sim::new();
        let pool = structural(3, cfg);
        for i in 0..40u64 {
            pool.run(&mut sim, 10 + (i % 7) * 13, |_| {});
            if i % 3 == 0 {
                pool.run_on(&mut sim, (i % 3) as usize, JobClass::Irq, 5, |_| {});
            }
        }
        sim.run_to_completion();
        let s = pool.stats();
        assert_eq!(s.per_core_busy_ns.iter().sum::<u64>(), s.busy_ns);
        assert_eq!(s.jobs_submitted, s.jobs_completed);
        pool.check_invariants();
    }

    #[test]
    fn done_callback_submitting_work_keeps_seed_order() {
        // A completion callback that synchronously submits new fabric
        // work must not grab the completing core ahead of the queue (the
        // seed freed the core only after `done` ran). Pin both engines.
        for kind in [FabricKind::CompatFifo, FabricKind::ReferenceFifo] {
            let mut sim = Sim::new();
            let pool = ComputeFabric::new_kind(kind, 1, FabricConfig::default());
            let log: Rc<RefCell<Vec<(u32, Time)>>> = Rc::new(RefCell::new(Vec::new()));
            {
                let pool2 = pool.clone();
                let log2 = log.clone();
                pool.run(&mut sim, 10, move |sim| {
                    log2.borrow_mut().push((0, sim.now()));
                    let log3 = log2.clone();
                    // Submitted from inside the done callback: must queue
                    // behind job 1, not double-dispatch this core.
                    pool2.run(sim, 5, move |s| log3.borrow_mut().push((2, s.now())));
                });
            }
            {
                let log2 = log.clone();
                pool.run(&mut sim, 10, move |s| log2.borrow_mut().push((1, s.now())));
            }
            sim.run_to_completion();
            assert_eq!(
                *log.borrow(),
                vec![(0, 10), (1, 20), (2, 25)],
                "{kind:?}: callback-submitted work must wait its turn"
            );
            pool.check_invariants();
        }
    }

    #[test]
    fn reserve_mid_slice_migrates_affine_work_off_the_core() {
        // A core reserved while running a core-affine job must shed that
        // job at its next quantum edge (pin stripped) instead of
        // stranding it on a core that never dispatches again.
        let cfg = FabricConfig { quantum_ns: 5, steal: false, migration_cost_ns: 0 };
        let mut sim = Sim::new();
        let pool = structural(2, cfg);
        let log = Rc::new(RefCell::new(Vec::new()));
        {
            let log = log.clone();
            pool.run_on(&mut sim, 0, JobClass::Normal, 20, move |s| {
                log.borrow_mut().push(("x", s.now()));
            });
        }
        {
            let log = log.clone();
            pool.run_on(&mut sim, 1, JobClass::Normal, 30, move |s| {
                log.borrow_mut().push(("z", s.now()));
            });
        }
        let pool2 = pool.clone();
        sim.at(2, move |_| pool2.reserve(1)); // reserves core 0 mid-slice
        {
            let pool3 = pool.clone();
            let log = log.clone();
            sim.at(3, move |sim| {
                let log = log.clone();
                pool3.run(sim, 5, move |s| log.borrow_mut().push(("y", s.now())));
            });
        }
        sim.run_to_completion();
        // Core 0's job is forced off at the t=5 edge and finishes on core
        // 1 behind z and the queued shared job — nothing hangs.
        let done = log.borrow().clone();
        assert_eq!(done.len(), 3, "all jobs must complete: {done:?}");
        assert_eq!(pool.jobs_submitted(), pool.jobs_completed());
        assert!(pool.stats().preemptions >= 1, "{:?}", pool.stats());
        pool.check_invariants();
    }

    // ---- differential: compat engine ≡ seed reference -------------------

    /// Drive one schedule against a fabric and log (job id, completion
    /// time) in completion order, plus the final telemetry that must
    /// match (jobs_run and max_queue are event-order-sensitive).
    fn drive(
        kind: FabricKind,
        cores: usize,
        jobs: &[(Time, Time)],
    ) -> (Vec<(u32, Time)>, u64, usize) {
        let mut sim = Sim::new();
        let pool = ComputeFabric::new_kind(kind, cores, FabricConfig::default());
        let log: Rc<RefCell<Vec<(u32, Time)>>> = Rc::new(RefCell::new(Vec::new()));
        for (i, &(at, dur)) in jobs.iter().enumerate() {
            let pool2 = pool.clone();
            let log2 = log.clone();
            sim.at(at, move |sim| {
                let log3 = log2.clone();
                pool2.run(sim, dur, move |s| log3.borrow_mut().push((i as u32, s.now())));
            });
        }
        sim.run_to_completion();
        let out = log.borrow().clone();
        (out, pool.jobs_run(), pool.max_queue())
    }

    #[test]
    fn property_compat_fifo_matches_seed_reference_bit_for_bit() {
        forall("fabric compat ≡ seed FIFO", 80, |g: &mut Gen| {
            let cores = g.usize(1, 6);
            let n = g.usize(1, 40);
            let jobs: Vec<(Time, Time)> =
                (0..n).map(|_| (g.u64(0, 500), g.u64(0, 120))).collect();
            let (a, a_run, a_maxq) = drive(FabricKind::CompatFifo, cores, &jobs);
            let (b, b_run, b_maxq) = drive(FabricKind::ReferenceFifo, cores, &jobs);
            assert_eq!(a, b, "completion order/timing diverged from the seed");
            assert_eq!(a_run, b_run, "jobs_run diverged");
            assert_eq!(a_maxq, b_maxq, "max_queue diverged");
        });
    }

    #[test]
    fn structural_neutral_config_also_matches_reference() {
        // Structural kind with the neutral config (quantum = ∞, steal
        // off) and only shared Normal jobs is the compat path by another
        // name — pin it too.
        let jobs: Vec<(Time, Time)> =
            (0..30).map(|i| ((i * 37) % 200, 10 + (i * 13) % 50)).collect();
        let (a, ..) = drive(FabricKind::Structural, 3, &jobs);
        let (b, ..) = drive(FabricKind::ReferenceFifo, 3, &jobs);
        assert_eq!(a, b);
    }

    #[test]
    fn default_fabric_is_thread_local_and_restorable() {
        assert_eq!(default_fabric(), FabricKind::Structural);
        let prev = set_default_fabric(FabricKind::ReferenceFifo);
        assert_eq!(prev, FabricKind::Structural);
        assert_eq!(default_fabric(), FabricKind::ReferenceFifo);
        set_default_fabric(prev);
        assert_eq!(default_fabric(), FabricKind::Structural);
    }
}
