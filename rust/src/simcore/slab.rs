//! Slab arena of event entries.
//!
//! Every scheduled event lives in one [`EventSlab`] slot: the ordering key
//! (`(time, seq)` — stated once, as a derived lexicographic [`EventKey`]),
//! a generation counter, and the boxed callback. The ordering tiers
//! ([`super::wheel::TimerWheel`] buckets, the far/reference heaps) hold
//! only copies of `(key, idx, gen)` — 24 bytes, no pointer chasing — so
//! steady-state scheduling reuses freed slots and does **zero per-event
//! heap allocations** beyond the caller's own closure captures (a
//! zero-sized closure boxes without allocating).
//!
//! Generation checking makes cancellation O(1) and ABA-safe: cancelling a
//! handle bumps the slot's generation, so any stale `(idx, gen)` copy
//! still sitting in a wheel bucket or heap is skipped when it surfaces —
//! the engine never fires a cancelled or superseded event, even after the
//! slot has been reused.

use super::engine::{Sim, Time};

/// Boxed event callback. Zero-sized closures box without allocating.
pub(crate) type EventFn = Box<dyn FnOnce(&mut Sim)>;

/// Event ordering key. The derived lexicographic order — earlier `time`
/// first, `seq` breaking ties — is the engine's entire determinism
/// contract. Under the default [`TieBreak::SeqAscending`] policy `seq`
/// is the insertion sequence number, so simultaneous events fire in
/// schedule order; the other policies substitute a bijective remapping
/// of it (see [`TieBreak::token`]) to permute equal-time runs without
/// touching this derive.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub(crate) struct EventKey {
    pub time: Time,
    pub seq: u64,
}

/// Policy for ordering events scheduled at the **same** virtual time.
///
/// The schedule explorer (`schedcheck`) reruns whole experiments under
/// each policy: aggregate output that is byte-identical across all three
/// is certified tie-break-invariant — the property a sharded engine
/// needs, since conservative parallel execution cannot promise schedule
/// order *within* a synchronization window, only across windows.
///
/// Each policy is a bijection `seq → token`; the token replaces `seq`
/// inside [`EventKey`], so key uniqueness (and therefore the total event
/// order) is preserved and the ordering structures stay untouched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TieBreak {
    /// Ties fire in schedule order — today's behaviour, bit-identical to
    /// the engine before this policy existed (`token(seq) == seq`).
    SeqAscending,
    /// Ties fire in reverse schedule order (`token(seq) == !seq`).
    SeqDescending,
    /// Ties fire in a seeded pseudo-random order: `seq` is passed through
    /// a splitmix64-style finalizer (every step invertible, so distinct
    /// seqs keep distinct tokens) salted with the seed.
    SeededShuffle(u64),
}

impl TieBreak {
    /// The tie-break token stored in [`EventKey::seq`] for insertion
    /// sequence number `seq`. Bijective for every policy.
    pub(crate) fn token(self, seq: u64) -> u64 {
        match self {
            TieBreak::SeqAscending => seq,
            TieBreak::SeqDescending => !seq,
            TieBreak::SeededShuffle(seed) => {
                let mut z = seq.wrapping_add(seed).wrapping_add(0x9e37_79b9_7f4a_7c15);
                z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
                z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
                z ^ (z >> 31)
            }
        }
    }
}

/// Generation-checked handle to a scheduled event, returned by
/// [`Sim::at_handle`] / [`Sim::after_handle`]. Supports O(1)
/// [`Sim::cancel`] and [`Sim::reschedule`]; a handle whose event already
/// fired, was cancelled, or was rescheduled is simply stale (cancel
/// returns `false`), never dangling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimerHandle {
    pub(crate) idx: u32,
    pub(crate) gen: u32,
}

struct Slot {
    gen: u32,
    key: EventKey,
    /// Insertion sequence number (the pre-tie-break identity of the
    /// event). Under [`TieBreak::SeqAscending`] this equals `key.seq`;
    /// under the other policies it is the schedule-order diagnostic the
    /// explorer reports divergences in.
    orig: u64,
    cb: Option<EventFn>,
}

/// Arena of event slots with a free list. Slots are reused in LIFO order,
/// so a steady-state schedule/fire workload touches a small, hot set of
/// slots and never allocates.
pub(crate) struct EventSlab {
    slots: Vec<Slot>,
    free: Vec<u32>,
    live: usize,
}

impl EventSlab {
    pub fn new() -> Self {
        EventSlab { slots: Vec::new(), free: Vec::new(), live: 0 }
    }

    /// Live (scheduled, not yet fired/cancelled) events.
    pub fn len(&self) -> usize {
        self.live
    }

    /// Store an event; returns its generation-checked handle. `orig` is
    /// the insertion sequence number before tie-break tokenization.
    pub fn insert(&mut self, key: EventKey, orig: u64, cb: EventFn) -> TimerHandle {
        self.live += 1;
        if let Some(idx) = self.free.pop() {
            let s = &mut self.slots[idx as usize];
            debug_assert!(s.cb.is_none(), "free-list slot still holds a callback");
            s.key = key;
            s.orig = orig;
            s.cb = Some(cb);
            TimerHandle { idx, gen: s.gen }
        } else {
            let idx = u32::try_from(self.slots.len()).expect("event slab exceeded u32 slots");
            self.slots.push(Slot { gen: 0, key, orig, cb: Some(cb) });
            TimerHandle { idx, gen: 0 }
        }
    }

    /// Take the callback out if `(idx, gen)` is still live, freeing the
    /// slot. Returns `None` for stale references (already fired, cancelled
    /// or rescheduled) — the lazy-deletion check every ordering tier
    /// relies on.
    pub fn take(&mut self, idx: u32, gen: u32) -> Option<(EventKey, u64, EventFn)> {
        let s = self.slots.get_mut(idx as usize)?;
        if s.gen != gen {
            return None;
        }
        let cb = s.cb.take()?;
        let key = s.key;
        let orig = s.orig;
        s.gen = s.gen.wrapping_add(1);
        self.free.push(idx);
        self.live -= 1;
        Some((key, orig, cb))
    }

    /// Drop the event behind the handle (O(1) cancellation). Returns
    /// `true` when a live event was cancelled.
    pub fn cancel(&mut self, h: TimerHandle) -> bool {
        self.take(h.idx, h.gen).is_some()
    }

    /// Key of a still-live handle (tests / diagnostics).
    #[cfg(test)]
    pub fn key_of(&self, h: TimerHandle) -> Option<EventKey> {
        let s = self.slots.get(h.idx as usize)?;
        if s.gen != h.gen || s.cb.is_none() {
            return None;
        }
        Some(s.key)
    }

    /// Total slots ever created (capacity telemetry for the §Perf bench).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Earliest key time among live events, `None` when nothing is
    /// pending. O(slots): a linear scan over the arena, intended for the
    /// shard runner's idle fast-forward (called only when the live count
    /// is small — the ordering tiers cannot answer this without popping,
    /// and popping would re-sequence ties).
    pub fn min_time(&self) -> Option<Time> {
        if self.live == 0 {
            return None;
        }
        self.slots.iter().filter(|s| s.cb.is_some()).map(|s| s.key.time).min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(time: Time, seq: u64) -> EventKey {
        EventKey { time, seq }
    }

    #[test]
    fn key_orders_by_time_then_seq() {
        assert!(key(1, 9) < key(2, 0));
        assert!(key(5, 1) < key(5, 2));
        assert_eq!(key(3, 3), key(3, 3));
        // The derive states the invariant once: plain lexicographic order.
        let mut v = vec![key(2, 0), key(1, 1), key(1, 0), key(2, 1)];
        v.sort();
        assert_eq!(v, vec![key(1, 0), key(1, 1), key(2, 0), key(2, 1)]);
    }

    #[test]
    fn insert_take_roundtrip() {
        let mut slab = EventSlab::new();
        let h = slab.insert(key(10, 0), 0, Box::new(|_| {}));
        assert_eq!(slab.len(), 1);
        assert_eq!(slab.key_of(h), Some(key(10, 0)));
        let (k, orig, _cb) = slab.take(h.idx, h.gen).expect("live");
        assert_eq!(k, key(10, 0));
        assert_eq!(orig, 0);
        assert_eq!(slab.len(), 0);
        // Second take is stale.
        assert!(slab.take(h.idx, h.gen).is_none());
    }

    #[test]
    fn cancelled_handle_goes_stale_and_slot_is_reused() {
        let mut slab = EventSlab::new();
        let a = slab.insert(key(1, 0), 0, Box::new(|_| {}));
        assert!(slab.cancel(a));
        assert!(!slab.cancel(a), "double cancel must be a no-op");
        // The freed slot is reused with a bumped generation: the old
        // handle stays stale even though the index matches.
        let b = slab.insert(key(2, 1), 1, Box::new(|_| {}));
        assert_eq!(a.idx, b.idx, "LIFO free list must reuse the slot");
        assert_ne!(a.gen, b.gen);
        assert!(slab.take(a.idx, a.gen).is_none(), "stale gen must not take");
        assert!(slab.take(b.idx, b.gen).is_some());
    }

    #[test]
    fn steady_state_reuses_slots_without_growth() {
        let mut slab = EventSlab::new();
        // Prime two slots, then churn: capacity must not grow.
        let h1 = slab.insert(key(1, 0), 0, Box::new(|_| {}));
        let h2 = slab.insert(key(2, 1), 1, Box::new(|_| {}));
        slab.take(h1.idx, h1.gen);
        slab.take(h2.idx, h2.gen);
        let cap = slab.capacity();
        for i in 0..10_000u64 {
            let a = slab.insert(key(i, i), i, Box::new(|_| {}));
            let b = slab.insert(key(i, i + 1), i + 1, Box::new(|_| {}));
            slab.take(a.idx, a.gen);
            slab.cancel(b);
        }
        assert_eq!(slab.capacity(), cap, "steady-state churn must not grow the slab");
        assert_eq!(slab.len(), 0);
    }

    #[test]
    fn tiebreak_tokens_are_bijective_and_order_as_documented() {
        use std::collections::BTreeSet;
        // Ascending is the identity (the bit-compatibility guarantee);
        // descending reverses; shuffle permutes without collisions.
        for seq in [0u64, 1, 7, u64::MAX - 1] {
            assert_eq!(TieBreak::SeqAscending.token(seq), seq);
            assert_eq!(TieBreak::SeqDescending.token(seq), !seq);
        }
        assert!(TieBreak::SeqDescending.token(5) < TieBreak::SeqDescending.token(4));
        for seed in [0u64, 17, 0xdead_beef] {
            let p = TieBreak::SeededShuffle(seed);
            let tokens: BTreeSet<u64> = (0..4096u64).map(|s| p.token(s)).collect();
            assert_eq!(tokens.len(), 4096, "seeded shuffle must stay injective");
        }
        // Distinct seeds give distinct permutations (overwhelmingly).
        let a: Vec<u64> = (0..64u64).map(|s| TieBreak::SeededShuffle(1).token(s)).collect();
        let b: Vec<u64> = (0..64u64).map(|s| TieBreak::SeededShuffle(2).token(s)).collect();
        assert_ne!(a, b);
    }
}
