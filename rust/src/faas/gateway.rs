//! The faasd front-end gateway: auth, route lookup, replica round-robin.

use std::collections::BTreeMap;

/// Gateway routing state + counters.
#[derive(Debug, Default)]
pub struct Gateway {
    rr: BTreeMap<String, usize>,
    pub requests: u64,
    pub auth_failures: u64,
    pub route_misses: u64,
}

impl Gateway {
    pub fn new() -> Self {
        Self::default()
    }

    /// Authenticate a request (stub: a shared-secret check; the cost is
    /// part of `gateway_cpu_ns` in the platform model).
    pub fn authenticate(&mut self, token: &str) -> bool {
        let ok = !token.is_empty();
        if !ok {
            self.auth_failures += 1;
        }
        ok
    }

    /// Pick a replica for `name` by round-robin over `n_replicas`.
    /// Returns `None` (and counts a miss) when the function is unknown or
    /// has no replicas — the caller surfaces a 404/503. A miss also evicts
    /// any stale counter so unknown-function probes cannot pin state.
    pub fn route(&mut self, name: &str, n_replicas: u32) -> Option<u32> {
        self.requests += 1;
        if n_replicas == 0 {
            self.route_misses += 1;
            self.rr.remove(name);
            return None;
        }
        let ctr = self.rr.entry(name.to_string()).or_insert(0);
        let pick = (*ctr % n_replicas as usize) as u32;
        *ctr += 1;
        Some(pick)
    }

    /// Drop the round-robin counter for `name` (called on undeploy).
    /// Without this the `rr` map grows without bound under function churn
    /// — a million-function trace leaks a counter per retired name.
    pub fn evict(&mut self, name: &str) {
        self.rr.remove(name);
    }

    /// Number of functions with live routing state (leak telemetry).
    pub fn tracked_functions(&self) -> usize {
        self.rr.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut gw = Gateway::new();
        let picks: Vec<u32> = (0..6).map(|_| gw.route("f", 3).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn independent_counters_per_function() {
        let mut gw = Gateway::new();
        assert_eq!(gw.route("a", 2), Some(0));
        assert_eq!(gw.route("b", 2), Some(0));
        assert_eq!(gw.route("a", 2), Some(1));
    }

    #[test]
    fn zero_replicas_is_miss() {
        let mut gw = Gateway::new();
        assert_eq!(gw.route("gone", 0), None);
        assert_eq!(gw.route_misses, 1);
    }

    #[test]
    fn rr_counters_do_not_leak_under_churn() {
        let mut gw = Gateway::new();
        for i in 0..1000 {
            let name = format!("fn-{i}");
            assert!(gw.route(&name, 2).is_some());
            gw.evict(&name); // undeploy
        }
        assert_eq!(gw.tracked_functions(), 0, "retired functions must not pin counters");
        // A live function keeps exactly one counter...
        gw.route("live", 2);
        assert_eq!(gw.tracked_functions(), 1);
        // ...and an unknown-function miss evicts stale state too.
        gw.route("live", 0);
        assert_eq!(gw.tracked_functions(), 0);
    }

    #[test]
    fn auth_stub() {
        let mut gw = Gateway::new();
        assert!(gw.authenticate("secret"));
        assert!(!gw.authenticate(""));
        assert_eq!(gw.auth_failures, 1);
    }
}
