//! Function registry: what is deployed, how it scales, what it runs.

use std::collections::BTreeMap;

use crate::simcore::Time;

/// Language runtime of the function image — determines the §3 scale-up
/// mode junctiond picks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuntimeKind {
    /// No native parallelism → scale with multiple uProcs per instance.
    Python,
    /// Native threads → scale by raising the instance's max-core cap
    /// (custom Go compile target per §5 "Functions benchmark").
    Go,
    /// Native threads via LD_PRELOAD'd glibc (§4).
    Cpp,
}

/// How junctiond scales a function's concurrency (§3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleMode {
    /// Multiple processes inside one Junction instance (Python-style).
    MultiProcess,
    /// Raise the uProc's max core assignment (Go/C++-style).
    MaxCores,
    /// Independent Junction instances per replica ("if isolation is
    /// required across instances of the same function").
    IsolatedInstances,
}

impl RuntimeKind {
    pub fn default_scale_mode(self) -> ScaleMode {
        match self {
            RuntimeKind::Python => ScaleMode::MultiProcess,
            RuntimeKind::Go | RuntimeKind::Cpp => ScaleMode::MaxCores,
        }
    }
}

/// A deployed function.
#[derive(Debug, Clone)]
pub struct FunctionSpec {
    pub name: String,
    /// AOT artifact the worker executes (e.g. `aes600`).
    pub artifact: String,
    pub runtime: RuntimeKind,
    pub scale_mode: ScaleMode,
    /// Desired concurrency (uProcs or max cores, per mode).
    pub scale: u32,
    /// Per-function body compute override (ns). `None` uses the sim-wide
    /// calibrated cost; multi-tenant experiments give antagonist tenants
    /// chunkier bodies than the latency-sensitive function (E14).
    pub compute_ns: Option<Time>,
    /// Batch-class work: sheddable first under admission-control
    /// brownout when healthy cluster capacity drops below the
    /// `fault_brownout_watermark_bp` watermark (E16).
    pub batch: bool,
}

impl FunctionSpec {
    pub fn new(name: &str, artifact: &str, runtime: RuntimeKind) -> Self {
        FunctionSpec {
            name: name.to_string(),
            artifact: artifact.to_string(),
            runtime,
            scale_mode: runtime.default_scale_mode(),
            scale: 1,
            compute_ns: None,
            batch: false,
        }
    }

    pub fn with_scale(mut self, mode: ScaleMode, scale: u32) -> Self {
        self.scale_mode = mode;
        self.scale = scale.max(1);
        self
    }

    pub fn with_compute(mut self, compute_ns: Time) -> Self {
        self.compute_ns = Some(compute_ns);
        self
    }

    pub fn with_batch(mut self) -> Self {
        self.batch = true;
        self
    }
}

/// Deployed-function table (gateway + provider both consult it).
#[derive(Debug, Default)]
pub struct Registry {
    functions: BTreeMap<String, FunctionSpec>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn deploy(&mut self, spec: FunctionSpec) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.functions.contains_key(&spec.name),
            "function '{}' already deployed",
            spec.name
        );
        self.functions.insert(spec.name.clone(), spec);
        Ok(())
    }

    pub fn remove(&mut self, name: &str) -> Option<FunctionSpec> {
        self.functions.remove(name)
    }

    pub fn get(&self, name: &str) -> Option<&FunctionSpec> {
        self.functions.get(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.functions.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.functions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.functions.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deploy_and_lookup() {
        let mut r = Registry::new();
        r.deploy(FunctionSpec::new("aes", "aes600", RuntimeKind::Go)).unwrap();
        assert_eq!(r.get("aes").unwrap().artifact, "aes600");
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn duplicate_deploy_rejected() {
        let mut r = Registry::new();
        r.deploy(FunctionSpec::new("aes", "aes600", RuntimeKind::Go)).unwrap();
        assert!(r.deploy(FunctionSpec::new("aes", "aes600", RuntimeKind::Go)).is_err());
    }

    #[test]
    fn scale_modes_follow_runtime() {
        assert_eq!(RuntimeKind::Python.default_scale_mode(), ScaleMode::MultiProcess);
        assert_eq!(RuntimeKind::Go.default_scale_mode(), ScaleMode::MaxCores);
        assert_eq!(RuntimeKind::Cpp.default_scale_mode(), ScaleMode::MaxCores);
    }

    #[test]
    fn remove_undeploys() {
        let mut r = Registry::new();
        r.deploy(FunctionSpec::new("aes", "aes600", RuntimeKind::Go)).unwrap();
        assert!(r.remove("aes").is_some());
        assert!(r.get("aes").is_none());
        assert!(r.is_empty());
    }
}
