//! The faasd-shaped FaaS runtime (paper §2.1, Figure 2).
//!
//! faasd's invocation path is: client → **gateway** → **provider** →
//! function instance, with every hop a gRPC-ish RPC. This module carries
//! the runtime pieces that are backend-agnostic:
//!
//! * [`Registry`] / [`FunctionSpec`] — deployed function metadata.
//! * [`Gateway`] — authentication stub + replica round-robin routing.
//! * [`Provider`] — resolve/scale logic with the §4 **metadata cache**
//!   (replica count + instance address cached so containerd/junctiond
//!   state queries leave the critical path).
//! * [`Gate`] — DES counting semaphore modeling per-instance concurrency.
//! * [`pipeline`] — the discrete-event invocation pipeline for both
//!   backends (the simulation counterpart of `server/` which runs the
//!   same topology on real sockets).
//! * [`shardcluster`] — the message-passing cluster model that runs the
//!   gateway and worker racks as endpoints on the parallel shard runner
//!   (`simcore::shard`, DESIGN.md §3j).

pub mod cluster;
mod gate;
mod gateway;
pub mod pipeline;
mod provider;
mod registry;
pub mod shardcluster;

pub use cluster::{Cluster, Placement, RecoveryStats, ScalePolicy, Worker, WorkerHealth};
pub use gate::Gate;
pub use gateway::Gateway;
pub use pipeline::{CostTelemetry, FaasSim, RequestTiming};
pub use provider::{CacheOutcome, Provider, ReplicaMeta};
pub use registry::{FunctionSpec, Registry, RuntimeKind, ScaleMode};
pub use shardcluster::{
    run_shard_cluster, ClusterMsg, GatewayTotals, ShardClusterCfg, ShardClusterOut, ShardHost,
    WorkerTotals,
};
