//! DES counting semaphore: per-instance concurrency control.
//!
//! A containerd function container admits `container_concurrency` requests
//! at once (classic-watchdog fork model ≈ 1); a Junction instance admits
//! `concurrency()` (uProcs × threads). Excess requests queue FIFO at the
//! instance — this queueing is what bends the Fig. 6 latency curve at the
//! backend-specific knee.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use crate::simcore::Sim;

type Waiter = Box<dyn FnOnce(&mut Sim)>;

struct GateInner {
    capacity: u32,
    in_use: u32,
    waiters: VecDeque<Waiter>,
    max_waiters: usize,
    admitted: u64,
}

/// Cloneable handle to a concurrency gate.
#[derive(Clone)]
pub struct Gate {
    inner: Rc<RefCell<GateInner>>,
}

impl Gate {
    pub fn new(capacity: u32) -> Self {
        assert!(capacity >= 1);
        Gate {
            inner: Rc::new(RefCell::new(GateInner {
                capacity,
                in_use: 0,
                waiters: VecDeque::new(),
                max_waiters: 0,
                admitted: 0,
            })),
        }
    }

    /// Raise (or lower) capacity at runtime (junctiond scale-up). Lowering
    /// never revokes admitted requests, matching the real system.
    pub fn set_capacity(&self, sim: &mut Sim, capacity: u32) {
        assert!(capacity >= 1);
        self.inner.borrow_mut().capacity = capacity;
        // Newly freed slots admit waiters.
        self.pump(sim);
    }

    pub fn capacity(&self) -> u32 {
        self.inner.borrow().capacity
    }

    pub fn in_use(&self) -> u32 {
        self.inner.borrow().in_use
    }

    pub fn waiting(&self) -> usize {
        self.inner.borrow().waiters.len()
    }

    pub fn max_waiting(&self) -> usize {
        self.inner.borrow().max_waiters
    }

    pub fn admitted(&self) -> u64 {
        self.inner.borrow().admitted
    }

    /// Acquire a slot; `go` runs immediately (same virtual instant) if a
    /// slot is free, otherwise when one frees up.
    pub fn acquire<F: FnOnce(&mut Sim) + 'static>(&self, sim: &mut Sim, go: F) {
        let mut g = self.inner.borrow_mut();
        if g.in_use < g.capacity {
            g.in_use += 1;
            g.admitted += 1;
            drop(g);
            go(sim);
        } else {
            g.waiters.push_back(Box::new(go));
            let w = g.waiters.len();
            if w > g.max_waiters {
                g.max_waiters = w;
            }
        }
    }

    /// Release a slot, then admit waiters only while `in_use` is below
    /// `capacity` (via `pump`). The released slot must *not* be handed to
    /// a waiter unconditionally: after `set_capacity` lowered the limit,
    /// doing so pins `in_use` above the new capacity forever (the gate
    /// never drains down to the new limit).
    pub fn release(&self, sim: &mut Sim) {
        {
            let mut g = self.inner.borrow_mut();
            assert!(g.in_use > 0, "release without acquire");
            g.in_use -= 1;
        }
        self.pump(sim);
    }

    fn pump(&self, sim: &mut Sim) {
        loop {
            let next = {
                let mut g = self.inner.borrow_mut();
                if g.in_use < g.capacity && !g.waiters.is_empty() {
                    g.in_use += 1;
                    g.admitted += 1;
                    g.waiters.pop_front()
                } else {
                    None
                }
            };
            match next {
                Some(w) => w(sim),
                None => break,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[test]
    fn serializes_at_capacity_one() {
        let mut sim = Sim::new();
        let gate = Gate::new(1);
        let log = Rc::new(RefCell::new(Vec::new()));
        for i in 0..3u32 {
            let gate2 = gate.clone();
            let log = log.clone();
            gate.acquire(&mut sim, move |sim| {
                let log = log.clone();
                let gate3 = gate2.clone();
                // tie-break: grants at the same instant are the point —
                // the asserted log pins the gate's FIFO grant order.
                sim.after(10, move |sim| {
                    log.borrow_mut().push((i, sim.now()));
                    gate3.release(sim);
                });
            });
        }
        sim.run_to_completion();
        assert_eq!(*log.borrow(), vec![(0, 10), (1, 20), (2, 30)]);
    }

    #[test]
    fn capacity_two_overlaps() {
        let mut sim = Sim::new();
        let gate = Gate::new(2);
        let log = Rc::new(RefCell::new(Vec::new()));
        for i in 0..4u32 {
            let gate2 = gate.clone();
            let log = log.clone();
            gate.acquire(&mut sim, move |sim| {
                let log = log.clone();
                let gate3 = gate2.clone();
                // tie-break: grants at the same instant are the point —
                // the asserted log pins the gate's FIFO grant order.
                sim.after(10, move |sim| {
                    log.borrow_mut().push((i, sim.now()));
                    gate3.release(sim);
                });
            });
        }
        sim.run_to_completion();
        assert_eq!(*log.borrow(), vec![(0, 10), (1, 10), (2, 20), (3, 20)]);
    }

    #[test]
    fn scale_up_admits_waiters() {
        let mut sim = Sim::new();
        let gate = Gate::new(1);
        let started = Rc::new(RefCell::new(0u32));
        for _ in 0..3 {
            let started = started.clone();
            gate.acquire(&mut sim, move |_| *started.borrow_mut() += 1);
        }
        assert_eq!(*started.borrow(), 1);
        assert_eq!(gate.waiting(), 2);
        gate.set_capacity(&mut sim, 3);
        assert_eq!(*started.borrow(), 3);
        assert_eq!(gate.waiting(), 0);
    }

    #[test]
    #[should_panic(expected = "release without acquire")]
    fn release_underflow_panics() {
        let mut sim = Sim::new();
        Gate::new(1).release(&mut sim);
    }

    /// Regression for the capacity-lowering leak: after `set_capacity`
    /// shrinks the gate, a release must not admit a waiter while `in_use`
    /// still exceeds the new limit. The old `release` admitted
    /// unconditionally, so concurrency never converged down to the new
    /// capacity (observed here as >1 overlapping executions after the
    /// scale-down).
    #[test]
    fn scale_down_converges() {
        let mut sim = Sim::new();
        let gate = Gate::new(4);
        let active = Rc::new(RefCell::new(0i32));
        let max_after_scale_down = Rc::new(RefCell::new(0i32));
        let scaled = Rc::new(RefCell::new(false));
        for _ in 0..8 {
            let gate2 = gate.clone();
            let active2 = active.clone();
            let max2 = max_after_scale_down.clone();
            let scaled2 = scaled.clone();
            gate.acquire(&mut sim, move |sim| {
                *active2.borrow_mut() += 1;
                if *scaled2.borrow() {
                    let cur = *active2.borrow();
                    let mut m = max2.borrow_mut();
                    if cur > *m {
                        *m = cur;
                    }
                }
                let active3 = active2.clone();
                // tie-break: the tied releases at each instant are
                // symmetric; only the concurrency high-water mark is
                // asserted, not which waiter runs first.
                sim.after(10, move |sim| {
                    *active3.borrow_mut() -= 1;
                    gate2.release(sim);
                });
            });
        }
        assert_eq!(gate.in_use(), 4);
        assert_eq!(gate.waiting(), 4);
        gate.set_capacity(&mut sim, 1);
        *scaled.borrow_mut() = true;
        sim.run_to_completion();
        assert_eq!(
            *max_after_scale_down.borrow(),
            1,
            "waiters admitted past the lowered capacity"
        );
        assert_eq!(gate.in_use(), 0);
        assert_eq!(gate.waiting(), 0);
        assert_eq!(gate.admitted(), 8, "every queued request must still run");
    }
}
