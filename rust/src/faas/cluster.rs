//! Cluster layer: the rest of the paper's Figure 1 — a pool of worker
//! servers behind the gateway, a **controller** that deploys function
//! instances and autoscales them, and a **worker manager** that grows and
//! shrinks the pool.
//!
//! faasd itself is single-node (§2.1.1), which is why the headline
//! experiments (E1/E2) run on one worker; this module builds the
//! general-architecture version (§2.1: "gateway … controller … worker
//! manager … workers are also typically deployed on separate servers") so
//! the repo covers the full system a deployment would need. The cluster
//! experiments (`experiments::autoscale_table`, E8) exercise it.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::config::{Backend, ExperimentConfig, PlatformConfig};
use crate::invariants::{check, AuditTree, Violation};
use crate::junction::BypassCosts;
use crate::netpath::{NicQueue, NicStats, Packet, TxStats};
use crate::oskernel::KernelCosts;
use crate::rpc::Message;
use crate::simcore::{Rng, Sim, Time, TimerHandle, MILLIS, SECONDS};
use crate::telemetry::{Hop, Tracer};

use super::pipeline::{trace_finish, FaasSim, RequestTiming};
use super::registry::FunctionSpec;

/// Scaling policy knobs for the controller (per function).
#[derive(Debug, Clone)]
pub struct ScalePolicy {
    /// Target in-flight requests per replica before scaling up.
    pub target_inflight_per_replica: f64,
    /// Min/max replicas (min 0 enables scale-to-zero).
    pub min_replicas: u32,
    pub max_replicas: u32,
    /// Idle duration after which a function scales to zero.
    pub scale_to_zero_after: Time,
    /// Controller reconcile interval.
    pub interval: Time,
    /// Request scale-up instances through the tiered provisioning ladder
    /// (warm pool → snapshot restore → cold boot). Off = always cold boot
    /// (the seed's behavior, kept as the ablation baseline).
    pub warm_pool: bool,
}

impl Default for ScalePolicy {
    fn default() -> Self {
        ScalePolicy {
            target_inflight_per_replica: 4.0,
            min_replicas: 1,
            max_replicas: 8,
            scale_to_zero_after: 30 * SECONDS,
            interval: 500 * MILLIS,
            warm_pool: true,
        }
    }
}

/// Per-worker health view the recovery router reads: response-time EWMA,
/// consecutive-failure ejection, and crash downtime. All zeroes until the
/// fault plane or the recovery path writes it — the fast path never does.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerHealth {
    /// EWMA of recovery-path response times on this worker (ns; 0 until
    /// the first sample). Routing tiebreak: prefer the faster worker.
    pub ewma_ns: Time,
    /// Consecutive failed attempts routed here since the last success.
    pub consec_fails: u32,
    /// Ejected from routing until this virtual time (health checker).
    pub ejected_until: Time,
    /// Marked down (worker crash) until this virtual time.
    pub down_until: Time,
}

/// Counters of the end-to-end recovery machinery (deadline timeouts,
/// cross-worker retries, hedges, brownout sheds, wire losses, health
/// ejections). Carries one law: a hedge can only win if it was issued.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecoveryStats {
    /// Requests resolved by the gateway-side deadline.
    pub timed_out: u64,
    /// Attempts re-issued on another replica after a failure.
    pub retries_other: u64,
    /// Hedged duplicates issued after the quantile delay.
    pub hedges: u64,
    /// Requests whose hedge beat the primary.
    pub hedge_wins: u64,
    /// Batch-class submissions shed by the admission brownout.
    pub shed_batch: u64,
    /// Attempts eaten by an active wire-loss window.
    pub wire_lost: u64,
    /// Health-checker ejections (consecutive-failure threshold hit).
    pub ejections: u64,
}

/// One worker server: an independent single-node `FaasSim` (its own core
/// pool, scheduler, containerd, cost samplers) plus placement metadata.
pub struct Worker {
    pub id: u32,
    pub sim_node: FaasSim,
    /// Functions with a replica on this worker.
    pub hosted: Vec<String>,
    pub in_flight: Rc<RefCell<i64>>,
    /// Health view (EWMA, ejection, downtime) the recovery router reads.
    pub health: Rc<RefCell<WorkerHealth>>,
}

/// The front end's own RX NIC: response frames coming back from the
/// workers land in a bounded ring at the cluster gateway and pay *that*
/// machine's per-packet (kernel) or per-burst (bypass) receive costs
/// before the client sees them — the gateway-side half of the full-duplex
/// path. A full ring backpressures the worker side: the held frame is
/// re-offered after the retry backoff; the front end never abandons a
/// response the cluster already paid to compute.
struct FrontendRx {
    nic: NicQueue,
    kc: KernelCosts,
    bc: BypassCosts,
    backend: Backend,
    platform: Rc<PlatformConfig>,
    /// Shared cluster tracer. The front end owns trace completion: a
    /// worker's `done` fires before the return wire + frontend RX, which
    /// belong to the trace's tx hop.
    tracer: Tracer,
}

type RespFn = Box<dyn FnOnce(&mut Sim, RequestTiming)>;

/// Offer one worker response frame to the front end's RX ring.
fn frontend_rx_ingress(
    front: Rc<RefCell<FrontendRx>>,
    sim: &mut Sim,
    t: RequestTiming,
    done: RespFn,
) {
    let mut resp = Some((t, done));
    let kicked = {
        let mut f = front.borrow_mut();
        if !f.nic.is_full() {
            let (t, done) = resp.take().expect("response consumed before accept");
            let bytes = Message::response_frame_size(f.platform.rpc_payload_bytes as usize);
            // The frontend ring wait closes the trace's tx hop; the span
            // and the trace itself complete at delivery.
            let ring_trace = (t.seq != 0).then(|| (f.tracer.clone(), sim.now()));
            let kick = f.nic.enqueue(Packet {
                bytes,
                enqueued_at: sim.now(),
                deliver: Box::new(move |sim| {
                    let mut t = t;
                    t.done = sim.now();
                    if let Some((tracer, enq)) = ring_trace {
                        tracer.event(t.seq, Hop::Tx, "front.rx", "frontend_ring", enq, t.done);
                        trace_finish(&tracer, &t);
                    }
                    done(sim, t);
                }),
            });
            Some(kick)
        } else {
            // Backpressure, not loss: the frame is held, so this is not
            // an `rx_dropped` (which means shed-on-the-wire everywhere
            // else) — count only the re-offer it schedules.
            f.nic.stats.retries += 1;
            None
        }
    };
    match kicked {
        Some(true) => {
            let front2 = front.clone();
            // The deferred kick lets a burst of tied responses coalesce
            // into one drain batch; the drain pops whatever is ringed.
            // tie-break: order among tied deliveries only moves batch
            // boundaries, never which responses are delivered.
            sim.after(0, move |sim| frontend_rx_drain(front2, sim));
        }
        Some(false) => {}
        None => {
            let backoff = front.borrow().platform.nic_retry_backoff_ns;
            let (t, done) = resp.take().expect("response consumed before re-offer");
            if t.seq != 0 {
                let now = sim.now();
                let tr = front.borrow().tracer.clone();
                tr.event(t.seq, Hop::Tx, "front.backoff", "ring_full", now, now + backoff);
            }
            let front2 = front.clone();
            sim.after(backoff, move |sim| frontend_rx_ingress(front2, sim, t, done));
        }
    }
}

/// Drain one burst off the front end's RX ring, charging that machine's
/// receive costs: per-packet IRQ + stack + copy + app receive on the
/// kernel path; a polled zero-copy burst on the bypass path, the flat
/// poll-iteration cost amortizing across the batch (the front end has no
/// central scheduler, so the platform constant stands in for its polling
/// core's iteration).
fn frontend_rx_drain(front: Rc<RefCell<FrontendRx>>, sim: &mut Sim) {
    let (deliveries, burst_ns) = {
        let mut f = front.borrow_mut();
        let burst_max = match f.backend {
            Backend::Containerd => 1,
            Backend::Junctiond => f.platform.nic_batch_max as usize,
        };
        let pkts = f.nic.pop_burst(burst_max, sim.now());
        let copy_per_kb = f.platform.nic_copy_ns_per_kb;
        let mut deliveries: Vec<(Time, Box<dyn FnOnce(&mut Sim)>)> =
            Vec::with_capacity(pkts.len());
        let mut offset: Time = 0;
        match f.backend {
            Backend::Containerd => {
                for p in pkts {
                    let copy = p.bytes as Time * copy_per_kb / 1024;
                    let cost = f.kc.nic_rx_packet(copy) + f.kc.app_recv();
                    offset += cost;
                    deliveries.push((offset, p.deliver));
                }
            }
            Backend::Junctiond => {
                if !pkts.is_empty() {
                    offset += f.platform.junction_poll_iter_ns;
                }
                for p in pkts {
                    offset += f.bc.rx_poll_packet();
                    deliveries.push((offset, p.deliver));
                }
            }
        }
        (deliveries, offset)
    };
    for (off, deliver) in deliveries {
        sim.after(off, deliver);
    }
    let front2 = front.clone();
    sim.after(burst_ns, move |sim| {
        let more = front2.borrow_mut().nic.burst_done();
        if more {
            frontend_rx_drain(front2, sim);
        }
    });
}

/// One routable attempt target on the recovery path: the worker's sim
/// node plus the shared gauges/health cells the router reads.
struct AttemptTarget {
    node: FaasSim,
    gauge: Rc<RefCell<i64>>,
    health: Rc<RefCell<WorkerHealth>>,
}

/// Shared state of one recoverable invocation: the winner slot (the
/// client's continuation — whoever takes it resolves the request),
/// cancellable deadline/hedge timers, the routable targets, and the
/// cluster-wide cells the attempts update. Lives in an `Rc` captured by
/// every timer and attempt callback; the engine's generation-checked
/// `cancel` makes stale timer handles safe to cancel twice.
struct RecoveryCtx {
    platform: Rc<PlatformConfig>,
    targets: Vec<AttemptTarget>,
    fn_inflight: Rc<RefCell<BTreeMap<String, i64>>>,
    last_active: Rc<RefCell<BTreeMap<String, Time>>>,
    front: Rc<RefCell<FrontendRx>>,
    recovery: Rc<RefCell<RecoveryStats>>,
    fault_rng: Rc<RefCell<Rng>>,
    wire_loss: Rc<RefCell<(u64, Time)>>,
    resp_ring: Rc<RefCell<(Vec<Time>, usize)>>,
    name: String,
    slot: RefCell<Option<RespFn>>,
    deadline: RefCell<Option<TimerHandle>>,
    hedge: RefCell<Option<TimerHandle>>,
    /// Target index the most recent attempt was routed to (the hedge and
    /// the retry path avoid it when an alternative exists).
    last_target: RefCell<Option<usize>>,
    submit_t: Time,
    retries_used: RefCell<u32>,
}

/// Pick an attempt target: healthy (not down, not ejected) workers first,
/// avoiding `avoid` when an alternative exists, least in-flight with the
/// response-time EWMA as tiebreak. Falls back to the full set when no
/// target is healthy — a request is never unroutable.
fn recovery_route(ctx: &RecoveryCtx, now: Time, avoid: Option<usize>) -> usize {
    let key = |ti: usize| {
        let g = *ctx.targets[ti].gauge.borrow();
        let e = ctx.targets[ti].health.borrow().ewma_ns;
        (g, e, ti)
    };
    let healthy = |ti: &usize| {
        let h = ctx.targets[*ti].health.borrow();
        now >= h.down_until && now >= h.ejected_until
    };
    let all: Vec<usize> = (0..ctx.targets.len()).collect();
    let pool: Vec<usize> = all.iter().copied().filter(healthy).collect();
    let pool = if pool.is_empty() { all } else { pool };
    let preferred: Vec<usize> = pool.iter().copied().filter(|&ti| Some(ti) != avoid).collect();
    let pool = if preferred.is_empty() { pool } else { preferred };
    pool.into_iter().min_by_key(|&ti| key(ti)).expect("no replica targets")
}

/// Hedge delay: the `hedge_quantile_bp` quantile of the recent response
/// ring. `None` (no hedge) when hedging is off, only one replica exists,
/// or no responses were observed yet.
fn recovery_hedge_delay(ctx: &RecoveryCtx) -> Option<Time> {
    let bp = ctx.platform.hedge_quantile_bp;
    if bp == 0 || ctx.targets.len() < 2 {
        return None;
    }
    let ring = ctx.resp_ring.borrow();
    if ring.0.is_empty() {
        return None;
    }
    let mut v = ring.0.clone();
    v.sort_unstable();
    Some(v[((v.len() as u64 - 1) * bp / 10_000) as usize])
}

/// A routed attempt responded: reset the target's failure streak, fold
/// the response time into its EWMA, and feed the hedge-quantile ring.
fn recovery_note_success(ctx: &RecoveryCtx, ti: usize, resp: Time) {
    {
        let mut h = ctx.targets[ti].health.borrow_mut();
        h.consec_fails = 0;
        h.ewma_ns = if h.ewma_ns == 0 { resp } else { h.ewma_ns - h.ewma_ns / 8 + resp / 8 };
    }
    let mut ring = ctx.resp_ring.borrow_mut();
    let cur = ring.1;
    if ring.0.len() < 128 {
        ring.0.push(resp);
    } else {
        ring.0[cur % 128] = resp;
    }
    ring.1 = cur + 1;
}

/// A routed attempt failed: bump the target's failure streak and eject
/// it from routing once the streak crosses the configured threshold.
fn recovery_note_failure(ctx: &RecoveryCtx, now: Time, ti: usize) {
    let ejected = {
        let mut h = ctx.targets[ti].health.borrow_mut();
        h.consec_fails += 1;
        let thresh = ctx.platform.fault_health_fail_threshold;
        if thresh > 0
            && ctx.platform.fault_health_eject_ns > 0
            && h.consec_fails as u64 >= thresh
        {
            h.ejected_until = now + ctx.platform.fault_health_eject_ns;
            h.consec_fails = 0;
            true
        } else {
            false
        }
    };
    if ejected {
        ctx.recovery.borrow_mut().ejections += 1;
    }
}

/// Launch one attempt of a recoverable invocation: route it, maybe lose
/// it to an active wire-loss window, otherwise submit it to the chosen
/// worker. The attempt's completion either resolves the request (first
/// winner), drives a retry (failure), or — when a sibling already won —
/// just closes its own bookkeeping.
fn recovery_launch(ctx: Rc<RecoveryCtx>, sim: &mut Sim, avoid: Option<usize>, is_hedge: bool) {
    if ctx.slot.borrow().is_none() {
        return;
    }
    let now = sim.now();
    let ti = recovery_route(&ctx, now, avoid);
    *ctx.last_target.borrow_mut() = Some(ti);
    let lost = {
        let (bp, until) = *ctx.wire_loss.borrow();
        bp > 0 && now < until && ctx.fault_rng.borrow_mut().below(10_000) < bp
    };
    if lost {
        // The frame vanished in flight: nothing reached the worker (no
        // trace, no gauges). A synthetic failure after the retry backoff
        // drives the re-send; the deadline bounds the worst case.
        ctx.recovery.borrow_mut().wire_lost += 1;
        let backoff = ctx.platform.deadline_retry_backoff_ns.max(1);
        let ctx2 = ctx.clone();
        sim.after(backoff, move |sim| recovery_attempt_failed(ctx2, sim, ti));
        return;
    }
    *ctx.targets[ti].gauge.borrow_mut() += 1;
    *ctx.fn_inflight.borrow_mut().entry(ctx.name.clone()).or_insert(0) += 1;
    let ctx2 = ctx.clone();
    let start = now;
    ctx.targets[ti].node.clone().submit(sim, &ctx.name, move |sim, t| {
        *ctx2.targets[ti].gauge.borrow_mut() -= 1;
        *ctx2.fn_inflight.borrow_mut().get_mut(&ctx2.name).unwrap() -= 1;
        ctx2.last_active.borrow_mut().insert(ctx2.name.clone(), sim.now());
        let resolved = ctx2.slot.borrow().is_none();
        if t.dropped {
            // Worker-level failure (RX give-up or TX abandon): the frame
            // never crossed back, so close the attempt's trace here.
            trace_finish(&ctx2.front.borrow().tracer, &t);
            if !resolved {
                recovery_note_failure(&ctx2, sim.now(), ti);
                recovery_attempt_failed(ctx2, sim, ti);
            }
        } else {
            recovery_note_success(&ctx2, ti, sim.now() - start);
            if resolved {
                // A sibling attempt already won; this response is
                // redundant — close its trace and discard it.
                trace_finish(&ctx2.front.borrow().tracer, &t);
            } else {
                recovery_deliver(ctx2, sim, t, is_hedge);
            }
        }
    });
}

/// An attempt failed (worker drop or wire loss). Re-issue on a different
/// replica after a jittered backoff while budget remains; otherwise
/// resolve the request as a failure now instead of waiting out the
/// deadline.
fn recovery_attempt_failed(ctx: Rc<RecoveryCtx>, sim: &mut Sim, from: usize) {
    if ctx.slot.borrow().is_none() {
        return;
    }
    let used = *ctx.retries_used.borrow() as u64;
    if used >= ctx.platform.deadline_max_retries {
        let Some(done) = ctx.slot.borrow_mut().take() else { return };
        if let Some(h) = ctx.deadline.borrow_mut().take() {
            sim.cancel(h);
        }
        if let Some(h) = ctx.hedge.borrow_mut().take() {
            sim.cancel(h);
        }
        let now = sim.now();
        let t = RequestTiming {
            submit: ctx.submit_t,
            done: now,
            dropped: true,
            failed: true,
            retried_other_worker: used as u32,
            ..Default::default()
        };
        done(sim, t);
        return;
    }
    *ctx.retries_used.borrow_mut() += 1;
    ctx.recovery.borrow_mut().retries_other += 1;
    // Decorrelated-flavored jitter on the retry backoff: base + U[0, base)
    // from the seeded fault stream, so synchronized failures don't
    // re-collide on the retry boundary.
    let base = ctx.platform.deadline_retry_backoff_ns;
    let backoff = if base == 0 { 0 } else { base + ctx.fault_rng.borrow_mut().below(base) };
    let ctx2 = ctx.clone();
    sim.after(backoff, move |sim| recovery_launch(ctx2, sim, Some(from), false));
}

/// The per-invocation deadline fired with no resolution: synthesize a
/// timeout. Attempts still in flight keep draining — their callbacks see
/// the empty winner slot and only close their own bookkeeping.
fn recovery_timeout(ctx: Rc<RecoveryCtx>, sim: &mut Sim) {
    ctx.deadline.borrow_mut().take();
    let Some(done) = ctx.slot.borrow_mut().take() else { return };
    if let Some(h) = ctx.hedge.borrow_mut().take() {
        sim.cancel(h);
    }
    ctx.recovery.borrow_mut().timed_out += 1;
    let now = sim.now();
    let t = RequestTiming {
        submit: ctx.submit_t,
        done: now,
        timed_out: true,
        retried_other_worker: *ctx.retries_used.borrow(),
        ..Default::default()
    };
    done(sim, t);
}

/// First winning response: cancel the pending timers, stamp the
/// recovery fields, and hand the frame to the front end's RX ring (the
/// same return path the fast path pays).
fn recovery_deliver(ctx: Rc<RecoveryCtx>, sim: &mut Sim, mut t: RequestTiming, is_hedge: bool) {
    let Some(done) = ctx.slot.borrow_mut().take() else { return };
    if let Some(h) = ctx.deadline.borrow_mut().take() {
        sim.cancel(h);
    }
    if let Some(h) = ctx.hedge.borrow_mut().take() {
        sim.cancel(h);
    }
    t.submit = ctx.submit_t;
    t.hedge_won = is_hedge;
    t.retried_other_worker = *ctx.retries_used.borrow();
    if is_hedge {
        ctx.recovery.borrow_mut().hedge_wins += 1;
    }
    frontend_rx_ingress(ctx.front.clone(), sim, t, done);
}

/// Replica placement strategies for the controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Next worker in order.
    RoundRobin,
    /// Worker currently hosting the fewest replicas.
    LeastLoaded,
    /// First worker with room (bin packing; densest packing first).
    BinPack,
}

/// Controller + worker-manager state for a multi-server deployment.
pub struct Cluster {
    platform: Rc<PlatformConfig>,
    backend: Backend,
    seed: u64,
    compute_ns: Time,
    pub workers: Vec<Worker>,
    pub placement: Placement,
    /// function → (spec, replica locations as worker indices)
    functions: BTreeMap<String, (FunctionSpec, Vec<usize>)>,
    /// function → in-flight count (controller's demand signal)
    inflight: Rc<RefCell<BTreeMap<String, i64>>>,
    /// function → last time a request completed (scale-to-zero signal)
    last_active: Rc<RefCell<BTreeMap<String, Time>>>,
    pub policy: ScalePolicy,
    rr_next: usize,
    // telemetry
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub scale_to_zeros: u64,
    /// Functions re-provisioned on demand after a scale-to-zero (the
    /// cluster-level cold-start path).
    pub zero_redeploys: u64,
    /// Scale-ups served per provisioning tier (index =
    /// `crate::snapshot::ProvisionTier::idx`).
    pub tier_scale_ups: [u64; 3],
    /// The front end's own RX NIC for the response direction.
    front_rx: Rc<RefCell<FrontendRx>>,
    /// Shared invocation tracer (disabled until [`Cluster::enable_tracing`]).
    tracer: Tracer,
    /// Recovery-machinery counters (active only with
    /// `platform.deadline_timeout_ns > 0`).
    recovery: Rc<RefCell<RecoveryStats>>,
    /// Seeded fault stream: wire-loss draws and retry jitter. Independent
    /// of every other RNG in the sim and only drawn from on the recovery
    /// path, so faults-off runs stay byte-identical.
    fault_rng: Rc<RefCell<Rng>>,
    /// Active wire-loss window: (loss in 1/10000, open until).
    wire_loss: Rc<RefCell<(u64, Time)>>,
    /// Ring of recent recovery-path response times (cap 128) feeding the
    /// hedge delay quantile: (buffer, write cursor).
    resp_ring: Rc<RefCell<(Vec<Time>, usize)>>,
}

impl Cluster {
    pub fn new(
        backend: Backend,
        n_workers: usize,
        worker_cores: usize,
        seed: u64,
        compute_ns: Time,
    ) -> Self {
        Cluster::new_with_platform(
            backend,
            n_workers,
            worker_cores,
            seed,
            compute_ns,
            Rc::new(PlatformConfig::default()),
        )
    }

    /// Build a cluster against an explicit platform model (the duplex
    /// payload sweep varies `rpc_payload_bytes` and the NIC knobs).
    pub fn new_with_platform(
        backend: Backend,
        n_workers: usize,
        worker_cores: usize,
        seed: u64,
        compute_ns: Time,
        platform: Rc<PlatformConfig>,
    ) -> Self {
        assert!(n_workers >= 1);
        let workers = (0..n_workers)
            .map(|i| {
                let cfg = ExperimentConfig {
                    backend,
                    provider_cache: true,
                    worker_cores,
                    seed: seed.wrapping_add(i as u64 * 7919),
                    function_compute_ns: compute_ns,
                    instance_concurrency: 4,
                };
                Worker {
                    id: i as u32,
                    sim_node: FaasSim::new(&cfg, platform.clone()),
                    hosted: Vec::new(),
                    in_flight: Rc::new(RefCell::new(0)),
                    health: Rc::new(RefCell::new(WorkerHealth::default())),
                }
            })
            .collect();
        let front_rx = Rc::new(RefCell::new(FrontendRx {
            nic: NicQueue::new(platform.nic_queue_depth as usize),
            kc: KernelCosts::new(platform.clone(), Rng::new(seed ^ 0xF00D)),
            bc: BypassCosts::new(platform.clone(), Rng::new(seed ^ 0xBEEF)),
            backend,
            platform: platform.clone(),
            tracer: Tracer::new(),
        }));
        Cluster {
            platform,
            backend,
            seed,
            compute_ns,
            workers,
            placement: Placement::LeastLoaded,
            functions: BTreeMap::new(),
            inflight: Rc::new(RefCell::new(BTreeMap::new())),
            last_active: Rc::new(RefCell::new(BTreeMap::new())),
            policy: ScalePolicy::default(),
            rr_next: 0,
            scale_ups: 0,
            scale_downs: 0,
            scale_to_zeros: 0,
            zero_redeploys: 0,
            tier_scale_ups: [0; 3],
            front_rx,
            tracer: Tracer::new(),
            recovery: Rc::new(RefCell::new(RecoveryStats::default())),
            fault_rng: Rc::new(RefCell::new(Rng::new(seed ^ 0xFA17))),
            wire_loss: Rc::new(RefCell::new((0, 0))),
            resp_ring: Rc::new(RefCell::new((Vec::new(), 0))),
        }
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Turn on span-per-invocation tracing across the whole cluster: every
    /// worker shares one tracer (one seq space), workers leave traces open
    /// at their local `done`, and the front end closes them after the
    /// return wire + its own RX ring. Returns the shared handle.
    pub fn enable_tracing(&mut self, k: usize) -> Tracer {
        self.tracer.enable(k);
        self.front_rx.borrow_mut().tracer = self.tracer.clone();
        for w in &self.workers {
            w.sim_node.set_tracer(self.tracer.clone(), false);
        }
        self.tracer.clone()
    }

    /// The cluster's tracer handle (disabled unless `enable_tracing` ran).
    pub fn tracer(&self) -> Tracer {
        self.tracer.clone()
    }

    fn pick_worker(&mut self, _function: &str) -> usize {
        match self.placement {
            Placement::RoundRobin => {
                let w = self.rr_next % self.workers.len();
                self.rr_next += 1;
                w
            }
            Placement::LeastLoaded => self
                .workers
                .iter()
                .enumerate()
                .min_by_key(|(_, w)| w.hosted.len())
                .map(|(i, _)| i)
                .unwrap(),
            Placement::BinPack => {
                // Densest worker that still has headroom (≤ 16 replicas).
                self.workers
                    .iter()
                    .enumerate()
                    .filter(|(_, w)| w.hosted.len() < 16)
                    .max_by_key(|(_, w)| w.hosted.len())
                    .map(|(i, _)| i)
                    .unwrap_or_else(|| {
                        // All full: fall back to least loaded.
                        self.workers
                            .iter()
                            .enumerate()
                            .min_by_key(|(_, w)| w.hosted.len())
                            .map(|(i, _)| i)
                            .unwrap()
                    })
            }
        }
    }

    /// Deploy the first replica of a function. Returns its cold-start time.
    pub fn deploy(&mut self, sim: &mut Sim, spec: FunctionSpec) -> Time {
        let w = self.pick_worker(&spec.name);
        let per_worker_name = spec.name.clone();
        let (cold, _) =
            self.workers[w].sim_node.deploy_tiered(sim, spec.clone(), self.policy.warm_pool);
        self.workers[w].hosted.push(per_worker_name);
        // A fresh deploy counts as activity: without this stamp a
        // never-invoked function looks idle-since-epoch and the very next
        // reconcile would scale it straight back to zero.
        self.last_active.borrow_mut().insert(spec.name.clone(), sim.now());
        self.functions.insert(spec.name.clone(), (spec, vec![w]));
        cold
    }

    /// Add one replica on a (newly picked) worker. Returns cold time.
    pub fn scale_up(&mut self, sim: &mut Sim, name: &str) -> Option<Time> {
        let (spec, locs) = self.functions.get(name)?.clone();
        if locs.len() as u32 >= self.policy.max_replicas {
            return None;
        }
        let w = self.pick_worker(name);
        // A worker can host at most one replica of a given function in
        // this model (mirrors faasd's one-container-per-function/node).
        if locs.contains(&w) {
            // Try any worker without this function.
            let alt = (0..self.workers.len()).find(|i| !locs.contains(i))?;
            return self.scale_up_on(sim, name, alt, &spec);
        }
        self.scale_up_on(sim, name, w, &spec)
    }

    fn scale_up_on(
        &mut self,
        sim: &mut Sim,
        name: &str,
        w: usize,
        spec: &FunctionSpec,
    ) -> Option<Time> {
        let mut replica_spec = spec.clone();
        replica_spec.name = name.to_string();
        // Request the instance through the tier ladder: a worker that
        // previously parked this function serves it from its warm pool (or
        // restores from its snapshot) instead of cold booting.
        let (cold, tier) =
            self.workers[w].sim_node.deploy_tiered(sim, replica_spec, self.policy.warm_pool);
        self.workers[w].hosted.push(name.to_string());
        self.functions.get_mut(name).unwrap().1.push(w);
        self.last_active.borrow_mut().insert(name.to_string(), sim.now());
        self.scale_ups += 1;
        self.tier_scale_ups[tier.idx()] += 1;
        Some(cold)
    }

    /// Remove the most recently added replica (keep ≥ min_replicas): the
    /// worker parks the instance into its warm pool. Refuses while the
    /// replica still has requests in flight.
    pub fn scale_down(&mut self, sim: &mut Sim, name: &str) -> bool {
        let Some((_, locs)) = self.functions.get_mut(name) else { return false };
        if locs.len() as u32 <= 1 {
            return false;
        }
        let w = *locs.last().unwrap();
        if !self.workers[w].sim_node.undeploy(sim, name) {
            return false; // busy replica: retry next reconcile
        }
        // Cold-only baseline keeps no warm memory resident (the seed's
        // behavior): drop whatever the undeploy just parked.
        if !self.policy.warm_pool {
            self.workers[w].sim_node.flush_warm_pool(sim);
        }
        self.functions.get_mut(name).unwrap().1.pop();
        let hosted = &mut self.workers[w].hosted;
        if let Some(pos) = hosted.iter().position(|h| h == name) {
            hosted.remove(pos);
        }
        self.scale_downs += 1;
        true
    }

    /// Retire *every* replica of an idle function (min_replicas == 0):
    /// each worker parks its instance into the local warm pool, so the
    /// next invocation re-provisions from the warm tier instead of a cold
    /// boot. Stops early (returning `false`) if any replica is still busy
    /// or booting; the remaining replicas stay routable.
    pub fn scale_to_zero(&mut self, sim: &mut Sim, name: &str) -> bool {
        let locs = match self.functions.get(name) {
            Some((_, l)) if !l.is_empty() => l.clone(),
            _ => return false,
        };
        let mut remaining = locs.clone();
        for &w in &locs {
            if !self.workers[w].sim_node.undeploy(sim, name) {
                break;
            }
            if !self.policy.warm_pool {
                self.workers[w].sim_node.flush_warm_pool(sim);
            }
            let hosted = &mut self.workers[w].hosted;
            if let Some(pos) = hosted.iter().position(|h| h == name) {
                hosted.remove(pos);
            }
            remaining.retain(|&x| x != w);
        }
        let drained = remaining.is_empty();
        self.functions.get_mut(name).unwrap().1 = remaining;
        if drained {
            self.scale_to_zeros += 1;
        }
        drained
    }

    pub fn replica_count(&self, name: &str) -> u32 {
        self.functions.get(name).map(|(_, l)| l.len() as u32).unwrap_or(0)
    }

    /// Submit one invocation; the cluster-level gateway picks the replica's
    /// worker (least in-flight first — the "stateless load-balancer" of
    /// Figure 1). With `platform.deadline_timeout_ns > 0` the request goes
    /// through the recovery layer instead: per-invocation deadline,
    /// retry-on-another-replica, optional hedging, health-aware routing,
    /// and brownout admission control. With the knob at its default the
    /// fast path below runs untouched — byte-identical to the seed.
    pub fn submit<F: FnOnce(&mut Sim, RequestTiming) + 'static>(
        &mut self,
        sim: &mut Sim,
        function: &str,
        done: F,
    ) {
        if self.platform.deadline_timeout_ns > 0 {
            return self.submit_recoverable(sim, function, Box::new(done));
        }
        // Routing reads the replica list in place — cloning the spec per
        // submission (two Strings) was measurable at density-experiment
        // invocation counts.
        let routed = {
            let (_, locs) = self.functions.get(function).expect("unknown function");
            locs.iter()
                .min_by_key(|&&i| *self.workers[i].in_flight.borrow())
                .copied()
        };
        let w = if let Some(w) = routed {
            w
        } else {
            // Scaled to zero: re-provision on demand through the tier
            // ladder and route to the fresh replica. Prefer a worker that
            // parked this function in its warm pool — any other placement
            // would silently degrade the re-deploy to a snapshot restore
            // or cold boot.
            let (spec, _) = self.functions.get(function).unwrap().clone();
            let warm = (0..self.workers.len())
                .find(|&i| self.workers[i].sim_node.pool_warm_count(function) > 0);
            let w = match warm {
                Some(w) => w,
                None => self.pick_worker(function),
            };
            let _ = self.scale_up_on(sim, function, w, &spec);
            self.zero_redeploys += 1;
            w
        };
        *self.workers[w].in_flight.borrow_mut() += 1;
        {
            let mut inf = self.inflight.borrow_mut();
            *inf.entry(function.to_string()).or_insert(0) += 1;
        }
        let worker_inflight = self.workers[w].in_flight.clone();
        let fn_inflight = self.inflight.clone();
        let last_active = self.last_active.clone();
        let front = self.front_rx.clone();
        let fname = function.to_string();
        self.workers[w].sim_node.submit(sim, function, move |sim, t| {
            *worker_inflight.borrow_mut() -= 1;
            *fn_inflight.borrow_mut().get_mut(&fname).unwrap() -= 1;
            last_active.borrow_mut().insert(fname.clone(), sim.now());
            if t.dropped {
                // Nothing crossed back over the wire: the request died at
                // a worker ring (RX tail drop or TX stall budget). Close
                // (and discard) its trace here — the frontend never sees it.
                trace_finish(&front.borrow().tracer, &t);
                done(sim, t);
            } else {
                // The response frame lands in the front end's RX NIC and
                // pays its receive costs before the client sees it.
                frontend_rx_ingress(front, sim, t, Box::new(done));
            }
        });
    }

    /// The recovery-layer submission path (active when
    /// `platform.deadline_timeout_ns > 0`): admission brownout, health-
    /// aware routing, a cancellable per-invocation deadline, jittered
    /// retry on a *different* replica after an attempt fails, and an
    /// optional hedged duplicate after the observed-quantile delay.
    /// Exactly one resolution reaches the client: the first winning
    /// response, a synthesized failure when the retry budget is gone, or
    /// a synthesized timeout at the deadline. Losing sibling attempts
    /// still drain through the pipeline (their gauges and traces close),
    /// so the engine's drain invariant holds under any schedule.
    fn submit_recoverable(&mut self, sim: &mut Sim, function: &str, done: RespFn) {
        let now = sim.now();
        // Admission brownout: when the healthy fraction of the pool falls
        // below the watermark, Batch-class work is shed at the door so
        // Interactive work keeps the survivors.
        let batch = self.functions.get(function).expect("unknown function").0.batch;
        if batch && self.platform.fault_brownout_watermark_bp > 0 {
            let healthy = self
                .workers
                .iter()
                .filter(|w| {
                    let h = w.health.borrow();
                    now >= h.down_until && now >= h.ejected_until
                })
                .count() as u64;
            let watermark = self.platform.fault_brownout_watermark_bp;
            if healthy * 10_000 < watermark * self.workers.len() as u64 {
                self.recovery.borrow_mut().shed_batch += 1;
                let t = RequestTiming {
                    submit: now,
                    done: now,
                    dropped: true,
                    failed: true,
                    ..Default::default()
                };
                done(sim, t);
                return;
            }
        }
        // Scaled to zero: re-provision on demand exactly like the fast
        // path, then route the attempt(s) at the fresh replica.
        let locs = self.functions.get(function).unwrap().1.clone();
        let locs = if locs.is_empty() {
            let (spec, _) = self.functions.get(function).unwrap().clone();
            let warm = (0..self.workers.len())
                .find(|&i| self.workers[i].sim_node.pool_warm_count(function) > 0);
            let w = warm.unwrap_or_else(|| self.pick_worker(function));
            let _ = self.scale_up_on(sim, function, w, &spec);
            self.zero_redeploys += 1;
            vec![w]
        } else {
            locs
        };
        let targets = locs
            .iter()
            .map(|&w| AttemptTarget {
                node: self.workers[w].sim_node.clone(),
                gauge: self.workers[w].in_flight.clone(),
                health: self.workers[w].health.clone(),
            })
            .collect();
        let ctx = Rc::new(RecoveryCtx {
            platform: self.platform.clone(),
            targets,
            fn_inflight: self.inflight.clone(),
            last_active: self.last_active.clone(),
            front: self.front_rx.clone(),
            recovery: self.recovery.clone(),
            fault_rng: self.fault_rng.clone(),
            wire_loss: self.wire_loss.clone(),
            resp_ring: self.resp_ring.clone(),
            name: function.to_string(),
            slot: RefCell::new(Some(done)),
            deadline: RefCell::new(None),
            hedge: RefCell::new(None),
            last_target: RefCell::new(None),
            submit_t: now,
            retries_used: RefCell::new(0),
        });
        let ctx2 = ctx.clone();
        let h = sim
            .after_handle(self.platform.deadline_timeout_ns, move |sim| {
                recovery_timeout(ctx2, sim)
            });
        *ctx.deadline.borrow_mut() = Some(h);
        // Hedge: after a delay derived from the observed response-time
        // quantile, duplicate the attempt on another replica if the
        // primary hasn't resolved. Needs >1 replica and a warm ring.
        if let Some(delay) = recovery_hedge_delay(&ctx) {
            let ctx2 = ctx.clone();
            let h = sim.after_handle(delay, move |sim| {
                ctx2.hedge.borrow_mut().take();
                if ctx2.slot.borrow().is_none() {
                    return;
                }
                ctx2.recovery.borrow_mut().hedges += 1;
                let avoid = *ctx2.last_target.borrow();
                recovery_launch(ctx2.clone(), sim, avoid, true);
            });
            *ctx.hedge.borrow_mut() = Some(h);
        }
        recovery_launch(ctx, sim, None, false);
    }

    /// Fault hook: crash worker `w` — its warm pool is wiped (it lived in
    /// the worker's memory) and every hosted function's replicas die
    /// mid-invocation and re-provision through the tier ladder (the
    /// snapshot store survives host-side, so recovery normally pays a
    /// restore, not a cold boot). Routing treats the worker as down for
    /// the longest re-provision window. Returns that window.
    pub fn crash_worker(&mut self, sim: &mut Sim, w: usize) -> Time {
        let w = w % self.workers.len();
        self.workers[w].sim_node.flush_warm_pool(sim);
        let hosted = self.workers[w].hosted.clone();
        let mut worst = 0;
        for name in hosted {
            if let Some(lat) = self.workers[w].sim_node.crash_function(sim, &name) {
                worst = worst.max(lat);
            }
        }
        self.workers[w].health.borrow_mut().down_until = sim.now() + worst;
        worst
    }

    /// Fault hook: crash one function's replicas on worker `w` only.
    /// Returns the re-provision latency (0 if not hosted there).
    pub fn crash_instance(&mut self, sim: &mut Sim, w: usize, function: &str) -> Time {
        let w = w % self.workers.len();
        self.workers[w].sim_node.crash_function(sim, function).unwrap_or(0)
    }

    /// Fault hook: gray failure — degrade worker `w`'s function compute
    /// to `factor_x100`/100 of nominal for `duration`, then restore.
    /// Nothing fails and nothing ejects; only deadline/hedging machinery
    /// can defend the tail.
    pub fn set_gray(&mut self, sim: &mut Sim, w: usize, factor_x100: u64, duration: Time) {
        let w = w % self.workers.len();
        let node = self.workers[w].sim_node.clone();
        node.set_degrade(factor_x100);
        sim.after(duration, move |_| node.set_degrade(100));
    }

    /// Fault hook: open a wire-loss window — until it closes, each
    /// recovery-path attempt is lost in flight with probability
    /// `loss_bp`/10000 (drawn from the cluster's seeded fault stream).
    pub fn set_wire_loss(&mut self, sim: &mut Sim, loss_bp: u64, duration: Time) {
        *self.wire_loss.borrow_mut() = (loss_bp, sim.now() + duration);
    }

    /// Recovery-machinery counters.
    pub fn recovery_stats(&self) -> RecoveryStats {
        *self.recovery.borrow()
    }

    /// Health view of worker `w`.
    pub fn worker_health(&self, w: usize) -> WorkerHealth {
        *self.workers[w].health.borrow()
    }

    /// One controller reconcile pass (§2.1 "outside of the critical path,
    /// the controller will perform autoscaling operations"). Call this on
    /// a timer (see [`Cluster::start_controller`]).
    pub fn reconcile(&mut self, sim: &mut Sim) {
        let names: Vec<String> = self.functions.keys().cloned().collect();
        for name in names {
            let inflight = *self.inflight.borrow().get(&name).unwrap_or(&0);
            let actual = self.replica_count(&name);
            let replicas = actual.max(1);
            let per = inflight as f64 / replicas as f64;
            let idle_ns = sim
                .now()
                .saturating_sub(self.last_active.borrow().get(&name).copied().unwrap_or(0));
            if per > self.policy.target_inflight_per_replica
                && replicas < self.policy.max_replicas
            {
                self.scale_up(sim, &name);
            } else if self.policy.min_replicas == 0
                && actual >= 1
                && inflight == 0
                && idle_ns > self.policy.scale_to_zero_after
            {
                // Fully idle past the keep-warm horizon: release every
                // replica (they park warm; the next invocation re-deploys
                // on demand through the tier ladder).
                self.scale_to_zero(sim, &name);
            } else if per < self.policy.target_inflight_per_replica / 4.0 && actual > 1 {
                if inflight == 0 && idle_ns > self.policy.interval {
                    self.scale_down(sim, &name);
                }
            }
        }
        // Reconciles are the cluster's quiesce points: debug builds
        // re-prove every conservation law after the scaling churn.
        crate::invariants::debug_quiesce(self);
    }

    /// Drive `reconcile` on the policy interval for `horizon` virtual
    /// time. The tick times are the seed's fixed train (`now + k·interval`
    /// while `< now + horizon`), but driven by
    /// [`crate::simcore::tick_train`]: one pending reconcile event at a
    /// time instead of `horizon/interval` closures materialized up front —
    /// at density-experiment horizons the old train alone was tens of
    /// thousands of heap-resident events per worker.
    pub fn start_controller(cluster: Rc<RefCell<Cluster>>, sim: &mut Sim, horizon: Time) {
        let interval = cluster.borrow().policy.interval;
        crate::simcore::tick_train(sim, interval, horizon, move |sim| {
            cluster.borrow_mut().reconcile(sim);
        });
    }

    /// Front-end RX NIC counters (the gateway-side half of the duplex
    /// path: responses received, burst amortization, backpressure
    /// re-offers in `retries`; `rx_dropped` stays 0 — the front end never
    /// loses a held frame).
    pub fn frontend_rx_stats(&self) -> NicStats {
        self.front_rx.borrow().nic.stats
    }

    /// Aggregate worker NIC counters across the pool: (RX totals, TX
    /// totals). `max_depth` aggregates as the per-worker maximum.
    pub fn nic_totals(&self) -> (NicStats, TxStats) {
        let mut rx = NicStats::default();
        let mut tx = TxStats::default();
        for w in &self.workers {
            let s = w.sim_node.nic_stats();
            rx.rx_enqueued += s.rx_enqueued;
            rx.rx_delivered += s.rx_delivered;
            rx.rx_dropped += s.rx_dropped;
            rx.retries += s.retries;
            rx.retrans_cancelled += s.retrans_cancelled;
            rx.rx_bytes += s.rx_bytes;
            rx.bursts += s.bursts;
            rx.max_depth = rx.max_depth.max(s.max_depth);
            let x = w.sim_node.tx_stats();
            tx.tx_enqueued += x.tx_enqueued;
            tx.tx_packets += x.tx_packets;
            tx.tx_bytes += x.tx_bytes;
            tx.tx_stalled += x.tx_stalled;
            tx.tx_retries += x.tx_retries;
            tx.tx_abandoned += x.tx_abandoned;
            tx.tx_bursts += x.tx_bursts;
            tx.tx_max_depth = tx.tx_max_depth.max(x.tx_max_depth);
        }
        (rx, tx)
    }

    /// Aggregate compute-fabric counters across the pool (the CPU-side
    /// twin of [`Cluster::nic_totals`]): busy time, job conservation,
    /// preemption/steal/migration churn, and per-core busy rollups
    /// (worker core `i` accumulates across workers).
    pub fn fabric_totals(&self) -> crate::simcore::FabricStats {
        let mut agg = crate::simcore::FabricStats::default();
        for w in &self.workers {
            agg.merge(&w.sim_node.fabric_stats());
        }
        agg
    }

    /// Invocations served across the pool (sum of worker completions).
    pub fn total_completed(&self) -> u64 {
        self.workers.iter().map(|w| w.sim_node.completed()).sum()
    }

    /// Requests abandoned across the pool (RX give-ups + TX abandons).
    pub fn total_dropped(&self) -> u64 {
        self.workers.iter().map(|w| w.sim_node.dropped()).sum()
    }

    /// Total cores in the pool (worker-manager capacity view).
    pub fn total_cores(&self) -> usize {
        self.workers.len() * 10
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Grow the pool by one worker (worker-manager action, §2.1: "adding
    /// more workers to the pool via the worker manager if there is
    /// insufficient capacity").
    pub fn add_worker(&mut self, worker_cores: usize) -> u32 {
        let i = self.workers.len();
        let cfg = ExperimentConfig {
            backend: self.backend,
            provider_cache: true,
            worker_cores,
            seed: self.seed.wrapping_add(i as u64 * 7919),
            function_compute_ns: self.compute_ns,
            instance_concurrency: 4,
        };
        let sim_node = FaasSim::new(&cfg, self.platform.clone());
        if self.tracer.is_enabled() {
            sim_node.set_tracer(self.tracer.clone(), false);
        }
        self.workers.push(Worker {
            id: i as u32,
            sim_node,
            hosted: Vec::new(),
            in_flight: Rc::new(RefCell::new(0)),
            health: Rc::new(RefCell::new(WorkerHealth::default())),
        });
        i as u32
    }
}

/// Cluster-wide invariant walk: every worker's full single-node audit,
/// plus the front-end laws only the cluster can see — the frontend RX
/// ring never sheds a held frame (`rx_dropped == 0`, backpressure is
/// counted as `retries`), its ring conserves frames, and no worker's
/// in-flight gauge goes negative.
impl AuditTree for Cluster {
    fn audit_tree(&self, out: &mut Vec<Violation>) {
        for w in &self.workers {
            w.sim_node.audit_tree(out);
            let inflight = *w.in_flight.borrow();
            check(out, "faas/cluster", "inflight-gauge", inflight >= 0, || {
                format!("worker {} in-flight gauge is {inflight}", w.id)
            });
        }
        let m = "faas/cluster";
        let front = self.front_rx.borrow();
        let s = front.nic.stats;
        check(out, m, "front-rx-no-loss", s.rx_dropped == 0, || {
            format!("front end dropped {} held response frames", s.rx_dropped)
        });
        let held = front.nic.len() as u64;
        check(out, m, "front-rx-conservation", s.rx_enqueued == s.rx_delivered + held, || {
            format!(
                "front rx_enqueued {} != rx_delivered {} + ring depth {held}",
                s.rx_enqueued, s.rx_delivered
            )
        });
        let r = *self.recovery.borrow();
        check(out, m, "hedge-conservation", r.hedge_wins <= r.hedges, || {
            format!("hedge_wins {} exceeds hedges issued {}", r.hedge_wins, r.hedges)
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faas::RuntimeKind;
    use crate::invariants::Audit;
    use crate::simcore::MICROS;
    use crate::workload::RunResult;

    fn cluster(backend: Backend, n: usize) -> (Sim, Rc<RefCell<Cluster>>) {
        let mut sim = Sim::new();
        let mut c = Cluster::new(backend, n, 10, 1, 100_000);
        c.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        sim.run_until(SECONDS);
        (sim, Rc::new(RefCell::new(c)))
    }

    #[test]
    fn deploy_places_one_replica() {
        let (_, c) = cluster(Backend::Junctiond, 3);
        assert_eq!(c.borrow().replica_count("aes"), 1);
        let hosted: usize = c.borrow().workers.iter().map(|w| w.hosted.len()).sum();
        assert_eq!(hosted, 1);
    }

    #[test]
    fn submit_completes_across_cluster() {
        let (mut sim, c) = cluster(Backend::Junctiond, 3);
        let done = Rc::new(RefCell::new(0u32));
        for _ in 0..20 {
            let d = done.clone();
            c.borrow_mut().submit(&mut sim, "aes", move |_, _| *d.borrow_mut() += 1);
        }
        sim.run_to_completion();
        assert_eq!(*done.borrow(), 20);
    }

    #[test]
    fn controller_elastic_cycle_up_then_down() {
        let (mut sim, c) = cluster(Backend::Containerd, 4);
        Cluster::start_controller(c.clone(), &mut sim, 10 * SECONDS);
        // Sustained heavy load (8k rps > one containerd replica's ~5.5k
        // capacity) for 3 s: in-flight piles up until the controller adds
        // replicas; after the burst the idle path sheds them again.
        let mut t = sim.now();
        for _ in 0..24_000 {
            t += 125_000; // 8k rps offered
            let c2 = c.clone();
            sim.at(t, move |sim| {
                c2.borrow_mut().submit(sim, "aes", |_, _| {});
            });
        }
        sim.run_to_completion();
        let cl = c.borrow();
        assert!(cl.scale_ups >= 1, "controller never scaled up");
        assert!(cl.scale_downs >= 1, "controller never scaled back down");
        assert_eq!(cl.replica_count("aes"), 1, "should return to baseline when idle");
    }

    #[test]
    fn controller_scales_down_when_idle() {
        let (mut sim, c) = cluster(Backend::Junctiond, 4);
        // Manually scale to 3 replicas, then leave idle with controller on.
        {
            let mut cl = c.borrow_mut();
            cl.scale_up(&mut sim, "aes");
            cl.scale_up(&mut sim, "aes");
            assert_eq!(cl.replica_count("aes"), 3);
        }
        sim.run_until(sim.now() + SECONDS);
        Cluster::start_controller(c.clone(), &mut sim, 20 * SECONDS);
        sim.run_to_completion();
        assert!(c.borrow().replica_count("aes") < 3, "idle function should shed replicas");
        assert!(c.borrow().scale_downs > 0);
    }

    #[test]
    fn scale_up_respects_max_replicas() {
        let (mut sim, c) = cluster(Backend::Junctiond, 2);
        let mut cl = c.borrow_mut();
        cl.policy.max_replicas = 2;
        assert!(cl.scale_up(&mut sim, "aes").is_some());
        assert!(cl.scale_up(&mut sim, "aes").is_none(), "must stop at max_replicas");
    }

    #[test]
    fn scale_cycle_reuses_worker_warm_pool() {
        use crate::snapshot::ProvisionTier;
        let mut sim = Sim::new();
        let mut c = Cluster::new(Backend::Junctiond, 2, 10, 1, 100_000);
        c.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        sim.run_until(SECONDS);
        // First scale-up lands cold on the empty second worker.
        assert!(c.scale_up(&mut sim, "aes").is_some());
        sim.run_until(2 * SECONDS);
        // Scale down parks the replica in that worker's warm pool...
        assert!(c.scale_down(&mut sim, "aes"), "idle replica must park");
        assert_eq!(c.replica_count("aes"), 1);
        // ...so the next scale-up acquires it at the warm tier.
        assert!(c.scale_up(&mut sim, "aes").is_some());
        assert_eq!(c.tier_scale_ups[ProvisionTier::ColdBoot.idx()], 1);
        assert_eq!(c.tier_scale_ups[ProvisionTier::WarmPool.idx()], 1);
        sim.run_to_completion();
    }

    #[test]
    fn cold_only_policy_never_uses_pool() {
        use crate::snapshot::ProvisionTier;
        let mut sim = Sim::new();
        let mut c = Cluster::new(Backend::Junctiond, 2, 10, 1, 100_000);
        c.policy.warm_pool = false;
        c.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        sim.run_until(SECONDS);
        assert!(c.scale_up(&mut sim, "aes").is_some());
        sim.run_until(2 * SECONDS);
        assert!(c.scale_down(&mut sim, "aes"));
        assert!(c.scale_up(&mut sim, "aes").is_some());
        assert_eq!(c.tier_scale_ups[ProvisionTier::WarmPool.idx()], 0);
        assert_eq!(c.tier_scale_ups[ProvisionTier::ColdBoot.idx()], 2);
        sim.run_to_completion();
    }

    #[test]
    fn scale_to_zero_and_redeploy_reconciles() {
        use crate::snapshot::ProvisionTier;
        let mut sim = Sim::new();
        let mut c = Cluster::new(Backend::Junctiond, 2, 10, 1, 100_000);
        // Round-robin placement advances past the parking worker between
        // deploy and re-deploy: the warm-pool-aware routing must still
        // find the worker holding the parked instance.
        c.placement = Placement::RoundRobin;
        c.policy.min_replicas = 0;
        c.policy.scale_to_zero_after = 2 * SECONDS;
        c.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        sim.run_until(SECONDS);
        let done = Rc::new(RefCell::new(0u32));
        for _ in 0..5 {
            let d = done.clone();
            c.submit(&mut sim, "aes", move |_, _| *d.borrow_mut() += 1);
        }
        sim.run_to_completion();
        assert_eq!(*done.borrow(), 5);
        assert_eq!(c.replica_count("aes"), 1);
        // Idle past the scale-to-zero horizon with the controller ticking.
        let c = Rc::new(RefCell::new(c));
        Cluster::start_controller(c.clone(), &mut sim, 6 * SECONDS);
        sim.run_to_completion();
        assert_eq!(c.borrow().replica_count("aes"), 0, "idle function must scale to zero");
        assert_eq!(c.borrow().scale_to_zeros, 1, "exactly one scale-to-zero event");
        // Re-deploy on demand: the next invocation re-provisions (from the
        // worker's warm pool, not a cold boot) and serves.
        {
            let d = done.clone();
            c.borrow_mut().submit(&mut sim, "aes", move |_, _| *d.borrow_mut() += 1);
        }
        sim.run_to_completion();
        assert_eq!(*done.borrow(), 6);
        let cl = c.borrow();
        assert_eq!(cl.replica_count("aes"), 1, "on-demand redeploy must restore a replica");
        assert_eq!(cl.zero_redeploys, 1);
        assert!(
            cl.tier_scale_ups[ProvisionTier::WarmPool.idx()] >= 1,
            "redeploy after scale-to-zero should hit the warm pool: {:?}",
            cl.tier_scale_ups
        );
    }

    #[test]
    fn duplex_conservation_under_overload() {
        use crate::workload::OpenLoop;
        // Overloaded duplex runs on both backends: every submitted request
        // must resolve exactly once (completed or dropped — nothing leaks,
        // nothing double-counts), and the response direction's counters
        // must agree with completions end to end: worker RX deliveries ==
        // completions + TX abandons, worker TX frames == completions ==
        // front-end RX deliveries.
        for (backend, rate) in [(Backend::Containerd, 320_000.0), (Backend::Junctiond, 64_000.0)]
        {
            let mut sim = Sim::new();
            let mut c = Cluster::new(backend, 2, 10, 11, 100_000);
            c.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
            c.scale_up(&mut sim, "aes");
            sim.run_until(SECONDS);
            let c = Rc::new(RefCell::new(c));
            let r = OpenLoop::new("aes", rate, 150 * MILLIS, 7).run_on(&mut sim, &c);
            assert_eq!(
                r.submitted,
                r.completed + r.dropped,
                "{backend:?}: submitted requests leaked"
            );
            let cl = c.borrow();
            let (rx, tx) = cl.nic_totals();
            let gw = cl.frontend_rx_stats();
            let served = cl.total_completed();
            assert_eq!(tx.tx_packets, served, "{backend:?}: worker TX frames != completions");
            assert_eq!(gw.rx_delivered, served, "{backend:?}: front-end RX != completions");
            assert_eq!(gw.rx_dropped, 0, "{backend:?}: the front end never loses a held frame");
            assert_eq!(
                rx.rx_delivered,
                served + tx.tx_abandoned,
                "{backend:?}: RX deliveries must all complete or abandon at TX"
            );
            assert!(
                cl.total_dropped() >= tx.tx_abandoned,
                "{backend:?}: worker drop counter must cover the TX abandons"
            );
            if backend == Backend::Containerd {
                assert!(rx.rx_dropped > 0, "320k rps must overflow the kernel RX rings");
                assert!(r.dropped > 0, "RX give-ups must surface as dropped requests");
            }
        }
    }

    #[test]
    fn trace_spans_tile_and_sum_under_overload() {
        use crate::workload::OpenLoop;
        // Cluster-wide tracing under overload on both backends: all
        // workers share one sequence space, the front end closes traces
        // after the return wire (drops close at the drop point), and
        // every retained exemplar's hop spans tile the root exactly —
        // no gaps, no overlap, even with retransmits and backpressure.
        for (backend, rate) in [(Backend::Containerd, 320_000.0), (Backend::Junctiond, 64_000.0)]
        {
            let mut sim = Sim::new();
            let mut c = Cluster::new(backend, 2, 10, 11, 100_000);
            c.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
            c.scale_up(&mut sim, "aes");
            sim.run_until(SECONDS);
            let tracer = c.enable_tracing(8);
            let c = Rc::new(RefCell::new(c));
            let r = OpenLoop::new("aes", rate, 150 * MILLIS, 7).run_on(&mut sim, &c);
            assert!(r.completed > 0, "{backend:?}: no completions under load");
            let cl = c.borrow();
            assert_eq!(
                tracer.completions(),
                cl.total_completed(),
                "{backend:?}: every completed request must close exactly one trace"
            );
            let exemplars = tracer.exemplars();
            assert_eq!(exemplars.len(), 8, "{backend:?}: tail reservoir should be full");
            for tr in &exemplars {
                let root = &tr.spans[0];
                assert_eq!(root.duration(), tr.e2e, "{backend:?}: root must span e2e");
                let kids = tr.root_children();
                assert_eq!(kids.len(), 5, "{backend:?}: five hop spans under the root");
                let mut cursor = root.start;
                let mut sum = 0;
                for k in &kids {
                    assert_eq!(k.start, cursor, "{backend:?}: hop spans must tile");
                    cursor = k.end;
                    sum += k.duration();
                }
                assert_eq!(cursor, root.end, "{backend:?}: hop spans must reach done");
                assert_eq!(sum, tr.e2e, "{backend:?}: hop durations must sum to e2e");
                for s in &tr.spans[7..] {
                    let p = &tr.spans[s.parent.unwrap() as usize];
                    assert!(
                        s.start >= p.start && s.end <= p.end,
                        "{backend:?}: event {} [{}, {}] escapes parent {} [{}, {}]",
                        s.name,
                        s.start,
                        s.end,
                        p.name,
                        p.start,
                        p.end
                    );
                }
            }
        }
    }

    #[test]
    fn conservation_and_traces_close_under_fault_schedule() {
        use crate::workload::OpenLoop;
        // The PR-6 overload law extended to an active fault plane: with a
        // worker crash, an instance crash, a gray window and a wire-loss
        // window all firing mid-run, every submitted request must still
        // resolve exactly once (completed, dropped, or timed out — the
        // deadline machinery guarantees wire-lost work resolves too), the
        // RX give-up and TX abandon paths must close their span trees on
        // both backends (no leaked live traces), and the whole audit tree
        // — including the fault plane's own injection conservation — must
        // stay clean.
        for (backend, rate) in [(Backend::Containerd, 320_000.0), (Backend::Junctiond, 64_000.0)]
        {
            let mut sim = Sim::new();
            let platform = Rc::new(PlatformConfig {
                deadline_timeout_ns: 20 * MILLIS,
                deadline_max_retries: 2,
                deadline_retry_backoff_ns: 20 * MICROS,
                hedge_quantile_bp: 9_500,
                fault_health_fail_threshold: 8,
                fault_health_eject_ns: 5 * MILLIS,
                nic_retry_jitter: 1,
                ..PlatformConfig::default()
            });
            let mut c = Cluster::new_with_platform(backend, 2, 10, 11, 100_000, platform);
            c.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
            c.scale_up(&mut sim, "aes");
            sim.run_until(SECONDS);
            let tracer = c.enable_tracing(8);
            let c = Rc::new(RefCell::new(c));
            let schedule = crate::faultplane::FaultSchedule::new()
                .instance_crash(SECONDS + 20 * MILLIS, 0, "aes")
                .worker_crash(SECONDS + 50 * MILLIS, 1)
                .gray(SECONDS + 70 * MILLIS, 0, 800, 30 * MILLIS)
                .wire_loss(SECONDS + 100 * MILLIS, 500, 30 * MILLIS);
            let faults = crate::faultplane::install(schedule, &mut sim, &c);
            let r = OpenLoop::new("aes", rate, 150 * MILLIS, 7).run_on(&mut sim, &c);
            assert_eq!(
                r.submitted,
                r.completed + r.dropped + r.timed_out,
                "{backend:?}: requests leaked under the fault schedule"
            );
            assert!(r.completed > 0, "{backend:?}: nothing completed under faults");
            assert_eq!(
                tracer.open_traces(),
                0,
                "{backend:?}: give-up/abandon paths leaked live traces"
            );
            let fs = *faults.borrow();
            assert_eq!(fs.injected, 4, "{backend:?}: all scheduled faults must fire");
            fs.assert_clean();
            assert!(fs.worst_recovery_ns > 0, "{backend:?}: crashes must pay recovery");
            let cl = c.borrow();
            let violations = crate::invariants::audit_all(&*cl);
            assert!(violations.is_empty(), "{backend:?}: audit violations: {violations:?}");
        }
    }

    #[test]
    fn fabric_totals_roll_up_and_conserve() {
        for backend in [Backend::Containerd, Backend::Junctiond] {
            let (mut sim, c) = cluster(backend, 3);
            for _ in 0..60 {
                c.borrow_mut().submit(&mut sim, "aes", |_, _| {});
            }
            sim.run_to_completion();
            let cl = c.borrow();
            let agg = cl.fabric_totals();
            let per_worker: Vec<_> =
                cl.workers.iter().map(|w| w.sim_node.fabric_stats()).collect();
            assert_eq!(
                agg.busy_ns,
                per_worker.iter().map(|s| s.busy_ns).sum::<u64>(),
                "{backend:?}: rollup busy_ns != sum of workers"
            );
            assert_eq!(
                agg.jobs_submitted,
                per_worker.iter().map(|s| s.jobs_submitted).sum::<u64>(),
                "{backend:?}: rollup job counts != sum of workers"
            );
            assert_eq!(agg.jobs_submitted, agg.jobs_completed, "{backend:?}: segments leaked");
            assert_eq!(
                agg.per_core_busy_ns.iter().sum::<u64>(),
                agg.busy_ns,
                "{backend:?}: index-wise per-core rollup drifted from the total"
            );
            assert_eq!(agg.cores, per_worker.iter().map(|s| s.cores).sum::<usize>());
            assert!(agg.busy_ns > 0, "{backend:?}: the cluster did run work");
        }
    }

    #[test]
    fn worker_manager_grows_pool() {
        let (_, c) = cluster(Backend::Junctiond, 2);
        let mut cl = c.borrow_mut();
        assert_eq!(cl.worker_count(), 2);
        cl.add_worker(10);
        assert_eq!(cl.worker_count(), 3);
        assert_eq!(cl.total_cores(), 30);
    }

    #[test]
    fn placement_least_loaded_spreads() {
        let mut sim = Sim::new();
        let mut c = Cluster::new(Backend::Junctiond, 3, 10, 1, 100_000);
        c.placement = Placement::LeastLoaded;
        for i in 0..6 {
            c.deploy(&mut sim, FunctionSpec::new(&format!("f{i}"), "aes600", RuntimeKind::Go));
        }
        let counts: Vec<usize> = c.workers.iter().map(|w| w.hosted.len()).collect();
        assert_eq!(counts, vec![2, 2, 2], "least-loaded should balance: {counts:?}");
    }

    #[test]
    fn placement_binpack_fills_densely() {
        let mut sim = Sim::new();
        let mut c = Cluster::new(Backend::Junctiond, 3, 10, 1, 1_000);
        c.placement = Placement::BinPack;
        for i in 0..6 {
            c.deploy(&mut sim, FunctionSpec::new(&format!("f{i}"), "aes600", RuntimeKind::Go));
        }
        let max = c.workers.iter().map(|w| w.hosted.len()).max().unwrap();
        assert!(max >= 5, "bin-pack should concentrate: {:?}",
            c.workers.iter().map(|w| w.hosted.len()).collect::<Vec<_>>());
    }

    #[test]
    fn cluster_throughput_scales_with_workers() {
        // Fixed offered load far above one containerd worker's capacity:
        // more workers with pre-scaled replicas → more goodput.
        let run = |n_workers: usize| -> f64 {
            let mut sim = Sim::new();
            let mut c = Cluster::new(Backend::Containerd, n_workers, 10, 1, 100_000);
            c.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
            for _ in 1..n_workers {
                c.scale_up(&mut sim, "aes");
            }
            sim.run_until(SECONDS);
            let c = Rc::new(RefCell::new(c));
            let result = Rc::new(RefCell::new(RunResult::default()));
            let mut t = sim.now();
            let end = t + SECONDS;
            let mut n = 0u64;
            while t < end {
                t += 33_333; // 30k rps offered — saturates up to ~5 workers
                n += 1;
                let c2 = c.clone();
                let r2 = result.clone();
                let end2 = end;
                sim.at(t, move |sim| {
                    c2.borrow_mut().submit(sim, "aes", move |sim, _| {
                        if sim.now() <= end2 {
                            r2.borrow_mut().completed_in_window += 1;
                        }
                    });
                });
            }
            let _ = n;
            sim.run_to_completion();
            let r = result.borrow();
            r.completed_in_window as f64
        };
        let one = run(1);
        let four = run(4);
        assert!(four > 2.5 * one, "4 workers should ≫ 1: {one} vs {four}");
    }
}
