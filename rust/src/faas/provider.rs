//! The faasd provider: resolves function names to running instances and
//! carries the §4 **metadata cache**.
//!
//! In mainline faasd every invocation asks containerd for the function's
//! state (replica count, task IP/port); the paper measured those queries
//! as "slower than the function invocation itself" and cached them in the
//! provider, invalidated through the gateway's deploy/scale path. The same
//! cache fronts junctiond, for a like-for-like comparison (§4). The E4
//! ablation toggles `cache_enabled`.

use std::collections::BTreeMap;

/// What the provider caches per function (§4: "the number of active
/// replicas of a function, as well as the associated local IP and port").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicaMeta {
    pub replicas: u32,
    pub addr: (u32, u16),
}

/// Result of a resolve attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Served from cache: no backend state query.
    Hit(ReplicaMeta),
    /// Cache disabled/cold: the caller must pay a backend state query and
    /// then `fill` the result.
    Miss,
}

/// Provider state.
#[derive(Debug)]
pub struct Provider {
    cache_enabled: bool,
    cache: BTreeMap<String, ReplicaMeta>,
    pub hits: u64,
    pub misses: u64,
    pub invalidations: u64,
}

impl Provider {
    pub fn new(cache_enabled: bool) -> Self {
        Provider {
            cache_enabled,
            cache: BTreeMap::new(),
            hits: 0,
            misses: 0,
            invalidations: 0,
        }
    }

    pub fn cache_enabled(&self) -> bool {
        self.cache_enabled
    }

    /// Resolve a function. A disabled cache always misses (mainline faasd
    /// behaviour: go to containerd every time).
    pub fn resolve(&mut self, name: &str) -> CacheOutcome {
        if self.cache_enabled {
            if let Some(meta) = self.cache.get(name) {
                self.hits += 1;
                return CacheOutcome::Hit(*meta);
            }
        }
        self.misses += 1;
        CacheOutcome::Miss
    }

    /// Install the result of a backend state query.
    pub fn fill(&mut self, name: &str, meta: ReplicaMeta) {
        if self.cache_enabled {
            self.cache.insert(name.to_string(), meta);
        }
    }

    /// Invalidate on scale/remove (all mutations flow through the gateway,
    /// which is the assumption the paper states for the cache's coherence).
    pub fn invalidate(&mut self, name: &str) {
        self.invalidations += 1;
        self.cache.remove(name);
    }

    pub fn cached(&self, name: &str) -> Option<ReplicaMeta> {
        self.cache.get(name).copied()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::{forall, Gen};

    const META: ReplicaMeta = ReplicaMeta { replicas: 1, addr: (0x0A00_0002, 31000) };

    #[test]
    fn first_miss_then_hits() {
        let mut p = Provider::new(true);
        assert_eq!(p.resolve("f"), CacheOutcome::Miss);
        p.fill("f", META);
        assert_eq!(p.resolve("f"), CacheOutcome::Hit(META));
        assert_eq!(p.hits, 1);
        assert_eq!(p.misses, 1);
    }

    #[test]
    fn disabled_cache_always_misses() {
        let mut p = Provider::new(false);
        p.fill("f", META);
        assert_eq!(p.resolve("f"), CacheOutcome::Miss);
        assert_eq!(p.resolve("f"), CacheOutcome::Miss);
        assert_eq!(p.hit_rate(), 0.0);
    }

    #[test]
    fn invalidate_forces_requery() {
        let mut p = Provider::new(true);
        p.fill("f", META);
        p.invalidate("f");
        assert_eq!(p.resolve("f"), CacheOutcome::Miss);
    }

    #[test]
    fn property_cache_coherent_with_ground_truth() {
        // Model: ground truth mutates only via deploy/scale (which
        // invalidates). A cached hit must always equal ground truth.
        forall("provider cache coherence", 60, |g: &mut Gen| {
            let mut p = Provider::new(true);
            let mut truth: BTreeMap<String, ReplicaMeta> = BTreeMap::new();
            let names = ["a", "b", "c"];
            for _ in 0..100 {
                let name = *g.choose(&names);
                match g.u64(0, 3) {
                    0 => {
                        // scale mutation through the gateway
                        let meta = ReplicaMeta {
                            replicas: g.u64(1, 8) as u32,
                            addr: (g.u64(1, 1 << 30) as u32, g.u64(1024, 65535) as u16),
                        };
                        truth.insert(name.to_string(), meta);
                        p.invalidate(name);
                    }
                    _ => match p.resolve(name) {
                        CacheOutcome::Hit(meta) => {
                            assert_eq!(Some(meta), truth.get(name).copied(), "stale cache");
                        }
                        CacheOutcome::Miss => {
                            if let Some(meta) = truth.get(name) {
                                p.fill(name, *meta);
                            }
                        }
                    },
                }
            }
        });
    }
}
