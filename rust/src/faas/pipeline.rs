//! The discrete-event invocation pipeline: client → gateway → provider →
//! function instance → provider → gateway → client, for both backends.
//!
//! This is the simulation counterpart of the paper's Figure 2/4 topology.
//! Each component pass is one CPU *segment* on the shared worker core
//! pool, prefixed by that backend's wakeup/delivery latency:
//!
//! * **containerd**: segments pay kernel RX/TX (IRQ + softirq + stack +
//!   wakeup + syscalls), veth hops into the container, heavy-tailed
//!   scheduling noise, and rare interference bursts — all from
//!   [`crate::oskernel::KernelCosts`].
//! * **junctiond**: segments pay the Junction user-space stack and the
//!   central scheduler's wakeup/grant path — from
//!   [`crate::junction::BypassCosts`] and the live
//!   [`crate::junction::Scheduler`] instance inside [`crate::junctiond::Junctiond`].
//!
//! Function compute is *real*: the default segment cost comes from PJRT
//! calibration of the AES-600B artifact (`runtime::calibrate`), so the
//! simulated function body costs what the actual lowered HLO costs on
//! this machine.
//!
//! **Provisioning** goes through the tiered ladder in [`crate::snapshot`]:
//! every replica is acquired from the warm pool when possible, restored
//! from a per-function snapshot otherwise, and cold-booted only as a last
//! resort. The tier that provisioned the serving replica is recorded on
//! every [`RequestTiming`] and in per-tier counters exported through
//! [`crate::telemetry::MetricsRegistry`].

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::config::{Backend, ExperimentConfig, PlatformConfig};
use crate::containerd_sim::{ContainerId, ContainerState, Containerd};
use crate::invariants::{check, Audit, AuditTree, Violation};
use crate::junction::{BypassCosts, InstanceId};
use crate::junctiond::Junctiond;
use crate::netpath::{NicQueue, NicStats, Packet, TxQueue, TxStats};
use crate::oskernel::KernelCosts;
use crate::rpc::Message;
use crate::simcore::{
    ComputeFabric, FabricConfig, FabricStats, JobClass, Rng, Sim, SliceObs, SliceRecord, Time,
    TimerHandle, MILLIS,
};
use crate::snapshot::{
    ArrivalEstimator, PoolConfig, PoolHandle, PoolStats, PrewarmPolicy, ProvisionTier, SlotId,
    SnapshotStore, TierCosts, WarmPool,
};
use crate::telemetry::{Hop, HopTimes, Tracer};

use super::{CacheOutcome, FunctionSpec, Gate, Gateway, Provider, Registry, ReplicaMeta};

/// Time constant for the per-function arrival-rate estimator feeding the
/// prewarm policy.
const ESTIMATOR_TAU: Time = 250 * MILLIS;

/// Per-request timestamps (virtual ns).
#[derive(Debug, Clone, Copy, Default)]
pub struct RequestTiming {
    /// Client issued the request.
    pub submit: Time,
    /// Request frame reached the worker NIC RX ring (after the wire hop).
    pub nic_in: Time,
    /// Gateway received it (start of the gateway-observed window).
    pub gateway_in: Time,
    /// Function instance admitted the request (exec window start).
    pub exec_start: Time,
    /// Function instance finished (exec window end).
    pub exec_end: Time,
    /// Client received the response.
    pub done: Time,
    /// Provisioning tier of the replica that served this invocation.
    pub tier: ProvisionTier,
    /// Client retransmissions this request needed (NIC RX tail drops).
    pub retries: u32,
    /// Response frame first offered to the worker's TX ring.
    pub tx_in: Time,
    /// Responder re-offers after TX-ring backpressure stalls.
    pub tx_retries: u32,
    /// True when the request was abandoned — either the client exhausted
    /// its RX retransmits, or the worker exhausted its TX stall budget
    /// (`tx_retries` > 0 distinguishes the latter); only `submit`,
    /// `nic_in`, `retries`, `tx_retries` and `done` are meaningful then.
    pub dropped: bool,
    /// True when the resolution was caused by the fault plane (a wire-loss
    /// window or a crashed worker ate an attempt); always accompanied by
    /// `dropped` when the request never completed.
    pub failed: bool,
    /// True when the gateway-side deadline expired before any attempt
    /// resolved (recovery path); disjoint from `dropped` — a timed-out
    /// request was neither completed nor counted as a NIC/TX abandon.
    pub timed_out: bool,
    /// True when a hedged duplicate beat the primary attempt to the
    /// response.
    pub hedge_won: bool,
    /// Retries the recovery layer re-issued on a *different* worker
    /// (distinct from `retries`, which counts NIC retransmits on one
    /// worker's wire).
    pub retried_other_worker: u32,
    /// Trace sequence number assigned at submit; 0 when tracing is off.
    pub seq: u64,
}

impl RequestTiming {
    /// Client-observed end-to-end latency.
    pub fn e2e(&self) -> Time {
        self.done - self.submit
    }
    /// Gateway-observed latency (what the paper's Fig. 5 plots).
    pub fn gateway_observed(&self) -> Time {
        self.done.saturating_sub(self.gateway_in)
    }
    /// Function execution latency (Fig. 5's second series).
    pub fn exec(&self) -> Time {
        self.exec_end - self.exec_start
    }
    /// NIC hop latency: RX ring wait + per-packet service, plus any
    /// retransmit backoffs the request ate before being accepted.
    pub fn nic_hop(&self) -> Time {
        self.gateway_in.saturating_sub(self.nic_in)
    }
    /// Gateway + provider passes, queueing, and instance admission — the
    /// in-worker RPC hops before the exec window opens.
    pub fn pre_exec(&self) -> Time {
        self.exec_start.saturating_sub(self.gateway_in)
    }
    /// Response path from instance completion back to the client.
    pub fn response_hop(&self) -> Time {
        self.done.saturating_sub(self.exec_end)
    }
    /// Transmit hop (a sub-span of [`RequestTiming::response_hop`]): TX
    /// ring wait + per-frame flush service + the return wire, plus any
    /// backpressure stalls the response ate — symmetric with
    /// [`RequestTiming::nic_hop`] on the request side.
    pub fn tx_hop(&self) -> Time {
        self.done.saturating_sub(self.tx_in)
    }
}

/// One deployed replica's runtime handle.
enum ReplicaHandle {
    Container(ContainerId),
    Junction(InstanceId),
}

/// One provisioned replica: backend handle, concurrency gate, readiness,
/// and the provenance the telemetry reports.
struct Replica {
    handle: ReplicaHandle,
    gate: Gate,
    /// Virtual time this replica starts accepting traffic.
    ready_at: Time,
    /// Which rung of the ladder produced it.
    tier: ProvisionTier,
    /// Name junctiond's bookkeeping filed the instance(s) under.
    jd_name: String,
}

struct DeployedFn {
    spec: FunctionSpec,
    replicas: Vec<Replica>,
    meta: ReplicaMeta,
    /// Requests submitted but not yet fully responded (guards undeploy).
    outstanding: u32,
}

struct World {
    platform: Rc<PlatformConfig>,
    backend: Backend,
    cores: ComputeFabric,
    /// Kernel backend: cores (from `softirq_core_mask`) that take NIC
    /// IRQ/softirq work, and the round-robin cursor spreading bursts
    /// across them. Empty = unpinned (the seed's abstract pool charge).
    softirq_cores: Vec<usize>,
    softirq_rr: u64,
    // Per-component cost samplers (independent RNG streams).
    kc_gw: KernelCosts,
    kc_prov: KernelCosts,
    kc_fn: KernelCosts,
    bc_gw: BypassCosts,
    bc_prov: BypassCosts,
    bc_fn: BypassCosts,
    // Backends.
    jd: Junctiond,
    containerd: Containerd,
    // faasd services.
    gateway: Gateway,
    provider: Provider,
    registry: Registry,
    functions: BTreeMap<String, DeployedFn>,
    // Tiered provisioning (snapshot/ subsystem).
    pool: WarmPool,
    snapshots: SnapshotStore,
    tier_costs: TierCosts,
    estimators: BTreeMap<String, ArrivalEstimator>,
    prewarm: PrewarmPolicy,
    /// Per-slot idle-TTL eviction timers (armed while pool maintenance is
    /// active; cancelled in O(1) when the slot is acquired or reclaimed).
    ttl_timers: BTreeMap<SlotId, TimerHandle>,
    /// True once `start_pool_maintenance` switched the pool to per-slot
    /// TTL timers.
    ttl_active: bool,
    /// Warm slots acquired inside `provision_single` (which has no `Sim`
    /// access); the public entry points drain this and cancel the slots'
    /// TTL timers.
    ttl_cancel_queue: Vec<SlotId>,
    /// Instances provisioned per tier (index = `ProvisionTier::idx`).
    tier_provisioned: [u64; 3],
    /// Invocations served per replica-provisioning tier.
    tier_served: [u64; 3],
    // The services' own junction instances (§3: services run in instances).
    gw_inst: Option<InstanceId>,
    prov_inst: Option<InstanceId>,
    compute_ns: Time,
    pub completed: u64,
    // Network data path (netpath): the worker's bounded NIC RX + TX rings
    // plus their per-packet cost samplers (shared by both directions).
    nic: NicQueue,
    tx: TxQueue,
    kc_nic: KernelCosts,
    bc_nic: BypassCosts,
    /// Payload bytes each invocation carries in its framed `rpc::Message`
    /// (the AES-600B input); packets are sized via
    /// `Message::request_frame_size` without materializing bodies.
    payload_bytes: usize,
    /// Requests abandoned after exhausting NIC retransmits.
    pub dropped: u64,
    /// Span-per-invocation tracer (disabled by default: every call is a
    /// cheap early return and `seq` stays 0, so the traced pipeline is
    /// byte-identical to the untraced one).
    tracer: Tracer,
    /// Whether this sim closes traces when `done` fires. A cluster shares
    /// one tracer across workers and closes traces at its frontend
    /// instead (the worker-local `done` fires before the return wire and
    /// frontend RX, which belong to the trace's tx hop).
    trace_finalize: bool,
    /// Gray-failure multiplier on function compute, in percent (100 =
    /// healthy). Only the fault plane moves it; runs without a fault
    /// schedule never touch it.
    degrade_x100: Time,
    /// Decorrelated-jitter state for NIC retry backoffs. Seeded
    /// independently of the cost samplers' fork chain and only drawn from
    /// when `platform.nic_retry_jitter == 1`, so the default path stays
    /// byte-identical to the constant-backoff seed.
    jitter_rng: Rng,
    rx_backoff_prev: Time,
    tx_backoff_prev: Time,
}

impl World {
    /// Wakeup latency + in-flight accounting for a service instance on the
    /// junction path; no-op for containerd. Also returns the grant
    /// outcome's stable cause tag (`"none"` off the junction path) for
    /// the `sched.wakeup` trace span.
    fn service_wakeup(&mut self, inst: Option<InstanceId>) -> (Time, &'static str) {
        match (self.backend, inst) {
            (Backend::Junctiond, Some(id)) => {
                let out = self.jd.scheduler.packet_arrival(id);
                (out.latency(), out.kind())
            }
            _ => (0, "none"),
        }
    }

    /// Physical core a junction instance's next segment should run on
    /// (round-robin over its grant); `None` on the kernel backend or for
    /// a grant-less (contended) instance — the segment then takes the
    /// fabric's shared queue.
    fn segment_core(&mut self, inst: Option<InstanceId>) -> Option<usize> {
        match (self.backend, inst) {
            (Backend::Junctiond, Some(id)) => {
                self.jd.scheduler.pick_core(id).map(|c| c as usize)
            }
            _ => None,
        }
    }

    /// Kernel backend: the core the next NIC softirq burst lands on.
    fn next_softirq_core(&mut self) -> Option<usize> {
        if self.softirq_cores.is_empty() {
            return None;
        }
        let i = (self.softirq_rr as usize) % self.softirq_cores.len();
        self.softirq_rr += 1;
        Some(self.softirq_cores[i])
    }

    fn service_done(&mut self, inst: Option<InstanceId>) {
        if let (Backend::Junctiond, Some(id)) = (self.backend, inst) {
            // The instance may have been crash-retired while the segment
            // ran (its in-flight was zeroed then); `request_done` asserts
            // otherwise. Without a crash the guard is always true.
            if self.jd.scheduler.instance(id).map_or(false, |i| i.in_flight > 0) {
                self.jd.scheduler.request_done(id);
            }
        }
    }

    /// RX retransmit backoff for the next client attempt. With
    /// `nic_retry_jitter` off this is the constant platform backoff (the
    /// seed's behavior — zero RNG draws); with it on, *decorrelated
    /// jitter*: each wait is drawn uniformly from
    /// `[base, min(prev * 3, base * 10)]`, so synchronized retry storms
    /// spread out instead of re-colliding on the backoff boundary.
    fn rx_retry_backoff(&mut self) -> Time {
        let base = self.platform.nic_retry_backoff_ns;
        if self.platform.nic_retry_jitter == 0 {
            return base;
        }
        let hi = (self.rx_backoff_prev * 3).clamp(base, base * 10);
        let b = base + self.jitter_rng.below(hi - base + 1);
        self.rx_backoff_prev = b;
        b
    }

    /// TX stall backoff for the responder's next re-offer; same
    /// decorrelated-jitter scheme as [`World::rx_retry_backoff`], with
    /// its own state so the two directions don't correlate.
    fn tx_retry_backoff(&mut self) -> Time {
        let base = self.platform.nic_tx_retry_backoff_ns;
        if self.platform.nic_retry_jitter == 0 {
            return base;
        }
        let hi = (self.tx_backoff_prev * 3).clamp(base, base * 10);
        let b = base + self.jitter_rng.below(hi - base + 1);
        self.tx_backoff_prev = b;
        b
    }

    /// Provision one single-instance replica through the tier ladder:
    /// warm pool → snapshot restore → cold boot. `jd_name` is the name the
    /// backend's own bookkeeping uses (distinct for added replicas);
    /// `spec.name` keys the pool and the snapshot store.
    fn provision_single(
        &mut self,
        now: Time,
        jd_name: &str,
        spec: &FunctionSpec,
        allow_pool: bool,
    ) -> Replica {
        let fn_name = &spec.name;
        if allow_pool {
            if let Some((slot, handle)) = self.pool.acquire_warm(fn_name, now) {
                // The slot left `Warm`: queue its idle-TTL timer for O(1)
                // cancellation by the caller (which holds the `Sim`).
                self.ttl_cancel_queue.push(slot);
                let lat = self.tier_costs.warm_acquire_ns;
                let (handle, conc) = match handle {
                    PoolHandle::Junction(id) => {
                        self.jd.adopt_instances(jd_name, spec.scale.max(1), &[id]);
                        (ReplicaHandle::Junction(id), self.jd.concurrency_of(id, spec))
                    }
                    PoolHandle::Container(cid) => {
                        match self.containerd.get(cid).unwrap().state {
                            ContainerState::Paused => self.containerd.resume(cid),
                            // Acquired at the same instant its background
                            // restore finished: the park-side fixup (mark
                            // running + pause) hasn't run yet.
                            ContainerState::Creating => self.containerd.mark_running(cid),
                            _ => {}
                        }
                        (
                            ReplicaHandle::Container(cid),
                            self.platform.container_concurrency as u32,
                        )
                    }
                };
                self.tier_provisioned[ProvisionTier::WarmPool.idx()] += 1;
                return Replica {
                    handle,
                    gate: Gate::new(conc),
                    ready_at: now + lat,
                    tier: ProvisionTier::WarmPool,
                    jd_name: jd_name.to_string(),
                };
            }
            if self.snapshots.ready(fn_name, now) {
                let (handle, conc, lat) = match self.backend {
                    Backend::Junctiond => {
                        let mut s = spec.clone();
                        s.name = jd_name.to_string();
                        let (ids, lat) = self.jd.restore_function(&s, self.tier_costs.restore_ns);
                        (ReplicaHandle::Junction(ids[0]), self.jd.concurrency_of(ids[0], spec), lat)
                    }
                    Backend::Containerd => {
                        let (cid, lat) = self.containerd.restore_from_snapshot(
                            jd_name,
                            now,
                            self.tier_costs.restore_ns,
                        );
                        (
                            ReplicaHandle::Container(cid),
                            self.platform.container_concurrency as u32,
                            lat,
                        )
                    }
                };
                self.snapshots.note_restore(fn_name);
                self.tier_provisioned[ProvisionTier::SnapshotRestore.idx()] += 1;
                return Replica {
                    handle,
                    gate: Gate::new(conc),
                    ready_at: now + lat,
                    tier: ProvisionTier::SnapshotRestore,
                    jd_name: jd_name.to_string(),
                };
            }
        }
        // Cold boot — the seed's only path — plus an off-critical-path
        // snapshot capture so later provisions can take the faster rungs.
        let (handle, conc, lat) = match self.backend {
            Backend::Junctiond => {
                let mut s = spec.clone();
                s.name = jd_name.to_string();
                let (ids, lat) = self.jd.deploy_function(&s);
                (ReplicaHandle::Junction(ids[0]), self.jd.concurrency_of(ids[0], spec), lat)
            }
            Backend::Containerd => {
                let (cid, lat) = self.containerd.create_and_start(jd_name, now);
                (ReplicaHandle::Container(cid), self.platform.container_concurrency as u32, lat)
            }
        };
        self.snapshots.capture(
            fn_name,
            now + lat,
            self.tier_costs.capture_ns,
            self.tier_costs.instance_mem_bytes,
        );
        self.tier_provisioned[ProvisionTier::ColdBoot.idx()] += 1;
        Replica {
            handle,
            gate: Gate::new(conc),
            ready_at: now + lat,
            tier: ProvisionTier::ColdBoot,
            jd_name: jd_name.to_string(),
        }
    }

    /// Tear down instances the pool evicted.
    fn teardown(&mut self, handles: Vec<PoolHandle>) {
        for h in handles {
            match h {
                PoolHandle::Junction(id) => self.jd.retire_instance(id),
                PoolHandle::Container(cid) => {
                    if self.containerd.get(cid).is_some() {
                        self.containerd.stop(cid);
                    }
                }
            }
        }
    }
}

/// The simulated faasd deployment (one worker server + a client machine).
#[derive(Clone)]
pub struct FaasSim {
    w: Rc<RefCell<World>>,
}

impl FaasSim {
    pub fn new(cfg: &ExperimentConfig, platform: Rc<PlatformConfig>) -> Self {
        let mut rng = Rng::new(cfg.seed);
        // Per-backend fabric shape: the kernel backend is CFS-flavored
        // (timeslices, wakeup migration/stealing), the bypass backend
        // maps grants to soft-pinned cores with run-to-completion sliced
        // only at the Junction scheduler's fine regrant quantum.
        let fabric_cfg = match cfg.backend {
            Backend::Containerd => FabricConfig {
                quantum_ns: platform.sched_quantum_ns,
                steal: platform.sched_steal != 0,
                migration_cost_ns: platform.sched_migration_cost_ns,
            },
            Backend::Junctiond => FabricConfig {
                quantum_ns: platform.junction_quantum_ns,
                steal: false,
                migration_cost_ns: 0,
            },
        };
        let cores =
            ComputeFabric::new_kind(crate::simcore::default_fabric(), cfg.worker_cores, fabric_cfg);
        let softirq_cores: Vec<usize> = match cfg.backend {
            Backend::Containerd => (0..cfg.worker_cores.min(64))
                .filter(|i| (platform.softirq_core_mask & (1u64 << i)) != 0)
                .collect(),
            Backend::Junctiond => Vec::new(),
        };
        let mut jd = Junctiond::new(platform.clone(), cfg.worker_cores as u32, rng.fork());
        let containerd = Containerd::new(platform.clone(), rng.fork());
        let mut gw_inst = None;
        let mut prov_inst = None;
        if cfg.backend == Backend::Junctiond {
            // The scheduler busy-polls on a dedicated, reserved core (§2.2.1).
            cores.reserve(1);
            // Gateway and provider run inside Junction instances (§3).
            // Their segments execute on granted physical cores now, so the
            // multi-queue services carry a 4-core cap to keep the service
            // plane off the critical path at high offered load.
            gw_inst = Some(jd.deploy_service("gateway", 4).0);
            prov_inst = Some(jd.deploy_service("provider", 4).0);
        }
        let world = World {
            backend: cfg.backend,
            cores,
            softirq_cores,
            softirq_rr: 0,
            kc_gw: KernelCosts::new(platform.clone(), rng.fork()),
            kc_prov: KernelCosts::new(platform.clone(), rng.fork()),
            kc_fn: KernelCosts::new(platform.clone(), rng.fork()),
            bc_gw: BypassCosts::new(platform.clone(), rng.fork()).with_sched_tail(),
            bc_prov: BypassCosts::new(platform.clone(), rng.fork()).with_sched_tail(),
            bc_fn: BypassCosts::new(platform.clone(), rng.fork()),
            jd,
            containerd,
            gateway: Gateway::new(),
            provider: Provider::new(cfg.provider_cache),
            registry: Registry::new(),
            functions: BTreeMap::new(),
            pool: WarmPool::new(PoolConfig::from_platform(&platform)),
            snapshots: SnapshotStore::new(),
            tier_costs: TierCosts::for_backend(cfg.backend, &platform),
            estimators: BTreeMap::new(),
            prewarm: PrewarmPolicy::default(),
            ttl_timers: BTreeMap::new(),
            ttl_active: false,
            ttl_cancel_queue: Vec::new(),
            tier_provisioned: [0; 3],
            tier_served: [0; 3],
            gw_inst,
            prov_inst,
            compute_ns: cfg.function_compute_ns,
            completed: 0,
            nic: NicQueue::new(platform.nic_queue_depth as usize),
            tx: TxQueue::new(platform.nic_tx_queue_depth as usize),
            kc_nic: KernelCosts::new(platform.clone(), rng.fork()),
            bc_nic: BypassCosts::new(platform.clone(), rng.fork()),
            payload_bytes: platform.rpc_payload_bytes as usize,
            dropped: 0,
            tracer: Tracer::new(),
            trace_finalize: true,
            degrade_x100: 100,
            jitter_rng: Rng::new(cfg.seed ^ 0x4A17_7E5A),
            rx_backoff_prev: 0,
            tx_backoff_prev: 0,
            platform,
        };
        FaasSim { w: Rc::new(RefCell::new(world)) }
    }

    /// Deploy a function on the active backend via the tier ladder.
    /// Returns the provisioning duration; the function accepts traffic
    /// from `sim.now() + duration`.
    pub fn deploy(&self, sim: &mut Sim, spec: FunctionSpec) -> Time {
        self.deploy_tiered(sim, spec, true).0
    }

    /// Deploy bypassing the pool and the snapshot store (always cold —
    /// the seed's behavior, kept as the ablation baseline).
    pub fn deploy_cold(&self, sim: &mut Sim, spec: FunctionSpec) -> Time {
        self.deploy_tiered(sim, spec, false).0
    }

    /// Deploy and report which provisioning tier served the request.
    pub fn deploy_tiered(
        &self,
        sim: &mut Sim,
        spec: FunctionSpec,
        allow_pool: bool,
    ) -> (Time, ProvisionTier) {
        let now = sim.now();
        let (lat, tier, marks, ttl_cancels) = {
            let mut w = self.w.borrow_mut();
            w.registry.deploy(spec.clone()).expect("duplicate deploy");
            let replicas = if spec.scale.max(1) == 1 {
                vec![w.provision_single(now, &spec.name, &spec, allow_pool)]
            } else {
                // Multi-instance shapes (uProc fan-out, isolated replicas)
                // keep the seed's cold path: the ladder hands out single
                // instances.
                provision_multi(&mut w, now, &spec)
            };
            let lat = replicas.iter().map(|r| r.ready_at).max().unwrap() - now;
            let tier = replicas[0].tier;
            let addr = match &replicas[0].handle {
                ReplicaHandle::Container(cid) => w.containerd.get(*cid).unwrap().addr,
                ReplicaHandle::Junction(id) => {
                    let cfg = w.jd.config_of(*id).unwrap();
                    (cfg.ip, cfg.port)
                }
            };
            // Containers still booting flip to Running at their ready time.
            let marks: Vec<(ContainerId, Time)> = replicas
                .iter()
                .filter_map(|r| match r.handle {
                    ReplicaHandle::Container(cid)
                        if w.containerd.get(cid).unwrap().state == ContainerState::Creating =>
                    {
                        Some((cid, r.ready_at))
                    }
                    _ => None,
                })
                .collect();
            let meta = ReplicaMeta { replicas: replicas.len() as u32, addr };
            w.functions.insert(
                spec.name.clone(),
                DeployedFn { spec: spec.clone(), replicas, meta, outstanding: 0 },
            );
            let ttl_cancels = std::mem::take(&mut w.ttl_cancel_queue);
            (lat, tier, marks, ttl_cancels)
        };
        self.ttl_cancel(sim, ttl_cancels);
        for (cid, at) in marks {
            let this = self.clone();
            sim.at(at, move |_| this.w.borrow_mut().containerd.mark_running(cid));
        }
        (lat, tier)
    }

    /// Remove a function and park its (idle) instances into the warm pool.
    /// Refuses — returning `false` — while any request is outstanding or a
    /// replica is still booting, so an invocation can never land on a
    /// parked instance.
    pub fn undeploy(&self, sim: &mut Sim, name: &str) -> bool {
        let now = sim.now();
        let mut w = self.w.borrow_mut();
        let Some(f) = w.functions.get(name) else { return false };
        if f.outstanding > 0 {
            return false;
        }
        if f.replicas.iter().any(|r| r.ready_at > now || r.gate.in_use() > 0 || r.gate.waiting() > 0)
        {
            return false;
        }
        for r in &f.replicas {
            if let ReplicaHandle::Junction(id) = r.handle {
                if w.jd.scheduler.instance(id).map_or(0, |i| i.in_flight) > 0 {
                    return false;
                }
            }
        }
        let f = w.functions.remove(name).unwrap();
        w.registry.remove(name);
        w.provider.invalidate(name);
        w.gateway.evict(name);
        let mem = w.tier_costs.instance_mem_bytes;
        let mut parked: Vec<SlotId> = Vec::new();
        for r in &f.replicas {
            match r.handle {
                ReplicaHandle::Junction(_) => {
                    for id in w.jd.park_instances(&r.jd_name) {
                        match w.pool.try_park(name, PoolHandle::Junction(id), now, mem) {
                            Some(slot) => parked.push(slot),
                            None => w.jd.retire_instance(id),
                        }
                    }
                }
                ReplicaHandle::Container(cid) => {
                    if w.containerd.get(cid).unwrap().state == ContainerState::Running {
                        w.containerd.pause(cid);
                        match w.pool.try_park(name, PoolHandle::Container(cid), now, mem) {
                            Some(slot) => parked.push(slot),
                            None => w.containerd.stop(cid),
                        }
                    } else {
                        w.containerd.stop(cid);
                    }
                }
            }
        }
        let reclaimed = w.pool.reclaim_to_budget();
        let reclaimed_slots: Vec<SlotId> = reclaimed.iter().map(|(s, _)| *s).collect();
        let handles = reclaimed.into_iter().map(|(_, h)| h).collect();
        w.teardown(handles);
        drop(w);
        // Arm per-slot idle-TTL timers for what survived the budget pass;
        // cancel timers of previously-warm slots the pass reclaimed.
        for slot in parked {
            if !reclaimed_slots.contains(&slot) {
                self.ttl_arm(sim, slot, now);
            }
        }
        self.ttl_cancel(sim, reclaimed_slots);
        true
    }

    pub fn is_deployed(&self, name: &str) -> bool {
        self.w.borrow().functions.contains_key(name)
    }

    /// Add one replica to a deployed function through the tier ladder
    /// (the pipeline-level scale-up path). Returns the tier that served
    /// the request and the time until the replica is ready.
    pub fn scale_up_replica(
        &self,
        sim: &mut Sim,
        name: &str,
        allow_pool: bool,
    ) -> Option<(ProvisionTier, Time)> {
        let now = sim.now();
        let (tier, lat, mark, ttl_cancels) = {
            let mut w = self.w.borrow_mut();
            let (spec, idx) = {
                let f = w.functions.get(name)?;
                (f.spec.clone(), f.replicas.len())
            };
            let mut rspec = spec;
            rspec.scale = 1;
            let jd_name = format!("{name}#r{idx}");
            let r = w.provision_single(now, &jd_name, &rspec, allow_pool);
            let tier = r.tier;
            let lat = r.ready_at - now;
            let mark = match r.handle {
                ReplicaHandle::Container(cid)
                    if w.containerd.get(cid).unwrap().state == ContainerState::Creating =>
                {
                    Some((cid, r.ready_at))
                }
                _ => None,
            };
            let f = w.functions.get_mut(name).unwrap();
            f.replicas.push(r);
            f.meta.replicas += 1;
            w.provider.invalidate(name);
            let ttl_cancels = std::mem::take(&mut w.ttl_cancel_queue);
            (tier, lat, mark, ttl_cancels)
        };
        self.ttl_cancel(sim, ttl_cancels);
        if let Some((cid, at)) = mark {
            let this = self.clone();
            sim.at(at, move |_| this.w.borrow_mut().containerd.mark_running(cid));
        }
        Some((tier, lat))
    }

    /// TTL sweep: evict idle warm instances past the keep-alive and tear
    /// them down. (Manual/bench entry point; with maintenance active the
    /// per-slot TTL timers do this exactly at each slot's deadline.)
    pub fn pool_sweep(&self, sim: &mut Sim) {
        let slots = {
            let mut w = self.w.borrow_mut();
            let now = sim.now();
            let evicted = w.pool.sweep_ttl(now);
            let slots: Vec<SlotId> = evicted.iter().map(|(s, _)| *s).collect();
            let handles = evicted.into_iter().map(|(_, h)| h).collect();
            w.teardown(handles);
            slots
        };
        self.ttl_cancel(sim, slots);
        // Sweeps are quiesce points: debug builds re-prove every
        // conservation law after the teardown churn.
        crate::invariants::debug_quiesce(self);
    }

    /// Evict *every* parked instance (bench helper: forces the next
    /// provision down to the snapshot-restore or cold tier).
    pub fn flush_warm_pool(&self, sim: &mut Sim) {
        let slots = {
            let mut w = self.w.borrow_mut();
            let evicted = w.pool.flush();
            let slots: Vec<SlotId> = evicted.iter().map(|(s, _)| *s).collect();
            let handles = evicted.into_iter().map(|(_, h)| h).collect();
            w.teardown(handles);
            slots
        };
        self.ttl_cancel(sim, slots);
        crate::invariants::debug_quiesce(self);
    }

    /// Fault plane: set the gray-degradation multiplier (percent of the
    /// healthy compute cost; 100 restores health). Purely multiplicative
    /// on function bodies — no events, no RNG draws.
    pub fn set_degrade(&self, x100: Time) {
        self.w.borrow_mut().degrade_x100 = x100.max(1);
    }

    /// Current gray-degradation multiplier (100 = healthy).
    pub fn degrade(&self) -> Time {
        self.w.borrow().degrade_x100
    }

    /// Fault plane: crash every replica of `name` mid-flight and
    /// re-provision the function through the tier ladder. The snapshot
    /// store and warm pool survive the crash (they live host-side), so
    /// recovery normally lands on the restore rung instead of a cold
    /// boot. In-flight requests on the crashed replicas keep flowing
    /// through the pipeline (their scheduler bookkeeping was zeroed at
    /// crash time — the completion path's guards skip the double
    /// release); requests arriving afterwards wait on the replacement's
    /// readiness. Returns the re-provision latency (the recovery
    /// window), or `None` if the function is not deployed.
    pub fn crash_function(&self, sim: &mut Sim, name: &str) -> Option<Time> {
        let (spec, carried) = {
            let mut w = self.w.borrow_mut();
            let f = w.functions.remove(name)?;
            w.registry.remove(name);
            w.provider.invalidate(name);
            w.gateway.evict(name);
            for r in &f.replicas {
                match r.handle {
                    ReplicaHandle::Junction(_) => {
                        // fail first (zeroes in-flight, ticks the crash
                        // counter), then detach and retire — junctiond's
                        // restart sweep must not see these as revivable.
                        let ids = w.jd.instances_of(&r.jd_name).to_vec();
                        for id in &ids {
                            w.jd.fail_instance(*id);
                        }
                        w.jd.park_instances(&r.jd_name);
                        for id in ids {
                            w.jd.retire_instance(id);
                        }
                    }
                    ReplicaHandle::Container(cid) => {
                        if w.containerd.get(cid).is_some() {
                            w.containerd.stop(cid);
                        }
                    }
                }
            }
            (f.spec, f.outstanding)
        };
        let lat = self.deploy_tiered(sim, spec, true).0;
        if carried > 0 {
            // Requests in flight at crash time still resolve through the
            // redeployed entry; keep the outstanding guard exact.
            if let Some(f) = self.w.borrow_mut().functions.get_mut(name) {
                f.outstanding = carried;
            }
        }
        Some(lat)
    }

    /// Arm the per-slot idle-TTL eviction timer for a freshly-parked (or
    /// freshly-promoted) warm slot. No-op until `start_pool_maintenance`
    /// activates timer-driven keep-alive.
    fn ttl_arm(&self, sim: &mut Sim, slot: SlotId, parked_at: Time) {
        let (active, ttl) = {
            let w = self.w.borrow();
            (w.ttl_active, w.pool.cfg.idle_ttl_ns)
        };
        if !active {
            return;
        }
        let this = self.clone();
        let h = sim.at_handle(parked_at.saturating_add(ttl), move |_| this.ttl_fire(slot));
        let prev = self.w.borrow_mut().ttl_timers.insert(slot, h);
        debug_assert!(prev.is_none(), "slot {slot} double-armed a TTL timer");
    }

    /// A slot's idle TTL expired without an acquire: evict and tear down.
    /// With real cancellation this only ever fires on a still-warm slot
    /// (acquire/reclaim/flush cancel the timer), so there is no tombstone
    /// state to re-check beyond the pool's own defensive guard.
    fn ttl_fire(&self, slot: SlotId) {
        let mut w = self.w.borrow_mut();
        w.ttl_timers.remove(&slot);
        if let Some(h) = w.pool.evict_idle(slot) {
            w.teardown(vec![h]);
        }
    }

    /// Cancel the TTL timers of slots that just left the warm state
    /// (acquired, reclaimed, swept, or flushed) — O(1) per slot.
    fn ttl_cancel<I: IntoIterator<Item = SlotId>>(&self, sim: &mut Sim, slots: I) {
        let handles: Vec<TimerHandle> = {
            let mut w = self.w.borrow_mut();
            slots.into_iter().filter_map(|s| w.ttl_timers.remove(&s)).collect()
        };
        for h in handles {
            let live = sim.cancel(h);
            debug_assert!(live, "TTL timer map held a stale handle");
        }
    }

    /// Prewarm hook: for every deployed function whose estimated arrival
    /// rate warrants it, restore (or boot) instances into the pool in the
    /// background so later scale-ups take the warm tier.
    pub fn prewarm_tick(&self, sim: &mut Sim) {
        let now = sim.now();
        let scheduled = {
            let mut w = self.w.borrow_mut();
            w.pool.promote_ready(now);
            let names: Vec<String> = w.functions.keys().cloned().collect();
            let mut scheduled = Vec::new();
            for name in names {
                let rate = w.estimators.get(&name).map(|e| e.rate_rps(now)).unwrap_or(0.0);
                let target = w.prewarm.target_warm(rate) as usize;
                let have = w.pool.warm_count(&name) + w.pool.restoring_count(&name);
                for _ in have..target {
                    let mem = w.tier_costs.instance_mem_bytes;
                    // Never prewarm past the pool's memory budget: an
                    // over-budget restore would only be LRU-reclaimed on
                    // arrival (restore → evict thrash, never converging).
                    if w.pool.mem_in_use + mem > w.pool.cfg.mem_budget_bytes {
                        break;
                    }
                    let pw_name = format!("{name}#pw");
                    let (handle, ready_at) = if w.snapshots.ready(&name, now) {
                        w.snapshots.note_restore(&name);
                        match w.backend {
                            Backend::Junctiond => {
                                let (id, _) = w.jd.spawn_parked(&pw_name, 1);
                                (PoolHandle::Junction(id), now + w.tier_costs.restore_ns)
                            }
                            Backend::Containerd => {
                                let (cid, lat) = w.containerd.restore_from_snapshot(
                                    &pw_name,
                                    now,
                                    w.tier_costs.restore_ns,
                                );
                                (PoolHandle::Container(cid), now + lat)
                            }
                        }
                    } else {
                        match w.backend {
                            Backend::Junctiond => {
                                let (id, boot) = w.jd.spawn_parked(&pw_name, 1);
                                (PoolHandle::Junction(id), now + boot)
                            }
                            Backend::Containerd => {
                                let (cid, lat) = w.containerd.create_and_start(&pw_name, now);
                                (PoolHandle::Container(cid), now + lat)
                            }
                        }
                    };
                    let slot = w.pool.begin_prewarm(&name, handle, ready_at, mem);
                    scheduled.push((slot, handle, ready_at));
                }
            }
            scheduled
        };
        for (slot, handle, ready_at) in scheduled {
            let this = self.clone();
            sim.at(ready_at, move |sim| {
                let (arm, reclaimed_slots) = {
                    let mut w = this.w.borrow_mut();
                    w.pool.promote_ready(sim.now());
                    // Containers park paused; Junction instances just sit
                    // idle. Skip the fixup if the slot was acquired (a
                    // deploy landed at this exact instant) or already
                    // evicted — the acquire/teardown paths own the
                    // container state then.
                    let warm = w.pool.slot(slot).state == crate::snapshot::SlotState::Warm;
                    if warm {
                        if let PoolHandle::Container(cid) = handle {
                            w.containerd.mark_running(cid);
                            if w.containerd.get(cid).unwrap().state == ContainerState::Running {
                                w.containerd.pause(cid);
                            }
                        }
                    }
                    let reclaimed = w.pool.reclaim_to_budget();
                    let slots: Vec<SlotId> = reclaimed.iter().map(|(s, _)| *s).collect();
                    let handles = reclaimed.into_iter().map(|(_, h)| h).collect();
                    w.teardown(handles);
                    (warm && !slots.contains(&slot), slots)
                };
                // The promoted slot starts its idle TTL now; reclaimed
                // slots lose their timers.
                if arm {
                    this.ttl_arm(sim, slot, sim.now());
                }
                this.ttl_cancel(sim, reclaimed_slots);
            });
        }
    }

    /// Drive pool maintenance for `horizon` of virtual time.
    ///
    /// Keep-alive switches to **per-slot idle-TTL timers**: every parked
    /// instance arms a cancellable timer that evicts it exactly at
    /// `parked_at + idle_ttl`, and the timer is cancelled in O(1) when
    /// the slot is acquired (or reclaimed by the memory budget) — no
    /// periodic sweep scanning the pool, no dead sweep closures burning
    /// host CPU while the pool idles. The prewarm hook still runs on a
    /// fixed tick cadence, but as a self-rescheduling
    /// [`crate::simcore::tick_train`] holding one pending event instead
    /// of `horizon/interval` closures scheduled up front.
    ///
    /// Like the seed's sweep train, maintenance is bounded by `horizon`:
    /// at its end the remaining TTL timers are cancelled and keep-alive
    /// deactivates (the seed's sweeps simply stopped ticking), so the run
    /// never evicts past the window the caller asked for.
    pub fn start_pool_maintenance(&self, sim: &mut Sim, interval: Time, horizon: Time) {
        let warm = {
            let mut w = self.w.borrow_mut();
            w.ttl_active = true;
            w.pool.warm_slots()
        };
        for (slot, parked_at) in warm {
            self.ttl_arm(sim, slot, parked_at);
        }
        let this = self.clone();
        crate::simcore::tick_train(sim, interval, horizon, move |sim| {
            this.prewarm_tick(sim);
        });
        let this = self.clone();
        sim.after(horizon, move |sim| this.ttl_deactivate(sim));
    }

    /// Maintenance horizon reached: stop arming TTL timers and cancel the
    /// ones still pending (their deadlines lie beyond the horizon or they
    /// would already have fired).
    fn ttl_deactivate(&self, sim: &mut Sim) {
        let handles: Vec<TimerHandle> = {
            let mut w = self.w.borrow_mut();
            w.ttl_active = false;
            std::mem::take(&mut w.ttl_timers).into_values().collect()
        };
        for h in handles {
            sim.cancel(h);
        }
    }

    /// Override the keep-alive policy (TTL / memory budget / per-fn cap).
    pub fn set_pool_config(&self, cfg: PoolConfig) {
        self.w.borrow_mut().pool.cfg = cfg;
    }

    pub fn pool_config(&self) -> PoolConfig {
        self.w.borrow().pool.cfg
    }

    pub fn pool_stats(&self) -> PoolStats {
        self.w.borrow().pool.stats
    }

    /// Instances currently parked warm for `function` on this worker
    /// (placement hint: route a scale-from-zero re-deploy to a worker
    /// that can serve it from its pool).
    pub fn pool_warm_count(&self, function: &str) -> usize {
        self.w.borrow().pool.warm_count(function)
    }

    /// (provisioned, served) counters per tier, indexed by
    /// [`ProvisionTier::idx`].
    pub fn tier_counts(&self) -> ([u64; 3], [u64; 3]) {
        let w = self.w.borrow();
        (w.tier_provisioned, w.tier_served)
    }

    /// Export the provisioning subsystem's counters and gauges into a
    /// metrics registry (call once per run).
    pub fn export_metrics(&self, reg: &mut crate::telemetry::MetricsRegistry) {
        let w = self.w.borrow();
        let b = w.backend.name();
        for tier in ProvisionTier::ALL {
            reg.counter_add(
                "provision_total",
                "instances provisioned, by tier",
                &[("backend", b), ("tier", tier.name())],
                w.tier_provisioned[tier.idx()],
            );
            reg.counter_add(
                "invocations_served_total",
                "invocations served, by the serving replica's provisioning tier",
                &[("backend", b), ("tier", tier.name())],
                w.tier_served[tier.idx()],
            );
        }
        reg.counter_add(
            "snapshot_captures_total",
            "per-function snapshots captured",
            &[("backend", b)],
            w.snapshots.captures,
        );
        reg.counter_add(
            "pool_ttl_evictions_total",
            "warm instances evicted by idle TTL",
            &[("backend", b)],
            w.pool.stats.ttl_evictions,
        );
        reg.counter_add(
            "pool_lru_evictions_total",
            "warm instances evicted by the memory budget",
            &[("backend", b)],
            w.pool.stats.lru_evictions,
        );
        reg.gauge_set(
            "pool_warm_instances",
            "instances currently parked warm",
            &[("backend", b)],
            w.pool.total_warm() as f64,
        );
        reg.gauge_set(
            "pool_resident_bytes",
            "resident memory held by the warm pool",
            &[("backend", b)],
            w.pool.mem_in_use as f64,
        );
    }

    /// Submit one invocation; `done` fires at the client with the timings.
    /// The request crosses the wire as a framed `rpc::Message` and enters
    /// the worker through its bounded NIC RX ring (tail-drop + retransmit
    /// on overflow); `done` fires with `timing.dropped == true` when the
    /// retransmit budget is exhausted.
    pub fn submit<F: FnOnce(&mut Sim, RequestTiming) + 'static>(
        &self,
        sim: &mut Sim,
        function: &str,
        done: F,
    ) {
        let mut timing = RequestTiming { submit: sim.now(), ..Default::default() };
        let this = self.clone();
        let name = function.to_string();
        let (wire, finalizer) = {
            let mut w = self.w.borrow_mut();
            let now = sim.now();
            w.estimators
                .entry(name.clone())
                .or_insert_with(|| ArrivalEstimator::new(ESTIMATOR_TAU))
                .observe(now);
            if let Some(f) = w.functions.get_mut(&name) {
                f.outstanding += 1;
            }
            timing.seq = w.tracer.begin(&name);
            let finalizer =
                if timing.seq != 0 && w.trace_finalize { Some(w.tracer.clone()) } else { None };
            (w.platform.wire_ns, finalizer)
        };
        // Single-node runs close the trace when `done` fires at the
        // client; a cluster's workers leave it open for the frontend.
        let done: DoneFn = match finalizer {
            Some(tracer) => Box::new(move |sim: &mut Sim, t: RequestTiming| {
                trace_finish(&tracer, &t);
                done(sim, t);
            }),
            None => Box::new(done),
        };
        // client → worker wire hop, then the worker NIC RX ring.
        sim.after(wire, move |sim| nic_ingress(this, sim, name, timing, 0, done));
    }

    /// Turn on span-per-invocation tracing, keeping the `k` slowest
    /// complete traces as tail exemplars. Returns the shared tracer
    /// handle (blame reports, exemplars, Chrome export). Tracing only
    /// reads the virtual clock — it never schedules events or draws
    /// randomness, so an enabled run replays the disabled run's timings
    /// exactly.
    pub fn enable_tracing(&self, k: usize) -> Tracer {
        let w = self.w.borrow();
        w.tracer.enable(k);
        w.tracer.clone()
    }

    /// The sim's tracer handle (disabled unless `enable_tracing` ran).
    pub fn tracer(&self) -> Tracer {
        self.w.borrow().tracer.clone()
    }

    /// Cluster wiring: share `tracer` across workers. With `finalize`
    /// false the worker-local `done` leaves traces open and the cluster
    /// frontend closes them after the return wire + frontend RX.
    pub(crate) fn set_tracer(&self, tracer: Tracer, finalize: bool) {
        let mut w = self.w.borrow_mut();
        w.tracer = tracer;
        w.trace_finalize = finalize;
    }

    pub fn completed(&self) -> u64 {
        self.w.borrow().completed
    }

    /// Requests abandoned after exhausting the NIC retransmit budget.
    pub fn dropped(&self) -> u64 {
        self.w.borrow().dropped
    }

    /// Worker NIC RX counters (ring occupancy, drops, batching).
    pub fn nic_stats(&self) -> NicStats {
        self.w.borrow().nic.stats
    }

    /// Worker NIC TX counters (ring occupancy, backpressure stalls, flush
    /// batching).
    pub fn tx_stats(&self) -> TxStats {
        self.w.borrow().tx.stats
    }

    pub fn cores(&self) -> ComputeFabric {
        self.w.borrow().cores.clone()
    }

    /// Compute-fabric counter snapshot (per-core busy time, preemptions,
    /// steals, migrations, job conservation).
    pub fn fabric_stats(&self) -> FabricStats {
        self.w.borrow().cores.stats()
    }

    pub fn provider_stats(&self) -> (u64, u64) {
        let w = self.w.borrow();
        (w.provider.hits, w.provider.misses)
    }

    pub fn scheduler_stats(&self) -> crate::junction::SchedulerStats {
        self.w.borrow().jd.scheduler.stats
    }

    /// junctiond's crash/restart counters (fault-plane conservation).
    pub fn manager_stats(&self) -> crate::junctiond::ManagerStats {
        self.w.borrow().jd.stats
    }

    /// Virtual time at which `function` becomes warm (latest replica).
    pub fn ready_at(&self, function: &str) -> Time {
        self.w.borrow().functions[function].replicas.iter().map(|r| r.ready_at).max().unwrap_or(0)
    }

    /// Host-kernel vs user-space interaction counters, summed over all
    /// components — the quantitative side of the paper's §3 isolation
    /// argument (how much trusted host-kernel surface each invocation
    /// exercises).
    pub fn cost_telemetry(&self) -> CostTelemetry {
        let w = self.w.borrow();
        CostTelemetry {
            host_syscalls: w.kc_gw.syscalls + w.kc_prov.syscalls + w.kc_fn.syscalls
                + w.kc_nic.syscalls,
            host_wakeups: w.kc_gw.wakeups + w.kc_prov.wakeups + w.kc_fn.wakeups
                + w.kc_nic.wakeups,
            kernel_msgs: w.kc_gw.msgs_recv
                + w.kc_gw.msgs_sent
                + w.kc_prov.msgs_recv
                + w.kc_prov.msgs_sent
                + w.kc_fn.msgs_recv
                + w.kc_fn.msgs_sent
                + w.kc_nic.msgs_recv
                + w.kc_nic.msgs_sent,
            user_syscalls: w.bc_gw.syscalls + w.bc_prov.syscalls + w.bc_fn.syscalls
                + w.bc_nic.syscalls,
            bypass_msgs: w.bc_gw.msgs_recv
                + w.bc_gw.msgs_sent
                + w.bc_prov.msgs_recv
                + w.bc_prov.msgs_sent
                + w.bc_fn.msgs_recv
                + w.bc_fn.msgs_sent
                + w.bc_nic.msgs_recv
                + w.bc_nic.msgs_sent,
        }
    }
}

/// Whole-sim invariant walk: audit every owned component, then the
/// cross-component ring-conservation laws only the world can see. Runs
/// from `debug_quiesce` hooks, `experiments::selfcheck`, and the
/// `tests/invariants.rs` conservation suite.
impl AuditTree for FaasSim {
    fn audit_tree(&self, out: &mut Vec<Violation>) {
        let w = self.w.borrow();
        w.jd.scheduler.audit_into(out);
        w.jd.audit_into(out);
        w.cores.audit_into(out);
        w.pool.audit_into(out);
        // Ring conservation: every frame a ring accepted was consumed or
        // is still queued. Refused frames (rx_dropped, tx_stalled,
        // tx_abandoned before enqueue) never increment the enqueue side.
        let m = "faas/pipeline";
        let rx = w.nic.stats;
        let rx_held = w.nic.len() as u64;
        check(out, m, "rx-ring-conservation", rx.rx_enqueued == rx.rx_delivered + rx_held, || {
            format!(
                "rx_enqueued {} != rx_delivered {} + ring depth {rx_held}",
                rx.rx_enqueued, rx.rx_delivered
            )
        });
        let tx = w.tx.stats;
        let tx_held = w.tx.len() as u64;
        check(out, m, "tx-ring-conservation", tx.tx_enqueued == tx.tx_packets + tx_held, || {
            format!(
                "tx_enqueued {} != tx_packets {} + ring depth {tx_held}",
                tx.tx_enqueued, tx.tx_packets
            )
        });
    }
}

/// Multi-instance deploy shapes (scale > 1) keep the seed's cold path.
fn provision_multi(w: &mut World, now: Time, spec: &FunctionSpec) -> Vec<Replica> {
    let replicas = match w.backend {
        Backend::Containerd => {
            let conc = w.platform.container_concurrency as u32;
            let (cid, cold) = w.containerd.create_and_start(&spec.name, now);
            vec![Replica {
                handle: ReplicaHandle::Container(cid),
                gate: Gate::new(conc),
                ready_at: now + cold,
                tier: ProvisionTier::ColdBoot,
                jd_name: spec.name.clone(),
            }]
        }
        Backend::Junctiond => {
            let (ids, cold) = w.jd.deploy_function(spec);
            ids.iter()
                .map(|id| {
                    let conc = w.jd.concurrency_of(*id, spec);
                    Replica {
                        handle: ReplicaHandle::Junction(*id),
                        gate: Gate::new(conc),
                        ready_at: now + cold,
                        tier: ProvisionTier::ColdBoot,
                        jd_name: spec.name.clone(),
                    }
                })
                .collect()
        }
    };
    let ready = replicas.iter().map(|r| r.ready_at).max().unwrap();
    w.snapshots.capture(&spec.name, ready, w.tier_costs.capture_ns, w.tier_costs.instance_mem_bytes);
    w.tier_provisioned[ProvisionTier::ColdBoot.idx()] += replicas.len() as u64;
    replicas
}

/// Aggregated host-kernel vs user-space interaction counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct CostTelemetry {
    /// Syscalls that trapped into the host kernel.
    pub host_syscalls: u64,
    /// Host-kernel scheduler wakeups.
    pub host_wakeups: u64,
    /// Messages that traversed the host kernel network stack.
    pub kernel_msgs: u64,
    /// Syscalls handled inside Junction instances (user space).
    pub user_syscalls: u64,
    /// Messages that went through per-instance bypass queues.
    pub bypass_msgs: u64,
}

type DoneFn = Box<dyn FnOnce(&mut Sim, RequestTiming)>;

/// Close a finished request's trace: fold its `RequestTiming` boundaries
/// into the tracer's hop view. No-op for untraced requests (`seq == 0`).
pub(crate) fn trace_finish(tracer: &Tracer, t: &RequestTiming) {
    if t.seq == 0 {
        return;
    }
    let ht = HopTimes {
        submit: t.submit,
        nic_in: t.nic_in,
        gateway_in: t.gateway_in,
        exec_start: t.exec_start,
        exec_end: t.exec_end,
        tx_in: t.tx_in,
        done: t.done,
    };
    tracer.finish(t.seq, ht, t.dropped);
}

/// Record a closed sub-span on a traced request. The borrow is taken only
/// when the request carries a live trace, so the untraced hot path pays a
/// single integer compare.
fn trace_event(
    fs: &FaasSim,
    seq: u64,
    hop: Hop,
    name: &'static str,
    cause: &'static str,
    start: Time,
    end: Time,
) {
    if seq == 0 {
        return;
    }
    fs.w.borrow().tracer.event(seq, hop, name, cause, start, end);
}

/// Run one CPU segment on the fabric. Affinity is resolved here, at
/// dispatch time (the grant may have grown, shrunk, or been preempted
/// during the preceding wakeup latency): a junction instance's segment
/// takes its granted core's local queue (soft affinity — grant
/// exclusivity and quantum-edge waits are structural); everything else
/// takes the shared queue.
fn run_segment<F: FnOnce(&mut Sim) + 'static>(
    fs: &FaasSim,
    sim: &mut Sim,
    inst: Option<InstanceId>,
    cpu: Time,
    done: F,
) {
    run_segment_traced(fs, sim, inst, cpu, 0, Hop::Exec, done)
}

/// [`run_segment`] with per-slice tracing: each fabric slice the segment
/// runs (including preemptions and quantum-edge requeues) lands as a
/// `fabric.slice` sub-span under `hop`. The observer only records — it
/// cannot perturb the fabric's scheduling decisions.
fn run_segment_traced<F: FnOnce(&mut Sim) + 'static>(
    fs: &FaasSim,
    sim: &mut Sim,
    inst: Option<InstanceId>,
    cpu: Time,
    seq: u64,
    hop: Hop,
    done: F,
) {
    let (cores, core, obs) = {
        let mut w = fs.w.borrow_mut();
        let core = w.segment_core(inst);
        let obs: Option<SliceObs> = if seq != 0 && w.tracer.is_enabled() {
            let tracer = w.tracer.clone();
            Some(Rc::new(move |r: SliceRecord| {
                tracer.event(seq, hop, "fabric.slice", r.outcome.as_str(), r.start, r.end);
            }))
        } else {
            None
        };
        (w.cores.clone(), core, obs)
    };
    cores.run_observed(sim, core, JobClass::Normal, cpu, obs, done)
}

/// Charge one burst of kernel NIC softirq CPU to its IRQ-affinity core
/// (high-priority work stealing cycles from whatever tenant runs there),
/// or to the shared pool when the affinity mask is empty.
fn run_softirq(cores: &ComputeFabric, sim: &mut Sim, core: Option<usize>, cpu: Time) {
    if cpu == 0 {
        return;
    }
    match core {
        Some(c) => cores.run_on(sim, c, JobClass::Irq, cpu, |_| {}),
        None => cores.run(sim, cpu, |_| {}),
    }
}

/// NIC ingress: frame the invocation as an `rpc::Message` and offer it to
/// the worker's bounded RX ring. A full ring tail-drops the frame; the
/// client retransmits after a backoff up to `nic_max_retries` times, then
/// gives the request up (`done` fires with `timing.dropped`).
///
/// The retransmission is a **real cancellable timer**, modeling what the
/// client actually does on the wire: arm a retransmit timer with every
/// send, cancel it when the send is acknowledged. In-model the NIC's
/// accept/drop outcome is synchronous, so the accept-path cancel lands in
/// the same instant and both paths produce exactly the seed's virtual
/// times (the seed scheduled the retry closure only on the drop). The
/// arm+cancel costs one slab insert + O(1) cancel per frame — the price
/// of exercising engine cancellation on the simulator's hottest path,
/// counted in `NicStats::retrans_cancelled`.
fn nic_ingress(
    fs: FaasSim,
    sim: &mut Sim,
    name: String,
    mut t: RequestTiming,
    attempt: u32,
    done: DoneFn,
) {
    if attempt == 0 {
        t.nic_in = sim.now();
    }
    t.retries = attempt;
    // `done` must flow to whichever continuation wins: the delivery
    // closure (frame accepted) or the retransmit timer (frame dropped).
    // Cancellation guarantees exactly one of them ever runs.
    let done_slot: Rc<RefCell<Option<DoneFn>>> = Rc::new(RefCell::new(Some(done)));
    let backoff = fs.w.borrow_mut().rx_retry_backoff();
    let retrans = {
        let fs2 = fs.clone();
        let name2 = name.clone();
        let slot = done_slot.clone();
        sim.after_handle(backoff, move |sim| {
            let done = slot.borrow_mut().take().expect("retransmit raced the delivery path");
            nic_ingress(fs2, sim, name2, t, attempt + 1, done);
        })
    };
    enum Decision {
        Accept { kick: bool },
        Retry,
        GiveUp,
    }
    let decision = {
        let mut w = fs.w.borrow_mut();
        if !w.nic.is_full() {
            let bytes = Message::request_frame_size(&name, w.payload_bytes);
            let fs2 = fs.clone();
            let name2 = name.clone();
            let slot = done_slot.clone();
            // Ring-wait trace span: enqueue instant → drain delivery, tagged
            // with how the backend moves frames off the ring.
            let ring_trace = (t.seq != 0).then(|| {
                let cause = match w.backend {
                    Backend::Containerd => "irq_softirq",
                    Backend::Junctiond => "poll_burst",
                };
                (w.tracer.clone(), sim.now(), cause)
            });
            let kick = w.nic.enqueue(Packet {
                bytes,
                enqueued_at: sim.now(),
                deliver: Box::new(move |sim| {
                    if let Some((tracer, enq, cause)) = ring_trace {
                        tracer.event(t.seq, Hop::NicRx, "rx.ring", cause, enq, sim.now());
                    }
                    let done =
                        slot.borrow_mut().take().expect("delivery raced the retransmit timer");
                    stage_gateway(fs2, sim, name2, t, done);
                }),
            });
            Decision::Accept { kick }
        } else {
            w.nic.note_drop();
            if (attempt as u64) < w.platform.nic_max_retries {
                w.nic.stats.retries += 1;
                Decision::Retry
            } else {
                w.dropped += 1;
                if let Some(f) = w.functions.get_mut(&name) {
                    f.outstanding = f.outstanding.saturating_sub(1);
                }
                Decision::GiveUp
            }
        }
    };
    match decision {
        Decision::Accept { kick } => {
            // Frame accepted: cancel the retransmit timer (O(1); the seed
            // engine would have carried it to the top of the heap as a
            // tombstone).
            let live = sim.cancel(retrans);
            debug_assert!(live, "retransmit timer must be live at accept");
            fs.w.borrow_mut().nic.stats.retrans_cancelled += 1;
            if kick {
                // Defer the first poll one event so a burst of
                // same-instant arrivals coalesces into one drain batch.
                // tie-break: the drain pops whatever is ringed, so tie
                // order only moves batch boundaries.
                let fs2 = fs.clone();
                sim.after(0, move |sim| nic_drain(fs2, sim));
            }
        }
        Decision::Retry => {
            // Tail drop: the armed timer fires the retransmission at
            // `now + backoff`.
            let now = sim.now();
            trace_event(&fs, t.seq, Hop::NicRx, "rx.backoff", "rx_tail_drop", now, now + backoff);
        }
        Decision::GiveUp => {
            sim.cancel(retrans);
            t.dropped = true;
            t.done = sim.now();
            let done = done_slot.borrow_mut().take().expect("done already consumed");
            done(sim, t);
        }
    }
}

/// NIC drain engine: run one burst off the worker's RX ring.
///
/// * **containerd** — one packet at a time: hard IRQ + softirq + kernel
///   stack + a per-byte copy; the same work also occupies a shared worker
///   core (softirq steals CPU from the functions).
/// * **junctiond** — the scheduler's dedicated polling core drains up to
///   `nic_batch_max` packets per iteration; the iteration cost
///   (`Scheduler::note_nic_poll`, proportional to granted cores) is
///   charged once per burst and amortizes across it; per-packet work is
///   the zero-copy user-space stack.
fn nic_drain(fs: FaasSim, sim: &mut Sim) {
    let (deliveries, burst_ns, softirq_cpu_ns, softirq_core, cores) = {
        let mut w = fs.w.borrow_mut();
        let burst_max = match w.backend {
            Backend::Containerd => 1,
            Backend::Junctiond => w.platform.nic_batch_max as usize,
        };
        let pkts = w.nic.pop_burst(burst_max, sim.now());
        let copy_per_kb = w.platform.nic_copy_ns_per_kb;
        let mut deliveries: Vec<(Time, Box<dyn FnOnce(&mut Sim)>)> =
            Vec::with_capacity(pkts.len());
        let mut offset: Time = 0;
        let mut cpu: Time = 0;
        match w.backend {
            Backend::Containerd => {
                for p in pkts {
                    let copy = p.bytes as Time * copy_per_kb / 1024;
                    let cost = w.kc_nic.nic_rx_packet(copy);
                    offset += cost;
                    cpu += cost;
                    deliveries.push((offset, p.deliver));
                }
            }
            Backend::Junctiond => {
                if !pkts.is_empty() {
                    offset += w.jd.scheduler.note_nic_poll(pkts.len() as u32);
                }
                for p in pkts {
                    offset += w.bc_nic.rx_poll_packet();
                    deliveries.push((offset, p.deliver));
                }
            }
        }
        let sc = if cpu > 0 { w.next_softirq_core() } else { None };
        (deliveries, offset, cpu, sc, w.cores.clone())
    };
    // Kernel path only: the softirq RX work burns CPU on a *specific*
    // core (the IRQ affinity mask) as high-priority work — stealing
    // cycles from whatever tenant runs there at the next quantum edge.
    run_softirq(&cores, sim, softirq_core, softirq_cpu_ns);
    for (off, deliver) in deliveries {
        sim.after(off, deliver);
    }
    let fs2 = fs.clone();
    sim.after(burst_ns, move |sim| {
        let more = fs2.w.borrow_mut().nic.burst_done();
        if more {
            nic_drain(fs2, sim);
        }
    });
}

/// Gateway pass: auth + route + forward to the provider.
fn stage_gateway(fs: FaasSim, sim: &mut Sim, name: String, mut t: RequestTiming, done: DoneFn) {
    t.gateway_in = sim.now();
    let (lat, cpu, gw_inst, wake) = {
        let mut w = fs.w.borrow_mut();
        let gw_inst = w.gw_inst;
        let (lat, wake) = w.service_wakeup(gw_inst);
        let p = w.platform.clone();
        let n_replicas = w.functions.get(&name).map(|f| f.meta.replicas).unwrap_or(0);
        w.gateway.authenticate("token");
        let routed = w.gateway.route(&name, n_replicas);
        assert!(routed.is_some(), "function '{name}' not deployed");
        let cpu = match w.backend {
            Backend::Containerd => {
                // The NIC-level RX (IRQ + softirq + stack + copy) was
                // already charged per packet by the drain engine; the
                // gateway process pays the app-side receive here.
                w.kc_gw.app_recv()
                    + p.gateway_cpu_ns
                    + p.rpc_serde_ns
                    + w.kc_gw.send_msg()
                    + w.kc_gw.segment_interference()
            }
            Backend::Junctiond => {
                // RX was consumed by the polling core (netpath burst); the
                // gateway instance starts at the app logic.
                p.gateway_cpu_ns + p.rpc_serde_ns + w.bc_gw.send_msg()
            }
        };
        let lat = lat + w.bc_gw.sched_tail_delay();
        (lat, cpu, gw_inst, wake)
    };
    if lat > 0 && wake != "none" {
        trace_event(&fs, t.seq, Hop::PreExec, "sched.wakeup", wake, sim.now(), sim.now() + lat);
    }
    sim.after(lat, move |sim| {
        let fs2 = fs.clone();
        run_segment_traced(&fs, sim, gw_inst, cpu, t.seq, Hop::PreExec, move |sim| {
            fs2.w.borrow_mut().service_done(gw_inst);
            stage_provider(fs2, sim, name, t, done);
        });
    });
}

/// Provider pass: resolve (cache or backend state query) + forward.
fn stage_provider(fs: FaasSim, sim: &mut Sim, name: String, t: RequestTiming, done: DoneFn) {
    let (lat, query_lat, cpu, prov_inst, wake) = {
        let mut w = fs.w.borrow_mut();
        let prov_inst = w.prov_inst;
        let (lat, wake) = w.service_wakeup(prov_inst);
        let p = w.platform.clone();
        // §4 metadata cache: a miss pays the backend state query.
        let query_lat = match w.provider.resolve(&name) {
            CacheOutcome::Hit(_) => 0,
            CacheOutcome::Miss => {
                let meta = w.functions[&name].meta;
                w.provider.fill(&name, meta);
                match w.backend {
                    Backend::Containerd => w.containerd.state_query(),
                    Backend::Junctiond => p.junctiond_state_query_ns,
                }
            }
        };
        let cpu = match w.backend {
            Backend::Containerd => {
                // Send crosses the veth into the container's netns.
                w.kc_prov.recv_msg()
                    + p.provider_cpu_ns
                    + p.rpc_serde_ns
                    + w.kc_prov.send_msg()
                    + w.kc_prov.veth_hop()
                    + w.kc_prov.segment_interference()
            }
            Backend::Junctiond => {
                w.bc_prov.recv_msg() + p.provider_cpu_ns + p.rpc_serde_ns + w.bc_prov.send_msg()
            }
        };
        let lat = lat + w.bc_prov.sched_tail_delay();
        (lat, query_lat, cpu, prov_inst, wake)
    };
    if lat > 0 && wake != "none" {
        trace_event(&fs, t.seq, Hop::PreExec, "sched.wakeup", wake, sim.now(), sim.now() + lat);
    }
    sim.after(lat + query_lat, move |sim| {
        let fs2 = fs.clone();
        run_segment_traced(&fs, sim, prov_inst, cpu, t.seq, Hop::PreExec, move |sim| {
            fs2.w.borrow_mut().service_done(prov_inst);
            stage_function(fs2, sim, name, t, done);
        });
    });
}

/// Function pass: concurrency gate, then the exec segment.
fn stage_function(fs: FaasSim, sim: &mut Sim, name: String, mut t: RequestTiming, done: DoneFn) {
    // Pick the replica (round-robin mirrors the gateway's choice; per-
    // replica gates model per-instance concurrency). Each replica has its
    // own readiness time — warm acquires serve in microseconds while a
    // cold-booting sibling is still coming up.
    let (gate, handle_idx, ready_at, tier) = {
        let w = fs.w.borrow();
        let f = &w.functions[&name];
        let idx = (w.gateway.requests as usize) % f.replicas.len();
        let r = &f.replicas[idx];
        (r.gate.clone(), idx, r.ready_at, r.tier)
    };
    t.tier = tier;
    // Cold start: requests arriving early wait for instance readiness.
    let wait = ready_at.saturating_sub(sim.now());
    if wait > 0 {
        let now = sim.now();
        trace_event(&fs, t.seq, Hop::PreExec, "replica.ready", "provision", now, now + wait);
    }
    let gate2 = gate.clone();
    sim.after(wait, move |sim| {
        let gate_enter = sim.now();
        let fs2 = fs.clone();
        gate2.acquire(sim, move |sim| {
            // Concurrency-gate queueing: admitted later than offered.
            let now = sim.now();
            if now > gate_enter {
                trace_event(&fs2, t.seq, Hop::PreExec, "gate.wait", "concurrency", gate_enter, now);
            }
            exec_segment(fs2, sim, name, handle_idx, gate, t, done);
        });
    });
}

/// The exec segment inside the instance (the Fig. 5 "function execution
/// latency" window).
fn exec_segment(
    fs: FaasSim,
    sim: &mut Sim,
    name: String,
    replica: usize,
    gate: Gate,
    mut t: RequestTiming,
    done: DoneFn,
) {
    t.exec_start = sim.now();
    let (lat, cpu, inst, wake) = {
        let mut w = fs.w.borrow_mut();
        let p = w.platform.clone();
        let nsys = p.function_syscalls as u32;
        // A crash-redeploy may have replaced the replica set while this
        // request waited in the gate; route to a surviving replica then.
        // Without a crash the clamp is a no-op (the picked index is
        // always in range).
        let replica = replica.min(w.functions[&name].replicas.len() - 1);
        // Per-function body override (antagonist tenants in E14 carry
        // chunkier bodies); default is the sim-wide calibrated cost.
        let compute = w.functions[&name].spec.compute_ns.unwrap_or(w.compute_ns);
        // Gray failure: a degraded worker's bodies run slower by the
        // fault plane's multiplier (100 = healthy, the untouched default).
        let compute =
            if w.degrade_x100 == 100 { compute } else { compute * w.degrade_x100 / 100 };
        w.tier_served[t.tier.idx()] += 1;
        match w.backend {
            Backend::Containerd => {
                let cid = match w.functions[&name].replicas[replica].handle {
                    ReplicaHandle::Container(c) => c,
                    _ => unreachable!(),
                };
                w.containerd.get_mut(cid).unwrap().invocations += 1;
                let cpu = w.kc_fn.recv_msg()
                    + w.kc_fn.veth_hop()
                    + w.kc_fn.syscalls(nsys)
                    + compute
                    + w.kc_fn.sched_noise()
                    + w.kc_fn.segment_interference()
                    + w.kc_fn.send_msg()
                    + w.kc_fn.veth_hop();
                (0, cpu, None, "none")
            }
            Backend::Junctiond => {
                let id = match w.functions[&name].replicas[replica].handle {
                    ReplicaHandle::Junction(i) => i,
                    _ => unreachable!(),
                };
                let out = w.jd.scheduler.packet_arrival(id);
                let cpu = w.bc_fn.recv_msg()
                    + w.bc_fn.syscalls(nsys)
                    + compute
                    + w.bc_fn.send_msg();
                (out.latency(), cpu, Some(id), out.kind())
            }
        }
    };
    if lat > 0 && wake != "none" {
        trace_event(&fs, t.seq, Hop::Exec, "sched.wakeup", wake, sim.now(), sim.now() + lat);
    }
    sim.after(lat, move |sim| {
        let fs2 = fs.clone();
        run_segment_traced(&fs, sim, inst, cpu, t.seq, Hop::Exec, move |sim| {
            t.exec_end = sim.now();
            {
                let mut w = fs2.w.borrow_mut();
                if let Some(id) = inst {
                    // Crash-retired mid-exec: the scheduler already zeroed
                    // this instance's in-flight; skip the double release.
                    if w.jd.scheduler.instance(id).map_or(false, |i| i.in_flight > 0) {
                        w.jd.scheduler.request_done(id);
                    }
                }
            }
            gate.release(sim);
            stage_response(fs2, sim, name, t, done);
        });
    });
}

/// Response path: provider proxy pass, gateway proxy pass, then the
/// worker's bounded TX ring ([`tx_ingress`]/[`tx_drain`]) and the wire
/// back to the client.
fn stage_response(fs: FaasSim, sim: &mut Sim, name: String, t: RequestTiming, done: DoneFn) {
    let (lat_p, cpu_p, prov_inst, wake_p) = {
        let mut w = fs.w.borrow_mut();
        let prov_inst = w.prov_inst;
        let (lat, wake) = w.service_wakeup(prov_inst);
        let p = w.platform.clone();
        let cpu = match w.backend {
            Backend::Containerd => {
                w.kc_prov.recv_msg()
                    + w.kc_prov.veth_hop()
                    + p.rpc_serde_ns
                    + w.kc_prov.send_msg()
                    + w.kc_prov.segment_interference()
            }
            Backend::Junctiond => w.bc_prov.recv_msg() + p.rpc_serde_ns + w.bc_prov.send_msg(),
        };
        let lat = lat + w.bc_prov.sched_tail_delay();
        (lat, cpu, prov_inst, wake)
    };
    if lat_p > 0 && wake_p != "none" {
        trace_event(&fs, t.seq, Hop::Resp, "sched.wakeup", wake_p, sim.now(), sim.now() + lat_p);
    }
    sim.after(lat_p, move |sim| {
        let fs2 = fs.clone();
        run_segment_traced(&fs, sim, prov_inst, cpu_p, t.seq, Hop::Resp, move |sim| {
            let (lat_g, cpu_g, gw_inst, wake_g) = {
                let mut w = fs2.w.borrow_mut();
                w.service_done(prov_inst);
                let gw_inst = w.gw_inst;
                let (lat, wake) = w.service_wakeup(gw_inst);
                let p = w.platform.clone();
                let cpu = match w.backend {
                    Backend::Containerd => {
                        // App-side send half only: the NIC-level TX work
                        // (qdisc + copy + ACK softirq) is charged per
                        // frame by the TX flush engine (tx_drain).
                        w.kc_gw.recv_msg()
                            + p.rpc_serde_ns
                            + w.kc_gw.app_send()
                            + w.kc_gw.segment_interference()
                    }
                    Backend::Junctiond => {
                        // The TX doorbell is rung by the polling core's
                        // flush (tx_poll_packet); the gateway instance
                        // pays receive + serde only.
                        w.bc_gw.recv_msg() + p.rpc_serde_ns
                    }
                };
                let lat = lat + w.bc_gw.sched_tail_delay();
                (lat, cpu, gw_inst, wake)
            };
            if lat_g > 0 && wake_g != "none" {
                let now = sim.now();
                trace_event(&fs2, t.seq, Hop::Resp, "sched.wakeup", wake_g, now, now + lat_g);
            }
            let fs3 = fs2.clone();
            sim.after(lat_g, move |sim| {
                let fs4 = fs3.clone();
                run_segment_traced(&fs3, sim, gw_inst, cpu_g, t.seq, Hop::Resp, move |sim| {
                    fs4.w.borrow_mut().service_done(gw_inst);
                    tx_ingress(fs4, sim, name, t, 0, done);
                });
            });
        });
    });
}

/// TX ingress: offer the framed response to the worker's bounded TX ring.
/// A full ring exerts *backpressure*: the worker still holds the only
/// copy of the frame, so nothing is lost — the responder stalls, re-offers
/// the frame after `nic_tx_retry_backoff_ns`, and only abandons the
/// response after `nic_tx_max_retries` stalls (the request then resolves
/// with `timing.dropped`, the wasted execution being exactly the incast
/// pathology the bounded ring models). Unlike the RX side there is no
/// retransmit race to cancel.
fn tx_ingress(
    fs: FaasSim,
    sim: &mut Sim,
    name: String,
    mut t: RequestTiming,
    attempt: u32,
    done: DoneFn,
) {
    if attempt == 0 {
        t.tx_in = sim.now();
    }
    t.tx_retries = attempt;
    enum Decision {
        Accept { kick: bool },
        Hold,
        Abandon,
    }
    let mut done_opt = Some(done);
    let decision = {
        let mut w = fs.w.borrow_mut();
        if !w.tx.is_full() {
            let bytes = Message::response_frame_size(w.payload_bytes);
            let fs2 = fs.clone();
            let name2 = name.clone();
            let done = done_opt.take().expect("done consumed before accept");
            let wire = w.platform.wire_ns;
            // Ring-wait trace span: enqueue instant → flush, tagged with
            // how the backend moves frames off the TX ring.
            let ring_trace = (t.seq != 0).then(|| {
                let cause = match w.backend {
                    Backend::Containerd => "qdisc",
                    Backend::Junctiond => "poll_burst",
                };
                (w.tracer.clone(), sim.now(), cause)
            });
            let kick = w.tx.enqueue(Packet {
                bytes,
                enqueued_at: sim.now(),
                deliver: Box::new(move |sim| {
                    if let Some((tracer, enq, cause)) = ring_trace {
                        tracer.event(t.seq, Hop::Tx, "tx.ring", cause, enq, sim.now());
                    }
                    // The frame left the worker NIC: the invocation is
                    // served; only the wire hop remains.
                    {
                        let mut w = fs2.w.borrow_mut();
                        w.completed += 1;
                        if let Some(f) = w.functions.get_mut(&name2) {
                            f.outstanding = f.outstanding.saturating_sub(1);
                        }
                    }
                    sim.after(wire, move |sim| {
                        let mut t = t;
                        t.done = sim.now();
                        done(sim, t);
                    });
                }),
            });
            Decision::Accept { kick }
        } else {
            w.tx.note_stall();
            if (attempt as u64) < w.platform.nic_tx_max_retries {
                w.tx.stats.tx_retries += 1;
                Decision::Hold
            } else {
                w.tx.stats.tx_abandoned += 1;
                w.dropped += 1;
                if let Some(f) = w.functions.get_mut(&name) {
                    f.outstanding = f.outstanding.saturating_sub(1);
                }
                Decision::Abandon
            }
        }
    };
    match decision {
        Decision::Accept { kick } => {
            if kick {
                // Defer the first flush one event so a burst of
                // same-instant completions coalesces into one TX batch.
                // tie-break: tie order only moves batch boundaries.
                let fs2 = fs.clone();
                sim.after(0, move |sim| tx_drain(fs2, sim));
            }
        }
        Decision::Hold => {
            let backoff = fs.w.borrow_mut().tx_retry_backoff();
            let now = sim.now();
            trace_event(&fs, t.seq, Hop::Tx, "tx.backoff", "tx_backpressure", now, now + backoff);
            let done = done_opt.take().expect("done consumed before hold");
            let fs2 = fs.clone();
            sim.after(backoff, move |sim| tx_ingress(fs2, sim, name, t, attempt + 1, done));
        }
        Decision::Abandon => {
            let done = done_opt.take().expect("done consumed before abandon");
            t.dropped = true;
            t.done = sim.now();
            done(sim, t);
        }
    }
}

/// TX flush engine: run one burst off the worker's TX ring.
///
/// * **containerd** — one frame at a time: qdisc + driver TX path, the
///   socket-buffer → DMA copy sized by the frame, and the ACK softirq —
///   the same work also burning a shared worker core (TX softirq steals
///   CPU from the functions, like the RX side).
/// * **junctiond** — the scheduler's dedicated polling core flushes up to
///   `nic_tx_batch_max` frames per iteration; the iteration cost
///   (`Scheduler::note_nic_tx_poll`) is charged once per burst and
///   amortizes across it; per-frame work is the zero-copy user-space
///   stack + doorbell.
fn tx_drain(fs: FaasSim, sim: &mut Sim) {
    let (deliveries, burst_ns, softirq_cpu_ns, softirq_core, cores) = {
        let mut w = fs.w.borrow_mut();
        let burst_max = match w.backend {
            Backend::Containerd => 1,
            Backend::Junctiond => w.platform.nic_tx_batch_max as usize,
        };
        let pkts = w.tx.pop_burst(burst_max, sim.now());
        let copy_per_kb = w.platform.nic_copy_ns_per_kb;
        let mut deliveries: Vec<(Time, Box<dyn FnOnce(&mut Sim)>)> =
            Vec::with_capacity(pkts.len());
        let mut offset: Time = 0;
        let mut cpu: Time = 0;
        match w.backend {
            Backend::Containerd => {
                for p in pkts {
                    let copy = p.bytes as Time * copy_per_kb / 1024;
                    let cost = w.kc_nic.nic_tx_packet(copy);
                    offset += cost;
                    cpu += cost;
                    deliveries.push((offset, p.deliver));
                }
            }
            Backend::Junctiond => {
                if !pkts.is_empty() {
                    offset += w.jd.scheduler.note_nic_tx_poll(pkts.len() as u32);
                }
                for p in pkts {
                    offset += w.bc_nic.tx_poll_packet();
                    deliveries.push((offset, p.deliver));
                }
            }
        }
        let sc = if cpu > 0 { w.next_softirq_core() } else { None };
        (deliveries, offset, cpu, sc, w.cores.clone())
    };
    // Kernel path only: the TX/ACK softirq work burns a specific IRQ-
    // affinity core, like the RX side.
    run_softirq(&cores, sim, softirq_core, softirq_cpu_ns);
    for (off, deliver) in deliveries {
        sim.after(off, deliver);
    }
    let fs2 = fs.clone();
    sim.after(burst_ns, move |sim| {
        let more = fs2.w.borrow_mut().tx.burst_done();
        if more {
            tx_drain(fs2, sim);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faas::RuntimeKind;
    use crate::simcore::{MICROS, MILLIS, SECONDS};

    fn cfg(backend: Backend) -> ExperimentConfig {
        ExperimentConfig { backend, ..Default::default() }
    }

    fn run_n(backend: Backend, n: usize) -> Vec<RequestTiming> {
        let mut sim = Sim::new();
        let platform = Rc::new(PlatformConfig::default());
        let fs = FaasSim::new(&cfg(backend), platform);
        fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        // Warm up past the cold start.
        sim.run_until(2 * crate::simcore::SECONDS);
        let out = Rc::new(RefCell::new(Vec::new()));
        for _ in 0..n {
            let out2 = out.clone();
            fs.submit(&mut sim, "aes", move |_, t| out2.borrow_mut().push(t));
        }
        sim.run_to_completion();
        Rc::try_unwrap(out).ok().unwrap().into_inner()
    }

    #[test]
    fn containerd_invocation_completes_with_ordered_timestamps() {
        let ts = run_n(Backend::Containerd, 5);
        assert_eq!(ts.len(), 5);
        for t in ts {
            assert!(t.submit < t.gateway_in);
            assert!(t.gateway_in < t.exec_start);
            assert!(t.exec_start < t.exec_end);
            assert!(t.exec_end < t.done);
        }
    }

    #[test]
    fn junctiond_invocation_completes_with_ordered_timestamps() {
        let ts = run_n(Backend::Junctiond, 5);
        assert_eq!(ts.len(), 5);
        for t in ts {
            assert!(t.exec_start < t.exec_end);
            assert!(t.e2e() > 0);
        }
    }

    #[test]
    fn junction_is_faster_end_to_end() {
        let c: Vec<_> = run_n(Backend::Containerd, 50).iter().map(|t| t.e2e()).collect();
        let j: Vec<_> = run_n(Backend::Junctiond, 50).iter().map(|t| t.e2e()).collect();
        let cm = c.iter().sum::<u64>() / c.len() as u64;
        let jm = j.iter().sum::<u64>() / j.len() as u64;
        assert!(jm < cm, "junction mean {jm} vs containerd {cm}");
    }

    #[test]
    fn exec_window_contains_compute() {
        let cfg_default = ExperimentConfig::default();
        for backend in [Backend::Containerd, Backend::Junctiond] {
            let ts = run_n(backend, 10);
            for t in ts {
                assert!(
                    t.exec() >= cfg_default.function_compute_ns,
                    "{backend:?} exec {} < compute",
                    t.exec()
                );
            }
        }
    }

    #[test]
    fn first_request_pays_cold_start() {
        let mut sim = Sim::new();
        let platform = Rc::new(PlatformConfig::default());
        let fs = FaasSim::new(&cfg(Backend::Containerd), platform.clone());
        fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        let out = Rc::new(RefCell::new(Vec::new()));
        let out2 = out.clone();
        // Submit immediately — before the container is Running.
        fs.submit(&mut sim, "aes", move |_, t| out2.borrow_mut().push(t));
        sim.run_to_completion();
        let t = out.borrow()[0];
        assert!(
            t.e2e() > 100 * MILLIS,
            "cold-start e2e {}µs suspiciously warm",
            t.e2e() / MICROS
        );
        assert_eq!(t.tier, ProvisionTier::ColdBoot);
    }

    #[test]
    fn provider_cache_hits_after_first_request() {
        let mut sim = Sim::new();
        let platform = Rc::new(PlatformConfig::default());
        let fs = FaasSim::new(&cfg(Backend::Junctiond), platform);
        fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        sim.run_until(crate::simcore::SECONDS);
        for _ in 0..10 {
            fs.submit(&mut sim, "aes", |_, _| {});
        }
        sim.run_to_completion();
        let (hits, misses) = fs.provider_stats();
        assert_eq!(misses, 1);
        assert_eq!(hits, 9);
    }

    #[test]
    fn deterministic_across_runs() {
        let a: Vec<_> = run_n(Backend::Containerd, 20).iter().map(|t| t.e2e()).collect();
        let b: Vec<_> = run_n(Backend::Containerd, 20).iter().map(|t| t.e2e()).collect();
        assert_eq!(a, b);
    }

    // ---- tiered provisioning -------------------------------------------

    /// Deploy, serve, undeploy, and redeploy on one backend; returns the
    /// (cold, warm, restore) provisioning latencies the ladder reported.
    fn ladder(backend: Backend) -> (Time, Time, Time) {
        let mut sim = Sim::new();
        let fs = FaasSim::new(&cfg(backend), Rc::new(PlatformConfig::default()));
        let spec = FunctionSpec::new("aes", "aes600", RuntimeKind::Go);
        let (cold, tier) = fs.deploy_tiered(&mut sim, spec.clone(), true);
        assert_eq!(tier, ProvisionTier::ColdBoot);
        // Run past boot + snapshot capture.
        sim.run_until(SECONDS);
        assert!(fs.undeploy(&mut sim, "aes"), "idle function must undeploy");
        let (warm, tier) = fs.deploy_tiered(&mut sim, spec.clone(), true);
        assert_eq!(tier, ProvisionTier::WarmPool);
        sim.run_until(2 * SECONDS);
        assert!(fs.undeploy(&mut sim, "aes"));
        fs.flush_warm_pool(&mut sim);
        let (restore, tier) = fs.deploy_tiered(&mut sim, spec, true);
        assert_eq!(tier, ProvisionTier::SnapshotRestore);
        sim.run_to_completion();
        (cold, warm, restore)
    }

    #[test]
    fn tier_ladder_orders_costs_per_backend() {
        for backend in [Backend::Containerd, Backend::Junctiond] {
            let (cold, warm, restore) = ladder(backend);
            assert!(warm < restore, "{backend:?}: warm {warm} !< restore {restore}");
            assert!(restore < cold, "{backend:?}: restore {restore} !< cold {cold}");
        }
    }

    #[test]
    fn junction_beats_containerd_at_every_tier() {
        let (c_cold, c_warm, c_restore) = ladder(Backend::Containerd);
        let (j_cold, j_warm, j_restore) = ladder(Backend::Junctiond);
        assert!(j_warm * 10 <= c_warm, "warm: {j_warm} vs {c_warm}");
        assert!(j_restore * 10 <= c_restore, "restore: {j_restore} vs {c_restore}");
        assert!(j_cold * 10 <= c_cold, "cold: {j_cold} vs {c_cold}");
    }

    #[test]
    fn warm_redeploy_serves_invocations() {
        for backend in [Backend::Containerd, Backend::Junctiond] {
            let mut sim = Sim::new();
            let fs = FaasSim::new(&cfg(backend), Rc::new(PlatformConfig::default()));
            let spec = FunctionSpec::new("aes", "aes600", RuntimeKind::Go);
            fs.deploy(&mut sim, spec.clone());
            sim.run_until(SECONDS);
            let done = Rc::new(RefCell::new(Vec::new()));
            for _ in 0..5 {
                let d = done.clone();
                fs.submit(&mut sim, "aes", move |_, t| d.borrow_mut().push(t));
            }
            sim.run_to_completion();
            assert!(fs.undeploy(&mut sim, "aes"));
            assert!(!fs.is_deployed("aes"));
            fs.deploy(&mut sim, spec);
            for _ in 0..5 {
                let d = done.clone();
                fs.submit(&mut sim, "aes", move |_, t| d.borrow_mut().push(t));
            }
            sim.run_to_completion();
            let ts = done.borrow();
            assert_eq!(ts.len(), 10, "{backend:?}");
            assert!(ts[..5].iter().all(|t| t.tier == ProvisionTier::ColdBoot));
            assert!(ts[5..].iter().all(|t| t.tier == ProvisionTier::WarmPool));
            let (_, served) = fs.tier_counts();
            assert_eq!(served[ProvisionTier::WarmPool.idx()], 5);
            assert_eq!(served[ProvisionTier::ColdBoot.idx()], 5);
            assert_eq!(served.iter().sum::<u64>(), fs.completed());
        }
    }

    #[test]
    fn crash_function_resolves_inflight_and_serves_after_recovery() {
        for backend in [Backend::Containerd, Backend::Junctiond] {
            let mut sim = Sim::new();
            let fs = FaasSim::new(&cfg(backend), Rc::new(PlatformConfig::default()));
            fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
            sim.run_until(SECONDS);
            let out = Rc::new(RefCell::new(Vec::new()));
            for _ in 0..5 {
                let o = out.clone();
                fs.submit(&mut sim, "aes", move |_, t| o.borrow_mut().push(t));
            }
            // Crash while those requests are somewhere in the pipeline.
            let fs2 = fs.clone();
            sim.at(sim.now() + 10 * MICROS, move |sim| {
                fs2.crash_function(sim, "aes").expect("deployed");
            });
            sim.run_to_completion();
            assert_eq!(out.borrow().len(), 5, "{backend:?}: in-flight must resolve");
            // The function is live again and serves new traffic.
            assert!(fs.is_deployed("aes"), "{backend:?}");
            for _ in 0..3 {
                let o = out.clone();
                fs.submit(&mut sim, "aes", move |_, t| o.borrow_mut().push(t));
            }
            sim.run_to_completion();
            assert_eq!(out.borrow().len(), 8, "{backend:?}");
            assert!(
                out.borrow()[5..].iter().all(|t| !t.dropped),
                "{backend:?}: post-recovery traffic must complete"
            );
            if backend == Backend::Junctiond {
                let ms = fs.manager_stats();
                assert!(ms.crashed >= 1, "crash must be counted");
                assert!(ms.restarted <= ms.crashed);
            }
            let violations = crate::invariants::audit_all(&fs);
            assert!(violations.is_empty(), "{backend:?}: {violations:?}");
        }
    }

    #[test]
    fn jittered_backoff_is_deterministic_and_gated() {
        let run = |jitter: u64| -> Vec<Time> {
            let platform =
                PlatformConfig { nic_retry_jitter: jitter, ..PlatformConfig::default() };
            let mut sim = Sim::new();
            let fs = FaasSim::new(&cfg(Backend::Containerd), Rc::new(platform));
            fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
            sim.run_until(crate::simcore::SECONDS);
            let out = Rc::new(RefCell::new(Vec::new()));
            // A burst deeper than the 256-slot RX ring forces retransmits,
            // so the backoff policy is actually on the path.
            for _ in 0..600 {
                let o = out.clone();
                fs.submit(&mut sim, "aes", move |_, t| o.borrow_mut().push(t.done));
            }
            sim.run_to_completion();
            Rc::try_unwrap(out).ok().unwrap().into_inner()
        };
        assert_eq!(run(0), run(0));
        assert_eq!(run(1), run(1), "decorrelated jitter must be seed-deterministic");
        assert_ne!(run(0), run(1), "jitter must actually move the retransmit times");
    }

    #[test]
    fn undeploy_refuses_while_requests_outstanding() {
        let mut sim = Sim::new();
        let fs = FaasSim::new(&cfg(Backend::Junctiond), Rc::new(PlatformConfig::default()));
        fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        sim.run_until(SECONDS);
        fs.submit(&mut sim, "aes", |_, _| {});
        assert!(!fs.undeploy(&mut sim, "aes"), "must refuse with a request in flight");
        sim.run_to_completion();
        assert!(fs.undeploy(&mut sim, "aes"), "idle after drain: must undeploy");
        assert!(!fs.undeploy(&mut sim, "aes"), "already gone");
    }

    #[test]
    fn prewarm_hook_feeds_scale_up() {
        let mut sim = Sim::new();
        let fs = FaasSim::new(&cfg(Backend::Junctiond), Rc::new(PlatformConfig::default()));
        fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        sim.run_until(SECONDS);
        // Drive enough traffic that the arrival-rate estimator crosses the
        // prewarm threshold.
        let mut at = sim.now();
        for _ in 0..400 {
            at += MILLIS;
            let fs2 = fs.clone();
            sim.at(at, move |sim| fs2.submit(sim, "aes", |_, _| {}));
        }
        sim.run_to_completion();
        fs.prewarm_tick(&mut sim);
        assert!(fs.pool_stats().prewarms > 0, "estimator should trigger prewarms");
        // Let the background restores land, then scale up from the pool.
        sim.run_until(sim.now() + SECONDS);
        let (tier, lat) = fs.scale_up_replica(&mut sim, "aes", true).unwrap();
        assert_eq!(tier, ProvisionTier::WarmPool);
        assert!(lat < MILLIS, "warm scale-up should be near-instant, got {lat}");
        sim.run_to_completion();
    }

    // ---- network data path (netpath) ------------------------------------

    #[test]
    fn per_hop_breakdown_sums_to_e2e() {
        let wire = PlatformConfig::default().wire_ns;
        for backend in [Backend::Containerd, Backend::Junctiond] {
            let ts = run_n(backend, 10);
            for t in ts {
                assert!(t.nic_in > t.submit, "{backend:?}: wire precedes the NIC");
                assert!(t.nic_in <= t.gateway_in, "{backend:?}: NIC precedes the gateway");
                assert_eq!(t.retries, 0, "{backend:?}: no drops at sequential load");
                assert_eq!(t.tx_retries, 0, "{backend:?}: no TX stalls at sequential load");
                assert!(!t.dropped);
                assert!(t.tx_in >= t.exec_end, "{backend:?}: TX follows the exec window");
                assert!(
                    t.tx_hop() > 0 && t.tx_hop() <= t.response_hop(),
                    "{backend:?}: the TX hop is a sub-span of the response hop"
                );
                assert_eq!(
                    wire + t.nic_hop() + t.pre_exec() + t.exec() + t.response_hop(),
                    t.e2e(),
                    "{backend:?}: per-hop breakdown must cover the whole request"
                );
            }
        }
    }

    // ---- invocation tracing ---------------------------------------------

    /// `run_n` with tracing enabled (reservoir of 8 tail exemplars).
    fn run_n_traced(backend: Backend, n: usize) -> (Vec<RequestTiming>, Tracer) {
        let mut sim = Sim::new();
        let platform = Rc::new(PlatformConfig::default());
        let fs = FaasSim::new(&cfg(backend), platform);
        let tracer = fs.enable_tracing(8);
        fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        sim.run_until(2 * crate::simcore::SECONDS);
        let out = Rc::new(RefCell::new(Vec::new()));
        for _ in 0..n {
            let out2 = out.clone();
            fs.submit(&mut sim, "aes", move |_, t| out2.borrow_mut().push(t));
        }
        sim.run_to_completion();
        (Rc::try_unwrap(out).ok().unwrap().into_inner(), tracer)
    }

    #[test]
    fn tracing_does_not_perturb_the_pipeline() {
        for backend in [Backend::Containerd, Backend::Junctiond] {
            let base = run_n(backend, 20);
            let (traced, tracer) = run_n_traced(backend, 20);
            assert_eq!(base.len(), traced.len());
            for (a, b) in base.iter().zip(&traced) {
                assert_eq!(a.submit, b.submit, "{backend:?}");
                assert_eq!(a.nic_in, b.nic_in, "{backend:?}");
                assert_eq!(a.gateway_in, b.gateway_in, "{backend:?}");
                assert_eq!(a.exec_start, b.exec_start, "{backend:?}");
                assert_eq!(a.exec_end, b.exec_end, "{backend:?}");
                assert_eq!(a.tx_in, b.tx_in, "{backend:?}");
                assert_eq!(a.done, b.done, "{backend:?}: tracing must not move completions");
                assert_eq!(a.retries, b.retries, "{backend:?}");
                assert_eq!(a.tx_retries, b.tx_retries, "{backend:?}");
                assert_eq!(a.dropped, b.dropped, "{backend:?}");
                assert_eq!(a.seq, 0, "untraced runs never assign seqs");
                assert!(b.seq != 0, "traced runs tag every request");
            }
            assert_eq!(tracer.completions(), 20, "{backend:?}");
        }
    }

    #[test]
    fn trace_trees_tile_and_sum_to_e2e() {
        for backend in [Backend::Containerd, Backend::Junctiond] {
            let (timings, tracer) = run_n_traced(backend, 30);
            let by_seq: BTreeMap<u64, RequestTiming> =
                timings.iter().map(|t| (t.seq, *t)).collect();
            let exemplars = tracer.exemplars();
            assert_eq!(exemplars.len(), 8, "{backend:?}: the reservoir fills to K");
            for tr in &exemplars {
                let t = by_seq[&tr.seq];
                assert_eq!(tr.e2e, t.e2e(), "{backend:?}");
                let root = &tr.spans[0];
                assert_eq!(root.start, t.submit);
                assert_eq!(root.end, t.done);
                let kids = tr.root_children();
                assert_eq!(kids.len(), 5, "{backend:?}");
                assert_eq!(kids[0].start, root.start);
                for pair in kids.windows(2) {
                    assert_eq!(pair[0].end, pair[1].start, "{backend:?}: children must tile");
                }
                assert_eq!(kids.last().unwrap().end, root.end);
                let sum: Time = kids.iter().map(|s| s.duration()).sum();
                assert_eq!(sum, tr.e2e, "{backend:?}: hop spans must sum to e2e");
                // Every recorded sub-span nests inside its parent hop.
                for s in &tr.spans[7..] {
                    let parent = &tr.spans[s.parent.unwrap() as usize];
                    assert!(
                        s.start >= parent.start && s.end <= parent.end,
                        "{backend:?}: {} [{},{}] escapes {} [{},{}]",
                        s.name,
                        s.start,
                        s.end,
                        parent.name,
                        parent.start,
                        parent.end
                    );
                }
                // The exec window's fabric slices were observed.
                assert!(
                    tr.spans.iter().any(|s| s.name == "fabric.slice"),
                    "{backend:?}: exec slices must be recorded"
                );
            }
            let r = tracer.blame_report();
            assert_eq!(r.count, 30, "{backend:?}");
            let sum50: f64 = r.p50.iter().sum();
            let sum99: f64 = r.p99.iter().sum();
            assert!((sum50 - 1.0).abs() < 1e-9, "{backend:?}: p50 shares sum {sum50}");
            assert!((sum99 - 1.0).abs() < 1e-9, "{backend:?}: p99 shares sum {sum99}");
        }
    }

    #[test]
    fn nic_overflow_drops_and_retries() {
        // 2000 simultaneous arrivals against a 256-deep RX ring: the ring
        // must shed, clients must retransmit, and every request must still
        // resolve (completed or dropped — nothing leaks).
        let mut sim = Sim::new();
        let fs = FaasSim::new(&cfg(Backend::Containerd), Rc::new(PlatformConfig::default()));
        fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        sim.run_until(crate::simcore::SECONDS);
        let completed = Rc::new(RefCell::new(0u64));
        let dropped = Rc::new(RefCell::new(0u64));
        let max_retries = PlatformConfig::default().nic_max_retries as u32;
        for _ in 0..2000 {
            let c = completed.clone();
            let d = dropped.clone();
            fs.submit(&mut sim, "aes", move |_, t| {
                if t.dropped {
                    assert_eq!(t.retries, max_retries, "gave up before the retry budget");
                    *d.borrow_mut() += 1;
                } else {
                    assert!(t.retries <= max_retries);
                    *c.borrow_mut() += 1;
                }
            });
        }
        sim.run_to_completion();
        let (c, d) = (*completed.borrow(), *dropped.borrow());
        assert_eq!(c + d, 2000, "every request must resolve");
        assert!(d > 0, "a 2000-burst must overflow the 256-deep ring");
        assert!(c >= 256, "the ring capacity must be served");
        let stats = fs.nic_stats();
        assert!(stats.rx_dropped > 0 && stats.retries > 0, "{stats:?}");
        assert_eq!(stats.rx_delivered, c, "accepted == completed");
        assert_eq!(
            stats.retrans_cancelled, stats.rx_enqueued,
            "every accepted frame must cancel its retransmit timer in O(1)"
        );
        assert_eq!(fs.dropped(), d);
        assert_eq!(fs.completed(), c);
    }

    #[test]
    fn junction_nic_batches_simultaneous_bursts() {
        let mut sim = Sim::new();
        let fs = FaasSim::new(&cfg(Backend::Junctiond), Rc::new(PlatformConfig::default()));
        fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        sim.run_until(crate::simcore::SECONDS);
        for _ in 0..64 {
            fs.submit(&mut sim, "aes", |_, _| {});
        }
        sim.run_to_completion();
        let stats = fs.nic_stats();
        assert_eq!(stats.rx_delivered, 64);
        assert_eq!(stats.rx_dropped, 0);
        assert!(
            stats.bursts <= 4,
            "polled RX must coalesce a simultaneous burst: {stats:?}"
        );
        assert!(stats.mean_batch() >= 16.0, "{stats:?}");
        let s = fs.scheduler_stats();
        assert_eq!(s.nic_rx_packets, 64);
        assert!(s.nic_polls <= 4, "{s:?}");
        let tx = fs.tx_stats();
        assert_eq!(tx.tx_packets, 64, "one response frame per invocation");
        assert_eq!(tx.tx_abandoned, 0);
        assert_eq!(s.nic_tx_packets, 64, "scheduler TX poll accounting agrees");
        assert!(tx.mean_batch() >= 1.0, "{tx:?}");
    }

    #[test]
    fn kernel_nic_drains_serially() {
        let mut sim = Sim::new();
        let fs = FaasSim::new(&cfg(Backend::Containerd), Rc::new(PlatformConfig::default()));
        fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        sim.run_until(crate::simcore::SECONDS);
        for _ in 0..32 {
            fs.submit(&mut sim, "aes", |_, _| {});
        }
        sim.run_to_completion();
        let stats = fs.nic_stats();
        assert_eq!(stats.rx_delivered, 32);
        assert_eq!(stats.bursts, 32, "kernel path processes one packet per IRQ: {stats:?}");
        assert!((stats.mean_batch() - 1.0).abs() < 1e-9);
        // The TX direction is just as serial: one frame per qdisc pass.
        let tx = fs.tx_stats();
        assert_eq!(tx.tx_packets, 32);
        assert_eq!(tx.tx_bursts, 32, "kernel TX flushes one frame per burst: {tx:?}");
        assert!((tx.mean_batch() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tx_backpressure_stalls_then_resolves() {
        // A one-descriptor TX ring flushing one frame per burst under a
        // simultaneous 200-request storm: responses must stall
        // (backpressure), re-offer, and every request must still resolve
        // (completed or abandoned — nothing leaks, nothing double-counts).
        let platform = PlatformConfig {
            nic_tx_queue_depth: 1,
            nic_tx_batch_max: 1,
            nic_tx_retry_backoff_ns: 5 * MICROS,
            ..PlatformConfig::default()
        };
        let mut sim = Sim::new();
        let fs = FaasSim::new(&cfg(Backend::Junctiond), Rc::new(platform));
        // 8-way instance concurrency so completions cluster (an incast of
        // responses, not a serial trickle).
        fs.deploy(
            &mut sim,
            FunctionSpec::new("aes", "aes600", RuntimeKind::Go)
                .with_scale(crate::faas::ScaleMode::MaxCores, 8),
        );
        sim.run_until(crate::simcore::SECONDS);
        let completed = Rc::new(RefCell::new(0u64));
        let dropped = Rc::new(RefCell::new(0u64));
        for _ in 0..200 {
            let c = completed.clone();
            let d = dropped.clone();
            fs.submit(&mut sim, "aes", move |_, t| {
                if t.dropped {
                    *d.borrow_mut() += 1;
                } else {
                    *c.borrow_mut() += 1;
                }
            });
        }
        sim.run_to_completion();
        let (c, d) = (*completed.borrow(), *dropped.borrow());
        assert_eq!(c + d, 200, "every request must resolve");
        let tx = fs.tx_stats();
        assert!(tx.tx_stalled > 0, "a 1-deep TX ring must backpressure: {tx:?}");
        assert!(tx.tx_retries > 0, "stalled responses must re-offer: {tx:?}");
        assert_eq!(tx.tx_packets, c, "frames that left the worker == completions");
        assert_eq!(tx.tx_abandoned, d, "abandons == dropped requests");
        assert_eq!(fs.completed(), c);
        assert_eq!(fs.dropped(), d);
        // No response was both sent and abandoned.
        assert_eq!(tx.tx_enqueued, tx.tx_packets);
    }

    #[test]
    fn undeploy_evicts_gateway_routing_state() {
        let mut sim = Sim::new();
        let fs = FaasSim::new(&cfg(Backend::Junctiond), Rc::new(PlatformConfig::default()));
        let spec = FunctionSpec::new("aes", "aes600", RuntimeKind::Go);
        fs.deploy(&mut sim, spec);
        sim.run_until(crate::simcore::SECONDS);
        fs.submit(&mut sim, "aes", |_, _| {});
        sim.run_to_completion();
        assert_eq!(fs.w.borrow().gateway.tracked_functions(), 1);
        assert!(fs.undeploy(&mut sim, "aes"));
        assert_eq!(
            fs.w.borrow().gateway.tracked_functions(),
            0,
            "undeploy must drop the round-robin counter"
        );
    }

    #[test]
    fn ttl_sweep_evicts_parked_instances() {
        let mut sim = Sim::new();
        let fs = FaasSim::new(&cfg(Backend::Junctiond), Rc::new(PlatformConfig::default()));
        let ttl = fs.pool_config().idle_ttl_ns;
        let spec = FunctionSpec::new("aes", "aes600", RuntimeKind::Go);
        fs.deploy(&mut sim, spec.clone());
        sim.run_until(SECONDS);
        assert!(fs.undeploy(&mut sim, "aes"));
        // Before the TTL: still parked.
        fs.pool_sweep(&mut sim);
        assert_eq!(fs.pool_stats().ttl_evictions, 0);
        sim.run_until(sim.now() + ttl + SECONDS);
        fs.pool_sweep(&mut sim);
        assert_eq!(fs.pool_stats().ttl_evictions, 1);
        // Redeploy now restores from snapshot (warm slot is gone).
        let (_, tier) = fs.deploy_tiered(&mut sim, spec, true);
        assert_eq!(tier, ProvisionTier::SnapshotRestore);
        sim.run_to_completion();
    }
}
