//! The discrete-event invocation pipeline: client → gateway → provider →
//! function instance → provider → gateway → client, for both backends.
//!
//! This is the simulation counterpart of the paper's Figure 2/4 topology.
//! Each component pass is one CPU *segment* on the shared worker core
//! pool, prefixed by that backend's wakeup/delivery latency:
//!
//! * **containerd**: segments pay kernel RX/TX (IRQ + softirq + stack +
//!   wakeup + syscalls), veth hops into the container, heavy-tailed
//!   scheduling noise, and rare interference bursts — all from
//!   [`crate::oskernel::KernelCosts`].
//! * **junctiond**: segments pay the Junction user-space stack and the
//!   central scheduler's wakeup/grant path — from
//!   [`crate::junction::BypassCosts`] and the live
//!   [`crate::junction::Scheduler`] instance inside [`crate::junctiond::Junctiond`].
//!
//! Function compute is *real*: the default segment cost comes from PJRT
//! calibration of the AES-600B artifact (`runtime::calibrate`), so the
//! simulated function body costs what the actual lowered HLO costs on
//! this machine.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::config::{Backend, ExperimentConfig, PlatformConfig};
use crate::containerd_sim::{ContainerId, Containerd};
use crate::junction::{BypassCosts, InstanceId};
use crate::junctiond::Junctiond;
use crate::oskernel::KernelCosts;
use crate::simcore::{CorePool, Rng, Sim, Time};

use super::{CacheOutcome, FunctionSpec, Gate, Gateway, Provider, Registry, ReplicaMeta};

/// Per-request timestamps (virtual ns).
#[derive(Debug, Clone, Copy, Default)]
pub struct RequestTiming {
    /// Client issued the request.
    pub submit: Time,
    /// Gateway received it (start of the gateway-observed window).
    pub gateway_in: Time,
    /// Function instance admitted the request (exec window start).
    pub exec_start: Time,
    /// Function instance finished (exec window end).
    pub exec_end: Time,
    /// Client received the response.
    pub done: Time,
}

impl RequestTiming {
    /// Client-observed end-to-end latency.
    pub fn e2e(&self) -> Time {
        self.done - self.submit
    }
    /// Gateway-observed latency (what the paper's Fig. 5 plots).
    pub fn gateway_observed(&self) -> Time {
        self.done.saturating_sub(self.gateway_in)
    }
    /// Function execution latency (Fig. 5's second series).
    pub fn exec(&self) -> Time {
        self.exec_end - self.exec_start
    }
}

/// One deployed replica's runtime handle.
enum ReplicaHandle {
    Container(ContainerId),
    Junction(InstanceId),
}

struct DeployedFn {
    #[allow(dead_code)] // retained for monitoring/debug dumps
    spec: FunctionSpec,
    replicas: Vec<(ReplicaHandle, Gate)>,
    ready_at: Time,
    meta: ReplicaMeta,
}

struct World {
    platform: Rc<PlatformConfig>,
    backend: Backend,
    cores: CorePool,
    // Per-component cost samplers (independent RNG streams).
    kc_gw: KernelCosts,
    kc_prov: KernelCosts,
    kc_fn: KernelCosts,
    bc_gw: BypassCosts,
    bc_prov: BypassCosts,
    bc_fn: BypassCosts,
    // Backends.
    jd: Junctiond,
    containerd: Containerd,
    // faasd services.
    gateway: Gateway,
    provider: Provider,
    registry: Registry,
    functions: BTreeMap<String, DeployedFn>,
    // The services' own junction instances (§3: services run in instances).
    gw_inst: Option<InstanceId>,
    prov_inst: Option<InstanceId>,
    compute_ns: Time,
    pub completed: u64,
}

impl World {
    /// Wakeup latency + in-flight accounting for a service instance on the
    /// junction path; no-op for containerd.
    fn service_wakeup(&mut self, inst: Option<InstanceId>) -> Time {
        match (self.backend, inst) {
            (Backend::Junctiond, Some(id)) => self.jd.scheduler.packet_arrival(id).latency(),
            _ => 0,
        }
    }

    fn service_done(&mut self, inst: Option<InstanceId>) {
        if let (Backend::Junctiond, Some(id)) = (self.backend, inst) {
            self.jd.scheduler.request_done(id);
        }
    }
}

/// The simulated faasd deployment (one worker server + a client machine).
#[derive(Clone)]
pub struct FaasSim {
    w: Rc<RefCell<World>>,
}

impl FaasSim {
    pub fn new(cfg: &ExperimentConfig, platform: Rc<PlatformConfig>) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let cores = CorePool::new(cfg.worker_cores);
        let mut jd = Junctiond::new(platform.clone(), cfg.worker_cores as u32, rng.fork());
        let containerd = Containerd::new(platform.clone(), rng.fork());
        let mut gw_inst = None;
        let mut prov_inst = None;
        if cfg.backend == Backend::Junctiond {
            // The scheduler busy-polls on a dedicated, reserved core (§2.2.1).
            cores.reserve(1);
            // Gateway and provider run inside Junction instances (§3).
            gw_inst = Some(jd.deploy_service("gateway", 2).0);
            prov_inst = Some(jd.deploy_service("provider", 2).0);
        }
        let world = World {
            platform: platform.clone(),
            backend: cfg.backend,
            cores,
            kc_gw: KernelCosts::new(platform.clone(), rng.fork()),
            kc_prov: KernelCosts::new(platform.clone(), rng.fork()),
            kc_fn: KernelCosts::new(platform.clone(), rng.fork()),
            bc_gw: BypassCosts::new(platform.clone(), rng.fork()).with_sched_tail(),
            bc_prov: BypassCosts::new(platform.clone(), rng.fork()).with_sched_tail(),
            bc_fn: BypassCosts::new(platform.clone(), rng.fork()),
            jd,
            containerd,
            gateway: Gateway::new(),
            provider: Provider::new(cfg.provider_cache),
            registry: Registry::new(),
            functions: BTreeMap::new(),
            gw_inst,
            prov_inst,
            compute_ns: cfg.function_compute_ns,
            completed: 0,
        };
        FaasSim { w: Rc::new(RefCell::new(world)) }
    }

    /// Deploy a function on the active backend. Returns the cold-start
    /// duration; the function accepts traffic from `sim.now() + cold`.
    pub fn deploy(&self, sim: &mut Sim, spec: FunctionSpec) -> Time {
        let mut w = self.w.borrow_mut();
        w.registry.deploy(spec.clone()).expect("duplicate deploy");
        let now = sim.now();
        let (replicas, cold) = match w.backend {
            Backend::Containerd => {
                let conc = w.platform.container_concurrency as u32;
                let (cid, cold) = w.containerd.create_and_start(&spec.name, now);
                (vec![(ReplicaHandle::Container(cid), Gate::new(conc))], cold)
            }
            Backend::Junctiond => {
                let (ids, cold) = w.jd.deploy_function(&spec);
                let reps = ids
                    .iter()
                    .map(|id| {
                        let conc = w.jd.concurrency_of(*id, &spec);
                        (ReplicaHandle::Junction(*id), Gate::new(conc))
                    })
                    .collect();
                (reps, cold)
            }
        };
        let n_replicas = replicas.len() as u32;
        let addr = match &replicas[0].0 {
            ReplicaHandle::Container(cid) => w.containerd.get(*cid).unwrap().addr,
            ReplicaHandle::Junction(id) => {
                let cfg = w.jd.config_of(*id).unwrap();
                (cfg.ip, cfg.port)
            }
        };
        let deployed = DeployedFn {
            spec: spec.clone(),
            replicas,
            ready_at: now + cold,
            meta: ReplicaMeta { replicas: n_replicas, addr },
        };
        w.functions.insert(spec.name.clone(), deployed);
        // Containers flip to Running at ready_at.
        if w.backend == Backend::Containerd {
            let this = self.clone();
            let name = spec.name.clone();
            drop(w);
            sim.at(now + cold, move |_| {
                let mut w = this.w.borrow_mut();
                let ids: Vec<ContainerId> = w.functions[&name]
                    .replicas
                    .iter()
                    .map(|(h, _)| match h {
                        ReplicaHandle::Container(c) => *c,
                        _ => unreachable!(),
                    })
                    .collect();
                for c in ids {
                    w.containerd.mark_running(c);
                }
            });
        }
        cold
    }

    /// Submit one invocation; `done` fires at the client with the timings.
    pub fn submit<F: FnOnce(&mut Sim, RequestTiming) + 'static>(
        &self,
        sim: &mut Sim,
        function: &str,
        done: F,
    ) {
        let timing = RequestTiming { submit: sim.now(), ..Default::default() };
        let this = self.clone();
        let name = function.to_string();
        let wire = self.w.borrow().platform.wire_ns;
        // client → worker wire hop
        sim.after(wire, move |sim| stage_gateway(this, sim, name, timing, Box::new(done)));
    }

    pub fn completed(&self) -> u64 {
        self.w.borrow().completed
    }

    pub fn cores(&self) -> CorePool {
        self.w.borrow().cores.clone()
    }

    pub fn provider_stats(&self) -> (u64, u64) {
        let w = self.w.borrow();
        (w.provider.hits, w.provider.misses)
    }

    pub fn scheduler_stats(&self) -> crate::junction::SchedulerStats {
        self.w.borrow().jd.scheduler.stats
    }

    /// Virtual time at which `function` becomes warm.
    pub fn ready_at(&self, function: &str) -> Time {
        self.w.borrow().functions[function].ready_at
    }

    /// Host-kernel vs user-space interaction counters, summed over all
    /// components — the quantitative side of the paper's §3 isolation
    /// argument (how much trusted host-kernel surface each invocation
    /// exercises).
    pub fn cost_telemetry(&self) -> CostTelemetry {
        let w = self.w.borrow();
        CostTelemetry {
            host_syscalls: w.kc_gw.syscalls + w.kc_prov.syscalls + w.kc_fn.syscalls,
            host_wakeups: w.kc_gw.wakeups + w.kc_prov.wakeups + w.kc_fn.wakeups,
            kernel_msgs: w.kc_gw.msgs_recv
                + w.kc_gw.msgs_sent
                + w.kc_prov.msgs_recv
                + w.kc_prov.msgs_sent
                + w.kc_fn.msgs_recv
                + w.kc_fn.msgs_sent,
            user_syscalls: w.bc_gw.syscalls + w.bc_prov.syscalls + w.bc_fn.syscalls,
            bypass_msgs: w.bc_gw.msgs_recv
                + w.bc_gw.msgs_sent
                + w.bc_prov.msgs_recv
                + w.bc_prov.msgs_sent
                + w.bc_fn.msgs_recv
                + w.bc_fn.msgs_sent,
        }
    }
}

/// Aggregated host-kernel vs user-space interaction counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct CostTelemetry {
    /// Syscalls that trapped into the host kernel.
    pub host_syscalls: u64,
    /// Host-kernel scheduler wakeups.
    pub host_wakeups: u64,
    /// Messages that traversed the host kernel network stack.
    pub kernel_msgs: u64,
    /// Syscalls handled inside Junction instances (user space).
    pub user_syscalls: u64,
    /// Messages that went through per-instance bypass queues.
    pub bypass_msgs: u64,
}

type DoneFn = Box<dyn FnOnce(&mut Sim, RequestTiming)>;

/// Gateway pass: auth + route + forward to the provider.
fn stage_gateway(fs: FaasSim, sim: &mut Sim, name: String, mut t: RequestTiming, done: DoneFn) {
    t.gateway_in = sim.now();
    let (lat, cpu, cores) = {
        let mut w = fs.w.borrow_mut();
        let gw_inst = w.gw_inst;
        let lat = w.service_wakeup(gw_inst);
        let p = w.platform.clone();
        let n_replicas = w.functions.get(&name).map(|f| f.meta.replicas).unwrap_or(0);
        w.gateway.authenticate("token");
        let routed = w.gateway.route(&name, n_replicas);
        assert!(routed.is_some(), "function '{name}' not deployed");
        let cpu = match w.backend {
            Backend::Containerd => {
                w.kc_gw.recv_msg()
                    + p.gateway_cpu_ns
                    + p.rpc_serde_ns
                    + w.kc_gw.send_msg()
                    + w.kc_gw.segment_interference()
            }
            Backend::Junctiond => {
                w.bc_gw.recv_msg() + p.gateway_cpu_ns + p.rpc_serde_ns + w.bc_gw.send_msg()
            }
        };
        let lat = lat + w.bc_gw.sched_tail_delay();
        (lat, cpu, w.cores.clone())
    };
    sim.after(lat, move |sim| {
        let fs2 = fs.clone();
        cores.run(sim, cpu, move |sim| {
            {
                let mut w = fs2.w.borrow_mut();
                let gw_inst = w.gw_inst;
                w.service_done(gw_inst);
            }
            stage_provider(fs2, sim, name, t, done);
        });
    });
}

/// Provider pass: resolve (cache or backend state query) + forward.
fn stage_provider(fs: FaasSim, sim: &mut Sim, name: String, t: RequestTiming, done: DoneFn) {
    let (lat, query_lat, cpu, cores) = {
        let mut w = fs.w.borrow_mut();
        let prov_inst = w.prov_inst;
        let lat = w.service_wakeup(prov_inst);
        let p = w.platform.clone();
        // §4 metadata cache: a miss pays the backend state query.
        let query_lat = match w.provider.resolve(&name) {
            CacheOutcome::Hit(_) => 0,
            CacheOutcome::Miss => {
                let meta = w.functions[&name].meta;
                w.provider.fill(&name, meta);
                match w.backend {
                    Backend::Containerd => w.containerd.state_query(),
                    Backend::Junctiond => p.junctiond_state_query_ns,
                }
            }
        };
        let cpu = match w.backend {
            Backend::Containerd => {
                // Send crosses the veth into the container's netns.
                w.kc_prov.recv_msg()
                    + p.provider_cpu_ns
                    + p.rpc_serde_ns
                    + w.kc_prov.send_msg()
                    + w.kc_prov.veth_hop()
                    + w.kc_prov.segment_interference()
            }
            Backend::Junctiond => {
                w.bc_prov.recv_msg() + p.provider_cpu_ns + p.rpc_serde_ns + w.bc_prov.send_msg()
            }
        };
        let lat = lat + w.bc_prov.sched_tail_delay();
        (lat, query_lat, cpu, w.cores.clone())
    };
    sim.after(lat + query_lat, move |sim| {
        let fs2 = fs.clone();
        cores.run(sim, cpu, move |sim| {
            {
                let mut w = fs2.w.borrow_mut();
                let prov_inst = w.prov_inst;
                w.service_done(prov_inst);
            }
            stage_function(fs2, sim, name, t, done);
        });
    });
}

/// Function pass: concurrency gate, then the exec segment.
fn stage_function(fs: FaasSim, sim: &mut Sim, name: String, t: RequestTiming, done: DoneFn) {
    // Pick the replica (round-robin mirrors the gateway's choice; per-
    // replica gates model per-instance concurrency).
    let (gate, handle_idx, ready_at) = {
        let w = fs.w.borrow();
        let f = &w.functions[&name];
        let idx = (w.gateway.requests as usize) % f.replicas.len();
        let g = f.replicas[idx].1.clone();
        let ready = f.ready_at;
        (g, idx, ready)
    };
    // Cold start: requests arriving early wait for instance readiness.
    let wait = ready_at.saturating_sub(sim.now());
    let gate2 = gate.clone();
    sim.after(wait, move |sim| {
        gate2.acquire(sim, move |sim| {
            exec_segment(fs, sim, name, handle_idx, gate, t, done);
        });
    });
}

/// The exec segment inside the instance (the Fig. 5 "function execution
/// latency" window).
fn exec_segment(
    fs: FaasSim,
    sim: &mut Sim,
    name: String,
    replica: usize,
    gate: Gate,
    mut t: RequestTiming,
    done: DoneFn,
) {
    t.exec_start = sim.now();
    let (lat, cpu, cores, inst) = {
        let mut w = fs.w.borrow_mut();
        let p = w.platform.clone();
        let nsys = p.function_syscalls as u32;
        let compute = w.compute_ns;
        match w.backend {
            Backend::Containerd => {
                let cid = match w.functions[&name].replicas[replica].0 {
                    ReplicaHandle::Container(c) => c,
                    _ => unreachable!(),
                };
                w.containerd.get_mut(cid).unwrap().invocations += 1;
                let cpu = w.kc_fn.recv_msg()
                    + w.kc_fn.veth_hop()
                    + w.kc_fn.syscalls(nsys)
                    + compute
                    + w.kc_fn.sched_noise()
                    + w.kc_fn.segment_interference()
                    + w.kc_fn.send_msg()
                    + w.kc_fn.veth_hop();
                (0, cpu, w.cores.clone(), None)
            }
            Backend::Junctiond => {
                let id = match w.functions[&name].replicas[replica].0 {
                    ReplicaHandle::Junction(i) => i,
                    _ => unreachable!(),
                };
                let lat = w.jd.scheduler.packet_arrival(id).latency();
                let cpu = w.bc_fn.recv_msg()
                    + w.bc_fn.syscalls(nsys)
                    + compute
                    + w.bc_fn.send_msg();
                (lat, cpu, w.cores.clone(), Some(id))
            }
        }
    };
    sim.after(lat, move |sim| {
        let fs2 = fs.clone();
        cores.run(sim, cpu, move |sim| {
            t.exec_end = sim.now();
            {
                let mut w = fs2.w.borrow_mut();
                if let Some(id) = inst {
                    w.jd.scheduler.request_done(id);
                }
            }
            gate.release(sim);
            stage_response(fs2, sim, t, done);
        });
    });
}

/// Response path: provider proxy pass, gateway proxy pass, wire to client.
fn stage_response(fs: FaasSim, sim: &mut Sim, t: RequestTiming, done: DoneFn) {
    let (lat_p, cpu_p, cores) = {
        let mut w = fs.w.borrow_mut();
        let prov_inst = w.prov_inst;
        let lat = w.service_wakeup(prov_inst);
        let p = w.platform.clone();
        let cpu = match w.backend {
            Backend::Containerd => {
                w.kc_prov.recv_msg()
                    + w.kc_prov.veth_hop()
                    + p.rpc_serde_ns
                    + w.kc_prov.send_msg()
                    + w.kc_prov.segment_interference()
            }
            Backend::Junctiond => w.bc_prov.recv_msg() + p.rpc_serde_ns + w.bc_prov.send_msg(),
        };
        let lat = lat + w.bc_prov.sched_tail_delay();
        (lat, cpu, w.cores.clone())
    };
    sim.after(lat_p, move |sim| {
        let fs2 = fs.clone();
        cores.run(sim, cpu_p, move |sim| {
            let (lat_g, cpu_g, cores2, wire) = {
                let mut w = fs2.w.borrow_mut();
                let prov_inst = w.prov_inst;
                w.service_done(prov_inst);
                let gw_inst = w.gw_inst;
                let lat = w.service_wakeup(gw_inst);
                let p = w.platform.clone();
                let cpu = match w.backend {
                    Backend::Containerd => {
                        w.kc_gw.recv_msg()
                            + p.rpc_serde_ns
                            + w.kc_gw.send_msg()
                            + w.kc_gw.segment_interference()
                    }
                    Backend::Junctiond => {
                        w.bc_gw.recv_msg() + p.rpc_serde_ns + w.bc_gw.send_msg()
                    }
                };
                let lat = lat + w.bc_gw.sched_tail_delay();
                (lat, cpu, w.cores.clone(), p.wire_ns)
            };
            let fs3 = fs2.clone();
            sim.after(lat_g, move |sim| {
                cores2.run(sim, cpu_g, move |sim| {
                    {
                        let mut w = fs3.w.borrow_mut();
                        let gw_inst = w.gw_inst;
                        w.service_done(gw_inst);
                        w.completed += 1;
                    }
                    sim.after(wire, move |sim| {
                        let mut t = t;
                        t.done = sim.now();
                        done(sim, t);
                    });
                });
            });
        });
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faas::RuntimeKind;
    use crate::simcore::{MICROS, MILLIS};

    fn cfg(backend: Backend) -> ExperimentConfig {
        ExperimentConfig { backend, ..Default::default() }
    }

    fn run_n(backend: Backend, n: usize) -> Vec<RequestTiming> {
        let mut sim = Sim::new();
        let platform = Rc::new(PlatformConfig::default());
        let fs = FaasSim::new(&cfg(backend), platform);
        fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        // Warm up past the cold start.
        sim.run_until(2 * crate::simcore::SECONDS);
        let out = Rc::new(RefCell::new(Vec::new()));
        for _ in 0..n {
            let out2 = out.clone();
            fs.submit(&mut sim, "aes", move |_, t| out2.borrow_mut().push(t));
        }
        sim.run_to_completion();
        Rc::try_unwrap(out).ok().unwrap().into_inner()
    }

    #[test]
    fn containerd_invocation_completes_with_ordered_timestamps() {
        let ts = run_n(Backend::Containerd, 5);
        assert_eq!(ts.len(), 5);
        for t in ts {
            assert!(t.submit < t.gateway_in);
            assert!(t.gateway_in < t.exec_start);
            assert!(t.exec_start < t.exec_end);
            assert!(t.exec_end < t.done);
        }
    }

    #[test]
    fn junctiond_invocation_completes_with_ordered_timestamps() {
        let ts = run_n(Backend::Junctiond, 5);
        assert_eq!(ts.len(), 5);
        for t in ts {
            assert!(t.exec_start < t.exec_end);
            assert!(t.e2e() > 0);
        }
    }

    #[test]
    fn junction_is_faster_end_to_end() {
        let c: Vec<_> = run_n(Backend::Containerd, 50).iter().map(|t| t.e2e()).collect();
        let j: Vec<_> = run_n(Backend::Junctiond, 50).iter().map(|t| t.e2e()).collect();
        let cm = c.iter().sum::<u64>() / c.len() as u64;
        let jm = j.iter().sum::<u64>() / j.len() as u64;
        assert!(jm < cm, "junction mean {jm} vs containerd {cm}");
    }

    #[test]
    fn exec_window_contains_compute() {
        let cfg_default = ExperimentConfig::default();
        for backend in [Backend::Containerd, Backend::Junctiond] {
            let ts = run_n(backend, 10);
            for t in ts {
                assert!(
                    t.exec() >= cfg_default.function_compute_ns,
                    "{backend:?} exec {} < compute",
                    t.exec()
                );
            }
        }
    }

    #[test]
    fn first_request_pays_cold_start() {
        let mut sim = Sim::new();
        let platform = Rc::new(PlatformConfig::default());
        let fs = FaasSim::new(&cfg(Backend::Containerd), platform.clone());
        fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        let out = Rc::new(RefCell::new(Vec::new()));
        let out2 = out.clone();
        // Submit immediately — before the container is Running.
        fs.submit(&mut sim, "aes", move |_, t| out2.borrow_mut().push(t));
        sim.run_to_completion();
        let t = out.borrow()[0];
        assert!(
            t.e2e() > 100 * MILLIS,
            "cold-start e2e {}µs suspiciously warm",
            t.e2e() / MICROS
        );
    }

    #[test]
    fn provider_cache_hits_after_first_request() {
        let mut sim = Sim::new();
        let platform = Rc::new(PlatformConfig::default());
        let fs = FaasSim::new(&cfg(Backend::Junctiond), platform);
        fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        sim.run_until(crate::simcore::SECONDS);
        for _ in 0..10 {
            fs.submit(&mut sim, "aes", |_, _| {});
        }
        sim.run_to_completion();
        let (hits, misses) = fs.provider_stats();
        assert_eq!(misses, 1);
        assert_eq!(hits, 9);
    }

    #[test]
    fn deterministic_across_runs() {
        let a: Vec<_> = run_n(Backend::Containerd, 20).iter().map(|t| t.e2e()).collect();
        let b: Vec<_> = run_n(Backend::Containerd, 20).iter().map(|t| t.e2e()).collect();
        assert_eq!(a, b);
    }
}
