//! Sharded cluster: the message-passing twin of [`super::Cluster`] that
//! runs on the parallel shard runner (`simcore::shard`, DESIGN.md §3j).
//!
//! Topology: one **gateway** endpoint plus one endpoint per **worker
//! rack**, each rack hosting a full [`FaasSim`] pipeline (NIC rings,
//! scheduler, compute fabric, pools) *and* the rack-local slice of the
//! open-loop client population. Everything between endpoints travels as
//! timestamped [`WireMsg`]s over the shard runner's wire seam — there is
//! no shared mutable state across endpoints, which is exactly what makes
//! the results invariant under the shard count:
//!
//! * arrivals are per-rack Poisson substreams split from the root seed by
//!   **worker id** (never shard id),
//! * the worker count is a model constant independent of `--shards N`,
//! * every handler touches only its destination endpoint's state, and
//! * per-source wire seqs make the merge order packing-independent.
//!
//! Flow per invocation: rack client stages `Submit` → gateway routes
//! least-in-flight (ties to the lowest worker id) and stages `Invoke` →
//! the rack's `FaasSim` runs the full invocation pipeline → the done
//! callback stages `Response` → the gateway settles the in-flight gauge
//! and records the end-to-end latency. The gateway-observed e2e therefore
//! pays two cross-rack wire hops on top of the in-rack pipeline.

use std::cell::RefCell;
use std::rc::Rc;

use crate::config::{Backend, ExperimentConfig, PlatformConfig};
use crate::invariants::audit_all;
use crate::simcore::{
    run_sharded, EndpointId, NetHandle, Rng, ShardNet, ShardPlan, ShardRun, ShardStats,
    ShardWorld, Sim, Time, WireMsg, SECONDS,
};
use crate::telemetry::Samples;
use crate::workload::population;

use super::pipeline::{FaasSim, RequestTiming};
use super::registry::{FunctionSpec, RuntimeKind};

/// The gateway's fixed endpoint id; workers are `1 + worker_id`.
const GATEWAY: EndpointId = 0;

/// Clients start after the deploy-time cold-start storm has settled
/// (mirrors E12's warm-up `run_until` before the open loop).
const CLIENT_START: Time = SECONDS;

/// Every payload crossing a shard boundary in the sharded cluster. Plain
/// `Copy` data — handles never ride the wire.
#[derive(Debug, Clone, Copy)]
pub enum ClusterMsg {
    /// Rack client → gateway: one open-loop arrival.
    Submit { function: u32, submitted_at: Time },
    /// Gateway → worker rack: routed invocation.
    Invoke { function: u32, submitted_at: Time },
    /// Worker rack → gateway: the pipeline's resolution (completed,
    /// dropped, or timed out — exactly one per `Invoke`).
    Response { timing: RequestTiming, submitted_at: Time },
}

/// Shape of one sharded-cluster run.
#[derive(Debug, Clone)]
pub struct ShardClusterCfg {
    pub backend: Backend,
    /// Engine shards. `1` hosts every endpoint on one shard — through the
    /// identical message path — and is the serial-equality baseline.
    pub shards: usize,
    /// One OS thread per shard, or the single-threaded transport (same
    /// protocol, byte-identical output).
    pub threaded: bool,
    /// Worker racks — a model constant, deliberately independent of
    /// `shards` so results stay comparable across shard counts.
    pub workers: usize,
    pub worker_cores: usize,
    /// Registered population (hot Zipf head + idle tail).
    pub functions: u64,
    pub hot_functions: usize,
    /// Aggregate open-loop arrival rate, split evenly across racks.
    pub rate_rps: f64,
    /// Measurement window; warm-up is an extra `duration / 10` up front.
    pub duration: Time,
    pub seed: u64,
}

impl ShardClusterCfg {
    /// Endpoint placement: everything on shard 0 when `shards == 1`;
    /// otherwise the gateway gets shard 0 to itself and racks round-robin
    /// over the rest.
    fn endpoint_shard(&self) -> Vec<usize> {
        let n = self.shards.max(1);
        (0..=self.workers)
            .map(|e| if n == 1 || e == 0 { 0 } else { 1 + (e - 1) % (n - 1) })
            .collect()
    }
}

/// Merged deterministic output of [`run_shard_cluster`], plus host-side
/// shard telemetry (never printed into byte-diffed tables).
pub struct ShardClusterOut {
    pub gateway: GatewayTotals,
    /// Per-worker pipeline totals, sorted by worker id.
    pub workers: Vec<WorkerTotals>,
    /// Per-shard runner telemetry (epochs, messages, wall clock).
    pub shard_stats: Vec<ShardStats>,
    /// Engine events fired, summed over shards.
    pub events_fired: u64,
    /// Per-worker `audit_all` findings plus merged cross-shard
    /// conservation checks; empty means every law held.
    pub audit_violations: Vec<String>,
}

/// Gateway-side counters and latency samples.
#[derive(Debug, Clone, Default)]
pub struct GatewayTotals {
    pub submitted: u64,
    pub completed: u64,
    pub dropped: u64,
    pub timed_out: u64,
    pub failed: u64,
    pub completed_in_window: u64,
    /// Gateway-observed end-to-end latency (client stage → response back
    /// at the gateway), post-warm-up arrivals only.
    pub e2e: Samples,
    /// Function execution window, post-warm-up arrivals only.
    pub exec: Samples,
}

/// One worker rack's pipeline totals, with its conservation audit.
#[derive(Debug, Clone)]
pub struct WorkerTotals {
    pub worker: usize,
    pub completed: u64,
    pub dropped: u64,
    pub audit_violations: Vec<String>,
}

/// Gateway state: the in-flight gauge routing reads and the result
/// ledger. Lives entirely on the gateway's shard.
struct GatewayCore {
    in_flight: Vec<u32>,
    submitted: u64,
    completed: u64,
    dropped: u64,
    timed_out: u64,
    failed: u64,
    completed_in_window: u64,
    e2e: Samples,
    exec: Samples,
    measure_from: Time,
    measure_until: Time,
}

impl GatewayCore {
    fn new(workers: usize, measure_from: Time, measure_until: Time) -> Self {
        GatewayCore {
            in_flight: vec![0; workers],
            submitted: 0,
            completed: 0,
            dropped: 0,
            timed_out: 0,
            failed: 0,
            completed_in_window: 0,
            e2e: Samples::new(),
            exec: Samples::new(),
            measure_from,
            measure_until,
        }
    }
}

/// One rack hosted on this shard: its endpoint and its full pipeline.
struct WorkerNode {
    endpoint: EndpointId,
    faas: FaasSim,
}

/// Everything one shard hosts: at most the gateway, plus the racks the
/// plan assigned here. Built on the shard's own thread.
pub struct ShardHost {
    net: Rc<RefCell<ShardNet<ClusterMsg>>>,
    gateway: Option<Rc<RefCell<GatewayCore>>>,
    workers: Vec<WorkerNode>,
    names: Rc<Vec<String>>,
}

/// What one shard reports back (crosses the thread boundary: plain data).
pub struct HostReport {
    gateway: Option<(GatewayTotals, Vec<String>)>,
    workers: Vec<WorkerTotals>,
}

fn gateway_on_submit(
    core: &Rc<RefCell<GatewayCore>>,
    net: &Rc<RefCell<ShardNet<ClusterMsg>>>,
    sim: &mut Sim,
    function: u32,
    submitted_at: Time,
) {
    let worker = {
        let mut g = core.borrow_mut();
        g.submitted += 1;
        // Least in-flight, ties to the lowest worker id: deterministic
        // and shard-count-independent (the gauge is gateway-local state).
        let mut best = 0usize;
        for (w, &n) in g.in_flight.iter().enumerate() {
            if n < g.in_flight[best] {
                best = w;
            }
        }
        g.in_flight[best] += 1;
        best
    };
    let dst = 1 + worker as EndpointId;
    net.borrow_mut().send(sim.now(), GATEWAY, dst, ClusterMsg::Invoke { function, submitted_at });
}

fn gateway_on_response(
    core: &Rc<RefCell<GatewayCore>>,
    sim: &mut Sim,
    worker: usize,
    timing: RequestTiming,
    submitted_at: Time,
) {
    let mut g = core.borrow_mut();
    debug_assert!(g.in_flight[worker] > 0, "response from a worker with nothing in flight");
    g.in_flight[worker] -= 1;
    let now = sim.now();
    if timing.timed_out {
        g.timed_out += 1;
    } else if timing.dropped {
        g.dropped += 1;
        if timing.failed {
            g.failed += 1;
        }
    } else {
        g.completed += 1;
        if submitted_at >= g.measure_from {
            if submitted_at < g.measure_until && now <= g.measure_until {
                g.completed_in_window += 1;
            }
            g.e2e.record(now - submitted_at);
            g.exec.record(timing.exec_end - timing.exec_start);
        }
    }
}

/// One rack's open-loop client: a Poisson substream seeded by worker id,
/// picking from the shared Zipf CDF, staging `Submit`s to the gateway.
struct RackClient {
    rng: Rng,
    t: f64,
    gap_ns: f64,
    until: Time,
    me: EndpointId,
    cdf: Rc<Vec<f64>>,
    net: NetHandle<ClusterMsg>,
}

fn arm_client(mut c: RackClient, sim: &mut Sim) {
    c.t += c.rng.exp(c.gap_ns);
    let at = c.t as Time;
    if at >= c.until {
        return;
    }
    let x = c.rng.next_f64();
    let function = c.cdf.partition_point(|&cum| cum < x).min(c.cdf.len() - 1) as u32;
    sim.at(at, move |sim| {
        c.net.borrow_mut().send(
            sim.now(),
            c.me,
            GATEWAY,
            ClusterMsg::Submit { function, submitted_at: sim.now() },
        );
        arm_client(c, sim);
    });
}

/// The shared hot population: names plus the arrival-pick CDF. Pure
/// function of `(hot_functions, seed)`, so every shard derives the
/// identical table locally — nothing to ship across threads.
fn hot_population(cfg: &ShardClusterCfg) -> (Vec<String>, Vec<f64>) {
    let mut rng = Rng::new(cfg.seed ^ 0xD57);
    let pop = population(cfg.hot_functions, &mut rng);
    let names = pop.iter().map(|(n, _)| n.clone()).collect();
    let mut acc = 0.0;
    let cdf = pop
        .iter()
        .map(|(_, w)| {
            acc += w;
            acc
        })
        .collect();
    (names, cdf)
}

fn build_host(
    shard: usize,
    cfg: &ShardClusterCfg,
    endpoint_shard: &[usize],
    platform: &PlatformConfig,
    sim: &mut Sim,
    net: NetHandle<ClusterMsg>,
) -> ShardHost {
    let (names, cdf) = hot_population(cfg);
    let names = Rc::new(names);
    let cdf = Rc::new(cdf);
    let warmup = cfg.duration / 10;
    let measure_from = CLIENT_START + warmup;
    let measure_until = measure_from + cfg.duration;
    let mut host = ShardHost {
        net: net.clone(),
        gateway: None,
        workers: Vec::new(),
        names: names.clone(),
    };
    if endpoint_shard[GATEWAY as usize] == shard {
        host.gateway =
            Some(Rc::new(RefCell::new(GatewayCore::new(cfg.workers, measure_from, measure_until))));
    }
    for w in 0..cfg.workers {
        let endpoint = 1 + w as EndpointId;
        if endpoint_shard[endpoint as usize] != shard {
            continue;
        }
        let ecfg = ExperimentConfig {
            backend: cfg.backend,
            provider_cache: true,
            worker_cores: cfg.worker_cores,
            // The same per-worker seed split the serial Cluster uses.
            seed: cfg.seed.wrapping_add(w as u64 * 7919),
            function_compute_ns: platform.function_compute_ns,
            instance_concurrency: 4,
        };
        let faas = FaasSim::new(&ecfg, Rc::new(platform.clone()));
        // The Zipf head is pre-deployed on every rack (E12's pre-scale:
        // the experiment measures the engine, not autoscaler lag)...
        for name in names.iter() {
            faas.deploy(sim, FunctionSpec::new(name, "aes600", RuntimeKind::Go));
        }
        // ...and the idle tail is striped across racks: registered,
        // deployed once, never invoked.
        let mut i = cfg.hot_functions as u64 + w as u64;
        while i < cfg.functions {
            let cold = format!("cold-{i:07}");
            faas.deploy(sim, FunctionSpec::new(&cold, "aes600", RuntimeKind::Python));
            i += cfg.workers as u64;
        }
        // This rack's slice of the open-loop arrival stream, seeded by
        // worker id so the stream set is invariant under resharding.
        let client = RackClient {
            rng: Rng::new(cfg.seed ^ 0xC11E47 ^ (w as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            t: CLIENT_START as f64,
            gap_ns: 1e9 * cfg.workers as f64 / cfg.rate_rps,
            until: measure_until,
            me: endpoint,
            cdf: cdf.clone(),
            net: net.clone(),
        };
        arm_client(client, sim);
        host.workers.push(WorkerNode { endpoint, faas });
    }
    host
}

impl ShardHost {
    fn worker(&self, endpoint: EndpointId) -> &WorkerNode {
        self.workers
            .iter()
            .find(|w| w.endpoint == endpoint)
            .expect("message routed to a shard not hosting its endpoint")
    }
}

impl ShardWorld<ClusterMsg> for ShardHost {
    type Report = HostReport;

    fn inject(&mut self, sim: &mut Sim, m: WireMsg<ClusterMsg>) {
        match m.payload {
            ClusterMsg::Submit { function, submitted_at } => {
                let core = self.gateway.clone().expect("Submit routed off the gateway shard");
                let net = self.net.clone();
                sim.at(m.deliver_at, move |sim| {
                    gateway_on_submit(&core, &net, sim, function, submitted_at);
                });
            }
            ClusterMsg::Invoke { function, submitted_at } => {
                let node = self.worker(m.dst);
                let faas = node.faas.clone();
                let net = self.net.clone();
                let name = self.names[function as usize].clone();
                let me = m.dst;
                sim.at(m.deliver_at, move |sim| {
                    faas.submit(sim, &name, move |sim: &mut Sim, timing: RequestTiming| {
                        let msg = ClusterMsg::Response { timing, submitted_at };
                        net.borrow_mut().send(sim.now(), me, GATEWAY, msg);
                    });
                });
            }
            ClusterMsg::Response { timing, submitted_at } => {
                let core = self.gateway.clone().expect("Response routed off the gateway shard");
                let worker = (m.src - 1) as usize;
                sim.at(m.deliver_at, move |sim| {
                    gateway_on_response(&core, sim, worker, timing, submitted_at);
                });
            }
        }
    }

    fn finish(self, _sim: &mut Sim) -> HostReport {
        let gateway = self.gateway.map(|core| {
            let g = core.borrow();
            let mut violations = Vec::new();
            for (w, &n) in g.in_flight.iter().enumerate() {
                if n != 0 {
                    violations.push(format!(
                        "[faas/shardcluster] in-flight-drained: worker {w} still holds {n}"
                    ));
                }
            }
            if g.submitted != g.completed + g.dropped + g.timed_out {
                violations.push(format!(
                    "[faas/shardcluster] request-conservation: submitted {} != completed {} + \
                     dropped {} + timed_out {}",
                    g.submitted, g.completed, g.dropped, g.timed_out
                ));
            }
            let totals = GatewayTotals {
                submitted: g.submitted,
                completed: g.completed,
                dropped: g.dropped,
                timed_out: g.timed_out,
                failed: g.failed,
                completed_in_window: g.completed_in_window,
                e2e: g.e2e.clone(),
                exec: g.exec.clone(),
            };
            (totals, violations)
        });
        let workers = self
            .workers
            .iter()
            .map(|w| WorkerTotals {
                worker: (w.endpoint - 1) as usize,
                completed: w.faas.completed(),
                dropped: w.faas.dropped(),
                audit_violations: audit_all(&w.faas).iter().map(|v| v.to_string()).collect(),
            })
            .collect();
        HostReport { gateway, workers }
    }
}

/// Run one sharded-cluster workload under the conservative shard runner
/// and merge the per-shard reports. Deterministic fields of the result
/// are byte-identical across `shards` ∈ {1, 2, 4, 8}, across repeated
/// same-seed runs, and across the serial/threaded transports.
pub fn run_shard_cluster(cfg: &ShardClusterCfg) -> ShardClusterOut {
    assert!(cfg.workers > 0 && cfg.hot_functions > 0, "need at least one worker and function");
    assert!(cfg.hot_functions as u64 <= cfg.functions, "hot set larger than the population");
    let platform = PlatformConfig::default();
    let endpoint_shard = cfg.endpoint_shard();
    let plan = ShardPlan {
        shards: cfg.shards.max(1),
        endpoint_shard: endpoint_shard.clone(),
        wire_ns: platform.shard_wire_ns,
    };
    type HostBuilder = Box<dyn FnOnce(&mut Sim, NetHandle<ClusterMsg>) -> ShardHost + Send>;
    let builders: Vec<HostBuilder> = (0..plan.shards)
        .map(|s| {
            let cfg = cfg.clone();
            let map = endpoint_shard.clone();
            let platform = platform.clone();
            Box::new(move |sim: &mut Sim, net: NetHandle<ClusterMsg>| {
                build_host(s, &cfg, &map, &platform, sim, net)
            }) as HostBuilder
        })
        .collect();
    let run: ShardRun<HostReport> = run_sharded(&plan, builders, cfg.threaded);
    let events_fired = run.stats.iter().map(|s| s.events_fired).sum();
    let mut gateway = None;
    let mut workers: Vec<WorkerTotals> = Vec::new();
    let mut audit_violations = Vec::new();
    for report in run.reports {
        if let Some((totals, mut viol)) = report.gateway {
            gateway = Some(totals);
            audit_violations.append(&mut viol);
        }
        workers.extend(report.workers);
    }
    workers.sort_by_key(|w| w.worker);
    let gateway = gateway.expect("the plan always places the gateway");
    for w in &workers {
        audit_violations.extend(w.audit_violations.iter().cloned());
    }
    // Merged cross-shard conservation: what the racks resolved must be
    // exactly what the gateway settled.
    let rack_completed: u64 = workers.iter().map(|w| w.completed).sum();
    if rack_completed != gateway.completed {
        audit_violations.push(format!(
            "[faas/shardcluster] merged-conservation: racks completed {} but the gateway \
             settled {}",
            rack_completed, gateway.completed
        ));
    }
    for s in &run.stats {
        if s.past_schedules != 0 {
            audit_violations.push(format!(
                "[simcore/shard] lookahead: shard {} clamped {} past schedules",
                s.shard, s.past_schedules
            ));
        }
    }
    ShardClusterOut {
        gateway,
        workers,
        shard_stats: run.stats,
        events_fired,
        audit_violations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::MILLIS;

    fn tiny(shards: usize, threaded: bool) -> ShardClusterOut {
        run_shard_cluster(&ShardClusterCfg {
            backend: Backend::Junctiond,
            shards,
            threaded,
            workers: 4,
            worker_cores: 8,
            functions: 64,
            hot_functions: 16,
            rate_rps: 4_000.0,
            duration: 50 * MILLIS,
            seed: 11,
        })
    }

    fn fingerprint(out: &mut ShardClusterOut) -> Vec<u64> {
        let g = &mut out.gateway;
        let mut v = vec![
            g.submitted,
            g.completed,
            g.dropped,
            g.timed_out,
            g.completed_in_window,
            g.e2e.quantile(0.5),
            g.e2e.quantile(0.99),
            g.exec.quantile(0.99),
        ];
        v.extend(out.workers.iter().map(|w| w.completed));
        v
    }

    #[test]
    fn audits_are_clean_and_requests_conserved() {
        let out = tiny(2, false);
        assert!(out.audit_violations.is_empty(), "violations: {:?}", out.audit_violations);
        assert!(out.gateway.submitted > 50, "workload too small to mean anything");
        assert_eq!(
            out.gateway.submitted,
            out.gateway.completed + out.gateway.dropped + out.gateway.timed_out
        );
    }

    #[test]
    fn output_is_invariant_across_shard_counts() {
        let mut base = tiny(1, false);
        let want = fingerprint(&mut base);
        for shards in [2, 3, 4] {
            let mut out = tiny(shards, false);
            assert_eq!(fingerprint(&mut out), want, "diverged at {shards} shards");
        }
    }

    #[test]
    fn threaded_transport_matches_serial() {
        let mut serial = tiny(4, false);
        let mut threaded = tiny(4, true);
        assert_eq!(fingerprint(&mut serial), fingerprint(&mut threaded));
    }
}
