//! containerd baseline backend (paper §2.1.1).
//!
//! Models the mainline-faasd execution path: functions run as Linux
//! containers deployed by containerd, orchestration services run as host
//! processes, and *everything* traverses the kernel network stack. The
//! pieces that matter for the evaluation:
//!
//! * **Container lifecycle** — create/start/pause/remove with a cold-start
//!   cost in the hundreds of milliseconds (image present; no pull).
//! * **containerd API latency** — the provider's state queries go to
//!   containerd over gRPC and "can be slower than the function invocation
//!   itself" (§4), which is why the provider cache exists.
//! * **Per-container kernel networking** — every message into a container
//!   additionally crosses a veth/bridge pair (software switching).

mod lifecycle;

pub use lifecycle::{Container, ContainerId, ContainerState, Containerd};
