//! Container lifecycle state machine + containerd API cost model.

use std::collections::BTreeMap;
use std::rc::Rc;

use crate::config::PlatformConfig;
use crate::simcore::{Rng, Time};

pub type ContainerId = u32;

/// containerd task states (subset faasd uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContainerState {
    Creating,
    Running,
    Paused,
    Stopped,
}

/// One container (function replica) under containerd.
#[derive(Debug, Clone)]
pub struct Container {
    pub id: ContainerId,
    pub name: String,
    pub state: ContainerState,
    /// Local IP:port faasd's provider resolves to.
    pub addr: (u32, u16),
    /// Virtual time the container becomes Running.
    pub ready_at: Time,
    pub invocations: u64,
}

/// The containerd daemon: container table + API costs.
///
/// API calls model gRPC to the containerd socket *plus* containerd's own
/// work (snapshotter, runc shim spawn for create; task-list scans for
/// state queries). faasd's provider hits `state_query` on every invocation
/// unless the metadata cache (§4) short-circuits it.
pub struct Containerd {
    p: Rc<PlatformConfig>,
    rng: Rng,
    containers: BTreeMap<ContainerId, Container>,
    next_id: ContainerId,
    next_port: u16,
    // telemetry
    pub creates: u64,
    pub state_queries: u64,
    pub restores: u64,
    pub resumes: u64,
}

impl Containerd {
    pub fn new(platform: Rc<PlatformConfig>, rng: Rng) -> Self {
        Containerd {
            p: platform,
            rng,
            containers: BTreeMap::new(),
            next_id: 0,
            next_port: 31000,
            creates: 0,
            state_queries: 0,
            restores: 0,
            resumes: 0,
        }
    }

    /// Create + start a container. Returns (id, cold_start_duration): the
    /// runc shim spawn, rootfs mount, netns + veth setup, and the function
    /// process boot. Cold starts are heavy-tailed in practice (image cache
    /// state, cgroup contention): ±40% spread around the configured cost.
    pub fn create_and_start(&mut self, name: &str, now: Time) -> (ContainerId, Time) {
        self.creates += 1;
        let id = self.next_id;
        self.next_id += 1;
        let base = self.p.container_cold_start_ns;
        let spread = base * 2 / 5;
        let cold = base - spread / 2 + self.rng.below(spread + 1);
        let port = self.next_port;
        self.next_port += 1;
        self.containers.insert(
            id,
            Container {
                id,
                name: name.to_string(),
                state: ContainerState::Creating,
                addr: (0x0A00_0002 + id, port), // 10.0.0.x
                ready_at: now + cold,
                invocations: 0,
            },
        );
        (id, cold)
    }

    /// CRIU-style restore of a checkpointed container (the
    /// snapshot-restore provisioning tier): no runc shim spawn, no rootfs
    /// prep from scratch — pages come back from the checkpoint image at a
    /// cost ≪ cold boot (±10% spread), though still 10–100× the Junction
    /// restore.
    pub fn restore_from_snapshot(
        &mut self,
        name: &str,
        now: Time,
        restore_base_ns: Time,
    ) -> (ContainerId, Time) {
        self.restores += 1;
        let id = self.next_id;
        self.next_id += 1;
        let spread = restore_base_ns / 5;
        let restore = restore_base_ns - spread / 2 + self.rng.below(spread + 1);
        let port = self.next_port;
        self.next_port += 1;
        self.containers.insert(
            id,
            Container {
                id,
                name: name.to_string(),
                state: ContainerState::Creating,
                addr: (0x0A00_0002 + id, port),
                ready_at: now + restore,
                invocations: 0,
            },
        );
        (id, restore)
    }

    /// Mark a container Running (caller schedules this at `ready_at`).
    /// No-op unless the container is still Creating — a container the pool
    /// stopped or parked in the meantime keeps its state.
    pub fn mark_running(&mut self, id: ContainerId) {
        let c = self.containers.get_mut(&id).expect("unknown container");
        if c.state == ContainerState::Creating {
            c.state = ContainerState::Running;
        }
    }

    pub fn pause(&mut self, id: ContainerId) {
        let c = self.containers.get_mut(&id).expect("unknown container");
        assert_eq!(c.state, ContainerState::Running);
        c.state = ContainerState::Paused;
    }

    pub fn resume(&mut self, id: ContainerId) {
        let c = self.containers.get_mut(&id).expect("unknown container");
        assert_eq!(c.state, ContainerState::Paused);
        c.state = ContainerState::Running;
        self.resumes += 1;
    }

    pub fn stop(&mut self, id: ContainerId) {
        let c = self.containers.get_mut(&id).expect("unknown container");
        c.state = ContainerState::Stopped;
    }

    pub fn get(&self, id: ContainerId) -> Option<&Container> {
        self.containers.get(&id)
    }

    pub fn get_mut(&mut self, id: ContainerId) -> Option<&mut Container> {
        self.containers.get_mut(&id)
    }

    pub fn running_count(&self) -> usize {
        self.containers.values().filter(|c| c.state == ContainerState::Running).count()
    }

    /// Latency of a provider→containerd state query (replica count, task
    /// IP). The paper (§4): "requests to containerd can be slower than the
    /// function invocation itself and can be on the critical path". Cost
    /// scales mildly with table size (task-list scan) and carries jitter.
    pub fn state_query(&mut self) -> Time {
        self.state_queries += 1;
        let base = self.p.provider_state_query_ns;
        let scan = (self.containers.len() as Time) * 500; // per-entry scan cost
        let jitter = self.rng.below(base / 2 + 1);
        base + scan + jitter
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::MILLIS;

    fn daemon() -> Containerd {
        Containerd::new(Rc::new(PlatformConfig::default()), Rng::new(21))
    }

    #[test]
    fn create_start_lifecycle() {
        let mut d = daemon();
        let (id, cold) = d.create_and_start("fn-aes", 0);
        assert!(cold > 100 * MILLIS, "cold start {cold}ns implausibly fast");
        assert_eq!(d.get(id).unwrap().state, ContainerState::Creating);
        d.mark_running(id);
        assert_eq!(d.get(id).unwrap().state, ContainerState::Running);
        assert_eq!(d.running_count(), 1);
    }

    #[test]
    fn pause_resume_stop() {
        let mut d = daemon();
        let (id, _) = d.create_and_start("fn", 0);
        d.mark_running(id);
        d.pause(id);
        assert_eq!(d.get(id).unwrap().state, ContainerState::Paused);
        d.resume(id);
        d.stop(id);
        assert_eq!(d.get(id).unwrap().state, ContainerState::Stopped);
        assert_eq!(d.running_count(), 0);
    }

    #[test]
    fn unique_addresses_assigned() {
        let mut d = daemon();
        let (a, _) = d.create_and_start("f1", 0);
        let (b, _) = d.create_and_start("f2", 0);
        assert_ne!(d.get(a).unwrap().addr, d.get(b).unwrap().addr);
    }

    #[test]
    fn state_query_is_slower_than_typical_invocation() {
        let mut d = daemon();
        d.create_and_start("f", 0);
        // The paper's motivation for the provider cache: containerd round
        // trips dwarf the ~100µs function invocation.
        let q = d.state_query();
        assert!(q > 500 * crate::simcore::MICROS, "state query {q}ns");
        assert_eq!(d.state_queries, 1);
    }

    #[test]
    fn snapshot_restore_is_cheaper_than_cold_boot() {
        let mut d = daemon();
        let p = PlatformConfig::default();
        let (_, cold) = d.create_and_start("fn", 0);
        let (id, restore) = d.restore_from_snapshot("fn", 0, p.container_restore_ns);
        assert!(restore * 2 < cold, "restore {restore} should be ≪ cold {cold}");
        assert!(restore >= p.container_restore_ns - p.container_restore_ns / 10);
        assert!(restore <= p.container_restore_ns + p.container_restore_ns / 10);
        assert_eq!(d.get(id).unwrap().state, ContainerState::Creating);
        d.mark_running(id);
        assert_eq!(d.restores, 1);
        assert_eq!(d.running_count(), 1);
    }

    #[test]
    fn mark_running_does_not_revive_stopped() {
        let mut d = daemon();
        let (id, _) = d.create_and_start("fn", 0);
        d.stop(id);
        d.mark_running(id);
        assert_eq!(d.get(id).unwrap().state, ContainerState::Stopped);
    }

    #[test]
    fn cold_start_spread_is_bounded() {
        let mut d = daemon();
        let base = PlatformConfig::default().container_cold_start_ns;
        for i in 0..200 {
            let (_, cold) = d.create_and_start(&format!("f{i}"), 0);
            assert!(cold >= base - base * 2 / 5);
            assert!(cold <= base + base * 2 / 5);
        }
    }
}
