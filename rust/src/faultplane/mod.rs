//! Deterministic fault-injection plane.
//!
//! A [`FaultSchedule`] is a list of `(virtual time, fault)` pairs built
//! ahead of a run and installed onto the simcore engine with
//! [`install`]. Every fault is an ordinary scheduled event — no host
//! entropy, no host clock — so the same schedule against the same seed
//! replays bit-identically, and an *empty* schedule leaves the run
//! byte-identical to a build without this module.
//!
//! Four fault kinds cover the failure modes the paper's restart-cost
//! story cares about:
//!
//! * **Worker crash** — every instance on the worker dies mid-run; the
//!   warm pool is wiped (it lived in the worker's memory) and every
//!   hosted function re-provisions through the tier ladder. The on-disk
//!   snapshot survives, so recovery pays a restore, not a cold boot —
//!   the kernel-vs-bypass asymmetry E16 quantifies.
//! * **Instance crash** — one function's instances on one worker die;
//!   same recovery path, scoped to a single function.
//! * **Gray failure** — a worker's service times degrade by a factor
//!   without anything dying. Nothing fails, nothing ejects; only
//!   deadline/hedging machinery can defend the p99.
//! * **Wire loss** — for a window, each cluster submission is lost on
//!   the wire with probability `loss_bp`/10 000 (drawn from the
//!   cluster's own seeded fault stream). Requires the deadline/retry
//!   machinery to be on, which guarantees every lost request still
//!   resolves.
//!
//! [`FaultStats`] counts what was actually injected and carries its own
//! conservation law, so `audit_all` covers the fault plane itself.

use std::cell::RefCell;
use std::rc::Rc;

use crate::faas::Cluster;
use crate::invariants::{check, Audit, Violation};
use crate::simcore::{Sim, Time};

/// One injectable fault.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultKind {
    /// Kill every instance on worker `worker`; wipe its warm pool;
    /// re-provision every hosted function through the tier ladder.
    WorkerCrash { worker: usize },
    /// Kill `function`'s instances on worker `worker` mid-invocation.
    InstanceCrash { worker: usize, function: String },
    /// Degrade worker `worker`'s service times to `factor_x100`/100 of
    /// nominal for `duration` (e.g. 800 = 8× slower), then recover.
    Gray { worker: usize, factor_x100: u64, duration: Time },
    /// For `duration`, lose each cluster submission on the wire with
    /// probability `loss_bp`/10 000.
    WireLoss { loss_bp: u64, duration: Time },
}

/// A fault at a virtual-clock instant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultEvent {
    pub at: Time,
    pub kind: FaultKind,
}

/// A seeded, pre-built fault schedule. Built with the fluent
/// constructors below; installed once with [`install`].
#[derive(Debug, Clone, Default)]
pub struct FaultSchedule {
    events: Vec<FaultEvent>,
}

impl FaultSchedule {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn worker_crash(mut self, at: Time, worker: usize) -> Self {
        self.events.push(FaultEvent { at, kind: FaultKind::WorkerCrash { worker } });
        self
    }

    pub fn instance_crash(mut self, at: Time, worker: usize, function: &str) -> Self {
        self.events.push(FaultEvent {
            at,
            kind: FaultKind::InstanceCrash { worker, function: function.to_string() },
        });
        self
    }

    pub fn gray(mut self, at: Time, worker: usize, factor_x100: u64, duration: Time) -> Self {
        self.events.push(FaultEvent { at, kind: FaultKind::Gray { worker, factor_x100, duration } });
        self
    }

    pub fn wire_loss(mut self, at: Time, loss_bp: u64, duration: Time) -> Self {
        self.events.push(FaultEvent { at, kind: FaultKind::WireLoss { loss_bp, duration } });
        self
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// What the fault plane actually injected, with the worst recovery
/// latency any crash paid through the tier ladder.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Total fault events fired.
    pub injected: u64,
    pub worker_crashes: u64,
    pub instance_crashes: u64,
    pub gray_onsets: u64,
    pub wire_loss_windows: u64,
    /// Worst re-provision latency a crash paid (restore or cold boot).
    pub worst_recovery_ns: Time,
}

impl Audit for FaultStats {
    fn module(&self) -> &'static str {
        "faultplane"
    }

    fn audit_into(&self, out: &mut Vec<Violation>) {
        let m = self.module();
        let kinds = self.worker_crashes
            + self.instance_crashes
            + self.gray_onsets
            + self.wire_loss_windows;
        check(out, m, "injection-conservation", self.injected == kinds, || {
            format!(
                "injected {} != worker {} + instance {} + gray {} + wire {}",
                self.injected,
                self.worker_crashes,
                self.instance_crashes,
                self.gray_onsets,
                self.wire_loss_windows
            )
        });
    }
}

/// Install every event of `schedule` onto `sim` against `cluster`.
/// Returns the shared stats cell; read it after the run for the audit
/// and for recovery-latency telemetry.
pub fn install(
    schedule: FaultSchedule,
    sim: &mut Sim,
    cluster: &Rc<RefCell<Cluster>>,
) -> Rc<RefCell<FaultStats>> {
    let stats = Rc::new(RefCell::new(FaultStats::default()));
    for ev in schedule.events {
        let cluster = cluster.clone();
        let stats = stats.clone();
        let kind = ev.kind;
        sim.at(ev.at, move |sim| {
            let recovery = match kind {
                FaultKind::WorkerCrash { worker } => {
                    stats.borrow_mut().worker_crashes += 1;
                    cluster.borrow_mut().crash_worker(sim, worker)
                }
                FaultKind::InstanceCrash { worker, function } => {
                    stats.borrow_mut().instance_crashes += 1;
                    cluster.borrow_mut().crash_instance(sim, worker, &function)
                }
                FaultKind::Gray { worker, factor_x100, duration } => {
                    stats.borrow_mut().gray_onsets += 1;
                    cluster.borrow_mut().set_gray(sim, worker, factor_x100, duration);
                    0
                }
                FaultKind::WireLoss { loss_bp, duration } => {
                    stats.borrow_mut().wire_loss_windows += 1;
                    cluster.borrow_mut().set_wire_loss(sim, loss_bp, duration);
                    0
                }
            };
            let mut st = stats.borrow_mut();
            st.injected += 1;
            st.worst_recovery_ns = st.worst_recovery_ns.max(recovery);
        });
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::MILLIS;

    #[test]
    fn schedule_builder_accumulates_in_order() {
        let s = FaultSchedule::new()
            .worker_crash(MILLIS, 0)
            .instance_crash(2 * MILLIS, 1, "aes")
            .gray(3 * MILLIS, 0, 800, 5 * MILLIS)
            .wire_loss(4 * MILLIS, 500, 2 * MILLIS);
        assert_eq!(s.len(), 4);
        assert!(!s.is_empty());
        assert_eq!(s.events()[0].at, MILLIS);
        assert_eq!(
            s.events()[1].kind,
            FaultKind::InstanceCrash { worker: 1, function: "aes".to_string() }
        );
    }

    #[test]
    fn stats_conservation_law_catches_mismatch() {
        let mut ok = FaultStats { injected: 2, worker_crashes: 1, gray_onsets: 1, ..Default::default() };
        let mut out = Vec::new();
        ok.audit_into(&mut out);
        assert!(out.is_empty(), "{out:?}");
        ok.injected = 3;
        ok.audit_into(&mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, "injection-conservation");
    }
}
