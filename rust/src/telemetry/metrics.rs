//! Metrics registry with Prometheus-style text exposition.
//!
//! The paper's junctiond artifact lives on a branch named
//! `junction_manager_prometheus` — the real system exports Prometheus
//! metrics. This registry provides the same operational surface: counters,
//! gauges, and latency histograms, rendered in the Prometheus text format
//! (v0.0.4), pull-able from the real-mode server and dumpable from the
//! simulator.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::LogHistogram;

/// A single metric family's data.
enum Metric {
    Counter(u64),
    Gauge(f64),
    Histogram(LogHistogram),
}

/// Label-set → metric value, under one family name.
pub struct Registry {
    /// (family, help) → labels-string → metric
    families: BTreeMap<String, (String, BTreeMap<String, Metric>)>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

/// Escape a HELP string per the v0.0.4 text format: `\` and newline.
fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

/// Escape a label value per the v0.0.4 text format: `\`, `"`, newline.
fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Counters are exposed with the conventional `_total` suffix whether or
/// not the registration name carried it.
fn counter_exposed_name(name: &str) -> String {
    if name.ends_with("_total") {
        name.to_string()
    } else {
        format!("{name}_total")
    }
}

fn label_key(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    format!("{{{}}}", parts.join(","))
}

/// Splice one extra `key="value"` pair into a rendered label set
/// (`""` or `{a="b",...}`). `v` must already be escaped.
fn splice_label(labels: &str, k: &str, v: &str) -> String {
    if labels.is_empty() {
        format!("{{{k}=\"{v}\"}}")
    } else {
        let inner = &labels[1..labels.len() - 1];
        format!("{{{inner},{k}=\"{v}\"}}")
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry { families: BTreeMap::new() }
    }

    fn family(&mut self, name: &str, help: &str) -> &mut BTreeMap<String, Metric> {
        &mut self
            .families
            .entry(name.to_string())
            .or_insert_with(|| (help.to_string(), BTreeMap::new()))
            .1
    }

    /// Increment a counter by `v`.
    pub fn counter_add(&mut self, name: &str, help: &str, labels: &[(&str, &str)], v: u64) {
        let key = label_key(labels);
        match self.family(name, help).entry(key).or_insert(Metric::Counter(0)) {
            Metric::Counter(c) => *c += v,
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    /// Set a gauge.
    pub fn gauge_set(&mut self, name: &str, help: &str, labels: &[(&str, &str)], v: f64) {
        let key = label_key(labels);
        let slot = self.family(name, help).entry(key).or_insert(Metric::Gauge(0.0));
        match slot {
            Metric::Gauge(g) => *g = v,
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    /// Record a latency observation (ns) into a histogram metric.
    pub fn observe(&mut self, name: &str, help: &str, labels: &[(&str, &str)], ns: u64) {
        let key = label_key(labels);
        let slot =
            self.family(name, help).entry(key).or_insert_with(|| Metric::Histogram(LogHistogram::new()));
        match slot {
            Metric::Histogram(h) => h.record(ns),
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    pub fn counter_value(&self, name: &str, labels: &[(&str, &str)]) -> Option<u64> {
        let (_, fam) = self.families.get(name)?;
        match fam.get(&label_key(labels))? {
            Metric::Counter(c) => Some(*c),
            _ => None,
        }
    }

    pub fn gauge_value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        let (_, fam) = self.families.get(name)?;
        match fam.get(&label_key(labels))? {
            Metric::Gauge(g) => Some(*g),
            _ => None,
        }
    }

    /// Render the Prometheus text exposition format.
    pub fn expose(&self) -> String {
        let mut out = String::new();
        for (name, (help, fam)) in &self.families {
            let kind = match fam.values().next() {
                Some(Metric::Counter(_)) => "counter",
                Some(Metric::Gauge(_)) => "gauge",
                Some(Metric::Histogram(_)) => "histogram",
                None => continue,
            };
            let exposed =
                if kind == "counter" { counter_exposed_name(name) } else { name.clone() };
            let _ = writeln!(out, "# HELP {exposed} {}", escape_help(help));
            let _ = writeln!(out, "# TYPE {exposed} {kind}");
            for (labels, metric) in fam {
                match metric {
                    Metric::Counter(c) => {
                        let _ = writeln!(out, "{exposed}{labels} {c}");
                    }
                    Metric::Gauge(g) => {
                        let _ = writeln!(out, "{exposed}{labels} {g}");
                    }
                    Metric::Histogram(h) => {
                        // Cumulative `le` buckets in seconds: each bucket
                        // counts every observation ≤ its bound, and the
                        // `+Inf` bucket equals `_count`.
                        for (ub, cum) in h.cumulative_buckets() {
                            let le = ub as f64 / 1e9;
                            let lb = splice_label(labels, "le", &format!("{le}"));
                            let _ = writeln!(out, "{exposed}_bucket{lb} {cum}");
                        }
                        let lb = splice_label(labels, "le", "+Inf");
                        let _ = writeln!(out, "{exposed}_bucket{lb} {}", h.count());
                        let _ = writeln!(out, "{exposed}_count{labels} {}", h.count());
                        let _ = writeln!(
                            out,
                            "{exposed}_sum{labels} {}",
                            h.mean() * h.count() as f64 / 1e9
                        );
                    }
                }
            }
        }
        out
    }

    /// Flatten every metric into a scalar `(exposed name, labels, value)`
    /// series: counters (with `_total`), gauges, and histogram observation
    /// counts as `<name>_count`. Feeds the [`super::Timeline`] scraper.
    pub fn scalar_series(&self) -> Vec<(String, String, f64)> {
        let mut out = Vec::new();
        for (name, (_, fam)) in &self.families {
            for (labels, metric) in fam {
                match metric {
                    Metric::Counter(c) => {
                        out.push((counter_exposed_name(name), labels.clone(), *c as f64))
                    }
                    Metric::Gauge(g) => out.push((name.clone(), labels.clone(), *g)),
                    Metric::Histogram(h) => {
                        out.push((format!("{name}_count"), labels.clone(), h.count() as f64))
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_per_label_set() {
        let mut r = Registry::new();
        r.counter_add("invocations_total", "total invocations", &[("backend", "junctiond")], 2);
        r.counter_add("invocations_total", "total invocations", &[("backend", "junctiond")], 3);
        r.counter_add("invocations_total", "total invocations", &[("backend", "containerd")], 1);
        assert_eq!(r.counter_value("invocations_total", &[("backend", "junctiond")]), Some(5));
        assert_eq!(r.counter_value("invocations_total", &[("backend", "containerd")]), Some(1));
    }

    #[test]
    fn gauges_overwrite() {
        let mut r = Registry::new();
        r.gauge_set("replicas", "replica count", &[("fn", "aes")], 1.0);
        r.gauge_set("replicas", "replica count", &[("fn", "aes")], 4.0);
        assert_eq!(r.gauge_value("replicas", &[("fn", "aes")]), Some(4.0));
    }

    #[test]
    fn exposition_format_is_wellformed() {
        let mut r = Registry::new();
        r.counter_add("requests_total", "reqs", &[("code", "200")], 7);
        r.gauge_set("in_flight", "concurrent requests", &[], 3.0);
        for v in [1_000_000u64, 2_000_000, 50_000_000] {
            r.observe("latency_seconds", "request latency", &[], v);
        }
        let text = r.expose();
        assert!(text.contains("# TYPE requests_total counter"));
        assert!(text.contains("requests_total{code=\"200\"} 7"));
        assert!(text.contains("# TYPE in_flight gauge"));
        assert!(text.contains("in_flight 3"));
        assert!(text.contains("# TYPE latency_seconds histogram"));
        assert!(text.contains("latency_seconds_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("latency_seconds_count 3"));
    }

    #[test]
    fn help_and_label_values_escape_per_v004_spec() {
        // Mirrors the escaping examples in the Prometheus text-format
        // v0.0.4 spec: `\` → `\\` and newline → `\n` in HELP; label
        // values additionally escape `"` → `\"`.
        let mut r = Registry::new();
        r.counter_add("msgs", "line one\nline \\two", &[("path", "C:\\dir\n\"x\"")], 1);
        let text = r.expose();
        assert!(text.contains("# HELP msgs_total line one\\nline \\\\two"));
        assert!(text.contains("msgs_total{path=\"C:\\\\dir\\n\\\"x\\\"\"} 1"));
    }

    #[test]
    fn counters_expose_with_total_suffix() {
        let mut r = Registry::new();
        r.counter_add("frames", "frames seen", &[], 7);
        let text = r.expose();
        assert!(text.contains("# TYPE frames_total counter"));
        assert!(text.contains("frames_total 7"));
        assert!(!text.contains("# TYPE frames counter"));
        // Lookup still uses the registration name.
        assert_eq!(r.counter_value("frames", &[]), Some(7));
        // Already-suffixed names are not doubled.
        r.counter_add("drops_total", "drops", &[], 2);
        let text = r.expose();
        assert!(text.contains("drops_total 2"));
        assert!(!text.contains("drops_total_total"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_inf_equals_count() {
        let mut r = Registry::new();
        for v in [1_000u64, 1_000, 900_000, 50_000_000] {
            r.observe("lat", "latency", &[], v);
        }
        let text = r.expose();
        let counts: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("lat_bucket"))
            .map(|l| l.split_whitespace().last().unwrap().parse().unwrap())
            .collect();
        assert!(counts.len() >= 3, "expected several buckets, got {counts:?}");
        assert!(counts.windows(2).all(|w| w[0] <= w[1]), "non-cumulative: {counts:?}");
        assert_eq!(*counts.last().unwrap(), 4, "+Inf bucket must equal _count");
        assert!(text.contains("lat_bucket{le=\"+Inf\"} 4"));
        assert!(text.contains("lat_count 4"));
    }

    #[test]
    fn scalar_series_flattens_all_families() {
        let mut r = Registry::new();
        r.counter_add("frames", "f", &[("dir", "rx")], 3);
        r.gauge_set("depth", "d", &[], 2.5);
        r.observe("lat", "l", &[], 500);
        let series = r.scalar_series();
        assert!(series.contains(&("frames_total".to_string(), "{dir=\"rx\"}".to_string(), 3.0)));
        assert!(series.contains(&("depth".to_string(), String::new(), 2.5)));
        assert!(series.contains(&("lat_count".to_string(), String::new(), 1.0)));
    }

    #[test]
    #[should_panic(expected = "another type")]
    fn type_confusion_panics() {
        let mut r = Registry::new();
        r.counter_add("x", "h", &[], 1);
        r.gauge_set("x", "h", &[], 1.0);
    }
}
