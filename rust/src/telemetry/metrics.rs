//! Metrics registry with Prometheus-style text exposition.
//!
//! The paper's junctiond artifact lives on a branch named
//! `junction_manager_prometheus` — the real system exports Prometheus
//! metrics. This registry provides the same operational surface: counters,
//! gauges, and latency histograms, rendered in the Prometheus text format
//! (v0.0.4), pull-able from the real-mode server and dumpable from the
//! simulator.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::LogHistogram;

/// A single metric family's data.
enum Metric {
    Counter(u64),
    Gauge(f64),
    Histogram(LogHistogram),
}

/// Label-set → metric value, under one family name.
pub struct Registry {
    /// (family, help) → labels-string → metric
    families: BTreeMap<String, (String, BTreeMap<String, Metric>)>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

fn label_key(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", v.replace('"', "\\\""))).collect();
    format!("{{{}}}", parts.join(","))
}

impl Registry {
    pub fn new() -> Registry {
        Registry { families: BTreeMap::new() }
    }

    fn family(&mut self, name: &str, help: &str) -> &mut BTreeMap<String, Metric> {
        &mut self
            .families
            .entry(name.to_string())
            .or_insert_with(|| (help.to_string(), BTreeMap::new()))
            .1
    }

    /// Increment a counter by `v`.
    pub fn counter_add(&mut self, name: &str, help: &str, labels: &[(&str, &str)], v: u64) {
        let key = label_key(labels);
        match self.family(name, help).entry(key).or_insert(Metric::Counter(0)) {
            Metric::Counter(c) => *c += v,
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    /// Set a gauge.
    pub fn gauge_set(&mut self, name: &str, help: &str, labels: &[(&str, &str)], v: f64) {
        let key = label_key(labels);
        let slot = self.family(name, help).entry(key).or_insert(Metric::Gauge(0.0));
        match slot {
            Metric::Gauge(g) => *g = v,
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    /// Record a latency observation (ns) into a histogram metric.
    pub fn observe(&mut self, name: &str, help: &str, labels: &[(&str, &str)], ns: u64) {
        let key = label_key(labels);
        let slot =
            self.family(name, help).entry(key).or_insert_with(|| Metric::Histogram(LogHistogram::new()));
        match slot {
            Metric::Histogram(h) => h.record(ns),
            _ => panic!("metric '{name}' already registered with another type"),
        }
    }

    pub fn counter_value(&self, name: &str, labels: &[(&str, &str)]) -> Option<u64> {
        let (_, fam) = self.families.get(name)?;
        match fam.get(&label_key(labels))? {
            Metric::Counter(c) => Some(*c),
            _ => None,
        }
    }

    pub fn gauge_value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        let (_, fam) = self.families.get(name)?;
        match fam.get(&label_key(labels))? {
            Metric::Gauge(g) => Some(*g),
            _ => None,
        }
    }

    /// Render the Prometheus text exposition format.
    pub fn expose(&self) -> String {
        let mut out = String::new();
        for (name, (help, fam)) in &self.families {
            let kind = match fam.values().next() {
                Some(Metric::Counter(_)) => "counter",
                Some(Metric::Gauge(_)) => "gauge",
                Some(Metric::Histogram(_)) => "summary",
                None => continue,
            };
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} {kind}");
            for (labels, metric) in fam {
                match metric {
                    Metric::Counter(c) => {
                        let _ = writeln!(out, "{name}{labels} {c}");
                    }
                    Metric::Gauge(g) => {
                        let _ = writeln!(out, "{name}{labels} {g}");
                    }
                    Metric::Histogram(h) => {
                        // Summary quantiles in seconds (Prometheus units).
                        for q in [0.5, 0.9, 0.99] {
                            let v = h.quantile(q) as f64 / 1e9;
                            let lq = if labels.is_empty() {
                                format!("{{quantile=\"{q}\"}}")
                            } else {
                                // Splice the quantile label into the set.
                                let inner = &labels[1..labels.len() - 1];
                                format!("{{{inner},quantile=\"{q}\"}}")
                            };
                            let _ = writeln!(out, "{name}{lq} {v}");
                        }
                        let _ = writeln!(out, "{name}_count{labels} {}", h.count());
                        let _ = writeln!(
                            out,
                            "{name}_sum{labels} {}",
                            h.mean() * h.count() as f64 / 1e9
                        );
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_per_label_set() {
        let mut r = Registry::new();
        r.counter_add("invocations_total", "total invocations", &[("backend", "junctiond")], 2);
        r.counter_add("invocations_total", "total invocations", &[("backend", "junctiond")], 3);
        r.counter_add("invocations_total", "total invocations", &[("backend", "containerd")], 1);
        assert_eq!(r.counter_value("invocations_total", &[("backend", "junctiond")]), Some(5));
        assert_eq!(r.counter_value("invocations_total", &[("backend", "containerd")]), Some(1));
    }

    #[test]
    fn gauges_overwrite() {
        let mut r = Registry::new();
        r.gauge_set("replicas", "replica count", &[("fn", "aes")], 1.0);
        r.gauge_set("replicas", "replica count", &[("fn", "aes")], 4.0);
        assert_eq!(r.gauge_value("replicas", &[("fn", "aes")]), Some(4.0));
    }

    #[test]
    fn exposition_format_is_wellformed() {
        let mut r = Registry::new();
        r.counter_add("requests_total", "reqs", &[("code", "200")], 7);
        r.gauge_set("in_flight", "concurrent requests", &[], 3.0);
        for v in [1_000_000u64, 2_000_000, 50_000_000] {
            r.observe("latency_seconds", "request latency", &[], v);
        }
        let text = r.expose();
        assert!(text.contains("# TYPE requests_total counter"));
        assert!(text.contains("requests_total{code=\"200\"} 7"));
        assert!(text.contains("# TYPE in_flight gauge"));
        assert!(text.contains("in_flight 3"));
        assert!(text.contains("latency_seconds_count 3"));
        assert!(text.contains("quantile=\"0.99\""));
    }

    #[test]
    #[should_panic(expected = "another type")]
    fn type_confusion_panics() {
        let mut r = Registry::new();
        r.counter_add("x", "h", &[], 1);
        r.gauge_set("x", "h", &[], 1.0);
    }
}
