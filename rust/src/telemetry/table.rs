//! Table rendering: every bench prints its paper-figure counterpart as a
//! markdown table (and optionally CSV for plotting).

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// One table cell.
#[derive(Debug, Clone)]
pub enum Cell {
    Str(String),
    Int(i64),
    F2(f64),
    /// Nanoseconds rendered as microseconds with 2 decimals.
    NsAsUs(u64),
}

impl Cell {
    fn render(&self) -> String {
        match self {
            Cell::Str(s) => s.clone(),
            Cell::Int(v) => v.to_string(),
            Cell::F2(v) => format!("{v:.2}"),
            Cell::NsAsUs(ns) => format!("{:.2}", *ns as f64 / 1e3),
        }
    }
}

impl From<&str> for Cell {
    fn from(s: &str) -> Self {
        Cell::Str(s.to_string())
    }
}
impl From<String> for Cell {
    fn from(s: String) -> Self {
        Cell::Str(s)
    }
}
impl From<i64> for Cell {
    fn from(v: i64) -> Self {
        Cell::Int(v)
    }
}
impl From<u64> for Cell {
    fn from(v: u64) -> Self {
        Cell::Int(v as i64)
    }
}
impl From<f64> for Cell {
    fn from(v: f64) -> Self {
        Cell::F2(v)
    }
}

/// A simple column-named table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<Cell>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, row: Vec<Cell>) {
        assert_eq!(row.len(), self.columns.len(), "row arity mismatch in '{}'", self.title);
        self.rows.push(row);
    }

    pub fn to_markdown(&self) -> String {
        format_markdown_table(self)
    }

    /// Write the table as CSV (for external plotting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.columns.join(","));
        out.push('\n');
        for row in &self.rows {
            let cells: Vec<String> = row.iter().map(|c| c.render()).collect();
            out.push_str(&cells.join(","));
            out.push('\n');
        }
        out
    }
}

/// Render with aligned columns.
pub fn format_markdown_table(table: &Table) -> String {
    let rendered: Vec<Vec<String>> =
        table.rows.iter().map(|r| r.iter().map(|c| c.render()).collect()).collect();
    let mut widths: Vec<usize> = table.columns.iter().map(|c| c.len()).collect();
    for row in &rendered {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    if !table.title.is_empty() {
        let _ = writeln!(out, "### {}", table.title);
    }
    let header: Vec<String> =
        table.columns.iter().enumerate().map(|(i, c)| format!("{:w$}", c, w = widths[i])).collect();
    let _ = writeln!(out, "| {} |", header.join(" | "));
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    let _ = writeln!(out, "| {} |", sep.join(" | "));
    for row in &rendered {
        let cells: Vec<String> =
            row.iter().enumerate().map(|(i, c)| format!("{:w$}", c, w = widths[i])).collect();
        let _ = writeln!(out, "| {} |", cells.join(" | "));
    }
    out
}

/// Write a table to a CSV file, creating parent directories.
pub fn write_csv(table: &Table, path: &Path) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(table.to_csv().as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("t", &["a", "bb"]);
        t.push_row(vec![Cell::Int(1), Cell::F2(2.5)]);
        t.push_row(vec![Cell::Str("xyz".into()), Cell::NsAsUs(1500)]);
        t
    }

    #[test]
    fn markdown_has_all_rows() {
        let md = sample().to_markdown();
        assert!(md.contains("### t"));
        assert!(md.contains("| 1 "));
        assert!(md.contains("2.50"));
        assert!(md.contains("1.50")); // 1500 ns = 1.50 µs
        assert_eq!(md.lines().count(), 5);
    }

    #[test]
    fn csv_round_trips_columns() {
        let csv = sample().to_csv();
        let mut lines = csv.lines();
        assert_eq!(lines.next().unwrap(), "a,bb");
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new("t", &["a"]);
        t.push_row(vec![Cell::Int(1), Cell::Int(2)]);
    }
}
