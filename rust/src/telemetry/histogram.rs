//! Exact-sample and log-bucketed latency recorders.

use super::LatencySummary;

/// Exact recorder: stores every observation (nanoseconds).
#[derive(Debug, Clone, Default)]
pub struct Samples {
    values: Vec<u64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Samples { values: Vec::new(), sorted: true }
    }

    pub fn with_capacity(n: usize) -> Self {
        Samples { values: Vec::with_capacity(n), sorted: true }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.sorted = false;
        self.values.push(v);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn values(&self) -> &[u64] {
        &self.values
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.values.sort_unstable();
            self.sorted = true;
        }
    }

    /// Exact quantile via the nearest-rank method (q in [0,1]).
    pub fn quantile(&mut self, q: f64) -> u64 {
        if self.values.is_empty() {
            return 0;
        }
        self.ensure_sorted();
        let n = self.values.len();
        let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
        self.values[rank - 1]
    }

    pub fn min(&mut self) -> u64 {
        self.ensure_sorted();
        self.values.first().copied().unwrap_or(0)
    }

    pub fn max(&mut self) -> u64 {
        self.ensure_sorted();
        self.values.last().copied().unwrap_or(0)
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().map(|&v| v as f64).sum::<f64>() / self.values.len() as f64
    }

    pub fn summary(&mut self) -> LatencySummary {
        if self.values.is_empty() {
            return LatencySummary::empty();
        }
        LatencySummary {
            count: self.values.len() as u64,
            min: self.min(),
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
            p999: self.quantile(0.999),
            max: self.max(),
            mean: self.mean(),
        }
    }

    /// CDF points (value, cumulative fraction) — the shape plotted in Fig. 5.
    /// Duplicate observations collapse into one point carrying the *max*
    /// cumulative fraction for that value, so the CDF is a proper function
    /// of x (one y per distinct latency) and strictly increasing in both
    /// coordinates.
    pub fn cdf(&mut self) -> Vec<(u64, f64)> {
        self.ensure_sorted();
        let n = self.values.len();
        let mut out: Vec<(u64, f64)> = Vec::new();
        for (i, &v) in self.values.iter().enumerate() {
            let frac = (i + 1) as f64 / n as f64;
            match out.last_mut() {
                Some(last) if last.0 == v => last.1 = frac,
                _ => out.push((v, frac)),
            }
        }
        out
    }
}

/// HDR-style log-bucketed histogram: 64 exponents × `SUB` linear sub-buckets
/// → ≤ ~1.6% relative quantile error, O(1) record, fixed 4 KB footprint.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

const SUB_BITS: u32 = 5;
const SUB: usize = 1 << SUB_BITS; // 32 sub-buckets per power of two

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram {
            buckets: vec![0; 64 * SUB],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    #[inline]
    fn index(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let exp = 63 - v.leading_zeros();
        let sub = ((v >> (exp - SUB_BITS)) & (SUB as u64 - 1)) as usize;
        ((exp - SUB_BITS + 1) as usize) * SUB + sub
    }

    /// Representative (upper-bound) value of bucket `i`.
    fn value_of(i: usize) -> u64 {
        if i < SUB {
            return i as u64;
        }
        let exp = (i / SUB) as u32 + SUB_BITS - 1;
        let sub = (i % SUB) as u64;
        (1u64 << exp) + ((sub + 1) << (exp - SUB_BITS)) - 1
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.buckets[Self::index(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::value_of(i).min(self.max).max(self.min);
            }
        }
        self.max
    }

    pub fn summary(&self) -> LatencySummary {
        if self.count == 0 {
            return LatencySummary::empty();
        }
        LatencySummary {
            count: self.count,
            min: self.min,
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
            p999: self.quantile(0.999),
            max: self.max,
            mean: self.mean(),
        }
    }

    /// Cumulative `(upper bound, count ≤ bound)` pairs over the non-empty
    /// buckets — the Prometheus histogram `le` ladder. The last pair's
    /// count equals [`Self::count`].
    pub fn cumulative_buckets(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            acc += c;
            out.push((Self::value_of(i), acc));
        }
        out
    }

    /// Merge another histogram into this one (sharded recording).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::{forall, Rng};

    #[test]
    fn exact_quantiles_small() {
        let mut s = Samples::new();
        for v in [10u64, 20, 30, 40, 50, 60, 70, 80, 90, 100] {
            s.record(v);
        }
        assert_eq!(s.quantile(0.5), 50);
        assert_eq!(s.quantile(0.99), 100);
        assert_eq!(s.quantile(0.0), 10);
        assert_eq!(s.min(), 10);
        assert_eq!(s.max(), 100);
        assert!((s.mean() - 55.0).abs() < 1e-9);
    }

    #[test]
    fn cdf_is_monotone_and_complete() {
        let mut s = Samples::new();
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            s.record(rng.range(1, 1_000_000));
        }
        let cdf = s.cdf();
        assert!(!cdf.is_empty() && cdf.len() <= 1000);
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-12);
        // One point per distinct value, strictly increasing in x AND y —
        // duplicate samples must not produce several y's for the same x.
        for w in cdf.windows(2) {
            assert!(w[0].0 < w[1].0);
            assert!(w[0].1 < w[1].1);
        }
    }

    #[test]
    fn cdf_collapses_duplicates_to_max_fraction() {
        let mut s = Samples::new();
        for v in [5u64, 5, 5, 10] {
            s.record(v);
        }
        assert_eq!(s.cdf(), vec![(5, 0.75), (10, 1.0)]);
        // Heavily tied data: a constant series is a single CDF point.
        let mut c = Samples::new();
        for _ in 0..100 {
            c.record(42);
        }
        assert_eq!(c.cdf(), vec![(42, 1.0)]);
    }

    #[test]
    fn log_histogram_bounded_relative_error() {
        forall("loghist relative error", 50, |g| {
            let mut h = LogHistogram::new();
            let mut s = Samples::new();
            let n = g.usize(100, 5000);
            for _ in 0..n {
                let v = g.u64(1, 100_000_000);
                h.record(v);
                s.record(v);
            }
            for q in [0.5, 0.9, 0.99, 0.999] {
                let exact = s.quantile(q) as f64;
                let approx = h.quantile(q) as f64;
                let err = (approx - exact).abs() / exact.max(1.0);
                assert!(err < 0.04, "q={q} exact={exact} approx={approx} err={err}");
            }
        });
    }

    #[test]
    fn cumulative_buckets_are_monotone_and_end_at_count() {
        let mut rng = Rng::new(21);
        let mut h = LogHistogram::new();
        for _ in 0..4000 {
            h.record(rng.range(1, 50_000_000));
        }
        let cum = h.cumulative_buckets();
        assert!(!cum.is_empty());
        for w in cum.windows(2) {
            assert!(w[0].0 < w[1].0, "bounds must increase");
            assert!(w[0].1 < w[1].1, "counts must be cumulative");
        }
        assert_eq!(cum.last().unwrap().1, h.count());
        // Every bound's cumulative count is the number of samples ≤ bound
        // of the *bucketized* stream; spot-check the first bucket holds at
        // least one sample and never exceeds the total.
        assert!(cum[0].1 >= 1 && cum[0].1 <= h.count());
    }

    #[test]
    fn log_histogram_count_conservation() {
        let mut h = LogHistogram::new();
        for v in 0..10_000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 10_000);
        assert_eq!(h.buckets.iter().sum::<u64>(), 10_000);
    }

    #[test]
    fn log_histogram_merge_equals_combined() {
        let mut rng = Rng::new(9);
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut all = LogHistogram::new();
        for i in 0..5000 {
            let v = rng.range(1, 10_000_000);
            if i % 2 == 0 {
                a.record(v)
            } else {
                b.record(v)
            }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        for q in [0.5, 0.9, 0.99, 0.999] {
            assert_eq!(a.quantile(q), all.quantile(q));
        }
    }

    #[test]
    fn quantile_monotone_in_q() {
        forall("quantile monotone", 30, |g| {
            let mut s = Samples::new();
            for _ in 0..g.usize(1, 500) {
                s.record(g.u64(0, 1_000_000));
            }
            let mut last = 0;
            for i in 0..=100 {
                let v = s.quantile(i as f64 / 100.0);
                assert!(v >= last);
                last = v;
            }
        });
    }

    #[test]
    fn empty_recorders_are_sane() {
        let mut s = Samples::new();
        assert_eq!(s.summary(), LatencySummary::empty());
        let h = LogHistogram::new();
        assert_eq!(h.summary(), LatencySummary::empty());
    }
}
