//! Periodic timeline scraper: snapshot the Prometheus [`Registry`] into
//! per-interval series over *virtual* time.
//!
//! The metrics registry answers "what are the totals now"; transient
//! analysis (provisioning storms, saturation onset, queue growth) needs
//! "how did they move". A [`Timeline`] is driven from inside a running
//! experiment — typically a `simcore::tick_train` callback calling
//! [`Timeline::scrape`] every interval — and keeps one `(virtual ns,
//! value)` series per scalar metric (counters, gauges, histogram
//! observation counts), plus any ad-hoc series recorded directly with
//! [`Timeline::record`] (queue depths, fabric busy, pool occupancy).
//! Scraping only *reads* simulation state, so a scrape schedule is
//! deterministic and two same-seed runs render byte-identical tables.

use std::collections::BTreeMap;

use crate::simcore::Time;

use super::metrics::Registry;
use super::{Cell, Table};

/// Named `(virtual time, value)` series collected over a run.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    series: BTreeMap<String, Vec<(Time, f64)>>,
}

impl Timeline {
    pub fn new() -> Self {
        Timeline { series: BTreeMap::new() }
    }

    /// Append one point to the named series.
    pub fn record(&mut self, name: &str, now: Time, v: f64) {
        self.series.entry(name.to_string()).or_default().push((now, v));
    }

    /// Snapshot every scalar series in `reg` at virtual time `now`:
    /// counters and gauges by their exposed name (labels appended),
    /// histograms as `<name>_count`.
    pub fn scrape(&mut self, now: Time, reg: &Registry) {
        for (name, labels, v) in reg.scalar_series() {
            let key = if labels.is_empty() { name } else { format!("{name}{labels}") };
            self.record(&key, now, v);
        }
    }

    /// Number of distinct series.
    pub fn len(&self) -> usize {
        self.series.len()
    }

    pub fn is_empty(&self) -> bool {
        self.series.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        self.series.keys().map(|s| s.as_str()).collect()
    }

    pub fn series(&self, name: &str) -> Option<&[(Time, f64)]> {
        self.series.get(name).map(|v| v.as_slice())
    }

    /// Render the named series side by side: one row per scrape instant
    /// (taken from the first present series), `t_ms` first. A series
    /// missing a point at some instant renders 0.
    pub fn to_table(&self, title: &str, names: &[&str]) -> Table {
        let mut cols: Vec<&str> = vec!["t_ms"];
        cols.extend_from_slice(names);
        let mut table = Table::new(title, &cols);
        let times: Vec<Time> = names
            .iter()
            .find_map(|n| self.series(n))
            .map(|s| s.iter().map(|p| p.0).collect())
            .unwrap_or_default();
        for (i, ts) in times.iter().enumerate() {
            let mut row: Vec<Cell> = vec![Cell::F2(*ts as f64 / 1e6)];
            for n in names {
                let v = self.series(n).and_then(|s| s.get(i)).map(|p| p.1).unwrap_or(0.0);
                row.push(Cell::F2(v));
            }
            table.push_row(row);
        }
        table
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_render() {
        let mut tl = Timeline::new();
        tl.record("queue_depth", 0, 0.0);
        tl.record("queue_depth", 1_000_000, 3.0);
        tl.record("busy", 0, 0.5);
        tl.record("busy", 1_000_000, 0.9);
        assert_eq!(tl.len(), 2);
        let t = tl.to_table("tl", &["queue_depth", "busy"]);
        assert_eq!(t.rows.len(), 2);
        let md = t.to_markdown();
        assert!(md.contains("queue_depth"));
        assert!(md.contains("3.00"));
        assert!(md.contains("0.90"));
    }

    #[test]
    fn scrape_tracks_counters_gauges_and_histogram_counts() {
        let mut reg = Registry::new();
        let mut tl = Timeline::new();
        reg.counter_add("frames_total", "frames", &[], 2);
        reg.gauge_set("depth", "ring depth", &[], 1.0);
        reg.observe("lat", "latency", &[], 1_000);
        tl.scrape(0, &reg);
        reg.counter_add("frames_total", "frames", &[], 3);
        reg.gauge_set("depth", "ring depth", &[], 7.0);
        reg.observe("lat", "latency", &[], 2_000);
        tl.scrape(1_000_000, &reg);
        assert_eq!(
            tl.series("frames_total").unwrap(),
            &[(0, 2.0), (1_000_000, 5.0)][..]
        );
        assert_eq!(tl.series("depth").unwrap(), &[(0, 1.0), (1_000_000, 7.0)][..]);
        assert_eq!(tl.series("lat_count").unwrap(), &[(0, 1.0), (1_000_000, 2.0)][..]);
    }

    #[test]
    fn labeled_series_keep_label_sets_apart() {
        let mut reg = Registry::new();
        let mut tl = Timeline::new();
        reg.counter_add("served_total", "s", &[("tier", "warm")], 1);
        reg.counter_add("served_total", "s", &[("tier", "cold")], 9);
        tl.scrape(5, &reg);
        assert_eq!(tl.series("served_total{tier=\"warm\"}").unwrap(), &[(5, 1.0)][..]);
        assert_eq!(tl.series("served_total{tier=\"cold\"}").unwrap(), &[(5, 9.0)][..]);
    }
}
