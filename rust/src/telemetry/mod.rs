//! Latency recording and reporting.
//!
//! Two recorders:
//! * [`Samples`] — keeps every observation for *exact* percentiles; right
//!   for the paper's Fig. 5 (100 invocations) and any run up to a few
//!   million points.
//! * [`LogHistogram`] — HDR-style log-bucketed histogram with bounded
//!   relative error; right for the hot path of long load sweeps where
//!   storing every sample would distort the run being measured.
//!
//! Plus small helpers to render the markdown/CSV tables that the benches
//! print (the repo's equivalent of the paper's figures).

mod histogram;
pub mod metrics;
mod table;
pub mod timeline;
pub mod trace;

pub use histogram::{LogHistogram, Samples};
pub use metrics::Registry as MetricsRegistry;
pub use table::{format_markdown_table, write_csv, Cell, Table};
pub use timeline::Timeline;
pub use trace::{chrome_trace_json, BlameReport, Hop, HopTimes, Span, Trace, Tracer, HOP_NAMES};

/// Summary statistics used across every experiment report.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub count: u64,
    pub min: u64,
    pub p50: u64,
    pub p90: u64,
    pub p99: u64,
    pub p999: u64,
    pub max: u64,
    pub mean: f64,
}

impl LatencySummary {
    pub fn empty() -> Self {
        LatencySummary { count: 0, min: 0, p50: 0, p90: 0, p99: 0, p999: 0, max: 0, mean: 0.0 }
    }

    /// Render as `µs` with two decimals (inputs are nanoseconds).
    pub fn fmt_us(&self) -> String {
        format!(
            "n={} min={:.2} p50={:.2} p90={:.2} p99={:.2} p99.9={:.2} max={:.2} mean={:.2} (µs)",
            self.count,
            self.min as f64 / 1e3,
            self.p50 as f64 / 1e3,
            self.p90 as f64 / 1e3,
            self.p99 as f64 / 1e3,
            self.p999 as f64 / 1e3,
            self.max as f64 / 1e3,
            self.mean / 1e3,
        )
    }
}
