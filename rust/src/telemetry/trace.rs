//! Span-per-invocation tracing + tail-latency attribution.
//!
//! The paper's headline numbers are distributional (−37% p50 / −63% p99),
//! but `RequestTiming` alone can only *report* a tail, not *explain* it.
//! This module turns each invocation into a reconstructable span tree:
//! the pipeline opens a trace at submit ([`Tracer::begin`]), records
//! closed sub-spans as the request crosses each stage ([`Tracer::event`]
//! — retransmit backoffs, ring waits, scheduler wakeups, fabric slices,
//! TX backpressure), and closes the trace when the response reaches the
//! client ([`Tracer::finish`]). At close time the tracer assembles the
//! tree: a root span `[submit, done]` whose direct children are the five
//! tiling hop spans (`wire | nic_rx | pre_exec | exec | resp_svc+tx`),
//! with every recorded sub-span parented under its hop. The hop spans are
//! derived from the same `RequestTiming` timestamps the pinned
//! `per_hop_breakdown_sums_to_e2e` identity rests on, so the children
//! tile the root's extent and sum to the end-to-end latency by
//! construction.
//!
//! Three consumers sit on top:
//!
//! * **Top-K tail-exemplar reservoir** — the K slowest *complete* traces
//!   of a run, selected by `(e2e desc, seq asc)`. Determinism argument:
//!   `seq` is assigned in submit order and completions are offered in
//!   virtual-time order, both of which are fixed by the seed, and the
//!   tie-break prefers the earliest seq (an equal-latency later trace
//!   never displaces a resident one), so same-seed runs keep
//!   byte-identical exemplar sets.
//! * **Blame decomposition** ([`Tracer::blame_report`]) — per-hop share
//!   of end-to-end time over the completions at or above an e2e
//!   quantile. Shares are ratios of *sums* (`Σ hop_i / Σ e2e`), and each
//!   completion's six hops sum exactly to its e2e, so the six shares sum
//!   to 1.0 up to float rounding — the E15 acceptance gate.
//! * **Chrome `trace_event` export** ([`chrome_trace_json`]) — exemplars
//!   rendered as nested B/E duration events (`ts` in µs), one `tid` per
//!   trace, loadable in `chrome://tracing` / Perfetto.
//!
//! Zero-cost-when-off: a disabled tracer ([`Tracer::new`]) answers every
//! call with a cheap early return and assigns `seq == 0` to every
//! request, and no caller schedules events, draws randomness, or changes
//! control flow on its behalf — enabling tracing cannot perturb the
//! simulation, and disabling it cannot change any experiment's output.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::rc::Rc;

use crate::simcore::Time;

use super::Samples;

/// Which pipeline hop a recorded sub-span belongs to. Determines the
/// sub-span's parent in the assembled tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Hop {
    /// Client → worker wire flight: `[submit, nic_in]`.
    Wire,
    /// NIC RX ring wait + drain (IRQ/softirq or poll batch):
    /// `[nic_in, gateway_in]`.
    NicRx,
    /// Gateway + provider service, readiness and concurrency-gate wait:
    /// `[gateway_in, exec_start]`.
    PreExec,
    /// Function execution, including scheduler grant wait and fabric
    /// slices: `[exec_start, exec_end]`.
    Exec,
    /// Response passes back through provider + gateway:
    /// `[exec_end, tx_in]`.
    Resp,
    /// TX ring (backpressure retries, flush) + return wire + frontend RX:
    /// `[tx_in, done]`.
    Tx,
}

/// Names of the six blame stages, in [`BlameReport`] share order.
pub const HOP_NAMES: [&str; 6] = ["wire", "nic_rx", "pre_exec", "exec", "resp_svc", "tx"];

/// One node of an assembled trace tree. Times are virtual-clock ns.
#[derive(Debug, Clone)]
pub struct Span {
    pub id: u32,
    /// `None` only on the root.
    pub parent: Option<u32>,
    pub name: &'static str,
    /// Why the time was spent (e.g. `rx_tail_drop`, `tx_backpressure`, a
    /// grant outcome, a fabric slice outcome). Empty on structural spans.
    pub cause: &'static str,
    pub start: Time,
    pub end: Time,
}

impl Span {
    pub fn duration(&self) -> Time {
        self.end.saturating_sub(self.start)
    }
}

/// A complete invocation trace. `spans[0]` is the root.
#[derive(Debug, Clone)]
pub struct Trace {
    /// Submit-order sequence number (unique per tracer, never 0).
    pub seq: u64,
    pub function: String,
    /// End-to-end latency (`done - submit`).
    pub e2e: Time,
    pub spans: Vec<Span>,
}

impl Trace {
    /// Direct children of the root, in span-id order (construction order
    /// — the tiling hop spans).
    pub fn root_children(&self) -> Vec<&Span> {
        self.spans.iter().filter(|s| s.parent == Some(0)).collect()
    }
}

/// Absolute hop-boundary timestamps of one finished invocation (the
/// tracing view of `faas::RequestTiming`).
#[derive(Debug, Clone, Copy, Default)]
pub struct HopTimes {
    pub submit: Time,
    pub nic_in: Time,
    pub gateway_in: Time,
    pub exec_start: Time,
    pub exec_end: Time,
    pub tx_in: Time,
    pub done: Time,
}

impl HopTimes {
    /// The six hop durations, [`HOP_NAMES`] order. For a completed
    /// invocation the boundaries are monotone, so these sum exactly to
    /// `done - submit`.
    pub fn hop_durations(&self) -> [Time; 6] {
        [
            self.nic_in.saturating_sub(self.submit),
            self.gateway_in.saturating_sub(self.nic_in),
            self.exec_start.saturating_sub(self.gateway_in),
            self.exec_end.saturating_sub(self.exec_start),
            self.tx_in.saturating_sub(self.exec_end),
            self.done.saturating_sub(self.tx_in),
        ]
    }

    pub fn e2e(&self) -> Time {
        self.done.saturating_sub(self.submit)
    }
}

/// Per-hop blame decomposition: what share of end-to-end time each stage
/// owns, over the completions at or above the p50 / p99 e2e thresholds.
#[derive(Debug, Clone, Default)]
pub struct BlameReport {
    /// Completions the report covers.
    pub count: u64,
    pub e2e_p50: Time,
    pub e2e_p99: Time,
    /// Per-hop shares over completions with `e2e >= e2e_p50`
    /// ([`HOP_NAMES`] order; sums to 1.0).
    pub p50: [f64; 6],
    /// Per-hop shares over completions with `e2e >= e2e_p99`.
    pub p99: [f64; 6],
}

impl BlameReport {
    /// Share of the p99 tail owned by the network+scheduling stages
    /// (everything but function execution) — the quantity the paper's
    /// P99 claim attributes to the kernel's network path.
    pub fn p99_non_exec_share(&self) -> f64 {
        1.0 - self.p99[3]
    }
}

struct LiveTrace {
    function: String,
    /// (hop, name, cause, start, end) — closed sub-spans in record order.
    events: Vec<(Hop, &'static str, &'static str, Time, Time)>,
}

#[derive(Debug, Clone, Copy)]
struct HopBreakdown {
    e2e: Time,
    hops: [Time; 6],
}

struct TracerInner {
    enabled: bool,
    /// Reservoir capacity (K slowest complete traces kept).
    k: usize,
    next_seq: u64,
    live: BTreeMap<u64, LiveTrace>,
    completions: Vec<HopBreakdown>,
    /// Sorted by `(e2e desc, seq asc)`; at most `k` entries.
    reservoir: Vec<Trace>,
}

/// Cloneable handle to one tracing domain (one `FaasSim`, or one whole
/// cluster sharing a handle). All clones refer to the same state.
#[derive(Clone)]
pub struct Tracer {
    inner: Rc<RefCell<TracerInner>>,
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new()
    }
}

impl Tracer {
    /// A disabled tracer: every call is a cheap no-op.
    pub fn new() -> Self {
        Tracer {
            inner: Rc::new(RefCell::new(TracerInner {
                enabled: false,
                k: 0,
                next_seq: 0,
                live: BTreeMap::new(),
                completions: Vec::new(),
                reservoir: Vec::new(),
            })),
        }
    }

    /// An enabled tracer keeping the `k` slowest complete traces.
    pub fn new_enabled(k: usize) -> Self {
        let t = Tracer::new();
        t.enable(k);
        t
    }

    /// Turn tracing on, keeping the `k` slowest complete traces.
    pub fn enable(&self, k: usize) {
        let mut i = self.inner.borrow_mut();
        i.enabled = true;
        i.k = k;
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.borrow().enabled
    }

    /// Open a trace at submit time. Returns its seq — 0 when disabled
    /// (never a live id, so downstream calls with it are no-ops).
    pub fn begin(&self, function: &str) -> u64 {
        let mut i = self.inner.borrow_mut();
        if !i.enabled {
            return 0;
        }
        i.next_seq += 1;
        let seq = i.next_seq;
        i.live.insert(seq, LiveTrace { function: function.to_string(), events: Vec::new() });
        seq
    }

    /// Record a closed sub-span under `hop` of trace `seq`.
    pub fn event(
        &self,
        seq: u64,
        hop: Hop,
        name: &'static str,
        cause: &'static str,
        start: Time,
        end: Time,
    ) {
        if seq == 0 {
            return;
        }
        let mut i = self.inner.borrow_mut();
        if !i.enabled {
            return;
        }
        if let Some(lt) = i.live.get_mut(&seq) {
            lt.events.push((hop, name, cause, start, end));
        }
    }

    /// Close trace `seq`. A dropped request's trace is discarded; a
    /// completed one is folded into the blame accumulator and offered to
    /// the top-K reservoir.
    pub fn finish(&self, seq: u64, ht: HopTimes, dropped: bool) {
        if seq == 0 {
            return;
        }
        let mut i = self.inner.borrow_mut();
        if !i.enabled {
            return;
        }
        let Some(lt) = i.live.remove(&seq) else { return };
        if dropped {
            return;
        }
        let e2e = ht.e2e();
        i.completions.push(HopBreakdown { e2e, hops: ht.hop_durations() });
        let k = i.k;
        if k == 0 {
            return;
        }
        // Keep the K slowest by (e2e desc, seq asc). Seqs strictly
        // increase, so an equal-e2e resident always has a smaller seq and
        // stays ahead of (or keeps out) the newcomer — the deterministic
        // tie-break.
        let admit = i.reservoir.len() < k
            || i.reservoir.last().map(|t| e2e > t.e2e).unwrap_or(true);
        if admit {
            let trace = assemble(seq, lt, &ht);
            let pos = i.reservoir.partition_point(|t| t.e2e >= e2e);
            i.reservoir.insert(pos, trace);
            i.reservoir.truncate(k);
        }
    }

    /// Completed (non-dropped) invocations folded into the blame data.
    pub fn completions(&self) -> u64 {
        self.inner.borrow().completions.len() as u64
    }

    /// Traces opened by [`Tracer::begin`] but not yet finished. After a
    /// drained run this must be 0 on every path — including give-ups,
    /// TX abandons, and every fault-plane failure path (the span-leak
    /// conservation law the cluster tests pin).
    pub fn open_traces(&self) -> usize {
        self.inner.borrow().live.len()
    }

    /// Snapshot of the tail-exemplar reservoir, slowest first.
    pub fn exemplars(&self) -> Vec<Trace> {
        self.inner.borrow().reservoir.clone()
    }

    /// Per-hop blame shares over completions with e2e at or above the
    /// `q`-quantile, plus the threshold itself. `None` before any
    /// completion. Shares are `Σ hop_i / Σ e2e` over the selected set.
    pub fn blame(&self, q: f64) -> Option<(Time, [f64; 6])> {
        let i = self.inner.borrow();
        if i.completions.is_empty() {
            return None;
        }
        let mut e2es = Samples::with_capacity(i.completions.len());
        for c in &i.completions {
            e2es.record(c.e2e);
        }
        let threshold = e2es.quantile(q);
        let mut hop_sums = [0u128; 6];
        let mut e2e_sum = 0u128;
        for c in i.completions.iter().filter(|c| c.e2e >= threshold) {
            e2e_sum += c.e2e as u128;
            for (s, h) in hop_sums.iter_mut().zip(c.hops) {
                *s += h as u128;
            }
        }
        if e2e_sum == 0 {
            return Some((threshold, [0.0; 6]));
        }
        let mut shares = [0.0; 6];
        for (out, s) in shares.iter_mut().zip(hop_sums) {
            *out = s as f64 / e2e_sum as f64;
        }
        Some((threshold, shares))
    }

    /// The full p50/p99 blame decomposition.
    pub fn blame_report(&self) -> BlameReport {
        let count = self.completions();
        let Some((p50, s50)) = self.blame(0.50) else { return BlameReport::default() };
        let (p99, s99) = self.blame(0.99).expect("p50 present implies p99 present");
        BlameReport { count, e2e_p50: p50, e2e_p99: p99, p50: s50, p99: s99 }
    }
}

/// Span ids of the fixed tree skeleton: root 0, hops 1..=5, tx 6.
fn hop_span_id(hop: Hop) -> u32 {
    match hop {
        Hop::Wire => 1,
        Hop::NicRx => 2,
        Hop::PreExec => 3,
        Hop::Exec => 4,
        Hop::Resp => 5,
        Hop::Tx => 6,
    }
}

/// Build the span tree: root `[submit, done]`; direct children `wire |
/// nic_rx | pre_exec | exec | resp` tiling it exactly; `tx` nested under
/// `resp`; recorded sub-spans parented under their hop.
fn assemble(seq: u64, lt: LiveTrace, ht: &HopTimes) -> Trace {
    let mut spans = Vec::with_capacity(7 + lt.events.len());
    spans.push(Span {
        id: 0,
        parent: None,
        name: "invocation",
        cause: "",
        start: ht.submit,
        end: ht.done,
    });
    let bounds: [(&'static str, Time, Time, u32); 6] = [
        ("wire", ht.submit, ht.nic_in, 0),
        ("nic_rx", ht.nic_in, ht.gateway_in, 0),
        ("pre_exec", ht.gateway_in, ht.exec_start, 0),
        ("exec", ht.exec_start, ht.exec_end, 0),
        // resp covers [exec_end, done] so the root's children tile; the
        // tx span nests inside it and blame splits resp_svc/tx at tx_in.
        ("resp_svc", ht.exec_end, ht.done, 0),
        ("tx", ht.tx_in, ht.done, 5),
    ];
    for (i, (name, start, end, parent)) in bounds.into_iter().enumerate() {
        spans.push(Span { id: i as u32 + 1, parent: Some(parent), name, cause: "", start, end });
    }
    let mut next = 7u32;
    for (hop, name, cause, start, end) in lt.events {
        spans.push(Span {
            id: next,
            parent: Some(hop_span_id(hop)),
            name,
            cause,
            start,
            end,
        });
        next += 1;
    }
    Trace { seq, function: lt.function, e2e: ht.e2e(), spans }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Render trace groups as a Chrome `trace_event` JSON document. Each
/// group is `(pid, traces)` — one process per backend when exporting a
/// comparison — and each trace becomes one `tid` (its seq) of nested
/// `ph:"B"`/`ph:"E"` duration events, `ts` in microseconds. Children are
/// emitted depth-first in start order, so within a `(pid, tid)` the `ts`
/// sequence is nondecreasing and every `B` has a matching `E` (the CI
/// `jq` schema check pins both).
pub fn chrome_trace_json(groups: &[(u32, &[Trace])]) -> String {
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    for (pid, traces) in groups {
        for t in *traces {
            let n = t.spans.len();
            let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
            let mut root = None;
            for (i, s) in t.spans.iter().enumerate() {
                match s.parent {
                    Some(p) => children[p as usize].push(i),
                    None => root = Some(i),
                }
            }
            for c in &mut children {
                c.sort_by_key(|&i| (t.spans[i].start, t.spans[i].end, i));
            }
            let Some(root) = root else { continue };
            // Iterative DFS: emit B on entry, E after the children.
            let mut stack: Vec<(usize, usize)> = vec![(root, 0)];
            while let Some(&mut (i, ref mut cursor)) = stack.last_mut() {
                if *cursor == 0 {
                    let s = &t.spans[i];
                    let cat = if s.cause.is_empty() { "span" } else { s.cause };
                    if !first {
                        out.push(',');
                    }
                    first = false;
                    let _ = write!(
                        out,
                        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"B\",\"ts\":{:.3},\"pid\":{},\"tid\":{}",
                        json_escape(s.name),
                        json_escape(cat),
                        s.start as f64 / 1e3,
                        pid,
                        t.seq
                    );
                    if s.parent.is_none() {
                        let _ = write!(
                            out,
                            ",\"args\":{{\"function\":\"{}\",\"seq\":{}}}",
                            json_escape(&t.function),
                            t.seq
                        );
                    }
                    out.push('}');
                }
                if *cursor < children[i].len() {
                    let next = children[i][*cursor];
                    *cursor += 1;
                    stack.push((next, 0));
                } else {
                    let s = &t.spans[i];
                    let cat = if s.cause.is_empty() { "span" } else { s.cause };
                    let _ = write!(
                        out,
                        ",{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"E\",\"ts\":{:.3},\"pid\":{},\"tid\":{}}}",
                        json_escape(s.name),
                        json_escape(cat),
                        s.end as f64 / 1e3,
                        pid,
                        t.seq
                    );
                    stack.pop();
                }
            }
        }
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ht(submit: Time) -> HopTimes {
        HopTimes {
            submit,
            nic_in: submit + 10,
            gateway_in: submit + 30,
            exec_start: submit + 60,
            exec_end: submit + 160,
            tx_in: submit + 180,
            done: submit + 200,
        }
    }

    #[test]
    fn disabled_tracer_is_inert() {
        let tr = Tracer::new();
        assert_eq!(tr.begin("f"), 0);
        tr.event(0, Hop::Exec, "x", "", 0, 1);
        tr.finish(0, ht(0), false);
        assert_eq!(tr.completions(), 0);
        assert!(tr.exemplars().is_empty());
        assert!(tr.blame(0.99).is_none());
    }

    #[test]
    fn root_children_tile_and_sum_to_e2e() {
        let tr = Tracer::new_enabled(4);
        let seq = tr.begin("aes");
        tr.event(seq, Hop::Exec, "fabric.slice", "complete", 60, 160);
        tr.finish(seq, ht(0), false);
        let traces = tr.exemplars();
        assert_eq!(traces.len(), 1);
        let t = &traces[0];
        assert_eq!(t.e2e, 200);
        let root = &t.spans[0];
        let kids = t.root_children();
        assert_eq!(kids.len(), 5);
        assert_eq!(kids[0].start, root.start);
        for w in kids.windows(2) {
            assert_eq!(w[0].end, w[1].start, "children must tile");
        }
        assert_eq!(kids.last().unwrap().end, root.end);
        let sum: Time = kids.iter().map(|s| s.duration()).sum();
        assert_eq!(sum, t.e2e);
        // The recorded sub-span hangs off the exec hop.
        let sub = t.spans.iter().find(|s| s.name == "fabric.slice").unwrap();
        assert_eq!(sub.parent, Some(4));
        assert_eq!(sub.cause, "complete");
    }

    #[test]
    fn reservoir_keeps_k_slowest_deterministically() {
        let tr = Tracer::new_enabled(3);
        // e2e pattern: 200 for every trace except two slower ones.
        let lat = [200u64, 500, 200, 200, 300, 200];
        for (i, extra) in lat.iter().enumerate() {
            let seq = tr.begin("f");
            let mut h = ht(i as Time * 1000);
            h.done = h.submit + extra;
            h.tx_in = h.done.min(h.tx_in);
            tr.finish(seq, h, false);
        }
        let ex = tr.exemplars();
        assert_eq!(ex.len(), 3);
        assert_eq!(ex[0].e2e, 500);
        assert_eq!(ex[1].e2e, 300);
        // Tie at 200: the earliest seq (seq 1) wins and later equals never
        // displace it.
        assert_eq!(ex[2].e2e, 200);
        assert_eq!(ex[2].seq, 1);
        assert_eq!(tr.completions(), 6);
    }

    #[test]
    fn dropped_traces_are_discarded() {
        let tr = Tracer::new_enabled(4);
        let seq = tr.begin("f");
        tr.finish(seq, ht(0), true);
        assert_eq!(tr.completions(), 0);
        assert!(tr.exemplars().is_empty());
    }

    #[test]
    fn blame_shares_sum_to_one() {
        let tr = Tracer::new_enabled(0);
        for i in 0..100 {
            let seq = tr.begin("f");
            let mut h = ht(i * 1000);
            if i >= 98 {
                // Slow tail: all the extra time lands in nic_rx. Two slow
                // completions keep the all-inclusive p50 selection (the
                // nearest-rank p50 threshold is the common 200 ns e2e)
                // majority-fast, while the p99 selection is slow-only.
                h.gateway_in += 5_000;
                h.exec_start += 5_000;
                h.exec_end += 5_000;
                h.tx_in += 5_000;
                h.done += 5_000;
            }
            tr.finish(seq, h, false);
        }
        let r = tr.blame_report();
        assert_eq!(r.count, 100);
        let sum50: f64 = r.p50.iter().sum();
        let sum99: f64 = r.p99.iter().sum();
        assert!((sum50 - 1.0).abs() < 1e-9, "p50 shares sum to {sum50}");
        assert!((sum99 - 1.0).abs() < 1e-9, "p99 shares sum to {sum99}");
        // The injected tail is nic_rx-dominated at p99 but not at p50.
        assert!(r.p99[1] > 0.9, "nic_rx p99 share {}", r.p99[1]);
        assert!(r.p50[1] < 0.5, "nic_rx p50 share {}", r.p50[1]);
        assert!(r.e2e_p99 > r.e2e_p50);
    }

    #[test]
    fn chrome_export_is_nested_and_monotone() {
        let tr = Tracer::new_enabled(2);
        let seq = tr.begin("a\"es");
        tr.event(seq, Hop::NicRx, "rx.ring", "irq_softirq", 12, 30);
        tr.event(seq, Hop::Tx, "tx.backoff", "tx_backpressure", 182, 190);
        tr.finish(seq, ht(0), false);
        let ex = tr.exemplars();
        let json = chrome_trace_json(&[(1, &ex)]);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with("]}"));
        assert!(json.contains("\"name\":\"a\\\"es\"") || json.contains("a\\\"es"));
        // Every B has a matching E and ts is nondecreasing in emit order.
        let bs = json.matches("\"ph\":\"B\"").count();
        let es = json.matches("\"ph\":\"E\"").count();
        assert_eq!(bs, es);
        assert!(bs >= 8, "root + 6 hops + 2 sub-spans, got {bs} B events");
        let mut last = f64::MIN;
        for part in json.split("\"ts\":").skip(1) {
            let ts: f64 = part.split(',').next().unwrap().parse().unwrap();
            assert!(ts >= last, "ts must be nondecreasing: {ts} after {last}");
            last = ts;
        }
    }
}
