//! Configuration system: platform cost model, experiment parameters, and a
//! small INI-style parser (serde is unavailable offline; the format is a
//! flat `key = value` file with `#` comments and optional `[sections]`).

mod ini;
mod platform;

pub use ini::Ini;
pub use platform::PlatformConfig;

use crate::simcore::Time;

/// Which execution backend hosts the faasd components and functions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Baseline: containerd sandboxes + Linux kernel networking.
    Containerd,
    /// The paper's contribution: Junction instances + kernel-bypass.
    Junctiond,
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Containerd => "containerd",
            Backend::Junctiond => "junctiond",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "containerd" | "baseline" => Ok(Backend::Containerd),
            "junctiond" | "junction" => Ok(Backend::Junctiond),
            other => anyhow::bail!("unknown backend '{other}' (containerd|junctiond)"),
        }
    }
}

/// Experiment-level knobs shared by the drivers in `experiments/`.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub backend: Backend,
    /// Provider metadata cache (§4 of the paper). Both evaluated setups have
    /// it on; the E4 ablation toggles it.
    pub provider_cache: bool,
    /// Worker server core count (paper testbed: 10-core Xeon 4114).
    pub worker_cores: usize,
    /// RNG seed for workload generation.
    pub seed: u64,
    /// Measured compute time of the function body (ns). Filled from PJRT
    /// calibration (`runtime::calibrate`) or platform defaults.
    pub function_compute_ns: Time,
    /// Concurrency limit per function instance (uProc threads / container
    /// worker threads).
    pub instance_concurrency: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            backend: Backend::Junctiond,
            provider_cache: true,
            worker_cores: 10,
            seed: 1,
            function_compute_ns: 120 * crate::simcore::MICROS,
            instance_concurrency: 4,
        }
    }
}
