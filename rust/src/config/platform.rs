//! Platform cost model: the calibrated constants from DESIGN.md §4.
//!
//! Every per-operation cost the simulators charge lives here, in one place,
//! overridable from an INI file (`--platform <file>`). Values are virtual
//! nanoseconds. Sources for the defaults are documented per field; they are
//! deliberately conservative mid-range numbers for a ~2019 Xeon (the
//! paper's testbed is a 10-core Xeon 4114 @ 2.2 GHz).

use anyhow::Result;

use super::Ini;
use crate::simcore::{Time, MICROS, MILLIS, SECONDS};

/// All simulator cost constants (ns).
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformConfig {
    // ---- host kernel path (baseline) ----
    /// One syscall trap in/out (post-KPTI `getpid`-class).
    pub syscall_ns: Time,
    /// Context switch between tasks on one core, incl. cache disturbance.
    pub context_switch_ns: Time,
    /// Hard IRQ + NAPI softirq processing per received packet.
    pub irq_softirq_ns: Time,
    /// Kernel TCP stack traversal per send or recv of a small message.
    pub kernel_stack_msg_ns: Time,
    /// Futex/epoll wakeup → task running (scheduler latency).
    pub sched_wakeup_ns: Time,
    /// One epoll_wait round (syscall + ready-list scan).
    pub epoll_round_ns: Time,
    /// Extra per-message cost of traversing a veth/bridge pair into a
    /// container network namespace (software switching the paper calls out).
    pub veth_hop_ns: Time,

    // ---- junction (kernel-bypass) path ----
    /// Junction user-space network stack per message (send or recv).
    pub junction_stack_msg_ns: Time,
    /// uThread wakeup when the instance already holds a core.
    pub junction_wakeup_ns: Time,
    /// Scheduler grants a core to an idle instance (IPI + queue scan).
    pub junction_grant_ns: Time,
    /// Junction syscall handled in user space (function-call cost).
    pub junction_syscall_ns: Time,
    /// Scheduler polling loop iteration (charged to the dedicated core).
    pub junction_poll_iter_ns: Time,
    /// Rare scheduler-contention delay on *service* instances (gateway /
    /// provider): probability per segment in 1/10000, and bounds. Models
    /// grant delays when the shared machine's cores are contended — the
    /// residual tail Junction still has end-to-end, while the function
    /// instance (which holds its core for its whole short burst) stays
    /// tight. This is why the paper's exec P99 improves more (−81%) than
    /// the gateway-observed P99 (−63%).
    pub junction_sched_tail_prob_bp: Time,
    pub junction_sched_tail_min_ns: Time,
    pub junction_sched_tail_max_ns: Time,

    // ---- RPC / faasd components ----
    /// gRPC-ish serialize + deserialize per hop (small payload).
    pub rpc_serde_ns: Time,
    /// Gateway request handling CPU (auth, route lookup).
    pub gateway_cpu_ns: Time,
    /// Provider request handling CPU (resolve, forward) when the metadata
    /// cache hits.
    pub provider_cpu_ns: Time,
    /// Extra provider cost on metadata-cache miss: a round trip to the
    /// backend manager's state store (the paper: "requests to containerd
    /// can be slower than the function invocation itself").
    pub provider_state_query_ns: Time,
    /// Same round trip against junctiond (an in-memory table behind one
    /// local RPC, not containerd's task-list machinery).
    pub junctiond_state_query_ns: Time,

    // ---- wire / physical ----
    /// One-way wire + NIC DMA latency between the two machines (100 GbE).
    pub wire_ns: Time,
    /// One-way wire latency between *sharded-cluster* endpoints (gateway
    /// rack ↔ worker racks over the aggregation fabric). This is also the
    /// parallel shard runner's conservative lookahead window — epochs are
    /// this long — so it trades fidelity (a datacenter RTT, not a ToR
    /// hop) against synchronization overhead; see DESIGN.md §3j.
    pub shard_wire_ns: Time,

    // ---- per-worker NIC / network data path (netpath) ----
    /// RX descriptor ring depth (packets) of a worker NIC queue. Arrivals
    /// beyond this are tail-dropped; the client retries with backoff.
    pub nic_queue_depth: Time,
    /// Max packets one bypass poll iteration drains (DPDK `rx_burst`-style
    /// batch). The poll cost amortizes across the batch.
    pub nic_batch_max: Time,
    /// Kernel-path per-KiB copy cost (DMA buffer → socket buffer). The
    /// bypass path is zero-copy and never pays this.
    pub nic_copy_ns_per_kb: Time,
    /// Client retransmit backoff after a tail drop.
    pub nic_retry_backoff_ns: Time,
    /// Retransmit attempts before the client gives the request up.
    pub nic_max_retries: Time,
    /// TX descriptor ring depth (frames) of a worker NIC queue. A full
    /// ring exerts *backpressure*: the responder holds the frame and
    /// re-offers it after `nic_tx_retry_backoff_ns` (nothing is lost on
    /// the wire, unlike the RX tail drop).
    pub nic_tx_queue_depth: Time,
    /// Max frames one bypass TX poll iteration flushes (DPDK
    /// `tx_burst`-style batch). The poll cost amortizes across the batch.
    pub nic_tx_batch_max: Time,
    /// Responder re-offer backoff while the TX ring is full.
    pub nic_tx_retry_backoff_ns: Time,
    /// Re-offer attempts before the worker abandons the response.
    pub nic_tx_max_retries: Time,
    /// Invocation payload carried in each framed `rpc::Message` (bytes);
    /// the AES-600B artifact's 600-byte input.
    pub rpc_payload_bytes: Time,

    // ---- lifecycle ----
    /// containerd cold start (create + start, image present).
    pub container_cold_start_ns: Time,
    /// Junction instance init (paper §5: 3.4 ms).
    pub junction_cold_start_ns: Time,

    // ---- tiered provisioning (snapshot/ subsystem) ----
    /// Acquire a warm-paused Junction instance from the pool: unpark the
    /// uProc, remap the NIC queue pair — no boot work, memory resident.
    pub junction_warm_acquire_ns: Time,
    /// Restore a Junction instance from a per-function memory snapshot
    /// (ELF image + heap pages already laid out; ≪ cold init).
    pub junction_restore_ns: Time,
    /// Capture a Junction instance snapshot after first boot (off the
    /// critical path; gates snapshot availability).
    pub junction_snapshot_capture_ns: Time,
    /// Resident memory a parked warm Junction instance holds (bytes, not
    /// virtual time — deliberately plain u64).
    pub junction_instance_mem_bytes: u64,
    /// Resume a paused container (cgroup unfreeze + route refresh).
    pub container_warm_acquire_ns: Time,
    /// CRIU-style restore of a checkpointed container (≪ cold boot, but
    /// still 10–100× the Junction restore).
    pub container_restore_ns: Time,
    /// Checkpoint a running container (off the critical path).
    pub container_snapshot_capture_ns: Time,
    /// Resident memory a paused warm container holds (bytes).
    pub container_instance_mem_bytes: u64,
    /// Global memory budget for all parked warm instances on one worker
    /// (bytes); the pool LRU-reclaims past it.
    pub pool_mem_budget_bytes: u64,
    /// Idle TTL after which a parked warm instance is evicted.
    pub pool_idle_ttl_ns: Time,

    // ---- function compute ----
    /// Default AES-600B function body compute (overridden by PJRT
    /// calibration when artifacts are present).
    pub function_compute_ns: Time,
    /// Syscalls issued by one function invocation (read input, write
    /// output, clock_gettime, allocator traps...).
    pub function_syscalls: Time,

    // ---- compute fabric (per-core structural model) ----
    /// Kernel-backend preemption quantum (CFS-style timeslice). A running
    /// segment is preempted at the next quantum edge when equal-or-higher
    /// priority work waits for its core. 0 = run to completion.
    pub sched_quantum_ns: Time,
    /// Surcharge when a segment resumes on (or is stolen to) a different
    /// core than it last ran on: cache refill + wakeup IPI.
    pub sched_migration_cost_ns: Time,
    /// Kernel backend: idle cores steal from another core's local backlog
    /// (CFS load balancing / wakeup migration). 0 = off.
    pub sched_steal: Time,
    /// Bitmask of cores that take NIC IRQ/softirq work on the kernel
    /// backend (bit i = core i). Softirq segments land on these specific
    /// cores as high-priority work, stealing cycles from whatever tenant
    /// runs there. 0 = unpinned (the seed's abstract shared-pool charge).
    pub softirq_core_mask: Time,
    /// Bypass-backend preemption quantum: the Junction scheduler's
    /// regrant granularity. A preempted grantee structurally waits for
    /// the donor core's next quantum edge. 0 = run to completion.
    pub junction_quantum_ns: Time,
    /// Keep the seed's *sampled* interference add-ons
    /// (`KernelCosts::sched_noise` / `segment_interference` and the
    /// bypass service instances' `sched_tail_delay`) as residual jitter
    /// on top of the structural model. Defaults **off** now that
    /// interference emerges from per-core contention — leaving both on
    /// would double-count the tail.
    pub residual_jitter: Time,

    // ---- kernel interference (residual tail model; see residual_jitter) ----
    /// Per-CPU-segment probability (in 1/10000) of a kernel-path
    /// interference burst: CFS throttling, GC pause coinciding with a
    /// timer tick, IRQ storm. Junction instances don't take these.
    pub kernel_interference_prob_bp: Time,
    /// Burst magnitude bounds.
    pub kernel_interference_min_ns: Time,
    pub kernel_interference_max_ns: Time,

    // ---- concurrency model ----
    /// Requests a containerd function instance serves concurrently.
    /// faasd's classic watchdog forks one fprocess per request and its
    /// container has a single veth/NAPI queue: effectively serial.
    pub container_concurrency: Time,
    /// Max cores junctiond configures per function instance (§3 scale-up:
    /// uProc threads across granted cores / multi-process).
    pub junction_max_cores: Time,

    // ---- fault plane / recovery (E16; every knob defaults off) ----
    /// Per-invocation deadline at the cluster frontend. 0 disables the
    /// whole recovery path (deadline, retry, hedging, health routing,
    /// brownout): the off position draws no randomness and schedules no
    /// events, so faults-off runs stay byte-identical to pre-fault-plane
    /// output (DESIGN.md §3h).
    pub deadline_timeout_ns: Time,
    /// Failed attempts retried against a *different* replica before the
    /// deadline resolves the request as timed out.
    pub deadline_max_retries: Time,
    /// Base backoff before a failed attempt retries on another replica;
    /// jittered (decorrelated) from the cluster's seeded fault stream.
    pub deadline_retry_backoff_ns: Time,
    /// Hedged requests: duplicate a still-pending invocation to a second
    /// replica once it has waited past this quantile (1/10000, e.g.
    /// 9500 = p95) of recently observed response times. 0 = off.
    pub hedge_quantile_bp: Time,
    /// Consecutive failed attempts on one worker before the health
    /// checker ejects it from routing. 0 = never eject.
    pub fault_health_fail_threshold: Time,
    /// How long an ejected worker stays out of routing.
    pub fault_health_eject_ns: Time,
    /// Admission-control brownout watermark (1/10000 of workers
    /// healthy): below it, Batch-class submissions are shed at the
    /// frontend so interactive work keeps the surviving capacity. 0 = off.
    pub fault_brownout_watermark_bp: Time,
    /// 0/1 flag: decorrelated jitter on the netpath RX retransmit and TX
    /// re-offer backoffs (seeded, deterministic) instead of the paper's
    /// constant backoff.
    pub nic_retry_jitter: Time,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        PlatformConfig {
            syscall_ns: 600,
            context_switch_ns: 2_500,
            irq_softirq_ns: 3 * MICROS,
            kernel_stack_msg_ns: 4 * MICROS,
            sched_wakeup_ns: 3_500,
            epoll_round_ns: 1_200,
            veth_hop_ns: 1_500,

            junction_stack_msg_ns: 1_500,
            junction_wakeup_ns: 300,
            junction_grant_ns: 1 * MICROS,
            junction_syscall_ns: 80,
            junction_poll_iter_ns: 150,
            junction_sched_tail_prob_bp: 120,
            junction_sched_tail_min_ns: 40 * MICROS,
            junction_sched_tail_max_ns: 180 * MICROS,

            rpc_serde_ns: 5 * MICROS,
            gateway_cpu_ns: 25 * MICROS,
            provider_cpu_ns: 15 * MICROS,
            provider_state_query_ns: 700 * MICROS,
            junctiond_state_query_ns: 40 * MICROS,

            wire_ns: 2 * MICROS,
            shard_wire_ns: 20 * MICROS, // cross-rack aggregation hop

            nic_queue_depth: 256,
            nic_batch_max: 32,
            nic_copy_ns_per_kb: 280,
            nic_retry_backoff_ns: 200 * MICROS,
            nic_max_retries: 3,
            nic_tx_queue_depth: 256,
            nic_tx_batch_max: 32,
            nic_tx_retry_backoff_ns: 50 * MICROS,
            nic_tx_max_retries: 8,
            rpc_payload_bytes: 600,

            container_cold_start_ns: 250 * MILLIS,
            junction_cold_start_ns: 3_400 * MICROS, // paper §5: 3.4 ms

            junction_warm_acquire_ns: 25 * MICROS,
            junction_restore_ns: 600 * MICROS,
            junction_snapshot_capture_ns: 1_500 * MICROS,
            junction_instance_mem_bytes: 64 << 20, // 64 MiB
            container_warm_acquire_ns: 2_500 * MICROS,
            container_restore_ns: 45 * MILLIS,
            container_snapshot_capture_ns: 120 * MILLIS,
            container_instance_mem_bytes: 256 << 20, // 256 MiB
            pool_mem_budget_bytes: 2 << 30, // 2 GiB of parked instances
            pool_idle_ttl_ns: 10 * SECONDS,

            function_compute_ns: 100 * MICROS,
            function_syscalls: 50,

            sched_quantum_ns: 1 * MILLIS, // CFS min-granularity scale
            sched_migration_cost_ns: 2_500,
            sched_steal: 1,
            softirq_core_mask: 0b1, // NIC IRQ affinity: core 0
            junction_quantum_ns: 20 * MICROS, // Caladan-class regrant edge
            residual_jitter: 0,

            kernel_interference_prob_bp: 150, // 1.5% of kernel CPU segments
            kernel_interference_min_ns: 100 * MICROS,
            kernel_interference_max_ns: 500 * MICROS,

            container_concurrency: 1,
            junction_max_cores: 8,

            deadline_timeout_ns: 0,
            deadline_max_retries: 0,
            deadline_retry_backoff_ns: 0,
            hedge_quantile_bp: 0,
            fault_health_fail_threshold: 0,
            fault_health_eject_ns: 0,
            fault_brownout_watermark_bp: 0,
            nic_retry_jitter: 0,
        }
    }
}

macro_rules! load_fields {
    ($cfg:ident, $ini:ident, $( $field:ident ),+ $(,)?) => {
        $(
            if let Some(v) = $ini.get_u64(concat!("platform.", stringify!($field)))? {
                $cfg.$field = v;
            } else if let Some(v) = $ini.get_u64(stringify!($field))? {
                $cfg.$field = v;
            }
        )+
    };
}

impl PlatformConfig {
    /// Load overrides from an INI file on top of the defaults.
    pub fn from_ini(ini: &Ini) -> Result<Self> {
        let mut cfg = PlatformConfig::default();
        load_fields!(
            cfg,
            ini,
            syscall_ns,
            context_switch_ns,
            irq_softirq_ns,
            kernel_stack_msg_ns,
            sched_wakeup_ns,
            epoll_round_ns,
            veth_hop_ns,
            junction_stack_msg_ns,
            junction_wakeup_ns,
            junction_grant_ns,
            junction_syscall_ns,
            junction_poll_iter_ns,
            junction_sched_tail_prob_bp,
            junction_sched_tail_min_ns,
            junction_sched_tail_max_ns,
            rpc_serde_ns,
            gateway_cpu_ns,
            provider_cpu_ns,
            provider_state_query_ns,
            junctiond_state_query_ns,
            wire_ns,
            shard_wire_ns,
            nic_queue_depth,
            nic_batch_max,
            nic_copy_ns_per_kb,
            nic_retry_backoff_ns,
            nic_max_retries,
            nic_tx_queue_depth,
            nic_tx_batch_max,
            nic_tx_retry_backoff_ns,
            nic_tx_max_retries,
            rpc_payload_bytes,
            container_cold_start_ns,
            junction_cold_start_ns,
            junction_warm_acquire_ns,
            junction_restore_ns,
            junction_snapshot_capture_ns,
            junction_instance_mem_bytes,
            container_warm_acquire_ns,
            container_restore_ns,
            container_snapshot_capture_ns,
            container_instance_mem_bytes,
            pool_mem_budget_bytes,
            pool_idle_ttl_ns,
            function_compute_ns,
            function_syscalls,
            sched_quantum_ns,
            sched_migration_cost_ns,
            sched_steal,
            softirq_core_mask,
            junction_quantum_ns,
            residual_jitter,
            kernel_interference_prob_bp,
            kernel_interference_min_ns,
            kernel_interference_max_ns,
            container_concurrency,
            junction_max_cores,
            deadline_timeout_ns,
            deadline_max_retries,
            deadline_retry_backoff_ns,
            hedge_quantile_bp,
            fault_health_fail_threshold,
            fault_health_eject_ns,
            fault_brownout_watermark_bp,
            nic_retry_jitter,
        );
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity bounds: catches typo'd config files (e.g. µs pasted as ns).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.syscall_ns > 0 && self.syscall_ns < MILLIS, "syscall_ns out of range");
        anyhow::ensure!(
            self.junction_stack_msg_ns < self.kernel_stack_msg_ns,
            "bypass stack must be cheaper than the kernel stack"
        );
        anyhow::ensure!(
            self.junction_wakeup_ns < self.sched_wakeup_ns,
            "junction wakeup must be cheaper than a kernel wakeup"
        );
        anyhow::ensure!(
            self.junction_cold_start_ns < self.container_cold_start_ns,
            "junction cold start must be below container cold start"
        );
        // Tier ladder: warm < restore < cold within each backend, and the
        // Junction tier beats the containerd tier at every rung (the gap
        // the paper's cold-start result rests on).
        anyhow::ensure!(
            self.junction_warm_acquire_ns < self.junction_restore_ns
                && self.junction_restore_ns < self.junction_cold_start_ns,
            "junction tier ladder must be warm < restore < cold"
        );
        anyhow::ensure!(
            self.container_warm_acquire_ns < self.container_restore_ns
                && self.container_restore_ns < self.container_cold_start_ns,
            "container tier ladder must be warm < restore < cold"
        );
        anyhow::ensure!(
            self.junction_warm_acquire_ns < self.container_warm_acquire_ns
                && self.junction_restore_ns < self.container_restore_ns,
            "junction tiers must be cheaper than containerd tiers"
        );
        anyhow::ensure!(self.pool_mem_budget_bytes > 0, "pool_mem_budget_bytes must be > 0");
        anyhow::ensure!(self.nic_queue_depth >= 1, "nic_queue_depth must be >= 1");
        anyhow::ensure!(self.nic_batch_max >= 1, "nic_batch_max must be >= 1");
        anyhow::ensure!(self.nic_retry_backoff_ns > 0, "nic_retry_backoff_ns must be > 0");
        anyhow::ensure!(self.nic_tx_queue_depth >= 1, "nic_tx_queue_depth must be >= 1");
        anyhow::ensure!(self.nic_tx_batch_max >= 1, "nic_tx_batch_max must be >= 1");
        anyhow::ensure!(self.nic_tx_retry_backoff_ns > 0, "nic_tx_retry_backoff_ns must be > 0");
        anyhow::ensure!(self.rpc_payload_bytes >= 1, "rpc_payload_bytes must be >= 1");
        anyhow::ensure!(self.container_concurrency >= 1, "container_concurrency must be >= 1");
        anyhow::ensure!(self.junction_max_cores >= 1, "junction_max_cores must be >= 1");
        anyhow::ensure!(
            self.kernel_interference_min_ns <= self.kernel_interference_max_ns,
            "interference bounds inverted"
        );
        anyhow::ensure!(
            self.sched_quantum_ns == 0 || self.sched_quantum_ns >= MICROS,
            "sched_quantum_ns below a plausible timeslice (ns pasted as µs?)"
        );
        anyhow::ensure!(
            self.junction_quantum_ns == 0
                || self.sched_quantum_ns == 0
                || self.junction_quantum_ns <= self.sched_quantum_ns,
            "the bypass regrant quantum must not exceed the kernel timeslice"
        );
        anyhow::ensure!(self.residual_jitter <= 1, "residual_jitter is a 0/1 flag");
        anyhow::ensure!(self.sched_steal <= 1, "sched_steal is a 0/1 flag");
        anyhow::ensure!(self.hedge_quantile_bp <= 10_000, "hedge_quantile_bp is in 1/10000");
        anyhow::ensure!(
            self.fault_brownout_watermark_bp <= 10_000,
            "fault_brownout_watermark_bp is in 1/10000"
        );
        anyhow::ensure!(self.nic_retry_jitter <= 1, "nic_retry_jitter is a 0/1 flag");
        anyhow::ensure!(
            self.shard_wire_ns >= self.wire_ns,
            "the cross-rack shard wire cannot undercut the in-rack wire"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        PlatformConfig::default().validate().unwrap();
    }

    #[test]
    fn ini_overrides_apply() {
        let ini = Ini::parse("[platform]\nsyscall_ns = 900\nwire_ns = 5000\n").unwrap();
        let cfg = PlatformConfig::from_ini(&ini).unwrap();
        assert_eq!(cfg.syscall_ns, 900);
        assert_eq!(cfg.wire_ns, 5000);
        // Untouched fields keep defaults.
        assert_eq!(cfg.context_switch_ns, PlatformConfig::default().context_switch_ns);
    }

    #[test]
    fn unsectioned_keys_also_work() {
        let ini = Ini::parse("syscall_ns = 700\n").unwrap();
        let cfg = PlatformConfig::from_ini(&ini).unwrap();
        assert_eq!(cfg.syscall_ns, 700);
    }

    #[test]
    fn inverted_stacks_rejected() {
        let ini = Ini::parse("junction_stack_msg_ns = 99999999\n").unwrap();
        assert!(PlatformConfig::from_ini(&ini).is_err());
    }
}
