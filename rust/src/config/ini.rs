//! Minimal INI-style parser: `[section]`, `key = value`, `#`/`;` comments.
//!
//! Keys are addressed as `"section.key"` (or just `"key"` for the unnamed
//! top section). Typed getters return `anyhow` errors that carry the key
//! name, so a bad platform file fails loudly at startup, not mid-run.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

/// Parsed INI contents.
#[derive(Debug, Clone, Default)]
pub struct Ini {
    values: BTreeMap<String, String>,
}

impl Ini {
    pub fn parse(text: &str) -> Result<Ini> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            if line.starts_with('[') {
                anyhow::ensure!(
                    line.ends_with(']'),
                    "line {}: unterminated section header: {raw}",
                    lineno + 1
                );
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value: {raw}", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            // Strip trailing comments.
            let v = v.split('#').next().unwrap_or("").trim().to_string();
            values.insert(key, v);
        }
        Ok(Ini { values })
    }

    pub fn load(path: &Path) -> Result<Ini> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.values
            .get(key)
            .map(|v| v.parse::<u64>().with_context(|| format!("key '{key}' = '{v}' is not u64")))
            .transpose()
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.values
            .get(key)
            .map(|v| v.parse::<f64>().with_context(|| format!("key '{key}' = '{v}' is not f64")))
            .transpose()
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>> {
        self.values
            .get(key)
            .map(|v| match v.as_str() {
                "true" | "1" | "yes" | "on" => Ok(true),
                "false" | "0" | "no" | "off" => Ok(false),
                other => anyhow::bail!("key '{key}' = '{other}' is not a bool"),
            })
            .transpose()
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top comment
answer = 42
name = junction   # trailing comment

[net]
syscall_ns = 600
enabled = true
ratio = 2.5
"#;

    #[test]
    fn parses_sections_and_types() {
        let ini = Ini::parse(SAMPLE).unwrap();
        assert_eq!(ini.get("answer"), Some("42"));
        assert_eq!(ini.get("name"), Some("junction"));
        assert_eq!(ini.get_u64("net.syscall_ns").unwrap(), Some(600));
        assert_eq!(ini.get_bool("net.enabled").unwrap(), Some(true));
        assert_eq!(ini.get_f64("net.ratio").unwrap(), Some(2.5));
        assert_eq!(ini.get("missing"), None);
    }

    #[test]
    fn type_errors_name_the_key() {
        let ini = Ini::parse("x = notanumber").unwrap();
        let err = ini.get_u64("x").unwrap_err().to_string();
        assert!(err.contains("'x'"), "{err}");
    }

    #[test]
    fn bad_section_header_rejected() {
        assert!(Ini::parse("[oops").is_err());
    }

    #[test]
    fn missing_equals_rejected() {
        assert!(Ini::parse("just a line").is_err());
    }
}
