//! The cluster-scale network data path: a bounded per-worker NIC queue.
//!
//! Every invocation crosses gateway → worker → instance as a framed
//! [`crate::rpc::Message`]; this module is the worker-side NIC those frames
//! land in. The paper's headline gap — 10× throughput at 2× lower median
//! and 3.5× lower tail — comes from *how each backend drains this queue*:
//!
//! * **containerd (kernel path)** — one packet at a time: hard IRQ +
//!   softirq, kernel stack traversal, and a DMA-buffer → socket-buffer
//!   copy per packet, all burning shared worker cores.
//! * **junctiond (bypass path)** — the scheduler's dedicated polling core
//!   drains the queue in DPDK-style `rx_burst` batches; the poll-iteration
//!   cost (see [`crate::junction::Scheduler::poll_iteration_cost`])
//!   amortizes across the batch and the RX is zero-copy.
//!
//! Overflow is a *tail drop*: the ring is `depth` descriptors deep, and an
//! arrival into a full ring is shed. The client retries with backoff a
//! bounded number of times, then gives the request up — both outcomes are
//! accounted in [`NicStats`] and surfaced per-request on
//! [`crate::faas::RequestTiming`].
//!
//! This module owns only the queue *mechanics* (bounded FIFO, burst pop,
//! drop bookkeeping); the per-packet cost sampling lives with the backend
//! cost models in `oskernel`/`junction`, and the drain engine is driven by
//! `faas::pipeline`, which knows which backend it simulates. The real-mode
//! counterpart of the same discipline is `server::ring` (bounded rings +
//! `recv_batch`).

use std::collections::VecDeque;

use crate::simcore::{Sim, Time};

/// One frame sitting in the NIC RX ring: its wire size, when it was
/// enqueued, and the continuation that resumes the pipeline on delivery.
pub struct Packet {
    pub bytes: usize,
    pub enqueued_at: Time,
    pub deliver: Box<dyn FnOnce(&mut Sim)>,
}

/// NIC counters (per worker).
#[derive(Debug, Clone, Copy, Default)]
pub struct NicStats {
    /// Packets accepted into the RX ring.
    pub rx_enqueued: u64,
    /// Packets handed to the application side.
    pub rx_delivered: u64,
    /// Arrivals shed because the ring was full (tail drop). Counts every
    /// shed attempt, so one request retried three times can contribute up
    /// to four drops.
    pub rx_dropped: u64,
    /// Client retransmissions scheduled after a tail drop.
    pub retries: u64,
    /// Client retransmit timers cancelled in O(1) because the NIC
    /// accepted the frame (the engine-cancellation fast path; with the
    /// seed heap these would have fired as dead tombstone closures).
    pub retrans_cancelled: u64,
    /// Bytes accepted into the RX ring.
    pub rx_bytes: u64,
    /// Response frames sent back through the NIC (accounting only; the TX
    /// serialization cost is charged in the pipeline's response segments).
    pub tx_packets: u64,
    pub tx_bytes: u64,
    /// Drain bursts executed. `rx_delivered / bursts` is the achieved
    /// batch amortization (1.0 on the kernel path; grows with load on the
    /// bypass path).
    pub bursts: u64,
    /// High-water mark of ring occupancy.
    pub max_depth: usize,
}

impl NicStats {
    /// Mean packets drained per burst — the bypass path's amortization
    /// factor (the kernel path pins this at 1).
    pub fn mean_batch(&self) -> f64 {
        if self.bursts == 0 {
            return 0.0;
        }
        self.rx_delivered as f64 / self.bursts as f64
    }
}

/// A bounded FIFO of [`Packet`]s with burst pop — the DES model of one
/// worker's NIC RX ring. Single-threaded by construction (lives inside the
/// pipeline's world state).
pub struct NicQueue {
    depth: usize,
    q: VecDeque<Packet>,
    /// True while the drain engine has a burst in flight; arrivals during
    /// a burst wait for the burst-end continuation instead of kicking a
    /// second engine.
    draining: bool,
    pub stats: NicStats,
}

impl NicQueue {
    pub fn new(depth: usize) -> Self {
        assert!(depth >= 1, "a NIC ring needs at least one descriptor");
        NicQueue { depth, q: VecDeque::new(), draining: false, stats: NicStats::default() }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Would an arrival right now be tail-dropped?
    pub fn is_full(&self) -> bool {
        self.q.len() >= self.depth
    }

    /// Record a shed arrival (the caller decides retry vs give-up).
    pub fn note_drop(&mut self) {
        self.stats.rx_dropped += 1;
    }

    /// Accept one packet. Returns `true` when the ring was idle and the
    /// caller must start the drain engine; `false` when a burst is already
    /// in flight and will pick this packet up. Callers must check
    /// [`NicQueue::is_full`] first.
    pub fn enqueue(&mut self, p: Packet) -> bool {
        debug_assert!(!self.is_full(), "enqueue into a full ring");
        self.stats.rx_enqueued += 1;
        self.stats.rx_bytes += p.bytes as u64;
        self.q.push_back(p);
        if self.q.len() > self.stats.max_depth {
            self.stats.max_depth = self.q.len();
        }
        if self.draining {
            false
        } else {
            self.draining = true;
            true
        }
    }

    /// Pop the next burst (up to `max` packets) for the drain engine.
    pub fn pop_burst(&mut self, max: usize) -> Vec<Packet> {
        let k = self.q.len().min(max.max(1));
        let pkts: Vec<Packet> = self.q.drain(..k).collect();
        self.stats.bursts += 1;
        self.stats.rx_delivered += pkts.len() as u64;
        pkts
    }

    /// A burst finished. Returns `true` when more packets are waiting (the
    /// engine must run another burst), `false` when the ring went idle.
    pub fn burst_done(&mut self) -> bool {
        if self.q.is_empty() {
            self.draining = false;
            false
        } else {
            true
        }
    }

    /// Account one response frame leaving through the NIC.
    pub fn note_tx(&mut self, bytes: usize) {
        self.stats.tx_packets += 1;
        self.stats.tx_bytes += bytes as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    fn pkt(bytes: usize, log: &Rc<RefCell<Vec<usize>>>, tag: usize) -> Packet {
        let log = log.clone();
        Packet {
            bytes,
            enqueued_at: 0,
            deliver: Box::new(move |_| log.borrow_mut().push(tag)),
        }
    }

    #[test]
    fn bounded_ring_sheds_overflow() {
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut nic = NicQueue::new(4);
        for i in 0..6 {
            if nic.is_full() {
                nic.note_drop();
            } else {
                nic.enqueue(pkt(100, &log, i));
            }
        }
        assert_eq!(nic.len(), 4);
        assert_eq!(nic.stats.rx_enqueued, 4);
        assert_eq!(nic.stats.rx_dropped, 2);
        assert_eq!(nic.stats.rx_bytes, 400);
    }

    #[test]
    fn first_enqueue_kicks_engine_once() {
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut nic = NicQueue::new(16);
        assert!(nic.enqueue(pkt(10, &log, 0)), "idle ring must kick the engine");
        assert!(!nic.enqueue(pkt(10, &log, 1)), "draining ring must not double-kick");
        let burst = nic.pop_burst(8);
        assert_eq!(burst.len(), 2);
        assert!(!nic.burst_done(), "empty ring goes idle");
        assert!(nic.enqueue(pkt(10, &log, 2)), "idle again: next arrival kicks");
    }

    #[test]
    fn burst_pop_respects_max_and_fifo() {
        let mut sim = Sim::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut nic = NicQueue::new(64);
        for i in 0..5 {
            nic.enqueue(pkt(10, &log, i));
        }
        let b1 = nic.pop_burst(3);
        assert_eq!(b1.len(), 3);
        for p in b1 {
            (p.deliver)(&mut sim);
        }
        assert!(nic.burst_done(), "two packets still queued");
        let b2 = nic.pop_burst(3);
        assert_eq!(b2.len(), 2);
        for p in b2 {
            (p.deliver)(&mut sim);
        }
        assert!(!nic.burst_done());
        assert_eq!(*log.borrow(), vec![0, 1, 2, 3, 4], "FIFO order");
        assert_eq!(nic.stats.bursts, 2);
        assert_eq!(nic.stats.rx_delivered, 5);
        assert!((nic.stats.mean_batch() - 2.5).abs() < 1e-9);
        assert_eq!(nic.stats.max_depth, 5);
    }
}
