//! The cluster-scale network data path: bounded per-worker NIC queues,
//! full duplex.
//!
//! Every invocation crosses gateway → worker → instance as a framed
//! [`crate::rpc::Message`]; this module is the worker-side NIC those frames
//! land in ([`NicQueue`], the RX ring) and leave through ([`TxQueue`], the
//! TX ring). The paper's headline gap — 10× throughput at 2× lower median
//! and 3.5× lower tail — comes from *how each backend drains these
//! queues*:
//!
//! * **containerd (kernel path)** — one packet at a time: hard IRQ +
//!   softirq, kernel stack traversal, and a DMA-buffer ↔ socket-buffer
//!   copy per packet in both directions, all burning shared worker cores.
//! * **junctiond (bypass path)** — the scheduler's dedicated polling core
//!   drains RX and flushes TX in DPDK-style `rx_burst`/`tx_burst` batches;
//!   the poll-iteration cost (see
//!   [`crate::junction::Scheduler::poll_iteration_cost`]) amortizes across
//!   each batch and both directions are zero-copy.
//!
//! The two rings shed differently. RX overflow is a *tail drop*: an
//! arrival into a full ring is lost on the wire, and the remote client
//! retries with backoff a bounded number of times before giving the
//! request up. TX overflow is *backpressure*: the responder still holds
//! the only copy of the frame, so a full ring stalls it — the worker
//! re-offers the frame after a backoff, and only abandons the response
//! after exhausting its stall budget. Both outcomes are accounted in
//! [`NicStats`]/[`TxStats`] and surfaced per-request on
//! [`crate::faas::RequestTiming`].
//!
//! This module owns only the queue *mechanics* (bounded FIFO, burst pop,
//! drop/stall bookkeeping); the per-packet cost sampling lives with the
//! backend cost models in `oskernel`/`junction` (the RX split
//! `nic_rx_packet`/`app_recv` and the TX split `nic_tx_packet`/`app_send`
//! of the one-shot `recv_msg`/`send_msg` costs), and the drain engines are
//! driven by `faas::pipeline`, which knows which backend it simulates. The
//! cluster front end owns an RX ring of its own for the return direction
//! (`faas::cluster`). The real-mode counterpart of the same discipline is
//! `server::ring` (bounded rings + `recv_batch`).

use std::collections::VecDeque;

use crate::simcore::{Sim, Time};

/// One frame sitting in the NIC RX ring: its wire size, when it was
/// enqueued, and the continuation that resumes the pipeline on delivery.
pub struct Packet {
    pub bytes: usize,
    pub enqueued_at: Time,
    pub deliver: Box<dyn FnOnce(&mut Sim)>,
}

/// NIC counters (per worker).
#[derive(Debug, Clone, Copy, Default)]
pub struct NicStats {
    /// Packets accepted into the RX ring.
    pub rx_enqueued: u64,
    /// Packets handed to the application side.
    pub rx_delivered: u64,
    /// Arrivals shed because the ring was full (tail drop). Counts every
    /// shed attempt, so one request retried three times can contribute up
    /// to four drops.
    pub rx_dropped: u64,
    /// Client retransmissions scheduled after a tail drop.
    pub retries: u64,
    /// Client retransmit timers cancelled in O(1) because the NIC
    /// accepted the frame (the engine-cancellation fast path; with the
    /// seed heap these would have fired as dead tombstone closures).
    pub retrans_cancelled: u64,
    /// Bytes accepted into the RX ring.
    pub rx_bytes: u64,
    /// Drain bursts executed. `rx_delivered / bursts` is the achieved
    /// batch amortization (1.0 on the kernel path; grows with load on the
    /// bypass path).
    pub bursts: u64,
    /// High-water mark of ring occupancy.
    pub max_depth: usize,
    /// Total time packets spent waiting in the RX ring (enqueue → burst
    /// pop), summed over delivered packets. `rx_ring_wait_ns /
    /// rx_delivered` is the mean ring wait — the queueing component the
    /// tracing spans attribute per request.
    pub rx_ring_wait_ns: u64,
}

impl NicStats {
    /// Mean packets drained per burst — the bypass path's amortization
    /// factor (the kernel path pins this at 1).
    pub fn mean_batch(&self) -> f64 {
        if self.bursts == 0 {
            return 0.0;
        }
        self.rx_delivered as f64 / self.bursts as f64
    }
}

/// A bounded FIFO of [`Packet`]s with burst pop — the DES model of one
/// worker's NIC RX ring. Single-threaded by construction (lives inside the
/// pipeline's world state).
pub struct NicQueue {
    depth: usize,
    q: VecDeque<Packet>,
    /// True while the drain engine has a burst in flight; arrivals during
    /// a burst wait for the burst-end continuation instead of kicking a
    /// second engine.
    draining: bool,
    pub stats: NicStats,
}

impl NicQueue {
    pub fn new(depth: usize) -> Self {
        assert!(depth >= 1, "a NIC ring needs at least one descriptor");
        NicQueue { depth, q: VecDeque::new(), draining: false, stats: NicStats::default() }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Would an arrival right now be tail-dropped?
    pub fn is_full(&self) -> bool {
        self.q.len() >= self.depth
    }

    /// Record a shed arrival (the caller decides retry vs give-up).
    pub fn note_drop(&mut self) {
        self.stats.rx_dropped += 1;
    }

    /// Accept one packet. Returns `true` when the ring was idle and the
    /// caller must start the drain engine; `false` when a burst is already
    /// in flight and will pick this packet up. Callers must check
    /// [`NicQueue::is_full`] first.
    pub fn enqueue(&mut self, p: Packet) -> bool {
        debug_assert!(!self.is_full(), "enqueue into a full ring");
        self.stats.rx_enqueued += 1;
        self.stats.rx_bytes += p.bytes as u64;
        self.q.push_back(p);
        if self.q.len() > self.stats.max_depth {
            self.stats.max_depth = self.q.len();
        }
        if self.draining {
            false
        } else {
            self.draining = true;
            true
        }
    }

    /// Pop the next burst (up to `max` packets) for the drain engine at
    /// virtual time `now` (ring-wait accounting). An empty ring pops
    /// nothing and counts *no* burst: a zero-packet poll would deflate
    /// [`NicStats::mean_batch`], the amortization stat the bypass path's
    /// throughput argument rests on.
    pub fn pop_burst(&mut self, max: usize, now: Time) -> Vec<Packet> {
        if self.q.is_empty() {
            return Vec::new();
        }
        let k = self.q.len().min(max.max(1));
        let pkts: Vec<Packet> = self.q.drain(..k).collect();
        self.stats.bursts += 1;
        self.stats.rx_delivered += pkts.len() as u64;
        for p in &pkts {
            self.stats.rx_ring_wait_ns += now.saturating_sub(p.enqueued_at);
        }
        pkts
    }

    /// A burst finished. Returns `true` when more packets are waiting (the
    /// engine must run another burst), `false` when the ring went idle.
    pub fn burst_done(&mut self) -> bool {
        if self.q.is_empty() {
            self.draining = false;
            false
        } else {
            true
        }
    }
}

/// TX-side counters (per worker).
#[derive(Debug, Clone, Copy, Default)]
pub struct TxStats {
    /// Response frames accepted into the TX ring.
    pub tx_enqueued: u64,
    /// Frames flushed out of the ring (left the worker on the wire).
    pub tx_packets: u64,
    /// Bytes accepted into the TX ring.
    pub tx_bytes: u64,
    /// Enqueue attempts refused by a full ring (backpressure stalls).
    /// Counts every refused offer, so one response stalled three times
    /// contributes three.
    pub tx_stalled: u64,
    /// Responder re-offers scheduled after a stall.
    pub tx_retries: u64,
    /// Responses abandoned after exhausting the stall budget.
    pub tx_abandoned: u64,
    /// Flush bursts executed. `tx_packets / tx_bursts` is the achieved
    /// batch amortization (1.0 on the kernel path; grows with load on the
    /// bypass path).
    pub tx_bursts: u64,
    /// High-water mark of ring occupancy.
    pub tx_max_depth: usize,
    /// Total time frames spent waiting in the TX ring (enqueue → flush
    /// pop), summed over flushed frames.
    pub tx_ring_wait_ns: u64,
}

impl TxStats {
    /// Mean frames flushed per burst — the bypass path's TX amortization
    /// factor (the kernel path pins this at 1).
    pub fn mean_batch(&self) -> f64 {
        if self.tx_bursts == 0 {
            return 0.0;
        }
        self.tx_packets as f64 / self.tx_bursts as f64
    }
}

/// A bounded FIFO of response [`Packet`]s with burst pop — the DES model
/// of one worker's NIC TX ring. Same mechanics as [`NicQueue`] with the
/// opposite overflow discipline: the responder holds a frame the ring
/// refuses (backpressure) instead of the wire losing it (tail drop).
pub struct TxQueue {
    depth: usize,
    q: VecDeque<Packet>,
    /// True while the flush engine has a burst in flight.
    draining: bool,
    pub stats: TxStats,
}

impl TxQueue {
    pub fn new(depth: usize) -> Self {
        assert!(depth >= 1, "a NIC ring needs at least one descriptor");
        TxQueue { depth, q: VecDeque::new(), draining: false, stats: TxStats::default() }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Would an offer right now stall the responder?
    pub fn is_full(&self) -> bool {
        self.q.len() >= self.depth
    }

    /// Record a refused offer (the caller decides re-offer vs abandon).
    pub fn note_stall(&mut self) {
        self.stats.tx_stalled += 1;
    }

    /// Accept one response frame. Returns `true` when the ring was idle
    /// and the caller must start the flush engine; `false` when a burst is
    /// already in flight and will pick this frame up. Callers must check
    /// [`TxQueue::is_full`] first.
    pub fn enqueue(&mut self, p: Packet) -> bool {
        debug_assert!(!self.is_full(), "enqueue into a full TX ring");
        self.stats.tx_enqueued += 1;
        self.stats.tx_bytes += p.bytes as u64;
        self.q.push_back(p);
        if self.q.len() > self.stats.tx_max_depth {
            self.stats.tx_max_depth = self.q.len();
        }
        if self.draining {
            false
        } else {
            self.draining = true;
            true
        }
    }

    /// Pop the next flush burst (up to `max` frames) at virtual time
    /// `now`. Same empty-pop guard as [`NicQueue::pop_burst`]: an empty
    /// ring counts no burst.
    pub fn pop_burst(&mut self, max: usize, now: Time) -> Vec<Packet> {
        if self.q.is_empty() {
            return Vec::new();
        }
        let k = self.q.len().min(max.max(1));
        let pkts: Vec<Packet> = self.q.drain(..k).collect();
        self.stats.tx_bursts += 1;
        self.stats.tx_packets += pkts.len() as u64;
        for p in &pkts {
            self.stats.tx_ring_wait_ns += now.saturating_sub(p.enqueued_at);
        }
        pkts
    }

    /// A flush burst finished. Returns `true` when more frames are waiting.
    pub fn burst_done(&mut self) -> bool {
        if self.q.is_empty() {
            self.draining = false;
            false
        } else {
            true
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;
    use std::rc::Rc;

    fn pkt(bytes: usize, log: &Rc<RefCell<Vec<usize>>>, tag: usize) -> Packet {
        let log = log.clone();
        Packet {
            bytes,
            enqueued_at: 0,
            deliver: Box::new(move |_| log.borrow_mut().push(tag)),
        }
    }

    #[test]
    fn bounded_ring_sheds_overflow() {
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut nic = NicQueue::new(4);
        for i in 0..6 {
            if nic.is_full() {
                nic.note_drop();
            } else {
                nic.enqueue(pkt(100, &log, i));
            }
        }
        assert_eq!(nic.len(), 4);
        assert_eq!(nic.stats.rx_enqueued, 4);
        assert_eq!(nic.stats.rx_dropped, 2);
        assert_eq!(nic.stats.rx_bytes, 400);
    }

    #[test]
    fn first_enqueue_kicks_engine_once() {
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut nic = NicQueue::new(16);
        assert!(nic.enqueue(pkt(10, &log, 0)), "idle ring must kick the engine");
        assert!(!nic.enqueue(pkt(10, &log, 1)), "draining ring must not double-kick");
        let burst = nic.pop_burst(8, 0);
        assert_eq!(burst.len(), 2);
        assert!(!nic.burst_done(), "empty ring goes idle");
        assert!(nic.enqueue(pkt(10, &log, 2)), "idle again: next arrival kicks");
    }

    #[test]
    fn burst_pop_respects_max_and_fifo() {
        let mut sim = Sim::new();
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut nic = NicQueue::new(64);
        for i in 0..5 {
            nic.enqueue(pkt(10, &log, i));
        }
        let b1 = nic.pop_burst(3, 0);
        assert_eq!(b1.len(), 3);
        for p in b1 {
            (p.deliver)(&mut sim);
        }
        assert!(nic.burst_done(), "two packets still queued");
        let b2 = nic.pop_burst(3, 0);
        assert_eq!(b2.len(), 2);
        for p in b2 {
            (p.deliver)(&mut sim);
        }
        assert!(!nic.burst_done());
        assert_eq!(*log.borrow(), vec![0, 1, 2, 3, 4], "FIFO order");
        assert_eq!(nic.stats.bursts, 2);
        assert_eq!(nic.stats.rx_delivered, 5);
        assert!((nic.stats.mean_batch() - 2.5).abs() < 1e-9);
        assert_eq!(nic.stats.max_depth, 5);
    }

    #[test]
    fn empty_pop_counts_no_burst() {
        // Regression: an empty pop used to increment `bursts` (k = 0),
        // deflating `mean_batch` below the achieved amortization.
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut nic = NicQueue::new(8);
        assert!(nic.pop_burst(4, 0).is_empty());
        assert_eq!(nic.stats.bursts, 0, "empty pop must not count a burst");
        for i in 0..4 {
            nic.enqueue(pkt(10, &log, i));
        }
        let b = nic.pop_burst(8, 0);
        assert_eq!(b.len(), 4);
        assert!(nic.pop_burst(8, 0).is_empty());
        assert_eq!(nic.stats.bursts, 1);
        assert!((nic.stats.mean_batch() - 4.0).abs() < 1e-9, "{:?}", nic.stats);
    }

    #[test]
    fn tx_ring_backpressure_and_flush() {
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut tx = TxQueue::new(2);
        assert!(tx.enqueue(pkt(50, &log, 0)), "idle ring must kick the flush engine");
        assert!(!tx.enqueue(pkt(50, &log, 1)), "flushing ring must not double-kick");
        assert!(tx.is_full());
        tx.note_stall();
        assert_eq!(tx.stats.tx_stalled, 1);
        assert_eq!(tx.stats.tx_enqueued, 2);
        assert_eq!(tx.stats.tx_bytes, 100);
        assert_eq!(tx.stats.tx_max_depth, 2);
        let burst = tx.pop_burst(8, 0);
        assert_eq!(burst.len(), 2);
        assert_eq!(tx.stats.tx_packets, 2);
        assert_eq!(tx.stats.tx_bursts, 1);
        assert!((tx.stats.mean_batch() - 2.0).abs() < 1e-9);
        assert!(!tx.burst_done(), "empty ring goes idle");
        assert!(tx.enqueue(pkt(50, &log, 2)), "idle again: next frame kicks");
    }

    #[test]
    fn tx_empty_pop_counts_no_burst() {
        let mut tx = TxQueue::new(4);
        assert!(tx.pop_burst(4, 0).is_empty());
        assert_eq!(tx.stats.tx_bursts, 0);
        assert_eq!(tx.stats.mean_batch(), 0.0);
    }

    #[test]
    fn ring_wait_accumulates_enqueue_to_pop() {
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut nic = NicQueue::new(8);
        let mut a = pkt(10, &log, 0);
        a.enqueued_at = 100;
        let mut b = pkt(10, &log, 1);
        b.enqueued_at = 250;
        nic.enqueue(a);
        nic.enqueue(b);
        let burst = nic.pop_burst(8, 400);
        assert_eq!(burst.len(), 2);
        assert_eq!(nic.stats.rx_ring_wait_ns, (400 - 100) + (400 - 250));

        let mut tx = TxQueue::new(8);
        let mut c = pkt(10, &log, 2);
        c.enqueued_at = 50;
        tx.enqueue(c);
        assert_eq!(tx.pop_burst(8, 80).len(), 1);
        assert_eq!(tx.stats.tx_ring_wait_ns, 30);
    }
}
