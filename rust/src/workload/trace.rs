//! Multi-tenant invocation traces, after the production characterization
//! the paper cites ([22] Shahrad et al., "Serverless in the Wild"):
//! a large population of functions where a few are hot and most are
//! invoked rarely (often less than once per minute), with bursty
//! arrivals.
//!
//! [`TraceGenerator`] synthesizes such a trace deterministically;
//! [`replay`] drives it through a single-node [`FaasSim`] (the density
//! experiments) or through a [`Cluster`]. The output is per-function and
//! aggregate latency, plus cold-start counts — the signals the paper's
//! §1 motivation is about (most functions are cold, so per-function
//! polling cores are unaffordable).

use std::cell::RefCell;
use std::rc::Rc;

use crate::faas::FaasSim;
use crate::simcore::{Rng, Sim, Time, TimerHandle, SECONDS};
use crate::telemetry::Samples;

/// One synthetic invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub at: Time,
    pub function: u32,
}

/// Zipf-with-burstiness trace generator.
pub struct TraceGenerator {
    pub n_functions: u32,
    /// Aggregate offered rate across all functions (rps).
    pub total_rps: f64,
    /// Zipf skew (1.0–1.3 matches the production characterization).
    pub skew: f64,
    /// Burstiness: fraction of each function's traffic arriving in bursts
    /// of 3–8 back-to-back invocations (0 = pure Poisson).
    pub burst_fraction: f64,
    pub seed: u64,
}

impl TraceGenerator {
    pub fn new(n_functions: u32, total_rps: f64, seed: u64) -> Self {
        TraceGenerator { n_functions, total_rps, skew: 1.1, burst_fraction: 0.2, seed }
    }

    /// Per-function weights (normalized Zipf).
    pub fn weights(&self) -> Vec<f64> {
        let mut w: Vec<f64> =
            (0..self.n_functions).map(|i| 1.0 / ((i + 1) as f64).powf(self.skew)).collect();
        let total: f64 = w.iter().sum();
        for x in &mut w {
            *x /= total;
        }
        w
    }

    /// Generate events over `duration`, sorted by time.
    pub fn generate(&self, duration: Time) -> Vec<TraceEvent> {
        let mut rng = Rng::new(self.seed);
        let weights = self.weights();
        // Cumulative distribution for function sampling.
        let mut cdf = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for w in &weights {
            acc += w;
            cdf.push(acc);
        }
        let mean_gap = SECONDS as f64 / self.total_rps;
        let mut events = Vec::new();
        let mut t = 0.0f64;
        while (t as Time) < duration {
            t += rng.exp(mean_gap);
            if (t as Time) >= duration {
                break;
            }
            let u = rng.next_f64();
            let f = match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
                Ok(i) => i,
                Err(i) => i.min(cdf.len() - 1),
            } as u32;
            events.push(TraceEvent { at: t as Time, function: f });
            // Bursts: occasionally a back-to-back train for the same fn.
            if rng.next_f64() < self.burst_fraction {
                let train = rng.range(2, 7);
                for k in 1..=train {
                    let bt = t as Time + k * 200_000; // 200µs apart
                    if bt < duration {
                        events.push(TraceEvent { at: bt, function: f });
                    }
                }
            }
        }
        events.sort_by_key(|e| e.at);
        events
    }
}

/// Result of replaying a trace.
#[derive(Debug, Default)]
pub struct TraceResult {
    pub latency: Samples,
    /// Deploys that were full cold boots.
    pub cold_hits: u64,
    pub completed: u64,
    /// Requests the worker NIC abandoned after its retransmit budget
    /// (never recorded in `latency`/`completed`/`tier_served`).
    pub dropped: u64,
    pub per_function_count: Vec<u64>,
    /// Provisioning events per tier (index = `ProvisionTier::idx`):
    /// warm-pool / snapshot-restore / cold-boot.
    pub provisions: [u64; 3],
    /// Completions per serving replica's provisioning tier.
    pub tier_served: [u64; 3],
}

/// Replay a trace through a single-node deployment. Functions are
/// deployed **lazily** on first invocation (the FaaS scale-from-zero
/// path), so early invocations of each function pay its cold start.
pub fn replay(
    sim: &mut Sim,
    fs: &FaasSim,
    events: &[TraceEvent],
    n_functions: u32,
    make_name: impl Fn(u32) -> String,
) -> TraceResult {
    let result = Rc::new(RefCell::new(TraceResult {
        per_function_count: vec![0; n_functions as usize],
        ..Default::default()
    }));
    let deployed: Rc<RefCell<Vec<bool>>> = Rc::new(RefCell::new(vec![false; n_functions as usize]));
    for ev in events {
        let fs2 = fs.clone();
        let result2 = result.clone();
        let deployed2 = deployed.clone();
        let name = make_name(ev.function);
        let fid = ev.function as usize;
        sim.at(ev.at, move |sim| {
            // Lazy deploy on first touch (scale-from-zero).
            if !deployed2.borrow()[fid] {
                deployed2.borrow_mut()[fid] = true;
                let spec = crate::faas::FunctionSpec::new(
                    &name,
                    "aes600",
                    crate::faas::RuntimeKind::Go,
                );
                fs2.deploy(sim, spec);
                result2.borrow_mut().cold_hits += 1;
            }
            let r3 = result2.clone();
            fs2.submit(sim, &name, move |_, t| {
                let mut r = r3.borrow_mut();
                if t.dropped {
                    r.dropped += 1;
                    return;
                }
                r.latency.record(t.gateway_observed());
                r.completed += 1;
                r.per_function_count[fid] += 1;
                r.tier_served[t.tier.idx()] += 1;
            });
        });
    }
    sim.run_to_completion();
    Rc::try_unwrap(result).ok().expect("pending refs").into_inner()
}

/// Replay with **keep-alive scale-to-zero**: a function idle for
/// `keepalive_ns` is undeployed, which parks its instances in the warm
/// pool. Rare functions then walk the full provisioning ladder — first
/// touch cold-boots (and captures a snapshot), a quick re-touch unparks
/// from the pool, and a touch after the pool's idle TTL restores from the
/// snapshot. Start `fs.start_pool_maintenance` before calling this so TTL
/// eviction (and prewarms) actually run.
///
/// Keep-alive is **one cancellable timer per function, rescheduled on
/// every touch** (submission and completion). The seed scheduled a fresh
/// "is it still idle?" closure after *every* completion and let the stale
/// ones fire as tombstones — at trace rates that is one dead event per
/// request churning through the engine; the rescheduled timer fires
/// exactly once per idle gap, at the same virtual instant the first
/// successful seed check would have fired.
pub fn replay_with_keepalive(
    sim: &mut Sim,
    fs: &FaasSim,
    events: &[TraceEvent],
    n_functions: u32,
    keepalive_ns: Time,
    make_name: impl Fn(u32) -> String,
) -> TraceResult {
    use crate::snapshot::ProvisionTier;
    let result = Rc::new(RefCell::new(TraceResult {
        per_function_count: vec![0; n_functions as usize],
        ..Default::default()
    }));
    let outstanding: Rc<RefCell<Vec<u32>>> = Rc::new(RefCell::new(vec![0; n_functions as usize]));
    let katimers: Rc<RefCell<Vec<Option<TimerHandle>>>> =
        Rc::new(RefCell::new(vec![None; n_functions as usize]));
    for ev in events {
        let fs2 = fs.clone();
        let result2 = result.clone();
        let outstanding2 = outstanding.clone();
        let katimers2 = katimers.clone();
        let name = make_name(ev.function);
        let fid = ev.function as usize;
        sim.at(ev.at, move |sim| {
            if !fs2.is_deployed(&name) {
                let spec = crate::faas::FunctionSpec::new(
                    &name,
                    "aes600",
                    crate::faas::RuntimeKind::Go,
                );
                let (_, tier) = fs2.deploy_tiered(sim, spec, true);
                let mut r = result2.borrow_mut();
                r.provisions[tier.idx()] += 1;
                if tier == ProvisionTier::ColdBoot {
                    r.cold_hits += 1;
                }
            }
            outstanding2.borrow_mut()[fid] += 1;
            keepalive_touch(sim, &fs2, fid, &name, keepalive_ns, &outstanding2, &katimers2);
            let r3 = result2.clone();
            let fs3 = fs2.clone();
            let name2 = name.clone();
            let out3 = outstanding2.clone();
            let tim3 = katimers2.clone();
            fs2.submit(sim, &name, move |sim, t| {
                {
                    let mut r = r3.borrow_mut();
                    if t.dropped {
                        r.dropped += 1;
                    } else {
                        r.latency.record(t.gateway_observed());
                        r.completed += 1;
                        r.per_function_count[fid] += 1;
                        r.tier_served[t.tier.idx()] += 1;
                    }
                }
                out3.borrow_mut()[fid] -= 1;
                keepalive_touch(sim, &fs3, fid, &name2, keepalive_ns, &out3, &tim3);
            });
        });
    }
    sim.run_to_completion();
    Rc::try_unwrap(result).ok().expect("pending refs").into_inner()
}

/// Push the function's keep-alive deadline out to `now + keepalive_ns`:
/// an armed timer is rescheduled in O(1) (same callback, new deadline);
/// otherwise a fresh timer is armed. When it finally fires — no touch for
/// a full keep-alive — the function is undeployed if nothing is in
/// flight (a mid-flight fire simply lapses; the completion's touch
/// re-arms).
fn keepalive_touch(
    sim: &mut Sim,
    fs: &FaasSim,
    fid: usize,
    name: &str,
    keepalive_ns: Time,
    outstanding: &Rc<RefCell<Vec<u32>>>,
    timers: &Rc<RefCell<Vec<Option<TimerHandle>>>>,
) {
    let deadline = sim.now() + keepalive_ns;
    let existing = timers.borrow_mut()[fid].take();
    let rearmed = match existing {
        Some(h) => sim.reschedule(h, deadline),
        None => None,
    };
    let h = match rearmed {
        Some(h) => h,
        None => {
            let fs2 = fs.clone();
            let name2 = name.to_string();
            let out2 = outstanding.clone();
            let tim2 = timers.clone();
            sim.at_handle(deadline, move |sim| {
                tim2.borrow_mut()[fid] = None;
                if out2.borrow()[fid] == 0 {
                    fs2.undeploy(sim, &name2);
                }
            })
        }
    };
    timers.borrow_mut()[fid] = Some(h);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Backend, ExperimentConfig, PlatformConfig};
    use crate::simcore::MILLIS;

    #[test]
    fn trace_is_sorted_and_deterministic() {
        let g = TraceGenerator::new(100, 1000.0, 42);
        let a = g.generate(SECONDS);
        let b = g.generate(SECONDS);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].at <= w[1].at));
        // ~1000 base events plus burst trains.
        assert!(a.len() > 800 && a.len() < 2600, "{}", a.len());
    }

    #[test]
    fn trace_is_skewed() {
        let g = TraceGenerator::new(50, 2000.0, 7);
        let events = g.generate(2 * SECONDS);
        let mut counts = vec![0u64; 50];
        for e in &events {
            counts[e.function as usize] += 1;
        }
        // Hot head: function 0 sees far more than the median function.
        let mut sorted = counts.clone();
        sorted.sort_unstable();
        assert!(counts[0] > 8 * sorted[25].max(1), "head {} median {}", counts[0], sorted[25]);
    }

    #[test]
    fn replay_completes_everything() {
        let mut sim = Sim::new();
        let cfg = ExperimentConfig { backend: Backend::Junctiond, ..Default::default() };
        let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
        let g = TraceGenerator::new(20, 500.0, 3);
        let events = g.generate(SECONDS);
        let n = events.len() as u64;
        let r = replay(&mut sim, &fs, &events, 20, |i| format!("fn-{i}"));
        assert_eq!(r.completed, n);
        assert_eq!(r.per_function_count.iter().sum::<u64>(), n);
        // Every function touched was lazily deployed exactly once.
        let touched = r.per_function_count.iter().filter(|&&c| c > 0).count() as u64;
        assert_eq!(r.cold_hits, touched);
    }

    #[test]
    fn keepalive_replay_walks_the_tier_ladder() {
        let mut sim = Sim::new();
        let cfg = ExperimentConfig { backend: Backend::Junctiond, ..Default::default() };
        let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
        // Short keep-alive + short pool TTL so a skewed bursty trace
        // exercises all three tiers: first touch cold-boots, quick
        // re-touches unpark warm, touches after the TTL restore from the
        // snapshot.
        let mut pc = fs.pool_config();
        pc.idle_ttl_ns = 300 * MILLIS;
        fs.set_pool_config(pc);
        fs.start_pool_maintenance(&mut sim, 100 * MILLIS, 20 * SECONDS);
        let g = TraceGenerator::new(16, 100.0, 5);
        let events = g.generate(8 * SECONDS);
        let n = events.len() as u64;
        let r = replay_with_keepalive(&mut sim, &fs, &events, 16, 100 * MILLIS, |i| {
            format!("fn-{i}")
        });
        assert_eq!(r.completed, n);
        assert_eq!(r.tier_served.iter().sum::<u64>(), n);
        assert!(r.provisions[2] > 0, "cold boots expected: {:?}", r.provisions);
        assert!(r.provisions[0] > 0, "warm unparks expected: {:?}", r.provisions);
        assert!(r.provisions[1] > 0, "snapshot restores expected: {:?}", r.provisions);
        assert_eq!(r.cold_hits, r.provisions[2]);
        // Warm serves must be cheaper than the cold first touches on
        // average — the ladder is why the tail improves.
        assert!(fs.pool_stats().ttl_evictions > 0, "TTL sweeps should have evicted");
    }

    #[test]
    fn junction_tail_beats_containerd_on_multi_tenant_trace() {
        // The §1 motivation scenario: many functions, skewed traffic.
        let run = |backend| {
            let mut sim = Sim::new();
            let cfg = ExperimentConfig { backend, ..Default::default() };
            let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
            let g = TraceGenerator::new(30, 800.0, 11);
            let events = g.generate(SECONDS);
            let mut r = replay(&mut sim, &fs, &events, 30, |i| format!("fn-{i}"));
            (r.latency.quantile(0.5), r.latency.quantile(0.99))
        };
        let (c50, c99) = run(Backend::Containerd);
        let (j50, j99) = run(Backend::Junctiond);
        assert!(j50 < c50, "median: junction {j50} vs containerd {c50}");
        assert!(j99 < c99, "p99: junction {j99} vs containerd {c99}");
        // Cold starts dominate the containerd tail (hundreds of ms).
        assert!(c99 > 100 * MILLIS, "containerd p99 {c99} should include cold starts");
        assert!(j99 < 100 * MILLIS, "junction p99 {j99} should stay in the ms range");
    }
}
