//! Workload generators: the paper's two experiment drivers plus a
//! multi-tenant population generator for the density ablation.
//!
//! * [`ClosedLoop`] — N sequential invocations, next submitted when the
//!   previous completes (Fig. 5: "100 sequential invocations").
//! * [`OpenLoop`] — Poisson arrivals at a configured offered rate
//!   (Fig. 6: "varying request rates offered via the front-end load
//!   balancer"). Open-loop is the right model for tail-vs-load curves:
//!   arrivals don't slow down when the system queues.
//! * [`population`] — a skewed multi-tenant function population (most
//!   functions rarely invoked, per the Shahrad et al. characterization the
//!   paper cites [22]) — and [`PopulationLoop`], the open-loop driver that
//!   offers that mix.
//!
//! Every generator runs against a [`LoadTarget`]: the single-node
//! `FaasSim` or the multi-worker `Cluster` (the cluster-scale netpath
//! experiments drive the latter).

pub mod trace;

pub use trace::{replay, replay_with_keepalive, TraceEvent, TraceGenerator, TraceResult};

use std::cell::RefCell;
use std::rc::Rc;

use crate::faas::{Cluster, FaasSim, RequestTiming};
use crate::simcore::{Rng, Sim, Time, SECONDS};
use crate::telemetry::Samples;

/// Anything a load generator can drive: the single-node [`FaasSim`] or the
/// multi-worker [`Cluster`]. The generators are written against this trait
/// so every workload (closed loop, open loop, Zipf population) targets
/// both deployments.
pub trait LoadTarget: Clone + 'static {
    fn submit_to(
        &self,
        sim: &mut Sim,
        function: &str,
        done: Box<dyn FnOnce(&mut Sim, RequestTiming)>,
    );
}

impl LoadTarget for FaasSim {
    fn submit_to(
        &self,
        sim: &mut Sim,
        function: &str,
        done: Box<dyn FnOnce(&mut Sim, RequestTiming)>,
    ) {
        self.submit(sim, function, done);
    }
}

impl LoadTarget for Rc<RefCell<Cluster>> {
    fn submit_to(
        &self,
        sim: &mut Sim,
        function: &str,
        done: Box<dyn FnOnce(&mut Sim, RequestTiming)>,
    ) {
        self.borrow_mut().submit(sim, function, done);
    }
}

/// Collected timings of one workload run.
#[derive(Debug, Default)]
pub struct RunResult {
    /// Gateway-observed latency samples (ns) — the paper's Fig. 5 metric.
    pub gateway_observed: Samples,
    /// Function-execution latency samples (ns) — Fig. 5's second series.
    pub exec: Samples,
    /// Client end-to-end samples (ns).
    pub e2e: Samples,
    /// NIC hop samples (ns): RX ring wait + per-packet service + any
    /// retransmit backoffs (see `RequestTiming::nic_hop`).
    pub nic_hop: Samples,
    /// Gateway→instance-admission samples (ns): the in-worker RPC passes
    /// and queueing before the exec window (`RequestTiming::pre_exec`).
    pub pre_exec: Samples,
    /// Transmit-hop samples (ns): TX ring wait + per-frame flush service +
    /// the return wire, plus any backpressure stalls
    /// (`RequestTiming::tx_hop`).
    pub tx_hop: Samples,
    pub submitted: u64,
    pub completed: u64,
    /// Completions that landed *inside* the measurement window — the
    /// honest achieved-throughput numerator for saturated runs (backlog
    /// draining after the window does not count).
    pub completed_in_window: u64,
    /// Requests abandoned after exhausting the NIC retransmit budget.
    pub dropped: u64,
    /// NIC retransmissions across all requests (dropped or served).
    pub retried: u64,
    /// Worker-side TX backpressure re-offers across all requests.
    pub tx_retried: u64,
    /// Requests the frontend deadline resolved before any attempt
    /// returned (fault plane; disjoint from `completed` and `dropped`).
    pub timed_out: u64,
    /// Requests whose final resolution was a failed attempt (subset of
    /// `dropped` — failures also count there, keeping the conservation
    /// law submitted == completed + dropped + timed_out).
    pub failed: u64,
    /// Requests won by a hedged duplicate rather than the primary.
    pub hedge_wins: u64,
    /// Cross-replica retry attempts across all requests (fault plane).
    pub retried_other_worker: u64,
    /// Virtual duration of the measurement window.
    pub elapsed: Time,
}

impl RunResult {
    /// Record one finished request (shared by every generator).
    fn record(&mut self, t: &RequestTiming) {
        self.retried += t.retries as u64;
        self.tx_retried += t.tx_retries as u64;
        self.retried_other_worker += t.retried_other_worker as u64;
        if t.hedge_won {
            self.hedge_wins += 1;
        }
        if t.timed_out {
            self.timed_out += 1;
            return;
        }
        if t.failed {
            self.failed += 1;
        }
        if t.dropped {
            self.dropped += 1;
            return;
        }
        self.gateway_observed.record(t.gateway_observed());
        self.exec.record(t.exec());
        self.e2e.record(t.e2e());
        self.nic_hop.record(t.nic_hop());
        self.pre_exec.record(t.pre_exec());
        self.tx_hop.record(t.tx_hop());
        self.completed += 1;
    }
}

impl RunResult {
    pub fn throughput_rps(&self) -> f64 {
        if self.elapsed == 0 {
            return 0.0;
        }
        self.completed as f64 / (self.elapsed as f64 / SECONDS as f64)
    }

    /// Achieved goodput: completions within the window / window.
    pub fn goodput_rps(&self) -> f64 {
        if self.elapsed == 0 {
            return 0.0;
        }
        self.completed_in_window as f64 / (self.elapsed as f64 / SECONDS as f64)
    }
}

/// Closed-loop sequential client.
pub struct ClosedLoop {
    pub function: String,
    pub invocations: u32,
    /// Client think time between invocations (0 = immediate).
    pub think_ns: Time,
}

impl ClosedLoop {
    pub fn new(function: &str, invocations: u32) -> Self {
        ClosedLoop { function: function.to_string(), invocations, think_ns: 0 }
    }

    /// Run to completion on `sim`, returning the collected samples.
    pub fn run(&self, sim: &mut Sim, fs: &FaasSim) -> RunResult {
        self.run_on(sim, fs)
    }

    /// Run against any [`LoadTarget`] (single node or cluster).
    pub fn run_on<T: LoadTarget>(&self, sim: &mut Sim, target: &T) -> RunResult {
        let result = Rc::new(RefCell::new(RunResult::default()));
        let start = sim.now();
        submit_next(
            sim,
            target.clone(),
            self.function.clone(),
            self.invocations,
            self.think_ns,
            result.clone(),
        );
        sim.run_to_completion();
        let mut out = Rc::try_unwrap(result).ok().expect("pending refs").into_inner();
        out.elapsed = sim.now() - start;
        out
    }
}

fn submit_next<T: LoadTarget>(
    sim: &mut Sim,
    target: T,
    function: String,
    remaining: u32,
    think: Time,
    result: Rc<RefCell<RunResult>>,
) {
    if remaining == 0 {
        return;
    }
    result.borrow_mut().submitted += 1;
    let target2 = target.clone();
    let fname = function.clone();
    target.submit_to(
        sim,
        &function,
        Box::new(move |sim, t| {
            result.borrow_mut().record(&t);
            let result2 = result.clone();
            sim.after(think, move |sim| {
                submit_next(sim, target2, fname, remaining - 1, think, result2);
            });
        }),
    );
}

/// Open-loop Poisson generator at a fixed offered rate.
pub struct OpenLoop {
    pub function: String,
    /// Offered load (requests per second).
    pub rate_rps: f64,
    /// Measurement window (virtual time). A warmup of 10% precedes it.
    pub duration: Time,
    pub seed: u64,
}

impl OpenLoop {
    pub fn new(function: &str, rate_rps: f64, duration: Time, seed: u64) -> Self {
        OpenLoop { function: function.to_string(), rate_rps, duration, seed }
    }

    /// Run the open-loop experiment. Samples recorded only inside the
    /// measurement window (after warmup); the run drains before returning.
    pub fn run(&self, sim: &mut Sim, fs: &FaasSim) -> RunResult {
        self.run_on(sim, fs)
    }

    /// Run against any [`LoadTarget`] (single node or cluster).
    pub fn run_on<T: LoadTarget>(&self, sim: &mut Sim, target: &T) -> RunResult {
        let function = self.function.clone();
        open_loop_drive(sim, target, self.rate_rps, self.duration, self.seed, move |_| {
            function.clone()
        })
    }
}

/// Arrivals generated per batch by the open-loop driver.
const ARRIVAL_BATCH: usize = 4096;

/// Deterministic Poisson arrival generator, paused at batch boundaries.
/// Consumes the RNG in exactly the seed's order (one `exp` draw per gap,
/// then `pick`'s draws), so the arrival schedule is bit-identical to the
/// fully pre-generated list the seed materialized.
struct ArrivalGen<P> {
    rng: Rng,
    t: f64,
    mean_gap_ns: f64,
    measure_until: Time,
    exhausted: bool,
    pick: P,
}

impl<P: FnMut(&mut Rng) -> String> ArrivalGen<P> {
    fn refill(&mut self, batch: &mut Vec<(Time, String)>) {
        batch.clear();
        while !self.exhausted && batch.len() < ARRIVAL_BATCH {
            self.t += self.rng.exp(self.mean_gap_ns);
            if (self.t as Time) < self.measure_until {
                batch.push((self.t as Time, (self.pick)(&mut self.rng)));
            } else {
                self.exhausted = true;
            }
        }
    }
}

/// Shared open-loop driver: Poisson arrivals at `rate_rps`, each arrival
/// invoking whatever `pick` chooses, samples recorded only inside the
/// measurement window (a warmup of 10% of `duration` precedes it); the
/// run drains before returning. The arrival schedule is deterministic and
/// independent of completion order, but instead of materializing one
/// pre-scheduled closure per request up front (10M pending events at
/// density scale), arrivals are generated in bounded batches scheduled
/// straight into the engine's timer wheel: the driver keeps at most one
/// batch outstanding, and the last arrival of each batch schedules the
/// next.
fn open_loop_drive<T: LoadTarget, P: FnMut(&mut Rng) -> String + 'static>(
    sim: &mut Sim,
    target: &T,
    rate_rps: f64,
    duration: Time,
    seed: u64,
    pick: P,
) -> RunResult {
    assert!(rate_rps > 0.0);
    let result = Rc::new(RefCell::new(RunResult::default()));
    let warmup = duration / 10;
    let t_start = sim.now();
    let measure_from = t_start + warmup;
    let measure_until = measure_from + duration;
    let arrivals = Rc::new(RefCell::new(ArrivalGen {
        rng: Rng::new(seed),
        t: t_start as f64,
        mean_gap_ns: SECONDS as f64 / rate_rps,
        measure_until,
        exhausted: t_start >= measure_until,
        pick,
    }));
    schedule_arrival_batch(sim, target.clone(), result.clone(), arrivals, measure_from, measure_until);
    sim.run_to_completion();
    let mut out = Rc::try_unwrap(result).ok().expect("pending refs").into_inner();
    out.elapsed = duration;
    out
}

fn schedule_arrival_batch<T: LoadTarget, P: FnMut(&mut Rng) -> String + 'static>(
    sim: &mut Sim,
    target: T,
    result: Rc<RefCell<RunResult>>,
    arrivals: Rc<RefCell<ArrivalGen<P>>>,
    measure_from: Time,
    measure_until: Time,
) {
    let mut batch = Vec::new();
    arrivals.borrow_mut().refill(&mut batch);
    let n = batch.len();
    for (i, (at, function)) in batch.into_iter().enumerate() {
        let in_window = at >= measure_from;
        let target2 = target.clone();
        let result2 = result.clone();
        // The batch's last arrival refills and schedules the next batch.
        let chain = if i + 1 == n { Some(arrivals.clone()) } else { None };
        sim.at(at, move |sim| {
            if in_window {
                result2.borrow_mut().submitted += 1;
            }
            let r3 = result2.clone();
            target2.submit_to(
                sim,
                &function,
                Box::new(move |_, timing| {
                    if in_window {
                        let mut r = r3.borrow_mut();
                        r.record(&timing);
                        if !timing.dropped && !timing.timed_out && timing.done <= measure_until {
                            r.completed_in_window += 1;
                        }
                    }
                }),
            );
            if let Some(next) = chain {
                schedule_arrival_batch(sim, target2, result2, next, measure_from, measure_until);
            }
        });
    }
}

/// Zipf-skewed multi-tenant driver: aggregate Poisson arrivals at
/// `rate_rps`, each invocation sampling a function from a weighted
/// [`population`]. Targets single-node and cluster deployments alike —
/// the cluster case is the paper's Figure 1 front end fanning a skewed
/// tenant mix across the worker pool.
pub struct PopulationLoop {
    /// (function, weight) pairs; weights need not be normalized.
    pub functions: Vec<(String, f64)>,
    /// Aggregate offered load (requests per second).
    pub rate_rps: f64,
    /// Measurement window (virtual time). A warmup of 10% precedes it.
    pub duration: Time,
    pub seed: u64,
}

impl PopulationLoop {
    pub fn new(functions: Vec<(String, f64)>, rate_rps: f64, duration: Time, seed: u64) -> Self {
        PopulationLoop { functions, rate_rps, duration, seed }
    }

    /// Run against any [`LoadTarget`]; every function in the population
    /// must already be deployed on the target.
    pub fn run_on<T: LoadTarget>(&self, sim: &mut Sim, target: &T) -> RunResult {
        assert!(!self.functions.is_empty());
        let total_w: f64 = self.functions.iter().map(|(_, w)| w).sum();
        // Cumulative weights + binary search: O(log n) per arrival. The
        // seed's linear scan was fine for dozens of functions but
        // dominates the generator at the density experiment's
        // million-function populations. Not floating-point-identical to
        // the scan (prefix sums round differently than iterative
        // subtraction), so a roll within an ulp of a bucket boundary may
        // pick the adjacent function relative to the pre-rewrite seed;
        // runs remain fully deterministic and engine-independent.
        let mut cdf = Vec::with_capacity(self.functions.len());
        let mut acc = 0.0;
        for (_, w) in &self.functions {
            acc += w;
            cdf.push(acc);
        }
        let names: Vec<String> = self.functions.iter().map(|(n, _)| n.clone()).collect();
        let pick = move |rng: &mut Rng| {
            let roll = rng.next_f64() * total_w;
            let i = match cdf.binary_search_by(|p| p.partial_cmp(&roll).unwrap()) {
                // Exact boundary hit: the strict `roll < cum` rule the
                // linear scan used moves past an exactly-equal edge.
                Ok(i) => i + 1,
                Err(i) => i,
            }
            .min(names.len() - 1);
            names[i].clone()
        };
        open_loop_drive(sim, target, self.rate_rps, self.duration, self.seed, pick)
    }
}

/// Generate a skewed multi-tenant function population: `n` functions whose
/// relative invocation weights follow a Zipf-ish distribution (a few hot
/// functions, a long cold tail — Shahrad et al. [22]).
pub fn population(n: usize, rng: &mut Rng) -> Vec<(String, f64)> {
    let mut fns = Vec::with_capacity(n);
    let mut total = 0.0;
    for i in 0..n {
        let w = 1.0 / ((i + 1) as f64).powf(1.1) * (0.75 + 0.5 * rng.next_f64());
        total += w;
        fns.push((format!("fn-{i:04}"), w));
    }
    for f in &mut fns {
        f.1 /= total;
    }
    fns
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Backend, ExperimentConfig, PlatformConfig};
    use crate::faas::{FunctionSpec, RuntimeKind};
    use crate::simcore::MILLIS;

    fn setup(backend: Backend) -> (Sim, FaasSim) {
        let mut sim = Sim::new();
        let cfg = ExperimentConfig { backend, ..Default::default() };
        let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
        fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        sim.run_until(SECONDS); // past cold start
        (sim, fs)
    }

    #[test]
    fn closed_loop_completes_all() {
        let (mut sim, fs) = setup(Backend::Junctiond);
        let r = ClosedLoop::new("aes", 100).run(&mut sim, &fs);
        assert_eq!(r.submitted, 100);
        assert_eq!(r.completed, 100);
        assert_eq!(r.gateway_observed.len(), 100);
    }

    #[test]
    fn closed_loop_is_sequential() {
        // With one request in flight at a time, total duration >= sum of
        // latencies.
        let (mut sim, fs) = setup(Backend::Containerd);
        let t0 = sim.now();
        let mut r = ClosedLoop::new("aes", 20).run(&mut sim, &fs);
        let wall = sim.now() - t0;
        let sum: u64 = r.e2e.values().iter().sum();
        assert!(wall >= sum, "wall {wall} < sum of latencies {sum}");
        assert!(r.e2e.quantile(0.5) > 0);
    }

    #[test]
    fn open_loop_offered_rate_is_respected() {
        let (mut sim, fs) = setup(Backend::Junctiond);
        let r = OpenLoop::new("aes", 2000.0, 2 * SECONDS, 42).run(&mut sim, &fs);
        // 2000 rps over a 2s measurement window ≈ 4000 completions ± noise.
        assert!(r.completed > 3600 && r.completed < 4400, "completed={}", r.completed);
        let tput = r.throughput_rps();
        assert!((tput - 2000.0).abs() < 220.0, "tput={tput}");
    }

    #[test]
    fn open_loop_latency_grows_with_load() {
        let (mut sim, fs) = setup(Backend::Containerd);
        let mut low = OpenLoop::new("aes", 200.0, 2 * SECONDS, 7).run(&mut sim, &fs);
        let (mut sim2, fs2) = setup(Backend::Containerd);
        // Far beyond the serial instance's capacity (~1/exec_time ≈ 4.7k).
        let mut high = OpenLoop::new("aes", 9000.0, 2 * SECONDS, 7).run(&mut sim2, &fs2);
        assert!(
            high.gateway_observed.quantile(0.5) > 4 * low.gateway_observed.quantile(0.5),
            "saturation should blow up latency: low={} high={}",
            low.gateway_observed.quantile(0.5),
            high.gateway_observed.quantile(0.5)
        );
    }

    #[test]
    fn open_loop_deterministic() {
        let (mut a_sim, a_fs) = setup(Backend::Junctiond);
        let mut a = OpenLoop::new("aes", 500.0, SECONDS, 3).run(&mut a_sim, &a_fs);
        let (mut b_sim, b_fs) = setup(Backend::Junctiond);
        let mut b = OpenLoop::new("aes", 500.0, SECONDS, 3).run(&mut b_sim, &b_fs);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.gateway_observed.quantile(0.99), b.gateway_observed.quantile(0.99));
    }

    /// Pipeline-free target: completes every request after a fixed
    /// latency. Isolates the generator's arrival process from system
    /// queueing, so rate properties test the generator itself.
    #[derive(Clone)]
    struct InstantTarget {
        latency: Time,
    }

    impl LoadTarget for InstantTarget {
        fn submit_to(
            &self,
            sim: &mut Sim,
            _function: &str,
            done: Box<dyn FnOnce(&mut Sim, crate::faas::RequestTiming)>,
        ) {
            let submit = sim.now();
            sim.after(self.latency, move |sim| {
                let now = sim.now();
                let t = crate::faas::RequestTiming {
                    submit,
                    nic_in: submit,
                    gateway_in: submit,
                    exec_start: submit,
                    exec_end: now,
                    done: now,
                    ..Default::default()
                };
                done(sim, t);
            });
        }
    }

    #[test]
    fn property_open_loop_offered_rate_within_5pct() {
        use crate::simcore::{forall, Gen, MICROS};
        forall("open-loop offered rate", 25, |g: &mut Gen| {
            let rate = g.u64(5_000, 12_000) as f64;
            let seed = g.u64(0, u64::MAX - 1);
            let mut sim = Sim::new();
            let target = InstantTarget { latency: 10 * MICROS };
            let r = OpenLoop::new("f", rate, 2 * SECONDS, seed).run_on(&mut sim, &target);
            let offered = r.submitted as f64 / (r.elapsed as f64 / SECONDS as f64);
            let err = (offered - rate).abs() / rate;
            assert!(
                err < 0.05,
                "offered {offered:.0} vs configured {rate:.0} rps (err {err:.3})"
            );
            assert_eq!(r.completed, r.submitted, "instant target completes everything");
        });
    }

    #[test]
    fn open_loop_drives_cluster() {
        use crate::config::Backend;
        use crate::faas::Cluster;
        let mut sim = Sim::new();
        let mut c = Cluster::new(Backend::Junctiond, 3, 10, 1, 100_000);
        c.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        c.scale_up(&mut sim, "aes");
        c.scale_up(&mut sim, "aes");
        sim.run_until(SECONDS);
        let c = Rc::new(RefCell::new(c));
        let r = OpenLoop::new("aes", 3_000.0, SECONDS, 5).run_on(&mut sim, &c);
        assert!(r.completed > 2_500, "completed {}", r.completed);
        assert_eq!(r.dropped, 0);
        assert_eq!(r.nic_hop.len(), r.completed as usize, "per-hop samples recorded");
        // The least-inflight front end spreads load over all three workers.
        let served: Vec<u64> =
            c.borrow().workers.iter().map(|w| w.sim_node.completed()).collect();
        assert!(served.iter().all(|&s| s > 0), "all workers must serve: {served:?}");
    }

    #[test]
    fn closed_loop_drives_cluster() {
        use crate::config::Backend;
        use crate::faas::Cluster;
        let mut sim = Sim::new();
        let mut c = Cluster::new(Backend::Containerd, 2, 10, 1, 100_000);
        c.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        sim.run_until(SECONDS);
        let c = Rc::new(RefCell::new(c));
        let r = ClosedLoop::new("aes", 40).run_on(&mut sim, &c);
        assert_eq!(r.completed, 40);
        assert_eq!(r.submitted, 40);
    }

    #[test]
    fn population_loop_drives_cluster_with_zipf_mix() {
        use crate::config::Backend;
        use crate::faas::Cluster;
        let mut sim = Sim::new();
        let mut c = Cluster::new(Backend::Junctiond, 2, 10, 2, 100_000);
        let mut rng = Rng::new(9);
        let pop = population(8, &mut rng);
        for (name, _) in &pop {
            c.deploy(&mut sim, FunctionSpec::new(name, "aes600", RuntimeKind::Go));
        }
        sim.run_until(SECONDS);
        let c = Rc::new(RefCell::new(c));
        let r = PopulationLoop::new(pop, 2_000.0, SECONDS, 3).run_on(&mut sim, &c);
        assert!(r.completed > 1_700, "completed {}", r.completed);
        assert_eq!(r.dropped, 0);
        let served: u64 = c.borrow().workers.iter().map(|w| w.sim_node.completed()).sum();
        assert!(served >= r.completed, "cluster served {served} < recorded {}", r.completed);
    }

    #[test]
    fn population_weights_sum_to_one() {
        let mut rng = Rng::new(1);
        let pop = population(500, &mut rng);
        assert_eq!(pop.len(), 500);
        let total: f64 = pop.iter().map(|(_, w)| w).sum();
        assert!((total - 1.0).abs() < 1e-9);
        // Skew: head function dominates the median one.
        assert!(pop[0].1 > 20.0 * pop[250].1);
    }

    #[test]
    fn cold_start_visible_in_first_sample() {
        let mut sim = Sim::new();
        let cfg = ExperimentConfig { backend: Backend::Containerd, ..Default::default() };
        let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
        fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        // No warmup wait: first request hits the cold container.
        let mut r = ClosedLoop::new("aes", 3).run(&mut sim, &fs);
        let vals = r.e2e.values().to_vec();
        assert!(vals[0] > 100 * MILLIS);
        assert!(vals[1] < 10 * MILLIS);
        let _ = r.e2e.quantile(0.5);
    }
}
