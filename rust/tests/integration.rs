//! Integration tests: whole-stack flows across modules — DES pipeline over
//! both backends, PJRT runtime on the real artifacts, real-mode serving,
//! and cross-layer consistency (simulated service time == calibrated real
//! compute).

use std::cell::RefCell;
use std::rc::Rc;

use junctiond_repro::config::{Backend, ExperimentConfig, PlatformConfig};
use junctiond_repro::experiments as ex;
use junctiond_repro::faas::{FaasSim, FunctionSpec, RuntimeKind, ScaleMode};
use junctiond_repro::runtime::{calibrate, default_artifacts_dir, rustcrypto_aes_ctr, Executor};
use junctiond_repro::server::{run_pipeline, ServeMode};
use junctiond_repro::simcore::{Sim, MILLIS, SECONDS};
use junctiond_repro::workload::{ClosedLoop, OpenLoop};

fn cfg(backend: Backend) -> ExperimentConfig {
    ExperimentConfig { backend, ..Default::default() }
}

// ---------------------------------------------------------------------------
// DES pipeline, end to end
// ---------------------------------------------------------------------------

#[test]
fn full_faasd_flow_both_backends() {
    for backend in [Backend::Containerd, Backend::Junctiond] {
        let mut sim = Sim::new();
        let fs = FaasSim::new(&cfg(backend), Rc::new(PlatformConfig::default()));
        let cold = fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        assert!(cold > 0);
        sim.run_until(SECONDS);
        let r = ClosedLoop::new("aes", 50).run(&mut sim, &fs);
        assert_eq!(r.completed, 50, "{backend:?}");
        assert_eq!(fs.completed(), 50);
    }
}

#[test]
fn multiple_functions_roundrobin_and_cache() {
    let mut sim = Sim::new();
    let fs = FaasSim::new(&cfg(Backend::Junctiond), Rc::new(PlatformConfig::default()));
    for name in ["aes", "mlp", "rowsum"] {
        fs.deploy(&mut sim, FunctionSpec::new(name, "aes600", RuntimeKind::Go));
    }
    sim.run_until(SECONDS);
    let done = Rc::new(RefCell::new(0u32));
    for name in ["aes", "mlp", "rowsum", "aes", "mlp", "rowsum"] {
        let done2 = done.clone();
        fs.submit(&mut sim, name, move |_, _| *done2.borrow_mut() += 1);
        sim.run_to_completion();
    }
    assert_eq!(*done.borrow(), 6);
    let (hits, misses) = fs.provider_stats();
    assert_eq!(misses, 3, "one cold resolve per function");
    assert_eq!(hits, 3);
}

#[test]
fn isolated_replicas_spread_load() {
    let mut sim = Sim::new();
    let fs = FaasSim::new(&cfg(Backend::Junctiond), Rc::new(PlatformConfig::default()));
    fs.deploy(
        &mut sim,
        FunctionSpec::new("aes", "aes600", RuntimeKind::Go)
            .with_scale(ScaleMode::IsolatedInstances, 3),
    );
    sim.run_until(SECONDS);
    let r = OpenLoop::new("aes", 5_000.0, SECONDS, 11).run(&mut sim, &fs);
    assert!(r.completed > 4_000, "completed {}", r.completed);
}

#[test]
fn junctiond_scheduler_sees_all_traffic() {
    let mut sim = Sim::new();
    let fs = FaasSim::new(&cfg(Backend::Junctiond), Rc::new(PlatformConfig::default()));
    fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
    sim.run_until(SECONDS);
    ClosedLoop::new("aes", 25).run(&mut sim, &fs);
    let stats = fs.scheduler_stats();
    // Each invocation wakes gateway (×2 passes), provider (×2) and the
    // function instance at least once.
    assert!(stats.grants + stats.warm_wakeups >= 5 * 25, "{stats:?}");
}

#[test]
fn overload_recovers_after_burst() {
    // Saturate containerd far past its knee, then verify a subsequent
    // sequential run returns to baseline (no leaked state/queue).
    let mut sim = Sim::new();
    let fs = FaasSim::new(&cfg(Backend::Containerd), Rc::new(PlatformConfig::default()));
    fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
    sim.run_until(SECONDS);
    let burst = OpenLoop::new("aes", 20_000.0, SECONDS / 2, 9).run(&mut sim, &fs);
    assert!(burst.completed > 0);
    let mut after = ClosedLoop::new("aes", 20).run(&mut sim, &fs);
    assert!(
        after.gateway_observed.quantile(0.5) < 2 * MILLIS,
        "post-burst median {}µs should be warm-baseline",
        after.gateway_observed.quantile(0.5) / 1000
    );
}

// ---------------------------------------------------------------------------
// PJRT runtime ↔ simulator consistency
// ---------------------------------------------------------------------------

#[test]
fn calibration_feeds_simulation() {
    let exec = Executor::load(&default_artifacts_dir()).expect("make artifacts first");
    let cal = calibrate(&exec, 10).unwrap();
    let mut cfg = cfg(Backend::Junctiond);
    cfg.function_compute_ns = cal.p50_ns;
    let mut sim = Sim::new();
    let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
    fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
    sim.run_until(SECONDS);
    let mut r = ClosedLoop::new("aes", 20).run(&mut sim, &fs);
    // Simulated exec window must contain the real calibrated compute.
    assert!(r.exec.quantile(0.5) >= cal.p50_ns);
    assert!(r.exec.quantile(0.5) < cal.p50_ns + 100_000);
}

#[test]
fn mlp_and_rowsum_artifacts_execute() {
    let exec = Executor::load(&default_artifacts_dir()).expect("make artifacts first");
    // mlp_infer: (1,64) f32 — exercised through generic execute via i32 is
    // wrong dtype, so check shape metadata and run aes_blocks instead.
    let mlp = exec.artifact("mlp_infer").unwrap();
    assert_eq!(mlp.args[0].shape, vec![1, 64]);
    let blocks = vec![0i32; 256 * 16];
    let rks = vec![0i32; 11 * 16];
    let out = exec.invoke_i32("aes_blocks", &[blocks, rks]).unwrap();
    assert_eq!(out.len(), 256 * 16);
    // All-zero key ECB of all-zero block, FIPS-197-derivable constant:
    // every block identical.
    assert_eq!(&out[..16], &out[16..32]);
}

// ---------------------------------------------------------------------------
// Real-mode serving
// ---------------------------------------------------------------------------

#[test]
fn real_pipeline_matches_rustcrypto_both_modes() {
    for mode in [ServeMode::Kernel, ServeMode::Bypass] {
        let mut h = run_pipeline(mode, default_artifacts_dir()).unwrap();
        let mut pt = [0u8; 600];
        for (i, b) in pt.iter_mut().enumerate() {
            *b = (i * 7 % 256) as u8;
        }
        let ct = h.invoke_aes600(&pt).unwrap();
        assert_eq!(ct, rustcrypto_aes_ctr(&pt, b"junctiond-repro!", &[7u8; 12]), "{mode:?}");
        h.shutdown().unwrap();
    }
}

// ---------------------------------------------------------------------------
// Tiered provisioning (snapshot/ subsystem), whole stack
// ---------------------------------------------------------------------------

#[test]
fn provisioning_tier_ladder_end_to_end() {
    use junctiond_repro::snapshot::ProvisionTier;
    use junctiond_repro::telemetry::MetricsRegistry;
    for backend in [Backend::Containerd, Backend::Junctiond] {
        let mut sim = Sim::new();
        let fs = FaasSim::new(&cfg(backend), Rc::new(PlatformConfig::default()));
        let spec = FunctionSpec::new("aes", "aes600", RuntimeKind::Go);
        // Rung 3: cold boot (captures the snapshot off the critical path).
        let (cold, tier) = fs.deploy_tiered(&mut sim, spec.clone(), true);
        assert_eq!(tier, ProvisionTier::ColdBoot, "{backend:?}");
        sim.run_until(SECONDS);
        ClosedLoop::new("aes", 10).run(&mut sim, &fs);
        // Rung 1: park + warm re-acquire.
        assert!(fs.undeploy(&mut sim, "aes"));
        let (warm, tier) = fs.deploy_tiered(&mut sim, spec.clone(), true);
        assert_eq!(tier, ProvisionTier::WarmPool, "{backend:?}");
        ClosedLoop::new("aes", 10).run(&mut sim, &fs);
        // Rung 2: pool flushed → snapshot restore.
        assert!(fs.undeploy(&mut sim, "aes"));
        fs.flush_warm_pool(&mut sim);
        let (restore, tier) = fs.deploy_tiered(&mut sim, spec, true);
        assert_eq!(tier, ProvisionTier::SnapshotRestore, "{backend:?}");
        ClosedLoop::new("aes", 10).run(&mut sim, &fs);
        assert!(
            warm < restore && restore < cold,
            "{backend:?} ladder: warm {warm} restore {restore} cold {cold}"
        );
        // Every invocation was served and attributed to its replica's tier.
        let (provisioned, served) = fs.tier_counts();
        assert!(provisioned.iter().all(|&p| p >= 1), "{provisioned:?}");
        assert_eq!(served, [10, 10, 10], "{backend:?} served {served:?}");
        let mut reg = MetricsRegistry::new();
        fs.export_metrics(&mut reg);
        let text = reg.expose();
        assert!(text.contains("invocations_served_total"));
        assert!(text.contains("tier=\"warm-pool\""));
        assert!(text.contains("snapshot_captures_total"));
    }
}

// ---------------------------------------------------------------------------
// Engine differential: the wheel engine and the seed-shaped reference
// heap must produce identical virtual-time experiment outputs (satellite
// of the engine rebuild; the unit-level property test lives in simcore)
// ---------------------------------------------------------------------------

#[test]
fn e5_polling_table_identical_across_engines() {
    use junctiond_repro::simcore::{set_default_engine, EngineKind};
    let run = || ex::ablation_polling_table(&[1, 16, 64], 5).to_markdown();
    let wheel = run();
    let prev = set_default_engine(EngineKind::ReferenceHeap);
    let heap = run();
    set_default_engine(prev);
    assert_eq!(wheel, heap, "E5 virtual-time outputs diverged between engines");
}

#[test]
fn e11_netpath_table_identical_across_engines() {
    use junctiond_repro::simcore::{set_default_engine, EngineKind};
    let rates = [1_000.0, 3_000.0];
    let run = || {
        let (t, points) = ex::netpath_table(2, 10, &rates, &rates, 200 * MILLIS, 7);
        let details: Vec<(u64, u64, u64, u64)> =
            points.iter().map(|p| (p.p50, p.p99, p.dropped, p.retries)).collect();
        (t.to_markdown(), details)
    };
    let wheel = run();
    let prev = set_default_engine(EngineKind::ReferenceHeap);
    let heap = run();
    set_default_engine(prev);
    assert_eq!(wheel.0, heap.0, "E11 table diverged between engines");
    assert_eq!(wheel.1, heap.1, "E11 per-point results diverged between engines");
}

// ---------------------------------------------------------------------------
// Fabric differential: the per-core compute fabric degraded to the seed
// semantics (CompatFifo: quantum = ∞, stealing off, affinity/classes
// collapsed) must produce identical virtual-time experiment outputs to
// the retained seed pool (ReferenceFifo) — the same technique the PR 3
// engine swap used (the unit-level property test lives in simcore).
// ---------------------------------------------------------------------------

#[test]
fn e5_polling_table_identical_across_fabrics() {
    use junctiond_repro::simcore::{set_default_fabric, FabricKind};
    let run = || ex::ablation_polling_table(&[1, 16, 64], 5).to_markdown();
    let prev = set_default_fabric(FabricKind::CompatFifo);
    let compat = run();
    set_default_fabric(FabricKind::ReferenceFifo);
    let reference = run();
    set_default_fabric(prev);
    assert_eq!(compat, reference, "E5 outputs diverged between compat fabric and seed pool");
}

#[test]
fn e11_netpath_table_identical_across_fabrics() {
    use junctiond_repro::simcore::{set_default_fabric, FabricKind};
    let rates = [1_000.0, 3_000.0];
    let run = || {
        let (t, points) = ex::netpath_table(2, 10, &rates, &rates, 200 * MILLIS, 7);
        let details: Vec<(u64, u64, u64, u64)> =
            points.iter().map(|p| (p.p50, p.p99, p.dropped, p.retries)).collect();
        (t.to_markdown(), details)
    };
    let prev = set_default_fabric(FabricKind::CompatFifo);
    let compat = run();
    set_default_fabric(FabricKind::ReferenceFifo);
    let reference = run();
    set_default_fabric(prev);
    assert_eq!(compat.0, reference.0, "E11 table diverged between compat fabric and seed pool");
    assert_eq!(compat.1, reference.1, "E11 per-point results diverged between fabrics");
}

// ---------------------------------------------------------------------------
// Experiment drivers smoke (small sizes)
// ---------------------------------------------------------------------------

#[test]
fn experiment_tables_have_expected_shape() {
    let t = ex::coldstart_table(5, 1);
    assert_eq!(t.rows.len(), 4);
    let t = ex::ablation_cache_table(20, 1);
    assert_eq!(t.rows.len(), 4);
    let t = ex::ablation_polling_table(&[1, 16], 1);
    assert_eq!(t.rows.len(), 2);
    assert_eq!(t.columns.len(), 6);
}
