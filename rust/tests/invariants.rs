//! Conservation tests for every public `*Stats` block, plus the
//! end-to-end `selfcheck` run: after the audit-bearing experiments
//! (E5 / E11 / E14 / E15) finish on both backends, `audit_all` must find
//! nothing. detlint's `unaudited_stats` rule (L4) anchors here — each
//! counter struct is named below, so removing its coverage trips the
//! linter.

use std::rc::Rc;

use junctiond_repro::config::{Backend, ExperimentConfig, PlatformConfig};
use junctiond_repro::experiments as ex;
use junctiond_repro::faas::{FaasSim, FunctionSpec, RuntimeKind};
use junctiond_repro::invariants::audit_all;
use junctiond_repro::junction::SchedulerStats;
use junctiond_repro::netpath::{NicStats, TxStats};
use junctiond_repro::simcore::{EngineStats, FabricStats, Sim, MILLIS, SECONDS};
use junctiond_repro::snapshot::PoolStats;
use junctiond_repro::workload::ClosedLoop;

/// Drive a short closed loop to a drained quiesce point and return the
/// sim + node for counter inspection.
fn drained(backend: Backend, seed: u64) -> (Sim, FaasSim, u64) {
    let cfg = ExperimentConfig {
        backend,
        function_compute_ns: 100_000,
        seed,
        ..Default::default()
    };
    let mut sim = Sim::new();
    let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
    fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
    sim.run_until(SECONDS);
    let r = ClosedLoop::new("aes", 300).run(&mut sim, &fs);
    assert!(r.completed > 0, "closed loop completed nothing");
    (sim, fs, r.completed)
}

#[test]
fn stats_counters_obey_their_conservation_laws() {
    for backend in [Backend::Containerd, Backend::Junctiond] {
        let (sim, fs, completed) = drained(backend, 11);

        // NIC RX ring: everything accepted was delivered (ring drained).
        let ns: NicStats = fs.nic_stats();
        assert_eq!(ns.rx_enqueued, ns.rx_delivered, "{backend:?}: {ns:?}");
        assert!(ns.rx_delivered >= completed, "{backend:?}: {ns:?}");

        // TX ring: every accepted response left the worker.
        let tx: TxStats = fs.tx_stats();
        assert_eq!(tx.tx_enqueued, tx.tx_packets, "{backend:?}: {tx:?}");

        // Fabric: job conservation at quiesce, and the per-core busy
        // split must sum to the rollup (when the fabric keeps one).
        let fb: FabricStats = fs.fabric_stats();
        assert_eq!(fb.jobs_submitted, fb.jobs_completed, "{backend:?}: {fb:?}");
        if !fb.per_core_busy_ns.is_empty() {
            let split: u64 = fb.per_core_busy_ns.iter().sum();
            assert_eq!(split, fb.busy_ns, "{backend:?}: per-core split drifted: {fb:?}");
        }

        // Scheduler: cores cannot be released more often than granted.
        let ss: SchedulerStats = fs.scheduler_stats();
        assert!(ss.grants >= ss.releases, "{backend:?}: {ss:?}");

        // Warm pool: nothing leaves the pool that never entered it.
        let ps: PoolStats = fs.pool_stats();
        let left = ps.ttl_evictions + ps.lru_evictions + ps.flushes + ps.warm_hits;
        assert!(left <= ps.parks + ps.prewarms, "{backend:?}: {ps:?}");

        // Engine: live events fit in the slab's high-water capacity.
        let es: EngineStats = sim.engine_stats();
        assert!(es.pending <= es.slot_capacity, "{backend:?}: {es:?}");

        // And the structural walker agrees the node is lawful.
        let v = audit_all(&fs);
        assert!(v.is_empty(), "{backend:?}: audit_all found: {v:?}");
    }
}

#[test]
fn selfcheck_is_clean_after_all_audited_experiments() {
    for report in ex::selfcheck(30 * MILLIS, 17) {
        assert!(
            report.violations.is_empty(),
            "{} on {:?} left broken invariants: {:?}",
            report.scenario,
            report.backend,
            report.violations
        );
    }
}
