//! Conservation tests for every public `*Stats` block, plus the
//! end-to-end `selfcheck` run: after the audit-bearing experiments
//! (E5 / E11 / E14 / E15) finish on both backends, `audit_all` must find
//! nothing. detlint's `unaudited_stats` rule (L4) anchors here — each
//! counter struct is named below, so removing its coverage trips the
//! linter.

use std::cell::RefCell;
use std::rc::Rc;

use junctiond_repro::config::{Backend, ExperimentConfig, PlatformConfig};
use junctiond_repro::experiments as ex;
use junctiond_repro::faas::{Cluster, FaasSim, FunctionSpec, RecoveryStats, RuntimeKind};
use junctiond_repro::faultplane::{install, FaultSchedule, FaultStats};
use junctiond_repro::invariants::{audit_all, Audit};
use junctiond_repro::junction::SchedulerStats;
use junctiond_repro::junctiond::ManagerStats;
use junctiond_repro::netpath::{NicStats, TxStats};
use junctiond_repro::simcore::{EngineStats, FabricStats, Sim, MILLIS, SECONDS};
use junctiond_repro::snapshot::PoolStats;
use junctiond_repro::workload::{ClosedLoop, OpenLoop};

/// Drive a short closed loop to a drained quiesce point and return the
/// sim + node for counter inspection.
fn drained(backend: Backend, seed: u64) -> (Sim, FaasSim, u64) {
    let cfg = ExperimentConfig {
        backend,
        function_compute_ns: 100_000,
        seed,
        ..Default::default()
    };
    let mut sim = Sim::new();
    let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
    fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
    sim.run_until(SECONDS);
    let r = ClosedLoop::new("aes", 300).run(&mut sim, &fs);
    assert!(r.completed > 0, "closed loop completed nothing");
    (sim, fs, r.completed)
}

#[test]
fn stats_counters_obey_their_conservation_laws() {
    for backend in [Backend::Containerd, Backend::Junctiond] {
        let (sim, fs, completed) = drained(backend, 11);

        // NIC RX ring: everything accepted was delivered (ring drained).
        let ns: NicStats = fs.nic_stats();
        assert_eq!(ns.rx_enqueued, ns.rx_delivered, "{backend:?}: {ns:?}");
        assert!(ns.rx_delivered >= completed, "{backend:?}: {ns:?}");

        // TX ring: every accepted response left the worker.
        let tx: TxStats = fs.tx_stats();
        assert_eq!(tx.tx_enqueued, tx.tx_packets, "{backend:?}: {tx:?}");

        // Fabric: job conservation at quiesce, and the per-core busy
        // split must sum to the rollup (when the fabric keeps one).
        let fb: FabricStats = fs.fabric_stats();
        assert_eq!(fb.jobs_submitted, fb.jobs_completed, "{backend:?}: {fb:?}");
        if !fb.per_core_busy_ns.is_empty() {
            let split: u64 = fb.per_core_busy_ns.iter().sum();
            assert_eq!(split, fb.busy_ns, "{backend:?}: per-core split drifted: {fb:?}");
        }

        // Scheduler: cores cannot be released more often than granted.
        let ss: SchedulerStats = fs.scheduler_stats();
        assert!(ss.grants >= ss.releases, "{backend:?}: {ss:?}");

        // Warm pool: nothing leaves the pool that never entered it.
        let ps: PoolStats = fs.pool_stats();
        let left = ps.ttl_evictions + ps.lru_evictions + ps.flushes + ps.warm_hits;
        assert!(left <= ps.parks + ps.prewarms, "{backend:?}: {ps:?}");

        // Engine: live events fit in the slab's high-water capacity.
        let es: EngineStats = sim.engine_stats();
        assert!(es.pending <= es.slot_capacity, "{backend:?}: {es:?}");

        // And the structural walker agrees the node is lawful.
        let v = audit_all(&fs);
        assert!(v.is_empty(), "{backend:?}: audit_all found: {v:?}");
    }
}

#[test]
fn manager_crash_counters_conserve() {
    // The junctiond manager's crash ledger: every restart corresponds to
    // a crash (`restarted <= crashed`), and a crash mid-invocation leaves
    // the node lawful once the tier ladder re-provisions the function.
    let cfg = ExperimentConfig {
        backend: Backend::Junctiond,
        function_compute_ns: 100_000,
        seed: 23,
        ..Default::default()
    };
    let mut sim = Sim::new();
    let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
    fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
    sim.run_until(SECONDS);
    for _ in 0..5 {
        fs.submit(&mut sim, "aes", |_, _| {});
    }
    let fs2 = fs.clone();
    sim.after(10_000, move |sim| {
        fs2.crash_function(sim, "aes");
    });
    sim.run_to_completion();
    let ms: ManagerStats = fs.manager_stats();
    assert!(ms.crashed >= 1, "crash was not recorded: {ms:?}");
    assert!(ms.restarted <= ms.crashed, "restart without a crash: {ms:?}");
    let v = audit_all(&fs);
    assert!(v.is_empty(), "audit after crash recovery found: {v:?}");
}

#[test]
fn fault_schedule_conserves_requests_on_both_backends() {
    // The fault plane's end-to-end conservation law: under an active
    // schedule (instance crash + worker crash + gray + wire loss) with
    // the deadline/retry machinery on, every submitted request resolves
    // exactly once, and the full audit tree — including the fault plane's
    // own injection ledger — is clean afterwards.
    for backend in [Backend::Containerd, Backend::Junctiond] {
        let platform = Rc::new(PlatformConfig {
            deadline_timeout_ns: 20 * MILLIS,
            deadline_max_retries: 2,
            deadline_retry_backoff_ns: 20_000,
            nic_retry_jitter: 1,
            ..PlatformConfig::default()
        });
        let mut sim = Sim::new();
        let compute = platform.function_compute_ns;
        let mut c = Cluster::new_with_platform(backend, 2, 10, 13, compute, platform);
        c.policy.max_replicas = 2;
        c.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        c.scale_up(&mut sim, "aes");
        sim.run_until(SECONDS);
        let c = Rc::new(RefCell::new(c));
        let schedule = FaultSchedule::new()
            .instance_crash(SECONDS + 10 * MILLIS, 0, "aes")
            .worker_crash(SECONDS + 25 * MILLIS, 1)
            .gray(SECONDS + 35 * MILLIS, 0, 800, 15 * MILLIS)
            .wire_loss(SECONDS + 50 * MILLIS, 500, 15 * MILLIS);
        let faults = install(schedule, &mut sim, &c);
        let r = OpenLoop::new("aes", 4_000.0, 70 * MILLIS, 19).run_on(&mut sim, &c);
        assert_eq!(
            r.submitted,
            r.completed + r.dropped + r.timed_out,
            "{backend:?}: requests leaked under the fault schedule"
        );
        let fstats: FaultStats = *faults.borrow();
        assert_eq!(fstats.injected, 4, "{backend:?}: not every fault fired");
        fstats.assert_clean();
        let cl = c.borrow();
        let rec: RecoveryStats = cl.recovery_stats();
        assert!(rec.hedge_wins <= rec.hedges, "{backend:?}: {rec:?}");
        let v = audit_all(&*cl);
        assert!(v.is_empty(), "{backend:?}: audit found: {v:?}");
    }
}

#[test]
fn selfcheck_is_clean_after_all_audited_experiments() {
    for report in ex::selfcheck(30 * MILLIS, 17) {
        assert!(
            report.violations.is_empty(),
            "{} on {:?} left broken invariants: {:?}",
            report.scenario,
            report.backend,
            report.violations
        );
    }
}

#[test]
fn shard_map_covers_every_sim_module() {
    // The shard map is detlint's L5/L6 ground truth and the ROADMAP's
    // sharded-engine contract: every simulation module must carry a
    // declared shard domain, in the closed domain vocabulary. Parsed
    // with plain string ops here so the repro crate needs no dependency
    // on xtask.
    const SIM_MODULES: [&str; 10] = [
        "simcore",
        "faas",
        "netpath",
        "junction",
        "junctiond",
        "snapshot",
        "workload",
        "telemetry",
        "faultplane",
        "containerd_sim",
    ];
    const DOMAINS: [&str; 6] =
        ["per_worker", "gateway", "wire", "control", "global_readonly", "value"];
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/xtask/shard_map.toml");
    let src = std::fs::read_to_string(path).expect("xtask/shard_map.toml is checked in");
    let mut in_modules = false;
    let mut covered: Vec<(String, String)> = Vec::new();
    for raw in src.lines() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.starts_with('[') {
            in_modules = line == "[modules]";
            continue;
        }
        if !in_modules || line.is_empty() {
            continue;
        }
        let (k, v) = line.split_once('=').expect("module lines are `name = \"domain\"`");
        let domain = v.trim().trim_matches('"').to_string();
        covered.push((k.trim().to_string(), domain));
    }
    for m in SIM_MODULES {
        let hit = covered.iter().find(|(k, _)| k == m);
        let (_, domain) = hit.unwrap_or_else(|| panic!("module `{m}` missing from [modules]"));
        assert!(DOMAINS.contains(&domain.as_str()), "module `{m}` has unknown domain {domain:?}");
    }
    assert_eq!(covered.len(), SIM_MODULES.len(), "stale [modules] entries: {covered:?}");
}
