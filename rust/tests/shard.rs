//! Differential tests for the parallel shard runner (DESIGN.md §3j) —
//! the same technique as the PR 3 engine swap and PR 5 fabric swap: the
//! new path must be byte-identical to the old one at its degenerate
//! setting, and invariant across every setting that is not supposed to
//! change results.
//!
//! Three contracts are pinned here:
//!
//! 1. The E18 table is byte-identical across `--shards {1,2,4,8}`, across
//!    the serial (inline) and threaded transports, and across repeated
//!    same-seed runs — sharding moves wall clock only.
//! 2. The serial experiments (E5 polling, E11 netpath, quick E16
//!    resilience) render byte-identical tables before and after sharded
//!    runs execute in the same process: the shard runner must not perturb
//!    the serial `EngineKind` path's thread-local scheduling defaults.
//! 3. Conservation holds per shard and on the merged totals
//!    (`run_shard_cluster` folds `audit_all` per rack plus the merged
//!    gateway/rack conservation laws into one violation list).

use junctiond_repro::config::Backend;
use junctiond_repro::experiments as ex;
use junctiond_repro::faas::{run_shard_cluster, ShardClusterCfg};
use junctiond_repro::simcore::{ShardStats, MILLIS};

fn point(shards: usize, threaded: bool) -> ex::ShardScalePoint {
    let (workers, cores, functions, hot) = (4, 8, 128, 32);
    ex::shard_scale_run(
        Backend::Junctiond,
        shards,
        threaded,
        workers,
        cores,
        functions,
        hot,
        4_000.0,
        50 * MILLIS,
        13,
    )
}

/// Rendered table with the two legitimately varying cells (shard count,
/// transport) neutralized.
fn normalized(p: &ex::ShardScalePoint) -> String {
    let mut p = p.clone();
    p.shards = 0;
    p.transport = "-";
    ex::shard_scale_table(std::slice::from_ref(&p)).to_markdown()
}

#[test]
fn e18_table_identical_across_shard_counts() {
    let base = normalized(&point(1, false));
    for shards in [2, 4, 8] {
        assert_eq!(
            normalized(&point(shards, false)),
            base,
            "E18 table diverged at {shards} shards"
        );
    }
}

#[test]
fn e18_threaded_transport_matches_serial() {
    for shards in [1, 4] {
        assert_eq!(
            normalized(&point(shards, true)),
            normalized(&point(shards, false)),
            "transports diverged at {shards} shards"
        );
    }
}

#[test]
fn e18_same_seed_reruns_are_byte_identical() {
    let a = ex::shard_scale_table(std::slice::from_ref(&point(2, true))).to_markdown();
    let b = ex::shard_scale_table(std::slice::from_ref(&point(2, true))).to_markdown();
    assert_eq!(a, b, "same-seed threaded reruns diverged");
}

#[test]
fn sharded_runs_do_not_perturb_serial_experiments() {
    // Render the serial tables once, interleave sharded runs on both
    // transports, render again: every byte must survive. This is the
    // shards-1-vs-serial-EngineKind guarantee from the other side — the
    // shard runner captures its scheduling policy from the calling
    // thread and must never write anything back.
    let e5 = || ex::ablation_polling_table(&[1, 16], 5).to_markdown();
    let e11 = || {
        let rates = [1_000.0, 3_000.0];
        let (t, _) = ex::netpath_table(2, 10, &rates, &rates, 100 * MILLIS, 7);
        t.to_markdown()
    };
    let e16 = || ex::resilience_table(40 * MILLIS, 11).0.to_markdown();
    let (e5_before, e11_before, e16_before) = (e5(), e11(), e16());
    let _ = point(4, true);
    let _ = point(2, false);
    assert_eq!(e5(), e5_before, "E5 table changed after sharded runs");
    assert_eq!(e11(), e11_before, "E11 table changed after sharded runs");
    assert_eq!(e16(), e16_before, "quick E16 table changed after sharded runs");
}

#[test]
fn merged_audits_and_conservation_hold() {
    let out = run_shard_cluster(&ShardClusterCfg {
        backend: Backend::Junctiond,
        shards: 4,
        threaded: true,
        workers: 6,
        worker_cores: 8,
        functions: 128,
        hot_functions: 32,
        rate_rps: 6_000.0,
        duration: 50 * MILLIS,
        seed: 29,
    });
    assert!(out.audit_violations.is_empty(), "violations: {:?}", out.audit_violations);
    assert_eq!(
        out.gateway.submitted,
        out.gateway.completed + out.gateway.dropped + out.gateway.timed_out,
        "gateway lost requests"
    );
    assert_eq!(
        out.workers.iter().map(|w| w.completed).sum::<u64>(),
        out.gateway.completed,
        "rack completions disagree with the gateway ledger"
    );
    // The runner actually ran multi-shard: wire traffic crossed shards
    // and every shard observed the same barrier epochs.
    let stats: &[ShardStats] = &out.shard_stats;
    assert_eq!(stats.len(), 4);
    assert!(stats.iter().any(|s| s.msgs_out > 0), "no cross-shard traffic at 4 shards");
    assert!(stats.iter().all(|s| s.epochs == stats[0].epochs), "shards ran different epochs");
    assert!(stats.iter().all(|s| s.past_schedules == 0), "lookahead violated");
}
