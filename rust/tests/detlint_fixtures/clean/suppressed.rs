// Fixture: every would-be violation carries a reasoned suppression, so
// detlint must report nothing. Exercises both placements (same line,
// line above) for each lint that fires in fixture mode.

// detlint:allow(unordered_container, keys are drained sorted before any output)
use std::collections::HashMap;

pub fn scratch() {
    // detlint:allow(unordered_container, scratch map, populated and dropped, never iterated)
    let mut m = HashMap::new();
    m.insert(1u32, 1u64);
    let _ = m;
}

pub fn wall_report() -> u64 {
    let t0 = std::time::Instant::now(); // detlint:allow(wall_clock, host-side report only)
    t0.elapsed().as_nanos() as u64
}

// detlint:allow(raw_event_key, not an event key; total order is over plain u64)
impl Ord for Pair {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.cmp(&other.0)
    }
}

pub struct Pair(pub u64, pub u64);
