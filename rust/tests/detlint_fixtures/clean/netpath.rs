// Fixture: the wire seam may mutate per_worker state owned elsewhere —
// that is exactly what "crossing the netpath seam" means, so L6 must
// stay quiet here.

pub fn deliver(q: &Rc<RefCell<WorkerQueue>>) {
    q.borrow_mut().depth += 1;
}
