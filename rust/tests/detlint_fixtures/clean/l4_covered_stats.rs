// Fixture: a `pub struct *Stats` that L4 accepts — a conservation test
// in the file's #[cfg(test)] tail names it, so nothing drifts unchecked.

pub struct CoveredStats {
    pub enqueued: u64,
    pub delivered: u64,
}

pub fn bump(s: &mut CoveredStats) {
    s.enqueued += 1;
    s.delivered += 1;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covered_stats_conserve() {
        let mut s = CoveredStats { enqueued: 0, delivered: 0 };
        bump(&mut s);
        assert_eq!(s.enqueued, s.delivered);
    }
}
