// Fixture: defines the per_worker WorkerQueue that the netpath seam
// fixture delivers into.

pub struct WorkerQueue {
    pub depth: u64,
}
