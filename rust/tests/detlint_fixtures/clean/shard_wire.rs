// Fixture: a per_worker module may stage frames into a `wire`-domain
// ShardOutbox owned elsewhere — that is the sanctioned inter-shard
// channel seam (the fixture twin of simcore's ShardNet), so L6 must
// stay quiet here.

pub fn stage(outbox: &Rc<RefCell<ShardOutbox>>) {
    outbox.borrow_mut().frames += 1;
}
