// Fixture: a cross-module shared handle that IS declared in
// shard_map.toml — L5 stays quiet, and a gateway-domain mutation is
// not a cross-shard hazard.

pub fn credit(ledger: &Rc<RefCell<SharedLedger>>) {
    ledger.borrow_mut().total += 1;
}
