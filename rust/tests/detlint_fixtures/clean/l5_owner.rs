// Fixture: owning side of the declared-state pair — defines the
// SharedLedger that `l5_declared_handle.rs` holds across the module
// boundary.

pub struct SharedLedger {
    pub total: u64,
}
