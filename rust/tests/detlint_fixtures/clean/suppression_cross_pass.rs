// Fixture: regression for the unified suppression pass — an allow
// consumed by the graph-based L5 lint must not be reported stale by
// any later pass.

// detlint:allow(undeclared_shared_state, staged migration to a declared domain)
pub fn adopt(orphan: Rc<RefCell<OrphanLedger>>) -> u64 {
    orphan.borrow().total
}
