// Fixture: deliberately tied schedules carrying `tie-break:` ordering
// rationales — L7 must stay quiet.

pub fn fan_out(sim: &mut Sim, base: u64) {
    for worker in 0..4u32 {
        // tie-break: all workers wake together; each touches only its
        // own queue, so the firing order among them is immaterial.
        sim.at(base, move |s| poke(s, worker));
    }
    // tie-break: defer the drain behind the same-instant submissions.
    sim.after(0, drain);
}
