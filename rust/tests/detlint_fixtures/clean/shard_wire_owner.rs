// Fixture: defines the wire-domain ShardOutbox that `shard_wire.rs`
// stages frames into.

pub struct ShardOutbox {
    pub frames: u64,
}
