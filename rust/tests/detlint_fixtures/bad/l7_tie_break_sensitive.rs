// Fixture: trips `tie_break_sensitive` (L7) both ways and nothing
// else — a fan-out loop scheduling every worker at one instant with no
// ordering rationale, and an immediate .after(0) kick.

pub fn storm(sim: &mut Sim, base: u64) {
    for worker in 0..4u32 {
        sim.at(base, move |s| poke(s, worker));
    }
    sim.after(0, drain);
}
