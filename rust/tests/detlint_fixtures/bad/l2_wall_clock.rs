// Fixture: trips `wall_clock` (L2) four ways and nothing else.

use std::time::Instant;

pub fn leak_host_state() -> u64 {
    let t0 = Instant::now();
    let _boot = std::time::SystemTime::now();
    let _cfg = std::env::var("JUNCTIOND_SECRET_KNOB");
    t0.elapsed().as_nanos() as u64
}

pub fn leak_entropy() -> u64 {
    let mut rng = rand::thread_rng();
    rng.next_u64()
}
