// Fixture: trips `cross_shard_mut` (L6) for the inter-shard channel
// type — a per_worker module draining another per_worker module's
// ShardInbox through a shared handle instead of letting the shard
// runner's wire seam deliver the frames. The handle is declared in
// shard_map.toml, so L5 stays quiet.

pub fn drain(inbox: &Rc<RefCell<ShardInbox>>) {
    inbox.borrow_mut().frames -= 1;
}
