// Fixture: trips `cross_shard_mut` (L6) and nothing else — a
// per_worker module mutating per_worker state owned by another module
// without crossing the netpath wire seam. The handle itself is
// declared in shard_map.toml, so L5 stays quiet.

pub fn steal_work(q: &Rc<RefCell<RemoteQueue>>) {
    q.borrow_mut().depth -= 1;
}
