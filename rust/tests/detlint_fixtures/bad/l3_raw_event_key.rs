// Fixture: trips `raw_event_key` (L3) both ways and nothing else.

use std::collections::BinaryHeap;

pub struct Deadline {
    pub at: f64,
}

impl PartialOrd for Deadline {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        self.at.partial_cmp(&other.at)
    }
}

impl Ord for Deadline {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap()
    }
}

pub fn pending() -> BinaryHeap<(f64, u64)> {
    BinaryHeap::new()
}
