// Fixture: trips `unaudited_stats` (L4) and nothing else — a public
// counter block that no conservation test or audit body ever reads.

pub struct OrphanStats {
    pub enqueued: u64,
    pub delivered: u64,
}

pub fn bump(s: &mut OrphanStats) {
    s.enqueued += 1;
}
