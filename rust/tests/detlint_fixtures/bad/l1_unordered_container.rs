// Fixture: trips `unordered_container` (L1) and nothing else.
// Not compiled by cargo — tests/ subdirectories are not test targets;
// detlint lexes it in fixture mode (every file classed as a sim module).

use std::collections::HashMap;

pub fn instance_table() -> HashMap<u32, u64> {
    let mut m = HashMap::new();
    m.insert(1, 10);
    m
}
