// Fixture: the owning side of the L6 pair — defines RemoteQueue, the
// per_worker state `l6_cross_shard_mut.rs` reaches into. Clean itself.

pub struct RemoteQueue {
    pub depth: u64,
}
