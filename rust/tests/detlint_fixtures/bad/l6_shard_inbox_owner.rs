// Fixture: the owning side of the shard-channel L6 pair — defines the
// per_worker ShardInbox that `l6_shard_inbox.rs` reaches into. Clean
// itself.

pub struct ShardInbox {
    pub frames: u64,
}
