// Fixture: pins lexer hardening — a violation AFTER a nested block
// comment and a raw byte string must still fire, at the right line.

/* outer /* nested HashMap Instant */ still one stripped comment */
pub fn payload() -> &'static [u8] {
    br#"SystemTime " thread_rng"#
}

use std::collections::HashSet;
