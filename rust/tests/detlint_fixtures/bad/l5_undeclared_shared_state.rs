// Fixture: trips `undeclared_shared_state` (L5) and nothing else — a
// cross-module shared handle with no [state.*] entry in the shard map.

pub fn attach(ghost: Rc<RefCell<GhostTable>>) -> u64 {
    ghost.borrow().len() as u64
}
