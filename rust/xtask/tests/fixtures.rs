//! Fixture-driven end-to-end tests for detlint: every bad snippet trips
//! exactly its lint at the expected lines, every clean snippet (used
//! suppressions, covered stats, declared handles, rationale'd ties)
//! reports nothing, and the repo itself is clean — the same invocation
//! CI gates on.

use std::collections::BTreeSet;
use std::path::Path;

use xtask::lints::{self, Violation};
use xtask::scan;

fn fixture_dir(kind: &str) -> std::path::PathBuf {
    scan::crate_root().join("tests").join("detlint_fixtures").join(kind)
}

/// Lint one fixture dir the way `cargo xtask detlint --path` does:
/// every file a sim module, the dir's `shard_map.toml` (if any) loaded.
fn lint_dir(kind: &str) -> Vec<Violation> {
    let dir = fixture_dir(kind);
    let files = scan::collect_dir(&dir).expect("fixtures present");
    let map = lints::load_map(&dir.join("shard_map.toml")).expect("fixture map parses");
    lints::run(&files, map.as_ref())
}

fn lint_lines(violations: &[Violation], file: &str) -> (BTreeSet<&'static str>, BTreeSet<u32>) {
    let mut lints = BTreeSet::new();
    let mut lines = BTreeSet::new();
    for v in violations.iter().filter(|v| v.file == Path::new(file)) {
        lints.insert(v.lint);
        lines.insert(v.line);
    }
    (lints, lines)
}

#[test]
fn bad_fixtures_each_trip_exactly_their_lint() {
    let v = lint_dir("bad");

    let (lints, lines) = lint_lines(&v, "l1_unordered_container.rs");
    assert_eq!(lints.into_iter().collect::<Vec<_>>(), ["unordered_container"]);
    assert_eq!(lines.into_iter().collect::<Vec<_>>(), [5, 7, 8]);

    let (lints, lines) = lint_lines(&v, "l2_wall_clock.rs");
    assert_eq!(lints.into_iter().collect::<Vec<_>>(), ["wall_clock"]);
    assert_eq!(lines.into_iter().collect::<Vec<_>>(), [3, 6, 7, 8, 13]);

    let (lints, lines) = lint_lines(&v, "l3_raw_event_key.rs");
    assert_eq!(lints.into_iter().collect::<Vec<_>>(), ["raw_event_key"]);
    assert_eq!(lines.into_iter().collect::<Vec<_>>(), [9, 15, 21]);

    let (lints, lines) = lint_lines(&v, "l4_unaudited_stats.rs");
    assert_eq!(lints.into_iter().collect::<Vec<_>>(), ["unaudited_stats"]);
    assert_eq!(lines.into_iter().collect::<Vec<_>>(), [4]);

    let (lints, lines) = lint_lines(&v, "l5_undeclared_shared_state.rs");
    assert_eq!(lints.into_iter().collect::<Vec<_>>(), ["undeclared_shared_state"]);
    assert_eq!(lines.into_iter().collect::<Vec<_>>(), [4]);

    let (lints, lines) = lint_lines(&v, "l6_cross_shard_mut.rs");
    assert_eq!(lints.into_iter().collect::<Vec<_>>(), ["cross_shard_mut"]);
    assert_eq!(lines.into_iter().collect::<Vec<_>>(), [7]);

    // The inter-shard channel pair: draining a peer shard's inbox through
    // a shared handle is the same disease at the runner's boundary.
    let (lints, lines) = lint_lines(&v, "l6_shard_inbox.rs");
    assert_eq!(lints.into_iter().collect::<Vec<_>>(), ["cross_shard_mut"]);
    assert_eq!(lines.into_iter().collect::<Vec<_>>(), [8]);

    let (lints, lines) = lint_lines(&v, "l7_tie_break_sensitive.rs");
    assert_eq!(lints.into_iter().collect::<Vec<_>>(), ["tie_break_sensitive"]);
    assert_eq!(lines.into_iter().collect::<Vec<_>>(), [7, 9]);

    // Lexer hardening: the violation after a nested block comment and a
    // raw byte string fires at the right line, and nothing leaks out of
    // the stripped regions.
    let (lints, lines) = lint_lines(&v, "lexer_hardening.rs");
    assert_eq!(lints.into_iter().collect::<Vec<_>>(), ["unordered_container"]);
    assert_eq!(lines.into_iter().collect::<Vec<_>>(), [9]);

    // The owning-side helpers of the L6 pairs are themselves clean.
    let (lints, _) = lint_lines(&v, "l6_owner.rs");
    assert!(lints.is_empty(), "{v:#?}");
    let (lints, _) = lint_lines(&v, "l6_shard_inbox_owner.rs");
    assert!(lints.is_empty(), "{v:#?}");

    // Nothing beyond the fixture files, and every violation renders
    // as a clickable file:line diagnostic.
    assert_eq!(v.len(), 18, "{v:#?}");
    for violation in &v {
        let s = violation.to_string();
        let expect =
            format!("{}:{}: {}:", violation.file.display(), violation.line, violation.lint);
        assert!(s.starts_with(&expect), "diagnostic {s:?} lacks file:line prefix");
    }
}

#[test]
fn clean_fixtures_report_nothing() {
    let v = lint_dir("clean");
    assert!(v.is_empty(), "clean fixtures must lint clean, got:\n{v:#?}");
}

#[test]
fn unused_and_malformed_allows_are_violations() {
    let dir = fixture_dir("clean");
    let mut files = scan::collect_dir(&dir).expect("clean fixtures present");
    // Append a synthetic fixture in-memory: a stale allow and a reasonless
    // one must each surface rather than rot silently.
    let src = "// detlint:allow(wall_clock, stale)\nlet x = 1;\n// detlint:allow(wall_clock)\n";
    files.push(xtask::lints::SourceFile {
        path: "synthetic.rs".into(),
        class: Default::default(),
        module: None,
        lexed: xtask::lexer::lex(src),
    });
    let map = lints::load_map(&dir.join("shard_map.toml")).expect("fixture map parses");
    let v = lints::run(&files, map.as_ref());
    let (lints, lines) = lint_lines(&v, "synthetic.rs");
    assert_eq!(lints.into_iter().collect::<Vec<_>>(), ["bad_allow", "unused_allow"]);
    assert_eq!(lines.into_iter().collect::<Vec<_>>(), [1, 3]);
}

#[test]
fn repo_is_detlint_clean() {
    let root = scan::crate_root();
    let files = scan::collect_repo(&root).expect("repo readable");
    assert!(files.len() > 30, "repo walk looks truncated: {} files", files.len());
    let map = lints::load_map(&scan::repo_shard_map(&root))
        .expect("repo shard map parses")
        .expect("xtask/shard_map.toml is checked in");
    let v = lints::run(&files, Some(&map));
    assert!(v.is_empty(), "the repo must hold its own discipline, got:\n{v:#?}");
}

#[test]
fn repo_graph_sees_the_declared_cross_module_handles() {
    // The state-access graph is what L5 keys on; pin that it discovers
    // the two real cross-module handles (faultplane/workload → Cluster,
    // faas → Rng) so the lint can't go vacuously green.
    let files = scan::collect_repo(&scan::crate_root()).expect("repo readable");
    let g = xtask::graph::StateGraph::build(&files);
    assert_eq!(g.def_site("Cluster"), Some("faas"));
    assert_eq!(g.def_site("Rng"), Some("simcore"));
    let holds = |m: &str, ty: &str| {
        g.modules.get(m).is_some_and(|acc| acc.handles.iter().any(|h| h.inner == ty))
    };
    assert!(holds("faultplane", "Cluster"), "graph lost faultplane's Cluster handle");
    assert!(holds("workload", "Cluster"), "graph lost workload's Cluster handle");
    assert!(holds("faas", "Rng"), "graph lost faas's fault_rng handle");
}
