//! Fixture-driven end-to-end tests for detlint: every bad snippet trips
//! exactly its lint at the expected lines, every clean snippet (used
//! suppressions, covered stats) reports nothing, and the repo itself is
//! clean — the same invocation CI gates on.

use std::collections::BTreeSet;
use std::path::Path;

use xtask::lints::{self, Violation};
use xtask::scan;

fn fixture_dir(kind: &str) -> std::path::PathBuf {
    scan::crate_root().join("tests").join("detlint_fixtures").join(kind)
}

fn lint_lines(violations: &[Violation], file: &str) -> (BTreeSet<&'static str>, BTreeSet<u32>) {
    let mut lints = BTreeSet::new();
    let mut lines = BTreeSet::new();
    for v in violations.iter().filter(|v| v.file == Path::new(file)) {
        lints.insert(v.lint);
        lines.insert(v.line);
    }
    (lints, lines)
}

#[test]
fn bad_fixtures_each_trip_exactly_their_lint() {
    let files = scan::collect_dir(&fixture_dir("bad")).expect("bad fixtures present");
    let v = lints::run(&files);

    let (lints, lines) = lint_lines(&v, "l1_unordered_container.rs");
    assert_eq!(lints.into_iter().collect::<Vec<_>>(), ["unordered_container"]);
    assert_eq!(lines.into_iter().collect::<Vec<_>>(), [5, 7, 8]);

    let (lints, lines) = lint_lines(&v, "l2_wall_clock.rs");
    assert_eq!(lints.into_iter().collect::<Vec<_>>(), ["wall_clock"]);
    assert_eq!(lines.into_iter().collect::<Vec<_>>(), [3, 6, 7, 8, 13]);

    let (lints, lines) = lint_lines(&v, "l3_raw_event_key.rs");
    assert_eq!(lints.into_iter().collect::<Vec<_>>(), ["raw_event_key"]);
    assert_eq!(lines.into_iter().collect::<Vec<_>>(), [9, 15, 21]);

    let (lints, lines) = lint_lines(&v, "l4_unaudited_stats.rs");
    assert_eq!(lints.into_iter().collect::<Vec<_>>(), ["unaudited_stats"]);
    assert_eq!(lines.into_iter().collect::<Vec<_>>(), [4]);

    // Nothing beyond the four fixture files, and every violation renders
    // as a clickable file:line diagnostic.
    assert_eq!(v.len(), 12, "{v:#?}");
    for violation in &v {
        let s = violation.to_string();
        let expect =
            format!("{}:{}: {}:", violation.file.display(), violation.line, violation.lint);
        assert!(s.starts_with(&expect), "diagnostic {s:?} lacks file:line prefix");
    }
}

#[test]
fn clean_fixtures_report_nothing() {
    let files = scan::collect_dir(&fixture_dir("clean")).expect("clean fixtures present");
    let v = lints::run(&files);
    assert!(v.is_empty(), "clean fixtures must lint clean, got:\n{v:#?}");
}

#[test]
fn unused_and_malformed_allows_are_violations() {
    let dir = fixture_dir("clean");
    let mut files = scan::collect_dir(&dir).expect("clean fixtures present");
    // Append a synthetic fixture in-memory: a stale allow and a reasonless
    // one must each surface rather than rot silently.
    let src = "// detlint:allow(wall_clock, stale)\nlet x = 1;\n// detlint:allow(wall_clock)\n";
    files.push(xtask::lints::SourceFile {
        path: "synthetic.rs".into(),
        class: Default::default(),
        lexed: xtask::lexer::lex(src),
    });
    let v = lints::run(&files);
    let (lints, lines) = lint_lines(&v, "synthetic.rs");
    assert_eq!(lints.into_iter().collect::<Vec<_>>(), ["bad_allow", "unused_allow"]);
    assert_eq!(lines.into_iter().collect::<Vec<_>>(), [1, 3]);
}

#[test]
fn repo_is_detlint_clean() {
    let files = scan::collect_repo(&scan::crate_root()).expect("repo readable");
    assert!(files.len() > 30, "repo walk looks truncated: {} files", files.len());
    let v = lints::run(&files);
    assert!(v.is_empty(), "the repo must hold its own discipline, got:\n{v:#?}");
}
