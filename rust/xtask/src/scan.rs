//! File collection + classification for detlint.
//!
//! Repo mode walks the crate the way CI builds it: `src/**`, `tests/*.rs`
//! (minus the deliberately-bad `detlint_fixtures`), `benches/**`, and
//! `xtask/src/**`. Fixture mode (`--path DIR`) walks one directory and
//! treats every file as a simulation module with stats definitions, so a
//! fixture snippet can trip any lint without replicating the repo layout.
//!
//! Module attribution for the state-access graph: in repo mode a sim
//! file's module is its top-level directory (or file stem) under `src/`;
//! in fixture mode every file is its own module, named by its stem, so a
//! fixture `shard_map.toml` can declare cross-"module" state between two
//! sibling fixture files.

use std::io;
use std::path::{Path, PathBuf};

use crate::lexer::lex;
use crate::lints::{FileClass, SourceFile};

/// The modules whose state or output is part of the simulation timeline;
/// L1/L3/L7 apply here, and the shard-safety graph (L5/L6) is built over
/// exactly this set. Mirrors the list in ISSUE/DESIGN §3g/§3i.
pub const SIM_MODULES: [&str; 10] = [
    "simcore",
    "faas",
    "netpath",
    "junction",
    "junctiond",
    "snapshot",
    "workload",
    "telemetry",
    "faultplane",
    "containerd_sim",
];

/// Crate root (`rust/`), derived from xtask's own manifest dir so the
/// lint runs from any working directory.
pub fn crate_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("xtask sits inside rust/").to_path_buf()
}

/// The checked-in repo shard map consulted by L5/L6.
pub fn repo_shard_map(root: &Path) -> PathBuf {
    root.join("xtask").join("shard_map.toml")
}

/// Collect + lex every analyzable file of the repo rooted at `root`.
pub fn collect_repo(root: &Path) -> io::Result<Vec<SourceFile>> {
    let mut files = Vec::new();
    walk(&root.join("src"), &mut |p| {
        let (class, module) = classify_src(root, p);
        load(root, p, class, module, &mut files);
    })?;
    walk(&root.join("tests"), &mut |p| {
        if !p.components().any(|c| c.as_os_str() == "detlint_fixtures") {
            let class = FileClass { audited: true, ..FileClass::default() };
            load(root, p, class, None, &mut files);
        }
    })?;
    walk(&root.join("benches"), &mut |p| {
        let class = FileClass { audited: true, ..FileClass::default() };
        load(root, p, class, None, &mut files);
    })?;
    walk(&root.join("xtask").join("src"), &mut |p| {
        load(root, p, FileClass::default(), None, &mut files);
    })?;
    Ok(files)
}

/// Fixture mode: every `.rs` under `dir`, each treated as a simulation
/// module (named by its file stem) with stats definitions so all lints
/// are live.
pub fn collect_dir(dir: &Path) -> io::Result<Vec<SourceFile>> {
    let mut files = Vec::new();
    walk(dir, &mut |p| {
        let class = FileClass { sim: true, stats_defs: true, ..FileClass::default() };
        let module = p.file_stem().map(|s| s.to_string_lossy().into_owned());
        load(dir, p, class, module, &mut files);
    })?;
    if files.is_empty() {
        return Err(io::Error::new(
            io::ErrorKind::NotFound,
            format!("no .rs files under {}", dir.display()),
        ));
    }
    Ok(files)
}

fn classify_src(root: &Path, p: &Path) -> (FileClass, Option<String>) {
    let rel = p.strip_prefix(root).unwrap_or(p);
    let mut parts = rel.components().skip(1); // skip "src"
    let first = parts.next().map(|c| c.as_os_str().to_string_lossy().into_owned());
    let Some(first) = first else {
        return (FileClass { stats_defs: true, ..FileClass::default() }, None);
    };
    let module = first.trim_end_matches(".rs");
    let sim = SIM_MODULES.contains(&module);
    let class = FileClass {
        sim,
        hostclock: rel == Path::new("src/hostclock.rs"),
        stats_defs: true,
        audited: false,
    };
    (class, sim.then(|| module.to_string()))
}

fn load(
    base: &Path,
    p: &Path,
    class: FileClass,
    module: Option<String>,
    files: &mut Vec<SourceFile>,
) {
    let src = match std::fs::read_to_string(p) {
        Ok(s) => s,
        Err(_) => return, // non-UTF8 or vanished; rustc will complain, not us
    };
    let shown = p.strip_prefix(base).unwrap_or(p).to_path_buf();
    files.push(SourceFile { path: shown, class, module, lexed: lex(&src) });
}

/// Depth-first walk over `.rs` files in sorted order (read_dir order is
/// platform-dependent; diagnostics must be stable).
fn walk(dir: &Path, f: &mut dyn FnMut(&Path)) -> io::Result<()> {
    if !dir.exists() {
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = Vec::new();
    for e in std::fs::read_dir(dir)? {
        entries.push(e?.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, f)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            f(&p);
        }
    }
    Ok(())
}
